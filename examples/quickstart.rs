//! Quickstart: offload one KNN batch through all four mechanisms and
//! print the end-to-end comparison (and, if `make artifacts` has run,
//! execute the actual offloaded kernel through PJRT).
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```

use axle::config::{Protocol, SimConfig};
use axle::sim::ps_to_us;
use axle::Coordinator;

fn main() -> anyhow::Result<()> {
    // Table III hardware, Table IV workload (a): KNN, Dim 2048, 128 rows.
    let cfg = SimConfig::m2ndp();
    let coord = Coordinator::new(cfg);

    println!("AXLE quickstart — KNN (Dim 2048, Rows 128), Table III hardware\n");
    println!(
        "{:<16} {:>12} {:>8} {:>8} {:>8} {:>10}",
        "mechanism", "total (us)", "T_C%", "T_D%", "T_H%", "host stall"
    );
    let mut baseline = None;
    for p in Protocol::ALL {
        let m = coord.run('a', p);
        let base = *baseline.get_or_insert(m.total);
        println!(
            "{:<16} {:>12.2} {:>7.1}% {:>7.1}% {:>7.1}% {:>9.1}%   ({:.2}x vs RP)",
            m.protocol,
            ps_to_us(m.total),
            100.0 * m.frac(m.ccm_busy),
            100.0 * m.frac(m.dm_busy),
            100.0 * m.frac(m.host_busy),
            100.0 * m.frac(m.host_stall_clamped()),
            m.total as f64 / base as f64,
        );
    }

    // If the AOT artifacts exist, run the real offloaded numerics too.
    if std::path::Path::new("artifacts/manifest.json").exists() {
        println!("\nValidating the offloaded kernel's numerics through PJRT...");
        let mut coord = Coordinator::new(SimConfig::m2ndp()).with_artifacts("artifacts")?;
        let r = coord.validate_numerics('a')?;
        println!(
            "  {:?}: {} checks, max rel err {:.2e} — the Pallas distance kernel",
            r.artifacts, r.checks, r.max_rel_err
        );
        println!("  and the top-k host task agree with the Rust reference.");
    } else {
        println!("\n(run `make artifacts` to also execute the offloaded kernels via PJRT)");
    }
    Ok(())
}
