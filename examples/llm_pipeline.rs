//! LLM inference with attention offload: a batched decode pipeline where
//! the CCM runs the attention block (LayerNormQ → QKVProj → Attention →
//! OutProj → Residual, the paper's Fig. 3 kernel order) and the host runs
//! the MLP — including the paper's two hardware scenarios (Fig. 10h /
//! Fig. 11) and a real multi-layer decode through the PJRT artifacts.
//!
//! ```sh
//! make artifacts && cargo run --release --example llm_pipeline
//! ```

use anyhow::Result;
use axle::config::{poll_factors, Protocol, SimConfig};
use axle::runtime::{prand_f32, Runtime};
use axle::sim::ps_to_us;
use axle::{protocol, workload};

fn main() -> Result<()> {
    // ------------------------------------------------------------------
    // 1. Timing: why attention offload is marginal on big hosts (Fig. 10h)
    //    but wins when the host can't batch all requests (Fig. 11).
    // ------------------------------------------------------------------
    for (label, cfg) in [
        ("Table III baseline", SimConfig::m2ndp().with_poll(poll_factors::P10)),
        ("reduced PUs (Fig. 11)", SimConfig::reduced().with_poll(poll_factors::P10)),
    ] {
        let w = workload::by_annotation('h', &cfg);
        let rp = protocol::run(Protocol::Rp, &w, &cfg);
        let ax = protocol::run(Protocol::Axle, &w, &cfg);
        println!(
            "{label:<22} RP {:>12.1} us | AXLE {:>12.1} us  ({:.2}% of RP)",
            ps_to_us(rp.total),
            ps_to_us(ax.total),
            100.0 * ax.ratio_to(&rp)
        );
    }
    println!();

    // Per-kernel duality (Fig. 3): which attention kernels suffer under RP.
    let cfg = SimConfig::m2ndp();
    println!("attention kernels, BS/RP cycle ratio (Fig. 3):");
    for k in workload::llm::AttnKernel::ALL {
        let w = workload::llm::single_kernel(&cfg, k);
        let rp = protocol::run(Protocol::Rp, &w, &cfg);
        let bs = protocol::run(Protocol::Bs, &w, &cfg);
        println!(
            "  {:<12} {:>6.3} ({})",
            k.label(),
            bs.total as f64 / rp.total as f64,
            if k.is_heavy() { "heavy" } else { "light" }
        );
    }
    println!();

    // ------------------------------------------------------------------
    // 2. Numerics: an actual multi-layer decode step through PJRT.
    // ------------------------------------------------------------------
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        println!("(run `make artifacts` for the decode numerics)");
        return Ok(());
    }
    let mut rt = Runtime::new("artifacts")?;
    let attn = rt.entry("llm_attn_ccm")?.clone();
    let hidden = attn.inputs[0].shape[1];
    let (heads, tokens, hd) = (
        attn.inputs[1].shape[0],
        attn.inputs[1].shape[1],
        attn.inputs[1].shape[2],
    );
    let ffn = rt.entry("llm_mlp_host")?.inputs[1].shape[1];
    println!(
        "decoding through {} transformer layers (hidden {hidden}, {heads} heads, {tokens}-token cache, ffn {ffn}):",
        8
    );

    // Deterministic per-layer weights (exec-scale model).
    let scale = 0.03f32;
    let mut x: Vec<f32> = prand_f32(hidden, 1).iter().map(|v| v * 0.1).collect();
    for layer in 0..8u64 {
        let s = 100 + layer * 10;
        let kc: Vec<f32> = prand_f32(heads * tokens * hd, s + 1).iter().map(|v| v * 0.1).collect();
        let vc: Vec<f32> = prand_f32(heads * tokens * hd, s + 2).iter().map(|v| v * 0.1).collect();
        let wqkv: Vec<f32> = prand_f32(hidden * 3 * hidden, s + 3).iter().map(|v| v * scale).collect();
        let wo: Vec<f32> = prand_f32(hidden * hidden, s + 4).iter().map(|v| v * scale).collect();
        let ln_g = vec![1.0f32; hidden];
        let ln_b = vec![0.0f32; hidden];
        // CCM half: the attention block.
        let attn_out = rt.execute_f32(
            "llm_attn_ccm",
            &[&x, &kc, &vc, &wqkv, &wo, &ln_g, &ln_b],
        )?;
        // Host half: the MLP.
        let w1: Vec<f32> = prand_f32(hidden * ffn, s + 5).iter().map(|v| v * scale).collect();
        let b1 = vec![0.0f32; ffn];
        let w2: Vec<f32> = prand_f32(ffn * hidden, s + 6).iter().map(|v| v * scale).collect();
        let b2 = vec![0.0f32; hidden];
        let out = rt.execute_f32("llm_mlp_host", &[&attn_out[0], &w1, &b1, &w2, &b2])?;
        x = out.into_iter().next().unwrap();
        let norm: f32 = x.iter().map(|v| v * v).sum::<f32>().sqrt();
        assert!(norm.is_finite(), "activations diverged");
        println!("  layer {layer}: |h| = {norm:.4}");
    }
    println!("decode OK — all layers finite through the offloaded attention path");
    Ok(())
}
