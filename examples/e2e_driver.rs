//! End-to-end driver: the full-system proof that every layer composes.
//!
//! For each of the nine Table IV workloads it:
//!  1. executes the offloaded function's **real numerics** through the
//!     AOT-compiled JAX/Pallas artifacts on the PJRT CPU client (CCM half
//!     *and* host half, checked against Rust references), then
//!  2. runs the paper-scale **timing simulation** under RP, BS,
//!     AXLE_Interrupt and AXLE (p1/p10/p100), and
//!  3. reports the paper's headline metrics: end-to-end runtime
//!     reduction, the two idle times, and host core stall time.
//!
//! Results of a full run are recorded in EXPERIMENTS.md.
//!
//! ```sh
//! make artifacts && cargo run --release --example e2e_driver
//! ```

use anyhow::Result;
use axle::config::{poll_factors, Protocol, SimConfig};
use axle::metrics::{mean, RunMetrics};
use axle::sim::ps_to_us;
use axle::workload::ALL_ANNOTATIONS;
use axle::{protocol, workload, Coordinator};

fn main() -> Result<()> {
    println!("=== AXLE end-to-end driver ===\n");

    // ---------------------------------------------------------------
    // Phase 1: numerics through all three layers.
    // ---------------------------------------------------------------
    let have_artifacts = std::path::Path::new("artifacts/manifest.json").exists();
    if have_artifacts {
        println!("[1/2] offloaded-function numerics via PJRT artifacts");
        let mut coord = Coordinator::new(SimConfig::m2ndp()).with_artifacts("artifacts")?;
        let mut total_checks = 0;
        for a in ALL_ANNOTATIONS {
            let r = coord.validate_numerics(a)?;
            total_checks += r.checks;
            println!(
                "  ({a}) {:<34} {:>8} checks, max rel err {:.2e}",
                format!("{:?}", r.artifacts),
                r.checks,
                r.max_rel_err
            );
        }
        println!("  all nine workloads verified ({total_checks} checks)\n");
    } else {
        println!("[1/2] SKIPPED — run `make artifacts` to enable numerics validation\n");
    }

    // ---------------------------------------------------------------
    // Phase 2: paper-scale timing across the protocol matrix.
    // ---------------------------------------------------------------
    println!("[2/2] timing simulation (Table III hardware, paper-scale workloads)");
    let cfg = SimConfig::m2ndp();
    println!(
        "\n{:<4} {:>10} {:>9} {:>10} {:>8} {:>8} {:>8}   {}",
        "WL", "RP (us)", "BS", "AXLE_Int", "p1", "p10", "p100", "(normalized to RP)"
    );
    let mut reductions_rp = Vec::new();
    let mut reductions_bs = Vec::new();
    let mut rows: Vec<(char, RunMetrics, RunMetrics, RunMetrics)> = Vec::new();
    for a in ALL_ANNOTATIONS {
        let w = workload::by_annotation(a, &cfg);
        let rp = protocol::run(Protocol::Rp, &w, &cfg);
        let bs = protocol::run(Protocol::Bs, &w, &cfg);
        let int = protocol::run(Protocol::AxleInterrupt, &w, &cfg);
        let p1 = protocol::run(Protocol::Axle, &w, &cfg.clone().with_poll(poll_factors::P1));
        let p10 = protocol::run(Protocol::Axle, &w, &cfg.clone().with_poll(poll_factors::P10));
        let p100 = protocol::run(Protocol::Axle, &w, &cfg.clone().with_poll(poll_factors::P100));
        println!(
            "({a})  {:>10.1} {:>8.1}% {:>9.1}% {:>7.1}% {:>7.1}% {:>7.1}%",
            ps_to_us(rp.total),
            100.0 * bs.ratio_to(&rp),
            100.0 * int.ratio_to(&rp),
            100.0 * p1.ratio_to(&rp),
            100.0 * p10.ratio_to(&rp),
            100.0 * p100.ratio_to(&rp),
        );
        reductions_rp.push(1.0 - p1.ratio_to(&rp));
        reductions_bs.push(1.0 - p1.ratio_to(&bs));
        rows.push((a, rp, bs, p10));
    }
    println!(
        "\nheadline: AXLE(p1) end-to-end reduction — avg {:.2}% / max {:.2}% vs RP, avg {:.2}% / max {:.2}% vs BS",
        100.0 * mean(&reductions_rp),
        100.0 * reductions_rp.iter().cloned().fold(f64::MIN, f64::max),
        100.0 * mean(&reductions_bs),
        100.0 * reductions_bs.iter().cloned().fold(f64::MIN, f64::max),
    );

    // Idle-time + stall summary (paper abstract metrics).
    let mut ccm_red = Vec::new();
    let mut host_red = Vec::new();
    let mut stall_red = Vec::new();
    for (_a, rp, _bs, ax) in &rows {
        let fr = |x: u64, m: &RunMetrics| x.max(1) as f64 / m.total as f64;
        ccm_red.push(fr(rp.ccm_idle(), rp) / fr(ax.ccm_idle(), ax));
        host_red.push(fr(rp.host_idle(), rp) / fr(ax.host_idle(), ax));
        stall_red.push(
            fr(rp.host_stall_clamped(), rp) / fr(ax.host_stall_clamped(), ax),
        );
    }
    println!(
        "          CCM idle ↓ {:.2}x avg | host idle ↓ {:.2}x avg | host stall ↓ up to {:.2}x  (AXLE p10 vs RP)",
        mean(&ccm_red),
        mean(&host_red),
        stall_red.iter().cloned().fold(f64::MIN, f64::max),
    );
    println!("\n(paper: up to 50.14% runtime reduction, CCM idle ↓13.99x, host idle ↓3.93x, stall ↓ up to 6x)");
    Ok(())
}
