//! Graph analytics on CCM: run PageRank to convergence on a real RMAT
//! graph, computing every iteration's numerics through the AOT artifacts
//! (CCM half = Pallas edge-gather kernel, host half = segment-sum +
//! damped update) while the discrete-event simulator times the same
//! pipeline at paper scale under BS vs AXLE.
//!
//! This is the paper's §III-B motivating workload: per-edge intermediate
//! results make data movement ~half the runtime, which back-streaming
//! overlaps away.
//!
//! ```sh
//! make artifacts && cargo run --release --example graph_analytics
//! ```

use anyhow::Result;
use axle::config::{poll_factors, Protocol, SimConfig};
use axle::runtime::{literal_f32, literal_i32, Runtime};
use axle::sim::ps_to_us;
use axle::workload::graph::SynthGraph;
use axle::{protocol, workload};

fn main() -> Result<()> {
    // ------------------------------------------------------------------
    // 1. Timing at paper scale (|V| = 299067, |E| = 977676).
    // ------------------------------------------------------------------
    let cfg = SimConfig::m2ndp().with_poll(poll_factors::P1);
    let w = workload::by_annotation('e', &cfg);
    println!("PageRank timing at paper scale ({}):", w.name);
    let rp = protocol::run(Protocol::Rp, &w, &cfg);
    let bs = protocol::run(Protocol::Bs, &w, &cfg);
    let ax = protocol::run(Protocol::Axle, &w, &cfg);
    for m in [&rp, &bs, &ax] {
        println!(
            "  {:<6} total {:>10.2} us  (CCM {:>5.1}%  DM {:>5.1}%  host {:>5.1}%)",
            m.protocol,
            ps_to_us(m.total),
            100.0 * m.frac(m.ccm_busy),
            100.0 * m.frac(m.dm_busy),
            100.0 * m.frac(m.host_busy)
        );
    }
    println!(
        "  AXLE reduces end-to-end runtime by {:.1}% vs RP, {:.1}% vs BS\n",
        100.0 * (1.0 - ax.ratio_to(&rp)),
        100.0 * (1.0 - ax.ratio_to(&bs))
    );

    // ------------------------------------------------------------------
    // 2. Numerics at exec scale: PageRank to convergence through PJRT.
    // ------------------------------------------------------------------
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        println!("(run `make artifacts` for the numerics half of this example)");
        return Ok(());
    }
    let mut rt = Runtime::new("artifacts")?;
    let meta = rt.entry("pagerank_ccm")?.meta.clone();
    let v = meta.get("v").as_usize().unwrap();
    let e = meta.get("e").as_usize().unwrap();
    let g = SynthGraph::rmat(v, e, 42);
    let src: Vec<i32> = g.src.iter().map(|&x| x as i32).collect();
    let dst: Vec<i32> = g.dst.iter().map(|&x| x as i32).collect();
    let inv_deg: Vec<f32> = g.out_deg.iter().map(|&d| 1.0 / (d.max(1) as f32)).collect();
    let mut ranks = vec![1.0 / v as f32; v];

    println!("Running PageRank numerics on an RMAT graph (|V|={v}, |E|={e}) via PJRT:");
    let mut iters = 0;
    loop {
        iters += 1;
        // CCM half: per-edge contributions (the Pallas gather kernel).
        let contrib = rt.execute(
            "pagerank_ccm",
            &[
                literal_f32(&ranks, &[v])?,
                literal_f32(&inv_deg, &[v])?,
                literal_i32(&src, &[e])?,
            ],
        )?[0]
            .to_vec::<f32>()
            .map_err(|err| anyhow::anyhow!("{err:?}"))?;
        // Host half: segment sum + damped update.
        let new_ranks = rt.execute(
            "pagerank_host",
            &[literal_f32(&contrib, &[e])?, literal_i32(&dst, &[e])?],
        )?[0]
            .to_vec::<f32>()
            .map_err(|err| anyhow::anyhow!("{err:?}"))?;
        let delta: f32 = ranks
            .iter()
            .zip(&new_ranks)
            .map(|(a, b)| (a - b).abs())
            .sum();
        ranks = new_ranks;
        println!("  iter {iters:>2}: L1 delta {delta:.3e}");
        if delta < 1e-4 || iters >= 30 {
            break;
        }
    }
    let mut top: Vec<(usize, f32)> = ranks.iter().cloned().enumerate().collect();
    top.sort_by(|a, b| b.1.total_cmp(&a.1));
    println!("converged after {iters} iterations; top-5 vertices by rank:");
    for (vtx, r) in top.iter().take(5) {
        println!("  vertex {vtx:>6}: rank {r:.3e} (out-degree {})", g.out_deg[*vtx]);
    }
    Ok(())
}
