# Convenience targets; the CI gate is `build` + `test` + `lint`.
CARGO ?= cargo

.PHONY: build test lint bench artifacts

build:
	$(CARGO) build --release

test:
	$(CARGO) test -q

# Warnings are errors: keep the tree clippy-clean.
lint:
	$(CARGO) clippy --all-targets -- -D warnings

# Runs both bench binaries; figures.rs writes rust/BENCH_sweep.json
# (machine-readable wall-time per figure bench, incl. the serial vs
# parallel fig10 matrix pair).
bench:
	$(CARGO) bench

# AOT-compile the workload kernels to HLO text (needs the Python/JAX
# toolchain; the simulator itself never requires this).
artifacts:
	cd python/compile && python3 aot.py --out ../../rust/artifacts
