# Convenience targets; the CI gate is `fmt-check` + `build` + `test` +
# `lint` + `doc` + `doc-drift`, plus the `bench-smoke` measurement job.
CARGO ?= cargo

.PHONY: build test check-fast lint fmt-check doc doc-drift bench bench-smoke scenario-smoke learned-smoke pipeline-smoke trace-smoke artifacts

build:
	$(CARGO) build --release

test:
	$(CARGO) test -q

# Fast verification: build + unit tests only (lib and binaries), skipping
# the integration/property suites under rust/tests/. The quick local
# signal while iterating — a hang here (e.g. a closed-loop scheduler
# deadlock) surfaces in minutes, not a full proptest run.
check-fast:
	$(CARGO) build --release
	$(CARGO) test -q --lib --bins

# Warnings are errors: keep the tree clippy-clean.
lint:
	$(CARGO) clippy --all-targets -- -D warnings

# Formatting check (advisory in CI until the first `cargo fmt` pass
# lands and the workflow drops `continue-on-error`): run `cargo fmt` on
# a toolchain host to fix.
fmt-check:
	$(CARGO) fmt --all -- --check

# Rustdoc with warnings as errors: a broken intra-doc link fails the
# build (scoped to the axle package; the vendored stubs aren't gated).
doc:
	RUSTDOCFLAGS="-D warnings" $(CARGO) doc --no-deps -p axle

# Docs drift gate: every `axle` subcommand dispatched in main.rs must be
# documented in docs/CLI.md, and every `axle report fig*` figure name
# dispatched in the report binaries must appear there too.
doc-drift:
	@missing=0; \
	for s in $$(grep -oE 'Some\("[a-z0-9-]+"\)' rust/src/main.rs | sed 's/Some("//; s/")//' | sort -u); do \
		grep -q "axle $$s" docs/CLI.md || { echo "docs/CLI.md is missing subcommand: $$s"; missing=1; }; \
	done; \
	test $$missing -eq 0 && echo "docs/CLI.md covers every axle subcommand"
	@missing=0; \
	for f in $$(grep -ohE '"fig[0-9]+(-ext)?"' rust/src/bin/report.rs rust/src/main.rs rust/src/report/mod.rs | tr -d '"' | sort -u); do \
		grep -q "$$f" docs/CLI.md || { echo "docs/CLI.md is missing report figure: $$f"; missing=1; }; \
	done; \
	test $$missing -eq 0 && echo "docs/CLI.md covers every axle report figure"

# Runs both bench binaries; figures.rs writes rust/BENCH_sweep.json
# (machine-readable wall-time per figure bench, incl. the serial vs
# parallel fig10 matrix pair).
bench:
	$(CARGO) bench

# Downsized CI bench: only the fig10 serial-vs-parallel matrix pair at
# reduced reps. Writes rust/BENCH_sweep.json and prints the
# "fig10 matrix serial/parallel ratio" line CI lifts into its summary.
bench-smoke:
	$(CARGO) bench --bench figures -- --smoke

# Downsized fault-injection smoke (CI): the canned `axle scenario`
# failover — device 0 of a strong+weak pair fails permanently
# mid-service and the run completes on the survivor. Prints the
# "time-to-recover" line CI lifts into its job summary.
scenario-smoke:
	@$(CARGO) run --release --bin axle -- scenario --streams 3 --requests 2

# Downsized nonstationary learned-scheduling smoke (CI): the canned
# `axle scenario --learned` run — two identical devices behind a shared
# fabric, an 8x PU+link degradation landing on device 0 a quarter of
# the way into the fault-free heuristic run, all three deciders
# replayed on it. Prints the "learned/heuristic/oracle makespan =
# A/B/C" line CI lifts into its job summary.
learned-smoke:
	@$(CARGO) run --release --bin axle -- scenario --learned --streams 4 --requests 3

# Downsized pipelining smoke (CI): the same contended strong+weak
# closed loop run whole-request and chunked (`--chunks 4`). Each run's
# final line prints "host idle X% ccm idle Y%"; CI lifts both into its
# job summary to show the idle-fraction reduction chunking buys.
pipeline-smoke:
	@echo "whole-request (chunks 1):"
	@$(CARGO) run --release --bin axle -- sched --streams 3 --requests 2 \
		--policy static --protocol axle --workloads aei \
		--dev-ccm-pus 16,4 --devices 2 --admit 1 --depth 2 | tail -1
	@echo "chunked (chunks 4):"
	@$(CARGO) run --release --bin axle -- sched --streams 3 --requests 2 \
		--policy static --protocol axle --workloads aei \
		--dev-ccm-pus 16,4 --devices 2 --admit 1 --depth 2 --chunks 4 | tail -1

# Downsized tracing smoke (CI): the pipeline-smoke contention point
# re-run with the tracer armed. The run validates its own trace before
# exiting (the CLI runs every exported trace through trace::validate),
# writes trace-smoke.json (Chrome trace-event JSON — load in Perfetto),
# and prints the "trace events = N, host util p50 = X%" line plus the
# 8-bucket window table CI lifts into its job summary.
trace-smoke:
	@$(CARGO) run --release --bin axle -- sched --streams 3 --requests 2 \
		--policy static --protocol axle --workloads aei \
		--dev-ccm-pus 16,4 --devices 2 --admit 1 --depth 2 \
		--trace trace-smoke.json --trace-buckets 8

# AOT-compile the workload kernels to HLO text (needs the Python/JAX
# toolchain; the simulator itself never requires this).
artifacts:
	cd python/compile && python3 aot.py --out ../../rust/artifacts
