"""Layer-2 model correctness: workload compositions vs oracle compositions.

Exercises each workload's CCM half + host half end-to-end in Python, the
same graphs that aot.py lowers for the Rust runtime.
"""

import numpy as np
import jax.numpy as jnp

from compile import model
from compile.kernels import ref


def _rng(seed=0):
    return np.random.default_rng(seed)


# --------------------------------------------------------------------------
# KNN
# --------------------------------------------------------------------------

def test_knn_pipeline_finds_true_neighbors():
    r = _rng(1)
    dim, rows, k = 128, 512, 16
    db = r.standard_normal((rows, dim)).astype(np.float32)
    q = db[42] + 0.01 * r.standard_normal(dim).astype(np.float32)
    dists = model.knn_ccm(jnp.array(q), jnp.array(db))
    vals, idx = model.knn_host(dists, k=k)
    assert int(np.asarray(idx)[0]) == 42
    # Distances sorted ascending.
    v = np.asarray(vals)
    assert (np.diff(v) >= -1e-6).all()


def test_knn_ccm_matches_ref():
    r = _rng(2)
    q = r.standard_normal(64).astype(np.float32)
    db = r.standard_normal((32, 64)).astype(np.float32)
    np.testing.assert_allclose(
        np.asarray(model.knn_ccm(jnp.array(q), jnp.array(db))),
        np.asarray(model.knn_ccm_ref(jnp.array(q), jnp.array(db))),
        rtol=1e-2,
        atol=1e-2,
    )


# --------------------------------------------------------------------------
# PageRank
# --------------------------------------------------------------------------

def _ring_graph(v):
    """Directed ring: i -> (i+1) % v. Every vertex has out-degree 1."""
    src = np.arange(v, dtype=np.int32)
    dst = (src + 1) % v
    return src, dst


def test_pagerank_uniform_on_ring():
    """On a symmetric ring the stationary distribution is uniform."""
    v = 64
    src, dst = _ring_graph(v)
    ranks = np.full(v, 1.0 / v, dtype=np.float32)
    inv_deg = np.ones(v, dtype=np.float32)  # out-degree 1
    for _ in range(5):
        contrib = model.pagerank_ccm(jnp.array(ranks), jnp.array(inv_deg), jnp.array(src))
        ranks = np.asarray(model.pagerank_host(contrib, jnp.array(dst), num_vertices=v))
    np.testing.assert_allclose(ranks, 1.0 / v, rtol=1e-5)


def test_pagerank_mass_conservation():
    """Total rank stays ~1 when every vertex has outgoing edges."""
    r = _rng(3)
    v, e = 128, 512
    src = np.repeat(np.arange(v, dtype=np.int32), e // v)
    dst = r.integers(0, v, size=e).astype(np.int32)
    deg = np.bincount(src, minlength=v).astype(np.float32)
    inv_deg = 1.0 / np.maximum(deg, 1.0)
    ranks = np.full(v, 1.0 / v, dtype=np.float32)
    for _ in range(3):
        contrib = model.pagerank_ccm(jnp.array(ranks), jnp.array(inv_deg), jnp.array(src))
        ranks = np.asarray(model.pagerank_host(contrib, jnp.array(dst), num_vertices=v))
    assert abs(ranks.sum() - 1.0) < 1e-3


def test_pagerank_step_matches_ref():
    r = _rng(4)
    v, e = 32, 128
    src = r.integers(0, v, size=e).astype(np.int32)
    dst = r.integers(0, v, size=e).astype(np.int32)
    deg = np.bincount(src, minlength=v).astype(np.float32)
    inv_deg = 1.0 / np.maximum(deg, 1.0)
    ranks = r.random(v).astype(np.float32)
    contrib = model.pagerank_ccm(jnp.array(ranks), jnp.array(inv_deg), jnp.array(src))
    got = model.pagerank_host(contrib, jnp.array(dst), num_vertices=v)
    want = model.pagerank_step_ref(
        jnp.array(ranks), jnp.array(inv_deg), jnp.array(src), jnp.array(dst), v
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-5)


# --------------------------------------------------------------------------
# SSSP
# --------------------------------------------------------------------------

def test_sssp_converges_on_path_graph():
    """Path 0→1→2→…: dist[i] should converge to i (unit weights)."""
    v = 16
    src = np.arange(v - 1, dtype=np.int32)
    dst = src + 1
    w = np.ones(v - 1, dtype=np.float32)
    inf = np.float32(1e9)
    dist = np.full(v, inf, dtype=np.float32)
    dist[0] = 0.0
    ones = np.ones(v, dtype=np.float32)
    for _ in range(v):
        cand = model.sssp_ccm(jnp.array(dist), jnp.array(ones), jnp.array(src), jnp.array(w))
        dist = np.asarray(model.sssp_host(cand, jnp.array(dst), jnp.array(dist)))
    np.testing.assert_allclose(dist, np.arange(v, dtype=np.float32))


def test_sssp_monotone_nonincreasing():
    """Bellman-Ford relaxation never increases any distance."""
    r = _rng(5)
    v, e = 64, 256
    src = r.integers(0, v, size=e).astype(np.int32)
    dst = r.integers(0, v, size=e).astype(np.int32)
    w = r.random(e).astype(np.float32)
    dist = np.full(v, 1e9, dtype=np.float32)
    dist[0] = 0.0
    ones = np.ones(v, dtype=np.float32)
    for _ in range(4):
        prev = dist.copy()
        cand = model.sssp_ccm(jnp.array(dist), jnp.array(ones), jnp.array(src), jnp.array(w))
        dist = np.asarray(model.sssp_host(cand, jnp.array(dst), jnp.array(dist)))
        assert (dist <= prev + 1e-6).all()


# --------------------------------------------------------------------------
# SSB / OLAP
# --------------------------------------------------------------------------

def test_ssb_q1_revenue_matches_numpy():
    r = _rng(6)
    n = 4096
    discount = r.integers(0, 11, size=n).astype(np.float32)
    quantity = r.integers(1, 51, size=n).astype(np.float32)
    price = (1000 * r.random(n)).astype(np.float32)
    # Q1.1: discount in [1,3], quantity < 25 (i.e. [1,24] over ints).
    marks = model.ssb_q1_ccm(
        jnp.array(discount),
        jnp.array(quantity),
        jnp.array([1.0, 3.0], dtype=np.float32),
        jnp.array([1.0, 24.0], dtype=np.float32),
    )
    got = float(model.ssb_q1_host(marks, jnp.array(price), jnp.array(discount)))
    sel = (discount >= 1) & (discount <= 3) & (quantity >= 1) & (quantity <= 24)
    want = float((price[sel] * discount[sel]).sum())
    assert abs(got - want) / max(abs(want), 1.0) < 1e-3


def test_ssb_marks_are_conjunctive():
    disc = np.array([2.0, 2.0, 9.0], dtype=np.float32)
    qty = np.array([10.0, 40.0, 10.0], dtype=np.float32)
    marks = np.asarray(
        model.ssb_q1_ccm(
            jnp.array(disc),
            jnp.array(qty),
            jnp.array([1.0, 3.0], dtype=np.float32),
            jnp.array([1.0, 24.0], dtype=np.float32),
        )
    )
    np.testing.assert_array_equal(marks, [1.0, 0.0, 0.0])


# --------------------------------------------------------------------------
# LLM attention block
# --------------------------------------------------------------------------

def _llm_params(hidden=64, heads=4, t=8, seed=7):
    r = _rng(seed)
    d = hidden // heads
    return dict(
        x=r.standard_normal((1, hidden)).astype(np.float32) * 0.1,
        kcache=r.standard_normal((heads, t, d)).astype(np.float32) * 0.1,
        vcache=r.standard_normal((heads, t, d)).astype(np.float32) * 0.1,
        wqkv=r.standard_normal((hidden, 3 * hidden)).astype(np.float32) * 0.05,
        wo=r.standard_normal((hidden, hidden)).astype(np.float32) * 0.05,
        ln_g=np.ones(hidden, dtype=np.float32),
        ln_b=np.zeros(hidden, dtype=np.float32),
    )


def test_attention_block_matches_ref():
    p = {k: jnp.array(v) for k, v in _llm_params().items()}
    got = model.attention_block_ccm(**p)
    want = model.attention_block_ccm_ref(**p)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-3, atol=1e-4)


def test_attention_block_residual_path():
    """With zero output projection the block must be the identity."""
    p = _llm_params()
    p["wo"] = np.zeros_like(p["wo"])
    out = model.attention_block_ccm(**{k: jnp.array(v) for k, v in p.items()})
    np.testing.assert_allclose(np.asarray(out), p["x"], rtol=1e-6)


def test_mlp_host_shapes():
    r = _rng(8)
    hidden, ffn = 32, 128
    x = jnp.array(r.standard_normal((1, hidden)).astype(np.float32))
    w1 = jnp.array(r.standard_normal((hidden, ffn)).astype(np.float32) * 0.05)
    b1 = jnp.zeros(ffn)
    w2 = jnp.array(r.standard_normal((ffn, hidden)).astype(np.float32) * 0.05)
    b2 = jnp.zeros(hidden)
    out = model.mlp_host(x, w1, b1, w2, b2)
    assert out.shape == (1, hidden)
    assert np.isfinite(np.asarray(out)).all()


# --------------------------------------------------------------------------
# DLRM
# --------------------------------------------------------------------------

def test_dlrm_pipeline():
    r = _rng(9)
    vocab, dim, batch, lookups = 256, 16, 8, 4
    table = r.standard_normal((vocab, dim)).astype(np.float32)
    idx = r.integers(0, vocab, size=(batch, lookups)).astype(np.int32)
    pooled = model.dlrm_ccm(jnp.array(table), jnp.array(idx))
    np.testing.assert_allclose(
        np.asarray(pooled),
        np.asarray(ref.sparse_length_sum(jnp.array(table), jnp.array(idx))),
        rtol=1e-4,
    )
    dense = r.standard_normal((batch, dim)).astype(np.float32)
    w = r.standard_normal((2 * dim, 1)).astype(np.float32) * 0.1
    out = model.dlrm_host(pooled, jnp.array(dense), jnp.array(w))
    assert out.shape == (batch, 1)
    o = np.asarray(out)
    assert ((o > 0) & (o < 1)).all()  # sigmoid range
