"""AOT pipeline tests: registry completeness, HLO-text shape, manifest.

Ensures the artifacts the Rust runtime loads exist for every workload half
and that the lowered HLO text is parseable interchange (ENTRY present, no
serialized-proto path).
"""

import json
import os

import jax
import jax.numpy as jnp
import pytest

from compile import aot

EXPECTED_ARTIFACTS = {
    "knn_a_ccm", "knn_a_host",
    "knn_b_ccm", "knn_b_host",
    "knn_c_ccm", "knn_c_host",
    "pagerank_ccm", "pagerank_host",
    "sssp_ccm", "sssp_host",
    "ssb_q1_ccm", "ssb_q1_host",
    "llm_attn_ccm", "llm_mlp_host",
    "dlrm_ccm", "dlrm_host",
}


def test_registry_covers_all_workload_halves():
    assert set(aot.build_registry().keys()) == EXPECTED_ARTIFACTS


def test_registry_specs_traceable():
    """Every registry entry must trace (eval_shape) without error."""
    for name, (fn, specs, _meta) in aot.build_registry().items():
        out = jax.eval_shape(fn, *specs)
        assert out is not None, name


def test_lower_one_artifact_is_hlo_text(tmp_path):
    manifest = aot.lower_all(str(tmp_path), only=["knn_a_ccm"])
    assert set(manifest) == {"knn_a_ccm"}
    text = (tmp_path / "knn_a_ccm.hlo.txt").read_text()
    assert "ENTRY" in text  # HLO text module, not proto bytes
    assert "HloModule" in text
    m = manifest["knn_a_ccm"]
    assert m["inputs"][0]["shape"] == [2048]
    assert m["inputs"][1]["shape"] == [128, 2048]
    assert m["outputs"][0]["shape"] == [128]


def test_manifest_written_and_consistent(tmp_path):
    aot.lower_all(str(tmp_path), only=["ssb_q1_ccm", "ssb_q1_host"])
    manifest = json.loads((tmp_path / "manifest.json").read_text())
    for name, entry in manifest.items():
        assert os.path.exists(tmp_path / entry["file"]), name
        assert entry["sha256"]
        assert all("shape" in i and "dtype" in i for i in entry["inputs"])


def test_knn_host_topk_outputs_tuple_shapes():
    reg = aot.build_registry()
    fn, specs, meta = reg["knn_a_host"]
    vals, idx = jax.eval_shape(fn, *specs)
    assert vals.shape == (aot.KNN_K,)
    assert idx.shape == (aot.KNN_K,)
    assert idx.dtype == jnp.int32


def test_repo_artifacts_match_registry_if_built():
    """If `make artifacts` has run, the manifest must match the registry."""
    path = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts", "manifest.json")
    if not os.path.exists(path):
        pytest.skip("artifacts not built")
    manifest = json.loads(open(path).read())
    assert set(manifest.keys()) == EXPECTED_ARTIFACTS
