"""Kernel-vs-oracle correctness: the CORE signal for the Pallas layer.

Every Pallas kernel is checked against its pure-jnp oracle in
``compile.kernels.ref`` over hypothesis-swept shapes, dtypes and block
sizes. Failures here mean the HLO the Rust runtime executes is wrong.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import kernels as K
from compile.kernels import ref

RTOL = 2e-3
ATOL = 2e-3


def _rng(seed):
    return np.random.default_rng(seed)


# --------------------------------------------------------------------------
# matmul
# --------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 96),
    k=st.integers(1, 96),
    n=st.integers(1, 96),
    bm=st.integers(1, 128),
    bn=st.integers(1, 128),
    bk=st.integers(1, 128),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_sweep(m, k, n, bm, bn, bk, seed):
    r = _rng(seed)
    x = r.standard_normal((m, k), dtype=np.float32)
    y = r.standard_normal((k, n), dtype=np.float32)
    got = np.asarray(K.matmul(jnp.array(x), jnp.array(y), bm=bm, bn=bn, bk=bk))
    np.testing.assert_allclose(got, x @ y, rtol=RTOL, atol=ATOL)


@pytest.mark.parametrize("dtype", [np.float32, np.float16])
def test_matmul_dtypes(dtype):
    r = _rng(7)
    x = r.standard_normal((32, 48)).astype(dtype)
    y = r.standard_normal((48, 16)).astype(dtype)
    got = np.asarray(K.matmul(jnp.array(x), jnp.array(y)))
    want = x.astype(np.float32) @ y.astype(np.float32)
    np.testing.assert_allclose(got, want, rtol=5e-2, atol=5e-2)


def test_matmul_identity():
    x = np.eye(16, dtype=np.float32)
    got = np.asarray(K.matmul(jnp.array(x), jnp.array(x)))
    np.testing.assert_array_equal(got, x)


# --------------------------------------------------------------------------
# knn_squared_l2
# --------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    dim=st.integers(1, 256),
    rows=st.integers(1, 256),
    block=st.integers(1, 128),
    seed=st.integers(0, 2**31 - 1),
)
def test_knn_sweep(dim, rows, block, seed):
    r = _rng(seed)
    q = r.standard_normal(dim, dtype=np.float32)
    db = r.standard_normal((rows, dim), dtype=np.float32)
    got = np.asarray(K.knn_squared_l2(jnp.array(q), jnp.array(db), block_rows=block))
    want = np.asarray(ref.knn_squared_l2(jnp.array(q), jnp.array(db)))
    np.testing.assert_allclose(got, want, rtol=1e-2, atol=1e-2)


def test_knn_zero_distance():
    """A row equal to the query must yield (near-)zero distance."""
    r = _rng(3)
    q = r.standard_normal(64, dtype=np.float32)
    db = r.standard_normal((8, 64), dtype=np.float32)
    db[5] = q
    got = np.asarray(K.knn_squared_l2(jnp.array(q), jnp.array(db)))
    assert abs(got[5]) < 1e-3
    assert np.argmin(got) == 5


@pytest.mark.parametrize("dim,rows", [(2048, 128), (1024, 256), (512, 512)])
def test_knn_paper_configs(dim, rows):
    """Table IV (a)-(c) exact configurations."""
    r = _rng(dim)
    q = r.standard_normal(dim, dtype=np.float32)
    db = r.standard_normal((rows, dim), dtype=np.float32)
    got = np.asarray(K.knn_squared_l2(jnp.array(q), jnp.array(db)))
    want = np.asarray(ref.knn_squared_l2(jnp.array(q), jnp.array(db)))
    np.testing.assert_allclose(got, want, rtol=1e-2, atol=1e-1)


# --------------------------------------------------------------------------
# sparse_length_sum
# --------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    vocab=st.integers(1, 512),
    dim=st.integers(1, 64),
    batch=st.integers(1, 64),
    lookups=st.integers(1, 32),
    block=st.integers(1, 64),
    seed=st.integers(0, 2**31 - 1),
)
def test_sls_sweep(vocab, dim, batch, lookups, block, seed):
    r = _rng(seed)
    table = r.standard_normal((vocab, dim), dtype=np.float32)
    idx = r.integers(0, vocab, size=(batch, lookups)).astype(np.int32)
    got = np.asarray(K.sparse_length_sum(jnp.array(table), jnp.array(idx), block_b=block))
    want = np.asarray(ref.sparse_length_sum(jnp.array(table), jnp.array(idx)))
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


def test_sls_repeated_index():
    """Pooling the same row L times equals L * row."""
    table = np.arange(20, dtype=np.float32).reshape(5, 4)
    idx = np.full((1, 7), 3, dtype=np.int32)
    got = np.asarray(K.sparse_length_sum(jnp.array(table), jnp.array(idx)))
    np.testing.assert_allclose(got[0], 7 * table[3])


# --------------------------------------------------------------------------
# predicate_filter
# --------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 4096),
    block=st.integers(1, 1024),
    lo=st.floats(-3, 3, allow_nan=False, width=32),
    width=st.floats(0, 4, allow_nan=False, width=32),
    seed=st.integers(0, 2**31 - 1),
)
def test_filter_sweep(n, block, lo, width, seed):
    r = _rng(seed)
    vals = r.standard_normal(n, dtype=np.float32)
    bounds = np.array([lo, lo + width], dtype=np.float32)
    got = np.asarray(K.predicate_filter(jnp.array(vals), jnp.array(bounds), block_n=block))
    want = np.asarray(ref.predicate_filter(jnp.array(vals), jnp.array(bounds)))
    np.testing.assert_array_equal(got, want)


def test_filter_boundary_inclusive():
    vals = np.array([1.0, 2.0, 3.0, 4.0], dtype=np.float32)
    bounds = np.array([2.0, 3.0], dtype=np.float32)
    got = np.asarray(K.predicate_filter(jnp.array(vals), jnp.array(bounds)))
    np.testing.assert_array_equal(got, [0.0, 1.0, 1.0, 0.0])


def test_filter_empty_range():
    vals = np.linspace(-1, 1, 64).astype(np.float32)
    bounds = np.array([5.0, -5.0], dtype=np.float32)  # lo > hi: nothing
    got = np.asarray(K.predicate_filter(jnp.array(vals), jnp.array(bounds)))
    assert got.sum() == 0.0


# --------------------------------------------------------------------------
# mha_decode_attention
# --------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(
    heads=st.integers(1, 8),
    tokens=st.integers(1, 128),
    d=st.integers(1, 64),
    seed=st.integers(0, 2**31 - 1),
)
def test_attention_sweep(heads, tokens, d, seed):
    r = _rng(seed)
    q = r.standard_normal((heads, d), dtype=np.float32)
    k = r.standard_normal((heads, tokens, d), dtype=np.float32)
    v = r.standard_normal((heads, tokens, d), dtype=np.float32)
    got = np.asarray(K.mha_decode_attention(jnp.array(q), jnp.array(k), jnp.array(v)))
    want = np.asarray(ref.mha_decode_attention(jnp.array(q), jnp.array(k), jnp.array(v)))
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


def test_attention_uniform_when_scores_equal():
    """Identical keys ⇒ softmax uniform ⇒ output = mean of values."""
    q = np.ones((2, 8), dtype=np.float32)
    k = np.ones((2, 16, 8), dtype=np.float32)
    v = np.random.default_rng(0).standard_normal((2, 16, 8)).astype(np.float32)
    got = np.asarray(K.mha_decode_attention(jnp.array(q), jnp.array(k), jnp.array(v)))
    np.testing.assert_allclose(got, v.mean(axis=1), rtol=1e-4, atol=1e-4)


def test_attention_softmax_stability_large_scores():
    """Large-magnitude scores must not overflow (stable softmax)."""
    q = np.full((1, 32), 50.0, dtype=np.float32)
    k = np.full((1, 8, 32), 50.0, dtype=np.float32)
    v = np.ones((1, 8, 32), dtype=np.float32)
    got = np.asarray(K.mha_decode_attention(jnp.array(q), jnp.array(k), jnp.array(v)))
    assert np.isfinite(got).all()
    np.testing.assert_allclose(got, 1.0, rtol=1e-4)


# --------------------------------------------------------------------------
# edge_gather_scale
# --------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    v=st.integers(1, 512),
    e=st.integers(1, 2048),
    block=st.integers(1, 512),
    seed=st.integers(0, 2**31 - 1),
)
def test_spmv_sweep(v, e, block, seed):
    r = _rng(seed)
    values = r.standard_normal(v, dtype=np.float32)
    scales = r.standard_normal(v, dtype=np.float32)
    src = r.integers(0, v, size=e).astype(np.int32)
    got = np.asarray(
        K.edge_gather_scale(jnp.array(values), jnp.array(scales), jnp.array(src), block_e=block)
    )
    want = np.asarray(ref.edge_gather_scale(jnp.array(values), jnp.array(scales), jnp.array(src)))
    np.testing.assert_allclose(got, want, rtol=1e-5)


def test_spmv_unit_scales_is_gather():
    values = np.arange(10, dtype=np.float32)
    scales = np.ones(10, dtype=np.float32)
    src = np.array([9, 0, 4, 4], dtype=np.int32)
    got = np.asarray(K.edge_gather_scale(jnp.array(values), jnp.array(scales), jnp.array(src)))
    np.testing.assert_array_equal(got, [9.0, 0.0, 4.0, 4.0])
