"""Layer-2 JAX compute graphs for every AXLE workload (Table IV).

Each workload is split at the paper's offload boundary (Table I) into a
**CCM part** (executed by the simulated near-memory device) and a **host
part** (the downstream task consuming back-streamed results). Both halves
call the Layer-1 Pallas kernels where the hot loop lives and are AOT-lowered
by :mod:`compile.aot` into separate HLO-text artifacts, which the Rust
coordinator executes via PJRT for real numerics while the discrete-event
simulator provides timing.

Shapes are static at lowering time; :mod:`compile.aot` instantiates each
model at the configured "exec scale" (see DESIGN.md — numerics at a scale
the CPU PJRT client executes comfortably; the simulator's *timing* uses the
paper-scale parameters independently).
"""

import jax
import jax.numpy as jnp

from . import kernels
from .kernels import ref


# --------------------------------------------------------------------------
# VectorDB / KNN (Table IV a-c): CCM computes distances, host selects top-k.
# --------------------------------------------------------------------------

def knn_ccm(query: jax.Array, rows: jax.Array) -> jax.Array:
    """CCM half: per-row squared-L2 distance (Pallas MAC kernel)."""
    return kernels.knn_squared_l2(query, rows)


def knn_host(distances: jax.Array, *, k: int):
    """Host half: smallest-k selection over back-streamed distances.

    Lowered as a full sort + slice rather than ``lax.top_k``: jax emits the
    dedicated ``topk(..., largest=true)`` HLO instruction, which the
    xla_extension 0.5.1 text parser bundled in this image does not accept.
    ``sort`` round-trips cleanly and is equivalent for correctness.
    """
    idx = jnp.argsort(distances)[:k]
    return distances[idx], idx.astype(jnp.int32)


# --------------------------------------------------------------------------
# Graph analytics (Table IV d-e): CCM traverses edges, host updates frontier.
# --------------------------------------------------------------------------

def pagerank_ccm(ranks: jax.Array, inv_deg: jax.Array, src: jax.Array) -> jax.Array:
    """CCM half: per-edge contribution rank[src]/deg[src] (Pallas gather)."""
    return kernels.edge_gather_scale(ranks, inv_deg, src)


def pagerank_host(
    contrib: jax.Array, dst: jax.Array, *, num_vertices: int, damping: float = 0.85
) -> jax.Array:
    """Host half: destination segment-sum + damped rank update."""
    sums = jax.ops.segment_sum(contrib, dst, num_segments=num_vertices)
    return (1.0 - damping) / num_vertices + damping * sums


def sssp_ccm(dist: jax.Array, ones: jax.Array, src: jax.Array, w: jax.Array) -> jax.Array:
    """CCM half: per-edge relaxation candidates dist[src] + w[e]."""
    return kernels.edge_gather_scale(dist, ones, src) + w


def sssp_host(cand: jax.Array, dst: jax.Array, dist: jax.Array) -> jax.Array:
    """Host half: per-destination min + monotone distance update."""
    num_vertices = dist.shape[0]
    best = jax.ops.segment_min(cand, dst, num_segments=num_vertices)
    return jnp.minimum(dist, best)


# --------------------------------------------------------------------------
# OLAP / SSB Q1.x (Table IV f-g): CCM marks rows, host aggregates revenue.
# --------------------------------------------------------------------------

def ssb_q1_ccm(
    discount: jax.Array,
    quantity: jax.Array,
    disc_bounds: jax.Array,
    qty_bounds: jax.Array,
) -> jax.Array:
    """CCM half: conjunctive range predicates via the Pallas CMP kernel.

    SSB Q1.1: d_year = 1993 AND lo_discount in [1,3] AND lo_quantity < 25.
    SSB Q1.2: d_yearmonth AND lo_discount in [4,6] AND lo_quantity in [26,35].
    The year/month predicate is folded into the generator's row selection;
    discount/quantity are the CCM-scanned columns.
    """
    m1 = kernels.predicate_filter(discount, disc_bounds)
    m2 = kernels.predicate_filter(quantity, qty_bounds)
    return m1 * m2


def ssb_q1_host(
    marks: jax.Array, extendedprice: jax.Array, discount: jax.Array
) -> jax.Array:
    """Host half: sum(lo_extendedprice * lo_discount) over marked rows."""
    return jnp.sum(marks * extendedprice * discount)


# --------------------------------------------------------------------------
# LLM inference / OPT attention block (Table IV h, Fig. 3).
# --------------------------------------------------------------------------

def _layernorm(x: jax.Array, g: jax.Array, b: jax.Array, eps: float = 1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * g + b


def attention_block_ccm(
    x: jax.Array,  # (1, hidden) current-token hidden state
    kcache: jax.Array,  # (H, T, d)
    vcache: jax.Array,  # (H, T, d)
    wqkv: jax.Array,  # (hidden, 3*hidden)
    wo: jax.Array,  # (hidden, hidden)
    ln_g: jax.Array,  # (hidden,)
    ln_b: jax.Array,  # (hidden,)
) -> jax.Array:
    """CCM half: the paper's attention block in its Fig. 3 kernel order.

    LayerNormQ → QKVProj (Pallas matmul) → Attention1+2 (fused Pallas SDPA)
    → OutProj (Pallas matmul) → Residual. Returns the [1, hidden] output —
    the "considerably small" intermediate of §V-B.
    """
    hidden = x.shape[-1]
    h, t, d = kcache.shape
    ln = _layernorm(x, ln_g, ln_b)
    qkv = kernels.matmul(ln, wqkv)  # (1, 3*hidden)
    q = qkv[0, :hidden].reshape(h, d)
    # K/V of the current token extend the cache conceptually; for the static
    # artifact we attend over the provided cache (prefill-style history).
    attn = kernels.mha_decode_attention(q, kcache, vcache)  # (h, d)
    out = kernels.matmul(attn.reshape(1, hidden), wo)  # (1, hidden)
    return x + out


def mlp_host(
    x: jax.Array, w1: jax.Array, b1: jax.Array, w2: jax.Array, b2: jax.Array
) -> jax.Array:
    """Host half: the MLP the paper keeps on the host (fc1→gelu→fc2+res)."""
    hfc = jax.nn.gelu(kernels.matmul(x, w1) + b1)
    return x + kernels.matmul(hfc, w2) + b2


# --------------------------------------------------------------------------
# DLRM (Table IV i): CCM pools embeddings, host runs the interaction MLP.
# --------------------------------------------------------------------------

def dlrm_ccm(table: jax.Array, indices: jax.Array) -> jax.Array:
    """CCM half: embedding lookup → SLS (Pallas gather+sum kernel)."""
    return kernels.sparse_length_sum(table, indices)


def dlrm_host(pooled: jax.Array, dense: jax.Array, w: jax.Array) -> jax.Array:
    """Host half: concat pooled sparse + dense features → top MLP layer."""
    feat = jnp.concatenate([pooled, dense], axis=1)
    return jax.nn.sigmoid(kernels.matmul(feat, w))


# --------------------------------------------------------------------------
# Reference (oracle) compositions used by pytest to validate whole models.
# --------------------------------------------------------------------------

def knn_ccm_ref(query, rows):
    return ref.knn_squared_l2(query, rows)


def pagerank_step_ref(ranks, inv_deg, src, dst, num_vertices, damping=0.85):
    contrib = ref.edge_gather_scale(ranks, inv_deg, src)
    return pagerank_host(contrib, dst, num_vertices=num_vertices, damping=damping)


def attention_block_ccm_ref(x, kcache, vcache, wqkv, wo, ln_g, ln_b):
    hidden = x.shape[-1]
    h, t, d = kcache.shape
    ln = _layernorm(x, ln_g, ln_b)
    qkv = ref.matmul(ln, wqkv)
    q = qkv[0, :hidden].reshape(h, d)
    attn = ref.mha_decode_attention(q, kcache, vcache)
    out = ref.matmul(attn.reshape(1, hidden), wo)
    return x + out
