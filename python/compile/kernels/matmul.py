"""Tiled matmul Pallas kernel — the MXU-shaped workhorse.

Used by the LLM workload for QKVProj / OutProj (Table I: "Attention block"),
and as a building block elsewhere. The tiling maps the paper's CCM
DRAM→subcore streaming onto a Pallas ``BlockSpec`` HBM→VMEM schedule:
operand tiles of (bm, bk) × (bk, bn) stream through VMEM while the (bm, bn)
output block stays resident across the k loop — the near-memory analogue of
the CCM scheduler handing each μthread a fixed-size input slice.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(x_ref, y_ref, o_ref, *, n_k: int):
    """One (i, j, k) grid step: o += x_tile @ y_tile.

    The output block's index map ignores k, so Pallas keeps the same (i, j)
    tile resident in VMEM across the whole k loop (standard revisiting
    accumulator pattern — no scratch buffer needed).
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=o_ref.dtype
    )


def pick_block(dim: int, target: int) -> int:
    """Largest divisor of ``dim`` that is <= target (keeps grids exact)."""
    b = max(1, min(dim, target))
    while dim % b != 0:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matmul(
    x: jax.Array, y: jax.Array, *, bm: int = 128, bn: int = 128, bk: int = 128
) -> jax.Array:
    """``x @ y`` via a tiled Pallas kernel (interpret mode).

    Args:
      x: (M, K) array.
      y: (K, N) array.
      bm/bn/bk: target VMEM tile sizes; clipped to exact divisors of the
        corresponding dimension so the grid tiles exactly.

    Returns:
      (M, N) array in f32.
    """
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"
    bm = pick_block(m, bm)
    bn = pick_block(n, bn)
    bk = pick_block(k, bk)
    n_k = k // bk

    return pl.pallas_call(
        functools.partial(_matmul_kernel, n_k=n_k),
        grid=(m // bm, n // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x.astype(jnp.float32), y.astype(jnp.float32))
