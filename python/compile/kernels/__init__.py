"""Layer-1 Pallas kernels for the AXLE reproduction.

Every kernel is authored with ``jax.experimental.pallas`` and lowered with
``interpret=True`` so the resulting HLO is plain XLA ops executable on the
CPU PJRT client that the Rust coordinator embeds (real-TPU Mosaic
custom-calls cannot run there; see DESIGN.md §Hardware-Adaptation).

Each module exposes a single public entry point that mirrors one of the
paper's offloaded functions (Table I):

- :mod:`.matmul`        — tiled MXU-style matmul (LLM projections)
- :mod:`.knn_distance`  — MAC-based squared-L2 distance (VectorDB / KNN)
- :mod:`.sls`           — embedding gather + sparse-length-sum (DLRM)
- :mod:`.filter`        — numeric predicate filter / boolean marking (OLAP)
- :mod:`.attention`     — per-head scaled-dot-product attention (LLM)
- :mod:`.spmv`          — edge traversal gather/scale (graph analytics)

Pure-jnp oracles live in :mod:`.ref`; pytest asserts allclose between the
two for swept shapes/dtypes (python/tests/).
"""

from . import ref  # noqa: F401
from .matmul import matmul
from .knn_distance import knn_squared_l2
from .sls import sparse_length_sum
from .filter import predicate_filter
from .attention import mha_decode_attention
from .spmv import edge_gather_scale

__all__ = [
    "matmul",
    "knn_squared_l2",
    "sparse_length_sum",
    "predicate_filter",
    "mha_decode_attention",
    "edge_gather_scale",
    "ref",
]
