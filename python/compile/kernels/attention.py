"""Per-head scaled-dot-product attention Pallas kernel (LLM workload).

The paper's LLM case offloads the attention block (Table I, Fig. 3) —
LayerNormQ → QKVProj → Attention1 → Attention2 → OutProj → Residual — to
the CCM while the host runs the MLP. Attention1/2 are the two matmul halves
of SDPA; this kernel fuses them per head so the (T, d) K/V panels stream
through VMEM once and only the [1, hidden] attention output (the paper's
"considerably small" intermediate, §V-B) leaves the device.

Decode-style single-query attention: one grid step per head.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, scale: float):
    """One head: softmax(q·Kᵀ·scale)·V with a numerically-stable softmax."""
    q = q_ref[0]  # (d,)
    k = k_ref[0]  # (T, d)
    v = v_ref[0]  # (T, d)
    scores = jnp.dot(k, q, preferred_element_type=jnp.float32) * scale  # (T,)
    m = jnp.max(scores)
    p = jnp.exp(scores - m)
    p = p / jnp.sum(p)
    o_ref[0] = jnp.dot(p, v, preferred_element_type=jnp.float32)


@jax.jit
def mha_decode_attention(
    q: jax.Array, k: jax.Array, v: jax.Array
) -> jax.Array:
    """Multi-head single-token attention.

    Args:
      q: (H, d) query per head.
      k: (H, T, d) key cache.
      v: (H, T, d) value cache.

    Returns:
      (H, d) float32 attention output per head.
    """
    h, d = q.shape
    h2, t, d2 = k.shape
    assert (h, d) == (h2, d2), f"q {q.shape} vs k {k.shape}"
    scale = 1.0 / (d**0.5)

    return pl.pallas_call(
        functools.partial(_attn_kernel, scale=scale),
        grid=(h,),
        in_specs=[
            pl.BlockSpec((1, d), lambda i: (i, 0)),
            pl.BlockSpec((1, t, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, t, d), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((h, d), jnp.float32),
        interpret=True,
    )(
        q.astype(jnp.float32),
        k.astype(jnp.float32),
        v.astype(jnp.float32),
    )
