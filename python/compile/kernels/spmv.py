"""Edge-traversal gather/scale Pallas kernel (graph analytics, Table I/IV).

Grudon-style graph offload (§III-B): the CCM traverses edges and computes
per-edge contributions ``contrib[e] = value[src[e]] * scale[src[e]]``
(e.g. PageRank: rank/out-degree; SSSP: dist + edge weight), returning the
per-edge stream which the destination-side segment reduction consumes.
The L2 model (model.py) applies the segment sum — keeping the kernel the
pure gather/MAC hot loop that maps onto the CCM's ACC/MAC PFLs.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .matmul import pick_block


def _edge_kernel(values_ref, scales_ref, src_ref, o_ref):
    """One grid step: gather+scale a block of edges against full values."""
    values = values_ref[...]  # (V,)
    scales = scales_ref[...]  # (V,)
    src = src_ref[...]  # (block_e,) int32
    o_ref[...] = jnp.take(values, src) * jnp.take(scales, src)


@functools.partial(jax.jit, static_argnames=("block_e",))
def edge_gather_scale(
    values: jax.Array, scales: jax.Array, src: jax.Array, *, block_e: int = 4096
) -> jax.Array:
    """Per-edge gathered, scaled source values.

    Args:
      values: (V,) per-vertex values (ranks / distances), CCM-resident.
      scales: (V,) per-vertex multipliers (1/out-degree for PageRank, 1 for
        unweighted traversal).
      src: (E,) int32 source vertex per edge.
      block_e: target edges per grid step.

    Returns:
      (E,) float32 per-edge contributions.
    """
    (e,) = src.shape
    be = pick_block(e, block_e)

    return pl.pallas_call(
        _edge_kernel,
        grid=(e // be,),
        in_specs=[
            pl.BlockSpec(values.shape, lambda i: (0,)),
            pl.BlockSpec(scales.shape, lambda i: (0,)),
            pl.BlockSpec((be,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((be,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((e,), jnp.float32),
        interpret=True,
    )(
        values.astype(jnp.float32),
        scales.astype(jnp.float32),
        src.astype(jnp.int32),
    )
