"""Predicate-filter Pallas kernel (OLAP / SSB workload, Table I/IV).

M²NDP's OLAP offload performs "boolean marking within the selection
operation": the CCM scans a column resident in its DRAM, evaluates the
range predicate with the CMP primitive-function logic, and returns a
compact mark vector (§VI). Star Schema Benchmark Q1.x predicates are
conjunctions of range filters over discount/quantity — exactly this shape.

The kernel evaluates ``lo <= x <= hi`` per element, emitting f32 0/1 marks
(kept float so the same artifact feeds the revenue aggregation matvec).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .matmul import pick_block


def _filter_kernel(x_ref, bounds_ref, o_ref):
    x = x_ref[...]
    lo = bounds_ref[0]
    hi = bounds_ref[1]
    o_ref[...] = jnp.where((x >= lo) & (x <= hi), 1.0, 0.0).astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("block_n",))
def predicate_filter(
    values: jax.Array, bounds: jax.Array, *, block_n: int = 4096
) -> jax.Array:
    """Range-predicate boolean marking.

    Args:
      values: (N,) column values (CCM-resident).
      bounds: (2,) [lo, hi] inclusive range.
      block_n: target elements per VMEM tile.

    Returns:
      (N,) float32 marks in {0, 1} — the reduced result back-streamed to the
      host, which ANDs marks across predicates and aggregates revenue.
    """
    (n,) = values.shape
    bn = pick_block(n, block_n)

    return pl.pallas_call(
        _filter_kernel,
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((bn,), lambda i: (i,)),
            pl.BlockSpec((2,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bn,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(values.astype(jnp.float32), bounds.astype(jnp.float32))
