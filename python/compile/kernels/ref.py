"""Pure-jnp correctness oracles for every Layer-1 Pallas kernel.

These are the ground truth: each function computes the same result as its
Pallas counterpart using only ``jax.numpy`` (no pallas_call), so any
divergence is a kernel bug. pytest (python/tests/test_kernel.py) sweeps
shapes/dtypes with hypothesis and asserts allclose.
"""

import jax
import jax.numpy as jnp


def matmul(x: jax.Array, y: jax.Array) -> jax.Array:
    """Oracle for kernels.matmul."""
    return jnp.matmul(
        x.astype(jnp.float32), y.astype(jnp.float32)
    )


def knn_squared_l2(query: jax.Array, rows: jax.Array) -> jax.Array:
    """Oracle for kernels.knn_squared_l2: direct (q - r)² reduction."""
    diff = rows.astype(jnp.float32) - query.astype(jnp.float32)[None, :]
    return jnp.sum(diff * diff, axis=1)


def sparse_length_sum(table: jax.Array, indices: jax.Array) -> jax.Array:
    """Oracle for kernels.sparse_length_sum."""
    return jnp.sum(
        jnp.take(table.astype(jnp.float32), indices.astype(jnp.int32), axis=0),
        axis=1,
    )


def predicate_filter(values: jax.Array, bounds: jax.Array) -> jax.Array:
    """Oracle for kernels.predicate_filter."""
    v = values.astype(jnp.float32)
    lo, hi = bounds.astype(jnp.float32)
    return ((v >= lo) & (v <= hi)).astype(jnp.float32)


def mha_decode_attention(q: jax.Array, k: jax.Array, v: jax.Array) -> jax.Array:
    """Oracle for kernels.mha_decode_attention (per-head softmax(qKᵀ)V)."""
    q = q.astype(jnp.float32)
    k = k.astype(jnp.float32)
    v = v.astype(jnp.float32)
    d = q.shape[-1]
    scores = jnp.einsum("hd,htd->ht", q, k) / jnp.sqrt(jnp.float32(d))
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("ht,htd->hd", p, v)


def edge_gather_scale(
    values: jax.Array, scales: jax.Array, src: jax.Array
) -> jax.Array:
    """Oracle for kernels.edge_gather_scale."""
    s = src.astype(jnp.int32)
    return jnp.take(values.astype(jnp.float32), s) * jnp.take(
        scales.astype(jnp.float32), s
    )


def segment_sum(contrib: jax.Array, dst: jax.Array, num_vertices: int) -> jax.Array:
    """Destination-side reduction used by the graph L2 model."""
    return jax.ops.segment_sum(
        contrib.astype(jnp.float32), dst.astype(jnp.int32), num_segments=num_vertices
    )


def top_k(distances: jax.Array, k: int):
    """Host-side KNN downstream task oracle: smallest-k distances."""
    neg_vals, idx = jax.lax.top_k(-distances.astype(jnp.float32), k)
    return -neg_vals, idx
