"""KNN squared-L2 distance Pallas kernel (VectorDB workload, Table I/IV).

The paper's CCM offloads "vector distance calculation": for a query vector
q ∈ R^D against a row database R ∈ R^{RxD}, the CCM streams rows from its
local DRAM through the PNM MAC blocks and returns one 4-byte float per row
(§III-B, Case #1). Here rows stream HBM→VMEM in (block_rows, D) tiles and
the kernel emits the per-row distance — exactly the reduced result the CCM
back-streams.

Distances use the MXU-friendly expansion ||q - r||² = ||q||² - 2 q·r + ||r||²
so the hot loop is a (block_rows, D) × (D,) matvec on the MXU rather than a
subtract/square VPU pass.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .matmul import pick_block


def _knn_kernel(q_ref, rows_ref, o_ref):
    """One grid step: distances of a (block_rows, D) tile against q."""
    q = q_ref[...]  # (D,)
    rows = rows_ref[...]  # (block_rows, D)
    q_sq = jnp.sum(q * q)
    row_sq = jnp.sum(rows * rows, axis=1)
    cross = jnp.dot(rows, q, preferred_element_type=jnp.float32)
    o_ref[...] = q_sq - 2.0 * cross + row_sq


@functools.partial(jax.jit, static_argnames=("block_rows",))
def knn_squared_l2(
    query: jax.Array, rows: jax.Array, *, block_rows: int = 128
) -> jax.Array:
    """Squared L2 distance of ``query`` to every row of ``rows``.

    Args:
      query: (D,) float vector.
      rows: (R, D) row database.
      block_rows: target rows per VMEM tile (clipped to a divisor of R).

    Returns:
      (R,) float32 distances — the per-row reduced result the CCM streams
      back (4 bytes/row, matching the paper's data-movement model).
    """
    r, d = rows.shape
    assert query.shape == (d,), f"query dim {query.shape} vs rows {rows.shape}"
    br = pick_block(r, block_rows)

    return pl.pallas_call(
        _knn_kernel,
        grid=(r // br,),
        in_specs=[
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((br, d), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((br,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((r,), jnp.float32),
        interpret=True,
    )(query.astype(jnp.float32), rows.astype(jnp.float32))
