"""Sparse-Length-Sum (SLS) Pallas kernel (DLRM workload, Table I/IV).

DLRM offloads "embedding table lookup → SLS" to the CCM: for each sample,
gather L embedding rows and sum them into one (D,) pooled vector. The CCM
keeps the (V, D) table in its local DRAM and returns only the pooled
vectors — the canonical bandwidth-amplified offload.

Pallas mapping: the table lives in the kernel's memory space whole (for the
CPU interpret path); each grid step pools one block of samples. On a real
TPU the table would sit in HBM with per-row DMA — the BlockSpec schedule
below is the interpret-mode stand-in (DESIGN.md §Hardware-Adaptation).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .matmul import pick_block


def _sls_kernel(table_ref, idx_ref, o_ref):
    """Pool one (block_b, L) index block against the full table."""
    table = table_ref[...]  # (V, D)
    idx = idx_ref[...]  # (block_b, L) int32
    gathered = jnp.take(table, idx, axis=0)  # (block_b, L, D)
    o_ref[...] = jnp.sum(gathered, axis=1)


@functools.partial(jax.jit, static_argnames=("block_b",))
def sparse_length_sum(
    table: jax.Array, indices: jax.Array, *, block_b: int = 64
) -> jax.Array:
    """Embedding lookup + pooled sum.

    Args:
      table: (V, D) embedding table (resides in CCM-local memory).
      indices: (B, L) int32 row indices per sample.
      block_b: target samples per grid step.

    Returns:
      (B, D) float32 pooled embeddings — the reduced result streamed back.
    """
    v, d = table.shape
    b, l = indices.shape
    bb = pick_block(b, block_b)

    return pl.pallas_call(
        _sls_kernel,
        grid=(b // bb,),
        in_specs=[
            pl.BlockSpec((v, d), lambda i: (0, 0)),
            pl.BlockSpec((bb, l), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bb, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, d), jnp.float32),
        interpret=True,
    )(table.astype(jnp.float32), indices.astype(jnp.int32))
