"""AOT pipeline: lower every Layer-2 workload model to HLO-text artifacts.

Interchange format is HLO **text**, not ``.serialize()``: jax ≥ 0.5 emits
HloModuleProto with 64-bit instruction ids, which the xla_extension 0.5.1
bundled in this image rejects (``proto.id() <= INT_MAX``). The text parser
reassigns ids, so text round-trips cleanly into the Rust PJRT client
(see /opt/xla-example/README.md).

Each workload in Table IV contributes two artifacts — its CCM half and its
host half (the offload boundary of Table I) — plus a ``manifest.json``
describing input/output shapes so the Rust runtime can construct literals.

Numerics run at *exec scale* (sizes the CPU PJRT client executes quickly);
the Rust simulator's timing model independently uses paper-scale parameters
(DESIGN.md §Reproduction strategy).

Usage: ``cd python && python -m compile.aot --out ../artifacts``
"""

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _shape_of(x):
    if isinstance(x, (tuple, list)):
        return [_shape_of(e) for e in x]
    return {"shape": list(x.shape), "dtype": str(x.dtype)}


# --------------------------------------------------------------------------
# Artifact registry: name -> (fn, example arg specs, metadata)
# --------------------------------------------------------------------------

# KNN top-k size used by every VectorDB host half.
KNN_K = 16

# Exec-scale LLM config (OPT-2.7B geometry scaled 4x down; paper scale is
# hidden=2560, heads=32, head_dim=80, ffn=10240, tokens=1024 — used by the
# simulator's timing model, not by these numerics artifacts).
LLM = dict(hidden=640, heads=8, head_dim=80, ffn=2560, tokens=256)

# Exec-scale graph (paper scale: SSSP |V|=264346 |E|=733846; PageRank
# |V|=299067 |E|=977676). Exec scale keeps artifact execution sub-second.
GRAPH = dict(v=8192, e=32768)

# Exec-scale OLAP (paper runs SSB Q1.1/Q1.2; SF1 lineorder is ~6M rows).
SSB = dict(rows=262144)

# Exec-scale DLRM (paper: dim 256, 1M-row Criteo lookups).
DLRM = dict(vocab=16384, dim=64, batch=256, lookups=32)


def build_registry():
    """All artifacts: name -> (callable, arg specs, metadata dict)."""
    reg = {}

    # ---- VectorDB / KNN (a)-(c): paper-scale shapes are exec-friendly ----
    for tag, (dim, rows) in {
        "a": (2048, 128),
        "b": (1024, 256),
        "c": (512, 512),
    }.items():
        reg[f"knn_{tag}_ccm"] = (
            model.knn_ccm,
            (_spec((dim,)), _spec((rows, dim))),
            {"workload": "knn", "dim": dim, "rows": rows},
        )
        reg[f"knn_{tag}_host"] = (
            lambda d, _k=KNN_K: model.knn_host(d, k=_k),
            (_spec((rows,)),),
            {"workload": "knn", "k": KNN_K, "rows": rows},
        )

    # ---- Graph analytics (d)-(e) ----
    v, e = GRAPH["v"], GRAPH["e"]
    reg["pagerank_ccm"] = (
        model.pagerank_ccm,
        (_spec((v,)), _spec((v,)), _spec((e,), jnp.int32)),
        {"workload": "pagerank", **GRAPH},
    )
    reg["pagerank_host"] = (
        lambda c, d: model.pagerank_host(c, d, num_vertices=v),
        (_spec((e,)), _spec((e,), jnp.int32)),
        {"workload": "pagerank", **GRAPH},
    )
    reg["sssp_ccm"] = (
        model.sssp_ccm,
        (_spec((v,)), _spec((v,)), _spec((e,), jnp.int32), _spec((e,))),
        {"workload": "sssp", **GRAPH},
    )
    reg["sssp_host"] = (
        model.sssp_host,
        (_spec((e,)), _spec((e,), jnp.int32), _spec((v,))),
        {"workload": "sssp", **GRAPH},
    )

    # ---- OLAP / SSB (f)-(g) ----
    n = SSB["rows"]
    reg["ssb_q1_ccm"] = (
        model.ssb_q1_ccm,
        (_spec((n,)), _spec((n,)), _spec((2,)), _spec((2,))),
        {"workload": "ssb", **SSB},
    )
    reg["ssb_q1_host"] = (
        model.ssb_q1_host,
        (_spec((n,)), _spec((n,)), _spec((n,))),
        {"workload": "ssb", **SSB},
    )

    # ---- LLM attention block (h) ----
    hd, nh, d, ffn, t = (
        LLM["hidden"],
        LLM["heads"],
        LLM["head_dim"],
        LLM["ffn"],
        LLM["tokens"],
    )
    reg["llm_attn_ccm"] = (
        model.attention_block_ccm,
        (
            _spec((1, hd)),
            _spec((nh, t, d)),
            _spec((nh, t, d)),
            _spec((hd, 3 * hd)),
            _spec((hd, hd)),
            _spec((hd,)),
            _spec((hd,)),
        ),
        {"workload": "llm", **LLM},
    )
    reg["llm_mlp_host"] = (
        model.mlp_host,
        (_spec((1, hd)), _spec((hd, ffn)), _spec((ffn,)), _spec((ffn, hd)), _spec((hd,))),
        {"workload": "llm", **LLM},
    )

    # ---- DLRM (i) ----
    vv, dd, bb, ll = DLRM["vocab"], DLRM["dim"], DLRM["batch"], DLRM["lookups"]
    reg["dlrm_ccm"] = (
        model.dlrm_ccm,
        (_spec((vv, dd)), _spec((bb, ll), jnp.int32)),
        {"workload": "dlrm", **DLRM},
    )
    reg["dlrm_host"] = (
        model.dlrm_host,
        (_spec((bb, dd)), _spec((bb, dd)), _spec((2 * dd, 1))),
        {"workload": "dlrm", **DLRM},
    )

    return reg


def lower_all(out_dir: str, only=None) -> dict:
    """Lower every registry entry to ``<out_dir>/<name>.hlo.txt``."""
    os.makedirs(out_dir, exist_ok=True)
    manifest = {}
    reg = build_registry()
    for name, (fn, specs, meta) in reg.items():
        if only and name not in only:
            continue
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        out_shapes = _shape_of(jax.eval_shape(fn, *specs))
        if not isinstance(out_shapes, list):
            out_shapes = [out_shapes]
        manifest[name] = {
            "file": fname,
            "inputs": [_shape_of(s) for s in specs],
            "outputs": out_shapes,
            "meta": meta,
            "sha256": hashlib.sha256(text.encode()).hexdigest()[:16],
        }
        print(f"  {name}: {len(text)} chars -> {fname}")
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    ap.add_argument("--only", nargs="*", help="subset of artifact names")
    args = ap.parse_args()
    manifest = lower_all(args.out, only=args.only)
    print(f"wrote {len(manifest)} artifacts + manifest.json to {args.out}")


if __name__ == "__main__":
    main()
