//! Minimal micro-benchmark harness (offline criterion stand-in).
//!
//! Measures wall time of a closure with warmup + repeated timed runs and
//! prints mean / min / max per iteration. `cargo bench` runs both bench
//! binaries (`harness = false`). Each [`bench`] call returns its
//! [`BenchStat`]; a bench binary can collect those and emit a
//! machine-readable JSON trajectory file via [`write_json`] (the figures
//! bench writes `BENCH_sweep.json`) so future changes have a perf
//! baseline to compare against.

// Shared by multiple bench binaries; not every binary uses every item.
#![allow(dead_code)]

use std::collections::BTreeMap;
use std::time::Instant;

use axle::util::json::Json;

/// Wall-time statistics of one benchmark entry (seconds per iteration).
#[derive(Debug, Clone)]
pub struct BenchStat {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub min_s: f64,
    pub max_s: f64,
}

/// Benchmark `f`, printing a stats line tagged `name` and returning the
/// measured statistics (targets ~0.5 s of timed runs).
pub fn bench<F: FnMut()>(name: &str, f: F) -> BenchStat {
    bench_target(name, 0.5, f)
}

/// [`bench`] with an explicit total-time target in seconds — the
/// downsized CI smoke run (`--smoke`) uses a smaller budget so the
/// serial/parallel pair fits a quick job.
pub fn bench_target<F: FnMut()>(name: &str, target_s: f64, mut f: F) -> BenchStat {
    // Warmup + pick an iteration count targeting ~`target_s` total.
    let t0 = Instant::now();
    f();
    let once = t0.elapsed().as_secs_f64().max(1e-9);
    let iters = ((target_s / once) as usize).clamp(1, 1000);
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64());
    }
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let min = samples.iter().cloned().fold(f64::MAX, f64::min);
    let max = samples.iter().cloned().fold(f64::MIN, f64::max);
    println!(
        "bench {name:<44} {:>10} iters  mean {:>12}  min {:>12}  max {:>12}",
        iters,
        fmt(mean),
        fmt(min),
        fmt(max)
    );
    BenchStat { name: name.to_string(), iters, mean_s: mean, min_s: min, max_s: max }
}

/// Write the collected stats as JSON:
/// `{"schema": ..., "worker_threads": N, "benches": [{name, iters, mean_s, min_s, max_s}]}`.
pub fn write_json(path: &str, worker_threads: usize, stats: &[BenchStat]) -> std::io::Result<()> {
    let mut root = BTreeMap::new();
    root.insert("schema".to_string(), Json::Str("axle-bench-v1".into()));
    root.insert("worker_threads".to_string(), Json::Num(worker_threads as f64));
    let benches: Vec<Json> = stats
        .iter()
        .map(|s| {
            let mut o = BTreeMap::new();
            o.insert("name".to_string(), Json::Str(s.name.clone()));
            o.insert("iters".to_string(), Json::Num(s.iters as f64));
            o.insert("mean_s".to_string(), Json::Num(s.mean_s));
            o.insert("min_s".to_string(), Json::Num(s.min_s));
            o.insert("max_s".to_string(), Json::Num(s.max_s));
            Json::Obj(o)
        })
        .collect();
    root.insert("benches".to_string(), Json::Arr(benches));
    std::fs::write(path, Json::Obj(root).to_string())
}

fn fmt(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} us", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}
