//! Minimal micro-benchmark harness (offline criterion stand-in).
//!
//! Measures wall time of a closure with warmup + repeated timed runs and
//! prints mean / min / max per iteration. `cargo bench` runs both bench
//! binaries (`harness = false`).

use std::time::Instant;

/// Benchmark `f`, printing a stats line tagged `name`.
pub fn bench<F: FnMut()>(name: &str, mut f: F) {
    // Warmup + pick an iteration count targeting ~0.5 s total.
    let t0 = Instant::now();
    f();
    let once = t0.elapsed().as_secs_f64().max(1e-9);
    let iters = ((0.5 / once) as usize).clamp(1, 1000);
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64());
    }
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let min = samples.iter().cloned().fold(f64::MAX, f64::min);
    let max = samples.iter().cloned().fold(f64::MIN, f64::max);
    println!(
        "bench {name:<44} {:>10} iters  mean {:>12}  min {:>12}  max {:>12}",
        iters,
        fmt(mean),
        fmt(min),
        fmt(max)
    );
}

fn fmt(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} us", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}
