//! Hot-path micro-benchmarks: the simulator primitives the perf pass
//! optimizes (EXPERIMENTS.md §Perf).

mod harness;

use axle::config::{Protocol, SimConfig};
use axle::protocol;
use axle::ring::{ProducerView, Ring};
use axle::sim::{EventQueue, PuPool};
use axle::util::rng::Pcg32;
use axle::workload::by_annotation;
use harness::bench;

fn main() {
    // Event queue: push/pop churn (the DES inner loop).
    bench("event_queue_push_pop_100k", || {
        let mut q: EventQueue<u64> = EventQueue::new();
        let mut rng = Pcg32::seed_from_u64(1);
        for i in 0..100_000u64 {
            q.push_at(rng.below(1 << 30), i);
        }
        while q.pop().is_some() {}
    });

    // Ring buffer: produce/consume churn with OoO gaps.
    bench("ring_ooo_churn_100k", || {
        let mut ring = Ring::new(1024);
        let mut pv = ProducerView::new(1024);
        let mut rng = Pcg32::seed_from_u64(2);
        let mut outstanding: Vec<u64> = Vec::new();
        for _ in 0..100_000 {
            if outstanding.len() < 512 {
                if let Some(first) = pv.try_claim(8) {
                    ring.produce(8);
                    outstanding.extend(first..first + 8);
                }
            }
            if !outstanding.is_empty() {
                let i = rng.below(outstanding.len() as u64) as usize;
                let id = outstanding.swap_remove(i);
                ring.consume(id);
                pv.update_head(ring.head());
            }
        }
    });

    // PU pool dispatch.
    bench("pu_pool_dispatch_100k", || {
        let mut pool = PuPool::new(32);
        let mut rng = Pcg32::seed_from_u64(3);
        let mut ready = 0u64;
        for _ in 0..100_000 {
            ready += rng.below(100);
            pool.dispatch(ready, rng.range(100, 10_000));
        }
    });

    // Whole protocol runs on the heaviest workloads.
    let cfg = SimConfig::m2ndp();
    for (label, annot) in [("pagerank", 'e'), ("dlrm", 'i'), ("llm", 'h')] {
        let w = by_annotation(annot, &cfg);
        bench(&format!("axle_end_to_end_{label}"), || {
            std::hint::black_box(protocol::run(Protocol::Axle, &w, &cfg));
        });
        bench(&format!("bs_end_to_end_{label}"), || {
            std::hint::black_box(protocol::run(Protocol::Bs, &w, &cfg));
        });
    }

    // Workload generation (RMAT etc. excluded — spec building only).
    bench("workload_generation_all", || {
        for a in axle::workload::ALL_ANNOTATIONS {
            std::hint::black_box(by_annotation(a, &cfg));
        }
    });

    // RMAT synthesis for the numerics path.
    bench("rmat_generation_32k_edges", || {
        std::hint::black_box(axle::workload::graph::SynthGraph::rmat(8192, 32_768, 7));
    });
}
