//! Figure benches: one harness entry per paper table/figure.
//!
//! Each entry regenerates the experiment behind the figure (the printed
//! simulated-cycle report lives in `axle-report`; here we measure the
//! harness cost of regenerating it, one bench per table/figure, so
//! `cargo bench` exercises the full evaluation matrix).

mod harness;

use axle::config::{poll_factors, Protocol, SchedPolicy, SimConfig};
use axle::protocol;
use axle::workload::{by_annotation, knn, llm, ALL_ANNOTATIONS};
use harness::bench;

fn main() {
    let cfg = SimConfig::m2ndp();

    // Fig. 3: six attention kernels under RP and BS.
    bench("fig03_attention_kernel_duality", || {
        for k in llm::AttnKernel::ALL {
            let w = llm::single_kernel(&cfg, k);
            std::hint::black_box(protocol::run(Protocol::Rp, &w, &cfg));
            std::hint::black_box(protocol::run(Protocol::Bs, &w, &cfg));
        }
    });

    // Fig. 4: KNN sweep on the real-hardware profile.
    bench("fig04_knn_real_hw_sweep", || {
        let hw = SimConfig::real_hw();
        for (dim, rows) in [(2048, 128), (512, 512), (128, 2048), (32, 4096)] {
            let w = knn::generate_queries(&hw, dim, rows, 4);
            std::hint::black_box(protocol::run(Protocol::Rp, &w, &hw));
        }
    });

    // Fig. 5 + Fig. 7: RP/BS breakdowns and idle times (same runs).
    bench("fig05_fig07_breakdown_rp_bs", || {
        for a in ['a', 'b', 'c', 'd', 'e'] {
            let w = by_annotation(a, &cfg);
            std::hint::black_box(protocol::run(Protocol::Rp, &w, &cfg));
            std::hint::black_box(protocol::run(Protocol::Bs, &w, &cfg));
        }
    });

    // Fig. 10: the full end-to-end matrix (9 workloads × 6 variants).
    bench("fig10_end_to_end_matrix", || {
        for a in ALL_ANNOTATIONS {
            let w = by_annotation(a, &cfg);
            std::hint::black_box(protocol::run(Protocol::Rp, &w, &cfg));
            std::hint::black_box(protocol::run(Protocol::Bs, &w, &cfg));
            std::hint::black_box(protocol::run(Protocol::AxleInterrupt, &w, &cfg));
            for p in [poll_factors::P1, poll_factors::P10, poll_factors::P100] {
                let c = cfg.clone().with_poll(p);
                std::hint::black_box(protocol::run(Protocol::Axle, &w, &c));
            }
        }
    });

    // Fig. 11: LLM on baseline vs reduced hardware.
    bench("fig11_llm_reduced_hw", || {
        for c in [SimConfig::m2ndp(), SimConfig::reduced()] {
            let w = by_annotation('h', &c);
            std::hint::black_box(protocol::run(Protocol::Rp, &w, &c));
            std::hint::black_box(protocol::run(Protocol::Axle, &w, &c));
        }
    });

    // Fig. 12: idle times at p10.
    bench("fig12_idle_times_p10", || {
        let c = cfg.clone().with_poll(poll_factors::P10);
        for a in ALL_ANNOTATIONS {
            let w = by_annotation(a, &c);
            std::hint::black_box(protocol::run(Protocol::Axle, &w, &c));
        }
    });

    // Fig. 13: host-core stall at p10 and p100.
    bench("fig13_host_stall_p10_p100", || {
        for p in [poll_factors::P10, poll_factors::P100] {
            let c = cfg.clone().with_poll(p);
            for a in ALL_ANNOTATIONS {
                let w = by_annotation(a, &c);
                std::hint::black_box(protocol::run(Protocol::Axle, &w, &c));
            }
        }
    });

    // Fig. 14: streaming-factor sweep on (a), (d), (i).
    bench("fig14_streaming_factor_sweep", || {
        for a in ['a', 'd', 'i'] {
            let w = by_annotation(a, &cfg);
            for sf in [32u64, 64, 256, 1024, 2048] {
                let mut c = cfg.clone();
                c.axle.streaming_factor_bytes = sf;
                std::hint::black_box(protocol::run(Protocol::Axle, &w, &c));
            }
        }
    });

    // Fig. 15: OoO × scheduler ablation.
    bench("fig15_ooo_ablation", || {
        for a in ['d', 'e', 'i'] {
            for sched in [SchedPolicy::RoundRobin, SchedPolicy::Fifo] {
                for ooo in [true, false] {
                    let mut c = cfg.clone();
                    c.sched = sched;
                    c.axle.ooo_streaming = ooo;
                    let w = by_annotation(a, &c);
                    std::hint::black_box(protocol::run(Protocol::Axle, &w, &c));
                }
            }
        }
    });

    // Fig. 16: DMA slot capacity sweep (including the deadlock case).
    bench("fig16_capacity_sweep", || {
        for a in ['a', 'd', 'h', 'i'] {
            for div in [1usize, 2, 4, 8] {
                let mut c = cfg.clone();
                c.axle.dma_slot_capacity /= div;
                let w = by_annotation(a, &c);
                std::hint::black_box(protocol::run(Protocol::Axle, &w, &c));
            }
        }
    });

    // Table IV: workload generation cost itself.
    bench("table4_workload_generation", || {
        for a in ALL_ANNOTATIONS {
            std::hint::black_box(by_annotation(a, &cfg));
        }
    });
}
