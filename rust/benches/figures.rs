//! Figure benches: one harness entry per paper table/figure.
//!
//! Each entry regenerates the experiment behind the figure (the printed
//! simulated-cycle report lives in `axle-report`; here we measure the
//! harness cost of regenerating it). Everything routes through the
//! parallel sweep engine (`axle::sweep`) on all available cores; the
//! `fig10_end_to_end_matrix` entry also runs with a single worker
//! (`*_serial`) so the serial/parallel ratio is recorded alongside.
//!
//! Results are written to `BENCH_sweep.json` (schema `axle-bench-v1`,
//! see `harness::write_json`) to give future PRs a perf trajectory. The
//! closed-loop scheduler's million-request throughput run is recorded
//! separately in `BENCH_sched.json` (same schema) alongside the
//! `sched requests/sec = N` line CI greps into its summary.

mod harness;

use std::sync::Arc;

use axle::config::{poll_factors, Protocol, SchedPolicy, SimConfig};
use axle::report;
use axle::sweep::{self, ConfigDelta, SpecJob, SweepPoint, WorkloadCache};
use axle::workload::{by_annotation, knn, llm, ALL_ANNOTATIONS};
use harness::{bench, bench_target, write_json, BenchStat};

/// Print the fig10 serial/parallel wall-time ratio (the speedup record
/// ROADMAP's bench item tracks; CI greps this line into its summary).
fn print_fig10_ratio(stats: &[BenchStat]) {
    let mean = |name: &str| stats.iter().find(|s| s.name == name).map(|s| s.mean_s);
    if let (Some(par), Some(ser)) =
        (mean("fig10_end_to_end_matrix"), mean("fig10_end_to_end_matrix_serial"))
    {
        println!(
            "fig10 matrix serial/parallel ratio: {:.2}x (parallel {:.1} ms, serial {:.1} ms)",
            ser / par,
            par * 1e3,
            ser * 1e3
        );
    }
}

/// Million-request closed-loop scheduler run: 256 tenants × 4096
/// requests each on an 8-device fabric-free pinned topology, streaming
/// aggregation (no per-request retention), sharded across `jobs`
/// workers. Writes `BENCH_sched.json` and prints the
/// `sched requests/sec = N` throughput line CI greps into its summary.
fn bench_sched(cfg: &SimConfig, jobs: usize, target_s: f64) {
    use axle::config::{Placement, PolicyKind, SchedSpec, TopologySpec};
    const STREAMS: usize = 256;
    const REQUESTS: usize = 4096;
    let topo = TopologySpec { devices: 8, ..Default::default() }
        .with_placement(Placement::Pinned);
    let spec = SchedSpec::new(STREAMS)
        .with_workloads(vec!['f'])
        .with_policy(PolicyKind::Static(Protocol::Axle))
        .with_requests(REQUESTS)
        .with_depth(2)
        .with_retain(false);
    let stat = bench_target("sched_closed_loop_1m", target_s, || {
        let r = axle::sched::run(&axle::sched::SchedRun::new(cfg, &topo, &spec).with_jobs(jobs))
            .report;
        assert!(r.streamed, "retain=false must stream");
        assert_eq!(r.scheduled, (STREAMS * REQUESTS) as u64);
        std::hint::black_box(r);
    });
    println!("sched requests/sec = {:.0}", (STREAMS * REQUESTS) as f64 / stat.mean_s);
    match write_json("BENCH_sched.json", jobs, std::slice::from_ref(&stat)) {
        Ok(()) => println!("wrote BENCH_sched.json (1 entry, {jobs} worker threads)"),
        Err(e) => {
            // CI depends on the artifact: fail the step, don't just warn.
            eprintln!("could not write BENCH_sched.json: {e}");
            std::process::exit(1);
        }
    }
}

fn main() {
    let cfg = SimConfig::m2ndp();
    let jobs = sweep::available_jobs();
    let mut stats: Vec<BenchStat> = Vec::new();

    // `--smoke` (CI's `make bench-smoke`): only the fig10 serial-vs-
    // parallel matrix pair, with a reduced per-entry time budget — the
    // smallest run that still measures the sweep engine's speedup.
    if std::env::args().any(|a| a == "--smoke") {
        let fig10_points = report::fig10_points();
        stats.push(bench_target("fig10_end_to_end_matrix", 0.15, || {
            std::hint::black_box(sweep::run_points(&cfg, &fig10_points, jobs));
        }));
        stats.push(bench_target("fig10_end_to_end_matrix_serial", 0.15, || {
            std::hint::black_box(sweep::run_points(&cfg, &fig10_points, 1));
        }));
        match write_json("BENCH_sweep.json", jobs, &stats) {
            Ok(()) => println!(
                "wrote BENCH_sweep.json ({} entries, {jobs} worker threads, smoke)",
                stats.len()
            ),
            Err(e) => {
                // CI depends on the artifact: fail the step, don't just warn.
                eprintln!("could not write BENCH_sweep.json: {e}");
                std::process::exit(1);
            }
        }
        print_fig10_ratio(&stats);
        bench_sched(&cfg, jobs, 0.15);
        return;
    }

    // Fig. 3: six attention kernels under RP and BS (custom specs).
    stats.push(bench("fig03_attention_kernel_duality", || {
        let shared = Arc::new(cfg.clone());
        let mut list = Vec::new();
        for k in llm::AttnKernel::ALL {
            let w = Arc::new(llm::single_kernel(&cfg, k));
            for proto in [Protocol::Rp, Protocol::Bs] {
                list.push(SpecJob { w: Arc::clone(&w), proto, cfg: Arc::clone(&shared) });
            }
        }
        std::hint::black_box(sweep::run_jobs(&list, jobs));
    }));

    // Fig. 4: KNN sweep on the real-hardware profile (custom specs).
    stats.push(bench("fig04_knn_real_hw_sweep", || {
        let hw = SimConfig::real_hw();
        let shared = Arc::new(hw.clone());
        let list: Vec<SpecJob> = [(2048, 128), (512, 512), (128, 2048), (32, 4096)]
            .iter()
            .map(|&(dim, rows)| SpecJob {
                w: Arc::new(knn::generate_queries(&hw, dim, rows, 4)),
                proto: Protocol::Rp,
                cfg: Arc::clone(&shared),
            })
            .collect();
        std::hint::black_box(sweep::run_jobs(&list, jobs));
    }));

    // Fig. 5 + Fig. 7: RP/BS breakdowns and idle times (same runs).
    stats.push(bench("fig05_fig07_breakdown_rp_bs", || {
        let mut points = Vec::new();
        for a in ['a', 'b', 'c', 'd', 'e'] {
            points.push(SweepPoint::new(a, Protocol::Rp, ConfigDelta::identity()));
            points.push(SweepPoint::new(a, Protocol::Bs, ConfigDelta::identity()));
        }
        std::hint::black_box(sweep::run_points(&cfg, &points, jobs));
    }));

    // Fig. 10: the full end-to-end matrix (9 workloads × 6 variants) —
    // parallel, plus the single-worker baseline for the speedup record.
    let fig10_points = report::fig10_points();
    stats.push(bench("fig10_end_to_end_matrix", || {
        std::hint::black_box(sweep::run_points(&cfg, &fig10_points, jobs));
    }));
    stats.push(bench("fig10_end_to_end_matrix_serial", || {
        std::hint::black_box(sweep::run_points(&cfg, &fig10_points, 1));
    }));

    // Fig. 11: LLM on baseline vs reduced hardware.
    stats.push(bench("fig11_llm_reduced_hw", || {
        for c in [SimConfig::m2ndp(), SimConfig::reduced()] {
            let points = [
                SweepPoint::new('h', Protocol::Rp, ConfigDelta::identity()),
                SweepPoint::new('h', Protocol::Axle, ConfigDelta::identity()),
            ];
            std::hint::black_box(sweep::run_points(&c, &points, jobs));
        }
    }));

    // Fig. 12: idle times at p10.
    stats.push(bench("fig12_idle_times_p10", || {
        let p10 = ConfigDelta::identity().with_poll(poll_factors::P10);
        let points: Vec<SweepPoint> =
            ALL_ANNOTATIONS.iter().map(|&a| SweepPoint::new(a, Protocol::Axle, p10)).collect();
        std::hint::black_box(sweep::run_points(&cfg, &points, jobs));
    }));

    // Fig. 13: host-core stall at p10 and p100.
    stats.push(bench("fig13_host_stall_p10_p100", || {
        let mut points = Vec::new();
        for p in [poll_factors::P10, poll_factors::P100] {
            let delta = ConfigDelta::identity().with_poll(p);
            for a in ALL_ANNOTATIONS {
                points.push(SweepPoint::new(a, Protocol::Axle, delta));
            }
        }
        std::hint::black_box(sweep::run_points(&cfg, &points, jobs));
    }));

    // Fig. 14: streaming-factor sweep on (a), (d), (i).
    stats.push(bench("fig14_streaming_factor_sweep", || {
        let mut points = Vec::new();
        for a in ['a', 'd', 'i'] {
            for sf in [32u64, 64, 256, 1024, 2048] {
                let delta = ConfigDelta::identity().with_sf(sf);
                points.push(SweepPoint::new(a, Protocol::Axle, delta));
            }
        }
        std::hint::black_box(sweep::run_points(&cfg, &points, jobs));
    }));

    // Fig. 15: OoO × scheduler ablation.
    stats.push(bench("fig15_ooo_ablation", || {
        let mut points = Vec::new();
        for a in ['d', 'e', 'i'] {
            for sched in [SchedPolicy::RoundRobin, SchedPolicy::Fifo] {
                for ooo in [true, false] {
                    points.push(SweepPoint::new(
                        a,
                        Protocol::Axle,
                        ConfigDelta::identity().with_sched(sched).with_ooo(ooo),
                    ));
                }
            }
        }
        std::hint::black_box(sweep::run_points(&cfg, &points, jobs));
    }));

    // Fig. 16: DMA slot capacity sweep (including the deadlock case).
    stats.push(bench("fig16_capacity_sweep", || {
        let mut points = Vec::new();
        for a in ['a', 'd', 'h', 'i'] {
            for div in [1usize, 2, 4, 8] {
                points.push(SweepPoint::new(
                    a,
                    Protocol::Axle,
                    ConfigDelta::identity().with_capacity(cfg.axle.dma_slot_capacity / div),
                ));
            }
        }
        std::hint::black_box(sweep::run_points(&cfg, &points, jobs));
    }));

    // Table IV: workload generation cost itself (uncached vs cached).
    stats.push(bench("table4_workload_generation", || {
        for a in ALL_ANNOTATIONS {
            std::hint::black_box(by_annotation(a, &cfg));
        }
    }));
    stats.push(bench("table4_workload_generation_cached", || {
        let mut cache = WorkloadCache::new();
        for _ in 0..2 {
            for a in ALL_ANNOTATIONS {
                std::hint::black_box(cache.get(a, &cfg));
            }
        }
    }));

    match write_json("BENCH_sweep.json", jobs, &stats) {
        Ok(()) => {
            println!("wrote BENCH_sweep.json ({} entries, {jobs} worker threads)", stats.len())
        }
        Err(e) => eprintln!("could not write BENCH_sweep.json: {e}"),
    }
    print_fig10_ratio(&stats);
    bench_sched(&cfg, jobs, 0.5);
}
