//! Run metrics: the quantities the paper's evaluation reports.
//!
//! - **end-to-end runtime** (Fig. 10/11): completion time of the last host
//!   task of the last iteration;
//! - **component times** T_C / T_D / T_H (Fig. 5): busy-union of the CCM
//!   pool, CXL data movement, and the host pool;
//! - **two idle times** (Fig. 7/12): `total - busy_union` per side — idle
//!   aggregates launch latency, stalls and opposite-side waiting, exactly
//!   the paper's §III-C accounting;
//! - **host core stall time** (Fig. 13): cycles spent on CXL/local memory
//!   operations of the offload interaction (remote polls, synchronous
//!   loads, local uncached polls, flow-control stores);
//! - **back-pressure cycles** (Fig. 16b): time the CCM's DMA executor is
//!   blocked waiting for host ring credit.

pub mod sketch;

pub use sketch::QuantileSketch;

use std::collections::BTreeMap;

use crate::sim::Ps;
use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct RunMetrics {
    pub workload: String,
    pub annot: char,
    pub protocol: String,
    /// End-to-end runtime.
    pub total: Ps,
    /// CCM processing busy-union (T_C).
    pub ccm_busy: Ps,
    /// Data movement busy-union (T_D).
    pub dm_busy: Ps,
    /// Host task busy-union (T_H).
    pub host_busy: Ps,
    /// Host core stall time (Fig. 13 metric).
    pub host_stall: Ps,
    /// CCM DMA executor blocked on ring credit (Fig. 16b metric).
    pub backpressure: Ps,
    /// Simulation event count (engine load, perf accounting).
    pub events: u64,
    /// Remote/local polls issued.
    pub polls: u64,
    /// Back-streaming DMA batches sent (AXLE).
    pub dma_batches: u64,
    /// Flow-control messages sent host→CCM (AXLE).
    pub fc_messages: u64,
    /// Result bytes moved CCM→host.
    pub result_bytes: u64,
    /// True if the run ended in a detected deadlock (Fig. 16's (h) case).
    pub deadlock: bool,
}

impl RunMetrics {
    /// Zero-initialized metrics for `w` under `protocol` — the single
    /// construction point for the four protocol engines. Engines set only
    /// the quantities they actually measure; a field added to
    /// `RunMetrics` defaults to zero/false *here*, in one place, instead
    /// of being hand-stuffed (and silently mis-defaulted) in four
    /// engine-local struct literals.
    pub fn base(w: &crate::workload::WorkloadSpec, protocol: impl Into<String>) -> Self {
        Self {
            workload: w.name.clone(),
            annot: w.annot,
            protocol: protocol.into(),
            total: 0,
            ccm_busy: 0,
            dm_busy: 0,
            host_busy: 0,
            host_stall: 0,
            backpressure: 0,
            events: 0,
            polls: 0,
            dma_batches: 0,
            fc_messages: 0,
            result_bytes: 0,
            deadlock: false,
        }
    }

    /// CCM idle time (paper Observation #3): total − T_C.
    pub fn ccm_idle(&self) -> Ps {
        self.total.saturating_sub(self.ccm_busy)
    }

    /// Host idle time: total − T_H.
    pub fn host_idle(&self) -> Ps {
        self.total.saturating_sub(self.host_busy)
    }

    /// Host stall time clamped to the end-to-end total (Fig. 13's
    /// reported quantity). The aggregate spin-poll accounting can
    /// nominally exceed a short run's total, so every consumer reports
    /// this clamped value rather than `host_stall` directly.
    pub fn host_stall_clamped(&self) -> Ps {
        self.host_stall.min(self.total)
    }

    /// Fraction helpers (relative to this run's total).
    pub fn frac(&self, x: Ps) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            x as f64 / self.total as f64
        }
    }

    /// Ratio of this run's total to a baseline total (Fig. 10's
    /// "normalized end-to-end runtime ratio").
    pub fn ratio_to(&self, baseline: &RunMetrics) -> f64 {
        if baseline.total == 0 {
            f64::NAN
        } else {
            self.total as f64 / baseline.total as f64
        }
    }

    /// JSON dump (machine-readable metric exports).
    pub fn to_json(&self) -> Json {
        let mut o = BTreeMap::new();
        o.insert("workload".into(), Json::Str(self.workload.clone()));
        o.insert("annot".into(), Json::Str(self.annot.to_string()));
        o.insert("protocol".into(), Json::Str(self.protocol.clone()));
        for (k, v) in [
            ("total_ps", self.total),
            ("ccm_busy_ps", self.ccm_busy),
            ("dm_busy_ps", self.dm_busy),
            ("host_busy_ps", self.host_busy),
            ("host_stall_ps", self.host_stall),
            ("backpressure_ps", self.backpressure),
            ("events", self.events),
            ("polls", self.polls),
            ("dma_batches", self.dma_batches),
            ("fc_messages", self.fc_messages),
            ("result_bytes", self.result_bytes),
        ] {
            o.insert(k.into(), Json::Num(v as f64));
        }
        o.insert("deadlock".into(), Json::Bool(self.deadlock));
        Json::Obj(o)
    }
}

/// Geometric mean of a slice of positive ratios (Fig. 10j summary row).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// Arithmetic mean.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Rounded linear-index percentile of `xs` (`q` in 0..=100): sorts and
/// returns `sorted[round(q/100 · (len−1))]` — NOT the textbook
/// nearest-rank `sorted[ceil(q/100 · len) − 1]` (p50 of [1,2,3,4] is 3.0
/// here, 2.0 under nearest-rank). NaN on empty input. Used for the
/// multi-tenant p50/p99 slowdown aggregates.
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
    let idx = (q.clamp(0.0, 100.0) / 100.0 * (sorted.len() - 1) as f64).round() as usize;
    sorted[idx]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn m(total: Ps, ccm: Ps, host: Ps) -> RunMetrics {
        RunMetrics {
            workload: "t".into(),
            annot: 'a',
            protocol: "BS".into(),
            total,
            ccm_busy: ccm,
            dm_busy: 0,
            host_busy: host,
            host_stall: 0,
            backpressure: 0,
            events: 0,
            polls: 0,
            dma_batches: 0,
            fc_messages: 0,
            result_bytes: 0,
            deadlock: false,
        }
    }

    #[test]
    fn idle_times_are_complements() {
        let r = m(100, 30, 50);
        assert_eq!(r.ccm_idle(), 70);
        assert_eq!(r.host_idle(), 50);
    }

    #[test]
    fn serialized_pipeline_idle_identity() {
        // §III-C: in a fully serialized pipeline, host idle = T_C + T_D.
        let mut r = m(100, 49, 2);
        r.dm_busy = 49;
        assert_eq!(r.host_idle(), r.ccm_busy + r.dm_busy);
    }

    #[test]
    fn host_stall_clamps_to_total() {
        let mut r = m(100, 0, 0);
        r.host_stall = 250;
        assert_eq!(r.host_stall_clamped(), 100);
        r.host_stall = 40;
        assert_eq!(r.host_stall_clamped(), 40);
    }

    #[test]
    fn geomean_and_mean() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((mean(&[1.0, 3.0]) - 2.0).abs() < 1e-12);
        assert!(geomean(&[]).is_nan());
    }

    #[test]
    fn ratio_to_baseline() {
        let a = m(50, 0, 0);
        let b = m(100, 0, 0);
        assert!((a.ratio_to(&b) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn base_constructor_zeroes_everything() {
        let w = crate::workload::WorkloadSpec {
            name: "t".into(),
            annot: 'z',
            domain: "test",
            iters: vec![],
        };
        let b = RunMetrics::base(&w, "AXLE");
        assert_eq!(b.workload, "t");
        assert_eq!(b.annot, 'z');
        assert_eq!(b.protocol, "AXLE");
        assert_eq!(
            (b.total, b.ccm_busy, b.dm_busy, b.host_busy, b.host_stall, b.backpressure),
            (0, 0, 0, 0, 0, 0)
        );
        assert_eq!(
            (b.events, b.polls, b.dma_batches, b.fc_messages, b.result_bytes),
            (0, 0, 0, 0, 0)
        );
        assert!(!b.deadlock);
    }

    #[test]
    fn percentile_nearest_rank() {
        let xs = [4.0, 1.0, 3.0, 2.0];
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile(&xs, 100.0) - 4.0).abs() < 1e-12);
        assert!((percentile(&xs, 50.0) - 3.0).abs() < 1e-12); // round(1.5) = 2 → 3.0
        assert!(percentile(&[], 50.0).is_nan());
        assert!((percentile(&[7.0], 99.0) - 7.0).abs() < 1e-12);
    }
}
