//! Deterministic streaming quantile sketch for million-request runs.
//!
//! The closed-loop scheduler historically retained every request's
//! slowdown in a `Vec<f64>` and sorted it at report time — O(n) memory
//! and O(n log n) post-processing that caps a run at thousands of
//! requests. [`QuantileSketch`] replaces that with a **fixed-size
//! log-linear histogram** (HdrHistogram-style): 64 octaves × 128
//! sub-buckets taken straight from the top mantissa bits of the `f64`
//! bit pattern, 8192 `u64` counters total (64 KiB), O(1) record, O(1)
//! memory, O(buckets) quantile.
//!
//! Determinism is the design constraint, not an accident:
//!
//! - bucket indexing is pure bit arithmetic on the IEEE-754
//!   representation (no `ln`/`log2`, whose libm implementations vary
//!   across platforms);
//! - bucket representatives are reconstructed with `f64::from_bits`, so
//!   a quantile is a bit-exact function of the recorded multiset;
//! - merging two sketches is element-wise counter addition, so a
//!   sharded run's merged quantiles are bit-identical to the same
//!   requests recorded into one sketch in any order.
//!
//! The quantile rank rule mirrors [`crate::metrics::percentile`]
//! (`round(q/100 · (n−1))` on the sorted multiset), and results are
//! clamped to the exactly-tracked `[min, max]`, so p0/p100 are exact
//! and any interior quantile is within one sub-bucket (relative error
//! ≤ 2⁻⁸ ≈ 0.4%) of the retained-vector answer.

use crate::util::json::Json;

/// Lowest tracked octave: values below 2⁻¹⁶ clamp into bucket 0.
const EXP_LO: i64 = -16;
/// Number of octaves (binary orders of magnitude) tracked.
const OCTAVES: i64 = 64;
/// log₂(sub-buckets per octave): 7 bits of mantissa → 128 sub-buckets.
const SUB_BITS: u64 = 7;
/// Total bucket count: 64 octaves × 128 sub-buckets.
const BUCKETS: usize = (OCTAVES as usize) << SUB_BITS;

/// Fixed-size deterministic quantile sketch (see module docs).
#[derive(Debug, Clone)]
pub struct QuantileSketch {
    counts: Vec<u64>,
    count: u64,
    min: f64,
    max: f64,
}

impl Default for QuantileSketch {
    fn default() -> Self {
        Self::new()
    }
}

impl QuantileSketch {
    pub fn new() -> Self {
        Self { counts: vec![0; BUCKETS], count: 0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    /// Bucket index of `v`: octave from the exponent bits, sub-bucket
    /// from the top 7 mantissa bits. Non-positive and NaN values clamp
    /// to bucket 0, values above the top octave to the last bucket.
    fn index(v: f64) -> usize {
        if !(v > 0.0) {
            return 0;
        }
        let bits = v.to_bits();
        let exp = ((bits >> 52) & 0x7FF) as i64 - 1023;
        if exp < EXP_LO {
            return 0;
        }
        if exp >= EXP_LO + OCTAVES {
            return BUCKETS - 1;
        }
        let sub = (bits >> (52 - SUB_BITS)) & ((1 << SUB_BITS) - 1);
        ((((exp - EXP_LO) as u64) << SUB_BITS) | sub) as usize
    }

    /// Representative value of bucket `idx`: the bit-exact midpoint of
    /// the bucket's value range (`1.mmmmmmm1000…` × 2^octave).
    fn value_of(idx: usize) -> f64 {
        let exp = EXP_LO + (idx >> SUB_BITS) as i64;
        let sub = (idx as u64) & ((1 << SUB_BITS) - 1);
        let bits =
            (((exp + 1023) as u64) << 52) | (sub << (52 - SUB_BITS)) | (1 << (52 - SUB_BITS - 1));
        f64::from_bits(bits)
    }

    /// Record one observation. O(1), allocation-free.
    pub fn record(&mut self, v: f64) {
        if v.is_nan() {
            return;
        }
        self.counts[Self::index(v)] += 1;
        self.count += 1;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    /// Observations recorded.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Exact minimum recorded value (NaN when empty).
    pub fn min(&self) -> f64 {
        if self.count == 0 {
            f64::NAN
        } else {
            self.min
        }
    }

    /// Exact maximum recorded value (NaN when empty).
    pub fn max(&self) -> f64 {
        if self.count == 0 {
            f64::NAN
        } else {
            self.max
        }
    }

    /// Quantile `q` in `0..=100` under the same rank rule as
    /// [`crate::metrics::percentile`]: the bucket holding sorted element
    /// `round(q/100 · (n−1))`, clamped to the exact `[min, max]`. NaN
    /// when empty.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return f64::NAN;
        }
        let rank = (q.clamp(0.0, 100.0) / 100.0 * (self.count - 1) as f64).round() as u64;
        // Extreme ranks answer from the exactly-tracked bounds: a bucket
        // representative sits mid-bucket, so without these the clamp
        // alone would leave p0/p100 one half-bucket off.
        if rank == 0 {
            return self.min;
        }
        if rank == self.count - 1 {
            return self.max;
        }
        let mut cum = 0u64;
        for (idx, &c) in self.counts.iter().enumerate() {
            cum += c;
            if cum > rank {
                return Self::value_of(idx).clamp(self.min, self.max);
            }
        }
        self.max
    }

    /// Fold `other` into `self`: counter addition plus min/max folds.
    /// Merge order never affects any subsequent quantile.
    pub fn merge(&mut self, other: &QuantileSketch) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
        self.count += other.count;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    /// Compact JSON summary (count + the headline quantiles).
    pub fn to_json(&self) -> Json {
        let mut o = std::collections::BTreeMap::new();
        o.insert("count".into(), Json::Num(self.count as f64));
        o.insert("p50".into(), Json::Num(self.quantile(50.0)));
        o.insert("p99".into(), Json::Num(self.quantile(99.0)));
        o.insert("max".into(), Json::Num(self.max()));
        Json::Obj(o)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::percentile;

    #[test]
    fn empty_sketch_reports_nan() {
        let sk = QuantileSketch::new();
        assert_eq!(sk.count(), 0);
        assert!(sk.quantile(50.0).is_nan());
        assert!(sk.min().is_nan() && sk.max().is_nan());
    }

    #[test]
    fn single_value_is_exact_at_every_quantile() {
        let mut sk = QuantileSketch::new();
        sk.record(3.25);
        for q in [0.0, 50.0, 99.0, 100.0] {
            assert_eq!(sk.quantile(q), 3.25, "q={q}");
        }
    }

    #[test]
    fn extremes_are_exact_and_interior_is_within_a_bucket() {
        let mut sk = QuantileSketch::new();
        let xs: Vec<f64> = (0..1000).map(|i| 1.0 + (i as f64) * 0.01).collect();
        for &x in &xs {
            sk.record(x);
        }
        assert_eq!(sk.quantile(0.0), 1.0);
        assert_eq!(sk.quantile(100.0), 1.0 + 999.0 * 0.01);
        for q in [25.0, 50.0, 90.0, 99.0] {
            let exact = percentile(&xs, q);
            let approx = sk.quantile(q);
            assert!(
                (approx - exact).abs() / exact <= 1.0 / 128.0,
                "q={q}: sketch {approx} vs exact {exact}"
            );
        }
    }

    #[test]
    fn merge_equals_single_sketch_bit_for_bit() {
        let mut whole = QuantileSketch::new();
        let mut a = QuantileSketch::new();
        let mut b = QuantileSketch::new();
        for i in 0..500 {
            let v = 1.0 + (i % 97) as f64 * 0.37;
            whole.record(v);
            if i % 2 == 0 {
                a.record(v);
            } else {
                b.record(v);
            }
        }
        a.merge(&b);
        assert_eq!(a.count(), whole.count());
        for q in [0.0, 10.0, 50.0, 99.0, 100.0] {
            assert_eq!(a.quantile(q).to_bits(), whole.quantile(q).to_bits(), "q={q}");
        }
    }

    #[test]
    fn out_of_range_values_clamp_instead_of_panicking() {
        let mut sk = QuantileSketch::new();
        sk.record(0.0);
        sk.record(-4.0);
        sk.record(f64::MAX);
        sk.record(f64::NAN); // ignored
        assert_eq!(sk.count(), 3);
        assert_eq!(sk.min(), -4.0);
        assert_eq!(sk.max(), f64::MAX);
        // Quantiles stay inside the exact range even for clamped values.
        let q = sk.quantile(50.0);
        assert!((-4.0..=f64::MAX).contains(&q));
    }
}
