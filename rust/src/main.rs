//! `axle` CLI: the leader entrypoint for the AXLE reproduction.
//!
//! ```text
//! axle run --workload e --protocol axle --poll-ns 500
//! axle matrix [--profile real-hw|reduced]
//! axle sweep [--jobs N] [--workloads adei] [--protocol axle] [--json]
//! axle tenants --devices 2 --streams 8 [--qos wrr --weights 4,1] [--json]
//! axle sched --streams 8 --policy heuristic --depth 2 [--dev-ccm-pus 16,4] [--json]
//! axle validate [--artifacts DIR] [--workload e]
//! axle report fig10 | fig17 | fig19 | all | ...
//! axle list
//! axle config [--out cfg.json] / axle run --config cfg.json ...
//! ```

use anyhow::{bail, Context, Result};

use axle::config::{
    FaultEvent, FaultKind, FaultSpec, Placement, PipelineMode, PipelineSpec, PolicyKind, Protocol,
    QosPolicy, SchedPolicy, SchedSpec, SimConfig, TopologySpec,
};
use axle::config::TraceSpec;
use axle::sched;
use axle::sim::{ps_to_us, NS};
use axle::sweep::{self, ConfigDelta, SweepSpec};
use axle::topo::{self, TenantSpec};
use axle::trace;
use axle::util::args::Args;
use axle::util::fmt::{fmt_pct, fmt_time};
use axle::util::json::Json;
use axle::{report, Coordinator, RunMetrics};

const USAGE: &str = "\
axle — asynchronous back-streaming CCM offloading (paper reproduction)

USAGE:
  axle run --workload <a..i> [--protocol rp|bs|axle|axle-interrupt]
           [--profile m2ndp|real-hw|reduced] [--config FILE.json]
           [--poll-ns N] [--sf BYTES] [--adaptive-sf] [--capacity SLOTS]
           [--no-ooo] [--fifo] [--seed N] [--json]
  axle matrix [--profile ...]
  axle sweep [--jobs N] [--workloads <subset, e.g. adei>]
             [--protocol rp|bs|axle|axle-interrupt] [--profile ...] [--json]
        # the evaluation matrix on N worker threads (default: all cores);
        # results are bit-identical to the serial path in spec order
  axle tenants [--devices D] [--streams K] [--placement rr|least-loaded|pinned]
               [--fabric-gbps X | --no-fabric] [--topo FILE.json]
               [--qos fcfs|wrr|drr] [--weights W0,W1,...] [--floors F0,F1,...]
               [--workloads <mix, e.g. adei>] [--protocol ...] [--load F]
               [--tenant-seed N] [--jobs N] [--profile ...] [--json]
        # K concurrent streams over D CCM devices behind a shared CXL
        # fabric: deterministic open-loop arrivals, per-tenant slowdown
        # decomposed into wire + CCM-PU contention shifts; --qos picks
        # the link arbitration (fcfs | weighted rr | deficit rr with
        # per-tenant bandwidth floors), --weights/--floors cycle over
        # tenant ids
  axle sched [--streams K] [--requests R]
             [--policy static|heuristic|oracle|learned] [--explore N]
             [--protocol rp|bs|axle|axle-interrupt]  # static policy's pin
             [--depth N] [--admit M] [--prio C0,C1,...] [--think-ns T]
             [--qos fcfs|wrr|drr] [--weights W0,W1,...] [--floors F0,F1,...]
             [--open [--load F]]
             [--devices D] [--placement rr|least-loaded|pinned]
             [--fabric-gbps X | --no-fabric] [--topo FILE.json]
             [--dev-ccm-pus P0,P1,...] [--dev-gbps B0,B1,...]
             [--workloads <mix>] [--sched-seed N] [--jobs N]
             [--dump-requests]
             [--faults SPEC] [--max-retries N] [--backoff-us T]
             [--timeout-factor F]
             [--chunks N] [--chunk-mode auto|serial|pipelined]
             [--trace FILE.json] [--trace-buckets N]
             [--profile ...] [--json]
        # closed-loop scheduling: K tenants submit requests against
        # completion feedback (at most --depth outstanding each), each
        # device admits --admit requests at a time from its admission
        # queue (--prio cycles priority classes over tenants: a higher
        # class jumps the FIFO at admission, never revoking in-service
        # work), and --policy picks the decider that places each
        # request and picks its offload protocol (static pins one
        # protocol; heuristic adapts to compute/transfer ratio +
        # observed occupancy; oracle is the clairvoyant bound; learned
        # drives per-device latency estimators from completion feedback
        # with seeded epsilon-greedy exploration tuned by --explore N —
        # the rate starts at 1 and decays as N/(visits+N), 0 = pure
        # greedy); --qos
        # picks how the live link calendars charge wire time (fcfs |
        # weighted rr | deficit rr, --weights/--floors cycle over
        # tenant ids); --dev-ccm-pus/--dev-gbps cycle per-device
        # hardware overrides over the devices (heterogeneous classes);
        # --open reproduces the PR-3 open-loop `axle tenants` arrivals
        # bit-identically (static policies only); --faults injects
        # deterministic device faults: comma-separated events
        # kind@device:start_us[..end_us][xFACTOR] with kind one of
        # fail | stall | degrade-pus | degrade-link, e.g.
        # 'fail@0:800' 'stall@0:100..300' 'degrade-pus@1:50..150x4';
        # recovery is tuned by --max-retries (default 3), --backoff-us
        # (base exponential backoff, default 50) and --timeout-factor
        # (requeue timeout as a multiple of the solo estimate, default 8);
        # the closed loop aggregates through streaming sketches (O(1)
        # memory per request — million-request runs are fine) unless
        # --dump-requests retains per-request rows; --jobs N also shards
        # the event engine across worker threads on fabric-free --placement
        # pinned topologies (identical results to --jobs 1); --chunks N
        # splits each request into N stage-DAG chunks admitted at stage
        # granularity (back-streaming overlaps the next chunk's
        # transfer; --chunk-mode overrides the per-protocol DAG shape);
        # --trace FILE records every engine event (admissions, wire
        # grants, PU leases, retries, fault windows) and writes a
        # Chrome trace-event JSON loadable in Perfetto — tracing is
        # observation-only, results are bit-identical with it on or
        # off; --trace-buckets N also prints an N-window telemetry
        # table (host/CCM utilization, queue depth, p99 slowdown)
  axle scenario [--streams K] [--requests R] [--jobs N] [--profile ...]
                [--learned] [--json]
        # canned failover demo (the CI smoke): closed-loop tenants over
        # one strong + one weak CCM device, the strong device failing
        # permanently mid-service; prints the time-to-recover, lost
        # work, and makespan/slowdown deltas against the fault-free
        # baseline; --learned runs the nonstationary scenario instead
        # (device 0 degrades 8x mid-run) and prints the learned vs
        # heuristic vs oracle makespans
  axle validate [--artifacts DIR] [--workload <a..i>]
  axle report <all|table1|table2|table4|fig3|fig4|fig5|fig7|fig10|fig11|fig12|fig13|fig14|fig15|fig16|fig17|fig19|fig20|fig21|fig22|fig23>
  axle config [--out FILE.json]     # dump the Table III defaults
  axle list
";

fn parse_protocol(s: &str) -> Result<Protocol> {
    Protocol::parse(s)
        .ok_or_else(|| anyhow::anyhow!("unknown protocol {s:?} (rp|bs|axle|axle-interrupt)"))
}

fn parse_profile(s: &str) -> Result<SimConfig> {
    Ok(match s {
        "m2ndp" => SimConfig::m2ndp(),
        "real-hw" | "real_hw" => SimConfig::real_hw(),
        "reduced" => SimConfig::reduced(),
        _ => bail!("unknown profile {s:?} (m2ndp|real-hw|reduced)"),
    })
}

fn build_config(a: &Args) -> Result<SimConfig> {
    let mut cfg = match a.get("config") {
        Some(path) => {
            let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
            SimConfig::from_json(&Json::parse(&text).context("parsing config JSON")?)
        }
        None => parse_profile(a.get("profile").unwrap_or("m2ndp"))?,
    };
    if let Some(p) = a.get_as::<u64>("poll-ns") {
        cfg.axle.poll_interval = p * NS;
    }
    if let Some(s) = a.get_as::<u64>("sf") {
        cfg.axle.streaming_factor_bytes = s;
    }
    if let Some(c) = a.get_as::<usize>("capacity") {
        cfg.axle.dma_slot_capacity = c;
    }
    if let Some(s) = a.get_as::<u64>("seed") {
        cfg.seed = s;
    }
    if a.has("no-ooo") {
        cfg.axle.ooo_streaming = false;
    }
    if a.has("adaptive-sf") {
        cfg.axle.sf_policy = axle::config::SfPolicy::Adaptive;
    }
    if a.has("fifo") {
        cfg.sched = SchedPolicy::Fifo;
    }
    Ok(cfg)
}

/// Topology from a `--topo` file base (if given) plus flag overrides —
/// shared by the `tenants` and `sched` subcommands. The default is a
/// shared upstream fabric of one device-link width: the single x8 port a
/// multi-headed expander shares upstream.
fn build_topology(a: &Args, cfg: &SimConfig) -> Result<TopologySpec> {
    let mut topo = match a.get("topo") {
        Some(path) => {
            let text =
                std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
            TopologySpec::from_json(&Json::parse(&text).context("parsing topology JSON")?)
        }
        None => TopologySpec::shared_fabric(1, cfg.cxl_bw_gbps),
    };
    if let Some(d) = a.get_as::<usize>("devices") {
        topo.devices = d.max(1);
    }
    if let Some(bw) = a.get_as::<f64>("fabric-gbps") {
        if bw <= 0.0 || bw.is_nan() {
            bail!("--fabric-gbps must be positive (got {bw}); use --no-fabric to disable");
        }
        topo.fabric_bw_gbps = Some(bw);
    }
    if a.has("no-fabric") {
        topo.fabric_bw_gbps = None;
    }
    if let Some(p) = a.get("placement") {
        topo.placement = Placement::parse(p).with_context(|| format!("unknown placement {p:?}"))?;
    }
    if let Some(q) = a.get("qos") {
        topo.qos.policy = QosPolicy::parse(q)
            .with_context(|| format!("unknown qos policy {q:?} (fcfs|wrr|drr)"))?;
    }
    if let Some(ws) = a.get("weights") {
        topo.qos.weights = ws
            .split(',')
            .map(|s| s.trim().parse::<u64>())
            .collect::<Result<Vec<u64>, _>>()
            .with_context(|| format!("parsing --weights {ws:?} (comma-separated u64)"))?;
    }
    if let Some(fs) = a.get("floors") {
        topo.qos.floors = fs
            .split(',')
            .map(|s| s.trim().parse::<f64>())
            .collect::<Result<Vec<f64>, _>>()
            .with_context(|| format!("parsing --floors {fs:?} (comma-separated f64)"))?;
        if topo.qos.floors.iter().any(|f| !f.is_finite() || *f < 0.0) {
            bail!("--floors must be finite and non-negative");
        }
    }
    // A parameter flag for the wrong policy would be silently ignored by
    // the replay; refuse the misconfiguration instead.
    if a.has("weights") && topo.qos.policy != QosPolicy::Wrr {
        bail!("--weights only applies to weighted round-robin (add --qos wrr)");
    }
    if a.has("floors") && topo.qos.policy != QosPolicy::Drr {
        bail!("--floors only applies to deficit round-robin (add --qos drr)");
    }
    // Heterogeneous device classes: cycle the override lists over the
    // devices (entry i % len applies to device i), like --weights.
    if let Some(ps) = a.get("dev-ccm-pus") {
        let pus = ps
            .split(',')
            .map(|s| s.trim().parse::<usize>())
            .collect::<Result<Vec<usize>, _>>()
            .with_context(|| format!("parsing --dev-ccm-pus {ps:?} (comma-separated usize)"))?;
        if pus.is_empty() || pus.contains(&0) {
            bail!("--dev-ccm-pus entries must be positive");
        }
        for d in 0..topo.devices {
            let mut ov = topo.overrides.get(d).copied().unwrap_or_default();
            ov.ccm_pus = Some(pus[d % pus.len()]);
            topo = topo.with_override(d, ov);
        }
    }
    if let Some(bs) = a.get("dev-gbps") {
        let bws = bs
            .split(',')
            .map(|s| s.trim().parse::<f64>())
            .collect::<Result<Vec<f64>, _>>()
            .with_context(|| format!("parsing --dev-gbps {bs:?} (comma-separated f64)"))?;
        if bws.is_empty() || bws.iter().any(|b| !b.is_finite() || *b <= 0.0) {
            bail!("--dev-gbps entries must be positive");
        }
        for d in 0..topo.devices {
            let mut ov = topo.overrides.get(d).copied().unwrap_or_default();
            ov.link_bw_gbps = Some(bws[d % bws.len()]);
            topo = topo.with_override(d, ov);
        }
    }
    Ok(topo)
}

/// Parse a `--faults` schedule: comma-separated events of the form
/// `kind@device:start_us[..end_us][xFACTOR]` —
/// `fail@0:800`, `stall@0:100..300`, `degrade-pus@1:50..150x4`.
/// Times are microseconds (fractions allowed); `fail` takes no window
/// end, degradations require an `xFACTOR >= 1`.
fn parse_fault_events(s: &str) -> Result<Vec<FaultEvent>> {
    let us = |v: f64| (v * axle::sim::US as f64) as u64;
    let mut events = Vec::new();
    for (i, part) in s.split(',').enumerate() {
        let part = part.trim();
        let bad = |why: &str| {
            anyhow::anyhow!(
                "fault event {i} {part:?}: {why} (expected kind@device:start_us[..end_us][xFACTOR])"
            )
        };
        let (kind_s, rest) = part.split_once('@').ok_or_else(|| bad("missing '@'"))?;
        let kind = FaultKind::parse(kind_s.trim())
            .ok_or_else(|| bad("unknown kind (fail|stall|degrade-pus|degrade-link)"))?;
        let (dev_s, window) = rest.split_once(':').ok_or_else(|| bad("missing ':'"))?;
        let device: u32 =
            dev_s.trim().parse().map_err(|_| bad("device must be a non-negative integer"))?;
        let (window, factor) = match window.split_once('x') {
            Some((w, f)) => {
                let factor: f64 =
                    f.trim().parse().map_err(|_| bad("factor must be a number"))?;
                (w, Some(factor))
            }
            None => (window, None),
        };
        let (start_s, end_s) = match window.split_once("..") {
            Some((a, b)) => (a, Some(b)),
            None => (window, None),
        };
        let parse_us = |t: &str, what: &str| -> Result<u64> {
            let v: f64 = t
                .trim()
                .parse()
                .map_err(|_| bad(&format!("{what} must be a time in microseconds")))?;
            if !v.is_finite() || v < 0.0 {
                return Err(bad(&format!("{what} must be finite and non-negative")));
            }
            Ok(us(v))
        };
        let at = parse_us(start_s, "window start")?;
        let event = match kind {
            FaultKind::Fail => {
                if end_s.is_some() || factor.is_some() {
                    return Err(bad("fail is permanent: no window end or factor"));
                }
                FaultEvent::fail(device, at)
            }
            FaultKind::Stall => {
                if factor.is_some() {
                    return Err(bad("stall takes no factor"));
                }
                let until = parse_us(end_s.ok_or_else(|| bad("stall needs start..end"))?, "window end")?;
                FaultEvent::stall(device, at, until)
            }
            FaultKind::DegradePus | FaultKind::DegradeLink => {
                let until = parse_us(
                    end_s.ok_or_else(|| bad("degradation needs start..end"))?,
                    "window end",
                )?;
                let factor = factor.ok_or_else(|| bad("degradation needs an xFACTOR"))?;
                if kind == FaultKind::DegradePus {
                    FaultEvent::degrade_pus(device, at, until, factor)
                } else {
                    FaultEvent::degrade_link(device, at, until, factor)
                }
            }
        };
        events.push(event);
    }
    Ok(events)
}

/// The matrix/sweep results table (shared by both subcommands).
fn print_metrics_table(ms: &[RunMetrics]) {
    println!(
        "{:<4} {:<16} {:>12} {:>8} {:>8} {:>8} {:>8}",
        "WL", "protocol", "total(us)", "T_C%", "T_D%", "T_H%", "stall%"
    );
    for m in ms {
        println!(
            "({})  {:<16} {:>12.2} {:>7.1}% {:>7.1}% {:>7.1}% {:>7.1}%{}",
            m.annot,
            m.protocol,
            ps_to_us(m.total),
            100.0 * m.frac(m.ccm_busy),
            100.0 * m.frac(m.dm_busy),
            100.0 * m.frac(m.host_busy),
            100.0 * m.frac(m.host_stall_clamped()),
            if m.deadlock { "  DEADLOCK" } else { "" }
        );
    }
}

fn workload_arg(a: &Args) -> Result<char> {
    let s = a
        .get("workload")
        .or_else(|| a.get("w"))
        .context("missing --workload <a..i>")?;
    let c = s.chars().next().unwrap();
    if !('a'..='i').contains(&c) {
        bail!("workload must be a..i (Table IV)");
    }
    Ok(c)
}

fn main() -> Result<()> {
    let a = Args::from_env();
    match a.command() {
        Some("run") => {
            let cfg = build_config(&a)?;
            let proto = parse_protocol(a.get("protocol").or_else(|| a.get("p")).unwrap_or("axle"))?;
            let coord = Coordinator::new(cfg);
            let m = coord.run(workload_arg(&a)?, proto);
            if a.has("json") {
                println!("{}", m.to_json());
                return Ok(());
            }
            println!("{} under {}:", m.workload, m.protocol);
            println!("  total          {:12.2} us", ps_to_us(m.total));
            println!(
                "  T_C (CCM busy) {:12.2} us ({:5.1}%)",
                ps_to_us(m.ccm_busy),
                100.0 * m.frac(m.ccm_busy)
            );
            println!(
                "  T_D (data mv)  {:12.2} us ({:5.1}%)",
                ps_to_us(m.dm_busy),
                100.0 * m.frac(m.dm_busy)
            );
            println!(
                "  T_H (host)     {:12.2} us ({:5.1}%)",
                ps_to_us(m.host_busy),
                100.0 * m.frac(m.host_busy)
            );
            println!(
                "  CCM idle       {:12.2} us ({:5.1}%)",
                ps_to_us(m.ccm_idle()),
                100.0 * m.frac(m.ccm_idle())
            );
            println!(
                "  host idle      {:12.2} us ({:5.1}%)",
                ps_to_us(m.host_idle()),
                100.0 * m.frac(m.host_idle())
            );
            let stall = m.host_stall_clamped();
            println!(
                "  host stall     {:12.2} us ({:5.1}%)",
                ps_to_us(stall),
                100.0 * m.frac(stall)
            );
            println!("  backpressure   {:12.2} us", ps_to_us(m.backpressure));
            println!(
                "  polls {}  dma batches {}  fc msgs {}  events {}",
                m.polls, m.dma_batches, m.fc_messages, m.events
            );
            if m.deadlock {
                println!("  !! DEADLOCK detected");
            }
        }
        Some("matrix") => {
            let coord = Coordinator::new(build_config(&a)?);
            print_metrics_table(&coord.run_matrix(&Protocol::ALL));
        }
        Some("sweep") => {
            let cfg = build_config(&a)?;
            let jobs = a.get_as::<usize>("jobs").unwrap_or_else(sweep::available_jobs).max(1);
            let protos: Vec<Protocol> = match a.get("protocol").or_else(|| a.get("p")) {
                Some(s) => vec![parse_protocol(s)?],
                None => Protocol::ALL.to_vec(),
            };
            let workloads: Vec<char> = match a.get("workloads") {
                Some(s) => {
                    let ws: Vec<char> = s.chars().collect();
                    for &c in &ws {
                        if !('a'..='i').contains(&c) {
                            bail!("workload subset must use letters a..i, got {c:?}");
                        }
                    }
                    ws
                }
                None => axle::workload::ALL_ANNOTATIONS.to_vec(),
            };
            let spec = SweepSpec::matrix(cfg, &workloads, &protos, &[ConfigDelta::identity()]);
            let n_points = spec.len();
            let t0 = std::time::Instant::now();
            let ms = spec.run(jobs);
            let wall = t0.elapsed();
            if a.has("json") {
                let arr = Json::Arr(ms.iter().map(|m| m.to_json()).collect());
                println!("{arr}");
            } else {
                print_metrics_table(&ms);
            }
            // Stderr so the stdout stream stays bit-comparable across runs.
            eprintln!(
                "swept {n_points} points on {jobs} worker thread(s) in {:.1} ms",
                wall.as_secs_f64() * 1e3
            );
        }
        Some("tenants") => {
            let cfg = build_config(&a)?;
            let topo = build_topology(&a, &cfg)?;
            if topo.is_heterogeneous() {
                bail!(
                    "axle tenants models homogeneous devices; heterogeneous topologies \
                     (per-device overrides) run through `axle sched`"
                );
            }
            let mut tenants = TenantSpec::new(a.get_as::<usize>("streams").unwrap_or(8));
            if let Some(s) = a.get("workloads") {
                let ws: Vec<char> = s.chars().collect();
                for &c in &ws {
                    if !('a'..='i').contains(&c) {
                        bail!("workload mix must use letters a..i, got {c:?}");
                    }
                }
                tenants = tenants.with_workloads(ws);
            }
            if let Some(p) = a.get("protocol").or_else(|| a.get("p")) {
                tenants = tenants.with_proto(parse_protocol(p)?);
            }
            if let Some(l) = a.get_as::<f64>("load") {
                if l <= 0.0 || l.is_nan() {
                    bail!("--load must be positive (got {l})");
                }
                tenants = tenants.with_load(l);
            }
            if let Some(s) = a.get_as::<u64>("tenant-seed") {
                tenants = tenants.with_seed(s);
            }
            let jobs = a.get_as::<usize>("jobs").unwrap_or_else(sweep::available_jobs).max(1);
            let r = topo::run_tenants(&cfg, &topo, &tenants, jobs);
            if a.has("json") {
                println!("{}", r.to_json());
                return Ok(());
            }
            println!(
                "{} stream(s) on {} device(s), {} placement, {} arbitration, protocol {}:",
                r.tenants.len(),
                topo.devices,
                topo.placement.label(),
                topo.qos.policy.label(),
                tenants.proto.label()
            );
            for t in &r.tenants {
                println!("  {}", topo::tenant::format_tenant_row(t));
            }
            for (d, dev) in r.devices.iter().enumerate() {
                println!(
                    "  device {d}: {} tenant(s), link busy {}, wire wait {}, pu busy {}, pu wait {}, {} data bytes",
                    dev.tenants,
                    fmt_time(dev.link_busy),
                    fmt_time(dev.mem_wait + dev.io_wait),
                    fmt_time(dev.pu_busy),
                    fmt_time(dev.pu_wait),
                    dev.bytes
                );
            }
            match topo.fabric_bw_gbps {
                Some(bw) => println!(
                    "  fabric ({bw:.1} GB/s): {} msgs, {} bytes, busy {}, wait {}, util {}",
                    r.fabric.messages,
                    r.fabric.bytes,
                    fmt_time(r.fabric.busy),
                    fmt_time(r.fabric.wait),
                    fmt_pct(r.fabric.utilization)
                ),
                None => println!("  fabric: none (dedicated per-device uplinks)"),
            }
            println!(
                "  makespan {} | slowdown p50 {:.3} p99 {:.3} max {:.3}",
                fmt_time(r.makespan),
                r.p50_slowdown,
                r.p99_slowdown,
                r.max_slowdown
            );
        }
        Some("sched") => {
            let cfg = build_config(&a)?;
            let topo = build_topology(&a, &cfg)?;
            let open = a.has("open");
            let mut spec = SchedSpec::new(a.get_as::<usize>("streams").unwrap_or(4));
            if let Some(ps) = a.get("prio") {
                let prio = ps
                    .split(',')
                    .map(|s| s.trim().parse::<u32>())
                    .collect::<Result<Vec<u32>, _>>()
                    .with_context(|| format!("parsing --prio {ps:?} (comma-separated u32)"))?;
                spec = spec.with_priorities(prio);
            }
            if let Some(s) = a.get("workloads") {
                let ws: Vec<char> = s.chars().collect();
                for &c in &ws {
                    if !('a'..='i').contains(&c) {
                        bail!("workload mix must use letters a..i, got {c:?}");
                    }
                }
                spec = spec.with_workloads(ws);
            }
            let mut policy = match a.get("policy") {
                Some(p) => PolicyKind::parse(p).with_context(|| {
                    format!("unknown policy {p:?} (static|heuristic|oracle|learned)")
                })?,
                None => PolicyKind::Heuristic,
            };
            if let Some(p) = a.get("protocol").or_else(|| a.get("p")) {
                match policy {
                    PolicyKind::Static(_) => policy = PolicyKind::Static(parse_protocol(p)?),
                    _ => bail!("--protocol pins the static policy (add --policy static)"),
                }
            }
            spec = spec.with_policy(policy);
            if let Some(d) = a.get_as::<usize>("depth") {
                if d == 0 {
                    bail!("--depth must be at least 1 (outstanding-request window)");
                }
                spec = spec.with_depth(d);
            }
            if let Some(m) = a.get_as::<usize>("admit") {
                if m == 0 {
                    bail!("--admit must be at least 1 (device service slots)");
                }
                spec = spec.with_admit(m);
            }
            if let Some(r) = a.get_as::<usize>("requests") {
                spec = spec.with_requests(r);
            }
            if let Some(t) = a.get_as::<u64>("think-ns") {
                spec = spec.with_think(t * NS);
            }
            if let Some(l) = a.get_as::<f64>("load") {
                if !open {
                    bail!("--load shapes the open-loop arrival process (add --open); the closed loop paces itself by completion feedback");
                }
                if l <= 0.0 || l.is_nan() {
                    bail!("--load must be positive (got {l})");
                }
                spec = spec.with_load(l);
            }
            if let Some(s) = a.get_as::<u64>("sched-seed") {
                spec = spec.with_seed(s);
            }
            if let Some(e) = a.get_as::<u32>("explore") {
                if !matches!(spec.policy, PolicyKind::Learned) {
                    bail!("--explore tunes the learned policy (add --policy learned)");
                }
                spec = spec.with_explore(e);
            }
            let mut faults = FaultSpec::default();
            if let Some(s) = a.get("faults") {
                faults.events = parse_fault_events(s)?;
            }
            if let Some(n) = a.get_as::<u32>("max-retries") {
                faults.max_retries = n;
            }
            if let Some(t) = a.get_as::<u64>("backoff-us") {
                faults.backoff = t * axle::sim::US;
            }
            if let Some(f) = a.get_as::<f64>("timeout-factor") {
                if !f.is_finite() || f <= 0.0 {
                    bail!("--timeout-factor must be a positive finite number (got {f})");
                }
                faults.timeout_factor = f;
            }
            if !faults.events.is_empty() {
                faults.validate(topo.devices).map_err(|e| anyhow::anyhow!(e))?;
            }
            spec = spec.with_faults(faults);
            // Per-request retention is opt-in on the CLI: the default
            // streams every request through O(1) sketches so
            // million-request runs hold no per-request memory.
            spec = spec.with_retain(a.has("dump-requests"));
            if a.has("chunks") || a.has("chunk-mode") {
                let chunks = a.get_as::<u32>("chunks").unwrap_or(1);
                let mode = match a.get("chunk-mode") {
                    Some(m) => PipelineMode::parse(m).with_context(|| {
                        format!("unknown chunk mode {m:?} (auto|serial|pipelined)")
                    })?,
                    None => PipelineMode::Auto,
                };
                let p = PipelineSpec { chunks, mode };
                p.validate().map_err(|e| anyhow::anyhow!(e))?;
                spec = spec.with_pipeline(p);
            }
            let trace_path = a.get("trace").map(str::to_string);
            let trace_buckets = a.get_as::<u32>("trace-buckets");
            if trace_path.is_some() || trace_buckets.is_some() {
                let t = TraceSpec { buckets: trace_buckets.unwrap_or(TraceSpec::default().buckets) };
                t.validate().map_err(|e| anyhow::anyhow!(e))?;
                spec = spec.with_trace(t);
            }
            if open {
                // Closed-loop knobs would be silently meaningless under
                // the PR-3 open-loop replay; refuse them instead.
                for flag in [
                    "depth",
                    "admit",
                    "requests",
                    "think-ns",
                    "prio",
                    "faults",
                    "max-retries",
                    "backoff-us",
                    "timeout-factor",
                    "chunks",
                    "chunk-mode",
                    "trace",
                    "trace-buckets",
                ] {
                    if a.has(flag) {
                        bail!("--{flag} is a closed-loop knob; the --open replay runs one open-loop request per tenant");
                    }
                }
                if !matches!(spec.policy, PolicyKind::Static(_)) {
                    bail!("--open (PR-3 arrival pin) supports only --policy static");
                }
                if topo.is_heterogeneous() {
                    bail!("--open replays the homogeneous tenant path; drop the device overrides");
                }
                spec = spec.open_loop();
            }
            let jobs = a.get_as::<usize>("jobs").unwrap_or_else(sweep::available_jobs).max(1);
            let out = sched::run(&sched::SchedRun::new(&cfg, &topo, &spec).with_jobs(jobs));
            let (r, tr) = (out.report, out.trace);
            // The exported trace must reconcile with the report it
            // shipped with before anything is written or summarized.
            if let Some(tr) = &tr {
                trace::validate(tr, &r)
                    .map_err(|e| anyhow::anyhow!("trace validation failed: {e}"))?;
            }
            if let (Some(path), Some(tr)) = (trace_path.as_deref(), &tr) {
                let doc = trace::chrome::to_json(tr).to_string();
                std::fs::write(path, doc).with_context(|| format!("writing trace to {path}"))?;
            }
            if a.has("json") {
                println!("{}", r.to_json());
                return Ok(());
            }
            if r.closed {
                println!(
                    "{} tenant(s) x {} request(s), {} policy, closed-loop arrivals, depth {} admit {}, {} device(s), {} placement, {} arbitration:",
                    spec.streams,
                    spec.requests,
                    r.policy.label(),
                    r.depth,
                    r.admit,
                    topo.devices,
                    topo.placement.label(),
                    r.qos.label()
                );
            } else {
                println!(
                    "{} tenant(s) x 1 request, {} policy, open-loop arrivals (PR-3 pin), {} device(s), {} placement:",
                    spec.streams,
                    r.policy.label(),
                    topo.devices,
                    topo.placement.label()
                );
            }
            if r.streamed {
                println!(
                    "  {} request(s) aggregated through streaming sketches (--dump-requests retains per-request rows)",
                    r.scheduled
                );
            }
            for q in &r.requests {
                println!("  {}", sched::format_request_row(q));
            }
            for (d, dev) in r.devices.iter().enumerate() {
                println!(
                    "  device {d}: {} request(s), link busy {}, wire wait {}, pu busy {}, pu wait {}, {} data bytes",
                    dev.tenants,
                    fmt_time(dev.link_busy),
                    fmt_time(dev.mem_wait + dev.io_wait),
                    fmt_time(dev.pu_busy),
                    fmt_time(dev.pu_wait),
                    dev.bytes
                );
            }
            match topo.fabric_bw_gbps {
                Some(bw) => println!(
                    "  fabric ({bw:.1} GB/s): {} msgs, {} bytes, busy {}, wait {}, util {}",
                    r.fabric.messages,
                    r.fabric.bytes,
                    fmt_time(r.fabric.busy),
                    fmt_time(r.fabric.wait),
                    fmt_pct(r.fabric.utilization)
                ),
                None => println!("  fabric: none (dedicated per-device uplinks)"),
            }
            let mix: Vec<String> =
                r.proto_mix.iter().map(|(proto, n)| format!("{proto}:{n}")).collect();
            println!(
                "  makespan {} | slowdown p50 {:.3} p99 {:.3} max {:.3} | host idle {} ccm idle {} | mix {}",
                fmt_time(r.makespan),
                r.p50_slowdown,
                r.p99_slowdown,
                r.max_slowdown,
                fmt_pct(r.host_idle_frac()),
                fmt_pct(r.ccm_idle_frac()),
                mix.join(" ")
            );
            let classes = r.class_slowdowns();
            if classes.len() > 1 {
                for (class, n, p50, p99) in classes {
                    println!(
                        "  class {class}: {n} request(s), slowdown p50 {p50:.3} p99 {p99:.3}"
                    );
                }
            }
            if !r.faults.is_empty() {
                for f in &r.faults {
                    println!(
                        "  fault {} device {} at {} (until {}): {} displaced, recover {}, lost wire {} pu {}",
                        f.kind.label(),
                        f.device,
                        fmt_time(f.at),
                        fmt_time(f.until),
                        f.displaced,
                        fmt_time(f.recover),
                        fmt_time(f.lost_wire),
                        fmt_time(f.lost_pu)
                    );
                }
                println!(
                    "  lost work: wire {}, pu {} | failed requests {}",
                    fmt_time(r.lost_wire),
                    fmt_time(r.lost_pu),
                    r.failed_requests
                );
            }
            if let Some(tr) = &tr {
                let buckets = spec.trace.as_ref().map(|t| t.buckets).unwrap_or(16);
                let tel = trace::telemetry::windows(tr, buckets, r.makespan);
                println!(
                    "  trace events = {}, host util p50 = {}",
                    tr.len(),
                    fmt_pct(tel.host_util_p50())
                );
                if let Some(path) = trace_path.as_deref() {
                    println!("  trace written to {path} (load in Perfetto / chrome://tracing)");
                }
                if trace_buckets.is_some() {
                    println!(
                        "  {:<22} {:>7} {:>7} {:>12} {:>7} {:>6} {:>5} {:>4} {:>8}",
                        "window", "host", "ccm", "wire busy", "qdepth", "outst", "done", "rtry",
                        "p99 sd"
                    );
                    for w in &tel.windows {
                        let p99 = if w.slowdown.count() == 0 {
                            "-".to_string()
                        } else {
                            format!("{:.3}", w.slowdown.quantile(99.0))
                        };
                        println!(
                            "  [{:>9} {:>9}] {:>7} {:>7} {:>12} {:>7.2} {:>6.2} {:>5} {:>4} {:>8}",
                            fmt_time(w.start),
                            fmt_time(w.end),
                            fmt_pct(w.host_util()),
                            fmt_pct(w.ccm_util(tel.devices)),
                            fmt_time(w.wire_busy),
                            w.queue_depth,
                            w.outstanding,
                            w.completions,
                            w.retries,
                            p99
                        );
                    }
                }
            }
        }
        Some("scenario") => {
            let cfg = build_config(&a)?;
            let streams = a.get_as::<usize>("streams").unwrap_or(4);
            let requests = a.get_as::<usize>("requests").unwrap_or(2);
            let jobs = a.get_as::<usize>("jobs").unwrap_or_else(sweep::available_jobs).max(1);
            let coord = Coordinator::new(cfg);
            if a.has("learned") {
                let out = coord.run_nonstationary_scenario(streams, requests, jobs);
                if a.has("json") {
                    let mut o = std::collections::BTreeMap::new();
                    o.insert("degrade_at_ps".into(), Json::Num(out.at as f64));
                    o.insert("learned_makespan_ps".into(), Json::Num(out.learned.makespan as f64));
                    o.insert(
                        "heuristic_makespan_ps".into(),
                        Json::Num(out.heuristic.makespan as f64),
                    );
                    o.insert("oracle_makespan_ps".into(), Json::Num(out.oracle.makespan as f64));
                    o.insert("learned_p99_slowdown".into(), Json::Num(out.learned.p99_slowdown));
                    o.insert(
                        "heuristic_p99_slowdown".into(),
                        Json::Num(out.heuristic.p99_slowdown),
                    );
                    o.insert("oracle_p99_slowdown".into(), Json::Num(out.oracle.p99_slowdown));
                    println!("{}", Json::Obj(o));
                    return Ok(());
                }
                println!(
                    "nonstationary scenario: {streams} tenant(s) x {requests} request(s) over 2 devices, device 0 degrades 8x at {}",
                    fmt_time(out.at)
                );
                println!(
                    "learned/heuristic/oracle makespan = {}/{}/{}",
                    fmt_time(out.learned.makespan),
                    fmt_time(out.heuristic.makespan),
                    fmt_time(out.oracle.makespan)
                );
                println!(
                    "  p99 slowdown learned {:.3} | heuristic {:.3} | oracle {:.3}",
                    out.learned.p99_slowdown,
                    out.heuristic.p99_slowdown,
                    out.oracle.p99_slowdown
                );
                return Ok(());
            }
            let (base, faulted, at) = coord.run_failover_scenario(streams, requests, jobs);
            let row = &faulted.faults[0];
            if a.has("json") {
                let mut o = std::collections::BTreeMap::new();
                o.insert("fail_at_ps".into(), Json::Num(at as f64));
                o.insert("recover_ps".into(), Json::Num(row.recover as f64));
                o.insert("lost_wire_ps".into(), Json::Num(faulted.lost_wire as f64));
                o.insert("lost_pu_ps".into(), Json::Num(faulted.lost_pu as f64));
                o.insert("displaced".into(), Json::Num(row.displaced as f64));
                o.insert("failed_requests".into(), Json::Num(faulted.failed_requests as f64));
                o.insert("baseline_makespan_ps".into(), Json::Num(base.makespan as f64));
                o.insert("faulted_makespan_ps".into(), Json::Num(faulted.makespan as f64));
                o.insert("baseline_p50_slowdown".into(), Json::Num(base.p50_slowdown));
                o.insert("faulted_p50_slowdown".into(), Json::Num(faulted.p50_slowdown));
                o.insert("baseline_p99_slowdown".into(), Json::Num(base.p99_slowdown));
                o.insert("faulted_p99_slowdown".into(), Json::Num(faulted.p99_slowdown));
                println!("{}", Json::Obj(o));
                return Ok(());
            }
            println!(
                "failover scenario: {streams} tenant(s) x {requests} request(s) over 2 devices (strong+weak), device 0 fails at {}",
                fmt_time(at)
            );
            println!(
                "  time-to-recover {} | {} displaced, {} failed | lost work wire {} pu {}",
                fmt_time(row.recover),
                row.displaced,
                faulted.failed_requests,
                fmt_time(faulted.lost_wire),
                fmt_time(faulted.lost_pu)
            );
            println!(
                "  makespan {} -> {} | slowdown p50 {:.3} -> {:.3}, p99 {:.3} -> {:.3}",
                fmt_time(base.makespan),
                fmt_time(faulted.makespan),
                base.p50_slowdown,
                faulted.p50_slowdown,
                base.p99_slowdown,
                faulted.p99_slowdown
            );
        }
        Some("validate") => {
            let dir = a.get("artifacts").unwrap_or("artifacts");
            let mut coord = Coordinator::new(SimConfig::m2ndp()).with_artifacts(dir)?;
            let reports = match a.get("workload").or_else(|| a.get("w")) {
                Some(_) => vec![coord.validate_numerics(workload_arg(&a)?)?],
                None => coord.validate_all_numerics()?,
            };
            for r in reports {
                println!(
                    "({}) artifacts {:?}: {} checks, max rel err {:.2e} -- OK",
                    r.annot, r.artifacts, r.checks, r.max_rel_err
                );
            }
        }
        Some("report") => {
            let which = a.positional.get(1).map(|s| s.as_str()).unwrap_or("all");
            let cfg = SimConfig::m2ndp();
            match which {
                "all" => report::all(),
                "table1" => report::table1(),
                "table2" => report::table2(),
                "table4" => report::table4(&cfg),
                "fig3" => report::fig3(&cfg),
                "fig4" => report::fig4(),
                "fig5" => report::fig5(&cfg),
                "fig7" => report::fig7(&cfg),
                "fig10" => report::fig10(&cfg),
                "fig11" => report::fig11(),
                "fig12" => report::fig12(&cfg),
                "fig13" => report::fig13(&cfg),
                "fig14" => report::fig14(&cfg),
                "fig14-ext" => report::fig14_ext(&cfg),
                "fig15" => report::fig15(&cfg),
                "fig16" => report::fig16(&cfg),
                "fig17" | "tenants" => report::fig17(&cfg),
                "fig19" | "sched" => report::fig19(&cfg),
                "fig20" | "faults" => report::fig20(&cfg),
                "fig21" | "pipeline" => report::fig21(&cfg),
                "fig22" | "trace" => report::fig22(&cfg),
                "fig23" | "learned" => report::fig23(&cfg),
                other => bail!("unknown report {other:?}"),
            }
        }
        Some("config") => {
            let cfg = build_config(&a)?;
            let text = cfg.to_json().to_string();
            match a.get("out") {
                Some(path) => {
                    std::fs::write(path, &text)?;
                    println!("wrote {path}");
                }
                None => println!("{text}"),
            }
        }
        Some("list") => report::table4(&SimConfig::m2ndp()),
        _ => {
            print!("{USAGE}");
        }
    }
    Ok(())
}
