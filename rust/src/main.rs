//! `axle` CLI: the leader entrypoint for the AXLE reproduction.
//!
//! ```text
//! axle run --workload e --protocol axle --poll-ns 500
//! axle matrix [--profile real-hw|reduced]
//! axle validate [--artifacts DIR] [--workload e]
//! axle report fig10 | all | ...
//! axle list
//! axle config [--out cfg.json] / axle run --config cfg.json ...
//! ```

use anyhow::{bail, Context, Result};

use axle::config::{Protocol, SchedPolicy, SimConfig};
use axle::sim::{ps_to_us, NS};
use axle::util::args::Args;
use axle::util::json::Json;
use axle::{report, Coordinator};

const USAGE: &str = "\
axle — asynchronous back-streaming CCM offloading (paper reproduction)

USAGE:
  axle run --workload <a..i> [--protocol rp|bs|axle|axle-interrupt]
           [--profile m2ndp|real-hw|reduced] [--config FILE.json]
           [--poll-ns N] [--sf BYTES] [--adaptive-sf] [--capacity SLOTS]
           [--no-ooo] [--fifo] [--seed N] [--json]
  axle matrix [--profile ...]
  axle validate [--artifacts DIR] [--workload <a..i>]
  axle report <all|table1|table2|table4|fig3|fig4|fig5|fig7|fig10|fig11|fig12|fig13|fig14|fig15|fig16>
  axle config [--out FILE.json]     # dump the Table III defaults
  axle list
";

fn parse_protocol(s: &str) -> Result<Protocol> {
    Ok(match s {
        "rp" => Protocol::Rp,
        "bs" => Protocol::Bs,
        "axle" => Protocol::Axle,
        "axle-interrupt" | "axle_interrupt" => Protocol::AxleInterrupt,
        _ => bail!("unknown protocol {s:?} (rp|bs|axle|axle-interrupt)"),
    })
}

fn parse_profile(s: &str) -> Result<SimConfig> {
    Ok(match s {
        "m2ndp" => SimConfig::m2ndp(),
        "real-hw" | "real_hw" => SimConfig::real_hw(),
        "reduced" => SimConfig::reduced(),
        _ => bail!("unknown profile {s:?} (m2ndp|real-hw|reduced)"),
    })
}

fn build_config(a: &Args) -> Result<SimConfig> {
    let mut cfg = match a.get("config") {
        Some(path) => {
            let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
            SimConfig::from_json(&Json::parse(&text).context("parsing config JSON")?)
        }
        None => parse_profile(a.get("profile").unwrap_or("m2ndp"))?,
    };
    if let Some(p) = a.get_as::<u64>("poll-ns") {
        cfg.axle.poll_interval = p * NS;
    }
    if let Some(s) = a.get_as::<u64>("sf") {
        cfg.axle.streaming_factor_bytes = s;
    }
    if let Some(c) = a.get_as::<usize>("capacity") {
        cfg.axle.dma_slot_capacity = c;
    }
    if let Some(s) = a.get_as::<u64>("seed") {
        cfg.seed = s;
    }
    if a.has("no-ooo") {
        cfg.axle.ooo_streaming = false;
    }
    if a.has("adaptive-sf") {
        cfg.axle.sf_policy = axle::config::SfPolicy::Adaptive;
    }
    if a.has("fifo") {
        cfg.sched = SchedPolicy::Fifo;
    }
    Ok(cfg)
}

fn workload_arg(a: &Args) -> Result<char> {
    let s = a
        .get("workload")
        .or_else(|| a.get("w"))
        .context("missing --workload <a..i>")?;
    let c = s.chars().next().unwrap();
    if !('a'..='i').contains(&c) {
        bail!("workload must be a..i (Table IV)");
    }
    Ok(c)
}

fn main() -> Result<()> {
    let a = Args::from_env();
    match a.command() {
        Some("run") => {
            let cfg = build_config(&a)?;
            let proto = parse_protocol(a.get("protocol").or_else(|| a.get("p")).unwrap_or("axle"))?;
            let coord = Coordinator::new(cfg);
            let m = coord.run(workload_arg(&a)?, proto);
            if a.has("json") {
                println!("{}", m.to_json());
                return Ok(());
            }
            println!("{} under {}:", m.workload, m.protocol);
            println!("  total          {:12.2} us", ps_to_us(m.total));
            println!(
                "  T_C (CCM busy) {:12.2} us ({:5.1}%)",
                ps_to_us(m.ccm_busy),
                100.0 * m.frac(m.ccm_busy)
            );
            println!(
                "  T_D (data mv)  {:12.2} us ({:5.1}%)",
                ps_to_us(m.dm_busy),
                100.0 * m.frac(m.dm_busy)
            );
            println!(
                "  T_H (host)     {:12.2} us ({:5.1}%)",
                ps_to_us(m.host_busy),
                100.0 * m.frac(m.host_busy)
            );
            println!(
                "  CCM idle       {:12.2} us ({:5.1}%)",
                ps_to_us(m.ccm_idle()),
                100.0 * m.frac(m.ccm_idle())
            );
            println!(
                "  host idle      {:12.2} us ({:5.1}%)",
                ps_to_us(m.host_idle()),
                100.0 * m.frac(m.host_idle())
            );
            let stall = m.host_stall.min(m.total);
            println!(
                "  host stall     {:12.2} us ({:5.1}%)",
                ps_to_us(stall),
                100.0 * m.frac(stall)
            );
            println!("  backpressure   {:12.2} us", ps_to_us(m.backpressure));
            println!(
                "  polls {}  dma batches {}  fc msgs {}  events {}",
                m.polls, m.dma_batches, m.fc_messages, m.events
            );
            if m.deadlock {
                println!("  !! DEADLOCK detected");
            }
        }
        Some("matrix") => {
            let coord = Coordinator::new(build_config(&a)?);
            println!(
                "{:<4} {:<16} {:>12} {:>8} {:>8} {:>8} {:>8}",
                "WL", "protocol", "total(us)", "T_C%", "T_D%", "T_H%", "stall%"
            );
            for m in coord.run_matrix(&Protocol::ALL) {
                println!(
                    "({})  {:<16} {:>12.2} {:>7.1}% {:>7.1}% {:>7.1}% {:>7.1}%{}",
                    m.annot,
                    m.protocol,
                    ps_to_us(m.total),
                    100.0 * m.frac(m.ccm_busy),
                    100.0 * m.frac(m.dm_busy),
                    100.0 * m.frac(m.host_busy),
                    100.0 * m.frac(m.host_stall.min(m.total)),
                    if m.deadlock { "  DEADLOCK" } else { "" }
                );
            }
        }
        Some("validate") => {
            let dir = a.get("artifacts").unwrap_or("artifacts");
            let mut coord = Coordinator::new(SimConfig::m2ndp()).with_artifacts(dir)?;
            let reports = match a.get("workload").or_else(|| a.get("w")) {
                Some(_) => vec![coord.validate_numerics(workload_arg(&a)?)?],
                None => coord.validate_all_numerics()?,
            };
            for r in reports {
                println!(
                    "({}) artifacts {:?}: {} checks, max rel err {:.2e} -- OK",
                    r.annot, r.artifacts, r.checks, r.max_rel_err
                );
            }
        }
        Some("report") => {
            let which = a.positional.get(1).map(|s| s.as_str()).unwrap_or("all");
            let cfg = SimConfig::m2ndp();
            match which {
                "all" => report::all(),
                "table1" => report::table1(),
                "table2" => report::table2(),
                "table4" => report::table4(&cfg),
                "fig3" => report::fig3(&cfg),
                "fig4" => report::fig4(),
                "fig5" => report::fig5(&cfg),
                "fig7" => report::fig7(&cfg),
                "fig10" => report::fig10(&cfg),
                "fig11" => report::fig11(),
                "fig12" => report::fig12(&cfg),
                "fig13" => report::fig13(&cfg),
                "fig14" => report::fig14(&cfg),
                "fig14-ext" => report::fig14_ext(&cfg),
                "fig15" => report::fig15(&cfg),
                "fig16" => report::fig16(&cfg),
                other => bail!("unknown report {other:?}"),
            }
        }
        Some("config") => {
            let cfg = build_config(&a)?;
            let text = cfg.to_json().to_string();
            match a.get("out") {
                Some(path) => {
                    std::fs::write(path, &text)?;
                    println!("wrote {path}");
                }
                None => println!("{text}"),
            }
        }
        Some("list") => report::table4(&SimConfig::m2ndp()),
        _ => {
            print!("{USAGE}");
        }
    }
    Ok(())
}
