//! Multi-tenant driver: K concurrent workload streams over a shared
//! [`Topology`].
//!
//! The production scenario the ROADMAP targets — many users' offload
//! streams sharing a pool of CCM devices — is simulated in three
//! deterministic passes:
//!
//! 1. **Solo pass.** Each distinct `(workload, protocol)` job runs once
//!    through the unchanged protocol engines on a fresh traced
//!    [`DeviceCtx`](super::DeviceCtx) (fanned out across cores via
//!    [`crate::sweep::run_traced_jobs`]); streams sharing a job reuse its
//!    metrics and wire trace — devices are homogeneous, so one solo run
//!    stands for every tenant of that job. Per-tenant rings/queue pairs
//!    are private, so the solo timeline is exact.
//! 2. **Arrivals + placement.** Open-loop arrivals: stream `i` arrives at
//!    a seeded, jittered multiple of the mean inter-arrival gap (derived
//!    from mean solo runtime, device count and the load factor) —
//!    arrivals never depend on completions. Placement is round-robin or
//!    least-loaded ([`crate::config::Placement`]).
//! 3. **Contention pass.** Each device's CXL.mem and CXL.io links
//!    serialize the wire traffic of the tenants placed on it, and the
//!    optional shared upstream fabric link serializes *all* devices'
//!    traffic, via replay arbitration under the topology's QoS policy
//!    ([`super::fabric::arbitrate_qos`]: FCFS, weighted round-robin, or
//!    deficit round-robin with per-tenant bandwidth floors — see
//!    [`crate::config::QosSpec`]). Each device's CCM PU pool additionally
//!    serializes the co-located tenants' traced lease windows
//!    ([`super::fabric::arbitrate_pus`]), so compute contention inside
//!    the expander is charged too, not just wire contention.
//!
//! **Slowdown decomposition.** A tenant's contended runtime is
//! `solo + wire_shift + pu_shift`:
//!
//! - `wire_shift = max(device wait, fabric wait)` — device link and
//!   fabric form a pipelined two-stage path carrying the same bytes, so a
//!   conflict visible on both stages is one physical wait (RP/BS are
//!   fully serialized pipelines, so the wait lands on the critical path;
//!   for AXLE it is a conservative upper bound);
//! - `pu_shift` — the completion shift of the tenant's CCM lease windows
//!   on the shared pool. Compute occupancy and wire occupancy are
//!   disjoint phases of the offload pipeline (a result is produced, then
//!   moved), so the two shifts add rather than max.
//!
//! Both components are reported per tenant (`axle tenants`, `axle report
//! fig17`, and the JSON schema: `wire_wait_ps` + `pu_wait_ps` with
//! `total_ps = solo_total_ps + wire_wait_ps + pu_wait_ps`).
//!
//! Everything is a pure function of `(config, topology, tenant spec)`;
//! two invocations produce byte-identical reports.
//!
//! # Worked example: why QoS changes the numbers
//!
//! Suppose streams A and B both burst 4 MB onto one device link at
//! `t = 0`. FCFS serves A's whole train first (A wins the issue-order
//! tie), so B's completion shifts by 4 MB of serialization while A's
//! shifts by ~0. `--qos wrr` with equal weights alternates their
//! messages: both tails now shift by about half the combined burst —
//! the p99/max slowdown drops while the mean stays put. `--qos drr
//! --floors 0.75,0.25` skews the wire 3:1 toward A: A's shift shrinks
//! toward its solo schedule and B absorbs the rest, without ever
//! starving (B still drains one quantum per round). The busy time of the
//! link is identical in all three cases — QoS only chooses *who* waits.

use std::collections::BTreeMap;
use std::collections::HashMap;
use std::sync::Arc;

use crate::config::{Protocol, QosPolicy, QosSpec, SimConfig, TopologySpec};
use crate::metrics::{percentile, RunMetrics};
use crate::sim::{ps_to_us, Ps};
use crate::sweep::{self, SpecJob};
use crate::util::json::Json;
use crate::util::rng::Pcg32;
use crate::workload::ALL_ANNOTATIONS;

use super::fabric::{arbitrate_pus, arbitrate_qos, FabricMsg, PuDemand};
use super::{DeviceStats, Topology};

/// Declarative description of a tenant mix.
#[derive(Debug, Clone, PartialEq)]
pub struct TenantSpec {
    /// Number of concurrent streams (K).
    pub streams: usize,
    /// Workload annotations, cycled across streams.
    pub workloads: Vec<char>,
    /// Offload protocol every stream uses.
    pub proto: Protocol,
    /// Open-loop load factor: mean inter-arrival gap =
    /// `mean solo runtime / (devices × load)`. 1.0 ≈ devices kept busy.
    pub load: f64,
    /// Arrival-jitter seed (independent of the simulation seed).
    pub seed: u64,
}

impl TenantSpec {
    /// `streams` tenants cycling through all Table IV workloads under
    /// AXLE at unit load.
    pub fn new(streams: usize) -> Self {
        Self {
            streams,
            workloads: ALL_ANNOTATIONS.to_vec(),
            proto: Protocol::Axle,
            load: 1.0,
            seed: 0x7E4A_17,
        }
    }

    pub fn with_workloads(mut self, workloads: Vec<char>) -> Self {
        assert!(!workloads.is_empty(), "tenant mix needs at least one workload");
        self.workloads = workloads;
        self
    }

    pub fn with_proto(mut self, proto: Protocol) -> Self {
        self.proto = proto;
        self
    }

    pub fn with_load(mut self, load: f64) -> Self {
        assert!(load > 0.0, "load factor must be positive");
        self.load = load;
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }
}

/// One tenant's outcome.
#[derive(Debug, Clone)]
pub struct TenantRun {
    pub tenant: u32,
    pub annot: char,
    pub device: u32,
    /// Open-loop arrival time.
    pub arrival: Ps,
    /// Solo (uncontended) metrics of this tenant's stream.
    pub solo: RunMetrics,
    /// Completion shift from sharing the device's CXL.mem/CXL.io links
    /// (worst channel).
    pub device_wait: Ps,
    /// Completion shift from the shared upstream fabric link.
    pub fabric_wait: Ps,
    /// Completion shift from sharing the device's CCM PU pool with
    /// co-located tenants (compute contention).
    pub pu_wait: Ps,
}

impl TenantRun {
    /// Wire-contention component of the slowdown: the **bottleneck**
    /// stage's added delay. Device link and fabric are a pipelined
    /// (cut-through) two-stage path carrying the same bytes, so a
    /// conflict that appears on both stages is one physical wait, not
    /// two — charging `max` instead of the sum avoids double-counting
    /// the common case where the fabric replay sees the identical
    /// conflicts the device replay saw (it under-counts only when the
    /// two stages conflict with *different* tenants at different times).
    pub fn wire_wait(&self) -> Ps {
        self.device_wait.max(self.fabric_wait)
    }

    /// Contended end-to-end runtime (arrival-relative): solo runtime plus
    /// the wire shift plus the PU shift. Wire and compute occupancy are
    /// disjoint phases of the offload pipeline (a result is produced on a
    /// PU, then moved over the wire), so the two shifts add — see the
    /// module docs' slowdown decomposition.
    pub fn total(&self) -> Ps {
        self.solo.total + self.wire_wait() + self.pu_wait
    }

    /// Contended completion time (absolute).
    pub fn completion(&self) -> Ps {
        self.arrival + self.total()
    }

    /// Contended / solo runtime ratio (≥ 1).
    pub fn slowdown(&self) -> f64 {
        if self.solo.total == 0 {
            1.0
        } else {
            self.total() as f64 / self.solo.total as f64
        }
    }

    pub fn to_json(&self) -> Json {
        let mut o = BTreeMap::new();
        o.insert("tenant".into(), Json::Num(self.tenant as f64));
        o.insert("annot".into(), Json::Str(self.annot.to_string()));
        o.insert("device".into(), Json::Num(self.device as f64));
        o.insert("arrival_ps".into(), Json::Num(self.arrival as f64));
        o.insert("solo_total_ps".into(), Json::Num(self.solo.total as f64));
        o.insert("device_wait_ps".into(), Json::Num(self.device_wait as f64));
        o.insert("fabric_wait_ps".into(), Json::Num(self.fabric_wait as f64));
        o.insert("wire_wait_ps".into(), Json::Num(self.wire_wait() as f64));
        o.insert("pu_wait_ps".into(), Json::Num(self.pu_wait as f64));
        o.insert("total_ps".into(), Json::Num(self.total() as f64));
        o.insert("slowdown".into(), Json::Num(self.slowdown()));
        Json::Obj(o)
    }
}

/// Aggregate fabric-contention statistics.
#[derive(Debug, Clone, Default)]
pub struct FabricReport {
    /// Shared fabric bandwidth (GB/s); `None` if no fabric was modelled.
    pub bw_gbps: Option<f64>,
    pub messages: u64,
    pub bytes: u64,
    /// Wire busy-union of the fabric link.
    pub busy: Ps,
    /// Total added queueing delay across tenants.
    pub wait: Ps,
    /// busy / makespan.
    pub utilization: f64,
}

/// The full multi-tenant simulation result.
#[derive(Debug, Clone)]
pub struct TenantReport {
    /// Link-arbitration policy the contention pass ran under.
    pub qos: QosPolicy,
    pub tenants: Vec<TenantRun>,
    pub devices: Vec<DeviceStats>,
    pub fabric: FabricReport,
    /// Last contended completion across all tenants.
    pub makespan: Ps,
    pub p50_slowdown: f64,
    pub p99_slowdown: f64,
    pub max_slowdown: f64,
}

impl TenantReport {
    pub fn to_json(&self) -> Json {
        let mut fab = BTreeMap::new();
        match self.fabric.bw_gbps {
            Some(bw) => fab.insert("bw_gbps".into(), Json::Num(bw)),
            None => fab.insert("bw_gbps".into(), Json::Null),
        };
        fab.insert("messages".into(), Json::Num(self.fabric.messages as f64));
        fab.insert("bytes".into(), Json::Num(self.fabric.bytes as f64));
        fab.insert("busy_ps".into(), Json::Num(self.fabric.busy as f64));
        fab.insert("wait_ps".into(), Json::Num(self.fabric.wait as f64));
        fab.insert("utilization".into(), Json::Num(self.fabric.utilization));
        let devices: Vec<Json> = self
            .devices
            .iter()
            .map(|d| {
                let mut o = BTreeMap::new();
                o.insert("tenants".into(), Json::Num(d.tenants as f64));
                o.insert("load_ps".into(), Json::Num(d.load as f64));
                o.insert("mem_wait_ps".into(), Json::Num(d.mem_wait as f64));
                o.insert("io_wait_ps".into(), Json::Num(d.io_wait as f64));
                o.insert("pu_wait_ps".into(), Json::Num(d.pu_wait as f64));
                o.insert("pu_busy_ps".into(), Json::Num(d.pu_busy as f64));
                o.insert("bytes".into(), Json::Num(d.bytes as f64));
                o.insert("link_busy_ps".into(), Json::Num(d.link_busy as f64));
                Json::Obj(o)
            })
            .collect();
        let mut o = BTreeMap::new();
        o.insert("qos".into(), Json::Str(self.qos.label().into()));
        o.insert("tenants".into(), Json::Arr(self.tenants.iter().map(|t| t.to_json()).collect()));
        o.insert("devices".into(), Json::Arr(devices));
        o.insert("fabric".into(), Json::Obj(fab));
        o.insert("makespan_ps".into(), Json::Num(self.makespan as f64));
        o.insert("p50_slowdown".into(), Json::Num(self.p50_slowdown));
        o.insert("p99_slowdown".into(), Json::Num(self.p99_slowdown));
        o.insert("max_slowdown".into(), Json::Num(self.max_slowdown));
        Json::Obj(o)
    }
}

/// Run `spec.streams` concurrent streams over `topo_spec` devices with
/// `cfg` hardware, fanning the solo simulations across `jobs` worker
/// threads. Deterministic: the result is a pure function of the three
/// spec arguments (the worker count never changes results).
pub fn run_tenants(
    cfg: &SimConfig,
    topo_spec: &TopologySpec,
    spec: &TenantSpec,
    jobs: usize,
) -> TenantReport {
    assert!(!spec.workloads.is_empty(), "tenant mix needs at least one workload");
    assert!(
        !topo_spec.is_heterogeneous(),
        "the open-loop tenant driver models homogeneous devices; heterogeneous \
         topologies (per-device overrides) run through the closed-loop scheduler \
         (`axle sched`, crate::sched::run_sched)"
    );
    let mut topo = Topology::new(cfg.clone(), topo_spec.clone());
    if spec.streams == 0 {
        // Nothing to simulate: an empty report (unit slowdowns, zeroed
        // devices) rather than a panic — `axle tenants --streams 0`.
        return TenantReport {
            qos: topo_spec.qos.policy,
            tenants: Vec::new(),
            devices: topo.devices().to_vec(),
            fabric: FabricReport { bw_gbps: topo_spec.fabric_bw_gbps, ..FabricReport::default() },
            makespan: 0,
            p50_slowdown: 1.0,
            p99_slowdown: 1.0,
            max_slowdown: 1.0,
        };
    }

    // ---- Pass 1: solo runs, one per distinct (annot, proto) job. ----
    let annots: Vec<char> =
        (0..spec.streams).map(|i| spec.workloads[i % spec.workloads.len()]).collect();
    let mut job_of: HashMap<char, usize> = HashMap::new();
    let mut distinct: Vec<char> = Vec::new();
    for &a in &annots {
        job_of.entry(a).or_insert_with(|| {
            distinct.push(a);
            distinct.len() - 1
        });
    }
    let shared_cfg = Arc::new(cfg.clone());
    let mut cache = sweep::WorkloadCache::new();
    let job_list: Vec<SpecJob> = distinct
        .iter()
        .map(|&a| SpecJob {
            w: cache.get(a, cfg),
            proto: spec.proto,
            cfg: Arc::clone(&shared_cfg),
        })
        .collect();
    let solo_runs = sweep::run_traced_jobs(&job_list, jobs);

    // ---- Pass 2: open-loop arrivals + placement. ----
    let solo_total =
        |i: usize| solo_runs[job_of[&annots[i]]].metrics.total;
    let mean_solo: Ps = ((0..spec.streams).map(solo_total).sum::<Ps>()
        / spec.streams as u64)
        .max(1);
    let mean_gap: Ps =
        ((mean_solo as f64 / (topo.num_devices() as f64 * spec.load)).round() as Ps).max(1);
    let mut rng = Pcg32::seed_from_u64(spec.seed ^ 0x7E4A_4E7A_5EED_0001);
    let mut arrivals: Vec<Ps> = Vec::with_capacity(spec.streams);
    let mut t: Ps = 0;
    for i in 0..spec.streams {
        if i > 0 {
            // Jittered gap in [0.5, 1.5) × mean (open-loop: independent of
            // completions).
            let gap = (mean_gap as f64 * (0.5 + rng.next_f64())).round() as Ps;
            t += gap.max(1);
        }
        arrivals.push(t);
    }
    let placements: Vec<u32> = (0..spec.streams).map(|i| topo.place(solo_total(i))).collect();

    // ---- Pass 3: replay arbitration (device links + PU pool, fabric). ----
    let n = spec.streams;
    let qos = &topo_spec.qos;
    let mut device_wait: Vec<Ps> = vec![0; n];
    let mut pu_wait: Vec<Ps> = vec![0; n];
    let mut fabric_msgs: Vec<FabricMsg> = Vec::new();
    for d in 0..topo.num_devices() as u32 {
        let mut mem_msgs: Vec<FabricMsg> = Vec::new();
        let mut io_msgs: Vec<FabricMsg> = Vec::new();
        let mut pu_demands: Vec<PuDemand> = Vec::new();
        for i in 0..n {
            if placements[i] != d {
                continue;
            }
            let run = &solo_runs[job_of[&annots[i]]];
            let tenant = i as u32;
            for m in &run.mem_trace {
                mem_msgs.push(FabricMsg { at: arrivals[i] + m.start, bytes: m.bytes, tenant });
            }
            for m in &run.io_trace {
                io_msgs.push(FabricMsg { at: arrivals[i] + m.start, bytes: m.bytes, tenant });
            }
            for s in &run.ccm_trace {
                pu_demands.push(PuDemand { at: arrivals[i] + s.start, dur: s.dur(), tenant });
            }
        }
        // All device traffic also crosses the upstream fabric (skip the
        // copies entirely when no fabric link is modelled).
        if topo_spec.fabric_bw_gbps.is_some() {
            fabric_msgs.extend(mem_msgs.iter().copied());
            fabric_msgs.extend(io_msgs.iter().copied());
        }
        let mem_out = arbitrate_qos(mem_msgs, cfg.cxl_bw_gbps, cfg.cxl_bw_gbps, n, qos);
        let io_out = arbitrate_qos(io_msgs, cfg.cxl_bw_gbps, cfg.cxl_bw_gbps, n, qos);
        // Compute contention: co-located lease windows re-dispatched onto
        // this device's shared CCM pool (interval-merge accounting; FCFS —
        // QoS governs the wires, the PUs stay earliest-free).
        let pu_out = arbitrate_pus(pu_demands, cfg.ccm.num_pus, n);
        let dev = topo.device_mut(d);
        dev.mem_wait = mem_out.total_wait();
        dev.io_wait = io_out.total_wait();
        dev.pu_wait = pu_out.total_wait();
        dev.pu_busy = pu_out.busy_union;
        dev.bytes = mem_out.bytes + io_out.bytes;
        dev.link_busy = mem_out.busy.union() + io_out.busy.union();
        for i in 0..n {
            // CXL.mem and CXL.io are independent wires; a tenant's device
            // delay is its worst channel's completion shift (tenants on
            // other devices have zero in both vectors).
            device_wait[i] = device_wait[i].max(mem_out.waits[i].max(io_out.waits[i]));
            pu_wait[i] = pu_wait[i].max(pu_out.waits[i]);
        }
    }
    let fabric_out =
        topo_spec.fabric_bw_gbps.map(|bw| arbitrate_qos(fabric_msgs, bw, cfg.cxl_bw_gbps, n, qos));

    // ---- Assemble. ----
    let tenants: Vec<TenantRun> = (0..n)
        .map(|i| TenantRun {
            tenant: i as u32,
            annot: annots[i],
            device: placements[i],
            arrival: arrivals[i],
            solo: solo_runs[job_of[&annots[i]]].metrics.clone(),
            device_wait: device_wait[i],
            fabric_wait: fabric_out.as_ref().map_or(0, |f| f.waits[i]),
            pu_wait: pu_wait[i],
        })
        .collect();
    let makespan = tenants.iter().map(|t| t.completion()).max().unwrap_or(0);
    let fabric = match (&fabric_out, topo_spec.fabric_bw_gbps) {
        (Some(f), Some(bw)) => FabricReport {
            bw_gbps: Some(bw),
            messages: f.messages,
            bytes: f.bytes,
            busy: f.busy.union(),
            wait: f.total_wait(),
            utilization: f.utilization(makespan),
        },
        _ => FabricReport::default(),
    };
    let slowdowns: Vec<f64> = tenants.iter().map(|t| t.slowdown()).collect();
    TenantReport {
        qos: topo_spec.qos.policy,
        p50_slowdown: percentile(&slowdowns, 50.0),
        p99_slowdown: percentile(&slowdowns, 99.0),
        max_slowdown: slowdowns.iter().cloned().fold(f64::MIN, f64::max),
        makespan,
        devices: topo.devices().to_vec(),
        fabric,
        tenants,
    }
}

/// Sweep the topology axes: one [`TenantReport`] per `(policy, devices,
/// streams)` grid point, with the base specs' other knobs held fixed.
/// The QoS policy is the outermost axis (each policy re-walks the same
/// device/stream grid, reusing the base spec's weights/floors); the
/// devices/streams pair is the axis the contention figure (`axle report
/// fig17`) walks per policy.
pub fn sweep_tenant_grid(
    cfg: &SimConfig,
    topo_base: &TopologySpec,
    tenant_base: &TenantSpec,
    policy_axis: &[QosPolicy],
    devices_axis: &[usize],
    streams_axis: &[usize],
    jobs: usize,
) -> Vec<(QosPolicy, usize, usize, TenantReport)> {
    let mut out =
        Vec::with_capacity(policy_axis.len() * devices_axis.len() * streams_axis.len());
    for &policy in policy_axis {
        for &d in devices_axis {
            for &k in streams_axis {
                let topo = TopologySpec {
                    devices: d,
                    qos: QosSpec { policy, ..topo_base.qos.clone() },
                    ..topo_base.clone()
                };
                let tenants = TenantSpec { streams: k, ..tenant_base.clone() };
                out.push((policy, d, k, run_tenants(cfg, &topo, &tenants, jobs)));
            }
        }
    }
    out
}

/// One printable line per tenant (the `axle tenants` table body), with
/// the wire/PU slowdown decomposition.
pub fn format_tenant_row(t: &TenantRun) -> String {
    format!(
        "#{:<3} ({})  dev {:<2} arr {:>10.2} us  solo {:>10.2} us  +dev {:>8.2} us  +fab {:>8.2} us  +pu {:>8.2} us  x{:<5.3}",
        t.tenant,
        t.annot,
        t.device,
        ps_to_us(t.arrival),
        ps_to_us(t.solo.total),
        ps_to_us(t.device_wait),
        ps_to_us(t.fabric_wait),
        ps_to_us(t.pu_wait),
        t.slowdown()
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Placement;

    fn data_heavy_mix() -> Vec<char> {
        // KNN (a), SSSP (d), PageRank (e), DLRM (i) — 'e' and 'i' move
        // megabytes per iteration, the fabric-contention heavy hitters.
        vec!['a', 'd', 'e', 'i']
    }

    fn spec_2x8() -> (SimConfig, TopologySpec, TenantSpec) {
        let cfg = SimConfig::m2ndp();
        let topo = TopologySpec::shared_fabric(2, cfg.cxl_bw_gbps);
        let tenants = TenantSpec::new(8).with_workloads(data_heavy_mix());
        (cfg, topo, tenants)
    }

    #[test]
    fn two_devices_eight_streams_deterministic_with_fabric_contention() {
        // The PR's acceptance scenario: `axle tenants --devices 2
        // --streams 8` must be deterministic and show nonzero fabric
        // contention on at least one data-heavy workload.
        let (cfg, topo, tenants) = spec_2x8();
        let a = run_tenants(&cfg, &topo, &tenants, 4);
        let b = run_tenants(&cfg, &topo, &tenants, 1);
        assert_eq!(a.to_json().to_string(), b.to_json().to_string());
        assert_eq!(a.tenants.len(), 8);
        // Round-robin placement across both devices.
        for (i, t) in a.tenants.iter().enumerate() {
            assert_eq!(t.device, (i % 2) as u32);
        }
        assert!(a.fabric.wait > 0, "expected shared-fabric queueing");
        assert!(
            a.tenants.iter().any(|t| "dei".contains(t.annot) && t.fabric_wait > 0),
            "expected a data-heavy tenant to pay fabric wait"
        );
        assert!(a.p99_slowdown >= a.p50_slowdown);
        assert!(a.max_slowdown > 1.0);
        assert!(a.makespan >= a.tenants.iter().map(|t| t.completion()).max().unwrap());
        assert!(a.fabric.utilization > 0.0 && a.fabric.utilization <= 1.0);
    }

    #[test]
    fn single_stream_has_no_contention() {
        let cfg = SimConfig::m2ndp();
        let topo = TopologySpec::shared_fabric(1, cfg.cxl_bw_gbps);
        let tenants = TenantSpec::new(1).with_workloads(vec!['e']);
        let r = run_tenants(&cfg, &topo, &tenants, 2);
        assert_eq!(r.tenants.len(), 1);
        let t = &r.tenants[0];
        // Alone at device bandwidth/capacity the replay reproduces the
        // solo schedule: zero added wait on wires AND on the PU pool,
        // slowdown exactly 1.
        assert_eq!(t.device_wait, 0);
        assert_eq!(t.fabric_wait, 0);
        assert_eq!(t.pu_wait, 0);
        assert!((t.slowdown() - 1.0).abs() < 1e-12);
        assert_eq!(r.makespan, t.solo.total);
    }

    #[test]
    fn solo_metrics_match_direct_protocol_runs() {
        // The tenant driver's solo pass must be the exact single-device
        // simulation, not an approximation of it.
        let (cfg, topo, tenants) = spec_2x8();
        let r = run_tenants(&cfg, &topo, &tenants, 4);
        for t in &r.tenants {
            let w = crate::workload::by_annotation(t.annot, &cfg);
            let direct = crate::protocol::run(tenants.proto, &w, &cfg);
            assert_eq!(t.solo.to_json().to_string(), direct.to_json().to_string());
        }
    }

    #[test]
    fn least_loaded_placement_spreads_heavy_mix() {
        let cfg = SimConfig::m2ndp();
        let topo = TopologySpec::shared_fabric(2, cfg.cxl_bw_gbps)
            .with_placement(Placement::LeastLoaded);
        let tenants = TenantSpec::new(6).with_workloads(data_heavy_mix());
        let r = run_tenants(&cfg, &topo, &tenants, 4);
        assert!(r.devices.iter().all(|d| d.tenants > 0), "both devices used");
        // Greedy least-loaded: device loads within one max-solo of each
        // other.
        let max_solo = r.tenants.iter().map(|t| t.solo.total).max().unwrap();
        let loads: Vec<Ps> = r.devices.iter().map(|d| d.load).collect();
        assert!(loads.iter().max().unwrap() - loads.iter().min().unwrap() <= max_solo);
    }

    #[test]
    fn no_fabric_means_no_fabric_wait() {
        let cfg = SimConfig::m2ndp();
        let topo = TopologySpec { devices: 2, fabric_bw_gbps: None, ..TopologySpec::default() };
        let tenants = TenantSpec::new(4).with_workloads(data_heavy_mix());
        let r = run_tenants(&cfg, &topo, &tenants, 2);
        assert!(r.tenants.iter().all(|t| t.fabric_wait == 0));
        assert_eq!(r.fabric.bw_gbps, None);
        assert_eq!(r.fabric.wait, 0);
    }

    #[test]
    fn narrower_fabric_hurts_more() {
        let (cfg, topo, tenants) = spec_2x8();
        let wide = run_tenants(&cfg, &topo, &tenants, 4);
        let narrow_topo = TopologySpec::shared_fabric(2, cfg.cxl_bw_gbps / 4.0);
        let narrow = run_tenants(&cfg, &narrow_topo, &tenants, 4);
        assert!(narrow.fabric.wait > wide.fabric.wait);
        assert!(narrow.p99_slowdown >= wide.p99_slowdown);
    }

    #[test]
    fn grid_sweep_covers_axes() {
        let cfg = SimConfig::m2ndp();
        let topo = TopologySpec::shared_fabric(1, cfg.cxl_bw_gbps);
        let tenants = TenantSpec::new(1).with_workloads(vec!['a', 'd']);
        let grid = sweep_tenant_grid(
            &cfg,
            &topo,
            &tenants,
            &[QosPolicy::Fcfs, QosPolicy::Wrr],
            &[1, 2],
            &[2, 4],
            2,
        );
        assert_eq!(grid.len(), 8);
        assert_eq!((grid[0].0, grid[0].1, grid[0].2), (QosPolicy::Fcfs, 1, 2));
        assert_eq!((grid[3].0, grid[3].1, grid[3].2), (QosPolicy::Fcfs, 2, 4));
        assert_eq!((grid[4].0, grid[4].1, grid[4].2), (QosPolicy::Wrr, 1, 2));
        assert_eq!((grid[7].0, grid[7].1, grid[7].2), (QosPolicy::Wrr, 2, 4));
        for (p, _, k, r) in &grid {
            assert_eq!(r.tenants.len(), *k);
            assert_eq!(r.qos, *p);
        }
    }

    #[test]
    fn colocated_tenants_pay_pu_contention_under_saturation() {
        // Four copies of the same stream arriving nearly simultaneously
        // on ONE device: their CCM lease windows coincide, so aggregate
        // demand exceeds the 16-PU pool and the later arrivals' compute
        // slides right.
        let cfg = SimConfig::m2ndp();
        let topo = TopologySpec::shared_fabric(1, cfg.cxl_bw_gbps);
        let tenants = TenantSpec::new(4).with_workloads(vec!['e']).with_load(64.0);
        let r = run_tenants(&cfg, &topo, &tenants, 2);
        assert!(
            r.tenants.iter().any(|t| t.pu_wait > 0),
            "coinciding streams must contend for CCM PU time"
        );
        // The decomposition is exactly what total() reports.
        for t in &r.tenants {
            assert_eq!(t.total(), t.solo.total + t.wire_wait() + t.pu_wait);
            assert!(t.slowdown() >= 1.0);
        }
        // Device aggregates mirror the per-tenant shifts.
        let dev_pu: Ps = r.devices.iter().map(|d| d.pu_wait).sum();
        assert!(dev_pu >= r.tenants.iter().map(|t| t.pu_wait).max().unwrap());
        assert!(r.devices[0].pu_busy > 0);
    }

    #[test]
    fn zero_streams_returns_empty_report() {
        // `axle tenants --streams 0` must not panic: an empty report with
        // unit slowdowns and zeroed device stats.
        let cfg = SimConfig::m2ndp();
        let topo = TopologySpec::shared_fabric(2, cfg.cxl_bw_gbps);
        let r = run_tenants(&cfg, &topo, &TenantSpec::new(0), 2);
        assert!(r.tenants.is_empty());
        assert_eq!(r.devices.len(), 2);
        assert!(r.devices.iter().all(|d| d.tenants == 0 && d.load == 0));
        assert_eq!(r.makespan, 0);
        assert_eq!(r.p50_slowdown, 1.0);
        assert_eq!(r.p99_slowdown, 1.0);
        assert_eq!(r.max_slowdown, 1.0);
        assert_eq!(r.fabric.bw_gbps, Some(cfg.cxl_bw_gbps));
        assert_eq!(r.fabric.wait, 0);
        // JSON serialization of the empty report stays well-formed.
        let s = r.to_json().to_string();
        assert!(s.contains("\"tenants\": []") || s.contains("\"tenants\":[]"));
    }

    #[test]
    #[should_panic(expected = "homogeneous devices")]
    fn heterogeneous_topology_rejected_by_open_loop_driver() {
        let cfg = SimConfig::m2ndp();
        let topo = TopologySpec { devices: 2, ..TopologySpec::default() }.with_override(
            1,
            crate::config::DeviceOverride { ccm_pus: Some(4), ..Default::default() },
        );
        let _ = run_tenants(&cfg, &topo, &TenantSpec::new(2), 1);
    }

    #[test]
    fn qos_policy_is_threaded_and_seed_stable() {
        // WRR and DRR runs are deterministic (worker-count invariant,
        // repeatable) and tagged with their policy.
        let (cfg, topo, tenants) = spec_2x8();
        for qos in [
            crate::config::QosSpec::wrr(vec![4, 1]),
            crate::config::QosSpec::drr(vec![0.7, 0.1]),
        ] {
            let policy = qos.policy;
            let t = topo.clone().with_qos(qos);
            let a = run_tenants(&cfg, &t, &tenants, 4);
            let b = run_tenants(&cfg, &t, &tenants, 1);
            assert_eq!(a.to_json().to_string(), b.to_json().to_string());
            assert_eq!(a.qos, policy);
        }
    }

    #[test]
    fn wrr_differs_from_fcfs_under_heavy_contention() {
        // Six data-heavy streams crammed onto one device (load 32 ⇒
        // near-simultaneous arrivals): the link backlog is deep, so the
        // service order — and with it some tenant's completion shift —
        // must change between FCFS and a skewed WRR.
        let cfg = SimConfig::m2ndp();
        let topo = TopologySpec::shared_fabric(1, cfg.cxl_bw_gbps);
        let tenants = TenantSpec::new(6).with_workloads(vec!['e', 'i']).with_load(32.0);
        let fcfs = run_tenants(&cfg, &topo, &tenants, 2);
        let wrr = run_tenants(
            &cfg,
            &topo.clone().with_qos(crate::config::QosSpec::wrr(vec![8, 1])),
            &tenants,
            2,
        );
        let drr = run_tenants(
            &cfg,
            &topo.clone().with_qos(crate::config::QosSpec::drr(vec![0.8, 0.1])),
            &tenants,
            2,
        );
        assert!(fcfs.fabric.wait > 0, "scenario must actually contend");
        let wire = |r: &TenantReport| -> Vec<Ps> {
            r.tenants.iter().map(|t| t.wire_wait()).collect()
        };
        assert_ne!(wire(&fcfs), wire(&wrr), "WRR must reorder waits vs FCFS");
        assert_ne!(wire(&fcfs), wire(&drr), "DRR must reorder waits vs FCFS");
        // PU contention is policy-independent (QoS governs wires).
        let pu = |r: &TenantReport| -> Vec<Ps> { r.tenants.iter().map(|t| t.pu_wait).collect() };
        assert_eq!(pu(&fcfs), pu(&wrr));
        assert_eq!(pu(&fcfs), pu(&drr));
    }
}
