//! Shared-link serialization arbitration by deterministic replay.
//!
//! The tenant driver simulates each stream solo (exact per-tenant
//! timelines from the unchanged protocol engines) while tracing every
//! data-bearing wire occupancy ([`crate::cxl::WireMsg`]). This module
//! then replays the union of those traces against one shared link
//! frontier: messages are served in global issue order (time, then
//! tenant id, then per-tenant FIFO), queueing behind the frontier and
//! serializing at the shared link's bandwidth; each tenant is charged
//! the **completion shift** of its traffic (max per-message lateness vs
//! its solo schedule — see [`arbitrate`]).
//!
//! Because a solo trace records *wire starts* (already serialized
//! against the tenant's own link), replaying a single tenant alone at
//! the same bandwidth reproduces its solo schedule with **zero added
//! wait** — the arbitration measures pure contention. Replaying at a
//! narrower shared-fabric bandwidth additionally charges the upstream
//! bottleneck, which is exactly the fabric model the topology layer
//! wants.

use crate::sim::{transfer_ps, BusyTracker, Ps};

/// One data-bearing message offered to a shared link.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FabricMsg {
    /// Global issue time (tenant arrival + solo wire start).
    pub at: Ps,
    /// Payload bytes.
    pub bytes: u64,
    /// Issuing tenant id (index into the arbitration's wait vector).
    pub tenant: u32,
}

/// Result of one replay arbitration pass.
#[derive(Debug, Clone)]
pub struct ArbitrationOutcome {
    /// Added completion delay per tenant id (length = `n_tenants`): the
    /// maximum *lateness* of that tenant's messages on this link —
    /// `(contended finish) − (solo-trace finish)` — i.e. how far this
    /// link shifts the tail of the tenant's traffic. A max, not a sum:
    /// per-message queueing delays overlap in wall time (one head-of-line
    /// push-back ripples into every later message), so summing them would
    /// overstate the shift by up to the message count.
    pub waits: Vec<Ps>,
    /// Wire busy intervals (union = link busy time).
    pub busy: BusyTracker,
    /// Messages served.
    pub messages: u64,
    /// Bytes served.
    pub bytes: u64,
    /// Time the wire finally frees up.
    pub wire_free: Ps,
}

impl ArbitrationOutcome {
    /// Sum of per-tenant added completion delays (aggregate stat).
    pub fn total_wait(&self) -> Ps {
        self.waits.iter().sum()
    }

    /// Wire utilization over `[0, horizon)`.
    pub fn utilization(&self, horizon: Ps) -> f64 {
        if horizon == 0 {
            0.0
        } else {
            self.busy.union() as f64 / horizon as f64
        }
    }
}

/// Serialize `msgs` on one shared link of `bw_gbps`. The input order is
/// irrelevant (a stable sort on `(at, tenant)` restores global issue
/// order while preserving each tenant's FIFO trace order), so the result
/// is deterministic for any deterministic input set.
///
/// Each message's **lateness** is `(start + ser(bw_gbps)) − (issue +
/// ser(baseline_bw_gbps))`: its contended finish on this link versus the
/// finish already embedded in the solo timeline (recorded on a
/// `baseline_bw_gbps` link). That folds together queueing behind other
/// traffic *and* the serialization excess of a narrower shared link. A
/// tenant's reported delay is the **max** lateness across its messages —
/// the completion shift of its traffic tail — because overlapping
/// per-message queueing is one physical wait, not many. Same-bandwidth
/// replay of a lone tenant yields exactly zero; a narrower fabric
/// correctly charges even a lone tenant the upstream bottleneck.
pub fn arbitrate(
    mut msgs: Vec<FabricMsg>,
    bw_gbps: f64,
    baseline_bw_gbps: f64,
    n_tenants: usize,
) -> ArbitrationOutcome {
    msgs.sort_by_key(|m| (m.at, m.tenant));
    let mut out = ArbitrationOutcome {
        waits: vec![0; n_tenants],
        busy: BusyTracker::new(),
        messages: 0,
        bytes: 0,
        wire_free: 0,
    };
    for m in &msgs {
        let ser = transfer_ps(m.bytes, bw_gbps);
        let solo_finish = m.at + transfer_ps(m.bytes, baseline_bw_gbps);
        let start = m.at.max(out.wire_free);
        let lateness = (start + ser).saturating_sub(solo_finish);
        let w = &mut out.waits[m.tenant as usize];
        *w = (*w).max(lateness);
        out.busy.record(start, start + ser);
        out.wire_free = start + ser;
        out.messages += 1;
        out.bytes += m.bytes;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::NS;

    fn msg(at: Ps, bytes: u64, tenant: u32) -> FabricMsg {
        FabricMsg { at, bytes, tenant }
    }

    #[test]
    fn solo_tenant_replay_adds_no_wait() {
        // A solo trace is already serialized at this bandwidth: starts are
        // spaced at least one serialization apart.
        let bw = 16.0;
        let mut msgs = Vec::new();
        let mut t = 0;
        for _ in 0..10 {
            msgs.push(msg(t, 4096, 0));
            t += transfer_ps(4096, bw) + 3 * NS;
        }
        let out = arbitrate(msgs, bw, bw, 1);
        assert_eq!(out.waits[0], 0);
        assert_eq!(out.messages, 10);
        assert_eq!(out.bytes, 40_960);
    }

    #[test]
    fn overlapping_tenants_pay_serialization_wait() {
        let bw = 1.0; // 1 GB/s → 1 MB = 1 ms
        let out = arbitrate(vec![msg(0, 1_000_000, 0), msg(0, 1_000_000, 1)], bw, bw, 2);
        // Tenant 0 wins the (time, tenant) tie; tenant 1 queues a full
        // serialization behind it.
        assert_eq!(out.waits[0], 0);
        assert_eq!(out.waits[1], transfer_ps(1_000_000, bw));
        assert_eq!(out.busy.union(), 2 * transfer_ps(1_000_000, bw));
        assert!(out.utilization(out.wire_free) > 0.99);
    }

    #[test]
    fn order_of_input_does_not_matter() {
        let a = vec![msg(500, 64, 1), msg(0, 4096, 0), msg(200, 128, 1)];
        let mut b = a.clone();
        b.reverse();
        let oa = arbitrate(a, 16.0, 16.0, 2);
        let ob = arbitrate(b, 16.0, 16.0, 2);
        assert_eq!(oa.waits, ob.waits);
        assert_eq!(oa.wire_free, ob.wire_free);
    }

    #[test]
    fn head_of_line_pushback_counts_once_not_per_message() {
        // Tenant 0's single 1 MB transfer delays the head of tenant 1's
        // back-to-back train; the ripple through the train is ONE
        // completion shift (≈ the push-back), not per-message sums.
        let bw = 1.0;
        let big = transfer_ps(1_000_000, bw);
        let small = transfer_ps(10_000, bw);
        let mut msgs = vec![msg(0, 1_000_000, 0)];
        for k in 0..5u64 {
            msgs.push(msg(k * small, 10_000, 1));
        }
        let out = arbitrate(msgs, bw, bw, 2);
        assert_eq!(out.waits[0], 0);
        // Tail shift: last small message finishes at big + 5·small wire
        // time vs solo 5·small — exactly one `big` of lateness.
        assert_eq!(out.waits[1], big);
    }

    #[test]
    fn narrow_fabric_charges_even_a_single_tenant() {
        // Solo trace serialized at 16 GB/s, fabric at 4 GB/s: messages
        // issued back-to-back now queue.
        let dev_bw = 16.0;
        let mut msgs = Vec::new();
        let mut t = 0;
        for _ in 0..4 {
            msgs.push(msg(t, 1 << 20, 0));
            t += transfer_ps(1 << 20, dev_bw);
        }
        let out = arbitrate(msgs, 4.0, dev_bw, 1);
        assert!(out.waits[0] > 0);
    }
}
