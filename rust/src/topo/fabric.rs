//! Shared-resource arbitration by deterministic replay: FCFS / WRR / DRR
//! link scheduling plus CCM PU-pool sharing.
//!
//! The tenant driver simulates each stream solo (exact per-tenant
//! timelines from the unchanged protocol engines) while tracing every
//! data-bearing wire occupancy ([`crate::cxl::WireMsg`]) and every CCM PU
//! lease window ([`crate::sim::PuSpan`]). This module then replays the
//! union of those traces against the shared resources:
//!
//! - **Links** ([`arbitrate_qos`]): messages queue behind one wire
//!   frontier and serialize at the shared link's bandwidth. *Which*
//!   queued message the wire serves next is the pluggable part — the
//!   [`QosPolicy`] selected in [`QosSpec`]:
//!   [`Fcfs`](QosPolicy::Fcfs) (global issue order, the PR-2 arbiter,
//!   kept bit-identical in [`arbitrate`]),
//!   [`Wrr`](QosPolicy::Wrr) (weighted round-robin over per-tenant
//!   queues, message granularity) and
//!   [`Drr`](QosPolicy::Drr) (deficit round-robin, byte granularity,
//!   quanta proportional to per-tenant bandwidth floors).
//! - **CCM PUs** ([`arbitrate_pus`]): co-located tenants' traced lease
//!   windows are re-dispatched onto one earliest-free pool of the
//!   device's PU count; when aggregate demand exceeds capacity, the
//!   excess windows slide right and the displaced tenants are charged
//!   the completion shift.
//!
//! Every policy is **work-conserving**: the wire (or pool) never idles
//! while an arrived message (or lease) waits. A classic single-server
//! queueing fact follows: the busy periods — and therefore the wire's
//! busy-time union and final free-up time — are identical across
//! policies; QoS only redistributes *who* waits inside them (pinned by
//! `prop_qos_policies_share_busy_periods`).
//!
//! Each tenant is charged the **completion shift** of its traffic: the
//! maximum per-message (per-lease) lateness versus its solo schedule — a
//! max, not a sum, because overlapping per-message queueing is one
//! physical wait (see [`arbitrate`]).
//!
//! # Worked example: WRR
//!
//! Two tenants, weights `[2, 1]`, both with messages queued at `t = 0`.
//! Credits initialize to the weights; the scan pointer stays on a tenant
//! until its credits are spent, and refills one round of credits when
//! every backlogged tenant is out:
//!
//! ```text
//! service order:  T0 T0 T1 | T0 T0 T1 | ...    (2:1 message ratio)
//!                 └ credits [2,1] spent ┘ refill
//! ```
//!
//! FCFS on the same input would serve every T0 message before any T0/T1
//! tie loser — a burst from one tenant head-of-line-blocks the other for
//! its whole train. WRR bounds that: a backlogged tenant with weight
//! `w ≥ 1` is served at least `w` times per round of
//! `sum(weights of backlogged tenants)` services.
//!
//! # Worked example: DRR
//!
//! Two tenants with 1000-byte messages and bandwidth floors `[0.75,
//! 0.25]`. Quanta are `floor/Σfloors × max message size` = `[750, 250]`
//! bytes. Each round-robin visit banks one quantum; a queue sends while
//! its deficit covers the head message:
//!
//! ```text
//! visit T0: deficit  750 < 1000 — bank     visit T1: 250 < 1000 — bank
//! visit T0: deficit 1500 → send, keep 500  visit T1: 500 — bank
//! visit T0: deficit 1250 → send, keep 250  visit T1: 750 — bank
//! visit T0: deficit 1000 → send, keep 0    visit T1: 1000 → send
//! ```
//!
//! Steady state serves three T0 bytes for every T1 byte — exactly the
//! 0.75 : 0.25 floors. Because a queue's deficit grows by a positive
//! quantum every round (floors clamp to a 1-byte minimum quantum), no
//! backlogged tenant starves; an idle queue's deficit resets to zero
//! (no banking credit across idle gaps), per classic DRR.

use crate::config::{QosPolicy, QosSpec};
use crate::sim::{transfer_ps, BusyTracker, Ps, PuPool};

/// One data-bearing message offered to a shared link.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FabricMsg {
    /// Global issue time (tenant arrival + solo wire start).
    pub at: Ps,
    /// Payload bytes.
    pub bytes: u64,
    /// Issuing tenant id (index into the arbitration's wait vector).
    pub tenant: u32,
}

/// Result of one replay arbitration pass.
#[derive(Debug, Clone)]
pub struct ArbitrationOutcome {
    /// Added completion delay per tenant id (length = `n_tenants`): the
    /// maximum *lateness* of that tenant's messages on this link —
    /// `(contended finish) − (solo-trace finish)` — i.e. how far this
    /// link shifts the tail of the tenant's traffic. A max, not a sum:
    /// per-message queueing delays overlap in wall time (one head-of-line
    /// push-back ripples into every later message), so summing them would
    /// overstate the shift by up to the message count.
    pub waits: Vec<Ps>,
    /// Wire busy intervals (union = link busy time).
    pub busy: BusyTracker,
    /// Messages served.
    pub messages: u64,
    /// Bytes served.
    pub bytes: u64,
    /// Time the wire finally frees up.
    pub wire_free: Ps,
    /// Tenant ids in wire-service order (the scheduling decision trace —
    /// what the fairness/starvation tests inspect).
    pub order: Vec<u32>,
}

impl ArbitrationOutcome {
    fn empty(n_tenants: usize, capacity: usize) -> Self {
        Self {
            waits: vec![0; n_tenants],
            busy: BusyTracker::new(),
            messages: 0,
            bytes: 0,
            wire_free: 0,
            order: Vec::with_capacity(capacity),
        }
    }

    /// Charge one served message: lateness bookkeeping plus wire stats.
    fn serve(&mut self, m: &FabricMsg, bw_gbps: f64, baseline_bw_gbps: f64) {
        let ser = transfer_ps(m.bytes, bw_gbps);
        let solo_finish = m.at + transfer_ps(m.bytes, baseline_bw_gbps);
        let start = m.at.max(self.wire_free);
        let lateness = (start + ser).saturating_sub(solo_finish);
        let w = &mut self.waits[m.tenant as usize];
        *w = (*w).max(lateness);
        self.busy.record(start, start + ser);
        self.wire_free = start + ser;
        self.messages += 1;
        self.bytes += m.bytes;
        self.order.push(m.tenant);
    }

    /// Sum of per-tenant added completion delays (aggregate stat).
    pub fn total_wait(&self) -> Ps {
        self.waits.iter().sum()
    }

    /// Wire utilization over `[0, horizon)`.
    pub fn utilization(&self, horizon: Ps) -> f64 {
        if horizon == 0 {
            0.0
        } else {
            self.busy.union() as f64 / horizon as f64
        }
    }
}

/// Serialize `msgs` on one shared link of `bw_gbps` in **FCFS** order —
/// the PR-2 arbiter, kept verbatim as the reference implementation (the
/// FCFS policy path of [`arbitrate_qos`] and the baseline the QoS
/// regression tests pin against). The input order is irrelevant (a stable
/// sort on `(at, tenant)` restores global issue order while preserving
/// each tenant's FIFO trace order), so the result is deterministic for
/// any deterministic input set.
///
/// Each message's **lateness** is `(start + ser(bw_gbps)) − (issue +
/// ser(baseline_bw_gbps))`: its contended finish on this link versus the
/// finish already embedded in the solo timeline (recorded on a
/// `baseline_bw_gbps` link). That folds together queueing behind other
/// traffic *and* the serialization excess of a narrower shared link. A
/// tenant's reported delay is the **max** lateness across its messages —
/// the completion shift of its traffic tail — because overlapping
/// per-message queueing is one physical wait, not many. Same-bandwidth
/// replay of a lone tenant yields exactly zero; a narrower fabric
/// correctly charges even a lone tenant the upstream bottleneck.
pub fn arbitrate(
    mut msgs: Vec<FabricMsg>,
    bw_gbps: f64,
    baseline_bw_gbps: f64,
    n_tenants: usize,
) -> ArbitrationOutcome {
    msgs.sort_by_key(|m| (m.at, m.tenant));
    let mut out = ArbitrationOutcome::empty(n_tenants, msgs.len());
    for m in &msgs {
        out.serve(m, bw_gbps, baseline_bw_gbps);
    }
    out
}

/// Serialize `msgs` on one shared link under the arbitration policy in
/// `qos`. [`QosPolicy::Fcfs`] delegates to [`arbitrate`] (bit-identical
/// to the PR-2 arbiter by construction); WRR/DRR replay per-tenant FIFO
/// queues under the scheduler (see the module docs for the algorithms and
/// worked examples). All policies are work-conserving, so busy periods —
/// wire utilization and final free-up time — match FCFS exactly; only the
/// distribution of waits across tenants changes.
pub fn arbitrate_qos(
    msgs: Vec<FabricMsg>,
    bw_gbps: f64,
    baseline_bw_gbps: f64,
    n_tenants: usize,
    qos: &QosSpec,
) -> ArbitrationOutcome {
    match qos.policy {
        QosPolicy::Fcfs => arbitrate(msgs, bw_gbps, baseline_bw_gbps, n_tenants),
        QosPolicy::Wrr | QosPolicy::Drr => {
            replay_scheduled(msgs, bw_gbps, baseline_bw_gbps, n_tenants, qos)
        }
    }
}

/// Packet-granularity weighted-round-robin scheduler state.
#[derive(Debug)]
struct WrrState {
    weights: Vec<u64>,
    credits: Vec<u64>,
    ptr: usize,
}

impl WrrState {
    fn new(qos: &QosSpec, n: usize) -> Self {
        let weights: Vec<u64> = (0..n).map(|i| qos.weight(i)).collect();
        let credits = weights.clone();
        Self { weights, credits, ptr: 0 }
    }

    /// Pick the next tenant to serve among `eligible` (≥ 1 true entry).
    /// `head_at` orders the FCFS fallback when every eligible tenant has
    /// weight zero (best-effort class).
    fn pick(&mut self, eligible: &[bool], head_at: &[Ps]) -> usize {
        let n = self.weights.len();
        // Refill one round of credits once every backlogged queue is out.
        if (0..n).filter(|&i| eligible[i]).all(|i| self.credits[i] == 0) {
            self.credits.copy_from_slice(&self.weights);
        }
        // Cyclic scan from the pointer; stay on a queue until its credits
        // are spent (classic batched WRR).
        for k in 0..n {
            let i = (self.ptr + k) % n;
            if eligible[i] && self.credits[i] > 0 {
                self.credits[i] -= 1;
                self.ptr = if self.credits[i] == 0 { (i + 1) % n } else { i };
                return i;
            }
        }
        // Only zero-weight (best-effort) queues are backlogged: FCFS.
        (0..n)
            .filter(|&i| eligible[i])
            .min_by_key(|&i| (head_at[i], i))
            .expect("eligible set is non-empty")
    }
}

/// Byte-granularity deficit-round-robin scheduler state.
#[derive(Debug)]
struct DrrState {
    quantum: Vec<u64>,
    deficit: Vec<u64>,
    ptr: usize,
    /// Queue currently draining its banked deficit (stays selected until
    /// the deficit no longer covers its head message).
    cur: Option<usize>,
}

impl DrrState {
    /// Quanta are `floor_i / Σfloors × max_bytes`, clamped to ≥ 1 byte:
    /// the largest-floor tenant can send its largest message in about one
    /// round, and every tenant's deficit strictly grows each round (no
    /// starvation).
    fn new(qos: &QosSpec, n: usize, max_bytes: u64) -> Self {
        let floors: Vec<f64> = (0..n).map(|i| qos.floor(i)).collect();
        let sum: f64 = floors.iter().sum();
        let quantum = floors
            .iter()
            .map(|f| {
                let share = if sum > 0.0 { f / sum } else { 1.0 / n.max(1) as f64 };
                ((share * max_bytes as f64).round() as u64).max(1)
            })
            .collect();
        Self { quantum, deficit: vec![0; n], ptr: 0, cur: None }
    }

    fn pick(&mut self, eligible: &[bool], head_bytes: &[u64]) -> usize {
        let n = self.quantum.len();
        // Keep draining the current queue while its deficit lasts.
        if let Some(i) = self.cur {
            if eligible[i] && self.deficit[i] >= head_bytes[i] {
                self.deficit[i] -= head_bytes[i];
                return i;
            }
            self.cur = None;
        }
        let mut visits = 0usize;
        loop {
            let i = self.ptr;
            self.ptr = (self.ptr + 1) % n;
            if eligible[i] {
                self.deficit[i] = self.deficit[i].saturating_add(self.quantum[i]);
                if self.deficit[i] >= head_bytes[i] {
                    self.deficit[i] -= head_bytes[i];
                    self.cur = Some(i);
                    return i;
                }
            } else {
                // Classic DRR: an idle queue banks no deficit.
                self.deficit[i] = 0;
            }
            visits += 1;
            if visits % n == 0 {
                // One full cycle served nothing: every backlogged queue
                // needs more top-ups. Bank the remaining rounds in bulk so
                // a micro-quantum cannot make the scan quadratic in bytes;
                // the next cycle serves the round-robin-first queue that
                // needed the fewest rounds — exactly classic DRR's pick.
                let k = (0..n)
                    .filter(|&i| eligible[i])
                    .map(|i| (head_bytes[i] - self.deficit[i]).div_ceil(self.quantum[i]))
                    .min()
                    .expect("eligible set is non-empty");
                if k > 1 {
                    for i in 0..n {
                        if eligible[i] {
                            self.deficit[i] =
                                self.deficit[i].saturating_add((k - 1) * self.quantum[i]);
                        }
                    }
                }
            }
        }
    }
}

/// Policy-agnostic, incremental QoS scheduler state: the pick logic of
/// every [`QosPolicy`] behind one interface, usable both by the batch
/// replay ([`arbitrate_qos`]) and **online** by the closed-loop
/// scheduler's live link calendars ([`crate::sched::driver`]), which
/// consult it each time a wire must choose among queued tenants.
///
/// State (WRR credits and scan pointer, DRR deficits) persists across
/// `pick` calls, so an online caller gets the same round structure the
/// replay produces: feed it the per-tenant head-of-queue view
/// (`eligible` / `head_at` / `head_bytes`) whenever the wire frees up
/// and serve the returned tenant's head message.
#[derive(Debug)]
pub struct QosState {
    inner: QosInner,
}

#[derive(Debug)]
enum QosInner {
    /// Global issue order `(head arrival, tenant id)` — the stateless
    /// PR-2 discipline expressed as a pick rule.
    Fcfs,
    Wrr(WrrState),
    Drr(DrrState),
}

impl QosState {
    /// Scheduler state for `n_tenants` queues under `qos`. `max_bytes`
    /// sizes the DRR quanta (the largest message the link will carry;
    /// FCFS/WRR ignore it) — the replay derives it from the offered
    /// message set, an online caller from the solo traces it replays.
    pub fn new(qos: &QosSpec, n_tenants: usize, max_bytes: u64) -> Self {
        let inner = match qos.policy {
            QosPolicy::Fcfs => QosInner::Fcfs,
            QosPolicy::Wrr => QosInner::Wrr(WrrState::new(qos, n_tenants)),
            QosPolicy::Drr => QosInner::Drr(DrrState::new(qos, n_tenants, max_bytes.max(1))),
        };
        Self { inner }
    }

    /// Pick the tenant the wire serves next. `eligible[i]` marks queues
    /// whose head message has arrived (at least one must be set);
    /// `head_at[i]` is the head's arrival time (`Ps::MAX` for empty
    /// queues), `head_bytes[i]` its payload size (DRR deficit currency).
    pub fn pick(&mut self, eligible: &[bool], head_at: &[Ps], head_bytes: &[u64]) -> usize {
        match &mut self.inner {
            QosInner::Fcfs => (0..eligible.len())
                .filter(|&i| eligible[i])
                .min_by_key(|&i| (head_at[i], i))
                .expect("eligible set is non-empty"),
            QosInner::Wrr(s) => s.pick(eligible, head_at),
            QosInner::Drr(s) => s.pick(eligible, head_bytes),
        }
    }
}

/// The WRR/DRR replay core: per-tenant FIFO queues drained against one
/// wire frontier, the scheduler choosing among the queues whose head has
/// arrived. Work-conserving by construction — the decision clock `t` is
/// the wire frontier or, if the wire would idle, the next arrival, and
/// the tenant owning that earliest arrival is always eligible.
fn replay_scheduled(
    mut msgs: Vec<FabricMsg>,
    bw_gbps: f64,
    baseline_bw_gbps: f64,
    n_tenants: usize,
    qos: &QosSpec,
) -> ArbitrationOutcome {
    msgs.sort_by_key(|m| (m.at, m.tenant));
    let total = msgs.len();
    let mut out = ArbitrationOutcome::empty(n_tenants, total);
    if total == 0 {
        return out;
    }
    let max_bytes = msgs.iter().map(|m| m.bytes).max().unwrap_or(1).max(1);
    let mut sched = QosState::new(qos, n_tenants, max_bytes);
    // Per-tenant FIFO queues (the stable sort keeps each tenant's trace
    // order) walked by cursor.
    let mut queues: Vec<Vec<FabricMsg>> = vec![Vec::new(); n_tenants];
    for m in &msgs {
        queues[m.tenant as usize].push(*m);
    }
    let mut cursor = vec![0usize; n_tenants];
    let mut eligible = vec![false; n_tenants];
    let mut head_at = vec![Ps::MAX; n_tenants];
    let mut head_bytes = vec![0u64; n_tenants];
    let mut served = 0usize;
    while served < total {
        // Decision clock: the wire frontier, or the next arrival if the
        // wire would otherwise idle (work conservation).
        let t_min = (0..n_tenants)
            .filter(|&i| cursor[i] < queues[i].len())
            .map(|i| queues[i][cursor[i]].at)
            .min()
            .expect("unserved messages remain");
        let t = out.wire_free.max(t_min);
        for i in 0..n_tenants {
            if cursor[i] < queues[i].len() {
                let h = &queues[i][cursor[i]];
                head_at[i] = h.at;
                head_bytes[i] = h.bytes;
                eligible[i] = h.at <= t;
            } else {
                eligible[i] = false;
                head_at[i] = Ps::MAX;
                head_bytes[i] = 0;
            }
        }
        let i = sched.pick(&eligible, &head_at, &head_bytes);
        let m = queues[i][cursor[i]];
        cursor[i] += 1;
        served += 1;
        out.serve(&m, bw_gbps, baseline_bw_gbps);
    }
    out
}

/// One traced CCM PU lease window offered to a shared pool (a tenant's
/// solo-run occupancy, shifted by its arrival).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PuDemand {
    /// Global demand time (tenant arrival + solo span start).
    pub at: Ps,
    /// PU occupancy duration.
    pub dur: Ps,
    /// Demanding tenant id.
    pub tenant: u32,
}

/// Result of one PU-pool sharing replay.
#[derive(Debug, Clone)]
pub struct PuOutcome {
    /// Added completion delay per tenant id: the maximum lateness of that
    /// tenant's lease windows versus its solo schedule (same max-not-sum
    /// accounting as [`ArbitrationOutcome::waits`]).
    pub waits: Vec<Ps>,
    /// Pool busy-union across the replay.
    pub busy_union: Ps,
    /// Aggregate PU-time demand (Σ durations).
    pub busy_total: Ps,
    /// Lease windows replayed.
    pub spans: u64,
    /// Time the last PU frees up.
    pub pool_free: Ps,
}

impl PuOutcome {
    /// Sum of per-tenant added completion delays (aggregate stat).
    pub fn total_wait(&self) -> Ps {
        self.waits.iter().sum()
    }
}

/// Replay co-located tenants' traced CCM lease windows onto one shared
/// pool of `capacity` PUs (earliest-free dispatch in global `(at,
/// tenant)` order — the interval-merge accounting for compute
/// contention). A solo trace re-dispatched alone reproduces its own
/// schedule exactly: at any instant it holds at most `capacity`
/// concurrent leases (it was produced by a pool of the same size), so the
/// greedy always finds a free PU at the demand time and the lateness is
/// zero — the replay measures pure compute contention, precisely as the
/// link replay measures pure wire contention.
pub fn arbitrate_pus(mut demands: Vec<PuDemand>, capacity: usize, n_tenants: usize) -> PuOutcome {
    demands.sort_by_key(|d| (d.at, d.tenant));
    let mut pool = PuPool::new(capacity);
    let mut out = PuOutcome {
        waits: vec![0; n_tenants],
        busy_union: 0,
        busy_total: 0,
        spans: demands.len() as u64,
        pool_free: 0,
    };
    for d in &demands {
        let (_, end) = pool.dispatch(d.at, d.dur);
        let lateness = end.saturating_sub(d.at + d.dur);
        let w = &mut out.waits[d.tenant as usize];
        *w = (*w).max(lateness);
    }
    out.busy_union = pool.busy().union();
    out.busy_total = pool.busy().total();
    out.pool_free = pool.all_free();
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::NS;

    fn msg(at: Ps, bytes: u64, tenant: u32) -> FabricMsg {
        FabricMsg { at, bytes, tenant }
    }

    #[test]
    fn solo_tenant_replay_adds_no_wait() {
        // A solo trace is already serialized at this bandwidth: starts are
        // spaced at least one serialization apart.
        let bw = 16.0;
        let mut msgs = Vec::new();
        let mut t = 0;
        for _ in 0..10 {
            msgs.push(msg(t, 4096, 0));
            t += transfer_ps(4096, bw) + 3 * NS;
        }
        let out = arbitrate(msgs, bw, bw, 1);
        assert_eq!(out.waits[0], 0);
        assert_eq!(out.messages, 10);
        assert_eq!(out.bytes, 40_960);
    }

    #[test]
    fn overlapping_tenants_pay_serialization_wait() {
        let bw = 1.0; // 1 GB/s → 1 MB = 1 ms
        let out = arbitrate(vec![msg(0, 1_000_000, 0), msg(0, 1_000_000, 1)], bw, bw, 2);
        // Tenant 0 wins the (time, tenant) tie; tenant 1 queues a full
        // serialization behind it.
        assert_eq!(out.waits[0], 0);
        assert_eq!(out.waits[1], transfer_ps(1_000_000, bw));
        assert_eq!(out.busy.union(), 2 * transfer_ps(1_000_000, bw));
        assert!(out.utilization(out.wire_free) > 0.99);
        assert_eq!(out.order, vec![0, 1]);
    }

    #[test]
    fn order_of_input_does_not_matter() {
        let a = vec![msg(500, 64, 1), msg(0, 4096, 0), msg(200, 128, 1)];
        let mut b = a.clone();
        b.reverse();
        let oa = arbitrate(a, 16.0, 16.0, 2);
        let ob = arbitrate(b, 16.0, 16.0, 2);
        assert_eq!(oa.waits, ob.waits);
        assert_eq!(oa.wire_free, ob.wire_free);
        assert_eq!(oa.order, ob.order);
    }

    #[test]
    fn head_of_line_pushback_counts_once_not_per_message() {
        // Tenant 0's single 1 MB transfer delays the head of tenant 1's
        // back-to-back train; the ripple through the train is ONE
        // completion shift (≈ the push-back), not per-message sums.
        let bw = 1.0;
        let big = transfer_ps(1_000_000, bw);
        let small = transfer_ps(10_000, bw);
        let mut msgs = vec![msg(0, 1_000_000, 0)];
        for k in 0..5u64 {
            msgs.push(msg(k * small, 10_000, 1));
        }
        let out = arbitrate(msgs, bw, bw, 2);
        assert_eq!(out.waits[0], 0);
        // Tail shift: last small message finishes at big + 5·small wire
        // time vs solo 5·small — exactly one `big` of lateness.
        assert_eq!(out.waits[1], big);
    }

    #[test]
    fn narrow_fabric_charges_even_a_single_tenant() {
        // Solo trace serialized at 16 GB/s, fabric at 4 GB/s: messages
        // issued back-to-back now queue.
        let dev_bw = 16.0;
        let mut msgs = Vec::new();
        let mut t = 0;
        for _ in 0..4 {
            msgs.push(msg(t, 1 << 20, 0));
            t += transfer_ps(1 << 20, dev_bw);
        }
        let out = arbitrate(msgs, 4.0, dev_bw, 1);
        assert!(out.waits[0] > 0);
    }

    // ---- QoS policies ----

    /// 2 × `count` equal messages all queued at t = 0; the workhorse for
    /// order-sensitive assertions.
    fn burst_two_tenants(count: u64, bytes: u64) -> Vec<FabricMsg> {
        let mut msgs = Vec::new();
        for t in 0..2u32 {
            for _ in 0..count {
                msgs.push(msg(0, bytes, t));
            }
        }
        msgs
    }

    #[test]
    fn wrr_equal_weights_interleave_where_fcfs_serves_the_tie_winner_first() {
        let bw = 1.0;
        let msgs = burst_two_tenants(4, 1_000_000);
        let fcfs = arbitrate(msgs.clone(), bw, bw, 2);
        let wrr = arbitrate_qos(msgs, bw, bw, 2, &QosSpec::wrr(vec![1, 1]));
        // FCFS: tenant 0 wins every (t=0, tenant) tie → its whole train
        // goes first. WRR alternates.
        assert_eq!(fcfs.order, vec![0, 0, 0, 0, 1, 1, 1, 1]);
        assert_eq!(wrr.order, vec![0, 1, 0, 1, 0, 1, 0, 1]);
        // Work conservation: identical busy periods either way.
        assert_eq!(fcfs.busy.union(), wrr.busy.union());
        assert_eq!(fcfs.wire_free, wrr.wire_free);
        assert_eq!(fcfs.bytes, wrr.bytes);
        // The interleave changes who waits: under WRR tenant 0's tail
        // slips behind three of tenant 1's messages.
        let big = transfer_ps(1_000_000, bw);
        assert_eq!(fcfs.waits[0], 3 * big);
        assert_eq!(wrr.waits[0], 6 * big);
        assert_eq!(fcfs.waits[1], 7 * big);
        assert_eq!(wrr.waits[1], 7 * big);
    }

    #[test]
    fn wrr_weights_protect_a_mouse_from_a_hog() {
        let bw = 1.0;
        let mut msgs = Vec::new();
        for _ in 0..16 {
            msgs.push(msg(0, 1_000_000, 0)); // hog: 16 MB burst
        }
        msgs.push(msg(0, 64_000, 1)); // mouse: one small message
        let fcfs = arbitrate(msgs.clone(), bw, bw, 2);
        let wrr = arbitrate_qos(msgs, bw, bw, 2, &QosSpec::wrr(vec![1, 1]));
        // FCFS: the mouse queues behind the whole hog burst. WRR: it is
        // served second.
        assert_eq!(fcfs.waits[1], 16 * transfer_ps(1_000_000, bw));
        assert_eq!(wrr.waits[1], transfer_ps(1_000_000, bw));
        assert_eq!(wrr.order[1], 1);
    }

    #[test]
    fn wrr_ratio_matches_weights() {
        let msgs = burst_two_tenants(9, 10_000);
        let wrr = arbitrate_qos(msgs, 16.0, 16.0, 2, &QosSpec::wrr(vec![2, 1]));
        // While both queues are backlogged the pattern is T0 T0 T1.
        assert_eq!(&wrr.order[..6], &[0, 0, 1, 0, 0, 1]);
    }

    #[test]
    fn wrr_zero_weight_is_best_effort() {
        let msgs = burst_two_tenants(3, 10_000);
        let wrr = arbitrate_qos(msgs, 16.0, 16.0, 2, &QosSpec::wrr(vec![1, 0]));
        // The weighted tenant's whole backlog drains before the
        // best-effort tenant is served at all.
        assert_eq!(wrr.order, vec![0, 0, 0, 1, 1, 1]);
    }

    #[test]
    fn drr_floors_shift_bandwidth_three_to_one() {
        let msgs = burst_two_tenants(20, 1_000);
        let drr = arbitrate_qos(msgs, 16.0, 16.0, 2, &QosSpec::drr(vec![0.75, 0.25]));
        // Quanta [750, 250] over 1000-byte messages: steady-state pattern
        // serves three T0 messages per T1 message (see module docs).
        let t0_in_first_8 = drr.order[..8].iter().filter(|&&t| t == 0).count();
        assert!(
            (5..=7).contains(&t0_in_first_8),
            "expected ≈3:1 service ratio, got order {:?}",
            &drr.order[..8]
        );
        // All messages served, per-tenant counts preserved.
        assert_eq!(drr.order.iter().filter(|&&t| t == 0).count(), 20);
        assert_eq!(drr.order.iter().filter(|&&t| t == 1).count(), 20);
    }

    #[test]
    fn drr_equal_floors_round_robin_equal_packets() {
        let msgs = burst_two_tenants(4, 50_000);
        let drr = arbitrate_qos(msgs, 16.0, 16.0, 2, &QosSpec::drr(Vec::new()));
        // Equal floors over equal packets ⇒ quantum = packet size ⇒ pure
        // round-robin.
        assert_eq!(drr.order, vec![0, 1, 0, 1, 0, 1, 0, 1]);
    }

    #[test]
    fn qos_policies_agree_on_a_solo_tenant() {
        // With one queue there is nothing to schedule: every policy must
        // reproduce the FCFS outcome exactly.
        let bw = 8.0;
        let mut msgs = Vec::new();
        let mut t = 0;
        for k in 0..12u64 {
            msgs.push(msg(t, 1_000 + 137 * k, 0));
            t += transfer_ps(1_000, bw) / 2 + NS;
        }
        let fcfs = arbitrate(msgs.clone(), bw, bw, 1);
        for qos in [QosSpec::wrr(vec![5]), QosSpec::drr(vec![0.3])] {
            let out = arbitrate_qos(msgs.clone(), bw, bw, 1, &qos);
            assert_eq!(out.waits, fcfs.waits);
            assert_eq!(out.wire_free, fcfs.wire_free);
            assert_eq!(out.order, fcfs.order);
            assert_eq!(out.busy.union(), fcfs.busy.union());
        }
    }

    #[test]
    fn qos_replay_is_deterministic_and_input_order_free() {
        let mut a = burst_two_tenants(6, 2_000);
        a.push(msg(5 * NS, 9_000, 1));
        a.push(msg(3 * NS, 700, 0));
        let mut b = a.clone();
        b.reverse();
        for qos in [QosSpec::wrr(vec![3, 1]), QosSpec::drr(vec![0.6, 0.4])] {
            let oa = arbitrate_qos(a.clone(), 16.0, 16.0, 2, &qos);
            let ob = arbitrate_qos(b.clone(), 16.0, 16.0, 2, &qos);
            assert_eq!(oa.waits, ob.waits);
            assert_eq!(oa.order, ob.order);
            assert_eq!(oa.wire_free, ob.wire_free);
        }
    }

    #[test]
    fn qos_empty_input_yields_empty_outcome() {
        for qos in [QosSpec::fcfs(), QosSpec::wrr(vec![2]), QosSpec::drr(vec![0.5])] {
            let out = arbitrate_qos(Vec::new(), 16.0, 16.0, 3, &qos);
            assert_eq!(out.waits, vec![0, 0, 0]);
            assert_eq!(out.messages, 0);
            assert_eq!(out.wire_free, 0);
            assert!(out.order.is_empty());
        }
    }

    // ---- QosState (the online pick interface) ----

    #[test]
    fn qos_state_fcfs_picks_global_issue_order() {
        let mut s = QosState::new(&QosSpec::fcfs(), 3, 1);
        // Tenant 1's head arrived first; ties break on tenant id.
        let eligible = [true, true, true];
        assert_eq!(s.pick(&eligible, &[50, 10, 50], &[1, 1, 1]), 1);
        assert_eq!(s.pick(&eligible, &[50, 99, 50], &[1, 1, 1]), 0);
        // Ineligible queues are skipped even with the earliest head.
        assert_eq!(s.pick(&[false, true, true], &[0, 70, 60], &[1, 1, 1]), 2);
    }

    #[test]
    fn qos_state_wrr_round_structure_persists_across_picks() {
        let mut s = QosState::new(&QosSpec::wrr(vec![2, 1]), 2, 1);
        let eligible = [true, true];
        let at = [0, 0];
        let bytes = [1_000, 1_000];
        // Credits persist call to call: the classic 2:1 batched pattern.
        let order: Vec<usize> = (0..6).map(|_| s.pick(&eligible, &at, &bytes)).collect();
        assert_eq!(order, vec![0, 0, 1, 0, 0, 1]);
    }

    #[test]
    fn qos_state_drr_deficits_persist_across_picks() {
        // Quanta [750, 250] over 1000-byte heads: ≈3:1 service ratio,
        // exactly the replay's steady state (see module docs).
        let mut s = QosState::new(&QosSpec::drr(vec![0.75, 0.25]), 2, 1_000);
        let eligible = [true, true];
        let at = [0, 0];
        let bytes = [1_000, 1_000];
        let order: Vec<usize> = (0..8).map(|_| s.pick(&eligible, &at, &bytes)).collect();
        let t0 = order.iter().filter(|&&i| i == 0).count();
        assert!((5..=7).contains(&t0), "expected ≈3:1 ratio, got {order:?}");
    }

    // ---- PU-pool sharing ----

    fn dem(at: Ps, dur: Ps, tenant: u32) -> PuDemand {
        PuDemand { at, dur, tenant }
    }

    #[test]
    fn pu_replay_of_a_within_capacity_trace_adds_no_wait() {
        // ≤ capacity concurrent leases replay to their own schedule.
        let demands = vec![dem(0, 100, 0), dem(0, 80, 0), dem(50, 60, 0), dem(100, 10, 0)];
        let out = arbitrate_pus(demands, 3, 1);
        assert_eq!(out.waits[0], 0);
        assert_eq!(out.spans, 4);
        assert_eq!(out.busy_total, 250);
    }

    #[test]
    fn pu_overload_charges_the_displaced_tenant() {
        // One PU, two tenants demanding the same window: the (at, tenant)
        // tie goes to tenant 0, tenant 1 slides a full lease right.
        let out = arbitrate_pus(vec![dem(0, 100, 0), dem(0, 100, 1)], 1, 2);
        assert_eq!(out.waits[0], 0);
        assert_eq!(out.waits[1], 100);
        assert_eq!(out.busy_union, 200);
        assert_eq!(out.pool_free, 200);
    }

    #[test]
    fn pu_shift_is_a_max_not_a_sum() {
        // Tenant 1's back-to-back lease train slides right once behind
        // tenant 0's long lease — one completion shift, not per-span sums.
        let mut demands = vec![dem(0, 1_000, 0)];
        for k in 0..5u64 {
            demands.push(dem(k * 100, 100, 1));
        }
        let out = arbitrate_pus(demands, 1, 2);
        assert_eq!(out.waits[0], 0);
        assert_eq!(out.waits[1], 1_000);
    }

    #[test]
    fn pu_capacity_relieves_contention() {
        let demands: Vec<PuDemand> =
            (0..8).map(|k| dem(0, 100, (k % 4) as u32)).collect();
        let narrow = arbitrate_pus(demands.clone(), 2, 4);
        let wide = arbitrate_pus(demands, 8, 4);
        assert!(narrow.total_wait() > 0);
        assert_eq!(wide.total_wait(), 0);
        assert_eq!(narrow.busy_total, wide.busy_total);
    }
}
