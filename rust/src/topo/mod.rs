//! Shared-fabric resource/topology layer: multi-device, multi-tenant
//! simulation over contended CXL links.
//!
//! The paper evaluates each workload alone on one CCM; this layer scales
//! the same protocol engines to the deployments UDON and CXLMemUring
//! argue for — many concurrent streams sharing a pool of CCM devices
//! behind one CXL fabric. Three pieces:
//!
//! - [`DeviceCtx`] — the borrowed resource bundle every protocol engine
//!   runs against (host/CCM [`PuPool`]s, CXL.mem/CXL.io [`Link`]s). The
//!   engines in [`crate::protocol`] are *strategies over these borrowed
//!   resources*: `rp/bs/axle::run(w, cfg, &mut ctx)`. A fresh ctx per run
//!   reproduces the pre-refactor single-device timing bit for bit.
//! - [`Topology`] — N identical CCM devices described by a
//!   [`TopologySpec`] (per-device pools and links, optional shared
//!   upstream fabric link), plus tenant placement
//!   ([`Placement::RoundRobin`] / [`Placement::LeastLoaded`] /
//!   [`Placement::Pinned`]) and per-device contention accounting.
//! - [`tenant`] — the multi-tenant driver: K concurrent workload streams
//!   with deterministic open-loop arrivals, placed across devices;
//!   per-device link contention and shared-fabric serialization are
//!   arbitrated by [`fabric`] over the solo runs' wire traces.
//!
//! **Sharing model.** Each tenant gets its own protocol-visible device
//! resources — a fresh [`DeviceCtx`] per stream (command queue pair +
//! rings, the per-requestor state CXLMemUring's asynchronous pool-access
//! model assumes) — so a tenant's
//! solo timeline is simulated exactly by the existing engines. What
//! tenants *share* is the device's physical capacity: wire bandwidth
//! (the device's CXL.mem/CXL.io links and the optional upstream fabric
//! link) and **CCM PU time** (the device's processing-unit pool).
//! Contention is computed by deterministic replay arbitration of the
//! traced occupancies: wire traces through [`fabric::arbitrate_qos`]
//! under the configured [`QosSpec`] policy (FCFS / weighted round-robin
//! / deficit round-robin with bandwidth floors), and CCM lease windows
//! through [`fabric::arbitrate_pus`] (interval-merge accounting onto one
//! shared pool). Each tenant's slowdown decomposes into a wire shift and
//! a PU shift (see [`tenant::TenantRun`]).

pub mod fabric;
pub mod tenant;

pub use crate::config::{Placement, QosPolicy, QosSpec, TopologySpec};
// The closed-loop scheduler layers on top of this module; its grid sweep
// is re-exported here so the topology sweeps live side by side
// (`topo::sweep_tenant_grid` / `topo::sweep_sched_grid`).
pub use crate::sched::sweep_sched_grid;
pub use fabric::{
    arbitrate, arbitrate_pus, arbitrate_qos, ArbitrationOutcome, FabricMsg, PuDemand, PuOutcome,
    QosState,
};
pub use tenant::{run_tenants, sweep_tenant_grid, TenantReport, TenantRun, TenantSpec};

use crate::config::SimConfig;
use crate::cxl::Link;
use crate::sim::{Ps, PuPool};

/// The resource bundle one protocol run borrows: the host-side PU pool,
/// one device's CCM PU pool, and that device's two CXL channels.
///
/// Construction order and parameters match what the protocol engines
/// historically built internally, so `DeviceCtx::new(cfg)` + the
/// refactored engines reproduce the old output exactly.
#[derive(Debug)]
pub struct DeviceCtx {
    /// Host-side processing units (shared side of the interaction).
    pub host: PuPool,
    /// This device's CCM processing units.
    pub ccm: PuPool,
    /// This device's CXL.mem channel (launches, sync loads, flow control).
    pub mem: Link,
    /// This device's CXL.io channel (mailbox, DMA back-streaming).
    pub io: Link,
}

impl DeviceCtx {
    /// Fresh single-run resources for `cfg` (what each engine used to
    /// construct internally).
    pub fn new(cfg: &SimConfig) -> Self {
        Self {
            host: PuPool::new(cfg.host.num_pus),
            ccm: PuPool::new(cfg.ccm.num_pus),
            mem: Link::new(cfg.cxl_mem_rtt, cfg.cxl_bw_gbps),
            io: Link::new(cfg.cxl_io_rtt, cfg.cxl_bw_gbps),
        }
    }

    /// As [`DeviceCtx::new`] with occupancy tracing enabled on both links
    /// *and* the CCM PU pool (tracing never changes timing; see
    /// [`Link::enable_trace`] and [`PuPool::enable_trace`]). The host
    /// pool is deliberately untraced: host PUs are not a per-device
    /// shared resource in the topology model.
    pub fn traced(cfg: &SimConfig) -> Self {
        let mut ctx = Self::new(cfg);
        ctx.mem.enable_trace();
        ctx.io.enable_trace();
        ctx.ccm.enable_trace();
        ctx
    }
}

/// Per-device aggregate state: placement load plus the contention stats
/// the arbitration passes fold back in.
#[derive(Debug, Clone, Default)]
pub struct DeviceStats {
    /// Tenants placed on this device.
    pub tenants: u32,
    /// Accumulated solo service demand (placement load metric).
    pub load: Ps,
    /// Added completion delay on this device's CXL.mem link (sum of the
    /// per-tenant completion shifts; see `fabric::ArbitrationOutcome`).
    pub mem_wait: Ps,
    /// Added completion delay on this device's CXL.io link (same
    /// accounting as `mem_wait`).
    pub io_wait: Ps,
    /// Added completion delay on this device's shared CCM PU pool (sum of
    /// the per-tenant completion shifts; see `fabric::PuOutcome`).
    pub pu_wait: Ps,
    /// Busy-union of this device's shared CCM PU pool over the replay.
    pub pu_busy: Ps,
    /// Data bytes carried by this device's links.
    pub bytes: u64,
    /// Wire busy-union of this device's links (mem + io).
    pub link_busy: Ps,
}

/// N identical CCM devices built from one [`SimConfig`], with tenant
/// placement and per-device contention accounting. Per-tenant device
/// resources are materialized as fresh [`DeviceCtx`]s (devices are
/// homogeneous, so a ctx is exactly `DeviceCtx::new(config)`); the
/// per-device *shared* state lives here as [`DeviceStats`], folded in by
/// the tenant driver's arbitration passes.
#[derive(Debug)]
pub struct Topology {
    cfg: SimConfig,
    spec: TopologySpec,
    devices: Vec<DeviceStats>,
    rr_next: usize,
    /// Streams placed so far — the placement ordinal [`Placement::Pinned`]
    /// keys on (streams are placed in id order, so ordinal == stream id).
    placed: usize,
}

impl Topology {
    pub fn new(cfg: SimConfig, spec: TopologySpec) -> Self {
        assert!(spec.devices > 0, "topology needs at least one device");
        let devices = vec![DeviceStats::default(); spec.devices];
        Self { cfg, spec, devices, rr_next: 0, placed: 0 }
    }

    pub fn config(&self) -> &SimConfig {
        &self.cfg
    }

    pub fn spec(&self) -> &TopologySpec {
        &self.spec
    }

    pub fn num_devices(&self) -> usize {
        self.devices.len()
    }

    pub fn device(&self, d: u32) -> &DeviceStats {
        &self.devices[d as usize]
    }

    pub fn device_mut(&mut self, d: u32) -> &mut DeviceStats {
        &mut self.devices[d as usize]
    }

    pub fn devices(&self) -> &[DeviceStats] {
        &self.devices
    }

    /// Place one tenant with solo service demand `solo` under the spec's
    /// placement policy; returns the chosen device id and updates its
    /// load accounting.
    pub fn place(&mut self, solo: Ps) -> u32 {
        let ordinal = self.placed;
        self.placed += 1;
        let d = place_device(
            self.spec.placement,
            self.devices.len(),
            ordinal,
            |i| self.devices[i].load,
            &mut self.rr_next,
        );
        self.devices[d].tenants += 1;
        self.devices[d].load += solo;
        d as u32
    }
}

/// Pick the next placement target among `devices` devices: the
/// all-eligible convenience form of [`place_device_filtered`], kept for
/// the common no-fault path ([`Topology::place`], the closed-loop
/// scheduler's fault-free placement). Round-robin advances `rr_next`
/// exactly once; least-loaded takes the device with the smallest
/// accumulated `load` (ties broken by lowest id); pinned maps the
/// caller-supplied `ordinal` (stream / tenant id) straight to
/// `ordinal % devices` without touching any shared state. A thin
/// delegate — there is only **one** placement implementation, so the
/// filtered and unfiltered paths cannot drift (pinned by
/// `filtered_placement_with_all_eligible_matches_unfiltered`).
pub fn place_device(
    placement: Placement,
    devices: usize,
    ordinal: usize,
    load: impl Fn(usize) -> Ps,
    rr_next: &mut usize,
) -> usize {
    place_device_filtered(placement, devices, ordinal, |_| true, load, rr_next)
        .expect("placement over at least one device with every device eligible")
}

/// The single placement implementation, restricted to the devices
/// `eligible` admits — the closed-loop scheduler's fault-aware
/// placement point (requeue after a kill or timeout, admission-queue
/// redistribution after a permanent device failure). Returns `None`
/// when no device is eligible. With every device eligible the choice
/// matches the historical unfiltered [`place_device`] exactly:
/// round-robin takes `*rr_next % devices` and advances the cursor once;
/// least-loaded scans every eligible device with one shared
/// `min_by_key((load, id))` (ties always break to the lowest id — the
/// two pre-merge implementations used different scan styles for the
/// same rule, now unified); pinned probes `ordinal % D, ordinal % D +
/// 1, …` and takes the first eligible device (the home device when it
/// is alive, the nearest survivor in id order otherwise). Round-robin
/// probes at most one full rotation, advancing the cursor past
/// ineligible devices so the rotation stays deterministic as devices
/// come and go.
pub fn place_device_filtered(
    placement: Placement,
    devices: usize,
    ordinal: usize,
    eligible: impl Fn(usize) -> bool,
    load: impl Fn(usize) -> Ps,
    rr_next: &mut usize,
) -> Option<usize> {
    match placement {
        Placement::RoundRobin => {
            for _ in 0..devices {
                let d = *rr_next % devices;
                *rr_next += 1;
                if eligible(d) {
                    return Some(d);
                }
            }
            None
        }
        Placement::LeastLoaded => {
            (0..devices).filter(|&i| eligible(i)).min_by_key(|&i| (load(i), i))
        }
        Placement::Pinned => (0..devices).map(|k| (ordinal + k) % devices).find(|&d| eligible(d)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SimConfig;

    #[test]
    fn device_ctx_matches_engine_construction() {
        let cfg = SimConfig::m2ndp();
        let ctx = DeviceCtx::new(&cfg);
        assert_eq!(ctx.host.len(), cfg.host.num_pus);
        assert_eq!(ctx.ccm.len(), cfg.ccm.num_pus);
        assert_eq!(ctx.mem.rtt(), cfg.cxl_mem_rtt);
        assert_eq!(ctx.io.rtt(), cfg.cxl_io_rtt);
        assert!(ctx.mem.trace().is_empty() && ctx.io.trace().is_empty());
        assert!(ctx.ccm.trace().is_empty());
    }

    #[test]
    fn round_robin_placement_cycles() {
        let mut t = Topology::new(SimConfig::m2ndp(), TopologySpec::shared_fabric(3, 16.0));
        let got: Vec<u32> = (0..6).map(|_| t.place(100)).collect();
        assert_eq!(got, vec![0, 1, 2, 0, 1, 2]);
        assert!(t.devices().iter().all(|d| d.tenants == 2));
    }

    #[test]
    fn least_loaded_placement_fills_gaps() {
        let spec = TopologySpec::shared_fabric(2, 16.0).with_placement(Placement::LeastLoaded);
        let mut t = Topology::new(SimConfig::m2ndp(), spec);
        assert_eq!(t.place(100), 0); // both empty → lowest id
        assert_eq!(t.place(10), 1); // device 0 now loaded
        assert_eq!(t.place(10), 1); // device 1 (load 10) < device 0 (100)
        assert_eq!(t.place(10), 1); // still lighter (20 < 100)
        assert_eq!(t.device(0).tenants, 1);
        assert_eq!(t.device(1).tenants, 3);
    }

    #[test]
    fn pinned_placement_is_a_pure_function_of_the_stream_id() {
        let spec = TopologySpec::shared_fabric(3, 16.0).with_placement(Placement::Pinned);
        let mut t = Topology::new(SimConfig::m2ndp(), spec);
        // Load-independent: heavy early streams never push later ones off
        // their home device (contrast least_loaded_placement_fills_gaps).
        let got: Vec<u32> = [1_000_000, 10, 10, 10, 10, 10].iter().map(|&s| t.place(s)).collect();
        assert_eq!(got, vec![0, 1, 2, 0, 1, 2]);
        // Filtered probing falls back to the nearest eligible id.
        let mut rr = 0;
        let pick = place_device_filtered(Placement::Pinned, 3, 4, |d| d != 1, |_| 0, &mut rr);
        assert_eq!(pick, Some(2));
        assert_eq!(place_device_filtered(Placement::Pinned, 3, 4, |_| false, |_| 0, &mut rr), None);
    }

    #[test]
    fn filtered_placement_with_all_eligible_matches_unfiltered() {
        // The historical unfiltered behavior, pinned against the merged
        // single implementation: rr cycles advancing the cursor once per
        // call, least-loaded breaks load ties to the lowest id, pinned
        // is ordinal % devices.
        let loads = [30u64, 10, 10, 40];
        for placement in [Placement::RoundRobin, Placement::LeastLoaded, Placement::Pinned] {
            let (mut rr_a, mut rr_b) = (0usize, 0usize);
            for ordinal in 0..8 {
                let unfiltered =
                    place_device(placement, loads.len(), ordinal, |i| loads[i], &mut rr_a);
                let filtered = place_device_filtered(
                    placement,
                    loads.len(),
                    ordinal,
                    |_| true,
                    |i| loads[i],
                    &mut rr_b,
                );
                assert_eq!(Some(unfiltered), filtered, "{placement:?} ordinal {ordinal}");
                assert_eq!(rr_a, rr_b, "{placement:?} cursor after ordinal {ordinal}");
            }
        }
        // Least-loaded tie-break: devices 1 and 2 tie at load 10 — the
        // lowest id wins through both entry points.
        let mut rr = 0;
        assert_eq!(place_device(Placement::LeastLoaded, 4, 0, |i| loads[i], &mut rr), 1);
        assert_eq!(
            place_device_filtered(Placement::LeastLoaded, 4, 0, |_| true, |i| loads[i], &mut rr),
            Some(1)
        );
    }

    #[test]
    #[should_panic(expected = "at least one device")]
    fn zero_device_topology_rejected() {
        let spec = TopologySpec { devices: 0, ..TopologySpec::default() };
        let _ = Topology::new(SimConfig::m2ndp(), spec);
    }
}
