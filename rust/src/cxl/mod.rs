//! CXL link models: CXL.mem and CXL.io channels (§II, Table III).
//!
//! Both protocols ride the same PCIe PHY but with very different
//! transaction-layer costs (the paper's central trade-off):
//!
//! - **CXL.mem** — byte-addressable loads/stores in 64 B flits, low
//!   round-trip protocol latency (70 ns in Table III). Used by BS for
//!   kernel launch + synchronous result loads, and by AXLE for launches
//!   and flow-control messages.
//! - **CXL.io** — PCIe-semantics messages/DMA, higher round-trip latency
//!   (350 ns). Used by RP for mailbox commands + remote polling, and by
//!   AXLE for device-initiated back-streaming posted writes.
//!
//! A link serializes payload bytes at its effective bandwidth and adds
//! one-way (`rtt/2`) or full-RTT latency per message. Busy intervals feed
//! the paper's "data movement time" (T_D) union statistic.

use crate::sim::{transfer_ps, BusyTracker, Ps};

/// Message classes, used for accounting and tracing.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MsgKind {
    /// Kernel-launch store / descriptor write (CXL.mem).
    Launch,
    /// Synchronous result load (CXL.mem data flits).
    ResultLoad,
    /// Mailbox command or remote poll (CXL.io).
    Mailbox,
    /// Back-streamed DMA payload (CXL.io posted write).
    DmaPayload,
    /// DMA tail-update message (CXL.io).
    DmaTailUpdate,
    /// Flow-control head-index store (CXL.mem).
    FlowControl,
}

/// One data-bearing wire occupancy, recorded when tracing is enabled:
/// the serialization interval is `[start, start + transfer_ps(bytes))`.
///
/// Traces feed the topology layer's shared-fabric arbitration
/// ([`crate::topo::fabric`]): a tenant's solo-run wire starts are
/// replayed against other tenants' traffic to compute contention delay.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WireMsg {
    /// Time the wire began transmitting (post any same-link queueing).
    pub start: Ps,
    /// Payload bytes serialized.
    pub bytes: u64,
}

/// A unidirectional-bandwidth, latency-padded channel.
#[derive(Debug)]
pub struct Link {
    /// Round-trip protocol latency.
    rtt: Ps,
    /// Effective data bandwidth, GB/s.
    bw_gbps: f64,
    /// Serialization frontier: when the wire frees up.
    wire_free: Ps,
    busy: BusyTracker,
    msgs: u64,
    bytes: u64,
    /// Optional wire-occupancy trace (`None` ⇒ zero overhead). Only
    /// data-bearing messages (`bytes > 0`) are recorded — zero-byte
    /// control messages occupy no wire time.
    trace: Option<Vec<WireMsg>>,
}

impl Link {
    pub fn new(rtt: Ps, bw_gbps: f64) -> Self {
        Self {
            rtt,
            bw_gbps,
            wire_free: 0,
            busy: BusyTracker::new(),
            msgs: 0,
            bytes: 0,
            trace: None,
        }
    }

    /// Start recording data-bearing wire occupancies. Tracing never
    /// changes timing — it only observes the `(start, bytes)` pairs the
    /// link already computes.
    pub fn enable_trace(&mut self) {
        if self.trace.is_none() {
            self.trace = Some(Vec::new());
        }
    }

    /// Take the recorded trace (empty if tracing was never enabled).
    pub fn take_trace(&mut self) -> Vec<WireMsg> {
        self.trace.take().unwrap_or_default()
    }

    /// The recorded trace so far (empty slice if tracing is disabled).
    pub fn trace(&self) -> &[WireMsg] {
        self.trace.as_deref().unwrap_or(&[])
    }

    #[inline]
    pub fn rtt(&self) -> Ps {
        self.rtt
    }

    /// One-way protocol latency.
    #[inline]
    pub fn one_way(&self) -> Ps {
        self.rtt / 2
    }

    /// Send `bytes` at time `t`; returns the **arrival** time at the far
    /// side (serialization + one-way latency). Wire occupancy counts
    /// toward data-movement busy time only if `count_dm` (control
    /// messages are protocol overhead, not data movement).
    pub fn send(&mut self, t: Ps, bytes: u64, count_dm: bool) -> Ps {
        let ser = transfer_ps(bytes, self.bw_gbps);
        let start = t.max(self.wire_free);
        let wire_done = start + ser;
        self.wire_free = wire_done;
        self.msgs += 1;
        self.bytes += bytes;
        if bytes > 0 {
            if let Some(tr) = self.trace.as_mut() {
                tr.push(WireMsg { start, bytes });
            }
        }
        if count_dm && bytes > 0 {
            self.busy.record(start, wire_done + self.one_way());
        }
        wire_done + self.one_way()
    }

    /// Round-trip request/response of `bytes` payload returning at
    /// `send(t, bytes) + one_way` (e.g. a synchronous CXL.mem load: the
    /// request travels one way, data flits return).
    pub fn round_trip(&mut self, t: Ps, bytes: u64, count_dm: bool) -> Ps {
        // Request one-way, then data serialization + response one-way.
        let req_arrive = t + self.one_way();
        let ser = transfer_ps(bytes, self.bw_gbps);
        let start = req_arrive.max(self.wire_free);
        let done = start + ser;
        self.wire_free = done;
        self.msgs += 1;
        self.bytes += bytes;
        if bytes > 0 {
            if let Some(tr) = self.trace.as_mut() {
                tr.push(WireMsg { start, bytes });
            }
        }
        let arrive = done + self.one_way();
        if count_dm && bytes > 0 {
            self.busy.record(start, arrive);
        }
        arrive
    }

    /// Data-movement busy statistics (T_D accounting).
    #[inline]
    pub fn busy(&self) -> &BusyTracker {
        &self.busy
    }

    #[inline]
    pub fn messages(&self) -> u64 {
        self.msgs
    }

    #[inline]
    pub fn bytes(&self) -> u64 {
        self.bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::NS;

    #[test]
    fn zero_byte_message_costs_latency_only() {
        let mut l = Link::new(70 * NS, 32.0);
        assert_eq!(l.send(0, 0, false), 35 * NS);
    }

    #[test]
    fn serialization_adds_to_latency() {
        let mut l = Link::new(70 * NS, 32.0);
        // 64 B at 32 GB/s = 2 ns.
        assert_eq!(l.send(0, 64, true), 2 * NS + 35 * NS);
    }

    #[test]
    fn wire_serializes_back_to_back_messages() {
        let mut l = Link::new(0, 1.0); // 1 GB/s, no latency
        let a = l.send(0, 1_000_000, true); // 1 ms serialization
        let b = l.send(0, 1_000_000, true); // queued behind the first
        assert_eq!(a, 1_000_000 * NS);
        assert_eq!(b, 2_000_000 * NS);
    }

    #[test]
    fn round_trip_includes_both_directions() {
        let mut l = Link::new(70 * NS, 32.0);
        let back = l.round_trip(0, 64, true);
        assert_eq!(back, 35 * NS + 2 * NS + 35 * NS);
    }

    #[test]
    fn trace_records_wire_starts_without_changing_timing() {
        let mut plain = Link::new(70 * NS, 32.0);
        let mut traced = Link::new(70 * NS, 32.0);
        traced.enable_trace();
        for (t, b) in [(0, 64u64), (0, 0), (5 * NS, 4096), (5 * NS, 128)] {
            assert_eq!(plain.send(t, b, true), traced.send(t, b, true));
        }
        assert_eq!(plain.round_trip(10 * NS, 256, true), traced.round_trip(10 * NS, 256, true));
        assert!(plain.trace().is_empty());
        let tr = traced.take_trace();
        // Zero-byte control message is not traced.
        assert_eq!(tr.len(), 4);
        assert_eq!(tr[0], WireMsg { start: 0, bytes: 64 });
        // Starts are monotone and non-overlapping on the wire.
        for w in tr.windows(2) {
            assert!(w[1].start >= w[0].start + transfer_ps(w[0].bytes, 32.0));
        }
    }

    #[test]
    fn dm_accounting_only_when_requested() {
        let mut l = Link::new(70 * NS, 32.0);
        l.send(0, 4096, false);
        assert_eq!(l.busy().total(), 0);
        l.send(0, 4096, true);
        assert!(l.busy().total() > 0);
    }
}
