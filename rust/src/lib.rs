//! # AXLE — Coordinated Offloading with Asynchronous Back-Streaming in
//! # Computational Memory Systems (full-system reproduction)
//!
//! This crate reproduces the AXLE paper's system and evaluation, grown
//! toward shared-fabric, multi-tenant deployments:
//!
//! - a deterministic **discrete-event CCM simulator** standing in for the
//!   M²NDP testbed ([`sim`], [`cxl`], [`mem`], [`ring`]);
//! - a **resource/topology layer** ([`topo`]): [`DeviceCtx`] bundles one
//!   CCM device's PU pool and CXL.mem/CXL.io links with the host PU
//!   pool; [`Topology`] describes N devices behind an optional shared
//!   upstream fabric link ([`TopologySpec`]); the tenant driver
//!   ([`topo::tenant`]) runs K concurrent workload streams with
//!   deterministic open-loop arrivals, places them across devices
//!   (round-robin / least-loaded) and arbitrates link contention by
//!   deterministic wire-trace replay ([`topo::fabric`]) under a
//!   pluggable QoS policy ([`QosSpec`]: FCFS, weighted round-robin, or
//!   deficit round-robin with per-tenant bandwidth floors) plus CCM
//!   PU-pool sharing across co-located tenants (interval-merge replay of
//!   traced lease windows) — `axle tenants --devices D --streams K
//!   --qos wrr`;
//! - a **closed-loop offload scheduler** ([`sched`]) layered on the
//!   topology: K tenants submit requests against completion feedback
//!   (`depth`-bounded outstanding windows, per-device admission queues),
//!   an [`OffloadPolicy`] picks the protocol *per request* — `Static`
//!   pins today's behavior, `Heuristic` adapts to the workload's
//!   compute-vs-transfer ratio and observed link/PU occupancy, `Oracle`
//!   bounds it — and [`TopologySpec`] can mix **heterogeneous device
//!   classes** via per-device [`DeviceOverride`]s (`axle sched --streams
//!   K --policy heuristic --depth N`, `axle report fig19`);
//! - the four **partial-offloading mechanisms** ([`protocol`]) as
//!   strategies over borrowed [`DeviceCtx`] resources: Remote Polling,
//!   Bulk-Synchronous flow, AXLE's Asynchronous Back-Streaming and its
//!   interrupt-notification variant — single-device runs are
//!   bit-identical to the pre-topology engines;
//! - the nine **Table IV workloads** ([`workload`]);
//! - a **parallel sweep engine** ([`sweep`]): the evaluation matrix
//!   (workloads × protocols × config overrides) expanded from a
//!   declarative [`SweepSpec`], workload specs cached on
//!   `(annot, config fingerprint)`, jobs fanned out across a scoped
//!   work-stealing thread pool — results bit-identical to the serial
//!   path, several times faster on multicore hosts (`axle sweep --jobs N`);
//! - a **PJRT runtime** ([`runtime`]) that executes the offloaded
//!   functions' actual numerics from AOT-compiled JAX/Pallas artifacts
//!   (`artifacts/*.hlo.txt`) — Python never runs at simulation time;
//! - metrics and **figure/table regenerators** ([`metrics`], [`report`]),
//!   including the multi-tenant contention figure (`axle report fig17`);
//! - the top-level [`coordinator`] that runs workloads × protocols (and
//!   tenant mixes) and validates numerics alongside timing.
//!
//! Start with `examples/quickstart.rs`, or `cargo run --release --bin
//! axle-report -- all` to regenerate every paper figure.

pub mod config;
pub mod coordinator;
pub mod util;
pub mod cxl;
pub mod mem;
pub mod metrics;
pub mod protocol;
pub mod report;
pub mod ring;
pub mod runtime;
pub mod sched;
pub mod sim;
pub mod sweep;
pub mod topo;
pub mod trace;
pub mod workload;

pub use config::{
    poll_factors, DeviceOverride, Placement, PolicyKind, Protocol, QosPolicy, QosSpec, SchedPolicy,
    SchedSpec, SimConfig, TopologySpec,
};
pub use coordinator::Coordinator;
pub use metrics::RunMetrics;
#[allow(deprecated)]
pub use sched::run_sched;
pub use sched::{
    run, sweep_sched_grid, Decider, OffloadPolicy, RequestRun, SchedOutcome, SchedReport, SchedRun,
};
pub use sweep::{ConfigDelta, SweepSpec, WorkloadCache};
pub use topo::{DeviceCtx, TenantReport, TenantSpec, Topology};
pub use workload::{by_annotation, WorkloadSpec, ALL_ANNOTATIONS};
