//! Sweep executor: declarative spec → resolved jobs → scoped worker
//! pool with work stealing → results in deterministic spec order.
//!
//! Each job is a pure function of `(workload, protocol, config)`, so the
//! schedule (which worker runs which job, in what real-time order) can
//! never change a result — parallel output is bit-identical to the
//! serial path. Workers steal the next job index from a shared atomic
//! counter, which load-balances the very uneven per-job costs (the LLM
//! row costs orders of magnitude more than a single KNN query batch).

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};

use crate::config::{Protocol, SimConfig};
use crate::cxl::WireMsg;
use crate::metrics::RunMetrics;
use crate::sim::PuSpan;
use crate::protocol;
use crate::topo::DeviceCtx;
use crate::workload::WorkloadSpec;

use super::{ConfigDelta, WorkloadCache};

/// One point of a declarative sweep: a Table IV workload under one
/// protocol with a sparse config override.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct SweepPoint {
    pub annot: char,
    pub proto: Protocol,
    pub delta: ConfigDelta,
}

impl SweepPoint {
    pub fn new(annot: char, proto: Protocol, delta: ConfigDelta) -> Self {
        Self { annot, proto, delta }
    }
}

/// A declarative sweep: base config plus an ordered list of points.
/// Results always come back in `points` order.
#[derive(Debug, Clone)]
pub struct SweepSpec {
    pub base: SimConfig,
    pub points: Vec<SweepPoint>,
}

impl SweepSpec {
    pub fn new(base: SimConfig) -> Self {
        Self { base, points: Vec::new() }
    }

    /// Full cross product `workloads × protocols × deltas`, ordered with
    /// the workload as the outermost axis — for the identity delta this
    /// is exactly the serial `Coordinator::run_matrix_serial` order.
    pub fn matrix(
        base: SimConfig,
        workloads: &[char],
        protos: &[Protocol],
        deltas: &[ConfigDelta],
    ) -> Self {
        let mut spec = Self::new(base);
        spec.points.reserve(workloads.len() * protos.len() * deltas.len());
        for &annot in workloads {
            for &proto in protos {
                for &delta in deltas {
                    spec.points.push(SweepPoint { annot, proto, delta });
                }
            }
        }
        spec
    }

    /// Append one point.
    pub fn push(&mut self, annot: char, proto: Protocol, delta: ConfigDelta) {
        self.points.push(SweepPoint { annot, proto, delta });
    }

    pub fn len(&self) -> usize {
        self.points.len()
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    /// Execute on `jobs` worker threads (1 = run inline, serially).
    pub fn run(&self, jobs: usize) -> Vec<RunMetrics> {
        run_points(&self.base, &self.points, jobs)
    }
}

/// A fully resolved job: prebuilt spec + derived config, shared via
/// `Arc` across however many points reference them. Used directly for
/// sweeps over custom (non-Table IV) specs such as Fig. 3's single
/// attention kernels.
#[derive(Debug, Clone)]
pub struct SpecJob {
    pub w: Arc<WorkloadSpec>,
    pub proto: Protocol,
    pub cfg: Arc<SimConfig>,
}

/// Default worker count: the host's available parallelism.
pub fn available_jobs() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Expand `points` against `base` — deduplicating derived configs by
/// delta and workload builds through the [`WorkloadCache`] — then run
/// them on `jobs` workers. Results are in `points` order.
pub fn run_points(base: &SimConfig, points: &[SweepPoint], jobs: usize) -> Vec<RunMetrics> {
    let mut cfgs: HashMap<ConfigDelta, Arc<SimConfig>> = HashMap::new();
    let mut cache = WorkloadCache::new();
    let mut list: Vec<SpecJob> = Vec::with_capacity(points.len());
    for p in points {
        let cfg = cfgs.entry(p.delta).or_insert_with(|| Arc::new(p.delta.apply(base)));
        let w = cache.get(p.annot, cfg);
        list.push(SpecJob { w, proto: p.proto, cfg: Arc::clone(cfg) });
    }
    run_jobs(&list, jobs)
}

/// The shared fan-out core: map `f` over `list` on `jobs` workers with
/// work stealing over an atomic index; results return in `list` order
/// (`jobs = 1` runs inline on the calling thread). Both public runners
/// are thin wrappers so the pool/reorder machinery exists exactly once.
fn run_mapped<R: Send>(
    list: &[SpecJob],
    jobs: usize,
    f: impl Fn(&SpecJob) -> R + Sync,
) -> Vec<R> {
    let workers = jobs.max(1).min(list.len().max(1));
    if workers <= 1 {
        return list.iter().map(&f).collect();
    }

    let next = AtomicUsize::new(0);
    let (tx, rx) = mpsc::channel::<(usize, R)>();
    std::thread::scope(|s| {
        for _ in 0..workers {
            let tx = tx.clone();
            let next = &next;
            let f = &f;
            s.spawn(move || loop {
                // Work stealing: claim the next unclaimed job index.
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= list.len() {
                    break;
                }
                if tx.send((i, f(&list[i]))).is_err() {
                    break;
                }
            });
        }
    });
    drop(tx);

    let mut out: Vec<Option<R>> = Vec::with_capacity(list.len());
    out.resize_with(list.len(), || None);
    for (i, r) in rx {
        out[i] = Some(r);
    }
    out.into_iter().map(|r| r.expect("every sweep job reported a result")).collect()
}

/// Run prebuilt jobs on `jobs` workers; results are in `list` order and
/// bit-identical to running the list serially.
pub fn run_jobs(list: &[SpecJob], jobs: usize) -> Vec<RunMetrics> {
    run_mapped(list, jobs, |j| protocol::run(j.proto, &j.w, &j.cfg))
}

/// One job's result plus the occupancy traces of the device resources it
/// ran on (the tenant driver's raw material for contention arbitration).
#[derive(Debug, Clone)]
pub struct TracedRun {
    pub metrics: RunMetrics,
    /// CXL.mem data-bearing wire occupancies (solo timeline).
    pub mem_trace: Vec<WireMsg>,
    /// CXL.io data-bearing wire occupancies (solo timeline).
    pub io_trace: Vec<WireMsg>,
    /// CCM PU lease windows (solo timeline) — the raw material for
    /// PU-pool sharing across co-located tenants.
    pub ccm_trace: Vec<PuSpan>,
}

/// As [`run_jobs`], but each job runs on a fresh *traced* [`DeviceCtx`]
/// and returns its wire and CCM PU traces alongside the metrics. Tracing
/// never perturbs timing, so `metrics` is bit-identical to [`run_jobs`]'s.
/// Results are in `list` order regardless of worker count.
pub fn run_traced_jobs(list: &[SpecJob], jobs: usize) -> Vec<TracedRun> {
    run_mapped(list, jobs, |job| {
        let mut ctx = DeviceCtx::traced(&job.cfg);
        let metrics = protocol::run_on(job.proto, &job.w, &job.cfg, &mut ctx);
        TracedRun {
            metrics,
            mem_trace: ctx.mem.take_trace(),
            io_trace: ctx.io.take_trace(),
            ccm_trace: ctx.ccm.take_trace(),
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::poll_factors;

    #[test]
    fn available_jobs_is_positive() {
        assert!(available_jobs() >= 1);
    }

    #[test]
    fn matrix_order_is_workload_major() {
        let spec = SweepSpec::matrix(
            SimConfig::m2ndp(),
            &['a', 'b'],
            &[Protocol::Rp, Protocol::Bs],
            &[ConfigDelta::identity()],
        );
        let got: Vec<(char, Protocol)> = spec.points.iter().map(|p| (p.annot, p.proto)).collect();
        assert_eq!(
            got,
            vec![
                ('a', Protocol::Rp),
                ('a', Protocol::Bs),
                ('b', Protocol::Rp),
                ('b', Protocol::Bs),
            ]
        );
    }

    #[test]
    fn parallel_matches_serial_on_small_sweep() {
        let base = SimConfig::m2ndp();
        let mut spec = SweepSpec::new(base);
        for &a in &['a', 'f'] {
            for &p in &[Protocol::Bs, Protocol::Axle] {
                spec.push(a, p, ConfigDelta::identity());
                spec.push(a, p, ConfigDelta::identity().with_poll(poll_factors::P1));
            }
        }
        let serial = spec.run(1);
        let parallel = spec.run(4);
        assert_eq!(serial.len(), parallel.len());
        for (s, p) in serial.iter().zip(&parallel) {
            assert_eq!(s.to_json().to_string(), p.to_json().to_string());
        }
    }

    #[test]
    fn traced_jobs_match_untraced_metrics_and_capture_traces() {
        let base = SimConfig::m2ndp();
        let shared = std::sync::Arc::new(base.clone());
        let jobs: Vec<SpecJob> = [('a', Protocol::Bs), ('e', Protocol::Axle)]
            .iter()
            .map(|&(a, p)| SpecJob {
                w: std::sync::Arc::new(crate::workload::by_annotation(a, &base)),
                proto: p,
                cfg: std::sync::Arc::clone(&shared),
            })
            .collect();
        let plain = run_jobs(&jobs, 2);
        for workers in [1usize, 2] {
            let traced = run_traced_jobs(&jobs, workers);
            assert_eq!(traced.len(), plain.len());
            for (t, p) in traced.iter().zip(&plain) {
                assert_eq!(t.metrics.to_json().to_string(), p.to_json().to_string());
            }
            // BS moves data over CXL.mem; AXLE back-streams over CXL.io.
            assert!(!traced[0].mem_trace.is_empty());
            assert!(traced[0].io_trace.is_empty());
            assert!(!traced[1].io_trace.is_empty());
            // Every protocol executes CCM tasks: lease windows are traced.
            assert!(!traced[0].ccm_trace.is_empty());
            assert!(!traced[1].ccm_trace.is_empty());
        }
    }

    #[test]
    fn sweep_points_match_direct_protocol_runs() {
        let base = SimConfig::m2ndp();
        let mut spec = SweepSpec::new(base.clone());
        spec.push('f', Protocol::Rp, ConfigDelta::identity());
        spec.push('f', Protocol::Axle, ConfigDelta::identity().with_poll(poll_factors::P100));
        let ms = spec.run(2);
        let w = crate::workload::by_annotation('f', &base);
        let rp = protocol::run(Protocol::Rp, &w, &base);
        let axle_cfg = base.clone().with_poll(poll_factors::P100);
        let axle = protocol::run(Protocol::Axle, &w, &axle_cfg);
        assert_eq!(ms[0].to_json().to_string(), rp.to_json().to_string());
        assert_eq!(ms[1].to_json().to_string(), axle.to_json().to_string());
    }
}
