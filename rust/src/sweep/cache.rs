//! Workload-spec cache.
//!
//! `workload::by_annotation` compiles a Table IV row into a
//! [`WorkloadSpec`] — thousands of cost-model evaluations for the heavy
//! rows (see the `table4_workload_generation` bench). Sweep points
//! overwhelmingly share specs: spec generation reads only the hardware
//! half of the config (`host`, `ccm`, `cxl_bw_gbps` — see
//! [`SimConfig::workload_fingerprint`]), so a poll-factor or
//! streaming-factor sweep needs each workload built exactly once.

use std::collections::HashMap;
use std::sync::Arc;

use crate::config::{PuConfig, SimConfig};
use crate::workload::{self, WorkloadSpec};

/// Exact cache key: the verbatim bit patterns of every config field
/// workload generation reads (rather than a lossy hash of them), so a
/// key collision between distinct configs is impossible. Mirrors
/// [`SimConfig::workload_fingerprint`] — **keep both in sync** with
/// what `workload/` generators read.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
struct WorkloadKey {
    host: PuKey,
    ccm: PuKey,
    cxl_bw_bits: u64,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
struct PuKey {
    num_pus: usize,
    uthreads: usize,
    freq_bits: u64,
    flops_bits: u64,
    dram_channels: u32,
}

impl PuKey {
    fn of(p: &PuConfig) -> Self {
        Self {
            num_pus: p.num_pus,
            uthreads: p.uthreads,
            freq_bits: p.freq_ghz.to_bits(),
            flops_bits: p.flops_per_cycle.to_bits(),
            dram_channels: p.dram_channels,
        }
    }
}

impl WorkloadKey {
    fn of(cfg: &SimConfig) -> Self {
        Self {
            host: PuKey::of(&cfg.host),
            ccm: PuKey::of(&cfg.ccm),
            cxl_bw_bits: cfg.cxl_bw_gbps.to_bits(),
        }
    }
}

/// Memoizes workload generation on `(annot, generation-relevant config
/// fields)`. Specs are handed out as `Arc`s so parallel sweep jobs
/// share them without copies.
#[derive(Debug, Default)]
pub struct WorkloadCache {
    map: HashMap<(char, WorkloadKey), Arc<WorkloadSpec>>,
    hits: u64,
    misses: u64,
}

impl WorkloadCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// The spec for `annot` under `cfg`, building it on first use.
    pub fn get(&mut self, annot: char, cfg: &SimConfig) -> Arc<WorkloadSpec> {
        let key = (annot, WorkloadKey::of(cfg));
        if let Some(w) = self.map.get(&key) {
            self.hits += 1;
            return Arc::clone(w);
        }
        self.misses += 1;
        let w = Arc::new(workload::by_annotation(annot, cfg));
        self.map.insert(key, Arc::clone(&w));
        w
    }

    /// Distinct specs built so far.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Lookups served from the cache.
    pub fn hits(&self) -> u64 {
        self.hits
    }

    /// Lookups that had to build a spec.
    pub fn misses(&self) -> u64 {
        self.misses
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::poll_factors;

    #[test]
    fn shares_specs_across_protocol_knob_changes() {
        let base = SimConfig::m2ndp();
        let mut polled = base.clone();
        polled.axle.poll_interval = poll_factors::P100;
        let mut cache = WorkloadCache::new();
        let a = cache.get('a', &base);
        let b = cache.get('a', &polled);
        // Same underlying spec object: poll interval is simulation-time.
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(cache.misses(), 1);
        assert_eq!(cache.hits(), 1);
    }

    #[test]
    fn distinct_hardware_rebuilds() {
        let base = SimConfig::m2ndp();
        let reduced = SimConfig::reduced();
        let mut cache = WorkloadCache::new();
        let a = cache.get('a', &base);
        let b = cache.get('a', &reduced);
        assert!(!Arc::ptr_eq(&a, &b));
        assert_eq!(cache.len(), 2);
        assert_eq!(cache.misses(), 2);
    }

    #[test]
    fn cached_spec_matches_direct_generation() {
        let cfg = SimConfig::m2ndp();
        let mut cache = WorkloadCache::new();
        let cached = cache.get('e', &cfg);
        let direct = workload::by_annotation('e', &cfg);
        assert_eq!(cached.name, direct.name);
        assert_eq!(cached.iters.len(), direct.iters.len());
        assert_eq!(cached.total_ccm_tasks(), direct.total_ccm_tasks());
        assert_eq!(cached.total_result_bytes(), direct.total_result_bytes());
    }
}
