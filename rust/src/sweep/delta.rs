//! Sparse config overrides for sweep points.
//!
//! A [`ConfigDelta`] describes how one sweep point's configuration
//! differs from the sweep's base [`SimConfig`]. Deltas are tiny `Copy`
//! values with `Eq + Hash`, so the executor can deduplicate them and
//! clone the (much larger) `SimConfig` once per *distinct* delta
//! instead of once per sweep point.

use crate::config::{SchedPolicy, SfPolicy, SimConfig};
use crate::sim::Ps;

/// Sparse override set applied to a base [`SimConfig`]. `None` fields
/// keep the base value. Covers every knob the paper's figures sweep;
/// extend it (and [`ConfigDelta::apply`]) when a new axis appears.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq, Hash)]
pub struct ConfigDelta {
    /// AXLE host local-polling interval (Fig. 10's p1/p10/p100 axis).
    pub poll_interval: Option<Ps>,
    /// Streaming factor in bytes (Fig. 14 axis).
    pub streaming_factor_bytes: Option<u64>,
    /// Ring capacity in slots (Fig. 16 axis).
    pub dma_slot_capacity: Option<usize>,
    /// Fixed vs adaptive streaming factor (Fig. 14-ext axis).
    pub sf_policy: Option<SfPolicy>,
    /// Out-of-order streaming on/off (Fig. 15 axis).
    pub ooo_streaming: Option<bool>,
    /// Scheduler policy (Fig. 15 axis).
    pub sched: Option<SchedPolicy>,
    /// Duration-jitter seed.
    pub seed: Option<u64>,
}

impl ConfigDelta {
    /// The identity delta (every field inherited from the base).
    pub fn identity() -> Self {
        Self::default()
    }

    /// True when this delta changes nothing.
    pub fn is_identity(&self) -> bool {
        *self == Self::default()
    }

    pub fn with_poll(mut self, interval: Ps) -> Self {
        self.poll_interval = Some(interval);
        self
    }

    pub fn with_sf(mut self, bytes: u64) -> Self {
        self.streaming_factor_bytes = Some(bytes);
        self
    }

    pub fn with_capacity(mut self, slots: usize) -> Self {
        self.dma_slot_capacity = Some(slots);
        self
    }

    pub fn with_sf_policy(mut self, policy: SfPolicy) -> Self {
        self.sf_policy = Some(policy);
        self
    }

    pub fn with_ooo(mut self, on: bool) -> Self {
        self.ooo_streaming = Some(on);
        self
    }

    pub fn with_sched(mut self, sched: SchedPolicy) -> Self {
        self.sched = Some(sched);
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = Some(seed);
        self
    }

    /// Materialize the derived config: one clone of `base`, patched.
    pub fn apply(&self, base: &SimConfig) -> SimConfig {
        let mut cfg = base.clone();
        if let Some(p) = self.poll_interval {
            cfg.axle.poll_interval = p;
        }
        if let Some(sf) = self.streaming_factor_bytes {
            cfg.axle.streaming_factor_bytes = sf;
        }
        if let Some(cap) = self.dma_slot_capacity {
            cfg.axle.dma_slot_capacity = cap;
        }
        if let Some(pol) = self.sf_policy {
            cfg.axle.sf_policy = pol;
        }
        if let Some(ooo) = self.ooo_streaming {
            cfg.axle.ooo_streaming = ooo;
        }
        if let Some(s) = self.sched {
            cfg.sched = s;
        }
        if let Some(s) = self.seed {
            cfg.seed = s;
        }
        cfg
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::poll_factors;

    #[test]
    fn identity_applies_to_equal_fingerprint() {
        let base = SimConfig::m2ndp();
        let d = ConfigDelta::identity();
        assert!(d.is_identity());
        assert_eq!(d.apply(&base).fingerprint(), base.fingerprint());
    }

    #[test]
    fn apply_patches_exactly_the_set_fields() {
        let base = SimConfig::m2ndp();
        let d = ConfigDelta::identity()
            .with_poll(poll_factors::P100)
            .with_sf(2048)
            .with_capacity(625)
            .with_sf_policy(SfPolicy::Adaptive)
            .with_ooo(false)
            .with_sched(SchedPolicy::Fifo)
            .with_seed(99);
        assert!(!d.is_identity());
        let cfg = d.apply(&base);
        assert_eq!(cfg.axle.poll_interval, poll_factors::P100);
        assert_eq!(cfg.axle.streaming_factor_bytes, 2048);
        assert_eq!(cfg.axle.dma_slot_capacity, 625);
        assert_eq!(cfg.axle.sf_policy, SfPolicy::Adaptive);
        assert!(!cfg.axle.ooo_streaming);
        assert_eq!(cfg.sched, SchedPolicy::Fifo);
        assert_eq!(cfg.seed, 99);
        // Untouched fields inherit.
        assert_eq!(cfg.host.num_pus, base.host.num_pus);
        assert_eq!(cfg.cxl_mem_rtt, base.cxl_mem_rtt);
        // Delta-equal points would share this derived config.
        let d2 = ConfigDelta::identity()
            .with_poll(poll_factors::P100)
            .with_sf(2048)
            .with_capacity(625)
            .with_sf_policy(SfPolicy::Adaptive)
            .with_ooo(false)
            .with_sched(SchedPolicy::Fifo)
            .with_seed(99);
        assert_eq!(d, d2);
    }
}
