//! Parallel sweep engine: the evaluation matrix as a first-class,
//! cached, multicore pipeline.
//!
//! The paper's evaluation is a large matrix of *independent,
//! deterministic* simulations — 9 Table IV workloads × 4 protocols ×
//! poll-factor / streaming-factor / capacity / scheduler sweeps. This
//! module turns that matrix into data:
//!
//! - [`ConfigDelta`] ([`delta`]): a sparse, hashable override set applied
//!   to a base [`SimConfig`](crate::config::SimConfig). One derived
//!   config is materialized per *distinct* delta, not per sweep point,
//!   so a 9-workload poll sweep clones the config 3 times, not 27.
//! - [`WorkloadCache`] ([`cache`]): memoizes `workload::by_annotation`
//!   on `(annot, exact generation-relevant config fields)` (the lossy
//!   `SimConfig::workload_fingerprint()` exists for labelling) — spec
//!   generation is measurably hot (see `table4_workload_generation` in
//!   `benches/figures.rs`) and most sweep points share specs.
//! - [`SweepSpec`] / [`run_points`] / [`run_jobs`] ([`exec`]): expand a
//!   declarative spec into jobs and fan them out across a
//!   `std::thread::scope` worker pool with work stealing over an atomic
//!   job index. Results return in **deterministic spec order** and are
//!   bit-identical to the serial path (each simulation is a pure
//!   function of `(workload, protocol, config)`), which
//!   `tests/sweep_determinism.rs` asserts for jobs ∈ {1, 2, 8}.
//!
//! The coordinator's matrix, every `report::fig*` generator, the `axle
//! sweep` CLI subcommand and `benches/figures.rs` all run on this
//! engine.

pub mod cache;
pub mod delta;
pub mod exec;

pub use cache::WorkloadCache;
pub use delta::ConfigDelta;
pub use exec::{
    available_jobs, run_jobs, run_points, run_traced_jobs, SpecJob, SweepPoint, SweepSpec,
    TracedRun,
};
