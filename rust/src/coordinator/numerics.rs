//! Numerics validation: execute each workload's CCM-half and host-half
//! artifacts through PJRT and check the results against straight Rust
//! reference computations.
//!
//! This closes the loop across all three layers: the Pallas kernels (L1)
//! were checked against jnp oracles at build time; here the *lowered HLO*
//! the Rust coordinator actually runs is checked against an independent
//! Rust implementation — any lowering, manifest, or marshaling bug fails
//! loudly.

use anyhow::{anyhow, Result};

use crate::runtime::{literal_f32, literal_i32, prand_f32, prand_i32, Runtime};

/// Outcome of one workload's numerics validation.
#[derive(Debug, Clone)]
pub struct NumericsReport {
    pub annot: char,
    pub artifacts: Vec<String>,
    pub checks: u64,
    pub max_rel_err: f64,
}

fn rel_err(got: f32, want: f32) -> f64 {
    let denom = want.abs().max(1.0) as f64;
    ((got - want).abs() as f64) / denom
}

/// Validate workload `annot`; see module docs.
pub fn validate(rt: &mut Runtime, annot: char) -> Result<NumericsReport> {
    match annot {
        'a' => knn(rt, "knn_a", 2048, 128),
        'b' => knn(rt, "knn_b", 1024, 256),
        'c' => knn(rt, "knn_c", 512, 512),
        'd' => sssp(rt),
        'e' => pagerank(rt),
        'f' | 'g' => ssb(rt, annot),
        'h' => llm(rt),
        'i' => dlrm(rt),
        _ => Err(anyhow!("unknown annotation {annot:?}")),
    }
}

// ---------------------------------------------------------------------
// KNN: distances vs Rust; top-k must pick the true nearest rows sorted.
// ---------------------------------------------------------------------

fn knn(rt: &mut Runtime, prefix: &str, dim: usize, rows: usize) -> Result<NumericsReport> {
    let q = prand_f32(dim, 11);
    let db = prand_f32(rows * dim, 12);
    let out = rt.execute_f32(&format!("{prefix}_ccm"), &[&q, &db])?;
    let dists = &out[0];

    let mut max_err = 0.0f64;
    let mut want: Vec<f32> = Vec::with_capacity(rows);
    for r in 0..rows {
        let w: f32 = (0..dim)
            .map(|j| {
                let d = db[r * dim + j] - q[j];
                d * d
            })
            .sum();
        max_err = max_err.max(rel_err(dists[r], w));
        want.push(w);
    }
    if max_err > 1e-3 {
        return Err(anyhow!("{prefix}_ccm distance error {max_err}"));
    }

    // Host half: top-k over the CCM's back-streamed distances.
    let host = rt.execute_f32(&format!("{prefix}_host"), &[dists])?;
    let (vals, idx) = (&host[0], &host[1]);
    let k = vals.len();
    let mut order: Vec<usize> = (0..rows).collect();
    order.sort_by(|&a, &b| want[a].total_cmp(&want[b]));
    for i in 0..k {
        let got_i = idx[i] as usize;
        // Equal distances may order arbitrarily; compare by value.
        max_err = max_err.max(rel_err(vals[i], want[order[i]]));
        max_err = max_err.max(rel_err(want[got_i], want[order[i]]));
    }
    if max_err > 1e-3 {
        return Err(anyhow!("{prefix}_host top-k error {max_err}"));
    }
    Ok(NumericsReport {
        annot: match prefix {
            "knn_a" => 'a',
            "knn_b" => 'b',
            _ => 'c',
        },
        artifacts: vec![format!("{prefix}_ccm"), format!("{prefix}_host")],
        checks: (rows + 2 * k) as u64,
        max_rel_err: max_err,
    })
}

// ---------------------------------------------------------------------
// PageRank: one CCM+host step on an RMAT graph vs Rust reference.
// ---------------------------------------------------------------------

fn graph_scale(rt: &Runtime, name: &str) -> Result<(usize, usize)> {
    let meta = &rt.entry(name)?.meta;
    let v = meta.get("v").as_usize().ok_or_else(|| anyhow!("manifest meta.v"))?;
    let e = meta.get("e").as_usize().ok_or_else(|| anyhow!("manifest meta.e"))?;
    Ok((v, e))
}

fn pagerank(rt: &mut Runtime) -> Result<NumericsReport> {
    let (v, e) = graph_scale(rt, "pagerank_ccm")?;
    let g = crate::workload::graph::SynthGraph::rmat(v, e, 99);
    let src: Vec<i32> = g.src.iter().map(|&x| x as i32).collect();
    let dst: Vec<i32> = g.dst.iter().map(|&x| x as i32).collect();
    let ranks: Vec<f32> = vec![1.0 / v as f32; v];
    let inv_deg: Vec<f32> = g.out_deg.iter().map(|&d| 1.0 / (d.max(1) as f32)).collect();

    // CCM half: per-edge contributions.
    let contrib = {
        let lits = vec![
            literal_f32(&ranks, &[v])?,
            literal_f32(&inv_deg, &[v])?,
            literal_i32(&src, &[e])?,
        ];
        let out = rt.execute("pagerank_ccm", &lits)?;
        out[0].to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?
    };
    let mut max_err = 0.0f64;
    for i in 0..e {
        let s = src[i] as usize;
        let want = ranks[s] * inv_deg[s];
        max_err = max_err.max(rel_err(contrib[i], want));
    }
    if max_err > 1e-4 {
        return Err(anyhow!("pagerank_ccm contribution error {max_err}"));
    }

    // Host half: segment sum + damped update.
    let new_ranks = {
        let lits = vec![literal_f32(&contrib, &[e])?, literal_i32(&dst, &[e])?];
        let out = rt.execute("pagerank_host", &lits)?;
        out[0].to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?
    };
    let mut want = vec![0.0f32; v];
    for i in 0..e {
        want[dst[i] as usize] += contrib[i];
    }
    let damping = 0.85f32;
    for x in want.iter_mut() {
        *x = (1.0 - damping) / v as f32 + damping * *x;
    }
    for i in 0..v {
        max_err = max_err.max(rel_err(new_ranks[i], want[i]));
    }
    if max_err > 1e-3 {
        return Err(anyhow!("pagerank_host update error {max_err}"));
    }
    Ok(NumericsReport {
        annot: 'e',
        artifacts: vec!["pagerank_ccm".into(), "pagerank_host".into()],
        checks: (e + v) as u64,
        max_rel_err: max_err,
    })
}

// ---------------------------------------------------------------------
// SSSP: one relaxation round vs Rust Bellman-Ford step.
// ---------------------------------------------------------------------

fn sssp(rt: &mut Runtime) -> Result<NumericsReport> {
    let (v, e) = graph_scale(rt, "sssp_ccm")?;
    let g = crate::workload::graph::SynthGraph::rmat(v, e, 123);
    let src: Vec<i32> = g.src.iter().map(|&x| x as i32).collect();
    let dst: Vec<i32> = g.dst.iter().map(|&x| x as i32).collect();
    let w: Vec<f32> = prand_f32(e, 5).iter().map(|x| x.abs() + 0.01).collect();
    let inf = 1e9f32;
    let mut dist = vec![inf; v];
    dist[0] = 0.0;
    // Seed a few more sources so one round relaxes many edges.
    for i in 1..8 {
        dist[(i * 37) % v] = i as f32;
    }
    let ones = vec![1.0f32; v];

    let cand = {
        let lits = vec![
            literal_f32(&dist, &[v])?,
            literal_f32(&ones, &[v])?,
            literal_i32(&src, &[e])?,
            literal_f32(&w, &[e])?,
        ];
        let out = rt.execute("sssp_ccm", &lits)?;
        out[0].to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?
    };
    let mut max_err = 0.0f64;
    for i in 0..e {
        let want = dist[src[i] as usize] + w[i];
        max_err = max_err.max(rel_err(cand[i], want));
    }
    if max_err > 1e-4 {
        return Err(anyhow!("sssp_ccm candidate error {max_err}"));
    }

    let new_dist = {
        let lits = vec![
            literal_f32(&cand, &[e])?,
            literal_i32(&dst, &[e])?,
            literal_f32(&dist, &[v])?,
        ];
        let out = rt.execute("sssp_host", &lits)?;
        out[0].to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?
    };
    let mut want = dist.clone();
    for i in 0..e {
        let d = dst[i] as usize;
        want[d] = want[d].min(cand[i]);
    }
    let mut checks = e as u64;
    for i in 0..v {
        max_err = max_err.max(rel_err(new_dist[i], want[i]));
        // Monotonicity: relaxation never increases distances.
        if new_dist[i] > dist[i] * 1.0001 {
            return Err(anyhow!("sssp_host increased dist[{i}]"));
        }
        checks += 1;
    }
    if max_err > 1e-3 {
        return Err(anyhow!("sssp_host min-merge error {max_err}"));
    }
    Ok(NumericsReport {
        annot: 'd',
        artifacts: vec!["sssp_ccm".into(), "sssp_host".into()],
        checks,
        max_rel_err: max_err,
    })
}

// ---------------------------------------------------------------------
// SSB Q1: marks vs Rust predicate; revenue vs Rust aggregation.
// ---------------------------------------------------------------------

fn ssb(rt: &mut Runtime, annot: char) -> Result<NumericsReport> {
    let n = rt.entry("ssb_q1_ccm")?.inputs[0].shape[0];
    let q = if annot == 'f' {
        crate::workload::olap::SsbQuery::Q1_1
    } else {
        crate::workload::olap::SsbQuery::Q1_2
    };
    let (db, qb) = q.bounds();
    // Synthetic lineorder columns: integer-valued discounts 0..=10,
    // quantities 1..=50, prices.
    let discount: Vec<f32> = prand_i32(n, 11, 21).iter().map(|&x| x as f32).collect();
    let quantity: Vec<f32> = prand_i32(n, 50, 22).iter().map(|&x| (x + 1) as f32).collect();
    let price: Vec<f32> = prand_f32(n, 23).iter().map(|x| (x + 1.5) * 1000.0).collect();

    let marks = {
        let out = rt.execute_f32(
            "ssb_q1_ccm",
            &[&discount, &quantity, &[db[0], db[1]], &[qb[0], qb[1]]],
        )?;
        out.into_iter().next().unwrap()
    };
    let mut max_err = 0.0f64;
    let mut want_marks = Vec::with_capacity(n);
    for i in 0..n {
        let m = (discount[i] >= db[0]
            && discount[i] <= db[1]
            && quantity[i] >= qb[0]
            && quantity[i] <= qb[1]) as i32 as f32;
        if marks[i] != m {
            return Err(anyhow!("ssb mark mismatch at {i}: got {} want {m}", marks[i]));
        }
        want_marks.push(m);
    }

    let revenue = {
        let out = rt.execute_f32("ssb_q1_host", &[&marks, &price, &discount])?;
        out[0][0]
    };
    let want_rev: f64 = (0..n)
        .map(|i| (want_marks[i] * price[i] * discount[i]) as f64)
        .sum();
    max_err = max_err.max((revenue as f64 - want_rev).abs() / want_rev.abs().max(1.0));
    if max_err > 1e-3 {
        return Err(anyhow!("ssb revenue error {max_err}: got {revenue}, want {want_rev}"));
    }
    Ok(NumericsReport {
        annot,
        artifacts: vec!["ssb_q1_ccm".into(), "ssb_q1_host".into()],
        checks: n as u64 + 1,
        max_rel_err: max_err,
    })
}

// ---------------------------------------------------------------------
// LLM: attention block vs Rust reference implementation; MLP sanity.
// ---------------------------------------------------------------------

fn llm(rt: &mut Runtime) -> Result<NumericsReport> {
    let entry = rt.entry("llm_attn_ccm")?.clone();
    let hidden = entry.inputs[0].shape[1];
    let (heads, tokens, hd) = (
        entry.inputs[1].shape[0],
        entry.inputs[1].shape[1],
        entry.inputs[1].shape[2],
    );
    let scale = 0.05f32;
    let x: Vec<f32> = prand_f32(hidden, 31).iter().map(|v| v * 0.1).collect();
    let kc: Vec<f32> = prand_f32(heads * tokens * hd, 32).iter().map(|v| v * 0.1).collect();
    let vc: Vec<f32> = prand_f32(heads * tokens * hd, 33).iter().map(|v| v * 0.1).collect();
    let wqkv: Vec<f32> = prand_f32(hidden * 3 * hidden, 34).iter().map(|v| v * scale).collect();
    let wo: Vec<f32> = prand_f32(hidden * hidden, 35).iter().map(|v| v * scale).collect();
    let ln_g = vec![1.0f32; hidden];
    let ln_b = vec![0.0f32; hidden];

    let out = rt.execute_f32(
        "llm_attn_ccm",
        &[&x, &kc, &vc, &wqkv, &wo, &ln_g, &ln_b],
    )?;
    let got = &out[0];
    let want = attention_block_ref(&x, &kc, &vc, &wqkv, &wo, hidden, heads, tokens, hd);
    let mut max_err = 0.0f64;
    for i in 0..hidden {
        max_err = max_err.max(rel_err(got[i], want[i]));
    }
    if max_err > 5e-3 {
        return Err(anyhow!("llm_attn_ccm error {max_err}"));
    }

    // Host MLP: sanity (finite, residual-shaped).
    let ffn = rt.entry("llm_mlp_host")?.inputs[1].shape[1];
    let w1: Vec<f32> = prand_f32(hidden * ffn, 36).iter().map(|v| v * scale).collect();
    let b1 = vec![0.0f32; ffn];
    let w2: Vec<f32> = prand_f32(ffn * hidden, 37).iter().map(|v| v * scale).collect();
    let b2 = vec![0.0f32; hidden];
    let mlp = rt.execute_f32("llm_mlp_host", &[got, &w1, &b1, &w2, &b2])?;
    if !mlp[0].iter().all(|v| v.is_finite()) {
        return Err(anyhow!("llm_mlp_host produced non-finite values"));
    }
    Ok(NumericsReport {
        annot: 'h',
        artifacts: vec!["llm_attn_ccm".into(), "llm_mlp_host".into()],
        checks: (hidden * 2) as u64,
        max_rel_err: max_err,
    })
}

/// Straight-Rust reference of the attention block (layernorm → qkv →
/// per-head SDPA → out proj → residual), mirroring `model.attention_block_ccm`.
#[allow(clippy::too_many_arguments)]
fn attention_block_ref(
    x: &[f32],
    kc: &[f32],
    vc: &[f32],
    wqkv: &[f32],
    wo: &[f32],
    hidden: usize,
    heads: usize,
    tokens: usize,
    hd: usize,
) -> Vec<f32> {
    // LayerNorm.
    let mu: f32 = x.iter().sum::<f32>() / hidden as f32;
    let var: f32 = x.iter().map(|v| (v - mu) * (v - mu)).sum::<f32>() / hidden as f32;
    let ln: Vec<f32> = x.iter().map(|v| (v - mu) / (var + 1e-5).sqrt()).collect();
    // q = ln @ wqkv[:, :hidden].
    let mut q = vec![0.0f32; hidden];
    for (j, qj) in q.iter_mut().enumerate() {
        let mut acc = 0.0f32;
        for i in 0..hidden {
            acc += ln[i] * wqkv[i * 3 * hidden + j];
        }
        *qj = acc;
    }
    // Per-head attention over the cache.
    let mut attn = vec![0.0f32; hidden];
    let scale = 1.0 / (hd as f32).sqrt();
    for h in 0..heads {
        let qh = &q[h * hd..(h + 1) * hd];
        let mut scores = vec![0.0f32; tokens];
        for t in 0..tokens {
            let base = h * tokens * hd + t * hd;
            scores[t] = (0..hd).map(|j| kc[base + j] * qh[j]).sum::<f32>() * scale;
        }
        let m = scores.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut p: Vec<f32> = scores.iter().map(|s| (s - m).exp()).collect();
        let sum: f32 = p.iter().sum();
        p.iter_mut().for_each(|v| *v /= sum);
        for j in 0..hd {
            attn[h * hd + j] = (0..tokens)
                .map(|t| p[t] * vc[h * tokens * hd + t * hd + j])
                .sum();
        }
    }
    // Out projection + residual.
    let mut out = vec![0.0f32; hidden];
    for (j, oj) in out.iter_mut().enumerate() {
        let mut acc = 0.0f32;
        for i in 0..hidden {
            acc += attn[i] * wo[i * hidden + j];
        }
        *oj = x[j] + acc;
    }
    out
}

// ---------------------------------------------------------------------
// DLRM: SLS vs Rust gather-sum; host MLP output in sigmoid range.
// ---------------------------------------------------------------------

fn dlrm(rt: &mut Runtime) -> Result<NumericsReport> {
    let e = rt.entry("dlrm_ccm")?.clone();
    let (vocab, dim) = (e.inputs[0].shape[0], e.inputs[0].shape[1]);
    let (batch, lookups) = (e.inputs[1].shape[0], e.inputs[1].shape[1]);
    let table = prand_f32(vocab * dim, 41);
    let idx = prand_i32(batch * lookups, vocab as i32, 42);

    let pooled = {
        let lits = vec![
            literal_f32(&table, &[vocab, dim])?,
            literal_i32(&idx, &[batch, lookups])?,
        ];
        let out = rt.execute("dlrm_ccm", &lits)?;
        out[0].to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?
    };
    let mut max_err = 0.0f64;
    for b in 0..batch {
        for d in 0..dim {
            let want: f32 = (0..lookups)
                .map(|l| table[idx[b * lookups + l] as usize * dim + d])
                .sum();
            max_err = max_err.max(rel_err(pooled[b * dim + d], want));
        }
    }
    if max_err > 1e-3 {
        return Err(anyhow!("dlrm_ccm SLS error {max_err}"));
    }

    let dense = prand_f32(batch * dim, 43);
    let w = prand_f32(2 * dim, 44);
    let out = rt.execute_f32("dlrm_host", &[&pooled, &dense, &w])?;
    // Sigmoid range [0, 1]; saturated logits legitimately hit the ends in f32.
    if !out[0].iter().all(|v| v.is_finite() && (0.0..=1.0).contains(v)) {
        return Err(anyhow!("dlrm_host sigmoid out of range"));
    }
    Ok(NumericsReport {
        annot: 'i',
        artifacts: vec!["dlrm_ccm".into(), "dlrm_host".into()],
        checks: (batch * dim) as u64,
        max_rel_err: max_err,
    })
}
