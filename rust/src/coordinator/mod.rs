//! Top-level coordinator: runs Table IV workloads under the offloading
//! protocols, and validates the offloaded functions' numerics through the
//! PJRT artifacts alongside the timing simulation.
//!
//! This is the leader process of the three-layer stack: it owns the
//! simulation configs, compiles workload specs, drives the protocol
//! engines, and (optionally) executes the AOT artifacts so that a run is
//! both *timed* (discrete-event simulation at paper scale) and
//! *functionally verified* (real kernel outputs at exec scale).

pub mod numerics;

use anyhow::Result;

use crate::config::{Protocol, SchedSpec, SimConfig, TopologySpec};
use crate::metrics::RunMetrics;
use crate::protocol;
use crate::runtime::Runtime;
use crate::sched::{self, SchedReport};
use crate::sweep::{self, ConfigDelta, SweepSpec};
use crate::topo::{self, TenantReport, TenantSpec};
use crate::workload::{self, WorkloadSpec};

pub use numerics::NumericsReport;

/// What [`Coordinator::run_nonstationary_scenario`] produces: the same
/// degraded, load-shifted run under all three adaptive deciders, plus
/// the degradation window. The acceptance assertions (learned strictly
/// beats heuristic, stays within bound of oracle) live in
/// `rust/tests/sched_regression.rs`.
pub struct NonstationaryOutcome {
    pub learned: SchedReport,
    pub heuristic: SchedReport,
    pub oracle: SchedReport,
    /// Degradation onset instant.
    pub at: crate::sim::Ps,
    /// Degradation window end (past every run's completion).
    pub until: crate::sim::Ps,
}

/// Coordinates workload execution across protocols and the PJRT runtime.
pub struct Coordinator {
    cfg: SimConfig,
    runtime: Option<Runtime>,
}

impl Coordinator {
    pub fn new(cfg: SimConfig) -> Self {
        Self { cfg, runtime: None }
    }

    /// Attach the AOT artifact runtime (enables numerics validation).
    pub fn with_artifacts(mut self, dir: impl AsRef<std::path::Path>) -> Result<Self> {
        self.runtime = Some(Runtime::new(dir)?);
        Ok(self)
    }

    pub fn config(&self) -> &SimConfig {
        &self.cfg
    }

    pub fn set_config(&mut self, cfg: SimConfig) {
        self.cfg = cfg;
    }

    /// Build the Table IV workload for `annot` under the current config.
    pub fn workload(&self, annot: char) -> WorkloadSpec {
        workload::by_annotation(annot, &self.cfg)
    }

    /// Run one workload under one protocol.
    pub fn run(&self, annot: char, proto: Protocol) -> RunMetrics {
        let w = self.workload(annot);
        protocol::run(proto, &w, &self.cfg)
    }

    /// Run a prebuilt spec under one protocol (custom workloads).
    pub fn run_spec(&self, w: &WorkloadSpec, proto: Protocol) -> RunMetrics {
        protocol::run(proto, w, &self.cfg)
    }

    /// Run every Table IV workload under every requested protocol.
    ///
    /// Fans out across all available cores through the [`crate::sweep`]
    /// engine; results come back in deterministic (workload, protocol)
    /// order, bit-identical to [`Coordinator::run_matrix_serial`].
    pub fn run_matrix(&self, protos: &[Protocol]) -> Vec<RunMetrics> {
        self.run_matrix_jobs(protos, sweep::available_jobs())
    }

    /// [`Coordinator::run_matrix`] with an explicit worker count
    /// (`jobs = 1` runs inline on the calling thread).
    pub fn run_matrix_jobs(&self, protos: &[Protocol], jobs: usize) -> Vec<RunMetrics> {
        SweepSpec::matrix(
            self.cfg.clone(),
            &workload::ALL_ANNOTATIONS,
            protos,
            &[ConfigDelta::identity()],
        )
        .run(jobs)
    }

    /// The original single-threaded reference path, kept as the
    /// determinism baseline the sweep executor is tested against
    /// (`tests/sweep_determinism.rs`).
    pub fn run_matrix_serial(&self, protos: &[Protocol]) -> Vec<RunMetrics> {
        let mut out = Vec::new();
        for &a in &workload::ALL_ANNOTATIONS {
            for &p in protos {
                out.push(self.run(a, p));
            }
        }
        out
    }

    /// Run a multi-tenant mix over a shared-fabric topology: K concurrent
    /// streams with open-loop arrivals placed across `topo.devices`
    /// devices, link/fabric contention arbitrated deterministically under
    /// `topo.qos` (FCFS / WRR / DRR — see [`crate::config::QosSpec`]) and
    /// CCM PU-pool contention charged by interval-merge replay (see
    /// [`crate::topo::tenant`]). Solo simulations fan out across all
    /// available cores.
    pub fn run_tenants(&self, topo: &TopologySpec, tenants: &TenantSpec) -> TenantReport {
        self.run_tenants_jobs(topo, tenants, sweep::available_jobs())
    }

    /// [`Coordinator::run_tenants`] with an explicit worker count.
    pub fn run_tenants_jobs(
        &self,
        topo: &TopologySpec,
        tenants: &TenantSpec,
        jobs: usize,
    ) -> TenantReport {
        topo::run_tenants(&self.cfg, topo, tenants, jobs)
    }

    /// Run a closed-loop scheduling scenario: K tenants submitting
    /// requests against completion feedback over `topo.devices` devices
    /// (possibly heterogeneous via per-device overrides), placement and
    /// offload protocol chosen per request by `spec.policy`'s decider —
    /// see [`crate::sched`]. Equivalent to `sched::run(&SchedRun::new(
    /// coordinator.config(), topo, spec))`.
    #[deprecated(note = "use sched::run with a SchedRun options struct")]
    pub fn run_sched(&self, topo: &TopologySpec, spec: &SchedSpec) -> SchedReport {
        sched::run(&sched::SchedRun::new(&self.cfg, topo, spec)).report
    }

    /// Deprecated wrapper over [`crate::sched::run`]; kept one release.
    #[deprecated(note = "use sched::run with a SchedRun options struct")]
    pub fn run_sched_jobs(
        &self,
        topo: &TopologySpec,
        spec: &SchedSpec,
        jobs: usize,
    ) -> SchedReport {
        sched::run(&sched::SchedRun::new(&self.cfg, topo, spec).with_jobs(jobs)).report
    }

    /// Canned fault-injection scenario (`axle scenario`, the CI smoke):
    /// K closed-loop tenants over the strong+weak two-device topology,
    /// with the strong device failing **permanently mid-run**. The kill
    /// instant is derived from the fault-free baseline — strictly inside
    /// the longest device-0 service window — so the failure always
    /// catches an in-flight offload (the engine is deterministic and
    /// bit-identical up to the first fault event). Returns
    /// `(baseline, faulted, fail_at)`; the faulted report carries the
    /// time-to-recover and lost-work rows ([`crate::sched::FaultOutcome`]).
    pub fn run_failover_scenario(
        &self,
        streams: usize,
        requests: usize,
        jobs: usize,
    ) -> (SchedReport, SchedReport, crate::sim::Ps) {
        let topo = TopologySpec::shared_fabric(2, self.cfg.cxl_bw_gbps).with_override(
            1,
            crate::config::DeviceOverride { ccm_pus: Some(4), ..Default::default() },
        );
        let spec = SchedSpec::new(streams)
            .with_workloads(vec!['a', 'e'])
            .with_policy(crate::config::PolicyKind::Static(Protocol::Axle))
            .with_requests(requests)
            .with_admit(2);
        let base = sched::run(&sched::SchedRun::new(&self.cfg, &topo, &spec).with_jobs(jobs)).report;
        let at = base
            .requests
            .iter()
            .filter(|q| q.device == 0 && q.completion > q.admit + 1)
            .max_by_key(|q| q.completion - q.admit)
            .map(|q| q.admit + (q.completion - q.admit) / 2)
            .unwrap_or(base.makespan / 2);
        let faults = crate::config::FaultSpec::with(vec![crate::config::FaultEvent::fail(0, at)]);
        let spec = spec.with_faults(faults);
        let faulted = sched::run(&sched::SchedRun::new(&self.cfg, &topo, &spec).with_jobs(jobs)).report;
        (base, faulted, at)
    }

    /// Canned **nonstationary** scenario (`axle scenario --learned`, the
    /// CI learned-smoke): K closed-loop tenants over two identical
    /// devices with least-loaded placement, where device 0 degrades
    /// **mid-run** — PUs and link both slowed `8×` from a quarter of the
    /// fault-free makespan until past the end of the run. The static
    /// least-loaded metric keeps charging *undegraded* solo estimates,
    /// so the `Heuristic` and `Oracle` deciders keep splitting work
    /// ~evenly onto the slowed device; the `Learned` decider's
    /// estimators absorb the inflated completion latencies and its
    /// placement re-routes onto device 1, re-converging toward the
    /// clairvoyant bound. Deterministic for any worker count (faulted
    /// runs never shard).
    pub fn run_nonstationary_scenario(
        &self,
        streams: usize,
        requests: usize,
        jobs: usize,
    ) -> NonstationaryOutcome {
        let topo = TopologySpec::shared_fabric(2, self.cfg.cxl_bw_gbps)
            .with_placement(crate::config::Placement::LeastLoaded);
        let spec = SchedSpec::new(streams)
            .with_workloads(vec!['a', 'e'])
            .with_requests(requests)
            .with_admit(2);
        let base_spec = spec.clone().with_policy(crate::config::PolicyKind::Heuristic);
        let base =
            sched::run(&sched::SchedRun::new(&self.cfg, &topo, &base_spec).with_jobs(jobs)).report;
        let at = (base.makespan / 4).max(1);
        let until = base.makespan.saturating_mul(50).max(at + 1);
        let faults = crate::config::FaultSpec::with(vec![
            crate::config::FaultEvent::degrade_pus(0, at, until, 8.0),
            crate::config::FaultEvent::degrade_link(0, at, until, 8.0),
        ]);
        let [learned, heuristic, oracle] = [
            crate::config::PolicyKind::Learned,
            crate::config::PolicyKind::Heuristic,
            crate::config::PolicyKind::Oracle,
        ]
        .map(|policy| {
            let spec = spec.clone().with_policy(policy).with_faults(faults.clone());
            sched::run(&sched::SchedRun::new(&self.cfg, &topo, &spec).with_jobs(jobs)).report
        });
        NonstationaryOutcome { learned, heuristic, oracle, at, until }
    }

    /// Validate the offloaded numerics for workload `annot` through the
    /// PJRT artifacts. Errors if artifacts are not attached/built.
    pub fn validate_numerics(&mut self, annot: char) -> Result<NumericsReport> {
        let rt = self
            .runtime
            .as_mut()
            .ok_or_else(|| anyhow::anyhow!("no artifact runtime attached; run `make artifacts`"))?;
        numerics::validate(rt, annot)
    }

    /// Validate numerics for all nine workloads.
    pub fn validate_all_numerics(&mut self) -> Result<Vec<NumericsReport>> {
        crate::workload::ALL_ANNOTATIONS
            .iter()
            .map(|&a| self.validate_numerics(a))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Protocol;

    #[test]
    fn run_matrix_covers_everything() {
        let c = Coordinator::new(SimConfig::m2ndp());
        let ms = c.run_matrix(&[Protocol::Bs, Protocol::Axle]);
        assert_eq!(ms.len(), 9 * 2);
        assert!(ms.iter().all(|m| m.total > 0));
    }

    #[test]
    fn parallel_matrix_matches_serial_reference() {
        let c = Coordinator::new(SimConfig::m2ndp());
        let parallel = c.run_matrix(&[Protocol::Bs]);
        let serial = c.run_matrix_serial(&[Protocol::Bs]);
        assert_eq!(parallel.len(), serial.len());
        for (p, s) in parallel.iter().zip(&serial) {
            assert_eq!(p.to_json().to_string(), s.to_json().to_string());
        }
    }

    #[test]
    fn tenant_mix_through_coordinator_is_worker_count_invariant() {
        let c = Coordinator::new(SimConfig::m2ndp());
        // Thread a non-default QoS policy end to end through the
        // coordinator surface.
        let topo = TopologySpec::shared_fabric(2, c.config().cxl_bw_gbps)
            .with_qos(crate::config::QosSpec::wrr(vec![2, 1]));
        let tenants = crate::topo::TenantSpec::new(4).with_workloads(vec!['a', 'd']);
        let r1 = c.run_tenants_jobs(&topo, &tenants, 1);
        let r4 = c.run_tenants_jobs(&topo, &tenants, 4);
        assert_eq!(r1.to_json().to_string(), r4.to_json().to_string());
        assert_eq!(r1.tenants.len(), 4);
        assert_eq!(r1.qos, crate::config::QosPolicy::Wrr);
    }

    #[test]
    fn sched_through_coordinator_is_worker_count_invariant() {
        // Thread a non-default QoS policy and priority classes end to
        // end through the coordinator surface (the unified sched::run
        // front door).
        let c = Coordinator::new(SimConfig::m2ndp());
        let topo = TopologySpec::shared_fabric(2, c.config().cxl_bw_gbps)
            .with_qos(crate::config::QosSpec::wrr(vec![2, 1]));
        let spec = crate::config::SchedSpec::new(3)
            .with_workloads(vec!['a', 'f'])
            .with_requests(2)
            .with_priorities(vec![1, 0])
            .with_policy(crate::config::PolicyKind::Oracle);
        let r1 = sched::run(&sched::SchedRun::new(c.config(), &topo, &spec).with_jobs(1)).report;
        let r4 = sched::run(&sched::SchedRun::new(c.config(), &topo, &spec).with_jobs(4)).report;
        assert_eq!(r1.to_json().to_string(), r4.to_json().to_string());
        assert_eq!(r1.requests.len(), 6);
        assert!(r1.closed);
        assert_eq!(r1.qos, crate::config::QosPolicy::Wrr);
        assert_eq!(r1.class_slowdowns().len(), 2);
        // The deprecated wrappers stay byte-identical to the unified
        // entry point for their one-release grace period.
        #[allow(deprecated)]
        {
            let legacy = c.run_sched_jobs(&topo, &spec, 4);
            assert_eq!(legacy.to_json().to_string(), r4.to_json().to_string());
            let default_jobs = c.run_sched(&topo, &spec);
            assert_eq!(default_jobs.to_json().to_string(), r4.to_json().to_string());
        }
    }

    #[test]
    fn failover_scenario_recovers_on_survivor() {
        let c = Coordinator::new(SimConfig::m2ndp());
        let (base, faulted, at) = c.run_failover_scenario(3, 2, 2);
        assert_eq!(base.requests.len(), 6);
        assert_eq!(faulted.requests.len(), 6, "no request lost across the failure");
        assert_eq!(faulted.failed_requests, 0);
        assert!(at > 0 && at < base.makespan);
        let row = &faulted.faults[0];
        assert!(row.displaced > 0, "mid-service kill must catch in-flight work");
        assert!(row.recover > 0);
        // Deterministic: the same scenario replays bit-identically.
        let (_, again, at2) = c.run_failover_scenario(3, 2, 4);
        assert_eq!(at, at2);
        assert_eq!(faulted.to_json().to_string(), again.to_json().to_string());
    }

    #[test]
    fn custom_spec_runs() {
        use crate::workload::{CcmTask, HostTask, IterSpec};
        let c = Coordinator::new(SimConfig::m2ndp());
        let w = WorkloadSpec {
            name: "custom".into(),
            annot: 'x',
            domain: "test",
            iters: vec![IterSpec {
                ccm_tasks: vec![CcmTask { dur: 1000, result_bytes: 64 }],
                host_tasks: vec![HostTask { dur: 1000, deps: vec![0] }],
                host_serial: false,
            }],
        };
        for p in Protocol::ALL {
            let m = c.run_spec(&w, p);
            assert!(m.total > 0, "{}", p.label());
        }
    }
}
