//! The closed-loop scheduling driver: completion-fed request submission,
//! per-device admission queues, and online contention accounting.
//!
//! # Model
//!
//! K tenants each issue a sequence of requests (one request = one full
//! workload execution under one protocol). Unlike the open-loop tenant
//! driver ([`crate::topo::tenant`]), submission is driven by **completion
//! feedback**: a tenant holds at most `depth` outstanding requests and
//! schedules its next submission `think` after the window opens. Each
//! submitted request is placed on a device ([`crate::config::Placement`]),
//! its protocol chosen per request by the configured
//! [`OffloadPolicy`](super::policy::OffloadPolicy), and queued in that
//! device's **admission queue**; the device serves at most `admit`
//! requests concurrently. The queue pops the earliest request of the
//! highest **priority class** ([`SchedSpec::priority`], cycled over
//! tenants): a higher class jumps the FIFO at admission but never
//! revokes in-service work, and with all classes equal the order is the
//! plain PR-4 FIFO, bit for bit.
//!
//! # Online contention accounting
//!
//! The open-loop driver can batch-sort all wire traffic up front because
//! arrivals never depend on completions. A closed loop cannot — so the
//! shared resources are modelled *online*:
//!
//! - **Links** (`LinkCalendar`): each device channel (and the optional
//!   shared fabric) keeps a calendar of immutable busy intervals. An
//!   admitted request's solo wire trace is placed message by message into
//!   the **earliest idle gap at or after each message's issue time** (no
//!   preemption, no splitting) — a lone stream replays its solo schedule
//!   exactly (zero shift), and concurrent streams backfill each other's
//!   idle gaps, so the wire stays work-conserving. *Which* message of an
//!   admission batch is placed next is governed by
//!   [`TopologySpec::qos`](crate::config::TopologySpec): FCFS charges in
//!   pure admission order (the PR-4 path, kept verbatim), WRR/DRR drain
//!   per-tenant FIFO queues through a persistent per-wire
//!   [`QosState`] — the online counterpart of the PR-3 replay
//!   arbitration ([`crate::topo::fabric::arbitrate_qos`]).
//! - **CCM PUs** (`OnlinePool`): lease windows dispatch earliest-free
//!   onto the device's pool in admission order, the online analogue of
//!   [`crate::topo::fabric::arbitrate_pus`]. QoS governs the wires only,
//!   exactly as in the open-loop model.
//!
//! A request is charged the same **completion shift** decomposition as
//! the tenant driver: `completion = admit + solo + max(device_wait,
//! fabric_wait) + pu_wait`, with per-message lateness folded by max, not
//! sum. Queueing in the admission path appears separately as
//! `admit − submit`.
//!
//! # Intra-request pipelining (`--chunks`)
//!
//! With a [`crate::config::PipelineSpec`] of `chunks > 1`, a request is
//! admitted as a **stage DAG** ([`crate::protocol::StageGraph`]) instead
//! of an opaque triple: each chunk's wire transfer, CCM lease and
//! back-stream become stages wired with happens-after lane edges, and
//! [`admit_chunked`] places them in graph order, propagating each
//! stage's contention delay to its successors. Pipelined (AXLE-style)
//! graphs additionally release the admission slot when their last CCM
//! stage finishes (a kind-5 event), so the next request's transfer
//! overlaps the current one's back-stream drain — the paper's idle-time
//! mechanism at the multi-tenant scheduling level. `chunks == 1` (and an
//! absent spec) never enters any of this: whole-request admission stays
//! byte-identical to the PR-7 engine.
//!
//! Everything is a pure function of `(config, topology, sched spec)`;
//! the solo pass fans out across workers without affecting results.
//!
//! # Heterogeneous devices
//!
//! Each device's effective config is
//! [`TopologySpec::device_config`](crate::config::TopologySpec::device_config);
//! devices sharing a config share one *device class*. The solo pass
//! simulates every `(workload, protocol)` candidate **per class** (specs
//! deduped through the sweep engine's
//! [`WorkloadCache`](crate::sweep::WorkloadCache)), so policies see real
//! per-device trade-offs: a weak-CCM class inflates compute-bound
//! candidates, a narrow-linked class inflates transfer-bound ones.
//!
//! # Open-loop pin
//!
//! With `closed == false` (CLI `--open`) and a `Static` policy on a
//! homogeneous topology, the run delegates verbatim to
//! [`crate::topo::tenant::run_tenants`] — the PR-3 arrival process and
//! arbitration — and repackages its report. `rust/tests/sched_regression.rs`
//! pins that path bit-identical to `axle tenants`.

use std::cmp::Reverse;
use std::collections::{BTreeMap, BinaryHeap, HashMap, VecDeque};
use std::sync::Arc;

use crate::config::{
    FaultKind, Placement, PolicyKind, Protocol, QosPolicy, SchedSpec, SimConfig, TopologySpec,
};
use crate::metrics::{percentile, QuantileSketch};
use crate::protocol::{stage_graph_for, Lane, StageGraph};
use crate::sim::{ps_to_us, transfer_ps, Ps, US};
use crate::sweep::{self, SpecJob, TracedRun};
use crate::topo::fabric::QosState;
use crate::topo::tenant::{self, FabricReport, TenantSpec};
use crate::topo::DeviceStats;
use crate::trace::{Trace, TraceEvent, Tracer, Wire};
use crate::util::json::Json;
use crate::util::rng::Pcg32;

use super::fault::{FaultOutcome, FaultRuntime, Loc, ReqState};
use super::policy::{
    decider_for, required_candidates, Candidate, Decision, DeviceView, Feedback, Observed,
    RequestCtx,
};

/// One scheduled request's outcome.
#[derive(Debug, Clone)]
pub struct RequestRun {
    pub tenant: u32,
    /// Request index within the tenant's closed-loop sequence.
    pub index: u32,
    pub annot: char,
    /// The tenant's priority class ([`SchedSpec::priority`]; higher =
    /// more urgent at admission).
    pub class: u32,
    pub device: u32,
    /// Protocol the policy chose for this request.
    pub proto: Protocol,
    /// Tenant submitted (entered the device's admission queue).
    pub submit: Ps,
    /// Device admitted into service.
    pub admit: Ps,
    /// Solo end-to-end runtime on this device's config.
    pub solo: Ps,
    /// Completion shift from the device's CXL.mem/CXL.io links (worst
    /// channel).
    pub device_wait: Ps,
    /// Completion shift from the shared upstream fabric link.
    pub fabric_wait: Ps,
    /// Completion shift from the device's shared CCM PU pool.
    pub pu_wait: Ps,
    /// Absolute completion time.
    pub completion: Ps,
    /// Time lost to fault recovery: killed attempts' forfeited service
    /// plus retry backoff delays. Zero on every fault-free run.
    pub retry_wait: Ps,
    /// Retry attempts this request consumed (kills + timeouts; free
    /// re-placements after a device failure are not retries).
    pub retries: u32,
    /// Placement provenance: every device this request was queued on,
    /// in order. A single entry on fault-free runs.
    pub placed_on: Vec<u32>,
    /// The request was dropped after exhausting `max_retries` (only
    /// possible under an injected fault schedule).
    pub failed: bool,
}

impl RequestRun {
    /// Time spent waiting in the device's admission queue (across all
    /// placements; the fault-recovery share is carried by `retry_wait`).
    pub fn queue_wait(&self) -> Ps {
        (self.admit - self.submit).saturating_sub(self.retry_wait)
    }

    /// Wire-contention component (same max accounting as
    /// [`crate::topo::tenant::TenantRun::wire_wait`]).
    pub fn wire_wait(&self) -> Ps {
        self.device_wait.max(self.fabric_wait)
    }

    /// End-to-end request latency as the tenant sees it:
    /// `queue_wait + solo + wire_wait + pu_wait + retry_wait` (the last
    /// term is zero without injected faults). Failed requests close at
    /// their drop instant with zeroed service charges.
    pub fn total(&self) -> Ps {
        self.completion - self.submit
    }

    /// Latency / solo ratio (>= 1).
    pub fn slowdown(&self) -> f64 {
        if self.solo == 0 {
            1.0
        } else {
            self.total() as f64 / self.solo as f64
        }
    }

    pub fn to_json(&self) -> Json {
        let mut o = BTreeMap::new();
        o.insert("tenant".into(), Json::Num(self.tenant as f64));
        o.insert("index".into(), Json::Num(self.index as f64));
        o.insert("annot".into(), Json::Str(self.annot.to_string()));
        o.insert("prio".into(), Json::Num(self.class as f64));
        o.insert("device".into(), Json::Num(self.device as f64));
        o.insert("proto".into(), Json::Str(self.proto.label().into()));
        o.insert("submit_ps".into(), Json::Num(self.submit as f64));
        o.insert("admit_ps".into(), Json::Num(self.admit as f64));
        o.insert("queue_wait_ps".into(), Json::Num(self.queue_wait() as f64));
        o.insert("solo_total_ps".into(), Json::Num(self.solo as f64));
        o.insert("device_wait_ps".into(), Json::Num(self.device_wait as f64));
        o.insert("fabric_wait_ps".into(), Json::Num(self.fabric_wait as f64));
        o.insert("wire_wait_ps".into(), Json::Num(self.wire_wait() as f64));
        o.insert("pu_wait_ps".into(), Json::Num(self.pu_wait as f64));
        o.insert("total_ps".into(), Json::Num(self.total() as f64));
        o.insert("completion_ps".into(), Json::Num(self.completion as f64));
        o.insert("slowdown".into(), Json::Num(self.slowdown()));
        // Fault-recovery keys are sparse: fault-free request records stay
        // byte-identical to their pre-fault-layer JSON.
        if self.retries > 0 || self.failed {
            o.insert("retries".into(), Json::Num(self.retries as f64));
            o.insert("retry_wait_ps".into(), Json::Num(self.retry_wait as f64));
            o.insert("failed".into(), Json::Bool(self.failed));
        }
        if self.placed_on.len() > 1 {
            let devs = self.placed_on.iter().map(|&d| Json::Num(d as f64)).collect();
            o.insert("placed_on".into(), Json::Arr(devs));
        }
        Json::Obj(o)
    }
}

/// The full closed-loop (or open-loop-pinned) scheduling result.
#[derive(Debug, Clone)]
pub struct SchedReport {
    /// Policy the run was scheduled under.
    pub policy: PolicyKind,
    /// Link-arbitration policy the shared wires were charged under
    /// (`TopologySpec::qos`): online FCFS/WRR/DRR calendars for closed
    /// loops, the PR-3 replay arbitration for the open-loop pin.
    pub qos: QosPolicy,
    /// `true` for closed-loop arrivals, `false` for the open-loop pin.
    pub closed: bool,
    /// Per-tenant outstanding window the run enforced.
    pub depth: usize,
    /// Per-device concurrent-service limit the run enforced.
    pub admit: usize,
    /// All requests, sorted by `(tenant, index)`.
    pub requests: Vec<RequestRun>,
    /// Per-device aggregates (`tenants` counts *placements*: one per
    /// request on fault-free runs, and one per fault-driven re-placement
    /// on top of that, so the sum may exceed the request count).
    pub devices: Vec<DeviceStats>,
    pub fabric: FabricReport,
    /// Last completion across all requests.
    pub makespan: Ps,
    pub p50_slowdown: f64,
    pub p99_slowdown: f64,
    pub max_slowdown: f64,
    /// Aggregate host busy time across requests' solo runs (sum, not
    /// union — the host pool is not contended by this layer). Failed
    /// requests contribute nothing: their solo work never completed.
    pub host_busy: Ps,
    /// Sum over devices of the CCM pool busy-union.
    pub ccm_busy: Ps,
    /// Requests per chosen protocol (the policy's decision mix).
    pub proto_mix: BTreeMap<&'static str, u64>,
    /// Per-fault outcomes (time-to-recover, displacement, lost work) in
    /// spec order. Empty without an injected fault schedule.
    pub faults: Vec<FaultOutcome>,
    /// Total device-wire picoseconds wasted on killed in-service
    /// attempts across all faults.
    pub lost_wire: Ps,
    /// Total CCM PU picoseconds wasted on killed in-service attempts.
    pub lost_pu: Ps,
    /// Requests dropped after exhausting the retry budget.
    pub failed_requests: usize,
    /// Requests scheduled to completion (success or terminal failure).
    /// Equals `requests.len()` on retained runs; on streaming runs it is
    /// the only record of run size, since `requests` stays empty.
    pub scheduled: u64,
    /// `true` when the run aggregated through streaming sketches instead
    /// of retaining per-request rows (`SchedSpec::retain == false`).
    pub streamed: bool,
    /// Streaming-mode per-class rows (`class_slowdowns` shape), filled
    /// from the per-class sketches at assembly time. Empty on retained
    /// runs, where `class_slowdowns` recomputes from `requests`.
    pub class_rows: Vec<(u32, usize, f64, f64)>,
}

impl SchedReport {
    /// Fraction of `devices × makespan` the CCM pools sat idle — the
    /// paper's headline utilization metric, per Fig. 7/12 accounting.
    pub fn ccm_idle_frac(&self) -> f64 {
        let horizon = self.makespan as f64 * self.devices.len() as f64;
        if horizon <= 0.0 {
            0.0
        } else {
            (1.0 - self.ccm_busy as f64 / horizon).max(0.0)
        }
    }

    /// Fraction of the makespan the host spent outside request work
    /// (aggregate-sum accounting, clamped; see `host_busy`).
    pub fn host_idle_frac(&self) -> f64 {
        if self.makespan == 0 {
            0.0
        } else {
            (1.0 - self.host_busy as f64 / self.makespan as f64).max(0.0)
        }
    }

    /// Per-priority-class slowdown aggregates, ascending by class:
    /// `(class, requests, p50 slowdown, p99 slowdown)` — the fig19
    /// per-class columns. Empty when the run scheduled nothing.
    pub fn class_slowdowns(&self) -> Vec<(u32, usize, f64, f64)> {
        if self.streamed {
            return self.class_rows.clone();
        }
        let mut by_class: BTreeMap<u32, Vec<f64>> = BTreeMap::new();
        for r in &self.requests {
            by_class.entry(r.class).or_default().push(r.slowdown());
        }
        by_class
            .into_iter()
            .map(|(class, s)| (class, s.len(), percentile(&s, 50.0), percentile(&s, 99.0)))
            .collect()
    }

    pub fn to_json(&self) -> Json {
        let mut fab = BTreeMap::new();
        match self.fabric.bw_gbps {
            Some(bw) => fab.insert("bw_gbps".into(), Json::Num(bw)),
            None => fab.insert("bw_gbps".into(), Json::Null),
        };
        fab.insert("messages".into(), Json::Num(self.fabric.messages as f64));
        fab.insert("bytes".into(), Json::Num(self.fabric.bytes as f64));
        fab.insert("busy_ps".into(), Json::Num(self.fabric.busy as f64));
        fab.insert("wait_ps".into(), Json::Num(self.fabric.wait as f64));
        fab.insert("utilization".into(), Json::Num(self.fabric.utilization));
        let devices: Vec<Json> = self
            .devices
            .iter()
            .map(|d| {
                let mut o = BTreeMap::new();
                o.insert("requests".into(), Json::Num(d.tenants as f64));
                o.insert("load_ps".into(), Json::Num(d.load as f64));
                o.insert("mem_wait_ps".into(), Json::Num(d.mem_wait as f64));
                o.insert("io_wait_ps".into(), Json::Num(d.io_wait as f64));
                o.insert("pu_wait_ps".into(), Json::Num(d.pu_wait as f64));
                o.insert("pu_busy_ps".into(), Json::Num(d.pu_busy as f64));
                o.insert("bytes".into(), Json::Num(d.bytes as f64));
                o.insert("link_busy_ps".into(), Json::Num(d.link_busy as f64));
                Json::Obj(o)
            })
            .collect();
        let mut mix = BTreeMap::new();
        for (proto, n) in &self.proto_mix {
            mix.insert((*proto).into(), Json::Num(*n as f64));
        }
        let classes: Vec<Json> = self
            .class_slowdowns()
            .into_iter()
            .map(|(class, n, p50, p99)| {
                let mut o = BTreeMap::new();
                o.insert("class".into(), Json::Num(class as f64));
                o.insert("requests".into(), Json::Num(n as f64));
                o.insert("p50_slowdown".into(), Json::Num(p50));
                o.insert("p99_slowdown".into(), Json::Num(p99));
                Json::Obj(o)
            })
            .collect();
        let mut o = BTreeMap::new();
        o.insert("policy".into(), Json::Str(self.policy.label()));
        o.insert("qos".into(), Json::Str(self.qos.label().into()));
        o.insert("mode".into(), Json::Str(if self.closed { "closed" } else { "open" }.into()));
        o.insert("classes".into(), Json::Arr(classes));
        o.insert("depth".into(), Json::Num(self.depth as f64));
        o.insert("admit".into(), Json::Num(self.admit as f64));
        o.insert("requests".into(), Json::Arr(self.requests.iter().map(|r| r.to_json()).collect()));
        o.insert("devices".into(), Json::Arr(devices));
        o.insert("fabric".into(), Json::Obj(fab));
        o.insert("makespan_ps".into(), Json::Num(self.makespan as f64));
        o.insert("p50_slowdown".into(), Json::Num(self.p50_slowdown));
        o.insert("p99_slowdown".into(), Json::Num(self.p99_slowdown));
        o.insert("max_slowdown".into(), Json::Num(self.max_slowdown));
        o.insert("host_busy_ps".into(), Json::Num(self.host_busy as f64));
        o.insert("ccm_busy_ps".into(), Json::Num(self.ccm_busy as f64));
        o.insert("host_idle_frac".into(), Json::Num(self.host_idle_frac()));
        o.insert("ccm_idle_frac".into(), Json::Num(self.ccm_idle_frac()));
        o.insert("proto_mix".into(), Json::Obj(mix));
        // Sparse, like the per-request retry keys: a run without a fault
        // schedule keeps its pre-fault-layer JSON byte for byte.
        if !self.faults.is_empty() {
            o.insert("faults".into(), Json::Arr(self.faults.iter().map(|f| f.to_json()).collect()));
            o.insert("lost_wire_ps".into(), Json::Num(self.lost_wire as f64));
            o.insert("lost_pu_ps".into(), Json::Num(self.lost_pu as f64));
            o.insert("failed_requests".into(), Json::Num(self.failed_requests as f64));
        }
        // Streaming runs carry their size explicitly (requests is empty);
        // retained JSON stays byte-identical by omitting both keys.
        if self.streamed {
            o.insert("scheduled".into(), Json::Num(self.scheduled as f64));
            o.insert("streamed".into(), Json::Bool(true));
        }
        Json::Obj(o)
    }
}

/// One printable line per request (the `axle sched` table body). Rows
/// touched by fault recovery carry a trailing retry/failure marker;
/// fault-free rows print exactly as before.
pub fn format_request_row(r: &RequestRun) -> String {
    let mut row = format!(
        "#{:<3}.{:<2} ({}) c{:<2} dev {:<2} {:<6} sub {:>10.2} us  q {:>8.2} us  solo {:>10.2} us  +wire {:>8.2} us  +pu {:>8.2} us  x{:<5.3}",
        r.tenant,
        r.index,
        r.annot,
        r.class,
        r.device,
        r.proto.label(),
        ps_to_us(r.submit),
        ps_to_us(r.queue_wait()),
        ps_to_us(r.solo),
        ps_to_us(r.wire_wait()),
        ps_to_us(r.pu_wait),
        r.slowdown()
    );
    if r.retries > 0 || r.failed {
        row.push_str(&format!(
            "  +retry {:>8.2} us (x{}){}",
            ps_to_us(r.retry_wait),
            r.retries,
            if r.failed { " FAILED" } else { "" }
        ));
    }
    row
}

// ------------------------------------------------------------------
// Online resource models.
// ------------------------------------------------------------------

/// Busy calendar for one shared wire. Placed transfers are immutable,
/// non-overlapping intervals; a new transfer goes into the earliest idle
/// gap at or after its issue time that fits its serialization (no
/// preemption, no splitting).
///
/// The representation is a sorted `Vec` of **coalesced** busy intervals
/// (abutting placements merge), not one entry per message: in the
/// steady closed-loop state almost every placement lands at or past the
/// tail, so the common case is an O(1) append/extend of the last
/// element, and the backfill case is a binary search over the (far
/// shorter) coalesced list. `rust/tests/proptests.rs` pins this
/// equivalent to the PR-6 per-message BTreeMap under random
/// place/truncate sequences. Message *starts* are only needed by
/// [`Self::truncate`] (fault kills), so the per-message log is optional:
/// fault-free runs use [`Self::untracked`] and keep O(1) state.
#[derive(Debug)]
pub struct LinkCalendar {
    /// Coalesced busy intervals, sorted, non-overlapping, non-abutting.
    segs: Vec<(Ps, Ps)>,
    busy_total: Ps,
    msgs: u64,
    /// Start instant of every placed message, for [`Self::truncate`]'s
    /// message recount. `None` on untracked (fault-free) calendars.
    log: Option<Vec<Ps>>,
}

impl Default for LinkCalendar {
    /// A message-tracked calendar (supports [`Self::truncate`]).
    fn default() -> Self {
        Self { segs: Vec::new(), busy_total: 0, msgs: 0, log: Some(Vec::new()) }
    }
}

impl LinkCalendar {
    /// A calendar without the per-message start log: O(1) memory in the
    /// message count, but [`Self::truncate`] panics. For fault-free runs.
    pub fn untracked() -> Self {
        Self { segs: Vec::new(), busy_total: 0, msgs: 0, log: None }
    }

    /// Place a `dur`-long transfer issued at `issue`; returns its start
    /// (>= `issue`). Zero-length transfers occupy no wire time.
    pub fn place(&mut self, issue: Ps, dur: Ps) -> Ps {
        if dur == 0 {
            return issue;
        }
        // Fast path: at or past the tail (copy the tail end out first —
        // matching on `last_mut()` would hold the borrow across the push).
        let t = match self.segs.last().map(|&(_, e)| e) {
            Some(tail_end) if issue < tail_end => self.place_slow(issue, dur),
            Some(tail_end) if issue == tail_end => {
                self.segs.last_mut().expect("tail exists").1 = issue + dur;
                issue
            }
            _ => {
                self.segs.push((issue, issue + dur));
                issue
            }
        };
        self.busy_total += dur;
        self.msgs += 1;
        if let Some(log) = self.log.as_mut() {
            log.push(t);
        }
        t
    }

    /// Backfill path: the issue instant is before the calendar tail.
    /// Binary-search the first interval ending after `issue`, clamp past
    /// it if it covers the instant, then walk gaps until `dur` fits.
    #[cold]
    fn place_slow(&mut self, issue: Ps, dur: Ps) -> Ps {
        let mut i = self.segs.partition_point(|&(_, e)| e <= issue);
        let mut t = issue;
        if i < self.segs.len() && self.segs[i].0 <= issue {
            // An interval covers the issue instant: start no earlier
            // than its end.
            t = self.segs[i].1;
            i += 1;
        }
        while i < self.segs.len() && self.segs[i].0 - t < dur {
            t = self.segs[i].1;
            i += 1;
        }
        // Insert [t, t+dur), coalescing with abutting neighbours.
        let merge_left = i > 0 && self.segs[i - 1].1 == t;
        let merge_right = i < self.segs.len() && self.segs[i].0 == t + dur;
        match (merge_left, merge_right) {
            (true, true) => {
                let right_end = self.segs[i].1;
                self.segs[i - 1].1 = right_end;
                self.segs.remove(i);
            }
            (true, false) => self.segs[i - 1].1 = t + dur,
            (false, true) => self.segs[i].0 = t,
            (false, false) => self.segs.insert(i, (t, t + dur)),
        }
        t
    }

    /// End of the last placed interval (0 when never busy) — the
    /// occupancy-tail signal policies observe.
    pub fn tail(&self) -> Ps {
        self.segs.last().map(|&(_, e)| e).unwrap_or(0)
    }

    /// Messages placed (zero-length transfers excluded).
    pub fn msgs(&self) -> u64 {
        self.msgs
    }

    /// Wire busy time (placed transfers never overlap, so the union is
    /// the sum of durations, maintained incrementally).
    pub fn busy_union(&self) -> Ps {
        self.busy_total
    }

    /// Drop everything scheduled at or after `now`: future intervals are
    /// removed outright, an interval straddling `now` is clipped. The
    /// message count is recomputed from the start log — a message that
    /// *started* before the cut really went out and keeps its count.
    /// Used when a device dies mid-run — its booked future wire time is
    /// phantom work that must not appear in the busy union. Safe on an
    /// empty or fully-past calendar (both are no-ops). Panics on an
    /// [`Self::untracked`] calendar.
    pub fn truncate(&mut self, now: Ps) {
        while let Some(&(s, e)) = self.segs.last() {
            if s >= now {
                self.busy_total -= e - s;
                self.segs.pop();
            } else {
                if e > now {
                    self.busy_total -= e - now;
                    self.segs.last_mut().expect("tail exists").1 = now;
                }
                break;
            }
        }
        let log = self.log.as_mut().expect("truncate requires a message-tracked calendar");
        log.retain(|&s| s < now);
        self.msgs = log.len() as u64;
    }
}

/// Earliest-free PU pool for online (admission-order) dispatch. Unlike
/// [`crate::sim::PuPool`], ready times may regress across requests
/// admitted at different instants. The busy union is maintained
/// incrementally at dispatch time: dispatch starts are monotone per PU
/// and near-monotone overall, so the common case is an O(1)
/// extend-the-last-interval, with a `#[cold]` merge for regressed
/// starts — no clone-and-sort at report time. The raw span list is only
/// needed by [`Self::truncate`] (fault kills), so fault-free runs use
/// [`Self::untracked`] and keep O(1) state.
#[derive(Debug)]
pub struct OnlinePool {
    free_at: BinaryHeap<Reverse<Ps>>,
    /// Coalesced union of all dispatched spans (sorted, disjoint).
    union: Vec<(Ps, Ps)>,
    union_total: Ps,
    busy_total: Ps,
    /// Raw spans for [`Self::truncate`]. `None` on untracked pools.
    spans: Option<Vec<(Ps, Ps)>>,
}

impl OnlinePool {
    /// A span-tracked pool of `n` PUs (supports [`Self::truncate`]).
    pub fn new(n: usize) -> Self {
        Self::build(n, true)
    }

    /// A pool without the raw span list: O(1) memory in the dispatch
    /// count, but [`Self::truncate`] panics. For fault-free runs.
    pub fn untracked(n: usize) -> Self {
        Self::build(n, false)
    }

    fn build(n: usize, tracked: bool) -> Self {
        assert!(n > 0, "pool needs at least one PU");
        let mut free_at = BinaryHeap::with_capacity(n);
        for _ in 0..n {
            free_at.push(Reverse(0));
        }
        Self {
            free_at,
            union: Vec::new(),
            union_total: 0,
            busy_total: 0,
            spans: tracked.then(Vec::new),
        }
    }

    /// Run a `dur`-long span on the earliest-free PU, no earlier than
    /// `ready`; returns `(start, end)`.
    pub fn dispatch(&mut self, ready: Ps, dur: Ps) -> (Ps, Ps) {
        let Reverse(free) = self.free_at.pop().expect("pool never empty");
        let start = free.max(ready);
        let end = start + dur;
        self.free_at.push(Reverse(end));
        if dur > 0 {
            self.busy_total += dur;
            self.union_insert(start, end);
            if let Some(spans) = self.spans.as_mut() {
                spans.push((start, end));
            }
        }
        (start, end)
    }

    /// Fold span `[s, e)` into the coalesced union.
    fn union_insert(&mut self, s: Ps, e: Ps) {
        match self.union.last().map(|&(_, ue)| ue) {
            Some(last_end) if s < last_end => self.union_insert_slow(s, e),
            _ => {
                // At or past the covered frontier: extend or append.
                match self.union.last_mut() {
                    Some(last) if s == last.1 => last.1 = e,
                    _ => self.union.push((s, e)),
                }
                self.union_total += e - s;
            }
        }
    }

    /// Regressed-start path: binary-search the overlap range and splice
    /// the merged interval in.
    #[cold]
    fn union_insert_slow(&mut self, s: Ps, e: Ps) {
        let lo = self.union.partition_point(|&(_, ue)| ue < s);
        let mut hi = lo;
        let (mut ns, mut ne) = (s, e);
        while hi < self.union.len() && self.union[hi].0 <= e {
            ns = ns.min(self.union[hi].0);
            ne = ne.max(self.union[hi].1);
            self.union_total -= self.union[hi].1 - self.union[hi].0;
            hi += 1;
        }
        self.union.splice(lo..hi, std::iter::once((ns, ne)));
        self.union_total += ne - ns;
    }

    /// Earliest instant any PU is free.
    pub fn earliest_free(&self) -> Ps {
        self.free_at.peek().map(|Reverse(t)| *t).unwrap_or(0)
    }

    /// Wall-clock time during which at least one PU was busy.
    pub fn busy_union(&self) -> Ps {
        self.union_total
    }

    /// Sum of dispatched durations (PU-seconds, overlaps counted).
    pub fn busy_total(&self) -> Ps {
        self.busy_total
    }

    /// Drop PU work scheduled at or after `now` (mirror of
    /// [`LinkCalendar::truncate`]): future spans are removed, straddling
    /// spans clipped, and the union rebuilt from the surviving spans.
    /// The free heap is left alone — a dead device never dispatches
    /// again, so only the busy accounting matters. Panics on an
    /// [`Self::untracked`] pool.
    pub fn truncate(&mut self, now: Ps) {
        let spans = self.spans.as_mut().expect("truncate requires a span-tracked pool");
        let mut i = 0;
        while i < spans.len() {
            let (s, e) = spans[i];
            if s >= now {
                self.busy_total -= e - s;
                spans.swap_remove(i);
            } else {
                if e > now {
                    self.busy_total -= e - now;
                    spans[i].1 = now;
                }
                i += 1;
            }
        }
        // The union is a set of disjoint sorted intervals: truncating it
        // at `now` is exactly the union of the truncated spans.
        while let Some(&(s, e)) = self.union.last() {
            if s >= now {
                self.union_total -= e - s;
                self.union.pop();
            } else {
                if e > now {
                    self.union_total -= e - now;
                    self.union.last_mut().expect("tail exists").1 = now;
                }
                break;
            }
        }
    }
}

/// Per-device admission queue: FIFO within a priority class, classes
/// served highest-first. Replaces the PR-4 flat `VecDeque` + O(queue)
/// highest-class scan with per-class deques keyed by class in a
/// `BTreeMap` — pop is O(log classes) and preserves the scan's exact
/// earliest-of-highest-class order via a global arrival sequence number
/// (unit-tested equivalent in this module's tests).
///
/// Invariant: no empty per-class deque is ever stored (the map's last
/// key is always a non-empty class).
#[derive(Debug, Default)]
pub struct AdmitQueue {
    /// class → FIFO of `(arrival_seq, rid)`.
    classes: BTreeMap<u32, VecDeque<(u64, u32)>>,
    next_seq: u64,
    len: usize,
}

impl AdmitQueue {
    /// Enqueue `rid` under `class`, behind everything already queued.
    pub fn push(&mut self, rid: u32, class: u32) {
        let seq = self.next_seq;
        self.next_seq += 1;
        self.classes.entry(class).or_default().push_back((seq, rid));
        self.len += 1;
    }

    /// Queued request count.
    pub fn len(&self) -> usize {
        self.len
    }

    /// `true` when nothing is queued.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Pop the earliest-queued request of the highest present class —
    /// the admission order ([`SchedSpec::priority`] semantics).
    pub fn pop_admit(&mut self) -> Option<u32> {
        let (&class, _) = self.classes.iter().next_back()?;
        Some(self.pop_class(class))
    }

    /// Pop the globally earliest-queued request regardless of class —
    /// the fault drain order (the PR-6 `pop_front` on the flat queue).
    pub fn pop_front_fifo(&mut self) -> Option<u32> {
        let (&class, _) = self
            .classes
            .iter()
            .min_by_key(|(_, q)| q.front().expect("no empty class deque is stored").0)?;
        Some(self.pop_class(class))
    }

    fn pop_class(&mut self, class: u32) -> u32 {
        let q = self.classes.get_mut(&class).expect("class present");
        let (_, rid) = q.pop_front().expect("no empty class deque is stored");
        if q.is_empty() {
            self.classes.remove(&class);
        }
        self.len -= 1;
        rid
    }

    /// Remove a specific queued request (timeout eviction). Panics if
    /// absent — the caller tracked it as queued on this device.
    pub fn remove(&mut self, rid: u32, class: u32) {
        let q = self.classes.get_mut(&class).expect("class present");
        let pos = q
            .iter()
            .position(|&(_, r)| r == rid)
            .expect("queued request present in its device's admission queue");
        q.remove(pos);
        if q.is_empty() {
            self.classes.remove(&class);
        }
        self.len -= 1;
    }

    /// Iterate queued rids (class-major order; order-insensitive uses
    /// only — the fault layer arms one timeout per queued request).
    pub fn iter_rids(&self) -> impl Iterator<Item = u32> + '_ {
        self.classes.values().flat_map(|q| q.iter().map(|&(_, rid)| rid))
    }
}

// ------------------------------------------------------------------
// Streaming aggregation.
// ------------------------------------------------------------------

/// Request-slot arena. Retained mode (`recycle == false`) is the PR-6
/// layout verbatim: slot index == rid == event ticket, rows kept
/// forever. Streaming mode recycles the slot of every finished request
/// through a free list, so live memory is O(depth × streams) instead of
/// O(total requests); events then carry a monotone *ticket* resolved
/// through `live`, which doubles as the staleness filter for events
/// addressed to a recycled slot.
struct ReqArena {
    runs: Vec<RequestRun>,
    /// Current ticket held by each slot (parallel to `runs`).
    tickets: Vec<u64>,
    /// Recycled slot indices.
    free: Vec<u32>,
    /// ticket → slot, live requests only. Unused in retained mode.
    live: HashMap<u64, u32>,
    next_ticket: u64,
    recycle: bool,
}

impl ReqArena {
    fn new(recycle: bool, cap: usize) -> Self {
        Self {
            runs: Vec::with_capacity(cap),
            tickets: Vec::with_capacity(cap),
            free: Vec::new(),
            live: HashMap::new(),
            next_ticket: 0,
            recycle,
        }
    }

    /// Allocate a slot for a new submission; returns `(ticket, slot)`.
    /// The caller fills every `RequestRun` field; only `placed_on` needs
    /// clearing here (the one field reused rather than overwritten).
    fn alloc(&mut self) -> (u64, usize) {
        let ticket = self.next_ticket;
        self.next_ticket += 1;
        let slot = match self.free.pop() {
            Some(s) => {
                let s = s as usize;
                self.runs[s].placed_on.clear();
                self.tickets[s] = ticket;
                s
            }
            None => {
                self.runs.push(RequestRun {
                    tenant: 0,
                    index: 0,
                    annot: ' ',
                    class: 0,
                    device: 0,
                    proto: Protocol::Axle,
                    submit: 0,
                    admit: 0,
                    solo: 0,
                    device_wait: 0,
                    fabric_wait: 0,
                    pu_wait: 0,
                    completion: 0,
                    retry_wait: 0,
                    retries: 0,
                    placed_on: Vec::new(),
                    failed: false,
                });
                self.tickets.push(ticket);
                self.runs.len() - 1
            }
        };
        if self.recycle {
            self.live.insert(ticket, slot as u32);
        }
        (ticket, slot)
    }

    /// Resolve an event ticket to its slot, `None` when the request
    /// already finished (stale event against a recycled slot).
    fn slot_of(&self, ticket: u64) -> Option<usize> {
        if self.recycle {
            self.live.get(&ticket).map(|&s| s as usize)
        } else {
            Some(ticket as usize)
        }
    }

    /// Mark `slot` finished: in streaming mode its ticket dies and the
    /// slot returns to the free list. No-op in retained mode.
    fn release(&mut self, slot: usize) {
        if self.recycle {
            self.live.remove(&self.tickets[slot]);
            self.free.push(slot as u32);
        }
    }
}

/// Streaming slowdown sketches: the whole population plus one per class.
struct SkSet {
    all: QuantileSketch,
    by_class: BTreeMap<u32, QuantileSketch>,
}

impl SkSet {
    fn new() -> Self {
        Self { all: QuantileSketch::new(), by_class: BTreeMap::new() }
    }

    /// Counter-wise merge; order never affects any quantile.
    fn merge(&mut self, other: &SkSet) {
        self.all.merge(&other.all);
        for (c, s) in &other.by_class {
            self.by_class.entry(*c).or_default().merge(s);
        }
    }
}

/// Online scalar aggregates for streaming mode — everything the report
/// derives from the retained request vector, folded per terminal
/// request instead. Every fold is order-independent (sums, maxes,
/// counter maps, sketch records), so the result is independent of
/// completion order and equals the post-hoc computation exactly
/// (pinned in `rust/tests/sched_regression.rs`).
struct Agg {
    scheduled: u64,
    failed: u64,
    host_busy: Ps,
    makespan: Ps,
    proto_mix: BTreeMap<&'static str, u64>,
    sk: SkSet,
}

impl Agg {
    fn new() -> Self {
        Self {
            scheduled: 0,
            failed: 0,
            host_busy: 0,
            makespan: 0,
            proto_mix: BTreeMap::new(),
            sk: SkSet::new(),
        }
    }

    /// Fold one terminal (completed or failed) request. `host_busy` is
    /// the request's solo host-busy charge — 0 for failed requests,
    /// whose solo work never completed.
    fn finish(&mut self, r: &RequestRun, host_busy: Ps) {
        self.scheduled += 1;
        if r.failed {
            self.failed += 1;
        }
        self.host_busy += host_busy;
        self.makespan = self.makespan.max(r.completion);
        *self.proto_mix.entry(r.proto.label()).or_insert(0) += 1;
        let s = r.slowdown();
        self.sk.all.record(s);
        self.sk.by_class.entry(r.class).or_default().record(s);
    }
}

/// One engine run's raw result, before report assembly: either the
/// retained request vector (`sk == None`) or the streaming aggregates.
/// Shards of a partitioned run produce one each; [`merge_shards`] folds
/// them into a single equivalent `RawRun`.
struct RawRun {
    requests: Vec<RequestRun>,
    sk: Option<SkSet>,
    scheduled: u64,
    failed_requests: usize,
    makespan: Ps,
    host_busy: Ps,
    proto_mix: BTreeMap<&'static str, u64>,
    devices: Vec<DeviceStats>,
    ccm_busy: Ps,
    fabric: FabricReport,
    faults: Vec<FaultOutcome>,
    lost_wire: Ps,
    lost_pu: Ps,
    /// Recorded trace events (`Some` iff the engine ran traced; shard
    /// buffers are concatenated by [`merge_shards`] and canonicalized
    /// in [`Trace::new`]).
    trace: Option<Vec<TraceEvent>>,
}

// ------------------------------------------------------------------
// The driver.
// ------------------------------------------------------------------

/// One solo candidate run plus derived per-channel byte totals.
struct SoloRun {
    run: TracedRun,
    mem_bytes: u64,
    io_bytes: u64,
}

/// The solo pass's results, keyed on `(device class, annot, protocol)`.
struct SoloTable {
    idx: HashMap<(usize, char, Protocol), usize>,
    runs: Vec<SoloRun>,
}

impl SoloTable {
    fn get(&self, class: usize, annot: char, proto: Protocol) -> &SoloRun {
        &self.runs[self.idx[&(class, annot, proto)]]
    }

    /// Run index of one `(class, annot, proto)` point — the key chunked
    /// admission uses to pair a solo run with its stage graph.
    fn idx_of(&self, class: usize, annot: char, proto: Protocol) -> usize {
        self.idx[&(class, annot, proto)]
    }
}

/// Chunked-admission runtime (`spec.chunks() > 1` only): the per-solo-run
/// stage graphs plus the per-slot early-release flags. Whole-request
/// runs never construct one — the `chunks = 1` bit-identity pin.
struct PipeRt {
    /// Stage graph per [`SoloTable`] run index (shared by every request
    /// of that `(class, annot, proto)` point).
    graphs: Vec<StageGraph>,
    /// Per arena slot: true once a kind-5 event freed the admission slot
    /// early, so the completion event must not free it again. Reset at
    /// every admission (slots recycle in streaming mode).
    released: Vec<bool>,
}

struct DevState {
    class: usize,
    /// This device class's CXL link bandwidth (what its solo traces were
    /// recorded at).
    link_bw: f64,
    mem: LinkCalendar,
    io: LinkCalendar,
    /// Online WRR/DRR scheduler state per device channel. `None` under
    /// FCFS, which keeps the PR-4 admission-order charging verbatim.
    qos_mem: Option<QosState>,
    qos_io: Option<QosState>,
    pool: OnlinePool,
    queue: AdmitQueue,
    in_service: usize,
    stats: DeviceStats,
    /// `false` once a permanent failure removes the device. Dead devices
    /// are never placement targets and never admit.
    alive: bool,
    /// `false` while a transient stall (or permanent failure) holds the
    /// admission gate shut; [`try_admit`] is a no-op then.
    admit_open: bool,
    /// Link-degradation factor: effective bandwidth is
    /// `link_bw / bw_factor`. Exactly `1.0` outside degradation windows
    /// (and `x / 1.0` is exact in IEEE 754, keeping fault-free and
    /// post-window charging bit-identical).
    bw_factor: f64,
    /// PU-degradation factor: CCM lease durations scale by it on
    /// dispatch. Exactly `1.0` outside degradation windows (guarded, so
    /// no float round-trip touches the undegraded path).
    pu_factor: f64,
}

struct TenantState {
    next_index: usize,
    outstanding: usize,
    submit_scheduled: bool,
}

/// Event ordering: `(time, kind, id, seq)` with completions (kind 0)
/// before submissions (kind 1) at equal times, so freed windows and
/// service slots are visible to same-instant submissions. Fault
/// schedules add kind 2 (fault transition: `id` = spec event index,
/// `seq` = 0 start / 1 window end), kind 3 (requeue arrival after
/// backoff: `id` = request, `seq` = attempt) and kind 4 (queued-request
/// timeout check: `id` = request, `seq` = attempt). Chunked pipelined
/// admission adds kind 5 (early slot release at the last CCM stage:
/// `id` = ticket, `seq` = device — fault-free chunked runs only).
/// Completion events
/// pack the attempt into `id`'s high 32 bits (device in the low bits) so
/// stale completions of killed attempts are dropped; fault-free runs
/// never leave attempt 0, keeping their tuples bit-identical.
type Ev = (Ps, u8, u64, u64);

/// The solo pass's full output: device classes plus per-class candidate
/// profiles and traces. A pure function of `(base config, topology,
/// workload mix, candidate protocol set)` — reusable across closed-loop
/// runs that share those (e.g. the `fig19` depth axis, which cannot
/// change solo results).
pub(super) struct SoloPass {
    class_cfgs: Vec<Arc<SimConfig>>,
    class_of: Vec<usize>,
    /// Workload annotation of each tenant (tenant `i` runs `annots[i]`).
    annots: Vec<char>,
    table: SoloTable,
    cand_table: HashMap<(usize, char), Vec<Candidate>>,
}

/// Resolve device classes and run every `(class, annot, candidate
/// protocol)` solo simulation once, fanned across `jobs` workers.
pub(super) fn prepare_solo_pass(
    cfg: &SimConfig,
    topo_spec: &TopologySpec,
    spec: &SchedSpec,
    jobs: usize,
) -> SoloPass {
    // ---- Device classes (heterogeneous topologies dedupe per class). ----
    let mut class_cfgs: Vec<Arc<SimConfig>> = Vec::new();
    let mut class_of: Vec<usize> = Vec::with_capacity(topo_spec.devices);
    let mut class_by_fp: HashMap<u64, usize> = HashMap::new();
    for d in 0..topo_spec.devices {
        let dev_cfg = topo_spec.device_config(d, cfg);
        let fp = dev_cfg.fingerprint();
        let class = *class_by_fp.entry(fp).or_insert_with(|| {
            class_cfgs.push(Arc::new(dev_cfg));
            class_cfgs.len() - 1
        });
        class_of.push(class);
    }

    // ---- Solo pass: every (class, annot, candidate proto) once. ----
    let annots: Vec<char> =
        (0..spec.streams).map(|i| spec.workloads[i % spec.workloads.len()]).collect();
    let mut distinct: Vec<char> = Vec::new();
    for &a in &annots {
        if !distinct.contains(&a) {
            distinct.push(a);
        }
    }
    let protos = required_candidates(spec.policy);
    let mut cache = sweep::WorkloadCache::new();
    let mut solo_idx: HashMap<(usize, char, Protocol), usize> = HashMap::new();
    let mut job_list: Vec<SpecJob> = Vec::new();
    for (class, class_cfg) in class_cfgs.iter().enumerate() {
        for &a in &distinct {
            for &p in &protos {
                solo_idx.insert((class, a, p), job_list.len());
                job_list.push(SpecJob {
                    w: cache.get(a, class_cfg),
                    proto: p,
                    cfg: Arc::clone(class_cfg),
                });
            }
        }
    }
    let runs: Vec<SoloRun> = sweep::run_traced_jobs(&job_list, jobs)
        .into_iter()
        .map(|run| {
            let mem_bytes = run.mem_trace.iter().map(|m| m.bytes).sum();
            let io_bytes = run.io_trace.iter().map(|m| m.bytes).sum();
            SoloRun { run, mem_bytes, io_bytes }
        })
        .collect();
    let table = SoloTable { idx: solo_idx, runs };

    // Candidate tables per (class, annot), in `protos` order.
    let mut cand_table: HashMap<(usize, char), Vec<Candidate>> = HashMap::new();
    for class in 0..class_cfgs.len() {
        for &a in &distinct {
            let cands = protos
                .iter()
                .map(|&p| {
                    let s = table.get(class, a, p);
                    Candidate {
                        proto: p,
                        solo: s.run.metrics.total,
                        ccm_busy: s.run.metrics.ccm_busy,
                        dm_busy: s.run.metrics.dm_busy,
                        mem_bytes: s.mem_bytes,
                        io_bytes: s.io_bytes,
                    }
                })
                .collect();
            cand_table.insert((class, a), cands);
        }
    }
    SoloPass { class_cfgs, class_of, annots, table, cand_table }
}

/// Options struct for the unified scheduler entry point [`run`] — the
/// one front door that replaced the `run_sched` / `run_sched_traced` /
/// coordinator `run_sched_jobs` trio. New knobs land here as fields
/// with defaults instead of as new entry points.
#[derive(Debug, Clone, Copy)]
pub struct SchedRun<'a> {
    pub cfg: &'a SimConfig,
    pub topo: &'a TopologySpec,
    pub spec: &'a SchedSpec,
    /// Worker threads for the solo pass and (when the topology is
    /// shardable) the event engine. Never changes results.
    pub jobs: usize,
}

impl<'a> SchedRun<'a> {
    /// A run over all available worker threads; narrow with
    /// [`Self::with_jobs`].
    pub fn new(cfg: &'a SimConfig, topo: &'a TopologySpec, spec: &'a SchedSpec) -> Self {
        Self { cfg, topo, spec, jobs: crate::sweep::available_jobs() }
    }

    pub fn with_jobs(mut self, jobs: usize) -> Self {
        self.jobs = jobs;
        self
    }
}

/// Everything one scheduler run produces.
pub struct SchedOutcome {
    pub report: SchedReport,
    /// The run's canonical event trace — recorded iff `spec.trace` is
    /// set on a closed-loop run. Tracing is observation-only: the
    /// report is bit-identical (including every f64 bit) with `trace`
    /// set or unset, pinned in `rust/tests/sched_regression.rs`.
    pub trace: Option<Trace>,
}

/// Run a scheduler spec. Deterministic: a pure function of
/// `(cfg, topo, spec)` — the worker count never changes results, and on
/// pinned shardable topologies `--jobs N` merges byte-identical to
/// `--jobs 1` (including the learned policy, whose per-device state
/// never crosses a shard boundary).
pub fn run(params: &SchedRun<'_>) -> SchedOutcome {
    let &SchedRun { cfg, topo: topo_spec, spec, jobs } = params;
    assert!(topo_spec.devices > 0, "topology needs at least one device");
    assert!(!spec.workloads.is_empty(), "scheduler mix needs at least one workload");
    if !spec.closed {
        return SchedOutcome { report: run_sched_open(cfg, topo_spec, spec, jobs), trace: None };
    }
    let traced = spec.trace.is_some();
    if spec.streams == 0 || spec.requests == 0 {
        let trace = traced
            .then(|| Trace::new(topo_spec.devices, topo_spec.fabric_bw_gbps.is_some(), Vec::new()));
        return SchedOutcome { report: empty_report(topo_spec, spec), trace };
    }
    let pass = prepare_solo_pass(cfg, topo_spec, spec, jobs);
    if traced {
        let (report, trace) = run_closed_traced(topo_spec, spec, &pass, jobs);
        SchedOutcome { report, trace: Some(trace) }
    } else {
        SchedOutcome { report: run_closed_jobs(topo_spec, spec, &pass, jobs), trace: None }
    }
}

/// Deprecated wrapper over [`run`]; kept one release for out-of-tree
/// callers.
#[deprecated(note = "use sched::run with a SchedRun options struct")]
pub fn run_sched(
    cfg: &SimConfig,
    topo_spec: &TopologySpec,
    spec: &SchedSpec,
    jobs: usize,
) -> SchedReport {
    run(&SchedRun::new(cfg, topo_spec, spec).with_jobs(jobs)).report
}

/// Deprecated wrapper over [`run`]; kept one release for out-of-tree
/// callers. Note the tuple shape: [`run`] returns `Some(trace)` only
/// when `spec.trace` is set on a closed-loop run, exactly as this
/// wrapper always did.
#[deprecated(note = "use sched::run with a SchedRun options struct")]
pub fn run_sched_traced(
    cfg: &SimConfig,
    topo_spec: &TopologySpec,
    spec: &SchedSpec,
    jobs: usize,
) -> (SchedReport, Option<Trace>) {
    let out = run(&SchedRun::new(cfg, topo_spec, spec).with_jobs(jobs));
    (out.report, out.trace)
}

/// The closed-loop event engine over an already-prepared solo pass,
/// single-sharded. `pass` must have been prepared with the same
/// topology, workload mix and policy (only `depth`/`admit`/`requests`/
/// `think`/`seed`/`priorities` and the topology's `qos` may vary — none
/// of them affect solo results).
pub(super) fn run_closed(
    topo_spec: &TopologySpec,
    spec: &SchedSpec,
    pass: &SoloPass,
) -> SchedReport {
    assemble(topo_spec, spec, run_closed_core(topo_spec, spec, pass, None, false))
}

/// How many engine shards a run may be partitioned into. Sharding is
/// only sound when the shards share **no** mutable state: `Pinned`
/// placement (a pure function of the tenant id, so each tenant's whole
/// request stream stays on `tenant % devices` — no load/rr coupling),
/// no shared fabric, no fault schedule (faults re-place work across
/// devices). Everything else runs single-sharded.
fn shard_count(topo_spec: &TopologySpec, spec: &SchedSpec, jobs: usize) -> usize {
    let shardable = topo_spec.placement == Placement::Pinned
        && topo_spec.fabric_bw_gbps.is_none()
        && spec.faults.is_empty()
        && topo_spec.devices > 1;
    if shardable {
        jobs.min(topo_spec.devices).max(1)
    } else {
        1
    }
}

/// The closed-loop engine, fanned over up to `jobs` device shards when
/// [`shard_count`] allows. Shard `s` of `n` simulates exactly the
/// devices `{d : d % n == s}` and the tenants pinned to them; the
/// per-shard results are disjoint and merged deterministically
/// (order-free folds), so the merged report is identical to `--jobs 1`
/// — pinned in `rust/tests/sched_regression.rs`.
pub(super) fn run_closed_jobs(
    topo_spec: &TopologySpec,
    spec: &SchedSpec,
    pass: &SoloPass,
    jobs: usize,
) -> SchedReport {
    run_closed_jobs_inner(topo_spec, spec, pass, jobs, false).0
}

/// [`run_closed_jobs`] with the tracer armed: also returns the run's
/// canonical [`Trace`]. Shard event buffers carry disjoint multisets
/// whose union equals the single-shard recording, so the canonical sort
/// makes the merged trace byte-identical to `--jobs 1` — pinned in
/// `rust/tests/sched_regression.rs`.
pub(super) fn run_closed_traced(
    topo_spec: &TopologySpec,
    spec: &SchedSpec,
    pass: &SoloPass,
    jobs: usize,
) -> (SchedReport, Trace) {
    let (report, events) = run_closed_jobs_inner(topo_spec, spec, pass, jobs, true);
    (report, Trace::new(topo_spec.devices, topo_spec.fabric_bw_gbps.is_some(), events))
}

fn run_closed_jobs_inner(
    topo_spec: &TopologySpec,
    spec: &SchedSpec,
    pass: &SoloPass,
    jobs: usize,
    traced: bool,
) -> (SchedReport, Vec<TraceEvent>) {
    let shards = shard_count(topo_spec, spec, jobs);
    let mut raw = if shards <= 1 {
        run_closed_core(topo_spec, spec, pass, None, traced)
    } else {
        let raws: Vec<RawRun> = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..shards)
                .map(|s| {
                    scope.spawn(move || {
                        run_closed_core(topo_spec, spec, pass, Some((s, shards)), traced)
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("shard worker panicked")).collect()
        });
        merge_shards(raws)
    };
    let events = raw.trace.take().unwrap_or_default();
    (assemble(topo_spec, spec, raw), events)
}

/// Fold per-shard raw results into one, equivalent to the unsharded
/// run: requests re-sorted under the global `(tenant, index)` order,
/// each device row taken from its owning shard (every shard carries the
/// full device vector; the rows of devices it does not own stay zero),
/// scalars summed/maxed, sketches counter-merged (all order-free).
/// Shardable runs have no fabric and no faults, so those stay empty.
fn merge_shards(mut raws: Vec<RawRun>) -> RawRun {
    let shards = raws.len();
    let n_dev = raws[0].devices.len();
    let mut requests: Vec<RequestRun> = Vec::new();
    for raw in &mut raws {
        requests.append(&mut raw.requests);
    }
    requests.sort_by_key(|r| (r.tenant, r.index));
    let devices: Vec<DeviceStats> =
        (0..n_dev).map(|d| raws[d % shards].devices[d].clone()).collect();
    let mut sk = raws[0].sk.take();
    if let Some(sk) = sk.as_mut() {
        for raw in raws.iter().skip(1) {
            sk.merge(raw.sk.as_ref().expect("every shard runs the same aggregation mode"));
        }
    }
    // Trace buffers concatenate: shards record disjoint event multisets
    // (each owns its devices and their pinned tenants outright), so the
    // canonical sort downstream restores the single-shard order.
    let mut trace = raws[0].trace.take();
    if let Some(tv) = trace.as_mut() {
        for raw in raws.iter_mut().skip(1) {
            tv.append(raw.trace.as_mut().expect("every shard runs the same tracing mode"));
        }
    }
    let mut merged = RawRun {
        requests,
        sk,
        scheduled: 0,
        failed_requests: 0,
        makespan: 0,
        host_busy: 0,
        proto_mix: BTreeMap::new(),
        devices,
        ccm_busy: 0,
        fabric: FabricReport::default(),
        faults: Vec::new(),
        lost_wire: 0,
        lost_pu: 0,
        trace,
    };
    for raw in &raws {
        merged.scheduled += raw.scheduled;
        merged.failed_requests += raw.failed_requests;
        merged.makespan = merged.makespan.max(raw.makespan);
        merged.host_busy += raw.host_busy;
        merged.ccm_busy += raw.ccm_busy;
        for (p, n) in &raw.proto_mix {
            *merged.proto_mix.entry(*p).or_insert(0) += *n;
        }
    }
    merged
}

/// Raw-result → report assembly: the percentile math, retained from the
/// request vector exactly as PR-6, streamed from the sketches.
fn assemble(topo_spec: &TopologySpec, spec: &SchedSpec, raw: RawRun) -> SchedReport {
    let (p50, p99, max_slowdown, class_rows, streamed) = match &raw.sk {
        None => {
            let slowdowns: Vec<f64> = raw.requests.iter().map(|r| r.slowdown()).collect();
            (
                if slowdowns.is_empty() { 1.0 } else { percentile(&slowdowns, 50.0) },
                if slowdowns.is_empty() { 1.0 } else { percentile(&slowdowns, 99.0) },
                slowdowns.iter().cloned().fold(1.0, f64::max),
                Vec::new(),
                false,
            )
        }
        Some(sk) => {
            let q = |s: &QuantileSketch, p: f64| if s.count() == 0 { 1.0 } else { s.quantile(p) };
            let rows: Vec<(u32, usize, f64, f64)> = sk
                .by_class
                .iter()
                .map(|(&c, s)| (c, s.count() as usize, q(s, 50.0), q(s, 99.0)))
                .collect();
            // Empty-run floor matches the retained fold's 1.0 seed.
            let max = if sk.all.count() == 0 { 1.0 } else { sk.all.max().max(1.0) };
            (q(&sk.all, 50.0), q(&sk.all, 99.0), max, rows, true)
        }
    };
    SchedReport {
        policy: spec.policy,
        qos: topo_spec.qos.policy,
        closed: true,
        depth: spec.depth,
        admit: spec.admit,
        p50_slowdown: p50,
        p99_slowdown: p99,
        max_slowdown,
        requests: raw.requests,
        devices: raw.devices,
        fabric: raw.fabric,
        makespan: raw.makespan,
        host_busy: raw.host_busy,
        ccm_busy: raw.ccm_busy,
        proto_mix: raw.proto_mix,
        faults: raw.faults,
        lost_wire: raw.lost_wire,
        lost_pu: raw.lost_pu,
        failed_requests: raw.failed_requests,
        scheduled: raw.scheduled,
        streamed,
        class_rows,
    }
}

/// One shard of the closed-loop event engine (the whole run when
/// `shard` is `None`). Returns the raw, unassembled result.
fn run_closed_core(
    topo_spec: &TopologySpec,
    spec: &SchedSpec,
    pass: &SoloPass,
    shard: Option<(usize, usize)>,
    traced: bool,
) -> RawRun {
    assert!(spec.depth > 0, "closed-loop window needs depth >= 1");
    assert!(spec.admit > 0, "device admission needs at least one service slot");
    let SoloPass { class_cfgs, class_of, annots, table, cand_table } = pass;
    // The decision layer: one stateful decider per shard picks placement
    // + protocol and hears every completion's decomposed latency. On a
    // shardable (pinned) topology each shard's decider only ever sees
    // decisions and completions for the devices the shard owns, so
    // per-device decider state never crosses a shard boundary and the
    // merged run stays byte-identical to `--jobs 1`.
    let mut decider = decider_for(spec);
    // Reusable per-decision view buffer — cleared and refilled at every
    // submission, so the steady state allocates nothing.
    let mut views: Vec<DeviceView<'_>> = Vec::with_capacity(topo_spec.devices);
    // Online QoS link scheduling: under FCFS the qos states stay `None`
    // and every calendar keeps the PR-4 admission-order charging
    // verbatim; under WRR/DRR each shared wire carries a persistent
    // [`QosState`] consulted at every admission batch. DRR quanta are
    // sized by the largest message any candidate solo trace can offer —
    // the online analogue of the replay's per-input maximum.
    let qos = &topo_spec.qos;
    let max_bytes = table
        .runs
        .iter()
        .flat_map(|s| s.run.mem_trace.iter().chain(s.run.io_trace.iter()))
        .map(|m| m.bytes)
        .max()
        .unwrap_or(1);
    let online_qos =
        || (qos.policy != QosPolicy::Fcfs).then(|| QosState::new(qos, spec.streams, max_bytes));
    // Only fault schedules ever truncate calendars or pools, so only
    // they pay for per-message/per-span logs; fault-free runs keep O(1)
    // resource-model state regardless of run length.
    let faulted = !spec.faults.is_empty();
    let mut devs: Vec<DevState> = (0..topo_spec.devices)
        .map(|d| DevState {
            class: class_of[d],
            link_bw: class_cfgs[class_of[d]].cxl_bw_gbps,
            mem: if faulted { LinkCalendar::default() } else { LinkCalendar::untracked() },
            io: if faulted { LinkCalendar::default() } else { LinkCalendar::untracked() },
            qos_mem: online_qos(),
            qos_io: online_qos(),
            pool: if faulted {
                OnlinePool::new(class_cfgs[class_of[d]].ccm.num_pus)
            } else {
                OnlinePool::untracked(class_cfgs[class_of[d]].ccm.num_pus)
            },
            queue: AdmitQueue::default(),
            in_service: 0,
            stats: DeviceStats::default(),
            alive: true,
            admit_open: true,
            bw_factor: 1.0,
            pu_factor: 1.0,
        })
        .collect();
    // The fabric calendar is never truncated (faults kill devices, not
    // the fabric), so it never needs the message log.
    let mut fabric = Fabric {
        link: topo_spec.fabric_bw_gbps.map(|bw| (bw, LinkCalendar::untracked())),
        qos: if topo_spec.fabric_bw_gbps.is_some() { online_qos() } else { None },
        wait: 0,
        bytes: 0,
    };
    let mut tenants: Vec<TenantState> = (0..spec.streams)
        .map(|_| TenantState { next_index: 0, outstanding: 0, submit_scheduled: false })
        .collect();
    // Retained mode pre-sizes for every request (the PR-6 layout);
    // streaming mode starts empty and grows only to the live window.
    let mut arena = ReqArena::new(
        !spec.retain,
        if spec.retain { spec.streams * spec.requests } else { 0 },
    );
    let mut agg: Option<Agg> = (!spec.retain).then(Agg::new);
    let mut heap: BinaryHeap<Reverse<Ev>> = BinaryHeap::new();
    let mut rr_next = 0usize;
    // Deterministic event tracing: every recording site below is behind
    // this option, and the engine never reads it back — tracing is
    // observation-only by construction (the trace-on/off bit-identity
    // pin in tests/sched_regression.rs).
    let mut tr: Option<Tracer> = traced.then(Tracer::new);

    // Fault-injection runtime: constructed only when the spec schedules
    // events. The fault-free path never builds one, never reroutes
    // placement, and never packs a nonzero attempt into an event id —
    // the empty-FaultSpec bit-identity pin in tests/sched_regression.rs.
    let mut fx: Option<FaultRuntime> = if spec.faults.is_empty() {
        None
    } else {
        spec.faults
            .validate(topo_spec.devices)
            .unwrap_or_else(|e| panic!("invalid fault spec: {e}"));
        Some(FaultRuntime::new(&spec.faults))
    };
    if fx.is_some() {
        for (i, e) in spec.faults.events.iter().enumerate() {
            // Zero-duration degrade/stall windows schedule no runtime
            // transitions at all — such a run stays bit-identical to
            // fault-free (their outcome rows still report, with zeros).
            if e.kind == FaultKind::Fail || e.until > e.at {
                heap.push(Reverse((e.at, 2, i as u64, 0)));
            }
            if e.kind != FaultKind::Fail && e.until > e.at {
                heap.push(Reverse((e.until, 2, i as u64, 1)));
            }
        }
    }

    // Chunked stage-DAG admission: pre-build one stage graph per solo
    // run, shared by every request of its (class, annot, proto) point.
    // `chunks() == 1` never constructs this, so whole-request admission
    // stays byte-identical to the PR-7 engine.
    let mut pipe: Option<PipeRt> = (spec.chunks() > 1).then(|| {
        let mut graphs: Vec<Option<StageGraph>> = vec![None; table.runs.len()];
        for (&(_, _, proto), &i) in &table.idx {
            let s = &table.runs[i];
            graphs[i] = Some(stage_graph_for(
                proto,
                spec.chunk_mode(),
                spec.chunks(),
                s.run.mem_trace.len(),
                s.run.io_trace.len(),
                s.run.ccm_trace.len(),
            ));
        }
        PipeRt {
            graphs: graphs.into_iter().map(|g| g.expect("every solo run is indexed")).collect(),
            released: Vec::new(),
        }
    });

    // Seeded per-tenant start stagger (same role as the open-loop
    // arrival jitter: break exact ties without coupling tenants). Every
    // shard draws the full tenant sequence — identical per-tenant values
    // regardless of shard count — but seeds submissions only for the
    // tenants whose pinned device it owns.
    let mut rng = Pcg32::seed_from_u64(spec.seed ^ 0x5C4E_D0C1_05ED_0001);
    for (t, ten) in tenants.iter_mut().enumerate() {
        let start = rng.below(US);
        let owned = match shard {
            None => true,
            Some((s, n)) => (t % topo_spec.devices) % n == s,
        };
        if owned {
            ten.submit_scheduled = true;
            heap.push(Reverse((start, 1, t as u64, 0)));
        }
    }

    while let Some(Reverse((now, kind, id, seq))) = heap.pop() {
        match kind {
            0 => {
                // ---- Completion on device `id & u32::MAX` of the
                // request holding ticket `seq`, scheduled under attempt
                // `id >> 32`. ----
                let d = (id & u32::MAX as u64) as usize;
                let Some(rid) = arena.slot_of(seq) else {
                    // Ticket already retired: a stale completion whose
                    // slot was recycled (streaming fault mode only).
                    continue;
                };
                if let Some(f) = fx.as_mut() {
                    if f.rstate[rid].attempt != (id >> 32) as u32 {
                        // Stale completion of a killed or suspended
                        // attempt: the kill already released the slot.
                        continue;
                    }
                    f.rstate[rid].loc = Loc::Done;
                }
                let t = arena.runs[rid].tenant as usize;
                // A pipelined chunked request may have freed its slot at
                // its last CCM stage already (kind 5) — don't free twice.
                let early_released = match pipe.as_mut() {
                    Some(p) if rid < p.released.len() => {
                        std::mem::replace(&mut p.released[rid], false)
                    }
                    _ => false,
                };
                if !early_released {
                    devs[d].in_service -= 1;
                }
                tenants[t].outstanding -= 1;
                if let Some(a) = agg.as_mut() {
                    let r = &arena.runs[rid];
                    a.finish(r, table.get(devs[d].class, r.annot, r.proto).run.metrics.host_busy);
                }
                if let Some(tx) = tr.as_mut() {
                    let r = &arena.runs[rid];
                    tx.push(TraceEvent::Complete {
                        at: now,
                        tenant: r.tenant,
                        index: r.index,
                        device: d as u32,
                        submit: r.submit,
                        admit: r.admit,
                        solo: r.solo,
                        host_busy: table
                            .get(devs[d].class, r.annot, r.proto)
                            .run
                            .metrics
                            .host_busy,
                    });
                }
                {
                    // Feed the completion's decomposed latency back into
                    // the decision layer (stateless deciders ignore it).
                    let r = &arena.runs[rid];
                    decider.observe(&Feedback {
                        tenant: t,
                        index: r.index as u64,
                        annot: r.annot,
                        device: d,
                        device_class: devs[d].class,
                        proto: r.proto,
                        queue_wait: r.queue_wait(),
                        solo: r.solo,
                        wire_wait: r.wire_wait(),
                        pu_wait: r.pu_wait,
                    });
                }
                arena.release(rid);
                schedule_submit(&mut tenants[t], t, spec, now, &mut heap);
                try_admit(
                    now, d, spec, &mut devs[d], table, &mut fabric, &mut arena, &mut heap,
                    &mut fx, &mut pipe, &mut tr,
                );
            }
            1 => {
                // ---- Submission by tenant `id`. ----
                let t = id as usize;
                tenants[t].submit_scheduled = false;
                let annot = annots[t];
                let index = tenants[t].next_index as u32;
                tenants[t].next_index += 1;
                tenants[t].outstanding += 1;
                // Build the decision layer's per-device views (live
                // occupancy + class candidate profiles), then let the
                // run's decider pick placement and protocol together.
                // The policy deciders replicate the historical inline
                // sequence (place_device / fault-aware probe, then
                // choose on the placed device's view) bit-for-bit.
                views.clear();
                for dev in devs.iter() {
                    views.push(DeviceView {
                        class: dev.class,
                        alive: dev.alive,
                        eligible: dev.alive && dev.admit_open,
                        load: dev.stats.load,
                        obs: Observed {
                            mem_backlog: dev.mem.tail().saturating_sub(now),
                            io_backlog: dev.io.tail().saturating_sub(now),
                            pu_backlog: dev.pool.earliest_free().saturating_sub(now),
                            queued: dev.queue.len(),
                        },
                        cands: &cand_table[&(dev.class, annot)],
                    });
                }
                let ctx = RequestCtx {
                    tenant: t,
                    index: index as u64,
                    annot,
                    now,
                    placement: topo_spec.placement,
                    faulted: fx.is_some(),
                    devices: &views,
                };
                let Decision { device: d, proto } = decider.decide(&ctx, &mut rr_next);
                let solo_total = table.get(devs[d].class, annot, proto).run.metrics.total;
                let class = spec.priority(t);
                let (ticket, rid) = arena.alloc();
                {
                    let r = &mut arena.runs[rid];
                    r.tenant = t as u32;
                    r.index = index;
                    r.annot = annot;
                    r.class = class;
                    r.device = d as u32;
                    r.proto = proto;
                    r.submit = now;
                    r.admit = now;
                    r.solo = solo_total;
                    r.device_wait = 0;
                    r.fabric_wait = 0;
                    r.pu_wait = 0;
                    r.completion = now;
                    r.retry_wait = 0;
                    r.retries = 0;
                    r.placed_on.push(d as u32);
                    r.failed = false;
                }
                if let Some(tx) = tr.as_mut() {
                    tx.push(TraceEvent::Submit {
                        at: now,
                        tenant: t as u32,
                        index,
                        class,
                        device: d as u32,
                        proto,
                    });
                }
                devs[d].stats.tenants += 1;
                devs[d].stats.load += solo_total;
                devs[d].queue.push(rid as u32, class);
                if let Some(f) = fx.as_mut() {
                    if rid < f.rstate.len() {
                        // Recycled slot: reset its fault-layer state,
                        // carrying the attempt counter so completions of
                        // the slot's previous life stay stale.
                        f.rstate[rid].recycle(d as u32, now);
                    } else {
                        f.rstate.push(ReqState::queued(d as u32, now));
                    }
                    if !devs[d].admit_open {
                        // Forced onto a non-admitting device (everything
                        // else is down): arm a timeout so the request
                        // cannot be stranded if the device never recovers.
                        let expiry = now + f.timeout(solo_total);
                        let attempt = f.rstate[rid].attempt as u64;
                        heap.push(Reverse((expiry, 4, ticket, attempt)));
                    }
                }
                try_admit(
                    now, d, spec, &mut devs[d], table, &mut fabric, &mut arena, &mut heap,
                    &mut fx, &mut pipe, &mut tr,
                );
                // Window depth > 1: the tenant may pipeline its next request.
                schedule_submit(&mut tenants[t], t, spec, now, &mut heap);
            }
            2 => {
                // ---- Fault transition: spec event `id` starts (seq 0)
                // or its window ends (seq 1). ----
                if seq == 0 {
                    fault_start(
                        id as usize, now, topo_spec, spec, &mut devs, &mut tenants, table,
                        &mut fabric, &mut arena, &mut agg, &mut heap, &mut rr_next, &mut fx,
                        &mut pipe, &mut tr,
                    );
                } else {
                    fault_end(
                        id as usize, now, spec, &mut devs, table, &mut fabric, &mut arena,
                        &mut heap, &mut fx, &mut pipe, &mut tr,
                    );
                }
            }
            3 => {
                // ---- Requeue arrival: the request holding ticket `id`
                // finished its backoff under attempt `seq`. ----
                let Some(rid) = arena.slot_of(id) else {
                    continue;
                };
                let live = {
                    let f = fx.as_ref().expect("requeue events only exist in fault mode");
                    f.rstate[rid].attempt == seq as u32 && f.rstate[rid].loc == Loc::Backoff
                };
                if live {
                    re_place(
                        rid, now, topo_spec, spec, &mut devs, table, &mut fabric, &mut arena,
                        &mut heap, &mut rr_next, &mut fx, &mut pipe, true, &mut tr,
                    );
                }
            }
            5 => {
                // ---- Pipeline early release: the request holding
                // ticket `id` finished its last CCM stage on device
                // `seq`; the admission slot frees while its back-stream
                // drains (fault-free chunked runs only). ----
                let Some(rid) = arena.slot_of(id) else {
                    continue;
                };
                let d = seq as usize;
                let fire = {
                    let p = pipe.as_mut().expect("release events only exist in chunked mode");
                    if rid < p.released.len() && !p.released[rid] {
                        p.released[rid] = true;
                        true
                    } else {
                        false
                    }
                };
                if fire {
                    if let Some(tx) = tr.as_mut() {
                        let r = &arena.runs[rid];
                        tx.push(TraceEvent::EarlyRelease {
                            at: now,
                            tenant: r.tenant,
                            index: r.index,
                            device: d as u32,
                        });
                    }
                    devs[d].in_service -= 1;
                    try_admit(
                        now, d, spec, &mut devs[d], table, &mut fabric, &mut arena, &mut heap,
                        &mut fx, &mut pipe, &mut tr,
                    );
                }
            }
            _ => {
                // ---- Timeout check: the request holding ticket `id`,
                // armed under attempt `seq`. Fires only if the request
                // is still queued on a device that is still not
                // admitting. ----
                let Some(rid) = arena.slot_of(id) else {
                    continue;
                };
                let stuck = {
                    let f = fx.as_ref().expect("timeout events only exist in fault mode");
                    let st = &f.rstate[rid];
                    st.attempt == seq as u32
                        && st.loc == Loc::Queued
                        && !devs[st.loc_dev as usize].admit_open
                };
                if stuck {
                    let f = fx.as_mut().expect("timeout events only exist in fault mode");
                    let d = f.rstate[rid].loc_dev as usize;
                    f.rstate[rid].attempt += 1;
                    devs[d].queue.remove(rid as u32, arena.runs[rid].class);
                    if let Some(tx) = tr.as_mut() {
                        let r = &arena.runs[rid];
                        tx.push(TraceEvent::Timeout {
                            at: now,
                            tenant: r.tenant,
                            index: r.index,
                            device: d as u32,
                        });
                    }
                    retry_or_fail(
                        rid, now, false, spec, &mut tenants, &mut arena, &mut agg, &mut heap, f,
                        &mut tr,
                    );
                }
            }
        }
    }

    // ---- Raw assembly. ----
    let (faults, lost_wire, lost_pu) = match fx {
        Some(f) => {
            let lw = f.outcomes.iter().map(|o| o.lost_wire).sum();
            let lp = f.outcomes.iter().map(|o| o.lost_pu).sum();
            (f.outcomes, lw, lp)
        }
        None => (Vec::new(), 0, 0),
    };
    let (requests, sk, scheduled, failed_requests, makespan, host_busy, proto_mix) = match agg {
        None => {
            // Retained: the PR-6 post-hoc computation, verbatim.
            let mut requests = arena.runs;
            requests.sort_by_key(|r| (r.tenant, r.index));
            let failed_requests = requests.iter().filter(|r| r.failed).count();
            let makespan = requests.iter().map(|r| r.completion).max().unwrap_or(0);
            let host_busy = requests
                .iter()
                .filter(|r| !r.failed)
                .map(|r| {
                    table.get(devs[r.device as usize].class, r.annot, r.proto).run.metrics.host_busy
                })
                .sum();
            let mut proto_mix: BTreeMap<&'static str, u64> = BTreeMap::new();
            for r in &requests {
                *proto_mix.entry(r.proto.label()).or_insert(0) += 1;
            }
            let scheduled = requests.len() as u64;
            (requests, None, scheduled, failed_requests, makespan, host_busy, proto_mix)
        }
        Some(a) => {
            // Streaming: everything was folded per terminal request.
            (Vec::new(), Some(a.sk), a.scheduled, a.failed as usize, a.makespan, a.host_busy,
             a.proto_mix)
        }
    };
    let mut ccm_busy: Ps = 0;
    let devices: Vec<DeviceStats> = devs
        .iter_mut()
        .map(|dev| {
            dev.stats.pu_busy = dev.pool.busy_union();
            dev.stats.link_busy = dev.mem.busy_union() + dev.io.busy_union();
            ccm_busy += dev.stats.pu_busy;
            dev.stats.clone()
        })
        .collect();
    let fabric_report = match &fabric.link {
        Some((bw, cal)) => FabricReport {
            bw_gbps: Some(*bw),
            messages: cal.msgs(),
            bytes: fabric.bytes,
            busy: cal.busy_union(),
            wait: fabric.wait,
            utilization: if makespan == 0 {
                0.0
            } else {
                cal.busy_union() as f64 / makespan as f64
            },
        },
        None => FabricReport::default(),
    };
    RawRun {
        requests,
        sk,
        scheduled,
        failed_requests,
        makespan,
        host_busy,
        proto_mix,
        devices,
        ccm_busy,
        fabric: fabric_report,
        faults,
        lost_wire,
        lost_pu,
        trace: tr.map(|t| t.events),
    }
}

/// The shared upstream fabric's online state.
struct Fabric {
    link: Option<(f64, LinkCalendar)>,
    /// Online WRR/DRR scheduler state for the fabric wire (`None` under
    /// FCFS or when no fabric is modelled).
    qos: Option<QosState>,
    wait: Ps,
    bytes: u64,
}

/// Schedule the tenant's next submission if its window has room and it
/// has requests left (at most one pending submission event per tenant).
fn schedule_submit(
    ten: &mut TenantState,
    t: usize,
    spec: &SchedSpec,
    now: Ps,
    heap: &mut BinaryHeap<Reverse<Ev>>,
) {
    if !ten.submit_scheduled && ten.next_index < spec.requests && ten.outstanding < spec.depth {
        ten.submit_scheduled = true;
        heap.push(Reverse((now + spec.think, 1, t as u64, ten.next_index as u64)));
    }
}

/// Fault-aware placement: among alive devices, preferring ones whose
/// admission gate is open (not stalled). Only consulted when a fault
/// schedule is active — the fault-free path calls
/// [`crate::topo::place_device`] directly and identically (with every
/// device alive and admitting the filtered variants choose the same
/// device, so a schedule whose windows never open still matches
/// fault-free placement exactly).
fn pick_device(
    topo_spec: &TopologySpec,
    devs: &[DevState],
    ordinal: usize,
    rr_next: &mut usize,
) -> usize {
    crate::topo::place_device_filtered(
        topo_spec.placement,
        devs.len(),
        ordinal,
        |i| devs[i].alive && devs[i].admit_open,
        |i| devs[i].stats.load,
        rr_next,
    )
    .or_else(|| {
        // Everything alive is stalled: place on a stalled device anyway
        // (timeouts keep the request from being stranded there).
        crate::topo::place_device_filtered(
            topo_spec.placement,
            devs.len(),
            ordinal,
            |i| devs[i].alive,
            |i| devs[i].stats.load,
            rr_next,
        )
    })
    .expect("validated fault spec leaves at least one device alive")
}

/// Apply fault event `i` at its onset `now`: install degradation
/// factors, shut the admission gate (suspending in-service work and
/// arming queue timeouts) on a stall, or remove the device outright on
/// a permanent failure —
/// killing in-service attempts (their charges become the fault's lost
/// work), draining the queue onto survivors, and truncating the dead
/// device's calendars and pool so its phantom future work vanishes from
/// the busy accounting. The shared fabric calendar is deliberately NOT
/// truncated: killed requests' upstream occupancy really blocked the
/// wire, and that waste is what `lost_wire` measures.
#[allow(clippy::too_many_arguments)]
fn fault_start(
    i: usize,
    now: Ps,
    topo_spec: &TopologySpec,
    spec: &SchedSpec,
    devs: &mut [DevState],
    tenants: &mut [TenantState],
    table: &SoloTable,
    fabric: &mut Fabric,
    arena: &mut ReqArena,
    agg: &mut Option<Agg>,
    heap: &mut BinaryHeap<Reverse<Ev>>,
    rr_next: &mut usize,
    fx: &mut Option<FaultRuntime>,
    pipe: &mut Option<PipeRt>,
    tr: &mut Option<Tracer>,
) {
    let e = spec.faults.events[i];
    let d = e.device as usize;
    if let Some(tx) = tr.as_mut() {
        tx.push(TraceEvent::FaultBegin {
            at: now,
            device: d as u32,
            kind: e.kind,
            until: (e.kind != FaultKind::Fail).then_some(e.until),
        });
    }
    match e.kind {
        FaultKind::DegradePus => devs[d].pu_factor = e.factor,
        FaultKind::DegradeLink => devs[d].bw_factor = e.factor,
        FaultKind::Stall => {
            devs[d].admit_open = false;
            let f = fx.as_mut().expect("fault transitions only exist in fault mode");
            // Suspend in-service work: completion (and its pu_wait
            // charge) slides by the remaining window. The old completion
            // event goes stale via the attempt bump; the device resumes
            // where it left off, so these requests recover exactly at
            // the window end. The slot sweep covers live requests only
            // (recycled slots sit at Done/Failed and never match).
            let delta = e.until - now;
            for rid in 0..arena.runs.len() {
                let st = &mut f.rstate[rid];
                if st.loc == Loc::InService && st.loc_dev == d as u32 {
                    let r = &mut arena.runs[rid];
                    r.completion += delta;
                    r.pu_wait += delta;
                    // Chunked attempts: chunks still incomplete at the
                    // stall onset slide with the suspension too, so a
                    // later kill still loses exactly the right chunks.
                    st.slide_pending_chunks(now, delta);
                    st.attempt += 1;
                    let ev_id = ((st.attempt as u64) << 32) | d as u64;
                    heap.push(Reverse((r.completion, 0, ev_id, arena.tickets[rid])));
                    f.outcomes[i].displaced += 1;
                    f.outcomes[i].recover = f.outcomes[i].recover.max(e.until - e.at);
                }
            }
            // Queued work gets a requeue timeout sized from its solo
            // estimate; it fires only if the device is still stalled.
            for rid in devs[d].queue.iter_rids() {
                let st = &f.rstate[rid as usize];
                let expiry = (st.enqueued + f.timeout(arena.runs[rid as usize].solo)).max(now);
                heap.push(Reverse((expiry, 4, arena.tickets[rid as usize], st.attempt as u64)));
            }
        }
        FaultKind::Fail => {
            devs[d].alive = false;
            devs[d].admit_open = false;
            // Kill in-service attempts: wire/PU charges are lost work,
            // the requests retry with backoff on surviving devices.
            let killed: Vec<usize> = {
                let f = fx.as_ref().expect("fault transitions only exist in fault mode");
                (0..arena.runs.len())
                    .filter(|&rid| {
                        let st = &f.rstate[rid];
                        st.loc == Loc::InService && st.loc_dev == d as u32
                    })
                    .collect()
            };
            for &rid in &killed {
                devs[d].in_service -= 1;
                let f = fx.as_mut().expect("fault transitions only exist in fault mode");
                let st = &mut f.rstate[rid];
                st.attempt += 1;
                st.displaced_by = Some(i);
                // Chunk-granular loss: completed chunks' wire/PU time is
                // banked — only chunks still in flight at the kill count
                // as lost work. Unchunked attempts fall back to the whole
                // attempt totals inside `lost_work`.
                let (w, p) = st.lost_work(now);
                f.outcomes[i].displaced += 1;
                f.outcomes[i].lost_wire += w;
                f.outcomes[i].lost_pu += p;
                retry_or_fail(rid, now, true, spec, tenants, arena, agg, heap, f, tr);
            }
            // Drain the admission queue in order onto survivors. These
            // requests never started, so re-placement is free: no retry
            // consumed, no backoff, queue time keeps accruing normally.
            while let Some(rid) = devs[d].queue.pop_front_fifo() {
                {
                    let f = fx.as_mut().expect("fault transitions only exist in fault mode");
                    f.outcomes[i].displaced += 1;
                    f.rstate[rid as usize].displaced_by = Some(i);
                }
                re_place(
                    rid as usize, now, topo_spec, spec, devs, table, fabric, arena, heap, rr_next,
                    fx, pipe, false, tr,
                );
            }
            devs[d].mem.truncate(now);
            devs[d].io.truncate(now);
            devs[d].pool.truncate(now);
            // Mirror the calendar/pool truncation onto recorded grants so
            // the trace's busy unions stay equal to the report's.
            if let Some(tx) = tr.as_mut() {
                tx.truncate_device(d as u32, now);
            }
        }
    }
}

/// Close fault event `i`'s window at `now`: degradation factors reset
/// to exactly 1.0, a stalled device reopens its admission gate and
/// immediately admits what queued up during the window. Permanent
/// failures never schedule an end event.
#[allow(clippy::too_many_arguments)]
fn fault_end(
    i: usize,
    now: Ps,
    spec: &SchedSpec,
    devs: &mut [DevState],
    table: &SoloTable,
    fabric: &mut Fabric,
    arena: &mut ReqArena,
    heap: &mut BinaryHeap<Reverse<Ev>>,
    fx: &mut Option<FaultRuntime>,
    pipe: &mut Option<PipeRt>,
    tr: &mut Option<Tracer>,
) {
    let e = spec.faults.events[i];
    let d = e.device as usize;
    if let Some(tx) = tr.as_mut() {
        tx.push(TraceEvent::FaultEnd { at: now, device: d as u32, kind: e.kind });
    }
    match e.kind {
        FaultKind::DegradePus => devs[d].pu_factor = 1.0,
        FaultKind::DegradeLink => devs[d].bw_factor = 1.0,
        FaultKind::Stall => {
            // `alive` guard: a permanent failure may have struck after
            // this stall began — the gate stays shut forever then.
            if devs[d].alive {
                devs[d].admit_open = true;
                try_admit(now, d, spec, &mut devs[d], table, fabric, arena, heap, fx, pipe, tr);
            }
        }
        FaultKind::Fail => unreachable!("permanent failures schedule no end event"),
    }
}

/// Queue request `rid` on a freshly chosen surviving device: placement
/// provenance and load accounting are updated, the solo estimate is
/// re-resolved against the new device's class (heterogeneous topologies
/// may re-place onto a different class), and admission is attempted
/// immediately. Used by requeue-after-backoff and the failure drain.
#[allow(clippy::too_many_arguments)]
fn re_place(
    rid: usize,
    now: Ps,
    topo_spec: &TopologySpec,
    spec: &SchedSpec,
    devs: &mut [DevState],
    table: &SoloTable,
    fabric: &mut Fabric,
    arena: &mut ReqArena,
    heap: &mut BinaryHeap<Reverse<Ev>>,
    rr_next: &mut usize,
    fx: &mut Option<FaultRuntime>,
    pipe: &mut Option<PipeRt>,
    from_backoff: bool,
    tr: &mut Option<Tracer>,
) {
    let ordinal = arena.runs[rid].tenant as usize;
    let d = pick_device(topo_spec, devs, ordinal, rr_next);
    let class = {
        let r = &mut arena.runs[rid];
        r.device = d as u32;
        r.placed_on.push(d as u32);
        r.solo = table.get(devs[d].class, r.annot, r.proto).run.metrics.total;
        devs[d].stats.tenants += 1;
        devs[d].stats.load += r.solo;
        r.class
    };
    devs[d].queue.push(rid as u32, class);
    if let Some(tx) = tr.as_mut() {
        let r = &arena.runs[rid];
        tx.push(TraceEvent::Requeue {
            at: now,
            tenant: r.tenant,
            index: r.index,
            device: d as u32,
            from_backoff,
        });
    }
    {
        let f = fx.as_mut().expect("re-placement only exists in fault mode");
        let timeout = f.timeout(arena.runs[rid].solo);
        let st = &mut f.rstate[rid];
        st.loc = Loc::Queued;
        st.loc_dev = d as u32;
        st.enqueued = now;
        if !devs[d].admit_open {
            // Forced onto a stalled device (everything else is down):
            // arm a timeout so the run can never hang here.
            heap.push(Reverse((now + timeout, 4, arena.tickets[rid], st.attempt as u64)));
        }
    }
    try_admit(now, d, spec, &mut devs[d], table, fabric, arena, heap, fx, pipe, tr);
}

/// Consume one retry for request `rid` at `now`. Within budget: charge
/// `retry_wait` (a killed in-service attempt forfeits its whole service
/// time plus the backoff; a timed-out queued request pays only the
/// backoff — its queue time stays inside `queue_wait`) and schedule the
/// requeue arrival after exponential backoff. Out of budget: the
/// request is dropped — its record closes at the drop instant with
/// zeroed service charges (`failed = true`) and the tenant's window
/// reopens so the rest of the run proceeds. Graceful degradation means
/// a faulted run terminates either way.
#[allow(clippy::too_many_arguments)]
fn retry_or_fail(
    rid: usize,
    now: Ps,
    from_service: bool,
    spec: &SchedSpec,
    tenants: &mut [TenantState],
    arena: &mut ReqArena,
    agg: &mut Option<Agg>,
    heap: &mut BinaryHeap<Reverse<Ev>>,
    f: &mut FaultRuntime,
    tr: &mut Option<Tracer>,
) {
    let max_retries = f.spec.max_retries;
    f.rstate[rid].retries += 1;
    let retries = f.rstate[rid].retries;
    arena.runs[rid].retries = retries;
    if retries > max_retries {
        f.rstate[rid].loc = Loc::Failed;
        let t = {
            let r = &mut arena.runs[rid];
            r.failed = true;
            if from_service {
                r.retry_wait += now - r.admit;
            }
            r.admit = now;
            r.device_wait = 0;
            r.fabric_wait = 0;
            r.pu_wait = 0;
            r.completion = now;
            r.tenant as usize
        };
        if let Some(tx) = tr.as_mut() {
            let r = &arena.runs[rid];
            tx.push(TraceEvent::Failed {
                at: now,
                tenant: r.tenant,
                index: r.index,
                device: r.device,
                submit: r.submit,
            });
        }
        // A dropped request is terminal: fold it into the streaming
        // aggregates (no host-busy charge — its solo work never
        // completed) and retire its slot.
        if let Some(a) = agg.as_mut() {
            a.finish(&arena.runs[rid], 0);
        }
        arena.release(rid);
        tenants[t].outstanding -= 1;
        schedule_submit(&mut tenants[t], t, spec, now, heap);
    } else {
        let delay = f.backoff_delay(retries);
        let attempt = f.rstate[rid].attempt as u64;
        f.rstate[rid].loc = Loc::Backoff;
        let r = &mut arena.runs[rid];
        r.retry_wait += if from_service { (now - r.admit) + delay } else { delay };
        if let Some(tx) = tr.as_mut() {
            tx.push(TraceEvent::Retry {
                at: now,
                tenant: r.tenant,
                index: r.index,
                retries,
                backoff: delay,
                from_service,
            });
        }
        heap.push(Reverse((now + delay, 3, arena.tickets[rid], attempt)));
    }
}

/// Admit queued requests into service while the device has free slots,
/// charging each one's contention against the online resource models.
/// The admission *batch* (everything entering service at this instant)
/// is popped in [`AdmitQueue::pop_admit`] order — earliest-queued of
/// the highest present class; with all classes equal that is exactly
/// the PR-4 FIFO `pop_front`, which keeps default-priority calendars
/// bit-identical, and a higher class jumps the queue at admission time
/// but never revokes in-service work. The batch's wire traffic is then
/// charged
/// either in pure admission order (FCFS — the PR-4 path, verbatim) or
/// through the per-wire [`QosState`] schedulers (WRR/DRR). A stalled or
/// dead device keeps its admission gate shut (`admit_open == false`)
/// and this is a no-op — as it is on an empty queue, which any device
/// can be drained to mid-run once faults redistribute work.
#[allow(clippy::too_many_arguments)]
fn try_admit(
    now: Ps,
    d: usize,
    spec: &SchedSpec,
    dev: &mut DevState,
    table: &SoloTable,
    fabric: &mut Fabric,
    arena: &mut ReqArena,
    heap: &mut BinaryHeap<Reverse<Ev>>,
    fx: &mut Option<FaultRuntime>,
    pipe: &mut Option<PipeRt>,
    tr: &mut Option<Tracer>,
) {
    if !dev.admit_open {
        return;
    }
    let mut batch: Vec<u32> = Vec::new();
    while dev.in_service + batch.len() < spec.admit {
        let Some(rid) = dev.queue.pop_admit() else { break };
        batch.push(rid);
    }
    if batch.is_empty() {
        return;
    }
    if let Some(p) = pipe.as_mut() {
        admit_chunked(now, d, dev, table, fabric, arena, heap, &batch, fx, p, tr);
    } else if dev.qos_mem.is_none() {
        admit_fcfs(now, d, dev, table, fabric, arena, heap, &batch, fx, tr);
    } else {
        admit_qos(now, d, spec.streams, dev, table, fabric, arena, heap, &batch, fx, tr);
    }
}

/// Charge one admission batch in pure admission order — the PR-4 online
/// contention accounting. Outside link-degradation windows
/// `bw == dev.link_bw` exactly (`x / 1.0`), every lateness expression
/// reduces to the historical `start - issue`, and the path stays the
/// FCFS bit-identity pin; inside a window the device link serializes at
/// `link_bw / bw_factor` and each message's own inflated serialization
/// is charged against its full-bandwidth solo finish.
#[allow(clippy::too_many_arguments)]
fn admit_fcfs(
    now: Ps,
    d: usize,
    dev: &mut DevState,
    table: &SoloTable,
    fabric: &mut Fabric,
    arena: &mut ReqArena,
    heap: &mut BinaryHeap<Reverse<Ev>>,
    batch: &[u32],
    fx: &mut Option<FaultRuntime>,
    tr: &mut Option<Tracer>,
) {
    let bw = dev.link_bw / dev.bw_factor;
    for &rid in batch {
        let (annot, proto, tnt, ridx) = {
            let r = &arena.runs[rid as usize];
            (r.annot, r.proto, r.tenant, r.index)
        };
        let s = table.get(dev.class, annot, proto);
        let a = now;
        // Device-link replay: lateness is the finish shift versus the
        // solo finish at the trace's recorded bandwidth.
        let mut mem_late: Ps = 0;
        for m in &s.run.mem_trace {
            let issue = a + m.start;
            let dur = transfer_ps(m.bytes, bw);
            let start = dev.mem.place(issue, dur);
            if dur > 0 {
                if let Some(tx) = tr.as_mut() {
                    tx.push(TraceEvent::WireGrant {
                        at: start,
                        dur,
                        device: d as u32,
                        wire: Wire::Mem,
                        tenant: tnt,
                        index: ridx,
                        chunk: 0,
                    });
                }
            }
            let solo_finish = issue + transfer_ps(m.bytes, dev.link_bw);
            mem_late = mem_late.max((start + dur).saturating_sub(solo_finish));
        }
        let mut io_late: Ps = 0;
        for m in &s.run.io_trace {
            let issue = a + m.start;
            let dur = transfer_ps(m.bytes, bw);
            let start = dev.io.place(issue, dur);
            if dur > 0 {
                if let Some(tx) = tr.as_mut() {
                    tx.push(TraceEvent::WireGrant {
                        at: start,
                        dur,
                        device: d as u32,
                        wire: Wire::Io,
                        tenant: tnt,
                        index: ridx,
                        chunk: 0,
                    });
                }
            }
            let solo_finish = issue + transfer_ps(m.bytes, dev.link_bw);
            io_late = io_late.max((start + dur).saturating_sub(solo_finish));
        }
        // Shared-fabric replay: the same bytes cross the upstream link;
        // lateness compares against the solo finish at device bandwidth.
        let mut fab_late: Ps = 0;
        if let Some((fbw, cal)) = fabric.link.as_mut() {
            for m in s.run.mem_trace.iter().chain(s.run.io_trace.iter()) {
                let issue = a + m.start;
                let ser_f = transfer_ps(m.bytes, *fbw);
                let start = cal.place(issue, ser_f);
                if ser_f > 0 {
                    if let Some(tx) = tr.as_mut() {
                        tx.push(TraceEvent::WireGrant {
                            at: start,
                            dur: ser_f,
                            device: d as u32,
                            wire: Wire::Fabric,
                            tenant: tnt,
                            index: ridx,
                            chunk: 0,
                        });
                    }
                }
                let solo_finish = issue + transfer_ps(m.bytes, dev.link_bw);
                fab_late = fab_late.max((start + ser_f).saturating_sub(solo_finish));
                fabric.bytes += m.bytes;
            }
        }
        finish_admission(
            now, d, dev, table, fabric, arena, heap, rid, mem_late, io_late, fab_late, fx, tr,
        );
    }
}

/// Charge one admission batch at *stage* granularity (`--chunks > 1`).
///
/// Each request is decomposed by its protocol's [`StageGraph`] into
/// per-chunk wire/CCM stages. Traced solo-relative offsets already
/// encode the engine's internal overlap structure, so DAG edges
/// propagate only *contention delay*: a stage's inbound delay is the
/// max outbound delay over its lane predecessors, and its outbound
/// delay adds the stage's own lateness against the solo schedule. On
/// empty calendars every lateness is zero and the placement is exactly
/// the whole-request replay sliced — chunking is free without
/// contention.
///
/// Attribution walks the critical chain back from the stage with the
/// largest outbound delay, folding each link's own lateness into the
/// wire (`device_wait`/`fabric_wait`) or PU (`pu_wait`) bucket, so the
/// decomposition identity `total = queue + retry + solo + wire + pu`
/// holds exactly in u64 at every chunk count.
///
/// Fault-free pipelined graphs additionally schedule a kind-5 *early
/// slot release* at the last CCM stage's bound: once a request's CCM
/// work is provably done, the next request may enter service while the
/// back-stream drains — CCM spans of consecutive requests never
/// overlap, so device busy time is conserved while makespan (and both
/// idle fractions) shrink. Fault mode instead records per-chunk
/// completion bounds so a mid-service kill loses only unfinished
/// chunks.
#[allow(clippy::too_many_arguments)]
fn admit_chunked(
    now: Ps,
    d: usize,
    dev: &mut DevState,
    table: &SoloTable,
    fabric: &mut Fabric,
    arena: &mut ReqArena,
    heap: &mut BinaryHeap<Reverse<Ev>>,
    batch: &[u32],
    fx: &mut Option<FaultRuntime>,
    pipe: &mut PipeRt,
    tr: &mut Option<Tracer>,
) {
    let bw = dev.link_bw / dev.bw_factor;
    let link_bw = dev.link_bw;
    let puf = dev.pu_factor;
    let scale = move |dur: Ps| if puf == 1.0 { dur } else { (dur as f64 * puf) as Ps };
    for &rid in batch {
        if pipe.released.len() <= rid as usize {
            pipe.released.resize(rid as usize + 1, false);
        }
        pipe.released[rid as usize] = false;
        let (annot, proto, tnt, ridx) = {
            let r = &arena.runs[rid as usize];
            (r.annot, r.proto, r.tenant, r.index)
        };
        let si = table.idx_of(dev.class, annot, proto);
        let s = &table.runs[si];
        let g = &pipe.graphs[si];
        let n = g.stages.len();
        let mut delay_out: Vec<Ps> = vec![0; n];
        let mut own: Vec<Ps> = vec![0; n];
        let mut own_fab: Vec<Ps> = vec![0; n];
        let mut wend: Vec<Ps> = vec![0; n];
        let mut crit_pred: Vec<u32> = vec![u32::MAX; n];
        for i in 0..n {
            let st = &g.stages[i];
            // Inbound contention delay: argmax over lane predecessors
            // (first on ties — stable critical-chain attribution).
            let mut din: Ps = 0;
            let mut cp = u32::MAX;
            for &p in &st.after {
                let dout = delay_out[p as usize];
                if cp == u32::MAX || dout > din {
                    din = dout;
                    cp = p;
                }
            }
            let (lo, hi) = (st.lo as usize, st.hi as usize);
            let mut late: Ps = 0;
            let mut fab_late: Ps = 0;
            let mut end: Ps = 0;
            match st.lane {
                Lane::MemWire | Lane::IoWire => {
                    let wlane = if st.lane == Lane::MemWire { Wire::Mem } else { Wire::Io };
                    let trace =
                        if st.lane == Lane::MemWire { &s.run.mem_trace } else { &s.run.io_trace };
                    let cal = if st.lane == Lane::MemWire { &mut dev.mem } else { &mut dev.io };
                    for m in &trace[lo..hi] {
                        let issue = now + m.start + din;
                        let dur = transfer_ps(m.bytes, bw);
                        let start = cal.place(issue, dur);
                        if dur > 0 {
                            if let Some(tx) = tr.as_mut() {
                                tx.push(TraceEvent::WireGrant {
                                    at: start,
                                    dur,
                                    device: d as u32,
                                    wire: wlane,
                                    tenant: tnt,
                                    index: ridx,
                                    chunk: st.chunk,
                                });
                            }
                        }
                        let ser_solo = transfer_ps(m.bytes, link_bw);
                        late = late.max((start + dur).saturating_sub(issue + ser_solo));
                        end = end.max(m.start + ser_solo);
                    }
                    if let Some((fbw, cal)) = fabric.link.as_mut() {
                        for m in &trace[lo..hi] {
                            let issue = now + m.start + din;
                            let ser_f = transfer_ps(m.bytes, *fbw);
                            let start = cal.place(issue, ser_f);
                            if ser_f > 0 {
                                if let Some(tx) = tr.as_mut() {
                                    tx.push(TraceEvent::WireGrant {
                                        at: start,
                                        dur: ser_f,
                                        device: d as u32,
                                        wire: Wire::Fabric,
                                        tenant: tnt,
                                        index: ridx,
                                        chunk: st.chunk,
                                    });
                                }
                            }
                            let ser_solo = transfer_ps(m.bytes, link_bw);
                            fab_late = fab_late.max((start + ser_f).saturating_sub(issue + ser_solo));
                            fabric.bytes += m.bytes;
                        }
                    }
                }
                Lane::Ccm => {
                    for sp in &s.run.ccm_trace[lo..hi] {
                        let ready = now + sp.start + din;
                        let (ls, e) = dev.pool.dispatch(ready, scale(sp.dur()));
                        if e > ls {
                            if let Some(tx) = tr.as_mut() {
                                tx.push(TraceEvent::PuLease {
                                    at: ls,
                                    end: e,
                                    device: d as u32,
                                    tenant: tnt,
                                    index: ridx,
                                    chunk: st.chunk,
                                });
                            }
                        }
                        late = late.max(e - (ready + sp.dur()));
                        end = end.max(sp.start + sp.dur());
                    }
                }
            }
            own[i] = late.max(fab_late);
            own_fab[i] = fab_late.min(own[i]);
            wend[i] = end;
            delay_out[i] = din + own[i];
            crit_pred[i] = cp;
        }
        // Critical-chain attribution: the chain's own latenesses sum to
        // the max outbound delay, each charged to its stage's lane.
        let (mut dwait, mut fwait, mut pwait): (Ps, Ps, Ps) = (0, 0, 0);
        let (mut mem_wait, mut io_wait): (Ps, Ps) = (0, 0);
        if n > 0 {
            let mut cur = (0..n).max_by_key(|&i| delay_out[i]).expect("non-empty stage graph");
            loop {
                match g.stages[cur].lane {
                    Lane::Ccm => pwait += own[cur],
                    Lane::MemWire => {
                        dwait += own[cur];
                        fwait += own_fab[cur];
                        mem_wait += own[cur];
                    }
                    Lane::IoWire => {
                        dwait += own[cur];
                        fwait += own_fab[cur];
                        io_wait += own[cur];
                    }
                }
                if crit_pred[cur] == u32::MAX {
                    break;
                }
                cur = crit_pred[cur] as usize;
            }
        }
        let completion = {
            let r = &mut arena.runs[rid as usize];
            r.admit = now;
            r.device_wait = dwait;
            r.fabric_wait = fwait;
            r.pu_wait = pwait;
            r.completion = now + r.solo + dwait.max(fwait) + pwait;
            r.completion
        };
        if let Some(tx) = tr.as_mut() {
            tx.push(TraceEvent::Admit { at: now, tenant: tnt, index: ridx, device: d as u32 });
        }
        dev.in_service += 1;
        dev.stats.mem_wait += mem_wait;
        dev.stats.io_wait += io_wait;
        dev.stats.pu_wait += pwait;
        dev.stats.bytes += s.mem_bytes + s.io_bytes;
        fabric.wait += fwait;
        let mut attempt: u32 = 0;
        if let Some(fxr) = fx.as_mut() {
            let wire: Ps = s
                .run
                .mem_trace
                .iter()
                .chain(s.run.io_trace.iter())
                .map(|m| transfer_ps(m.bytes, bw))
                .sum();
            let pu: Ps = s.run.ccm_trace.iter().map(|sp| scale(sp.dur())).sum();
            let st = &mut fxr.rstate[rid as usize];
            st.loc = Loc::InService;
            st.loc_dev = d as u32;
            st.attempt_wire = wire;
            st.attempt_pu = pu;
            // Per-chunk completion bounds and charges: a kill mid-service
            // forfeits only the chunks whose bound lies past the kill.
            st.attempt_chunks.clear();
            for k in 0..g.chunks {
                let mut cend: Ps = 0;
                let mut cw: Ps = 0;
                let mut cpu: Ps = 0;
                let mut any = false;
                for (i, stg) in g.stages.iter().enumerate() {
                    if stg.chunk != k {
                        continue;
                    }
                    any = true;
                    cend = cend.max(wend[i] + delay_out[i]);
                    let (lo, hi) = (stg.lo as usize, stg.hi as usize);
                    match stg.lane {
                        Lane::MemWire => {
                            cw += s.run.mem_trace[lo..hi]
                                .iter()
                                .map(|m| transfer_ps(m.bytes, bw))
                                .sum::<Ps>();
                        }
                        Lane::IoWire => {
                            cw += s.run.io_trace[lo..hi]
                                .iter()
                                .map(|m| transfer_ps(m.bytes, bw))
                                .sum::<Ps>();
                        }
                        Lane::Ccm => {
                            cpu += s.run.ccm_trace[lo..hi]
                                .iter()
                                .map(|sp| scale(sp.dur()))
                                .sum::<Ps>();
                        }
                    }
                }
                if any {
                    st.attempt_chunks.push((now + cend, cw, cpu));
                }
            }
            attempt = st.attempt;
            fxr.note_recovered(rid as usize, now);
        } else if !g.serial {
            // Early slot release: the last CCM stage's bound dominates
            // every actual CCM span end, so releasing there can never
            // let two requests' CCM work overlap. Serial graphs gain
            // nothing (the bound coincides with completion); fault mode
            // holds the slot so kills find the request in service.
            let mut ccm_done: Option<Ps> = None;
            for (i, stg) in g.stages.iter().enumerate() {
                if stg.lane == Lane::Ccm {
                    let e = wend[i] + delay_out[i];
                    ccm_done = Some(ccm_done.map_or(e, |c: Ps| c.max(e)));
                }
            }
            if let Some(rel) = ccm_done {
                let release_at = now + rel;
                if release_at < completion {
                    heap.push(Reverse((release_at, 5, arena.tickets[rid as usize], d as u64)));
                }
            }
        }
        heap.push(Reverse((
            completion,
            0,
            ((attempt as u64) << 32) | d as u64,
            arena.tickets[rid as usize],
        )));
    }
}

/// One solo-trace message queued for QoS-ordered online placement.
#[derive(Debug, Clone, Copy)]
struct QMsg {
    /// Issue time (admission instant + solo wire offset).
    at: Ps,
    /// Payload bytes (the DRR deficit currency).
    bytes: u64,
    /// Serialization on the wire being charged.
    dur: Ps,
    /// Solo finish time the lateness is measured against.
    solo_finish: Ps,
    /// Index into the admission batch (which request to charge).
    slot: usize,
    /// Owning tenant (trace attribution).
    tenant: u32,
    /// Owning request index within the tenant (trace attribution).
    index: u32,
}

/// Charge one admission batch with its wire traffic ordered by the
/// per-wire QoS schedulers: per-tenant FIFO queues drained in
/// [`QosState::pick`] order against the live calendars. Placements from
/// earlier admissions are never revoked — QoS redistributes service
/// *within* work entering the wires together, the online counterpart of
/// the PR-3 replay arbitration. The CCM PU pool deliberately stays
/// earliest-free in batch order: QoS governs the wires only, exactly as
/// in the open-loop model.
#[allow(clippy::too_many_arguments)]
fn admit_qos(
    now: Ps,
    d: usize,
    streams: usize,
    dev: &mut DevState,
    table: &SoloTable,
    fabric: &mut Fabric,
    arena: &mut ReqArena,
    heap: &mut BinaryHeap<Reverse<Ev>>,
    batch: &[u32],
    fx: &mut Option<FaultRuntime>,
    tr: &mut Option<Tracer>,
) {
    let a = now;
    // Effective device-link bandwidth: degraded inside a fault window,
    // exactly `link_bw` otherwise (`x / 1.0` — the bit-identity pin).
    // Lateness always compares against the full-bandwidth solo finish.
    let bw = dev.link_bw / dev.bw_factor;
    let n = batch.len();
    let mut mem_late: Vec<Ps> = vec![0; n];
    let mut io_late: Vec<Ps> = vec![0; n];
    let mut fab_late: Vec<Ps> = vec![0; n];
    // Per-tenant FIFO queues per wire (tenant ids index the QosState,
    // so the vectors span all streams even when few are in the batch).
    let mut mem_q: Vec<Vec<QMsg>> = vec![Vec::new(); streams];
    let mut io_q: Vec<Vec<QMsg>> = vec![Vec::new(); streams];
    let mut fab_q: Vec<Vec<QMsg>> = vec![Vec::new(); streams];
    for (slot, &rid) in batch.iter().enumerate() {
        let (tenant, annot, proto, ridx) = {
            let r = &arena.runs[rid as usize];
            (r.tenant as usize, r.annot, r.proto, r.index)
        };
        let s = table.get(dev.class, annot, proto);
        for m in &s.run.mem_trace {
            let issue = a + m.start;
            let dur = transfer_ps(m.bytes, bw);
            let solo_finish = issue + transfer_ps(m.bytes, dev.link_bw);
            let q = QMsg {
                at: issue,
                bytes: m.bytes,
                dur,
                solo_finish,
                slot,
                tenant: tenant as u32,
                index: ridx,
            };
            mem_q[tenant].push(q);
        }
        for m in &s.run.io_trace {
            let issue = a + m.start;
            let dur = transfer_ps(m.bytes, bw);
            let solo_finish = issue + transfer_ps(m.bytes, dev.link_bw);
            let q = QMsg {
                at: issue,
                bytes: m.bytes,
                dur,
                solo_finish,
                slot,
                tenant: tenant as u32,
                index: ridx,
            };
            io_q[tenant].push(q);
        }
        if let Some((fbw, _)) = fabric.link.as_ref() {
            for m in s.run.mem_trace.iter().chain(s.run.io_trace.iter()) {
                let issue = a + m.start;
                fab_q[tenant].push(QMsg {
                    at: issue,
                    bytes: m.bytes,
                    dur: transfer_ps(m.bytes, *fbw),
                    solo_finish: issue + transfer_ps(m.bytes, dev.link_bw),
                    slot,
                    tenant: tenant as u32,
                    index: ridx,
                });
                fabric.bytes += m.bytes;
            }
        }
    }
    // Per-tenant FIFO discipline: order each queue by issue time (the
    // sort is stable, so a tenant's same-instant messages keep their
    // trace/batch order).
    for q in mem_q.iter_mut().chain(io_q.iter_mut()).chain(fab_q.iter_mut()) {
        q.sort_by_key(|m| m.at);
    }
    let qos_mem = dev.qos_mem.as_mut().expect("admit_qos runs only with QoS state");
    drain_qos(&mut dev.mem, qos_mem, &mem_q, &mut mem_late, tr, Wire::Mem, d as u32);
    let qos_io = dev.qos_io.as_mut().expect("admit_qos runs only with QoS state");
    drain_qos(&mut dev.io, qos_io, &io_q, &mut io_late, tr, Wire::Io, d as u32);
    if let Some((_, cal)) = fabric.link.as_mut() {
        let qos_fab = fabric.qos.as_mut().expect("fabric QoS state exists with a fabric link");
        drain_qos(cal, qos_fab, &fab_q, &mut fab_late, tr, Wire::Fabric, d as u32);
    }
    for (slot, &rid) in batch.iter().enumerate() {
        finish_admission(
            now,
            d,
            dev,
            table,
            fabric,
            arena,
            heap,
            rid,
            mem_late[slot],
            io_late[slot],
            fab_late[slot],
            fx,
            tr,
        );
    }
}

/// Drain one admission batch's queued messages onto a link calendar in
/// QoS pick order. The decision clock is the batch's own placement
/// frontier (or the next arrival when the batch's work would idle the
/// wire), and each served message goes into the earliest calendar gap
/// at or after `max(clock, issue)` — so a lone stream still replays its
/// solo schedule with zero shift, and earlier admissions' placements
/// are never revoked. Folds each message's lateness versus its solo
/// finish into `late[slot]` (max accounting, as everywhere).
#[allow(clippy::too_many_arguments)]
fn drain_qos(
    cal: &mut LinkCalendar,
    qos: &mut QosState,
    queues: &[Vec<QMsg>],
    late: &mut [Ps],
    tr: &mut Option<Tracer>,
    wire: Wire,
    device: u32,
) {
    let n = queues.len();
    let total: usize = queues.iter().map(|q| q.len()).sum();
    if total == 0 {
        return;
    }
    let mut cursor = vec![0usize; n];
    let mut eligible = vec![false; n];
    let mut head_at = vec![Ps::MAX; n];
    let mut head_bytes = vec![0u64; n];
    let mut clock: Ps = 0;
    let mut served = 0usize;
    while served < total {
        let t_min = (0..n)
            .filter(|&i| cursor[i] < queues[i].len())
            .map(|i| queues[i][cursor[i]].at)
            .min()
            .expect("unserved messages remain");
        let t = clock.max(t_min);
        for i in 0..n {
            if cursor[i] < queues[i].len() {
                let h = &queues[i][cursor[i]];
                head_at[i] = h.at;
                head_bytes[i] = h.bytes;
                eligible[i] = h.at <= t;
            } else {
                eligible[i] = false;
                head_at[i] = Ps::MAX;
                head_bytes[i] = 0;
            }
        }
        let i = qos.pick(&eligible, &head_at, &head_bytes);
        let m = &queues[i][cursor[i]];
        cursor[i] += 1;
        served += 1;
        let start = cal.place(t.max(m.at), m.dur);
        if m.dur > 0 {
            if let Some(tx) = tr.as_mut() {
                tx.push(TraceEvent::WireGrant {
                    at: start,
                    dur: m.dur,
                    device,
                    wire,
                    tenant: m.tenant,
                    index: m.index,
                    chunk: 0,
                });
            }
        }
        clock = clock.max(start + m.dur);
        late[m.slot] = late[m.slot].max((start + m.dur).saturating_sub(m.solo_finish));
    }
}

/// Fold one admitted request's charges into its record, the device
/// stats and the event heap — shared tail of both admission paths.
/// Under a PU-degradation window, lease durations scale by `pu_factor`
/// on dispatch; the inflation lands in `pu_wait` because lateness is
/// still measured against the undegraded solo lease end (guarded by an
/// exact `== 1.0` check so the fault-free path never round-trips
/// through floats). In fault mode this also records the attempt's
/// wire/PU charges (the lost work if the attempt is later killed) and
/// packs the attempt into the completion event id.
#[allow(clippy::too_many_arguments)]
fn finish_admission(
    now: Ps,
    d: usize,
    dev: &mut DevState,
    table: &SoloTable,
    fabric: &mut Fabric,
    arena: &mut ReqArena,
    heap: &mut BinaryHeap<Reverse<Ev>>,
    rid: u32,
    mem_late: Ps,
    io_late: Ps,
    fab_late: Ps,
    fx: &mut Option<FaultRuntime>,
    tr: &mut Option<Tracer>,
) {
    let (annot, proto, tnt, ridx) = {
        let r = &arena.runs[rid as usize];
        (r.annot, r.proto, r.tenant, r.index)
    };
    let s = table.get(dev.class, annot, proto);
    // CCM PU-pool replay (earliest-free, admission order).
    let f = dev.pu_factor;
    let scale = |dur: Ps| if f == 1.0 { dur } else { (dur as f64 * f) as Ps };
    let mut pu_late: Ps = 0;
    for sp in &s.run.ccm_trace {
        let ready = now + sp.start;
        let (ls, end) = dev.pool.dispatch(ready, scale(sp.dur()));
        if end > ls {
            if let Some(tx) = tr.as_mut() {
                tx.push(TraceEvent::PuLease {
                    at: ls,
                    end,
                    device: d as u32,
                    tenant: tnt,
                    index: ridx,
                    chunk: 0,
                });
            }
        }
        pu_late = pu_late.max(end - (ready + sp.dur()));
    }
    let completion = {
        let r = &mut arena.runs[rid as usize];
        r.admit = now;
        r.device_wait = mem_late.max(io_late);
        r.fabric_wait = fab_late;
        r.pu_wait = pu_late;
        r.completion = now + r.solo + r.device_wait.max(fab_late) + pu_late;
        r.completion
    };
    if let Some(tx) = tr.as_mut() {
        tx.push(TraceEvent::Admit { at: now, tenant: tnt, index: ridx, device: d as u32 });
    }
    dev.in_service += 1;
    dev.stats.mem_wait += mem_late;
    dev.stats.io_wait += io_late;
    dev.stats.pu_wait += pu_late;
    dev.stats.bytes += s.mem_bytes + s.io_bytes;
    fabric.wait += fab_late;
    let mut attempt: u32 = 0;
    if let Some(fxr) = fx.as_mut() {
        let bw = dev.link_bw / dev.bw_factor;
        let wire: Ps = s
            .run
            .mem_trace
            .iter()
            .chain(s.run.io_trace.iter())
            .map(|m| transfer_ps(m.bytes, bw))
            .sum();
        let pu: Ps = s.run.ccm_trace.iter().map(|sp| scale(sp.dur())).sum();
        let st = &mut fxr.rstate[rid as usize];
        st.loc = Loc::InService;
        st.loc_dev = d as u32;
        st.attempt_wire = wire;
        st.attempt_pu = pu;
        attempt = st.attempt;
        fxr.note_recovered(rid as usize, now);
    }
    heap.push(Reverse((
        completion,
        0,
        ((attempt as u64) << 32) | d as u64,
        arena.tickets[rid as usize],
    )));
}

/// The open-loop pin: delegate to the PR-3 tenant driver verbatim and
/// repackage its report (one request per tenant). Requires a `Static`
/// policy and a homogeneous topology — exactly the configuration the
/// regression suite compares against `axle tenants`.
fn run_sched_open(
    cfg: &SimConfig,
    topo_spec: &TopologySpec,
    spec: &SchedSpec,
    jobs: usize,
) -> SchedReport {
    assert!(
        spec.faults.is_empty(),
        "fault injection requires the closed-loop engine (drop --open)"
    );
    assert!(
        spec.chunks() == 1,
        "chunked pipelining requires the closed-loop engine (drop --open)"
    );
    let proto = match spec.policy {
        PolicyKind::Static(p) => p,
        _ => panic!(
            "open-loop arrivals support only static policies; adaptive policies need \
             closed-loop completion feedback (drop --open)"
        ),
    };
    if spec.streams == 0 {
        return empty_report(topo_spec, spec);
    }
    let tenant_spec = TenantSpec::new(spec.streams)
        .with_workloads(spec.workloads.clone())
        .with_proto(proto)
        .with_load(spec.load)
        .with_seed(spec.seed);
    let r = tenant::run_tenants(cfg, topo_spec, &tenant_spec, jobs);
    let requests: Vec<RequestRun> = r
        .tenants
        .iter()
        .map(|t| RequestRun {
            tenant: t.tenant,
            index: 0,
            annot: t.annot,
            class: spec.priority(t.tenant as usize),
            device: t.device,
            proto,
            submit: t.arrival,
            admit: t.arrival,
            solo: t.solo.total,
            device_wait: t.device_wait,
            fabric_wait: t.fabric_wait,
            pu_wait: t.pu_wait,
            completion: t.arrival + t.total(),
            retry_wait: 0,
            retries: 0,
            placed_on: vec![t.device],
            failed: false,
        })
        .collect();
    let host_busy = r.tenants.iter().map(|t| t.solo.host_busy).sum();
    let ccm_busy = r.devices.iter().map(|d| d.pu_busy).sum();
    let mut proto_mix: BTreeMap<&'static str, u64> = BTreeMap::new();
    if !requests.is_empty() {
        proto_mix.insert(proto.label(), requests.len() as u64);
    }
    let scheduled = requests.len() as u64;
    SchedReport {
        policy: spec.policy,
        qos: r.qos,
        closed: false,
        depth: spec.depth,
        admit: spec.admit,
        requests,
        devices: r.devices,
        fabric: r.fabric,
        makespan: r.makespan,
        p50_slowdown: r.p50_slowdown,
        p99_slowdown: r.p99_slowdown,
        max_slowdown: r.max_slowdown,
        host_busy,
        ccm_busy,
        proto_mix,
        faults: Vec::new(),
        lost_wire: 0,
        lost_pu: 0,
        failed_requests: 0,
        scheduled,
        streamed: false,
        class_rows: Vec::new(),
    }
}

/// Report for a run with nothing to schedule (`streams == 0` or
/// `requests == 0`): unit slowdowns, zeroed devices, zero makespan.
fn empty_report(topo_spec: &TopologySpec, spec: &SchedSpec) -> SchedReport {
    SchedReport {
        policy: spec.policy,
        qos: topo_spec.qos.policy,
        closed: spec.closed,
        depth: spec.depth,
        admit: spec.admit,
        requests: Vec::new(),
        devices: vec![DeviceStats::default(); topo_spec.devices],
        fabric: FabricReport { bw_gbps: topo_spec.fabric_bw_gbps, ..FabricReport::default() },
        makespan: 0,
        p50_slowdown: 1.0,
        p99_slowdown: 1.0,
        max_slowdown: 1.0,
        host_busy: 0,
        ccm_busy: 0,
        proto_mix: BTreeMap::new(),
        faults: Vec::new(),
        lost_wire: 0,
        lost_pu: 0,
        failed_requests: 0,
        scheduled: 0,
        streamed: false,
        class_rows: Vec::new(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DeviceOverride, QosSpec};

    /// Local shadow of the deprecated free function: every in-file test
    /// goes through the unified [`run`] entry point.
    fn run_sched(cfg: &SimConfig, topo: &TopologySpec, spec: &SchedSpec, jobs: usize) -> SchedReport {
        run(&SchedRun::new(cfg, topo, spec).with_jobs(jobs)).report
    }

    // ---- Online resource models. ----

    #[test]
    fn calendar_lone_trace_replays_exactly() {
        let mut cal = LinkCalendar::default();
        let mut t = 0;
        for _ in 0..5 {
            assert_eq!(cal.place(t, 100), t);
            t += 120; // solo-spaced: gaps of 20
        }
        assert_eq!(cal.busy_union(), 500);
        assert_eq!(cal.msgs, 5);
    }

    #[test]
    fn calendar_backfills_idle_gaps() {
        let mut cal = LinkCalendar::default();
        assert_eq!(cal.place(0, 100), 0);
        assert_eq!(cal.place(300, 100), 300);
        // A later placement with an early issue lands in the [100, 300)
        // gap instead of queueing behind the tail.
        assert_eq!(cal.place(50, 150), 100);
        // The gap is now too small for another 150: next fit is the tail.
        assert_eq!(cal.place(50, 150), 400);
        assert_eq!(cal.tail(), 550);
    }

    #[test]
    fn calendar_clamps_past_covering_interval() {
        let mut cal = LinkCalendar::default();
        assert_eq!(cal.place(100, 200), 100);
        // Issue inside the busy interval: starts when it ends.
        assert_eq!(cal.place(150, 50), 300);
        // Zero-duration transfers occupy nothing.
        assert_eq!(cal.place(40, 0), 40);
        assert_eq!(cal.msgs, 2);
    }

    #[test]
    fn online_pool_union_merges_out_of_order_spans() {
        let mut p = OnlinePool::new(2);
        assert_eq!(p.dispatch(100, 50), (100, 150));
        assert_eq!(p.dispatch(100, 80), (100, 180));
        // Third span queues earliest-free; a later regressed ready time
        // is legal for the online pool.
        assert_eq!(p.dispatch(90, 10), (150, 160));
        assert_eq!(p.busy_total, 140);
        assert_eq!(p.busy_union(), 80); // [100, 180)
        assert_eq!(p.earliest_free(), 160);
    }

    /// Reference union: sort-and-sweep over the raw span list (the PR-6
    /// report-time computation).
    fn brute_union(spans: &[(Ps, Ps)]) -> Ps {
        let mut spans = spans.to_vec();
        spans.sort_unstable();
        let mut union = 0;
        let mut covered = 0;
        for (s, e) in spans {
            if s >= covered {
                union += e - s;
                covered = e;
            } else if e > covered {
                union += e - covered;
                covered = e;
            }
        }
        union
    }

    #[test]
    fn online_pool_incremental_union_matches_brute_force() {
        // Random dispatch/truncate traffic: the incrementally maintained
        // union must equal the sort-and-sweep union of the live spans at
        // every step.
        let mut rng = Pcg32::seed_from_u64(0x0901);
        for pus in [1usize, 3] {
            let mut p = OnlinePool::new(pus);
            let mut spans: Vec<(Ps, Ps)> = Vec::new();
            for _ in 0..500 {
                let ready = rng.below(10_000);
                let dur = rng.below(200);
                let (s, e) = p.dispatch(ready, dur);
                if dur > 0 {
                    spans.push((s, e));
                }
                if rng.below(50) == 0 {
                    let cut = rng.below(12_000);
                    p.truncate(cut);
                    spans = spans
                        .iter()
                        .filter(|&&(s, _)| s < cut)
                        .map(|&(s, e)| (s, e.min(cut)))
                        .collect();
                }
                assert_eq!(p.busy_union(), brute_union(&spans));
            }
        }
    }

    // ---- Closed-loop driver. ----

    fn light_spec(streams: usize) -> SchedSpec {
        SchedSpec::new(streams).with_workloads(vec!['a', 'f']).with_requests(2)
    }

    #[test]
    fn lone_tenant_closed_loop_has_zero_contention() {
        // One tenant, one device, window 1: each request replays its solo
        // schedule against empty-or-drained calendars — zero shifts, and
        // successive requests are spaced by solo + think.
        let cfg = SimConfig::m2ndp();
        let topo = TopologySpec::default();
        let spec = SchedSpec::new(1)
            .with_workloads(vec!['f'])
            .with_policy(PolicyKind::Static(Protocol::Bs))
            .with_requests(3)
            .with_think(2 * US);
        let r = run_sched(&cfg, &topo, &spec, 2);
        assert_eq!(r.requests.len(), 3);
        for w in r.requests.windows(2) {
            assert!(w[1].submit >= w[0].completion + 2 * US);
        }
        for req in &r.requests {
            assert_eq!(req.device_wait, 0);
            assert_eq!(req.fabric_wait, 0);
            assert_eq!(req.pu_wait, 0);
            assert_eq!(req.queue_wait(), 0);
            assert!((req.slowdown() - 1.0).abs() < 1e-12);
            assert_eq!(req.proto, Protocol::Bs);
        }
        assert_eq!(r.proto_mix.get("BS"), Some(&3));
    }

    #[test]
    fn admission_depth_one_serializes_a_device() {
        // Two tenants on one device with a single service slot: the
        // second request cannot start before the first completes, so the
        // makespan covers both solos back to back.
        let cfg = SimConfig::m2ndp();
        let topo = TopologySpec::default();
        let spec = SchedSpec::new(2)
            .with_workloads(vec!['f'])
            .with_policy(PolicyKind::Static(Protocol::Axle))
            .with_requests(1)
            .with_admit(1);
        let r = run_sched(&cfg, &topo, &spec, 2);
        assert_eq!(r.requests.len(), 2);
        let solo_sum: Ps = r.requests.iter().map(|q| q.solo).sum();
        assert!(r.makespan >= solo_sum);
        // Somebody actually queued (start stagger < solo runtime).
        assert!(r.requests.iter().any(|q| q.queue_wait() > 0));
    }

    #[test]
    fn run_is_worker_count_invariant_and_deterministic() {
        let cfg = SimConfig::m2ndp();
        let topo = TopologySpec::shared_fabric(2, cfg.cxl_bw_gbps);
        for policy in [PolicyKind::Static(Protocol::Axle), PolicyKind::Heuristic] {
            let spec = light_spec(4).with_policy(policy);
            let a = run_sched(&cfg, &topo, &spec, 1);
            let b = run_sched(&cfg, &topo, &spec, 4);
            assert_eq!(a.to_json().to_string(), b.to_json().to_string());
            assert_eq!(a.requests.len(), 8);
        }
    }

    #[test]
    fn empty_runs_return_empty_reports() {
        let cfg = SimConfig::m2ndp();
        let topo = TopologySpec::shared_fabric(2, cfg.cxl_bw_gbps);
        for spec in [light_spec(0), light_spec(3).with_requests(0)] {
            let r = run_sched(&cfg, &topo, &spec, 2);
            assert!(r.requests.is_empty());
            assert_eq!(r.makespan, 0);
            assert_eq!(r.devices.len(), 2);
            assert_eq!(r.p50_slowdown, 1.0);
            assert_eq!(r.max_slowdown, 1.0);
            assert_eq!(r.ccm_idle_frac(), 0.0);
        }
    }

    #[test]
    fn decomposition_identity_holds_per_request() {
        let cfg = SimConfig::m2ndp();
        let topo = TopologySpec::shared_fabric(1, cfg.cxl_bw_gbps);
        let spec = light_spec(4).with_policy(PolicyKind::Oracle).with_admit(4);
        let r = run_sched(&cfg, &topo, &spec, 2);
        for q in &r.requests {
            assert_eq!(q.total(), q.queue_wait() + q.solo + q.wire_wait() + q.pu_wait);
            assert!(q.completion >= q.admit);
            assert!(q.admit >= q.submit);
            assert!(q.slowdown() >= 1.0);
        }
        let served: u32 = r.devices.iter().map(|d| d.tenants).sum();
        assert_eq!(served as usize, r.requests.len());
    }

    #[test]
    fn heterogeneous_weak_device_inflates_solo() {
        // Device 1 has a quarter of the CCM PUs: the same workload's solo
        // runtime there must exceed device 0's.
        let cfg = SimConfig::m2ndp();
        let topo = TopologySpec { devices: 2, ..TopologySpec::default() }
            .with_override(1, DeviceOverride { ccm_pus: Some(4), ..Default::default() });
        let spec = SchedSpec::new(2)
            .with_workloads(vec!['a'])
            .with_policy(PolicyKind::Static(Protocol::Bs))
            .with_requests(1);
        let r = run_sched(&cfg, &topo, &spec, 2);
        // Round-robin spreads the two requests over both devices (which
        // tenant lands where depends on the seeded stagger order).
        let on_base = r.requests.iter().find(|q| q.device == 0).expect("device 0 used");
        let on_weak = r.requests.iter().find(|q| q.device == 1).expect("device 1 used");
        assert!(on_weak.solo > on_base.solo);
    }

    #[test]
    #[should_panic(expected = "open-loop arrivals support only static")]
    fn open_mode_requires_static_policy() {
        let cfg = SimConfig::m2ndp();
        let topo = TopologySpec::default();
        let spec = light_spec(2).with_policy(PolicyKind::Heuristic).open_loop();
        let _ = run_sched(&cfg, &topo, &spec, 1);
    }

    // ---- Priority admission + online QoS. ----

    /// The PR-4 admission pop kept as a test-only reference: O(queue)
    /// scan of a flat FIFO for the earliest-queued request of the
    /// highest class.
    fn pop_admit_scan(queue: &mut VecDeque<u32>, class_of: &[u32]) -> Option<u32> {
        let idx = (0..queue.len())
            .min_by_key(|&i| (std::cmp::Reverse(class_of[queue[i] as usize]), i))?;
        queue.remove(idx)
    }

    #[test]
    fn pop_admit_is_fifo_for_equal_classes_and_jumps_for_higher() {
        // All class 0: exact FIFO (the PR-4 pop_front pin).
        let mut q = AdmitQueue::default();
        for rid in 0..4 {
            q.push(rid, 0);
        }
        let order: Vec<u32> = std::iter::from_fn(|| q.pop_admit()).collect();
        assert_eq!(order, vec![0, 1, 2, 3]);
        // Mixed classes: highest class first, FIFO within a class.
        let mut q = AdmitQueue::default();
        for (rid, class) in [(0, 0), (1, 2), (2, 0), (3, 2)] {
            q.push(rid, class);
        }
        let order: Vec<u32> = std::iter::from_fn(|| q.pop_admit()).collect();
        assert_eq!(order, vec![1, 3, 0, 2]);
        assert_eq!(q.pop_admit(), None);
        assert!(q.is_empty());
    }

    #[test]
    fn admit_queue_matches_the_reference_scan_on_random_traffic() {
        // Random interleavings of push / priority-pop / FIFO-pop against
        // the PR-4 flat-queue scan: every pop must agree, in every state.
        let mut rng = Pcg32::seed_from_u64(0xADC1);
        let mut classes: Vec<u32> = Vec::new();
        let mut q = AdmitQueue::default();
        let mut flat: VecDeque<u32> = VecDeque::new();
        for _ in 0..2000 {
            match rng.below(4) {
                0 | 1 => {
                    let rid = classes.len() as u32;
                    let class = rng.below(3) as u32;
                    classes.push(class);
                    q.push(rid, class);
                    flat.push_back(rid);
                }
                2 => assert_eq!(q.pop_admit(), pop_admit_scan(&mut flat, &classes)),
                // FIFO drain (the fault-kill path) is the flat pop_front.
                _ => assert_eq!(q.pop_front_fifo(), flat.pop_front()),
            }
            assert_eq!(q.len(), flat.len());
        }
        // Targeted removal (the timeout path) evicts one rid anywhere in
        // the queue; drain the survivors through it.
        while let Some(rid) = flat.pop_front() {
            q.remove(rid, classes[rid as usize]);
        }
        assert!(q.is_empty());
    }

    #[test]
    fn high_class_jumps_the_admission_queue() {
        // Four tenants, one device, one service slot: whoever submits
        // first is served; of the three that queue behind it, the
        // high-class tenant must be admitted first.
        let cfg = SimConfig::m2ndp();
        let topo = TopologySpec::default();
        let spec = SchedSpec::new(4)
            .with_workloads(vec!['f'])
            .with_policy(PolicyKind::Static(Protocol::Axle))
            .with_requests(1)
            .with_admit(1)
            .with_priorities(vec![0, 0, 0, 7]);
        let r = run_sched(&cfg, &topo, &spec, 2);
        assert_eq!(r.requests.len(), 4);
        let high = r.requests.iter().find(|q| q.tenant == 3).unwrap();
        assert_eq!(high.class, 7);
        // At most one request (the initially-served one) was admitted
        // before the high-class tenant.
        let earlier = r.requests.iter().filter(|q| q.admit < high.admit).count();
        assert!(earlier <= 1, "{earlier} requests admitted before the high class");
        // The decomposition identity survives priority admission.
        for q in &r.requests {
            assert_eq!(q.total(), q.queue_wait() + q.solo + q.wire_wait() + q.pu_wait);
        }
    }

    #[test]
    fn lone_tenant_wrr_and_drr_have_zero_contention() {
        // A lone closed-loop stream must replay its solo schedule with
        // zero shift under every online QoS policy, exactly as under
        // the FCFS calendars.
        let cfg = SimConfig::m2ndp();
        let spec = SchedSpec::new(1)
            .with_workloads(vec!['f'])
            .with_policy(PolicyKind::Static(Protocol::Axle))
            .with_requests(3)
            .with_think(US);
        for qos in [QosSpec::wrr(vec![2]), QosSpec::drr(vec![0.5])] {
            let topo = TopologySpec::default().with_qos(qos);
            let r = run_sched(&cfg, &topo, &spec, 2);
            assert_eq!(r.requests.len(), 3);
            for q in &r.requests {
                assert_eq!(q.queue_wait(), 0, "{:?}", r.qos);
                assert_eq!(q.wire_wait(), 0, "{:?}", r.qos);
                assert_eq!(q.pu_wait, 0, "{:?}", r.qos);
            }
        }
    }

    #[test]
    fn online_qos_policies_conserve_wire_work() {
        // Static policy on one fabric-backed device: the message multiset
        // is identical across QoS policies, so per-device bytes, link
        // busy time and fabric busy/bytes must all agree — QoS only
        // redistributes who waits.
        let cfg = SimConfig::m2ndp();
        let spec = SchedSpec::new(3)
            .with_workloads(vec!['a', 'f'])
            .with_policy(PolicyKind::Static(Protocol::Axle))
            .with_requests(2)
            .with_admit(3);
        let run = |qos: QosSpec| {
            let topo = TopologySpec::shared_fabric(1, cfg.cxl_bw_gbps).with_qos(qos);
            run_sched(&cfg, &topo, &spec, 2)
        };
        let fcfs = run(QosSpec::fcfs());
        for other in [run(QosSpec::wrr(vec![3, 1])), run(QosSpec::drr(vec![0.7, 0.3]))] {
            assert_eq!(other.requests.len(), fcfs.requests.len());
            assert_eq!(other.devices[0].bytes, fcfs.devices[0].bytes, "{:?}", other.qos);
            assert_eq!(other.devices[0].link_busy, fcfs.devices[0].link_busy, "{:?}", other.qos);
            assert_eq!(other.fabric.bytes, fcfs.fabric.bytes, "{:?}", other.qos);
            assert_eq!(other.fabric.busy, fcfs.fabric.busy, "{:?}", other.qos);
            for q in &other.requests {
                assert_eq!(q.total(), q.queue_wait() + q.solo + q.wire_wait() + q.pu_wait);
            }
        }
    }

    #[test]
    fn default_qos_is_bit_identical_to_explicit_fcfs() {
        // The FCFS dispatch must route through the unchanged PR-4 path:
        // a default-qos topology and an explicit-FCFS topology produce
        // byte-identical reports.
        let cfg = SimConfig::m2ndp();
        let spec = light_spec(4).with_policy(PolicyKind::Heuristic);
        let plain = TopologySpec::shared_fabric(2, cfg.cxl_bw_gbps);
        let explicit = plain.clone().with_qos(QosSpec::fcfs());
        let a = run_sched(&cfg, &plain, &spec, 2);
        let b = run_sched(&cfg, &explicit, &spec, 2);
        assert_eq!(a.to_json().to_string(), b.to_json().to_string());
        assert_eq!(a.qos, crate::config::QosPolicy::Fcfs);
    }

    #[test]
    fn class_slowdowns_aggregate_per_priority_class() {
        let cfg = SimConfig::m2ndp();
        let topo = TopologySpec::default();
        let spec = SchedSpec::new(4)
            .with_workloads(vec!['f'])
            .with_policy(PolicyKind::Static(Protocol::Bs))
            .with_requests(2)
            .with_priorities(vec![1, 0]);
        let r = run_sched(&cfg, &topo, &spec, 2);
        let classes = r.class_slowdowns();
        assert_eq!(classes.len(), 2);
        // Ascending by class, four requests each (two tenants × two).
        assert_eq!((classes[0].0, classes[0].1), (0, 4));
        assert_eq!((classes[1].0, classes[1].1), (1, 4));
        for (_, _, p50, p99) in &classes {
            assert!(*p50 >= 1.0 && *p99 >= *p50);
        }
        // The JSON mirror carries the same rows.
        let json = r.to_json().to_string();
        assert!(json.contains("\"classes\""));
        assert!(json.contains("\"prio\""));
    }

    #[test]
    fn empty_report_carries_qos_and_empty_classes() {
        let cfg = SimConfig::m2ndp();
        let topo =
            TopologySpec::shared_fabric(2, cfg.cxl_bw_gbps).with_qos(QosSpec::wrr(vec![2, 1]));
        let r = run_sched(&cfg, &topo, &light_spec(0), 2);
        assert_eq!(r.qos, crate::config::QosPolicy::Wrr);
        assert!(r.class_slowdowns().is_empty());
        assert!(r.to_json().to_string().contains("\"qos\""));
    }

    // ---- Fault injection + recovery. ----

    use crate::config::{FaultEvent, FaultSpec};

    /// Two-device strong+weak topology with a fault schedule installed.
    fn faulted(spec: SchedSpec, faults: FaultSpec) -> SchedReport {
        let cfg = SimConfig::m2ndp();
        let topo = TopologySpec::shared_fabric(2, cfg.cxl_bw_gbps)
            .with_override(1, DeviceOverride { ccm_pus: Some(4), ..Default::default() });
        run_sched(&cfg, &topo, &spec.with_faults(faults), 2)
    }

    #[test]
    fn calendar_truncate_drops_future_work_and_clips_straddlers() {
        let mut cal = LinkCalendar::default();
        cal.place(0, 100); // [0, 100)
        cal.place(200, 100); // [200, 300)
        cal.place(400, 100); // [400, 500)
        cal.truncate(250);
        // [400, 500) removed, [200, 300) clipped to [200, 250).
        assert_eq!(cal.busy_union(), 150);
        assert_eq!(cal.tail(), 250);
        assert_eq!(cal.msgs, 2);
        // No-ops: truncating past the tail, and an empty calendar.
        cal.truncate(1000);
        assert_eq!(cal.busy_union(), 150);
        let mut empty = LinkCalendar::default();
        empty.truncate(0);
        assert_eq!(empty.busy_union(), 0);
        // Truncating everything (a device dead from t=0) is also safe.
        cal.truncate(0);
        assert_eq!(cal.busy_union(), 0);
        assert_eq!(cal.tail(), 0);
    }

    #[test]
    fn pool_truncate_mirrors_calendar_semantics() {
        let mut p = OnlinePool::new(2);
        p.dispatch(0, 100); // [0, 100)
        p.dispatch(0, 300); // [0, 300)
        p.dispatch(150, 100); // [150, 250) hmm: earliest free is 100 → [150, 250)
        p.truncate(200);
        // [150, 250) clipped to [150, 200), [0, 300) clipped to [0, 200).
        assert_eq!(p.busy_total, 100 + 200 + 50);
        p.truncate(0);
        assert_eq!(p.busy_total, 0);
        assert_eq!(p.busy_union(), 0);
        let mut empty = OnlinePool::new(1);
        empty.truncate(50);
        assert_eq!(empty.busy_total, 0);
    }

    #[test]
    fn empty_fault_spec_is_structurally_fault_free() {
        // `FaultSpec::default()` never constructs a FaultRuntime, so no
        // fault keys appear in the JSON and nothing retries.
        let r = faulted(light_spec(3), FaultSpec::default());
        assert!(r.faults.is_empty());
        assert_eq!((r.lost_wire, r.lost_pu, r.failed_requests), (0, 0, 0));
        let json = r.to_json().to_string();
        assert!(!json.contains("\"faults\""));
        assert!(!json.contains("\"retries\""));
        assert!(!json.contains("\"placed_on\""));
        for q in &r.requests {
            assert_eq!(q.retries, 0);
            assert_eq!(q.retry_wait, 0);
            assert_eq!(q.placed_on.len(), 1);
            assert!(!q.failed);
        }
    }

    /// A baseline request served on device 0, plus an instant strictly
    /// inside its service window. The engine is deterministic and a
    /// faulted run matches the fault-free one bit for bit up to its
    /// first fault event, so a fault injected at this instant is
    /// guaranteed to catch exactly this request in service.
    fn mid_service_on_dev0(base: &SchedReport) -> (RequestRun, Ps) {
        let q = base
            .requests
            .iter()
            .filter(|q| q.device == 0 && q.completion > q.admit + 1)
            .max_by_key(|q| q.completion - q.admit)
            .expect("baseline places service on device 0");
        (q.clone(), q.admit + (q.completion - q.admit) / 2)
    }

    #[test]
    fn permanent_failure_completes_on_survivor_with_zero_lost_requests() {
        let spec = SchedSpec::new(4)
            .with_workloads(vec!['a', 'f'])
            .with_policy(PolicyKind::Static(Protocol::Axle))
            .with_requests(3);
        let base = faulted(spec.clone(), FaultSpec::default());
        let (_, at) = mid_service_on_dev0(&base);
        let r = faulted(spec, FaultSpec::with(vec![FaultEvent::fail(0, at)]));
        assert_eq!(r.requests.len(), 12, "no request may be lost");
        assert_eq!(r.failed_requests, 0, "survivor absorbs everything within the retry budget");
        for q in &r.requests {
            // Every request submitted after the failure ends on device 1.
            if q.submit > at {
                assert_eq!(q.device, 1);
            }
            assert!(!q.failed);
            assert_eq!(
                q.total(),
                q.queue_wait() + q.retry_wait + q.solo + q.wire_wait() + q.pu_wait
            );
        }
        let row = &r.faults[0];
        assert_eq!(row.kind, crate::config::FaultKind::Fail);
        assert_eq!((row.device, row.at, row.until), (0, at, at));
        // The kill caught at least one in-service attempt: displaced and
        // retried work, wasted wire/PU charges, time-to-recover.
        assert!(row.displaced > 0, "mid-service kill must displace live work");
        assert!(row.recover > 0, "displaced work must re-enter service after the fault");
        assert!(row.lost_wire + row.lost_pu > 0, "killed attempt charges count as lost work");
        assert!(r.requests.iter().any(|q| q.retries > 0));
        assert_eq!((r.lost_wire, r.lost_pu), (row.lost_wire, row.lost_pu));
        // Provenance: displaced requests record both devices.
        assert!(r.requests.iter().any(|q| q.placed_on.len() > 1));
        let json = r.to_json().to_string();
        assert!(json.contains("\"faults\"") && json.contains("\"recover_ps\""));
    }

    #[test]
    fn stall_window_suspends_and_recovers() {
        let spec = SchedSpec::new(2)
            .with_workloads(vec!['f'])
            .with_policy(PolicyKind::Static(Protocol::Axle))
            .with_requests(2);
        let base = faulted(spec.clone(), FaultSpec::default());
        let (victim, at) = mid_service_on_dev0(&base);
        let until = at + 300 * US;
        let r = faulted(spec, FaultSpec::with(vec![FaultEvent::stall(0, at, until)]));
        assert_eq!(r.requests.len(), 4);
        assert_eq!(r.failed_requests, 0);
        let row = &r.faults[0];
        // The suspended in-service attempt cannot resume before the
        // window closes, so recovery spans at least the window.
        assert!(row.displaced > 0, "mid-service stall must suspend live work");
        assert!(row.recover >= until - at);
        assert_eq!((row.lost_wire, row.lost_pu), (0, 0), "stalls waste no completed work");
        // Suspension slides the victim's completion by exactly the
        // remaining window, charged to its pu_wait.
        let rq = r
            .requests
            .iter()
            .find(|q| q.tenant == victim.tenant && q.index == victim.index)
            .expect("victim request present");
        assert_eq!(rq.completion, victim.completion + (until - at));
        assert_eq!(rq.pu_wait, victim.pu_wait + (until - at));
        for q in &r.requests {
            assert_eq!(
                q.total(),
                q.queue_wait() + q.retry_wait + q.solo + q.wire_wait() + q.pu_wait
            );
        }
    }

    #[test]
    fn degradation_slows_work_without_displacing_it() {
        let spec = SchedSpec::new(3)
            .with_workloads(vec!['a'])
            .with_policy(PolicyKind::Static(Protocol::Bs))
            .with_requests(2)
            .with_admit(3);
        let base = faulted(spec.clone(), FaultSpec::default());
        // Degrade both resources of device 0 heavily over a long window.
        let r = faulted(
            spec,
            FaultSpec::with(vec![
                FaultEvent::degrade_pus(0, 0, 4_000_000 * US, 8.0),
                FaultEvent::degrade_link(0, 0, 4_000_000 * US, 8.0),
            ]),
        );
        assert_eq!(r.requests.len(), base.requests.len());
        assert_eq!(r.failed_requests, 0);
        for row in &r.faults {
            assert_eq!(row.displaced, 0, "degradation displaces nothing");
            assert_eq!(row.recover, 0);
        }
        assert!(
            r.makespan > base.makespan,
            "an 8x degraded device must stretch the run ({} vs {})",
            r.makespan,
            base.makespan
        );
        for q in &r.requests {
            assert_eq!(
                q.total(),
                q.queue_wait() + q.retry_wait + q.solo + q.wire_wait() + q.pu_wait
            );
        }
    }

    #[test]
    fn fault_mode_placement_matches_fault_free_before_any_fault() {
        // A schedule whose only window opens after the run ends leaves
        // request-level results identical to fault-free (the outcome
        // rows differ, so compare per-request fields, not whole JSON).
        let spec = light_spec(3);
        let base = faulted(spec.clone(), FaultSpec::default());
        let far = faulted(
            spec,
            FaultSpec::with(vec![FaultEvent::stall(0, 4_000_000_000 * US, 4_000_001_000 * US)]),
        );
        assert_eq!(base.requests.len(), far.requests.len());
        for (a, b) in base.requests.iter().zip(far.requests.iter()) {
            assert_eq!(a.to_json().to_string(), b.to_json().to_string());
        }
        assert_eq!(base.makespan, far.makespan);
        assert_eq!(far.faults.len(), 1);
        assert_eq!(far.faults[0].displaced, 0);
    }
}
