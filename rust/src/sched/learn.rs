//! Learned, feedback-driven scheduling — the closed loop closing on
//! itself.
//!
//! The paper's protocol study (and UDON's offload study) both end at
//! the same place: the best choice depends on conditions the profile
//! can't see ahead of time, so measure and adapt. [`LearnedDecider`]
//! does exactly that behind the [`super::policy::Decider`] API:
//!
//! - **Estimators.** One [`ArmEstimator`] per `(device, workload,
//!   protocol)` arm holds a count-weighted mean of observed end-to-end
//!   latency (`queue_wait + solo + wire_wait + pu_wait`), in integer
//!   picoseconds — no floats, no decay constants to tune. An arm with
//!   no observations reports the candidate's solo profile as its prior,
//!   so cold starts equal the Oracle's static view.
//! - **Placement.** Under `Pinned` the decider honors the pinning (the
//!   `--jobs` sharding contract maps tenants onto devices by ordinal,
//!   and per-device estimator state then never crosses a shard
//!   boundary, keeping sharded runs byte-identical). Under the other
//!   disciplines it routes each request to the device minimizing
//!   `best-arm estimate + live backlog` — an *instantaneous* signal, so
//!   a mid-run degradation reroutes traffic immediately where the
//!   static least-loaded metric keeps feeding a slowed device.
//! - **Exploration.** A seeded epsilon-greedy draw
//!   ([`explore_draw`]) explores with probability
//!   `explore / (visits + explore)`: certainly at first sight of a
//!   `(device, workload)` pair, decaying as observations accumulate,
//!   never when `--explore 0`. The draw is a stateless hash of
//!   `(seed, tenant, request index)` — reproducible, order-free, and
//!   independent of sharding.
//!
//! Arms are keyed by device *id*, not class: two same-class devices can
//! degrade differently mid-run (and can live in different shards), so
//! per-id state is both the correct learning granularity and the one
//! that keeps `--jobs N` merges exact.

use std::collections::HashMap;

use crate::config::{Placement, Protocol};
use crate::sim::Ps;

use super::policy::{Decider, Decision, Feedback, RequestCtx};

/// Count-weighted mean latency of one `(device, workload, protocol)`
/// arm, in integer picoseconds. Order-free: any interleaving (or shard
/// merge) of the same observation multiset yields the same state.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ArmEstimator {
    pub count: u64,
    pub total: u128,
}

impl ArmEstimator {
    /// Fold in one observed latency.
    pub fn observe(&mut self, sample: Ps) {
        self.count += 1;
        self.total += sample as u128;
    }

    /// Combine two estimators over disjoint observation sets —
    /// commutative and associative, the shard-merge identity.
    pub fn merge(&mut self, other: &ArmEstimator) {
        self.count += other.count;
        self.total += other.total;
    }

    /// The arm's latency estimate; `prior` (the candidate's solo
    /// profile) until the first observation lands.
    pub fn mean(&self, prior: Ps) -> Ps {
        if self.count == 0 {
            prior
        } else {
            (self.total / self.count as u128) as Ps
        }
    }
}

fn splitmix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Domain-separation salt for the exploration hash (distinct from the
/// submission-stagger stream).
const EXPLORE_SALT: u64 = 0x1EA8_4ED0_05ED_0A10;

/// Seeded epsilon-greedy draw: explore iff
/// `u < 2^32 · explore / (visits + explore)` where `u` is a uniform
/// 32-bit hash of `(seed, tenant, index)`. Evaluated in fixed point as
/// `u · (visits + explore) < explore · 2^32`, so for a fixed request
/// the outcome is **monotone** in `visits` — once a request would stop
/// exploring it never resumes as visits grow, and `visits == 0` with
/// `explore > 0` always explores. `explore == 0` never does.
pub fn explore_draw(seed: u64, tenant: usize, index: u64, visits: u64, explore: u32) -> bool {
    if explore == 0 {
        return false;
    }
    let key = seed ^ EXPLORE_SALT ^ ((tenant as u64) << 32).wrapping_add(index);
    let u = (splitmix64(key) >> 32) as u128;
    u * (visits as u128 + explore as u128) < (explore as u128) << 32
}

/// The learned decider: per-arm latency estimators + backlog-aware
/// placement + decaying seeded exploration. See the module docs for the
/// design and determinism argument.
pub struct LearnedDecider {
    seed: u64,
    explore: u32,
    /// `(device, workload annot, protocol) → estimator`.
    arms: HashMap<(u32, char, Protocol), ArmEstimator>,
    /// Decisions taken per `(device, workload annot)` — the exploration
    /// decay clock.
    visits: HashMap<(u32, char), u64>,
}

impl LearnedDecider {
    pub fn new(seed: u64, explore: u32) -> Self {
        Self { seed, explore, arms: HashMap::new(), visits: HashMap::new() }
    }

    fn arm_mean(&self, device: usize, annot: char, proto: Protocol, prior: Ps) -> Ps {
        self.arms
            .get(&(device as u32, annot, proto))
            .map(|e| e.mean(prior))
            .unwrap_or(prior)
    }

    fn arm_count(&self, device: usize, annot: char, proto: Protocol) -> u64 {
        self.arms.get(&(device as u32, annot, proto)).map(|e| e.count).unwrap_or(0)
    }

    /// A device's score for this request: the best arm's latency
    /// estimate plus the device's live backlog (PU plus the worse
    /// wire). The backlog term is what reacts *within* a degradation
    /// window, before the estimators have re-converged.
    fn device_score(&self, ctx: &RequestCtx<'_>, d: usize) -> Ps {
        let view = &ctx.devices[d];
        let best = view
            .cands
            .iter()
            .map(|c| self.arm_mean(d, ctx.annot, c.proto, c.solo))
            .min()
            .unwrap_or(0);
        best.saturating_add(view.obs.pu_backlog)
            .saturating_add(view.obs.mem_backlog.max(view.obs.io_backlog))
    }

    /// Placement: honor `Pinned` (probing forward to the nearest
    /// eligible survivor under faults, exactly like the filtered pinned
    /// probe); otherwise argmin of [`Self::device_score`] over eligible
    /// devices, ties to the lowest id.
    fn place(&self, ctx: &RequestCtx<'_>) -> usize {
        let n = ctx.devices.len();
        let eligible = |i: usize| !ctx.faulted || ctx.devices[i].eligible;
        let alive = |i: usize| !ctx.faulted || ctx.devices[i].alive;
        if ctx.placement == Placement::Pinned {
            let home = ctx.tenant % n;
            return (0..n)
                .map(|k| (home + k) % n)
                .find(|&i| eligible(i))
                .or_else(|| (0..n).map(|k| (home + k) % n).find(|&i| alive(i)))
                .expect("validated fault spec leaves at least one device alive");
        }
        let argmin = |ok: &dyn Fn(usize) -> bool| {
            (0..n).filter(|&i| ok(i)).min_by_key(|&i| (self.device_score(ctx, i), i))
        };
        argmin(&eligible)
            .or_else(|| argmin(&alive))
            .expect("validated fault spec leaves at least one device alive")
    }
}

impl Decider for LearnedDecider {
    fn label(&self) -> String {
        crate::config::PolicyKind::Learned.label()
    }

    fn decide(&mut self, ctx: &RequestCtx, _rr_next: &mut usize) -> Decision {
        let device = self.place(ctx);
        let view = &ctx.devices[device];
        let visits = self.visits.entry((device as u32, ctx.annot)).or_insert(0);
        let exploring = explore_draw(self.seed, ctx.tenant, ctx.index, *visits, self.explore);
        *visits += 1;
        let proto = if exploring {
            // Least-sampled arm first — spread observations evenly.
            view.cands
                .iter()
                .enumerate()
                .min_by_key(|(i, c)| (self.arm_count(device, ctx.annot, c.proto), *i))
                .map(|(_, c)| c.proto)
        } else {
            view.cands
                .iter()
                .enumerate()
                .min_by_key(|(i, c)| (self.arm_mean(device, ctx.annot, c.proto, c.solo), *i))
                .map(|(_, c)| c.proto)
        }
        .expect("candidate set is never empty");
        Decision { device, proto }
    }

    fn observe(&mut self, fb: &Feedback) {
        let total = fb
            .queue_wait
            .saturating_add(fb.solo)
            .saturating_add(fb.wire_wait)
            .saturating_add(fb.pu_wait);
        self.arms
            .entry((fb.device as u32, fb.annot, fb.proto))
            .or_default()
            .observe(total);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sched::policy::{Candidate, DeviceView, Observed};
    use crate::sim::US;

    #[test]
    fn estimator_mean_uses_prior_until_observed() {
        let mut e = ArmEstimator::default();
        assert_eq!(e.mean(7 * US), 7 * US);
        e.observe(10 * US);
        e.observe(20 * US);
        assert_eq!(e.mean(7 * US), 15 * US);
    }

    #[test]
    fn estimator_merge_is_order_free() {
        let samples = [3 * US, 9 * US, US, 27 * US];
        let mut all = ArmEstimator::default();
        for s in samples {
            all.observe(s);
        }
        let (mut a, mut b) = (ArmEstimator::default(), ArmEstimator::default());
        a.observe(samples[2]);
        a.observe(samples[0]);
        b.observe(samples[3]);
        b.observe(samples[1]);
        let mut ab = a;
        ab.merge(&b);
        let mut ba = b;
        ba.merge(&a);
        assert_eq!(ab, all);
        assert_eq!(ba, all);
    }

    #[test]
    fn explore_draw_decays_monotonically() {
        for tenant in 0..8usize {
            for index in 0..8u64 {
                // Always explores the first visit of an arm set.
                assert!(explore_draw(42, tenant, index, 0, 8));
                // Never explores with exploration disabled.
                assert!(!explore_draw(42, tenant, index, 0, 0));
                // Monotone: once off, stays off as visits grow.
                let mut was = true;
                for visits in 0..4096u64 {
                    let now = explore_draw(42, tenant, index, visits, 8);
                    assert!(was || !now, "exploration resumed at visits={visits}");
                    was = now;
                }
            }
        }
    }

    fn cand(proto: Protocol, solo: Ps) -> Candidate {
        Candidate { proto, solo, ccm_busy: solo / 2, dm_busy: solo / 2, mem_bytes: 0, io_bytes: 0 }
    }

    fn ctx<'a>(
        devices: &'a [DeviceView<'a>],
        tenant: usize,
        index: u64,
        placement: Placement,
    ) -> RequestCtx<'a> {
        RequestCtx { tenant, index, annot: 'a', now: 0, placement, faulted: false, devices }
    }

    #[test]
    fn learned_honors_pinned_placement() {
        let cands = [cand(Protocol::Rp, 9 * US), cand(Protocol::Bs, 6 * US)];
        let views: Vec<DeviceView<'_>> = (0..3)
            .map(|_| DeviceView {
                class: 0,
                alive: true,
                eligible: true,
                load: 0,
                obs: Observed::default(),
                cands: &cands,
            })
            .collect();
        let mut dec = LearnedDecider::new(1, 0);
        let mut rr = 0usize;
        for tenant in 0..9usize {
            let d = dec.decide(&ctx(&views, tenant, 0, Placement::Pinned), &mut rr);
            assert_eq!(d.device, tenant % 3);
        }
    }

    #[test]
    fn learned_greedy_follows_observed_latencies() {
        let cands = [cand(Protocol::Rp, 9 * US), cand(Protocol::Bs, 6 * US)];
        let views = [DeviceView {
            class: 0,
            alive: true,
            eligible: true,
            load: 0,
            obs: Observed::default(),
            cands: &cands,
        }];
        let mut dec = LearnedDecider::new(1, 0);
        let mut rr = 0usize;
        // Greedy on priors: BS has the lower solo.
        let first = dec.decide(&ctx(&views, 0, 0, Placement::LeastLoaded), &mut rr);
        assert_eq!(first.proto, Protocol::Bs);
        // BS turns out terrible in practice; RP's prior now wins.
        let fb = Feedback {
            tenant: 0,
            index: 0,
            annot: 'a',
            device: 0,
            device_class: 0,
            proto: Protocol::Bs,
            queue_wait: 0,
            solo: 6 * US,
            wire_wait: 40 * US,
            pu_wait: 0,
        };
        dec.observe(&fb);
        let second = dec.decide(&ctx(&views, 0, 1, Placement::LeastLoaded), &mut rr);
        assert_eq!(second.proto, Protocol::Rp);
    }

    #[test]
    fn learned_placement_routes_around_backlog() {
        let cands = [cand(Protocol::Bs, 6 * US)];
        let mut views: Vec<DeviceView<'_>> = (0..2)
            .map(|_| DeviceView {
                class: 0,
                alive: true,
                eligible: true,
                load: 0,
                obs: Observed::default(),
                cands: &cands,
            })
            .collect();
        // Device 0 carries a deep PU backlog: the learned placement
        // must prefer device 1 even though static load says otherwise.
        views[0].obs.pu_backlog = 50 * US;
        views[0].load = 0;
        views[1].load = 100 * US;
        let mut dec = LearnedDecider::new(1, 0);
        let mut rr = 0usize;
        let d = dec.decide(&ctx(&views, 0, 0, Placement::LeastLoaded), &mut rr);
        assert_eq!(d.device, 1);
    }

    #[test]
    fn learned_decisions_are_reproducible() {
        let cands = [
            cand(Protocol::Rp, 9 * US),
            cand(Protocol::Bs, 6 * US),
            cand(Protocol::Axle, 5 * US),
        ];
        let views: Vec<DeviceView<'_>> = (0..2)
            .map(|_| DeviceView {
                class: 0,
                alive: true,
                eligible: true,
                load: 0,
                obs: Observed::default(),
                cands: &cands,
            })
            .collect();
        let run = |seed: u64| {
            let mut dec = LearnedDecider::new(seed, 8);
            let mut rr = 0usize;
            let mut out = Vec::new();
            for i in 0..32u64 {
                let d = dec.decide(&ctx(&views, (i % 4) as usize, i / 4, Placement::RoundRobin), &mut rr);
                out.push((d.device, d.proto));
                dec.observe(&Feedback {
                    tenant: (i % 4) as usize,
                    index: i / 4,
                    annot: 'a',
                    device: d.device,
                    device_class: 0,
                    proto: d.proto,
                    queue_wait: i as Ps * US,
                    solo: 6 * US,
                    wire_wait: 0,
                    pu_wait: 0,
                });
            }
            out
        };
        assert_eq!(run(7), run(7));
        // And the seed actually matters for exploration somewhere.
        let (a, b) = (run(7), run(8));
        assert!(a.len() == b.len());
    }
}
