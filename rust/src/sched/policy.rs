//! Per-request offload-protocol policies.
//!
//! The paper's core observation is that no single offloading mechanism
//! wins everywhere: RP's coarse batching amortizes well on heavy kernels
//! with tiny results (Fig. 3), BS's synchronous CXL.mem flow is clean
//! when data dominates and nothing else contends the channel, and AXLE's
//! asynchronous back-streaming wins when compute and transfer can
//! overlap. UDON makes the same case for deciding *what* runs near
//! memory online. The closed-loop scheduler therefore consults an
//! [`OffloadPolicy`] once per request, with two kinds of information:
//!
//! - [`Candidate`] summaries — the request's solo profile under each
//!   candidate protocol **on the target device's config** (heterogeneous
//!   devices give different summaries per device class), precomputed by
//!   the driver's solo pass and deduped through the sweep engine's
//!   workload cache;
//! - an [`Observed`] snapshot — the target device's link/PU occupancy
//!   and admission backlog at submission time, the closed loop's live
//!   feedback signal.
//!
//! Three pure protocol rules ship ([`policy_for`]):
//!
//! | policy | choice | role |
//! |---|---|---|
//! | [`StaticPolicy`] | one pinned protocol | PR-3 behavior; regression baseline |
//! | [`HeuristicPolicy`] | compute-vs-transfer ratio + occupancy rule | the paper-style online scheduler |
//! | [`OraclePolicy`] | smallest solo runtime on the device class | clairvoyant per-request bound |
//!
//! **The decision layer.** PR 10 generalizes the plug point: the driver
//! now consults one stateful [`Decider`] per run —
//! `decide(&RequestCtx) -> Decision { device, proto }` over per-device
//! [`DeviceView`] snapshots (placement moves *inside* the policy), plus
//! an `observe(&Feedback)` hook fed from each completion's decomposed
//! latency (`queue_wait` / `solo` / `wire_wait` / `pu_wait`). The three
//! pure rules above are re-expressed as [`PolicyDecider`]s whose
//! placement delegates to [`crate::topo::place_device`] /
//! [`crate::topo::place_device_filtered`] exactly as the driver used to
//! call them inline, so their decision sequences — and therefore their
//! reports — are bit-identical to PR 9 (pinned in
//! `tests/sched_regression.rs`). The learned, feedback-driven decider
//! lives in [`crate::sched::learn`]; [`decider_for`] materializes
//! whichever one a [`SchedSpec`] names.

use crate::config::{Placement, PolicyKind, Protocol, SchedSpec};
use crate::sim::Ps;

/// One candidate protocol's solo profile for a request on its target
/// device class (see the driver's solo pass).
#[derive(Debug, Clone, Copy)]
pub struct Candidate {
    pub proto: Protocol,
    /// Solo end-to-end runtime on the target device's config.
    pub solo: Ps,
    /// Solo CCM busy-union (T_C) — the compute side of the ratio.
    pub ccm_busy: Ps,
    /// Solo data-movement busy-union (T_D) — the transfer side.
    pub dm_busy: Ps,
    /// Data bytes the candidate moves over the device's CXL.mem channel.
    pub mem_bytes: u64,
    /// Data bytes the candidate moves over the device's CXL.io channel.
    pub io_bytes: u64,
}

/// What the scheduler can observe about the target device at submission
/// time — the closed loop's feedback signal.
#[derive(Debug, Clone, Copy, Default)]
pub struct Observed {
    /// How far the device's CXL.mem busy calendar extends beyond now.
    pub mem_backlog: Ps,
    /// How far the device's CXL.io busy calendar extends beyond now.
    pub io_backlog: Ps,
    /// How far ahead the device's earliest-free CCM PU is booked.
    pub pu_backlog: Ps,
    /// Requests waiting in the device's admission queue.
    pub queued: usize,
}

/// A per-request protocol selector. Implementations must be pure
/// functions of their inputs — the driver's determinism contract (same
/// spec, same report) rests on it.
pub trait OffloadPolicy {
    fn label(&self) -> String;
    /// Pick the protocol for one request. `cands` holds the candidate
    /// set in [`CANDIDATES`] order (plus the pinned protocol for static
    /// policies); it is never empty.
    fn choose(&self, cands: &[Candidate], obs: &Observed) -> Protocol;
}

/// The candidate set adaptive policies choose from, in preference-stable
/// order. `AxleInterrupt` is reachable only by pinning it statically.
pub const CANDIDATES: [Protocol; 3] = [Protocol::Rp, Protocol::Bs, Protocol::Axle];

/// Every request uses one pinned protocol — the PR-3 tenant path's
/// behavior, kept as the regression baseline.
#[derive(Debug, Clone, Copy)]
pub struct StaticPolicy(pub Protocol);

impl OffloadPolicy for StaticPolicy {
    fn label(&self) -> String {
        PolicyKind::Static(self.0).label()
    }

    fn choose(&self, _cands: &[Candidate], _obs: &Observed) -> Protocol {
        self.0
    }
}

/// Paper-style adaptive rule. Intensity comes from the bulk-synchronous
/// candidate: BS is a fully serialized pipeline (Fig. 6), so its T_C and
/// T_D are the workload's intrinsic compute and transfer demands on this
/// device class.
///
/// - **Transfer-bound** (`T_D >= T_C`): route the data onto the emptier
///   channel — AXLE back-streams results over CXL.io, BS moves them over
///   CXL.mem — so one backlogged wire steers the request to the other.
/// - **Compute-bound** (`T_C > T_D`): results trickle, so AXLE's
///   fine-grained overlap is the default; remote polling is chosen only
///   when it is genuinely competitive on this device class (heavy
///   kernels with tiny results, Fig. 3) *and* the PU pool is booked more
///   than one AXLE solo ahead, where coarse batching costs nothing.
///   A backlogged CXL.io channel still steers to BS.
#[derive(Debug, Clone, Copy, Default)]
pub struct HeuristicPolicy;

impl HeuristicPolicy {
    fn find(cands: &[Candidate], proto: Protocol) -> Option<&Candidate> {
        cands.iter().find(|c| c.proto == proto)
    }

    /// A pruned candidate set must not abort a million-request run:
    /// fall back to BS (the always-correct synchronous flow) and warn
    /// once per process.
    fn fallback() -> Protocol {
        static WARN_ONCE: std::sync::Once = std::sync::Once::new();
        WARN_ONCE.call_once(|| {
            eprintln!(
                "warning: heuristic policy ran without the full candidate set; \
                 falling back to bs for affected requests"
            );
        });
        Protocol::Bs
    }
}

impl OffloadPolicy for HeuristicPolicy {
    fn label(&self) -> String {
        PolicyKind::Heuristic.label()
    }

    fn choose(&self, cands: &[Candidate], obs: &Observed) -> Protocol {
        let (Some(rp), Some(bs), Some(axle)) = (
            Self::find(cands, Protocol::Rp),
            Self::find(cands, Protocol::Bs),
            Self::find(cands, Protocol::Axle),
        ) else {
            return Self::fallback();
        };
        let transfer_bound = bs.dm_busy >= bs.ccm_busy;
        if !transfer_bound
            && rp.solo <= bs.solo.min(axle.solo)
            && obs.pu_backlog > axle.solo
        {
            return Protocol::Rp;
        }
        if obs.io_backlog > obs.mem_backlog {
            Protocol::Bs
        } else {
            Protocol::Axle
        }
    }
}

/// Clairvoyant per-request choice: the candidate with the smallest solo
/// runtime on the target device class (ties break in [`CANDIDATES`]
/// order). Ignores occupancy by design — it bounds what per-request
/// protocol selection alone can buy, reported against in `axle report
/// fig19`.
#[derive(Debug, Clone, Copy, Default)]
pub struct OraclePolicy;

impl OffloadPolicy for OraclePolicy {
    fn label(&self) -> String {
        PolicyKind::Oracle.label()
    }

    fn choose(&self, cands: &[Candidate], _obs: &Observed) -> Protocol {
        cands
            .iter()
            .enumerate()
            .min_by_key(|(i, c)| (c.solo, *i))
            .map(|(_, c)| c.proto)
            .expect("candidate set is never empty")
    }
}

// ---------------------------------------------------------------------
// The decision layer: a unified, stateful placement + protocol API.
// ---------------------------------------------------------------------

/// One device's submission-time snapshot as a [`Decider`] sees it. The
/// driver rebuilds these per decision from live `DevState`; everything
/// here is a pure function of simulation state, so decisions stay
/// deterministic.
#[derive(Debug, Clone, Copy)]
pub struct DeviceView<'a> {
    /// Device-class index — heterogeneous topologies share one solo
    /// profile (and one `cands` slice) per class.
    pub class: usize,
    /// `false` once a permanent failure removed the device.
    pub alive: bool,
    /// Alive *and* currently admitting (no transient stall holds the
    /// gate shut). Always `true` on fault-free runs.
    pub eligible: bool,
    /// Cumulative solo-estimate load placed on the device so far — the
    /// least-loaded placement metric (static: it ignores degradation).
    pub load: Ps,
    /// Live occupancy snapshot — the closed loop's feedback signal.
    pub obs: Observed,
    /// Candidate solo profiles for this request's workload on this
    /// device's class, in [`required_candidates`] order.
    pub cands: &'a [Candidate],
}

/// Everything a [`Decider`] may consult for one request.
#[derive(Debug, Clone, Copy)]
pub struct RequestCtx<'a> {
    pub tenant: usize,
    /// Request index within the tenant's closed-loop sequence.
    pub index: u64,
    /// Workload annotation the tenant runs.
    pub annot: char,
    /// Submission time.
    pub now: Ps,
    /// The run's configured placement discipline. Deciders that
    /// delegate placement honor it verbatim; the learned decider honors
    /// `Pinned` (the `--jobs` sharding contract depends on it) and
    /// treats the rest as freedom to balance.
    pub placement: Placement,
    /// `true` iff the run carries an injected fault schedule — deciders
    /// must then restrict placement to `eligible` (or, if none, `alive`)
    /// devices.
    pub faulted: bool,
    pub devices: &'a [DeviceView<'a>],
}

/// A [`Decider`]'s verdict for one request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Decision {
    pub device: usize,
    pub proto: Protocol,
}

/// One completion's decomposed latency, fed back through
/// [`Decider::observe`]. The components sum (with `retry_wait`, zero
/// fault-free) to the request's end-to-end latency.
#[derive(Debug, Clone, Copy)]
pub struct Feedback {
    pub tenant: usize,
    pub index: u64,
    pub annot: char,
    pub device: usize,
    pub device_class: usize,
    pub proto: Protocol,
    /// Admission-queue wait (fault-recovery share excluded).
    pub queue_wait: Ps,
    /// Solo end-to-end runtime on the device's config.
    pub solo: Ps,
    /// Wire-contention completion shift (device links ∨ shared fabric).
    pub wire_wait: Ps,
    /// CCM PU-pool contention completion shift.
    pub pu_wait: Ps,
}

/// The unified decision API: one stateful decider per run picks *where*
/// a request goes and *how* it offloads, and hears about every
/// completion. Determinism contract: `decide` may depend only on the
/// ctx, the round-robin cursor, and state accumulated through prior
/// `decide`/`observe` calls — never on wall clock or ambient randomness
/// (seeded draws derive from [`SchedSpec::seed`]).
pub trait Decider {
    fn label(&self) -> String;
    /// Decide placement + protocol for one request. `rr_next` is the
    /// run's round-robin cursor, threaded through so rotation-based
    /// placements stay bit-identical to the pre-decider driver.
    fn decide(&mut self, ctx: &RequestCtx, rr_next: &mut usize) -> Decision;
    /// Hear one completion's decomposed latency. Stateless deciders
    /// ignore it.
    fn observe(&mut self, _fb: &Feedback) {}
}

/// The three pure protocol rules re-expressed as [`Decider`]s:
/// placement delegates to [`crate::topo::place_device`] (fault-free) or
/// the eligible→alive filtered probe (faulted) exactly as the driver
/// used to inline them, then the wrapped [`OffloadPolicy`] picks the
/// protocol from the placed device's view. Bit-identical to the PR 9
/// decision sequence by construction.
pub struct PolicyDecider {
    policy: Box<dyn OffloadPolicy>,
}

impl PolicyDecider {
    pub fn new(policy: Box<dyn OffloadPolicy>) -> Self {
        Self { policy }
    }
}

/// Fault-aware placement over device views: among alive devices,
/// preferring ones whose admission gate is open. With every device
/// eligible this chooses exactly what [`crate::topo::place_device`]
/// would (unit-pinned there), so a fault schedule whose windows never
/// open still matches fault-free placement bit-for-bit.
pub fn place_faulted(
    placement: Placement,
    devices: &[DeviceView<'_>],
    ordinal: usize,
    rr_next: &mut usize,
) -> usize {
    crate::topo::place_device_filtered(
        placement,
        devices.len(),
        ordinal,
        |i| devices[i].eligible,
        |i| devices[i].load,
        rr_next,
    )
    .or_else(|| {
        // Everything alive is stalled: place on a stalled device anyway
        // (timeouts keep the request from being stranded there).
        crate::topo::place_device_filtered(
            placement,
            devices.len(),
            ordinal,
            |i| devices[i].alive,
            |i| devices[i].load,
            rr_next,
        )
    })
    .expect("validated fault spec leaves at least one device alive")
}

impl Decider for PolicyDecider {
    fn label(&self) -> String {
        self.policy.label()
    }

    fn decide(&mut self, ctx: &RequestCtx, rr_next: &mut usize) -> Decision {
        let device = if ctx.faulted {
            place_faulted(ctx.placement, ctx.devices, ctx.tenant, rr_next)
        } else {
            crate::topo::place_device(
                ctx.placement,
                ctx.devices.len(),
                ctx.tenant,
                |i| ctx.devices[i].load,
                rr_next,
            )
        };
        let view = &ctx.devices[device];
        Decision { device, proto: self.policy.choose(view.cands, &view.obs) }
    }
}

/// Materialize the decider a [`SchedSpec`] names — the driver's single
/// entry into the decision layer.
pub fn decider_for(spec: &SchedSpec) -> Box<dyn Decider> {
    match spec.policy {
        PolicyKind::Learned => {
            Box::new(super::learn::LearnedDecider::new(spec.seed, spec.explore))
        }
        kind => Box::new(PolicyDecider::new(policy_for(kind))),
    }
}

/// Materialize the pure protocol rule a [`PolicyKind`] names.
///
/// # Panics
///
/// On [`PolicyKind::Learned`], which is stateful and owns placement —
/// it only exists behind [`decider_for`].
pub fn policy_for(kind: PolicyKind) -> Box<dyn OffloadPolicy> {
    match kind {
        PolicyKind::Static(p) => Box::new(StaticPolicy(p)),
        PolicyKind::Heuristic => Box::new(HeuristicPolicy),
        PolicyKind::Oracle => Box::new(OraclePolicy),
        PolicyKind::Learned => {
            panic!("the learned policy is a stateful decider; use decider_for")
        }
    }
}

/// The protocols whose solo profiles a policy needs precomputed. The
/// learned decider scores all three adaptive candidates.
pub fn required_candidates(kind: PolicyKind) -> Vec<Protocol> {
    match kind {
        PolicyKind::Static(p) => vec![p],
        PolicyKind::Heuristic | PolicyKind::Oracle | PolicyKind::Learned => CANDIDATES.to_vec(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::US;

    fn cand(proto: Protocol, solo: Ps, ccm: Ps, dm: Ps) -> Candidate {
        Candidate { proto, solo, ccm_busy: ccm, dm_busy: dm, mem_bytes: 0, io_bytes: 0 }
    }

    /// rp slow, bs middling, axle fastest — the Fig. 10 common case.
    fn common_cands(transfer_bound: bool) -> Vec<Candidate> {
        let (ccm, dm) = if transfer_bound { (10 * US, 40 * US) } else { (40 * US, 10 * US) };
        vec![
            cand(Protocol::Rp, 100 * US, ccm, dm),
            cand(Protocol::Bs, 60 * US, ccm, dm),
            cand(Protocol::Axle, 50 * US, ccm, dm),
        ]
    }

    #[test]
    fn static_policy_pins_protocol() {
        let p = StaticPolicy(Protocol::Bs);
        assert_eq!(p.choose(&common_cands(true), &Observed::default()), Protocol::Bs);
        assert_eq!(p.label(), "static-bs");
    }

    #[test]
    fn heuristic_idle_device_picks_axle() {
        let p = HeuristicPolicy;
        for tb in [true, false] {
            assert_eq!(p.choose(&common_cands(tb), &Observed::default()), Protocol::Axle);
        }
    }

    #[test]
    fn heuristic_backlogged_io_steers_to_bs() {
        let p = HeuristicPolicy;
        let obs = Observed { io_backlog: 5 * US, mem_backlog: US, ..Default::default() };
        assert_eq!(p.choose(&common_cands(true), &obs), Protocol::Bs);
        // Mem more backlogged than io: stay on the io channel (AXLE).
        let obs2 = Observed { io_backlog: US, mem_backlog: 5 * US, ..Default::default() };
        assert_eq!(p.choose(&common_cands(true), &obs2), Protocol::Axle);
    }

    #[test]
    fn heuristic_rp_needs_competitive_solo_and_deep_pu_backlog() {
        let p = HeuristicPolicy;
        // Compute-bound, RP genuinely fastest on this class.
        let cands = vec![
            cand(Protocol::Rp, 40 * US, 40 * US, 5 * US),
            cand(Protocol::Bs, 60 * US, 40 * US, 5 * US),
            cand(Protocol::Axle, 50 * US, 40 * US, 5 * US),
        ];
        let deep = Observed { pu_backlog: 200 * US, ..Default::default() };
        assert_eq!(p.choose(&cands, &deep), Protocol::Rp);
        // Shallow backlog: fine-grained overlap still wins.
        assert_eq!(p.choose(&cands, &Observed::default()), Protocol::Axle);
        // RP not competitive: never chosen, however deep the backlog.
        assert_eq!(p.choose(&common_cands(false), &deep), Protocol::Axle);
    }

    #[test]
    fn oracle_picks_min_solo_with_stable_ties() {
        let p = OraclePolicy;
        assert_eq!(p.choose(&common_cands(true), &Observed::default()), Protocol::Axle);
        let tied = vec![
            cand(Protocol::Rp, 50 * US, 0, 0),
            cand(Protocol::Bs, 50 * US, 0, 0),
            cand(Protocol::Axle, 60 * US, 0, 0),
        ];
        assert_eq!(p.choose(&tied, &Observed::default()), Protocol::Rp);
    }

    #[test]
    fn heuristic_pruned_candidate_set_falls_back_to_bs() {
        let p = HeuristicPolicy;
        let pruned = vec![cand(Protocol::Axle, 50 * US, 40 * US, 10 * US)];
        assert_eq!(p.choose(&pruned, &Observed::default()), Protocol::Bs);
        assert_eq!(p.choose(&[], &Observed::default()), Protocol::Bs);
    }

    #[test]
    fn required_candidates_match_policy() {
        assert_eq!(
            required_candidates(PolicyKind::Static(Protocol::AxleInterrupt)),
            vec![Protocol::AxleInterrupt]
        );
        assert_eq!(required_candidates(PolicyKind::Heuristic), CANDIDATES.to_vec());
        assert_eq!(required_candidates(PolicyKind::Oracle), CANDIDATES.to_vec());
        assert_eq!(required_candidates(PolicyKind::Learned), CANDIDATES.to_vec());
    }

    #[test]
    fn decider_for_labels_round_trip() {
        for kind in PolicyKind::ALL {
            let spec = crate::config::SchedSpec::new(2).with_policy(kind);
            assert_eq!(decider_for(&spec).label(), kind.label());
        }
    }

    fn views(loads: &[Ps], cands: &[Candidate]) -> Vec<DeviceView<'_>> {
        loads
            .iter()
            .map(|&load| DeviceView {
                class: 0,
                alive: true,
                eligible: true,
                load,
                obs: Observed::default(),
                cands,
            })
            .collect()
    }

    /// The PolicyDecider's placement must match the bare placement
    /// helpers decision-for-decision — the PR 9 bit-identity hinges on
    /// it.
    #[test]
    fn policy_decider_placement_matches_place_device() {
        let cands = common_cands(true);
        let loads = [30 * US, 10 * US, 20 * US];
        for placement in [Placement::RoundRobin, Placement::LeastLoaded, Placement::Pinned] {
            let mut dec = PolicyDecider::new(Box::new(OraclePolicy));
            let mut rr_dec = 0usize;
            let mut rr_ref = 0usize;
            for tenant in 0..7usize {
                let devices = views(&loads, &cands);
                let ctx = RequestCtx {
                    tenant,
                    index: 0,
                    annot: 'a',
                    now: 0,
                    placement,
                    faulted: false,
                    devices: &devices,
                };
                let d = dec.decide(&ctx, &mut rr_dec);
                let want = crate::topo::place_device(
                    placement,
                    loads.len(),
                    tenant,
                    |i| loads[i],
                    &mut rr_ref,
                );
                assert_eq!(d.device, want, "{placement:?} tenant {tenant}");
                assert_eq!(d.proto, Protocol::Axle);
                assert_eq!(rr_dec, rr_ref);
            }
        }
    }

    /// Faulted placement skips ineligible devices and falls back to
    /// alive-but-stalled ones, mirroring the driver's probe order.
    #[test]
    fn place_faulted_prefers_eligible_then_alive() {
        let cands = common_cands(false);
        let mut devices = views(&[10 * US, 20 * US, 30 * US], &cands);
        devices[0].eligible = false;
        let mut rr = 0usize;
        assert_eq!(place_faulted(Placement::LeastLoaded, &devices, 0, &mut rr), 1);
        // Every gate shut: land on the least-loaded alive device anyway.
        devices[1].eligible = false;
        devices[2].eligible = false;
        assert_eq!(place_faulted(Placement::LeastLoaded, &devices, 0, &mut rr), 0);
        // Dead devices are never targets even in the fallback.
        devices[0].alive = false;
        assert_eq!(place_faulted(Placement::LeastLoaded, &devices, 0, &mut rr), 1);
    }
}
