//! Per-request offload-protocol policies.
//!
//! The paper's core observation is that no single offloading mechanism
//! wins everywhere: RP's coarse batching amortizes well on heavy kernels
//! with tiny results (Fig. 3), BS's synchronous CXL.mem flow is clean
//! when data dominates and nothing else contends the channel, and AXLE's
//! asynchronous back-streaming wins when compute and transfer can
//! overlap. UDON makes the same case for deciding *what* runs near
//! memory online. The closed-loop scheduler therefore consults an
//! [`OffloadPolicy`] once per request, with two kinds of information:
//!
//! - [`Candidate`] summaries — the request's solo profile under each
//!   candidate protocol **on the target device's config** (heterogeneous
//!   devices give different summaries per device class), precomputed by
//!   the driver's solo pass and deduped through the sweep engine's
//!   workload cache;
//! - an [`Observed`] snapshot — the target device's link/PU occupancy
//!   and admission backlog at submission time, the closed loop's live
//!   feedback signal.
//!
//! Three implementations ship ([`policy_for`]):
//!
//! | policy | choice | role |
//! |---|---|---|
//! | [`StaticPolicy`] | one pinned protocol | PR-3 behavior; regression baseline |
//! | [`HeuristicPolicy`] | compute-vs-transfer ratio + occupancy rule | the paper-style online scheduler |
//! | [`OraclePolicy`] | smallest solo runtime on the device class | clairvoyant per-request bound |

use crate::config::{PolicyKind, Protocol};
use crate::sim::Ps;

/// One candidate protocol's solo profile for a request on its target
/// device class (see the driver's solo pass).
#[derive(Debug, Clone, Copy)]
pub struct Candidate {
    pub proto: Protocol,
    /// Solo end-to-end runtime on the target device's config.
    pub solo: Ps,
    /// Solo CCM busy-union (T_C) — the compute side of the ratio.
    pub ccm_busy: Ps,
    /// Solo data-movement busy-union (T_D) — the transfer side.
    pub dm_busy: Ps,
    /// Data bytes the candidate moves over the device's CXL.mem channel.
    pub mem_bytes: u64,
    /// Data bytes the candidate moves over the device's CXL.io channel.
    pub io_bytes: u64,
}

/// What the scheduler can observe about the target device at submission
/// time — the closed loop's feedback signal.
#[derive(Debug, Clone, Copy, Default)]
pub struct Observed {
    /// How far the device's CXL.mem busy calendar extends beyond now.
    pub mem_backlog: Ps,
    /// How far the device's CXL.io busy calendar extends beyond now.
    pub io_backlog: Ps,
    /// How far ahead the device's earliest-free CCM PU is booked.
    pub pu_backlog: Ps,
    /// Requests waiting in the device's admission queue.
    pub queued: usize,
}

/// A per-request protocol selector. Implementations must be pure
/// functions of their inputs — the driver's determinism contract (same
/// spec, same report) rests on it.
pub trait OffloadPolicy {
    fn label(&self) -> String;
    /// Pick the protocol for one request. `cands` holds the candidate
    /// set in [`CANDIDATES`] order (plus the pinned protocol for static
    /// policies); it is never empty.
    fn choose(&self, cands: &[Candidate], obs: &Observed) -> Protocol;
}

/// The candidate set adaptive policies choose from, in preference-stable
/// order. `AxleInterrupt` is reachable only by pinning it statically.
pub const CANDIDATES: [Protocol; 3] = [Protocol::Rp, Protocol::Bs, Protocol::Axle];

/// Every request uses one pinned protocol — the PR-3 tenant path's
/// behavior, kept as the regression baseline.
#[derive(Debug, Clone, Copy)]
pub struct StaticPolicy(pub Protocol);

impl OffloadPolicy for StaticPolicy {
    fn label(&self) -> String {
        PolicyKind::Static(self.0).label()
    }

    fn choose(&self, _cands: &[Candidate], _obs: &Observed) -> Protocol {
        self.0
    }
}

/// Paper-style adaptive rule. Intensity comes from the bulk-synchronous
/// candidate: BS is a fully serialized pipeline (Fig. 6), so its T_C and
/// T_D are the workload's intrinsic compute and transfer demands on this
/// device class.
///
/// - **Transfer-bound** (`T_D >= T_C`): route the data onto the emptier
///   channel — AXLE back-streams results over CXL.io, BS moves them over
///   CXL.mem — so one backlogged wire steers the request to the other.
/// - **Compute-bound** (`T_C > T_D`): results trickle, so AXLE's
///   fine-grained overlap is the default; remote polling is chosen only
///   when it is genuinely competitive on this device class (heavy
///   kernels with tiny results, Fig. 3) *and* the PU pool is booked more
///   than one AXLE solo ahead, where coarse batching costs nothing.
///   A backlogged CXL.io channel still steers to BS.
#[derive(Debug, Clone, Copy, Default)]
pub struct HeuristicPolicy;

impl HeuristicPolicy {
    fn find(cands: &[Candidate], proto: Protocol) -> &Candidate {
        cands
            .iter()
            .find(|c| c.proto == proto)
            .expect("adaptive policies run with the full candidate set")
    }
}

impl OffloadPolicy for HeuristicPolicy {
    fn label(&self) -> String {
        PolicyKind::Heuristic.label()
    }

    fn choose(&self, cands: &[Candidate], obs: &Observed) -> Protocol {
        let rp = Self::find(cands, Protocol::Rp);
        let bs = Self::find(cands, Protocol::Bs);
        let axle = Self::find(cands, Protocol::Axle);
        let transfer_bound = bs.dm_busy >= bs.ccm_busy;
        if !transfer_bound
            && rp.solo <= bs.solo.min(axle.solo)
            && obs.pu_backlog > axle.solo
        {
            return Protocol::Rp;
        }
        if obs.io_backlog > obs.mem_backlog {
            Protocol::Bs
        } else {
            Protocol::Axle
        }
    }
}

/// Clairvoyant per-request choice: the candidate with the smallest solo
/// runtime on the target device class (ties break in [`CANDIDATES`]
/// order). Ignores occupancy by design — it bounds what per-request
/// protocol selection alone can buy, reported against in `axle report
/// fig19`.
#[derive(Debug, Clone, Copy, Default)]
pub struct OraclePolicy;

impl OffloadPolicy for OraclePolicy {
    fn label(&self) -> String {
        PolicyKind::Oracle.label()
    }

    fn choose(&self, cands: &[Candidate], _obs: &Observed) -> Protocol {
        cands
            .iter()
            .enumerate()
            .min_by_key(|(i, c)| (c.solo, *i))
            .map(|(_, c)| c.proto)
            .expect("candidate set is never empty")
    }
}

/// Materialize the policy a [`PolicyKind`] names.
pub fn policy_for(kind: PolicyKind) -> Box<dyn OffloadPolicy> {
    match kind {
        PolicyKind::Static(p) => Box::new(StaticPolicy(p)),
        PolicyKind::Heuristic => Box::new(HeuristicPolicy),
        PolicyKind::Oracle => Box::new(OraclePolicy),
    }
}

/// The protocols whose solo profiles a policy needs precomputed.
pub fn required_candidates(kind: PolicyKind) -> Vec<Protocol> {
    match kind {
        PolicyKind::Static(p) => vec![p],
        PolicyKind::Heuristic | PolicyKind::Oracle => CANDIDATES.to_vec(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::US;

    fn cand(proto: Protocol, solo: Ps, ccm: Ps, dm: Ps) -> Candidate {
        Candidate { proto, solo, ccm_busy: ccm, dm_busy: dm, mem_bytes: 0, io_bytes: 0 }
    }

    /// rp slow, bs middling, axle fastest — the Fig. 10 common case.
    fn common_cands(transfer_bound: bool) -> Vec<Candidate> {
        let (ccm, dm) = if transfer_bound { (10 * US, 40 * US) } else { (40 * US, 10 * US) };
        vec![
            cand(Protocol::Rp, 100 * US, ccm, dm),
            cand(Protocol::Bs, 60 * US, ccm, dm),
            cand(Protocol::Axle, 50 * US, ccm, dm),
        ]
    }

    #[test]
    fn static_policy_pins_protocol() {
        let p = StaticPolicy(Protocol::Bs);
        assert_eq!(p.choose(&common_cands(true), &Observed::default()), Protocol::Bs);
        assert_eq!(p.label(), "static-bs");
    }

    #[test]
    fn heuristic_idle_device_picks_axle() {
        let p = HeuristicPolicy;
        for tb in [true, false] {
            assert_eq!(p.choose(&common_cands(tb), &Observed::default()), Protocol::Axle);
        }
    }

    #[test]
    fn heuristic_backlogged_io_steers_to_bs() {
        let p = HeuristicPolicy;
        let obs = Observed { io_backlog: 5 * US, mem_backlog: US, ..Default::default() };
        assert_eq!(p.choose(&common_cands(true), &obs), Protocol::Bs);
        // Mem more backlogged than io: stay on the io channel (AXLE).
        let obs2 = Observed { io_backlog: US, mem_backlog: 5 * US, ..Default::default() };
        assert_eq!(p.choose(&common_cands(true), &obs2), Protocol::Axle);
    }

    #[test]
    fn heuristic_rp_needs_competitive_solo_and_deep_pu_backlog() {
        let p = HeuristicPolicy;
        // Compute-bound, RP genuinely fastest on this class.
        let cands = vec![
            cand(Protocol::Rp, 40 * US, 40 * US, 5 * US),
            cand(Protocol::Bs, 60 * US, 40 * US, 5 * US),
            cand(Protocol::Axle, 50 * US, 40 * US, 5 * US),
        ];
        let deep = Observed { pu_backlog: 200 * US, ..Default::default() };
        assert_eq!(p.choose(&cands, &deep), Protocol::Rp);
        // Shallow backlog: fine-grained overlap still wins.
        assert_eq!(p.choose(&cands, &Observed::default()), Protocol::Axle);
        // RP not competitive: never chosen, however deep the backlog.
        assert_eq!(p.choose(&common_cands(false), &deep), Protocol::Axle);
    }

    #[test]
    fn oracle_picks_min_solo_with_stable_ties() {
        let p = OraclePolicy;
        assert_eq!(p.choose(&common_cands(true), &Observed::default()), Protocol::Axle);
        let tied = vec![
            cand(Protocol::Rp, 50 * US, 0, 0),
            cand(Protocol::Bs, 50 * US, 0, 0),
            cand(Protocol::Axle, 60 * US, 0, 0),
        ];
        assert_eq!(p.choose(&tied, &Observed::default()), Protocol::Rp);
    }

    #[test]
    fn required_candidates_match_policy() {
        assert_eq!(
            required_candidates(PolicyKind::Static(Protocol::AxleInterrupt)),
            vec![Protocol::AxleInterrupt]
        );
        assert_eq!(required_candidates(PolicyKind::Heuristic), CANDIDATES.to_vec());
        assert_eq!(required_candidates(PolicyKind::Oracle), CANDIDATES.to_vec());
    }

    #[test]
    fn policy_for_labels_round_trip() {
        for kind in PolicyKind::ALL {
            assert_eq!(policy_for(kind).label(), kind.label());
        }
    }
}
