//! Closed-loop offload scheduler: admission queues, adaptive per-request
//! protocol selection, and heterogeneous devices.
//!
//! This subsystem sits between the multi-tenant topology layer
//! ([`crate::topo`]) and the protocol engines ([`crate::protocol`]). The
//! open-loop tenant driver answers "what does contention do to a fixed
//! arrival process?"; this layer closes the loop and asks the production
//! question: **with tenants reacting to completions, which offload
//! protocol should each request use, and how deep should devices queue?**
//!
//! Three pieces:
//!
//! - [`driver`] — the closed-loop engine ([`run`]): K tenants with
//!   `depth`-bounded outstanding windows submitting against completion
//!   feedback, per-device admission queues with an `admit` service limit
//!   and per-tenant **priority classes** (higher class jumps the FIFO at
//!   admission, never revoking in-service work), and online contention
//!   accounting over link calendars and earliest-free PU pools. The
//!   calendars charge wire time under the topology's QoS policy —
//!   FCFS (admission order, the PR-4 path verbatim) or online WRR/DRR
//!   through [`crate::topo::fabric::QosState`]. With `--open` it
//!   degenerates to the PR-3 open-loop tenant path verbatim (the
//!   regression pin).
//! - [`policy`] — the per-request [`OffloadPolicy`](policy::OffloadPolicy)
//!   plug point: `Static` (pins one protocol — today's behavior),
//!   `Heuristic` (compute-vs-transfer ratio + observed link/PU
//!   occupancy, the paper-style online rule) and `Oracle` (clairvoyant
//!   per-request best-solo choice, the bound `axle report fig19` reports
//!   against).
//! - **Heterogeneous devices** — [`TopologySpec`](crate::config::TopologySpec)
//!   carries optional per-device
//!   [`DeviceOverride`](crate::config::DeviceOverride)s; the driver's
//!   solo pass simulates every candidate per *device class*, so policies
//!   see real placement trade-offs.
//! - [`fault`] — fault injection and recovery: a
//!   [`FaultSpec`](crate::config::FaultSpec) schedules deterministic
//!   device degradation / transient stalls / permanent failures, and
//!   the driver heals around them with timeouts, bounded
//!   exponential-backoff retries and requeue onto surviving devices,
//!   reporting per-fault time-to-recover and lost work
//!   ([`FaultOutcome`]) plus a `retry_wait` term in every request's
//!   decomposition. An empty spec is pinned bit-identical to the
//!   fault-free engine. Surfaces: `axle sched --faults`, `axle
//!   scenario`, `axle report fig20`.
//!
//! Surfaces: `axle sched --streams K --policy static|heuristic|oracle
//! --depth N --qos fcfs|wrr|drr --prio C0,C1,...`,
//! [`crate::coordinator::Coordinator::run_sched`], [`sweep_sched_grid`]
//! (policy × qos × depth axes; also re-exported as
//! `topo::sweep_sched_grid`) and `axle report fig19` (per-priority-class
//! p50/p99 slowdown columns under all three QoS policies).
//!
//! PR 8 adds **intra-request pipelining**: `--chunks N` decomposes each
//! request into a per-protocol stage DAG (host/wire/CCM stages tagged
//! with happens-after lane masks, built by the protocol engines'
//! `stage_graph` constructors) and the driver admits *stages*, so one
//! request's back-stream overlaps the next chunk's transfer and stages
//! of different requests interleave on the same calendars and PU pool.
//! Surfaces: `axle sched --chunks N [--chunk-mode auto|serial|pipelined]`,
//! [`sweep_pipeline_grid`] (qos × chunk-count axes) and `axle report
//! fig21` (host/CCM idle fractions vs chunk count per QoS policy).
//!
//! PR 10 redesigns the decision surface twice over:
//!
//! - **One front door.** [`run`] takes a [`SchedRun`] options struct
//!   and returns a [`SchedOutcome`] `{ report, trace }`, replacing the
//!   parallel `run_sched` / `run_sched_traced` / coordinator
//!   `run_sched_jobs` entry points (kept one release as deprecated
//!   wrappers).
//! - **A unified decision layer.** The driver consults one stateful
//!   [`Decider`](policy::Decider) per run — placement *and* protocol in
//!   one `decide(&RequestCtx) -> Decision`, with completion latencies
//!   fed back through `observe(&Feedback)`. Static/Heuristic/Oracle are
//!   re-expressed as deciders bit-identical to their PR 9 selves, and
//!   [`learn`] adds `--policy learned`: per-(device × workload ×
//!   protocol) count-weighted latency estimators with seeded, decaying
//!   epsilon-greedy exploration (`--explore N`) that re-converge when a
//!   mid-run fault degrades a device — `axle scenario --learned` and
//!   `axle report fig23` stage exactly that nonstationary comparison.

pub mod driver;
pub mod fault;
pub mod learn;
pub mod policy;

#[allow(deprecated)]
pub use driver::{run_sched, run_sched_traced};
pub use driver::{format_request_row, run, RequestRun, SchedOutcome, SchedReport, SchedRun};
pub use fault::FaultOutcome;
pub use learn::{ArmEstimator, LearnedDecider};
pub use policy::{
    decider_for, Candidate, Decider, Decision, DeviceView, Feedback, Observed, OffloadPolicy,
    RequestCtx,
};

use crate::config::{PolicyKind, QosPolicy, QosSpec, SchedSpec, SimConfig, TopologySpec};

/// Sweep the scheduler axes: one [`SchedReport`] per `(policy, qos,
/// depth)` grid point, with the base specs' other knobs held fixed. The
/// protocol policy is the outermost axis, the link-arbitration policy
/// (installed into `topo_base.qos`, keeping its weights/floors) comes
/// next — exactly the table `axle report fig19` walks.
///
/// Neither the qos nor the depth axis can change solo simulations, so
/// the solo candidate pass is prepared **once per policy** and shared
/// across its qos × depth points (results are identical to calling
/// [`run`] per point).
pub fn sweep_sched_grid(
    cfg: &SimConfig,
    topo_base: &TopologySpec,
    sched_base: &SchedSpec,
    policy_axis: &[PolicyKind],
    qos_axis: &[QosPolicy],
    depth_axis: &[usize],
    jobs: usize,
) -> Vec<(PolicyKind, QosPolicy, usize, SchedReport)> {
    let mut out = Vec::with_capacity(policy_axis.len() * qos_axis.len() * depth_axis.len());
    for &policy in policy_axis {
        let base = SchedSpec { policy, ..sched_base.clone() };
        // Only closed, non-empty runs reach the engine (and can share a
        // prepared pass); anything else goes through run's own dispatch
        // (open-loop pin, empty report).
        let pass = (base.closed && base.streams > 0 && base.requests > 0)
            .then(|| driver::prepare_solo_pass(cfg, topo_base, &base, jobs));
        for &qos in qos_axis {
            let topo = TopologySpec {
                qos: QosSpec { policy: qos, ..topo_base.qos.clone() },
                ..topo_base.clone()
            };
            for &depth in depth_axis {
                let spec = SchedSpec { depth, ..base.clone() };
                let report = match &pass {
                    Some(p) => driver::run_closed(&topo, &spec, p),
                    None => run(&SchedRun::new(cfg, &topo, &spec).with_jobs(jobs)).report,
                };
                out.push((policy, qos, depth, report));
            }
        }
    }
    out
}

/// Sweep chunked admission: one [`SchedReport`] per `(qos, chunks)`
/// grid point, with the base specs' other knobs held fixed — the table
/// `axle report fig21` walks. `chunks == 1` runs the whole-request
/// engine verbatim (the pipelining layer is gated off), so each qos
/// row's first column doubles as its unchunked baseline.
///
/// Neither axis can change solo simulations, so the solo candidate pass
/// is prepared **once** and shared across every grid point.
pub fn sweep_pipeline_grid(
    cfg: &SimConfig,
    topo_base: &TopologySpec,
    sched_base: &SchedSpec,
    qos_axis: &[QosPolicy],
    chunks_axis: &[u32],
    jobs: usize,
) -> Vec<(QosPolicy, u32, SchedReport)> {
    let mut out = Vec::with_capacity(qos_axis.len() * chunks_axis.len());
    let pass = (sched_base.closed && sched_base.streams > 0 && sched_base.requests > 0)
        .then(|| driver::prepare_solo_pass(cfg, topo_base, sched_base, jobs));
    for &qos in qos_axis {
        let topo = TopologySpec {
            qos: QosSpec { policy: qos, ..topo_base.qos.clone() },
            ..topo_base.clone()
        };
        for &chunks in chunks_axis {
            let spec = sched_base
                .clone()
                .with_pipeline(crate::config::PipelineSpec::with_chunks(chunks));
            let report = match &pass {
                Some(p) => driver::run_closed(&topo, &spec, p),
                None => run(&SchedRun::new(cfg, &topo, &spec).with_jobs(jobs)).report,
            };
            out.push((qos, chunks, report));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Protocol;

    #[test]
    fn grid_sweep_covers_axes_in_order() {
        let cfg = SimConfig::m2ndp();
        let topo = TopologySpec::default();
        let base = SchedSpec::new(2).with_workloads(vec!['f']).with_requests(1);
        let grid = sweep_sched_grid(
            &cfg,
            &topo,
            &base,
            &[PolicyKind::Static(Protocol::Axle), PolicyKind::Oracle],
            &[QosPolicy::Fcfs, QosPolicy::Wrr],
            &[1, 2],
            2,
        );
        assert_eq!(grid.len(), 8);
        let s = PolicyKind::Static(Protocol::Axle);
        assert_eq!((grid[0].0, grid[0].1, grid[0].2), (s, QosPolicy::Fcfs, 1));
        assert_eq!((grid[1].0, grid[1].1, grid[1].2), (s, QosPolicy::Fcfs, 2));
        assert_eq!((grid[2].0, grid[2].1, grid[2].2), (s, QosPolicy::Wrr, 1));
        assert_eq!((grid[3].0, grid[3].1, grid[3].2), (s, QosPolicy::Wrr, 2));
        assert_eq!((grid[4].0, grid[4].1, grid[4].2), (PolicyKind::Oracle, QosPolicy::Fcfs, 1));
        assert_eq!((grid[7].0, grid[7].1, grid[7].2), (PolicyKind::Oracle, QosPolicy::Wrr, 2));
        for (p, qos, depth, r) in &grid {
            assert_eq!(r.policy, *p);
            assert_eq!(r.qos, *qos);
            assert_eq!(r.depth, *depth);
            assert_eq!(r.requests.len(), 2);
        }
    }

    #[test]
    fn grid_sweep_qos_points_match_direct_runs() {
        // The shared solo pass must not drift the qos-overridden points
        // from a fresh run with the same effective topology.
        let cfg = SimConfig::m2ndp();
        let topo = TopologySpec::shared_fabric(1, cfg.cxl_bw_gbps);
        let base = SchedSpec::new(3).with_workloads(vec!['a', 'f']).with_requests(2);
        let grid = sweep_sched_grid(
            &cfg,
            &topo,
            &base,
            &[PolicyKind::Heuristic],
            &[QosPolicy::Drr],
            &[2],
            2,
        );
        let direct_topo = TopologySpec {
            qos: crate::config::QosSpec { policy: QosPolicy::Drr, ..topo.qos.clone() },
            ..topo.clone()
        };
        let direct = run(&SchedRun::new(&cfg, &direct_topo, &base.clone().with_depth(2)).with_jobs(2)).report;
        assert_eq!(grid[0].3.to_json().to_string(), direct.to_json().to_string());
    }
}
