//! Closed-loop offload scheduler: admission queues, adaptive per-request
//! protocol selection, and heterogeneous devices.
//!
//! This subsystem sits between the multi-tenant topology layer
//! ([`crate::topo`]) and the protocol engines ([`crate::protocol`]). The
//! open-loop tenant driver answers "what does contention do to a fixed
//! arrival process?"; this layer closes the loop and asks the production
//! question: **with tenants reacting to completions, which offload
//! protocol should each request use, and how deep should devices queue?**
//!
//! Three pieces:
//!
//! - [`driver`] — the closed-loop engine ([`run_sched`]): K tenants with
//!   `depth`-bounded outstanding windows submitting against completion
//!   feedback, per-device FIFO admission queues with an `admit` service
//!   limit, and online (admission-order) contention accounting over link
//!   calendars and earliest-free PU pools. With `--open` it degenerates
//!   to the PR-3 open-loop tenant path verbatim (the regression pin).
//! - [`policy`] — the per-request [`OffloadPolicy`](policy::OffloadPolicy)
//!   plug point: `Static` (pins one protocol — today's behavior),
//!   `Heuristic` (compute-vs-transfer ratio + observed link/PU
//!   occupancy, the paper-style online rule) and `Oracle` (clairvoyant
//!   per-request best-solo choice, the bound `axle report fig19` reports
//!   against).
//! - **Heterogeneous devices** — [`TopologySpec`](crate::config::TopologySpec)
//!   carries optional per-device
//!   [`DeviceOverride`](crate::config::DeviceOverride)s; the driver's
//!   solo pass simulates every candidate per *device class*, so policies
//!   see real placement trade-offs.
//!
//! Surfaces: `axle sched --streams K --policy static|heuristic|oracle
//! --depth N`, [`crate::coordinator::Coordinator::run_sched`],
//! [`sweep_sched_grid`] (policy × depth axes; also re-exported as
//! `topo::sweep_sched_grid`) and `axle report fig19`.

pub mod driver;
pub mod policy;

pub use driver::{format_request_row, run_sched, RequestRun, SchedReport};
pub use policy::{Candidate, Observed, OffloadPolicy};

use crate::config::{PolicyKind, SchedSpec, SimConfig, TopologySpec};

/// Sweep the scheduler axes: one [`SchedReport`] per `(policy, depth)`
/// grid point, with the base specs' other knobs held fixed. The policy
/// is the outermost axis — exactly the table `axle report fig19` walks.
///
/// The depth axis cannot change solo simulations, so the solo candidate
/// pass is prepared **once per policy** and shared across its depth
/// points (results are identical to calling [`run_sched`] per point).
pub fn sweep_sched_grid(
    cfg: &SimConfig,
    topo_base: &TopologySpec,
    sched_base: &SchedSpec,
    policy_axis: &[PolicyKind],
    depth_axis: &[usize],
    jobs: usize,
) -> Vec<(PolicyKind, usize, SchedReport)> {
    let mut out = Vec::with_capacity(policy_axis.len() * depth_axis.len());
    for &policy in policy_axis {
        let base = SchedSpec { policy, ..sched_base.clone() };
        // Only closed, non-empty runs reach the engine (and can share a
        // prepared pass); anything else goes through run_sched's own
        // dispatch (open-loop pin, empty report).
        let pass = (base.closed && base.streams > 0 && base.requests > 0)
            .then(|| driver::prepare_solo_pass(cfg, topo_base, &base, jobs));
        for &depth in depth_axis {
            let spec = SchedSpec { depth, ..base.clone() };
            let report = match &pass {
                Some(p) => driver::run_closed(topo_base, &spec, p),
                None => run_sched(cfg, topo_base, &spec, jobs),
            };
            out.push((policy, depth, report));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Protocol;

    #[test]
    fn grid_sweep_covers_axes_in_order() {
        let cfg = SimConfig::m2ndp();
        let topo = TopologySpec::default();
        let base = SchedSpec::new(2).with_workloads(vec!['f']).with_requests(1);
        let grid = sweep_sched_grid(
            &cfg,
            &topo,
            &base,
            &[PolicyKind::Static(Protocol::Axle), PolicyKind::Oracle],
            &[1, 2],
            2,
        );
        assert_eq!(grid.len(), 4);
        assert_eq!((grid[0].0, grid[0].1), (PolicyKind::Static(Protocol::Axle), 1));
        assert_eq!((grid[1].0, grid[1].1), (PolicyKind::Static(Protocol::Axle), 2));
        assert_eq!((grid[2].0, grid[2].1), (PolicyKind::Oracle, 1));
        assert_eq!((grid[3].0, grid[3].1), (PolicyKind::Oracle, 2));
        for (p, depth, r) in &grid {
            assert_eq!(r.policy, *p);
            assert_eq!(r.depth, *depth);
            assert_eq!(r.requests.len(), 2);
        }
    }
}
