//! Fault-injection runtime for the closed-loop scheduler.
//!
//! The ROADMAP's production-traffic gap starts here: every run so far
//! assumed devices that never fail. This module holds the *state* a
//! faulted run threads through [`super::driver`] — the driver owns the
//! event loop and the recovery transitions; this file owns what they
//! read and write:
//!
//! - [`FaultRuntime`] — the per-run bundle: the validated
//!   [`FaultSpec`], one [`ReqState`] per submitted request (attempt
//!   counter, lifecycle location, charge accounting for lost work), and
//!   one [`FaultOutcome`] per scheduled fault event.
//! - [`FaultOutcome`] — what one injected fault *cost*: how many
//!   requests it displaced, the time-to-recover (last displaced request
//!   back in service, measured from the fault instant), and the wasted
//!   wire/PU picoseconds of killed in-service attempts.
//!
//! **Recovery model** (enforced by the driver, documented in
//! `docs/ARCHITECTURE.md`):
//!
//! - A **stall** suspends in-service work (completion slides by the
//!   remaining window, charged to `pu_wait`) and arms a timeout on each
//!   queued request, sized `solo × timeout_factor`. A request whose
//!   timeout fires while its device is still not admitting is pulled
//!   from the queue and retried elsewhere after exponential backoff.
//! - A **permanent failure** kills in-service attempts (their wire/PU
//!   charges are the fault's lost work, the attempts retry with
//!   backoff) and drains the admission queue in order onto surviving
//!   devices (free re-placement — that work never started).
//! - Retries are bounded by `max_retries`; a request that exhausts them
//!   is dropped (`failed = true`) and releases its tenant window, so a
//!   faulted run always terminates.
//!
//! The attempt counter is the staleness guard: every scheduled
//! completion carries the attempt it was issued under, and the driver
//! drops completions whose attempt no longer matches. Fault-free runs
//! never leave attempt 0, which keeps their event tuples — and hence
//! the whole report — bit-identical to a run without this module.

use crate::config::{FaultKind, FaultSpec};
use crate::sim::Ps;
use crate::util::json::Json;

/// Where one request currently is in its fault-aware lifecycle.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(super) enum Loc {
    /// Waiting in a device's admission queue.
    Queued,
    /// Admitted; a completion event is in flight.
    InService,
    /// Between attempts, waiting out an exponential-backoff delay.
    Backoff,
    /// Completed.
    Done,
    /// Dropped after exhausting `max_retries`.
    Failed,
}

/// Per-request recovery bookkeeping, indexed by request id.
#[derive(Debug, Clone)]
pub(super) struct ReqState {
    /// Bumped on every kill/suspend/timeout; completions carry the
    /// attempt they were scheduled under and stale ones are dropped.
    pub attempt: u32,
    /// Retry count (kills + timeouts; free failure-drain re-placements
    /// are not retries).
    pub retries: u32,
    pub loc: Loc,
    /// Device currently holding the request (queue or service).
    pub loc_dev: u32,
    /// When the request last entered an admission queue (timeout base).
    pub enqueued: Ps,
    /// Device-wire picoseconds charged for the current attempt — lost
    /// work if the attempt is killed.
    pub attempt_wire: Ps,
    /// CCM PU picoseconds charged for the current attempt.
    pub attempt_pu: Ps,
    /// Fault event that displaced the current attempt, if any; cleared
    /// (and folded into that fault's time-to-recover) on re-admission.
    pub displaced_by: Option<usize>,
    /// Chunk-granular attempt profile, filled only by chunked admission
    /// (`--chunks > 1`): one `(end, wire, pu)` row per emitted chunk,
    /// where `end` is the absolute completion bound of the chunk's last
    /// stage and `wire`/`pu` are the chunk's charged picoseconds. Empty
    /// for whole-request attempts — [`ReqState::lost_work`] then falls
    /// back to the attempt totals.
    pub attempt_chunks: Vec<(Ps, Ps, Ps)>,
}

impl ReqState {
    pub fn queued(dev: u32, now: Ps) -> Self {
        Self {
            attempt: 0,
            retries: 0,
            loc: Loc::Queued,
            loc_dev: dev,
            enqueued: now,
            attempt_wire: 0,
            attempt_pu: 0,
            displaced_by: None,
            attempt_chunks: Vec::new(),
        }
    }

    /// Re-initialize a recycled request slot for a fresh submission.
    /// The attempt counter is *carried forward* (bumped, never reset):
    /// a stale event addressed to the slot's previous occupant then
    /// fails the attempt match and is dropped, exactly like a stale
    /// completion of a killed attempt.
    pub fn recycle(&mut self, dev: u32, now: Ps) {
        self.attempt += 1;
        self.retries = 0;
        self.loc = Loc::Queued;
        self.loc_dev = dev;
        self.enqueued = now;
        self.attempt_wire = 0;
        self.attempt_pu = 0;
        self.displaced_by = None;
        self.attempt_chunks.clear();
    }

    /// Wire/PU picoseconds forfeited if this attempt is killed at
    /// `now`. Chunk-granular attempts lose only the chunks whose
    /// completion bound lies past the kill — a fully back-streamed
    /// chunk's work is banked, never double-counted as lost. Attempts
    /// without a chunk profile (whole-request admission) lose the whole
    /// attempt, exactly the pre-pipelining accounting.
    pub fn lost_work(&self, now: Ps) -> (Ps, Ps) {
        if self.attempt_chunks.is_empty() {
            return (self.attempt_wire, self.attempt_pu);
        }
        let (mut w, mut p): (Ps, Ps) = (0, 0);
        for &(end, cw, cp) in &self.attempt_chunks {
            if end > now {
                w += cw;
                p += cp;
            }
        }
        (w, p)
    }

    /// Slide the completion bound of every chunk still pending at `now`
    /// by `delta` — the chunked counterpart of a stall suspending an
    /// in-service request. Chunks already complete at the stall onset
    /// keep their bounds, so a later kill still sees them as banked.
    pub fn slide_pending_chunks(&mut self, now: Ps, delta: Ps) {
        for c in self.attempt_chunks.iter_mut() {
            if c.0 > now {
                c.0 += delta;
            }
        }
    }
}

/// What one injected fault event cost the run.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultOutcome {
    /// Device the fault struck.
    pub device: u32,
    pub kind: FaultKind,
    /// Fault onset (ps).
    pub at: Ps,
    /// Window end (`== at` for permanent failures and zero-duration
    /// windows).
    pub until: Ps,
    /// Requests displaced: in-service attempts killed or suspended plus
    /// queued requests redistributed or timed out because of this fault.
    pub displaced: u32,
    /// Time-to-recover: latest displaced request's return to service,
    /// measured from `at`. Zero when nothing was displaced (pure
    /// degradation slows work but displaces none).
    pub recover: Ps,
    /// Device-wire picoseconds wasted on killed in-service attempts.
    pub lost_wire: Ps,
    /// CCM PU picoseconds wasted on killed in-service attempts.
    pub lost_pu: Ps,
}

impl FaultOutcome {
    pub fn to_json(&self) -> Json {
        let mut o = std::collections::BTreeMap::new();
        o.insert("device".into(), Json::Num(self.device as f64));
        o.insert("kind".into(), Json::Str(self.kind.label().into()));
        o.insert("at_ps".into(), Json::Num(self.at as f64));
        o.insert("until_ps".into(), Json::Num(self.until as f64));
        o.insert("displaced".into(), Json::Num(self.displaced as f64));
        o.insert("recover_ps".into(), Json::Num(self.recover as f64));
        o.insert("lost_wire_ps".into(), Json::Num(self.lost_wire as f64));
        o.insert("lost_pu_ps".into(), Json::Num(self.lost_pu as f64));
        Json::Obj(o)
    }
}

/// The per-run fault state the driver threads through its event loop.
/// Present (`Some`) exactly when the spec schedules at least one event;
/// the fault-free path never constructs one.
#[derive(Debug)]
pub(super) struct FaultRuntime {
    pub spec: FaultSpec,
    /// One entry per submitted request, indexed by request id.
    pub rstate: Vec<ReqState>,
    /// One row per spec event, in spec order, updated as faults land.
    pub outcomes: Vec<FaultOutcome>,
}

impl FaultRuntime {
    pub fn new(spec: &FaultSpec) -> Self {
        let outcomes = spec
            .events
            .iter()
            .map(|e| FaultOutcome {
                device: e.device,
                kind: e.kind,
                at: e.at,
                until: if e.kind == FaultKind::Fail { e.at } else { e.until },
                displaced: 0,
                recover: 0,
                lost_wire: 0,
                lost_pu: 0,
            })
            .collect();
        Self { spec: spec.clone(), rstate: Vec::new(), outcomes }
    }

    /// Exponential-backoff delay before retry `retry` (1-based):
    /// `backoff << (retry - 1)`, shift capped so the delay saturates
    /// instead of wrapping.
    pub fn backoff_delay(&self, retry: u32) -> Ps {
        self.spec.backoff.saturating_mul(1u64 << retry.saturating_sub(1).min(20))
    }

    /// Requeue timeout for a request with solo estimate `solo`.
    pub fn timeout(&self, solo: Ps) -> Ps {
        (solo as f64 * self.spec.timeout_factor) as Ps
    }

    /// Fold a displaced request's return to service at `now` into the
    /// displacing fault's time-to-recover.
    pub fn note_recovered(&mut self, rid: usize, now: Ps) {
        if let Some(ei) = self.rstate[rid].displaced_by.take() {
            let o = &mut self.outcomes[ei];
            o.recover = o.recover.max(now.saturating_sub(o.at));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::FaultEvent;
    use crate::sim::US;

    #[test]
    fn backoff_doubles_and_saturates() {
        let f = FaultRuntime::new(&FaultSpec::with(vec![FaultEvent::fail(0, 0)]));
        let base = f.spec.backoff;
        assert_eq!(f.backoff_delay(1), base);
        assert_eq!(f.backoff_delay(2), 2 * base);
        assert_eq!(f.backoff_delay(3), 4 * base);
        // Shift is capped: huge retry counts saturate, never wrap.
        assert!(f.backoff_delay(u32::MAX) >= f.backoff_delay(40));
    }

    #[test]
    fn lost_work_counts_only_pending_chunks() {
        let mut st = ReqState::queued(0, 0);
        st.attempt_wire = 100;
        st.attempt_pu = 200;
        // No chunk profile: the whole attempt is lost.
        assert_eq!(st.lost_work(50), (100, 200));
        // Three chunks ending at 10/20/30; a kill at 20 forfeits only
        // the chunk still in flight (bound 30) — completed chunks are
        // banked, and the totals are never double-counted.
        st.attempt_chunks = vec![(10, 30, 60), (20, 30, 60), (30, 40, 80)];
        assert_eq!(st.lost_work(20), (40, 80));
        assert_eq!(st.lost_work(5), (100, 200));
        assert_eq!(st.lost_work(30), (0, 0));
        // A stall at 15 slides only the pending bounds (20, 30) by 7.
        st.slide_pending_chunks(15, 7);
        assert_eq!(st.attempt_chunks, vec![(10, 30, 60), (27, 30, 60), (37, 40, 80)]);
        // Recycling clears the profile along with the attempt charges.
        st.recycle(0, 0);
        assert!(st.attempt_chunks.is_empty());
        assert_eq!(st.lost_work(0), (0, 0));
    }

    #[test]
    fn timeout_scales_solo_estimate() {
        let mut spec = FaultSpec::with(vec![FaultEvent::stall(0, 0, US)]);
        spec.timeout_factor = 4.0;
        let f = FaultRuntime::new(&spec);
        assert_eq!(f.timeout(10 * US), 40 * US);
    }

    #[test]
    fn outcomes_pin_fail_window_to_onset() {
        let f = FaultRuntime::new(&FaultSpec::with(vec![
            FaultEvent::fail(1, 5 * US),
            FaultEvent::stall(0, US, 3 * US),
        ]));
        assert_eq!(f.outcomes.len(), 2);
        assert_eq!((f.outcomes[0].at, f.outcomes[0].until), (5 * US, 5 * US));
        assert_eq!((f.outcomes[1].at, f.outcomes[1].until), (US, 3 * US));
        assert!(f.outcomes.iter().all(|o| o.displaced == 0 && o.recover == 0));
    }

    #[test]
    fn recover_tracks_latest_displaced_return() {
        let mut f = FaultRuntime::new(&FaultSpec::with(vec![FaultEvent::fail(0, 10 * US)]));
        f.rstate.push(ReqState::queued(0, 0));
        f.rstate.push(ReqState::queued(0, 0));
        f.rstate[0].displaced_by = Some(0);
        f.rstate[1].displaced_by = Some(0);
        f.note_recovered(0, 12 * US);
        assert_eq!(f.outcomes[0].recover, 2 * US);
        f.note_recovered(1, 15 * US);
        assert_eq!(f.outcomes[0].recover, 5 * US);
        // Cleared on fold: a later re-admission of rid 0 is not a
        // recovery of this fault.
        f.note_recovered(0, 50 * US);
        assert_eq!(f.outcomes[0].recover, 5 * US);
    }
}
