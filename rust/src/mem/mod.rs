//! DRAM timing model (DDR5-4800, 16 channels on both sides — Table III).
//!
//! The workload cost models (`workload::cost`) use this to convert byte
//! traffic into time. We model channel-level aggregate bandwidth with an
//! access-pattern derate rather than per-bank state: the paper's
//! conclusions depend on the *ratio* of memory-bound kernel time to data
//! movement and host time, all of which scale with effective bandwidth.

use crate::sim::{transfer_ps, Ps};

/// Cache-line / DRAM burst granularity in bytes.
pub const LINE_BYTES: u64 = 64;

#[derive(Debug, Clone, Copy)]
pub struct DramModel {
    /// Peak aggregate bandwidth, GB/s (channels × per-channel rate).
    pub peak_gbps: f64,
    /// Sustained fraction of peak for streaming access.
    pub stream_eff: f64,
    /// Sustained fraction of peak for random line-granularity access.
    pub random_eff: f64,
    /// Idle access latency (closed-page) for a single line.
    pub latency: Ps,
}

impl DramModel {
    /// DDR5-4800 × `channels`: 4.8 GT/s × 8 B per channel.
    pub fn ddr5_4800(channels: u32) -> Self {
        Self {
            peak_gbps: 4.8 * 8.0 * channels as f64,
            stream_eff: 0.85,
            // Line-granularity random sustained fraction. Together with
            // the 16 GB/s effective CXL bandwidth this puts PageRank's
            // T_C:T_D at 53:41 (paper Fig. 5b: 49.9:48) — the two terms
            // that bound the headline end-to-end reduction.
            random_eff: 0.35,
            latency: 90_000, // 90 ns closed-page access
        }
    }

    /// Effective streaming bandwidth, GB/s.
    #[inline]
    pub fn stream_gbps(&self) -> f64 {
        self.peak_gbps * self.stream_eff
    }

    /// Time to stream `bytes` sequentially.
    #[inline]
    pub fn stream_time(&self, bytes: u64) -> Ps {
        transfer_ps(bytes, self.stream_gbps())
    }

    /// Time for `accesses` random reads of `bytes_per_access` each:
    /// every access occupies at least one full line of bandwidth.
    pub fn random_time(&self, accesses: u64, bytes_per_access: u64) -> Ps {
        let lines = accesses * bytes_per_access.div_ceil(LINE_BYTES).max(1);
        transfer_ps(lines * LINE_BYTES, self.peak_gbps * self.random_eff)
    }

    /// Latency of one uncached access (e.g. the host's cache-bypass poll
    /// of the metadata tail pointer, §IV-C cache-staleness design).
    #[inline]
    pub fn uncached_access(&self) -> Ps {
        self.latency
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ddr5_16ch_peak() {
        let d = DramModel::ddr5_4800(16);
        assert!((d.peak_gbps - 614.4).abs() < 0.1);
    }

    #[test]
    fn stream_faster_than_random() {
        let d = DramModel::ddr5_4800(16);
        let bytes = 1 << 20;
        assert!(d.stream_time(bytes) < d.random_time(bytes / 4, 4));
    }

    #[test]
    fn random_access_rounds_to_lines() {
        let d = DramModel::ddr5_4800(1);
        // 100 accesses of 4 B each cost 100 lines, same as 100 of 64 B.
        assert_eq!(d.random_time(100, 4), d.random_time(100, 64));
        // ...but 100 of 65 B cost two lines each.
        assert_eq!(d.random_time(100, 65), d.random_time(200, 64));
    }

    #[test]
    fn zero_bytes_zero_time() {
        let d = DramModel::ddr5_4800(16);
        assert_eq!(d.stream_time(0), 0);
    }
}
