//! `axle-report`: regenerate every paper table/figure in one shot
//! (used by `make fig-all`; thin alias over `axle report <which>`).

use axle::config::SimConfig;
use axle::report;

fn main() {
    let which = std::env::args().nth(1).unwrap_or_else(|| "all".to_string());
    let cfg = SimConfig::m2ndp();
    match which.as_str() {
        "all" => report::all(),
        "table1" => report::table1(),
        "table2" => report::table2(),
        "table4" => report::table4(&cfg),
        "fig3" => report::fig3(&cfg),
        "fig4" => report::fig4(),
        "fig5" => report::fig5(&cfg),
        "fig7" => report::fig7(&cfg),
        "fig10" => report::fig10(&cfg),
        "fig11" => report::fig11(),
        "fig12" => report::fig12(&cfg),
        "fig13" => report::fig13(&cfg),
        "fig14" => report::fig14(&cfg),
        "fig14-ext" => report::fig14_ext(&cfg),
        "fig15" => report::fig15(&cfg),
        "fig16" => report::fig16(&cfg),
        "fig17" | "tenants" => report::fig17(&cfg),
        "fig19" | "sched" => report::fig19(&cfg),
        "fig20" | "faults" => report::fig20(&cfg),
        "fig21" | "pipeline" => report::fig21(&cfg),
        "fig22" | "trace" => report::fig22(&cfg),
        "fig23" | "learned" => report::fig23(&cfg),
        other => {
            eprintln!("unknown report {other:?}");
            std::process::exit(1);
        }
    }
}
