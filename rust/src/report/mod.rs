//! Figure/table regenerators: print the same rows and series the paper
//! reports (simulated cycles/ratios; see DESIGN.md per-experiment index).
//!
//! Every `fig*` function runs the corresponding experiment configuration
//! and prints a table whose *shape* should match the paper's figure —
//! who wins, by what factor, where the crossovers fall. `cargo run
//! --release --bin axle-report -- all` regenerates everything.
//!
//! All simulations route through the [`crate::sweep`] engine: each
//! generator declares its (workload, protocol, config-delta) points,
//! fans them out across every available core, and prints from the
//! deterministically ordered results — output is bit-identical to the
//! old serial loops, several times faster on multicore hosts.

use std::sync::Arc;

use crate::config::{poll_factors, Protocol, SchedPolicy, SimConfig};
use crate::metrics::{geomean, mean, RunMetrics};
use crate::sim::ps_to_us;
use crate::sweep::{self, ConfigDelta, SpecJob, SweepPoint};
use crate::workload::{self, llm, olap};

fn pct(x: f64) -> String {
    format!("{:6.2}%", 100.0 * x)
}

fn header(title: &str) {
    println!();
    println!("=== {title} ===");
}

/// Run sweep points on every available core (spec-order results).
fn par(cfg: &SimConfig, points: &[SweepPoint]) -> Vec<RunMetrics> {
    sweep::run_points(cfg, points, sweep::available_jobs())
}

/// Run prebuilt (spec, protocol, config) jobs on every available core.
fn par_jobs(jobs: &[SpecJob]) -> Vec<RunMetrics> {
    sweep::run_jobs(jobs, sweep::available_jobs())
}

/// Breakdown of one run relative to a baseline total.
fn breakdown(m: &RunMetrics, base_total: u64) -> String {
    let f = |x: u64| 100.0 * x as f64 / base_total as f64;
    format!(
        "CCM {:6.2}%  DM {:6.2}%  Host {:6.2}%  | total {:7.2}% ({:9.2} us)",
        f(m.ccm_busy),
        f(m.dm_busy),
        f(m.host_busy),
        f(m.total),
        ps_to_us(m.total)
    )
}

/// Table II: qualitative trade-off matrix (printed for completeness).
pub fn table2() {
    header("Table II: trade-offs across partial offloading mechanisms");
    println!("{:<28} {:^12} {:^10} {:^8}", "Mechanism", "Fine-grained", "Overhead", "Async");
    println!("{:<28} {:^12} {:^10} {:^8}", "Remote Polling (RP)", "no", "high", "yes");
    println!("{:<28} {:^12} {:^10} {:^8}", "Bulk Synchronous (BS)", "yes", "low", "no");
    println!("{:<28} {:^12} {:^10} {:^8}", "Async Back-Streaming", "yes", "hidden", "yes");
}

/// Table IV: the workload roster actually generated.
pub fn table4(cfg: &SimConfig) {
    header("Table IV: workloads");
    println!(
        "{:<6} {:<16} {:<44} {:>9} {:>9} {:>12}",
        "Annot", "Domain", "Application", "CCM tasks", "Host tasks", "Result bytes"
    );
    for a in workload::ALL_ANNOTATIONS {
        let w = workload::by_annotation(a, cfg);
        println!(
            "({})    {:<16} {:<44} {:>9} {:>9} {:>12}",
            a,
            w.domain,
            w.name,
            w.total_ccm_tasks(),
            w.total_host_tasks(),
            w.total_result_bytes()
        );
    }
}

/// Fig. 3: attention-block kernels under RP vs BS (heavy vs light).
pub fn fig3(cfg: &SimConfig) {
    header("Fig. 3: LLM attention kernels, RP vs BS (CCM kcycles)");
    println!(
        "{:<12} {:>12} {:>12} {:>8}  {}",
        "Kernel", "RP kcyc", "BS kcyc", "BS/RP", "class"
    );
    let shared = Arc::new(cfg.clone());
    let mut jobs = Vec::new();
    for k in llm::AttnKernel::ALL {
        let w = Arc::new(llm::single_kernel(cfg, k));
        for proto in [Protocol::Rp, Protocol::Bs] {
            jobs.push(SpecJob { w: Arc::clone(&w), proto, cfg: Arc::clone(&shared) });
        }
    }
    let ms = par_jobs(&jobs);
    for (k, pair) in llm::AttnKernel::ALL.into_iter().zip(ms.chunks(2)) {
        let (rp, bs) = (&pair[0], &pair[1]);
        let kc = |t: u64| t as f64 / cfg.ccm.cycle() as f64 / 1e3;
        println!(
            "{:<12} {:>12.1} {:>12.1} {:>8.3}  {}",
            k.label(),
            kc(rp.total),
            kc(bs.total),
            bs.total as f64 / rp.total as f64,
            if k.is_heavy() { "heavy" } else { "light" }
        );
    }
}

/// Fig. 4: KNN on the real-hardware profile across (dim, rows).
pub fn fig4() {
    header("Fig. 4: KNN real-hardware profile, CCM vs host runtime ratio");
    let cfg = SimConfig::real_hw();
    println!("{:<20} {:>10} {:>10}", "(dim, rows)", "CCM %", "Host %");
    const GRID: [(usize, usize); 7] = [
        (2048, 128),
        (1024, 256),
        (512, 512),
        (256, 1024),
        (128, 2048),
        (64, 4096),
        (32, 4096),
    ];
    let shared = Arc::new(cfg.clone());
    let jobs: Vec<SpecJob> = GRID
        .iter()
        .map(|&(dim, rows)| SpecJob {
            w: Arc::new(workload::knn::generate_queries(&cfg, dim, rows, 4)),
            proto: Protocol::Rp,
            cfg: Arc::clone(&shared),
        })
        .collect();
    for (&(dim, rows), m) in GRID.iter().zip(par_jobs(&jobs)) {
        let busy = (m.ccm_busy + m.host_busy) as f64;
        println!(
            "({:>5}, {:>5})       {:>9.2}% {:>9.2}%",
            dim,
            rows,
            100.0 * m.ccm_busy as f64 / busy,
            100.0 * m.host_busy as f64 / busy
        );
    }
}

/// Fig. 5: KNN + graph component breakdowns under RP and BS.
pub fn fig5(cfg: &SimConfig) {
    header("Fig. 5: runtime breakdown (normalized to RP total), RP vs BS");
    let annots = ['a', 'b', 'c', 'd', 'e'];
    let mut points = Vec::new();
    for a in annots {
        points.push(SweepPoint::new(a, Protocol::Rp, ConfigDelta::identity()));
        points.push(SweepPoint::new(a, Protocol::Bs, ConfigDelta::identity()));
    }
    let ms = par(cfg, &points);
    for (a, pair) in annots.into_iter().zip(ms.chunks(2)) {
        let (rp, bs) = (&pair[0], &pair[1]);
        println!("({a}) {}", rp.workload);
        println!("    RP: {}", breakdown(rp, rp.total));
        println!("    BS: {}", breakdown(bs, rp.total));
    }
}

/// Fig. 7: CCM and host idle times for the Fig. 5 setups.
pub fn fig7(cfg: &SimConfig) {
    header("Fig. 7: idle times (fraction of each run's total)");
    println!(
        "{:<4} {:<6} {:>10} {:>10} {:>12}",
        "WL", "proto", "CCM idle", "Host idle", "total(us)"
    );
    let annots = ['a', 'b', 'c', 'd', 'e'];
    let mut points = Vec::new();
    for a in annots {
        points.push(SweepPoint::new(a, Protocol::Rp, ConfigDelta::identity()));
        points.push(SweepPoint::new(a, Protocol::Bs, ConfigDelta::identity()));
    }
    let ms = par(cfg, &points);
    for (a, pair) in annots.into_iter().zip(ms.chunks(2)) {
        for m in pair {
            println!(
                "({a})  {:<6} {:>10} {:>10} {:>12.2}",
                m.protocol,
                pct(m.frac(m.ccm_idle())),
                pct(m.frac(m.host_idle())),
                ps_to_us(m.total)
            );
        }
    }
}

/// Fig. 10: end-to-end runtime, all workloads × {RP, BS, AXLE_Int, AXLE p1/p10/p100}.
pub fn fig10(cfg: &SimConfig) {
    header("Fig. 10: normalized end-to-end runtime ratio (RP = 100%)");
    println!(
        "{:<4} {:>8} {:>8} {:>10} {:>8} {:>8} {:>8}",
        "WL", "RP", "BS", "AXLE_Int", "p1", "p10", "p100"
    );
    let ms = par(cfg, &fig10_points());
    let mut red_rp = [Vec::new(), Vec::new(), Vec::new()];
    let mut red_bs = [Vec::new(), Vec::new(), Vec::new()];
    for (a, row) in workload::ALL_ANNOTATIONS.into_iter().zip(ms.chunks(6)) {
        let (rp, bs, int, axles) = (&row[0], &row[1], &row[2], &row[3..6]);
        for (i, m) in axles.iter().enumerate() {
            red_rp[i].push(1.0 - m.ratio_to(rp));
            red_bs[i].push(1.0 - m.ratio_to(bs));
        }
        println!(
            "({a})  {:>7.2}% {:>7.2}% {:>9.2}% {:>7.2}% {:>7.2}% {:>7.2}%",
            100.0,
            100.0 * bs.ratio_to(rp),
            100.0 * int.ratio_to(rp),
            100.0 * axles[0].ratio_to(rp),
            100.0 * axles[1].ratio_to(rp),
            100.0 * axles[2].ratio_to(rp),
        );
    }
    println!("(j) end-to-end time-ratio reduction of AXLE:");
    for (i, lbl) in ["p1", "p10", "p100"].iter().enumerate() {
        println!(
            "    {lbl:<5} vs RP: avg {} geomean {} max {} | vs BS: avg {} geomean {} max {}",
            pct(mean(&red_rp[i])),
            pct(geomean(&red_rp[i].iter().map(|x| x.max(1e-9)).collect::<Vec<_>>())),
            pct(red_rp[i].iter().cloned().fold(f64::MIN, f64::max)),
            pct(mean(&red_bs[i])),
            pct(geomean(&red_bs[i].iter().map(|x| x.max(1e-9)).collect::<Vec<_>>())),
            pct(red_bs[i].iter().cloned().fold(f64::MIN, f64::max)),
        );
    }
}

/// The Fig. 10 sweep matrix (also benchmarked by `benches/figures.rs`):
/// per workload, RP/BS/AXLE_Interrupt at defaults plus AXLE at p1/p10/p100.
pub fn fig10_points() -> Vec<SweepPoint> {
    let mut points = Vec::new();
    for a in workload::ALL_ANNOTATIONS {
        points.push(SweepPoint::new(a, Protocol::Rp, ConfigDelta::identity()));
        points.push(SweepPoint::new(a, Protocol::Bs, ConfigDelta::identity()));
        points.push(SweepPoint::new(a, Protocol::AxleInterrupt, ConfigDelta::identity()));
        for p in [poll_factors::P1, poll_factors::P10, poll_factors::P100] {
            points.push(SweepPoint::new(a, Protocol::Axle, ConfigDelta::identity().with_poll(p)));
        }
    }
    points
}

/// Fig. 11: the LLM case under the reduced-PU hardware profile.
pub fn fig11() {
    header("Fig. 11: LLM with reduced processing units (CCM/4, host/4)");
    let setups = [("Table III baseline", SimConfig::m2ndp()), ("reduced", SimConfig::reduced())];
    for (label, cfg) in setups {
        let points = [
            SweepPoint::new('h', Protocol::Rp, ConfigDelta::identity()),
            SweepPoint::new('h', Protocol::Bs, ConfigDelta::identity()),
            SweepPoint::new(
                'h',
                Protocol::Axle,
                ConfigDelta::identity().with_poll(poll_factors::P10),
            ),
        ];
        let ms = par(&cfg, &points);
        let (rp, bs, axle) = (&ms[0], &ms[1], &ms[2]);
        println!(
            "{label:<20} RP 100.00%  BS {:>7.2}%  AXLE(p10) {:>7.2}%",
            100.0 * bs.ratio_to(rp),
            100.0 * axle.ratio_to(rp)
        );
    }
}

/// Fig. 12: idle-time comparison, all workloads, p10.
pub fn fig12(cfg: &SimConfig) {
    header("Fig. 12: idle time ratios (p10), RP vs BS vs AXLE");
    println!(
        "{:<4} {:>10} {:>10} {:>10} | {:>10} {:>10} {:>10}",
        "WL", "CCM:RP", "CCM:BS", "CCM:AXLE", "Host:RP", "Host:BS", "Host:AXLE"
    );
    let p10 = ConfigDelta::identity().with_poll(poll_factors::P10);
    let mut points = Vec::new();
    for a in workload::ALL_ANNOTATIONS {
        points.push(SweepPoint::new(a, Protocol::Rp, ConfigDelta::identity()));
        points.push(SweepPoint::new(a, Protocol::Bs, ConfigDelta::identity()));
        points.push(SweepPoint::new(a, Protocol::Axle, p10));
    }
    let ms = par(cfg, &points);
    let mut ccm_red_rp = Vec::new();
    let mut ccm_red_bs = Vec::new();
    let mut host_red_rp = Vec::new();
    let mut host_red_bs = Vec::new();
    for (a, row) in workload::ALL_ANNOTATIONS.into_iter().zip(ms.chunks(3)) {
        let (rp, bs, ax) = (&row[0], &row[1], &row[2]);
        println!(
            "({a})  {:>10} {:>10} {:>10} | {:>10} {:>10} {:>10}",
            pct(rp.frac(rp.ccm_idle())),
            pct(bs.frac(bs.ccm_idle())),
            pct(ax.frac(ax.ccm_idle())),
            pct(rp.frac(rp.host_idle())),
            pct(bs.frac(bs.host_idle())),
            pct(ax.frac(ax.host_idle())),
        );
        let safe = |x: u64| (x.max(1)) as f64;
        let axt = ax.total as f64;
        ccm_red_rp.push(safe(rp.ccm_idle()) * axt / (safe(ax.ccm_idle()) * rp.total as f64));
        ccm_red_bs.push(safe(bs.ccm_idle()) * axt / (safe(ax.ccm_idle()) * bs.total as f64));
        host_red_rp.push(safe(rp.host_idle()) * axt / (safe(ax.host_idle()) * rp.total as f64));
        host_red_bs.push(safe(bs.host_idle()) * axt / (safe(ax.host_idle()) * bs.total as f64));
    }
    println!(
        "avg idle-ratio reduction: CCM {:.2}x (vs RP) {:.2}x (vs BS) | host {:.2}x (vs RP) {:.2}x (vs BS)",
        mean(&ccm_red_rp),
        mean(&ccm_red_bs),
        mean(&host_red_rp),
        mean(&host_red_bs)
    );
}

/// Fig. 13: host core stall time, p10 and p100.
pub fn fig13(cfg: &SimConfig) {
    header("Fig. 13: host core stall time / end-to-end runtime");
    println!(
        "{:<4} {:>10} {:>10} {:>12} {:>12}",
        "WL", "RP", "BS", "AXLE p10", "AXLE p100"
    );
    let mut points = Vec::new();
    for a in workload::ALL_ANNOTATIONS {
        points.push(SweepPoint::new(a, Protocol::Rp, ConfigDelta::identity()));
        points.push(SweepPoint::new(a, Protocol::Bs, ConfigDelta::identity()));
        let p10 = ConfigDelta::identity().with_poll(poll_factors::P10);
        let p100 = ConfigDelta::identity().with_poll(poll_factors::P100);
        points.push(SweepPoint::new(a, Protocol::Axle, p10));
        points.push(SweepPoint::new(a, Protocol::Axle, p100));
    }
    let ms = par(cfg, &points);
    for (a, row) in workload::ALL_ANNOTATIONS.into_iter().zip(ms.chunks(4)) {
        let (rp, bs, a10, a100) = (&row[0], &row[1], &row[2], &row[3]);
        println!(
            "({a})  {:>10} {:>10} {:>12} {:>12}",
            pct(rp.frac(rp.host_stall_clamped())),
            pct(bs.frac(bs.host_stall_clamped())),
            pct(a10.frac(a10.host_stall_clamped())),
            pct(a100.frac(a100.host_stall_clamped())),
        );
    }
}

/// Fig. 14: streaming-factor sweep.
pub fn fig14(cfg: &SimConfig) {
    header("Fig. 14: end-to-end runtime vs streaming factor (normalized to SF1)");
    for a in ['a', 'd', 'i'] {
        // One spec build per workload (needed up front for the
        // result-byte-relative SF settings), shared by every job below.
        let w = Arc::new(workload::by_annotation(a, cfg));
        let total_result = w.total_result_bytes() / w.iters.len() as u64;
        let sweep_sfs = [
            ("SF1", 32u64),
            ("SF2", 64),
            ("SF8", 256),
            ("SF32", 1024),
            ("SF64", 2048),
            ("SF_25%", total_result / 4),
            ("SF_50%", total_result / 2),
            ("SF_100%", total_result),
        ];
        let sf_cfg = |sf: u64| Arc::new(ConfigDelta::identity().with_sf(sf.max(32)).apply(cfg));
        let shared = Arc::new(cfg.clone());
        // Job 0 is the SF1 baseline; then the labelled sweep; then RP/BS.
        let mut jobs =
            vec![SpecJob { w: Arc::clone(&w), proto: Protocol::Axle, cfg: sf_cfg(32) }];
        for (_, sf) in sweep_sfs {
            jobs.push(SpecJob { w: Arc::clone(&w), proto: Protocol::Axle, cfg: sf_cfg(sf) });
        }
        jobs.push(SpecJob { w: Arc::clone(&w), proto: Protocol::Rp, cfg: Arc::clone(&shared) });
        jobs.push(SpecJob { w: Arc::clone(&w), proto: Protocol::Bs, cfg: Arc::clone(&shared) });
        let ms = par_jobs(&jobs);
        let base = &ms[0];
        print!("({a}) ");
        for ((label, _), m) in sweep_sfs.into_iter().zip(&ms[1..1 + sweep_sfs.len()]) {
            print!("{label} {:.3}  ", m.total as f64 / base.total as f64);
        }
        let (rp, bs) = (&ms[1 + sweep_sfs.len()], &ms[2 + sweep_sfs.len()]);
        println!(
            "| RP {:.3} BS {:.3}",
            rp.total as f64 / base.total as f64,
            bs.total as f64 / base.total as f64
        );
    }
}

/// Fig. 14-ext (extension): fixed vs adaptive streaming factor.
///
/// The paper flags "dynamically selecting an optimal SF" as future work
/// (§V-E). The adaptive policy targets one DMA-prep period's worth of
/// production; this report compares it against the best and worst fixed
/// settings per workload.
pub fn fig14_ext(cfg: &SimConfig) {
    header("Fig. 14-ext: adaptive streaming factor vs fixed (normalized to fixed SF1)");
    println!(
        "{:<4} {:>10} {:>10} {:>10} {:>10} {:>14} {:>14}",
        "WL", "SF1", "SF64", "SF_100%", "adaptive", "SF1 batches", "adapt batches"
    );
    for a in ['a', 'b', 'd', 'e', 'i'] {
        // One spec build per workload, shared by the four jobs.
        let w = Arc::new(workload::by_annotation(a, cfg));
        let axle_job = |d: ConfigDelta| SpecJob {
            w: Arc::clone(&w),
            proto: Protocol::Axle,
            cfg: Arc::new(d.apply(cfg)),
        };
        let jobs = [
            axle_job(ConfigDelta::identity()),
            axle_job(ConfigDelta::identity().with_sf(2048)),
            axle_job(ConfigDelta::identity().with_sf(w.iters[0].result_bytes().max(32))),
            axle_job(ConfigDelta::identity().with_sf_policy(crate::config::SfPolicy::Adaptive)),
        ];
        let ms = par_jobs(&jobs);
        let (base, sf64, sf_all, adaptive) = (&ms[0], &ms[1], &ms[2], &ms[3]);
        println!(
            "({a})  {:>10.3} {:>10.3} {:>10.3} {:>10.3} {:>14} {:>14}",
            1.0,
            sf64.total as f64 / base.total as f64,
            sf_all.total as f64 / base.total as f64,
            adaptive.total as f64 / base.total as f64,
            base.dma_batches,
            adaptive.dma_batches,
        );
    }
}

/// Fig. 15: OoO streaming on/off × RR/FIFO.
pub fn fig15(cfg: &SimConfig) {
    header("Fig. 15: runtime without OoO streaming / with OoO (per scheduler)");
    println!("{:<4} {:>10} {:>10}", "WL", "RR", "FIFO");
    let mut points = Vec::new();
    let annots = ['d', 'e', 'i'];
    for a in annots {
        for sched in [SchedPolicy::RoundRobin, SchedPolicy::Fifo] {
            for ooo in [true, false] {
                points.push(SweepPoint::new(
                    a,
                    Protocol::Axle,
                    ConfigDelta::identity().with_sched(sched).with_ooo(ooo),
                ));
            }
        }
    }
    let ms = par(cfg, &points);
    for (a, row) in annots.into_iter().zip(ms.chunks(4)) {
        // Per scheduler: [on, off] pairs in declaration order.
        let rr = row[1].total as f64 / row[0].total as f64;
        let fifo = row[3].total as f64 / row[2].total as f64;
        println!("({a})  {:>9.2}x {:>9.2}x", rr, fifo);
    }
}

/// Fig. 16: DMA slot capacity sweep + back-pressure cycles.
pub fn fig16(cfg: &SimConfig) {
    header("Fig. 16: runtime and back-pressure vs DMA slot capacity");
    println!(
        "{:<4} {:>10} {:>18} {:>18} {:>18}",
        "WL", "cap=100%", "50%", "25%", "12.5%"
    );
    let annots = ['a', 'd', 'h', 'i'];
    let mut points = Vec::new();
    for a in annots {
        points.push(SweepPoint::new(a, Protocol::Axle, ConfigDelta::identity()));
        for div in [2usize, 4, 8] {
            points.push(SweepPoint::new(
                a,
                Protocol::Axle,
                ConfigDelta::identity().with_capacity(cfg.axle.dma_slot_capacity / div),
            ));
        }
    }
    let ms = par(cfg, &points);
    for (a, row) in annots.into_iter().zip(ms.chunks(4)) {
        let base = &row[0];
        print!("({a})  {:>9.3} ", 1.0);
        for m in &row[1..] {
            if m.deadlock {
                print!("{:>18} ", "DEADLOCK");
            } else {
                print!(
                    "{:>9.3} (bp {:>4.1}%) ",
                    m.total as f64 / base.total as f64,
                    100.0 * m.frac(m.backpressure)
                );
            }
        }
        println!();
    }
}

/// Fig. 17 (extension): multi-tenant contention on a shared CXL fabric,
/// by QoS arbitration policy.
///
/// The paper runs every workload alone on one CCM; this figure walks the
/// topology layer's (policy, devices, streams) grid with a data-heavy
/// tenant mix under AXLE and reports the p50/p99 slowdown vs. each
/// stream's solo run, decomposed into the wire shift (fabric + device
/// links, policy-governed) and the CCM PU shift (compute contention,
/// policy-independent) — the contention behaviour a production
/// multi-tenant deployment (UDON's shared memory-expander scenario)
/// actually sees, and how FCFS / WRR / DRR arbitration redistributes it.
///
/// Row schema (JSON mirror in `TenantReport::to_json`): per tenant,
/// `total_ps = solo_total_ps + wire_wait_ps + pu_wait_ps` where
/// `wire_wait_ps = max(device_wait_ps, fabric_wait_ps)`.
pub fn fig17(cfg: &SimConfig) {
    header("Fig. 17-ext: multi-tenant slowdown by QoS policy, shared fabric");
    println!(
        "{:<6} {:<8} {:>8} {:>10} {:>10} {:>10} {:>12} {:>11} {:>10}",
        "qos",
        "(D, K)",
        "tenants",
        "p50 slow",
        "p99 slow",
        "max slow",
        "wire wait us",
        "pu wait us",
        "fab util"
    );
    let topo = crate::config::TopologySpec::shared_fabric(1, cfg.cxl_bw_gbps);
    let tenants = crate::topo::TenantSpec::new(1).with_workloads(vec!['a', 'd', 'e', 'i']);
    let grid = crate::topo::sweep_tenant_grid(
        cfg,
        &topo,
        &tenants,
        &crate::config::QosPolicy::ALL,
        &[2],
        &[4, 8],
        sweep::available_jobs(),
    );
    for (p, d, k, r) in &grid {
        let wire: crate::sim::Ps = r.tenants.iter().map(|t| t.wire_wait()).sum();
        let pu: crate::sim::Ps = r.tenants.iter().map(|t| t.pu_wait).sum();
        println!(
            "{:<6} ({d}, {k:>2})  {:>8} {:>10.3} {:>10.3} {:>10.3} {:>12.2} {:>11.2} {:>9.1}%",
            p.label(),
            r.tenants.len(),
            r.p50_slowdown,
            r.p99_slowdown,
            r.max_slowdown,
            ps_to_us(wire),
            ps_to_us(pu),
            100.0 * r.fabric.utilization
        );
    }
}

/// Fig. 19 (extension): closed-loop offload scheduling — end-to-end
/// runtime, host/CCM idle time and per-priority-class slowdown per
/// (protocol policy × QoS policy × depth), on a heterogeneous
/// two-device topology.
///
/// The paper's evaluation fixes the offload mechanism per run; KAI
/// exists because the right protocol depends on data and processing
/// intensity, and UDON argues the decision belongs online. This figure
/// closes the loop: tenants submit requests against completion feedback
/// (window `--depth`, per-device admission queues) over one strong and
/// one weak-CCM device, and the scheduler picks RP/BS/AXLE per request.
/// `static-*` rows pin one protocol (PR-3 behavior), `heuristic` adapts
/// per request (compute-vs-transfer ratio + observed occupancy), and
/// `oracle` is the clairvoyant per-request bound. The tenant mix runs
/// two priority classes (alternating 1/0): admission queues pop the
/// high class first, and the live link calendars charge wire time under
/// each of FCFS / WRR / DRR in turn.
///
/// Row schema (JSON mirror in `SchedReport::to_json`, `axle sched
/// --json`): per policy × qos × depth — `makespan_ps`, `p50_slowdown` /
/// `p99_slowdown` (per-request `total/solo`, queueing included),
/// per-class `classes` rows (`{class, requests, p50_slowdown,
/// p99_slowdown}`), `host_idle_frac` / `ccm_idle_frac` (the paper's
/// headline idle metrics) and `proto_mix` (requests per chosen
/// protocol).
pub fn fig19(cfg: &SimConfig) {
    header("Fig. 19-ext: closed-loop scheduling, policy x qos x depth, heterogeneous devices");
    println!(
        "{:<14} {:<5} {:>5} {:>12} {:>9} {:>9} {:>11} {:>11} {:>10} {:>10}  {}",
        "policy",
        "qos",
        "depth",
        "makespan us",
        "p50 slow",
        "p99 slow",
        "c0 p50/p99",
        "c1 p50/p99",
        "host idle",
        "ccm idle",
        "proto mix"
    );
    let topo = crate::config::TopologySpec::shared_fabric(2, cfg.cxl_bw_gbps).with_override(
        1,
        crate::config::DeviceOverride { ccm_pus: Some(4), ..Default::default() },
    );
    // Two priority classes, cycled: even tenants class 1, odd class 0.
    let base = crate::config::SchedSpec::new(4)
        .with_workloads(vec!['a', 'e', 'i'])
        .with_requests(2)
        .with_priorities(vec![1, 0]);
    let grid = crate::sched::sweep_sched_grid(
        cfg,
        &topo,
        &base,
        &crate::config::PolicyKind::ALL,
        &crate::config::QosPolicy::ALL,
        &[1, 2],
        sweep::available_jobs(),
    );
    for (p, qos, depth, r) in &grid {
        let mix: Vec<String> =
            r.proto_mix.iter().map(|(proto, n)| format!("{proto}:{n}")).collect();
        let classes = r.class_slowdowns();
        let per_class = |want: u32| {
            classes
                .iter()
                .find(|(class, ..)| *class == want)
                .map(|(_, _, p50, p99)| format!("{p50:.2}/{p99:.2}"))
                .unwrap_or_else(|| "-".into())
        };
        println!(
            "{:<14} {:<5} {:>5} {:>12.2} {:>9.3} {:>9.3} {:>11} {:>11} {:>9.1}% {:>9.1}%  {}",
            p.label(),
            qos.label(),
            depth,
            ps_to_us(r.makespan),
            r.p50_slowdown,
            r.p99_slowdown,
            per_class(0),
            per_class(1),
            100.0 * r.host_idle_frac(),
            100.0 * r.ccm_idle_frac(),
            mix.join(" ")
        );
    }
}

/// Fig. 20-ext (beyond the paper): fault injection and recovery. A
/// mid-run **permanent failure** of the strong CCM device under the
/// Fig. 19 strong+weak two-device closed loop, repeated under each of
/// FCFS / WRR / DRR link arbitration. The kill instant is derived from
/// each arbitration's fault-free baseline (the midpoint of the longest
/// device-0 service window), so the failure always catches an in-flight
/// offload; the scheduler kills the attempt, drains device 0's
/// admission queue, and re-places everything onto the surviving weak
/// device.
///
/// Row schema: per qos — the kill instant (`fail us`), time-to-recover
/// (`recover us`: latest displaced request back in service, from the
/// kill), displaced count, lost work (wire/PU picoseconds wasted on the
/// killed attempts, printed in us), and p50/p99 request slowdown split
/// by submission phase — `before` (submitted before the kill), `during`
/// (within the recovery window), `after` (once recovered) — plus whole
/// run host/CCM idle faulted vs. baseline. `failed` stays 0: every
/// displaced request completes on the survivor.
pub fn fig20(cfg: &SimConfig) {
    header("Fig. 20-ext: mid-run device failure, recovery across qos arbitration");
    println!(
        "{:<5} {:>9} {:>11} {:>9} {:>7} {:>13} {:>13} {:>13} {:>13} {:>6} {:>17} {:>17}",
        "qos",
        "fail us",
        "recover us",
        "displaced",
        "failed",
        "lost w/p us",
        "before 50/99",
        "during 50/99",
        "after 50/99",
        "",
        "host idle b/f",
        "ccm idle b/f"
    );
    let pctile = |xs: &[f64], p: f64| -> f64 {
        if xs.is_empty() {
            return 0.0;
        }
        let mut v = xs.to_vec();
        v.sort_by(|a, b| a.total_cmp(b));
        v[(((v.len() - 1) as f64) * p).round() as usize]
    };
    let phase_cell = |xs: &[f64]| -> String {
        if xs.is_empty() {
            "-".into()
        } else {
            format!("{:.2}/{:.2}", pctile(xs, 0.50), pctile(xs, 0.99))
        }
    };
    let topo_base = crate::config::TopologySpec::shared_fabric(2, cfg.cxl_bw_gbps).with_override(
        1,
        crate::config::DeviceOverride { ccm_pus: Some(4), ..Default::default() },
    );
    // Retention is explicit (the library default): this report reads
    // per-request rows to pick the kill instant and bucket slowdowns by
    // submission phase, so it must not run in streaming-sketch mode.
    let spec = crate::config::SchedSpec::new(4)
        .with_workloads(vec!['a', 'e'])
        .with_policy(crate::config::PolicyKind::Static(Protocol::Axle))
        .with_requests(2)
        .with_admit(2)
        .with_retain(true);
    for qos in [
        crate::config::QosSpec::fcfs(),
        crate::config::QosSpec::wrr(vec![4, 1]),
        crate::config::QosSpec::drr(vec![0.75, 0.25]),
    ] {
        let topo = topo_base.clone().with_qos(qos);
        let base = crate::sched::run(&crate::sched::SchedRun::new(cfg, &topo, &spec)).report;
        // Kill device 0 mid-service: the engine is deterministic and
        // bit-identical to the baseline up to the first fault event, so
        // the midpoint of the baseline's longest device-0 service
        // window is guaranteed to catch that request in flight.
        let at = base
            .requests
            .iter()
            .filter(|q| q.device == 0 && q.completion > q.admit + 1)
            .max_by_key(|q| q.completion - q.admit)
            .map(|q| q.admit + (q.completion - q.admit) / 2)
            .unwrap_or(base.makespan / 2);
        let faults =
            crate::config::FaultSpec::with(vec![crate::config::FaultEvent::fail(0, at)]);
        let fspec = spec.clone().with_faults(faults);
        let r = crate::sched::run(&crate::sched::SchedRun::new(cfg, &topo, &fspec)).report;
        let row = &r.faults[0];
        let recovered = at + row.recover;
        let (mut before, mut during, mut after) = (Vec::new(), Vec::new(), Vec::new());
        for q in &r.requests {
            let bucket = if q.submit < at {
                &mut before
            } else if q.submit < recovered {
                &mut during
            } else {
                &mut after
            };
            bucket.push(q.slowdown());
        }
        println!(
            "{:<5} {:>9.2} {:>11.2} {:>9} {:>7} {:>13} {:>13} {:>13} {:>13} {:>6} {:>17} {:>17}",
            r.qos.label(),
            ps_to_us(at),
            ps_to_us(row.recover),
            row.displaced,
            r.failed_requests,
            format!("{:.1}/{:.1}", ps_to_us(r.lost_wire), ps_to_us(r.lost_pu)),
            phase_cell(&before),
            phase_cell(&during),
            phase_cell(&after),
            "",
            format!("{:.1}%/{:.1}%", 100.0 * base.host_idle_frac(), 100.0 * r.host_idle_frac()),
            format!("{:.1}%/{:.1}%", 100.0 * base.ccm_idle_frac(), 100.0 * r.ccm_idle_frac())
        );
    }
}

/// Fig. 21-ext (beyond the paper): intra-request pipelining. The
/// Fig. 19 strong+weak two-device closed loop under AXLE offloads,
/// re-run with each request decomposed into a stage DAG of `--chunks`
/// back-streamed chunks (`axle sched --chunks N`). Whole-request
/// admission (`chunks 1`) holds a device slot until the back-stream
/// drains; chunked admission releases the slot once the last CCM stage
/// is provably done, so the next request's transfer and compute overlap
/// the tail of the current one. Device busy time is conserved — the
/// win shows up as a shorter makespan and lower host/CCM idle
/// fractions, the paper's headline idle metrics.
///
/// Row schema: per qos × chunk count — `makespan us`, p50/p99 request
/// slowdown, host/CCM idle fractions, and each idle fraction's delta
/// against the same qos row's `chunks 1` baseline (negative = chunking
/// recovered that much idle).
pub fn fig21(cfg: &SimConfig) {
    header("Fig. 21-ext: intra-request pipelining, host/CCM idle vs chunk count");
    println!(
        "{:<5} {:>6} {:>12} {:>9} {:>9} {:>10} {:>10} {:>11} {:>11}",
        "qos",
        "chunks",
        "makespan us",
        "p50 slow",
        "p99 slow",
        "host idle",
        "ccm idle",
        "d host idle",
        "d ccm idle"
    );
    let topo = crate::config::TopologySpec::shared_fabric(2, cfg.cxl_bw_gbps).with_override(
        1,
        crate::config::DeviceOverride { ccm_pus: Some(4), ..Default::default() },
    );
    // One service slot per device (admit 1) with a depth-2 window keeps
    // every device's queue non-empty, so the early slot release has a
    // successor to admit — the contention regime chunking targets.
    let base = crate::config::SchedSpec::new(4)
        .with_workloads(vec!['a', 'e', 'i'])
        .with_policy(crate::config::PolicyKind::Static(Protocol::Axle))
        .with_requests(2)
        .with_admit(1)
        .with_depth(2);
    let grid = crate::sched::sweep_pipeline_grid(
        cfg,
        &topo,
        &base,
        &crate::config::QosPolicy::ALL,
        &[1, 2, 4, 8],
        sweep::available_jobs(),
    );
    let mut baseline: Option<(f64, f64)> = None;
    for (qos, chunks, r) in &grid {
        if *chunks == 1 {
            baseline = Some((r.host_idle_frac(), r.ccm_idle_frac()));
        }
        let (bh, bc) = baseline.expect("chunks axis starts at 1");
        println!(
            "{:<5} {:>6} {:>12.2} {:>9.3} {:>9.3} {:>9.1}% {:>9.1}% {:>10.1}% {:>10.1}%",
            qos.label(),
            chunks,
            ps_to_us(r.makespan),
            r.p50_slowdown,
            r.p99_slowdown,
            100.0 * r.host_idle_frac(),
            100.0 * r.ccm_idle_frac(),
            100.0 * (r.host_idle_frac() - bh),
            100.0 * (r.ccm_idle_frac() - bc)
        );
    }
}

/// Fig. 22 (observability): windowed telemetry rendered from the
/// deterministic event trace. Two runs of the Fig. 21 strong+weak
/// contention point (`admit 1`, `depth 2` — every device queue stays
/// non-empty), each with the tracer armed (`--trace`):
///
/// 1. **fault-free** — per-window host/CCM utilization, time-averaged
///    admission-queue depth and outstanding occupancy, completions and
///    per-window p99 slowdown, straight from the recorded wire grants,
///    PU leases and request lifecycle events;
/// 2. **mid-run failure** — device 0 killed at the midpoint of its
///    longest fault-free service window (the Fig. 20 heuristic), so the
///    windows show the utilization dip at the kill, the retry burst,
///    and the recovery on the surviving device.
///
/// Both traces are run through [`crate::trace::validate`] against their
/// own reports first: every figure this emitter prints reconciles
/// exactly (integer picoseconds) with the run's `SchedReport`. Tracing
/// is observation-only, so both reports are bit-identical to untraced
/// runs of the same specs.
pub fn fig22(cfg: &SimConfig) {
    header("Fig. 22: windowed telemetry from the deterministic event trace");
    let jobs = sweep::available_jobs();
    let fmt_time = crate::util::fmt::fmt_time;
    let fmt_pct = crate::util::fmt::fmt_pct;
    let topo = crate::config::TopologySpec::shared_fabric(2, cfg.cxl_bw_gbps).with_override(
        1,
        crate::config::DeviceOverride { ccm_pus: Some(4), ..Default::default() },
    );
    let spec = crate::config::SchedSpec::new(4)
        .with_workloads(vec!['a', 'e', 'i'])
        .with_policy(crate::config::PolicyKind::Static(Protocol::Axle))
        .with_requests(2)
        .with_admit(1)
        .with_depth(2)
        .with_retain(true)
        .with_trace(crate::config::TraceSpec { buckets: 8 });
    let print_windows = |tel: &crate::trace::telemetry::Telemetry| {
        println!(
            "  {:<25} {:>7} {:>7} {:>7} {:>6} {:>5} {:>5} {:>8}",
            "window", "host", "ccm", "qdepth", "outst", "done", "rtry", "p99 sd"
        );
        for w in &tel.windows {
            let p99 = if w.slowdown.count() == 0 {
                "-".to_string()
            } else {
                format!("{:.3}", w.slowdown.quantile(99.0))
            };
            println!(
                "  [{:>10} {:>12}] {:>7} {:>7} {:>7.2} {:>6.2} {:>5} {:>5} {:>8}",
                fmt_time(w.start),
                fmt_time(w.end),
                fmt_pct(w.host_util()),
                fmt_pct(w.ccm_util(tel.devices)),
                w.queue_depth,
                w.outstanding,
                w.completions,
                w.retries,
                p99
            );
        }
    };

    let out = crate::sched::run(&crate::sched::SchedRun::new(cfg, &topo, &spec).with_jobs(jobs));
    let (r, tr) = (out.report, out.trace);
    let tr = tr.expect("trace spec is set");
    crate::trace::validate(&tr, &r).expect("fault-free trace reconciles with its report");
    let tel = crate::trace::telemetry::windows(&tr, 8, r.makespan);
    println!(
        "fault-free contention point: {} trace events, makespan {}, host util p50 {}",
        tr.len(),
        fmt_time(r.makespan),
        fmt_pct(tel.host_util_p50())
    );
    print_windows(&tel);

    // The kill instant comes from the fault-free run's own rows — the
    // engine is bit-identical up to the first fault event, so the
    // midpoint of the longest device-0 service window is guaranteed to
    // catch that request in flight (same heuristic as Fig. 20).
    let at = r
        .requests
        .iter()
        .filter(|q| q.device == 0 && q.completion > q.admit + 1)
        .max_by_key(|q| q.completion - q.admit)
        .map(|q| q.admit + (q.completion - q.admit) / 2)
        .unwrap_or(r.makespan / 2);
    let faults = crate::config::FaultSpec::with(vec![crate::config::FaultEvent::fail(0, at)]);
    let fspec = spec.clone().with_faults(faults);
    let outf = crate::sched::run(&crate::sched::SchedRun::new(cfg, &topo, &fspec).with_jobs(jobs));
    let (rf, trf) = (outf.report, outf.trace);
    let trf = trf.expect("trace spec is set");
    crate::trace::validate(&trf, &rf).expect("faulted trace reconciles with its report");
    let telf = crate::trace::telemetry::windows(&trf, 8, rf.makespan);
    println!(
        "device 0 fails at {}: {} displaced, {} retries recorded, makespan {}",
        fmt_time(at),
        rf.faults[0].displaced,
        telf.windows.iter().map(|w| w.retries as u64).sum::<u64>(),
        fmt_time(rf.makespan)
    );
    print_windows(&telf);
}

/// Fig. 23-ext (beyond the paper): learned, feedback-driven scheduling
/// under nonstationarity. Two identical devices behind a shared fabric
/// with least-loaded placement; device 0's PUs and link degrade `8x`
/// at a quarter of the fault-free makespan and stay degraded past the
/// end of the run. The static least-loaded metric keeps charging
/// undegraded solo estimates, so the `heuristic` and `oracle` deciders
/// keep splitting work onto the slowed device; the `learned` decider's
/// per-device latency estimators absorb the inflated completions and
/// its placement re-routes onto device 1 — the makespan/p99 gap this
/// table shows, windowed over each run's own timeline so the
/// re-convergence is visible (`axle scenario --learned` prints the
/// headline numbers; the acceptance assertion lives in
/// `tests/sched_regression.rs`).
pub fn fig23(cfg: &SimConfig) {
    header("Fig. 23-ext: learned vs heuristic vs oracle under mid-run degradation");
    let fmt_time = crate::util::fmt::fmt_time;
    let fmt_pct = crate::util::fmt::fmt_pct;
    let topo = crate::config::TopologySpec::shared_fabric(2, cfg.cxl_bw_gbps)
        .with_placement(crate::config::Placement::LeastLoaded);
    let spec = crate::config::SchedSpec::new(4)
        .with_workloads(vec!['a', 'e'])
        .with_requests(4)
        .with_admit(2)
        .with_retain(true)
        .with_trace(crate::config::TraceSpec { buckets: 8 });
    let base_spec = spec.clone().with_policy(crate::config::PolicyKind::Heuristic);
    let base = crate::sched::run(&crate::sched::SchedRun::new(cfg, &topo, &base_spec)).report;
    let at = (base.makespan / 4).max(1);
    let until = base.makespan.saturating_mul(50).max(at + 1);
    let faults = crate::config::FaultSpec::with(vec![
        crate::config::FaultEvent::degrade_pus(0, at, until, 8.0),
        crate::config::FaultEvent::degrade_link(0, at, until, 8.0),
    ]);
    println!(
        "device 0 degrades 8x (pus + link) at {} for the rest of the run",
        fmt_time(at)
    );
    for policy in [
        crate::config::PolicyKind::Learned,
        crate::config::PolicyKind::Heuristic,
        crate::config::PolicyKind::Oracle,
    ] {
        let pspec = spec.clone().with_policy(policy).with_faults(faults.clone());
        let out = crate::sched::run(&crate::sched::SchedRun::new(cfg, &topo, &pspec));
        let r = out.report;
        let tr = out.trace.expect("trace spec is set");
        crate::trace::validate(&tr, &r).expect("trace reconciles with its report");
        let tel = crate::trace::telemetry::windows(&tr, 8, r.makespan);
        // Post-onset placement split: how much work still lands on the
        // degraded device once the slowdown is observable.
        let after: Vec<_> = r.requests.iter().filter(|q| q.submit >= at).collect();
        let on_degraded = after.iter().filter(|q| q.device == 0).count();
        println!(
            "{:<9} makespan {} | p50/p99 slowdown {:.3}/{:.3} | post-onset requests on degraded device {}/{}",
            r.policy.label(),
            fmt_time(r.makespan),
            r.p50_slowdown,
            r.p99_slowdown,
            on_degraded,
            after.len()
        );
        println!(
            "  {:<25} {:>7} {:>7} {:>7} {:>6} {:>5} {:>8}",
            "window", "host", "ccm", "qdepth", "outst", "done", "p99 sd"
        );
        for w in &tel.windows {
            let p99 = if w.slowdown.count() == 0 {
                "-".to_string()
            } else {
                format!("{:.3}", w.slowdown.quantile(99.0))
            };
            println!(
                "  [{:>10} {:>12}] {:>7} {:>7} {:>7.2} {:>6.2} {:>5} {:>8}",
                fmt_time(w.start),
                fmt_time(w.end),
                fmt_pct(w.host_util()),
                fmt_pct(w.ccm_util(tel.devices)),
                w.queue_depth,
                w.outstanding,
                w.completions,
                p99
            );
        }
    }
}

/// Table I echo: what each workload offloads.
pub fn table1() {
    header("Table I: offloaded functions");
    for (dom, f) in [
        ("OLAP/OLTP", "Filtering (within SELECT)"),
        ("Graph Analytics", "Edge traversal -> Vertex update"),
        ("KNN/ANN", "Vector distance calculation"),
        ("LLM Inference", "Attention block"),
        ("DLRM", "Embedding lookup -> Sparse Length Sum"),
    ] {
        println!("{dom:<18} {f}");
    }
    let _ = olap::SsbQuery::Q1_1; // referenced by the generators
}

#[cfg(test)]
mod tests {
    use super::*;

    // Smoke tests: every emitter runs without panicking on the default
    // config (output goes to the test harness's captured stdout).
    #[test]
    fn fast_reports_run() {
        let cfg = SimConfig::m2ndp();
        table1();
        table2();
        table4(&cfg);
        fig3(&cfg);
        fig4();
        fig5(&cfg);
        fig7(&cfg);
    }

    #[test]
    fn sweep_reports_run() {
        let cfg = SimConfig::m2ndp();
        fig11();
        fig14(&cfg);
        fig14_ext(&cfg);
        fig15(&cfg);
        fig16(&cfg);
    }

    #[test]
    fn tenant_report_runs() {
        fig17(&SimConfig::m2ndp());
    }

    #[test]
    fn sched_report_runs() {
        fig19(&SimConfig::m2ndp());
    }

    #[test]
    fn fault_report_runs() {
        fig20(&SimConfig::m2ndp());
    }

    #[test]
    fn pipeline_report_runs() {
        fig21(&SimConfig::m2ndp());
    }

    #[test]
    fn trace_report_runs() {
        fig22(&SimConfig::m2ndp());
    }

    #[test]
    fn learned_report_runs() {
        fig23(&SimConfig::m2ndp());
    }

    #[test]
    fn fig10_and_idle_reports_run() {
        let cfg = SimConfig::m2ndp();
        fig10(&cfg);
        fig12(&cfg);
        fig13(&cfg);
    }

    #[test]
    fn fig10_points_cover_the_matrix() {
        let pts = fig10_points();
        assert_eq!(pts.len(), 9 * 6);
        // Workload-major, 6 variants per workload.
        assert!(pts[..6].iter().all(|p| p.annot == 'a'));
        assert_eq!(pts[0].proto, Protocol::Rp);
        assert_eq!(pts[5].proto, Protocol::Axle);
        assert_eq!(pts[5].delta.poll_interval, Some(poll_factors::P100));
    }
}

/// Run every figure/table with the default Table III config.
pub fn all() {
    let cfg = SimConfig::m2ndp();
    table1();
    table2();
    table4(&cfg);
    fig3(&cfg);
    fig4();
    fig5(&cfg);
    fig7(&cfg);
    fig10(&cfg);
    fig11();
    fig12(&cfg);
    fig13(&cfg);
    fig14(&cfg);
    fig14_ext(&cfg);
    fig15(&cfg);
    fig16(&cfg);
    fig17(&cfg);
    fig19(&cfg);
    fig20(&cfg);
    fig21(&cfg);
    fig22(&cfg);
    fig23(&cfg);
}
