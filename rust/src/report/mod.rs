//! Figure/table regenerators: print the same rows and series the paper
//! reports (simulated cycles/ratios; see DESIGN.md per-experiment index).
//!
//! Every `fig*` function runs the corresponding experiment configuration
//! and prints a table whose *shape* should match the paper's figure —
//! who wins, by what factor, where the crossovers fall. `cargo run
//! --release --bin axle-report -- all` regenerates everything.

use crate::config::{poll_factors, Protocol, SchedPolicy, SimConfig};
use crate::metrics::{geomean, mean, RunMetrics};
use crate::protocol;
use crate::sim::ps_to_us;
use crate::workload::{self, llm, olap};

fn pct(x: f64) -> String {
    format!("{:6.2}%", 100.0 * x)
}

fn header(title: &str) {
    println!();
    println!("=== {title} ===");
}

/// Breakdown of one run relative to a baseline total.
fn breakdown(m: &RunMetrics, base_total: u64) -> String {
    let f = |x: u64| 100.0 * x as f64 / base_total as f64;
    format!(
        "CCM {:6.2}%  DM {:6.2}%  Host {:6.2}%  | total {:7.2}% ({:9.2} us)",
        f(m.ccm_busy),
        f(m.dm_busy),
        f(m.host_busy),
        f(m.total),
        ps_to_us(m.total)
    )
}

/// Table II: qualitative trade-off matrix (printed for completeness).
pub fn table2() {
    header("Table II: trade-offs across partial offloading mechanisms");
    println!("{:<28} {:^12} {:^10} {:^8}", "Mechanism", "Fine-grained", "Overhead", "Async");
    println!("{:<28} {:^12} {:^10} {:^8}", "Remote Polling (RP)", "no", "high", "yes");
    println!("{:<28} {:^12} {:^10} {:^8}", "Bulk Synchronous (BS)", "yes", "low", "no");
    println!("{:<28} {:^12} {:^10} {:^8}", "Async Back-Streaming", "yes", "hidden", "yes");
}

/// Table IV: the workload roster actually generated.
pub fn table4(cfg: &SimConfig) {
    header("Table IV: workloads");
    println!(
        "{:<6} {:<16} {:<44} {:>9} {:>9} {:>12}",
        "Annot", "Domain", "Application", "CCM tasks", "Host tasks", "Result bytes"
    );
    for a in workload::ALL_ANNOTATIONS {
        let w = workload::by_annotation(a, cfg);
        println!(
            "({})    {:<16} {:<44} {:>9} {:>9} {:>12}",
            a,
            w.domain,
            w.name,
            w.total_ccm_tasks(),
            w.total_host_tasks(),
            w.total_result_bytes()
        );
    }
}

/// Fig. 3: attention-block kernels under RP vs BS (heavy vs light).
pub fn fig3(cfg: &SimConfig) {
    header("Fig. 3: LLM attention kernels, RP vs BS (CCM kcycles)");
    println!(
        "{:<12} {:>12} {:>12} {:>8}  {}",
        "Kernel", "RP kcyc", "BS kcyc", "BS/RP", "class"
    );
    for k in llm::AttnKernel::ALL {
        let w = llm::single_kernel(cfg, k);
        let rp = protocol::run(Protocol::Rp, &w, cfg);
        let bs = protocol::run(Protocol::Bs, &w, cfg);
        let kc = |t: u64| t as f64 / cfg.ccm.cycle() as f64 / 1e3;
        println!(
            "{:<12} {:>12.1} {:>12.1} {:>8.3}  {}",
            k.label(),
            kc(rp.total),
            kc(bs.total),
            bs.total as f64 / rp.total as f64,
            if k.is_heavy() { "heavy" } else { "light" }
        );
    }
}

/// Fig. 4: KNN on the real-hardware profile across (dim, rows).
pub fn fig4() {
    header("Fig. 4: KNN real-hardware profile, CCM vs host runtime ratio");
    let cfg = SimConfig::real_hw();
    println!("{:<20} {:>10} {:>10}", "(dim, rows)", "CCM %", "Host %");
    for (dim, rows) in [
        (2048, 128),
        (1024, 256),
        (512, 512),
        (256, 1024),
        (128, 2048),
        (64, 4096),
        (32, 4096),
    ] {
        let w = workload::knn::generate_queries(&cfg, dim, rows, 4);
        let m = protocol::run(Protocol::Rp, &w, &cfg);
        let busy = (m.ccm_busy + m.host_busy) as f64;
        println!(
            "({:>5}, {:>5})       {:>9.2}% {:>9.2}%",
            dim,
            rows,
            100.0 * m.ccm_busy as f64 / busy,
            100.0 * m.host_busy as f64 / busy
        );
    }
}

/// Fig. 5: KNN + graph component breakdowns under RP and BS.
pub fn fig5(cfg: &SimConfig) {
    header("Fig. 5: runtime breakdown (normalized to RP total), RP vs BS");
    for a in ['a', 'b', 'c', 'd', 'e'] {
        let w = workload::by_annotation(a, cfg);
        let rp = protocol::run(Protocol::Rp, &w, cfg);
        let bs = protocol::run(Protocol::Bs, &w, cfg);
        println!("({a}) {}", w.name);
        println!("    RP: {}", breakdown(&rp, rp.total));
        println!("    BS: {}", breakdown(&bs, rp.total));
    }
}

/// Fig. 7: CCM and host idle times for the Fig. 5 setups.
pub fn fig7(cfg: &SimConfig) {
    header("Fig. 7: idle times (fraction of each run's total)");
    println!(
        "{:<4} {:<6} {:>10} {:>10} {:>12}",
        "WL", "proto", "CCM idle", "Host idle", "total(us)"
    );
    for a in ['a', 'b', 'c', 'd', 'e'] {
        let w = workload::by_annotation(a, cfg);
        for p in [Protocol::Rp, Protocol::Bs] {
            let m = protocol::run(p, &w, cfg);
            println!(
                "({a})  {:<6} {:>10} {:>10} {:>12.2}",
                m.protocol,
                pct(m.frac(m.ccm_idle())),
                pct(m.frac(m.host_idle())),
                ps_to_us(m.total)
            );
        }
    }
}

/// Fig. 10: end-to-end runtime, all workloads × {RP, BS, AXLE_Int, AXLE p1/p10/p100}.
pub fn fig10(cfg: &SimConfig) {
    header("Fig. 10: normalized end-to-end runtime ratio (RP = 100%)");
    println!(
        "{:<4} {:>8} {:>8} {:>10} {:>8} {:>8} {:>8}",
        "WL", "RP", "BS", "AXLE_Int", "p1", "p10", "p100"
    );
    let mut red_rp = [Vec::new(), Vec::new(), Vec::new()];
    let mut red_bs = [Vec::new(), Vec::new(), Vec::new()];
    for a in workload::ALL_ANNOTATIONS {
        let w = workload::by_annotation(a, cfg);
        let rp = protocol::run(Protocol::Rp, &w, cfg);
        let bs = protocol::run(Protocol::Bs, &w, cfg);
        let int = protocol::run(Protocol::AxleInterrupt, &w, cfg);
        let polls = [poll_factors::P1, poll_factors::P10, poll_factors::P100];
        let axles: Vec<RunMetrics> = polls
            .iter()
            .map(|&p| {
                let c = cfg.clone().with_poll(p);
                protocol::run(Protocol::Axle, &w, &c)
            })
            .collect();
        for (i, m) in axles.iter().enumerate() {
            red_rp[i].push(1.0 - m.ratio_to(&rp));
            red_bs[i].push(1.0 - m.ratio_to(&bs));
        }
        println!(
            "({a})  {:>7.2}% {:>7.2}% {:>9.2}% {:>7.2}% {:>7.2}% {:>7.2}%",
            100.0,
            100.0 * bs.ratio_to(&rp),
            100.0 * int.ratio_to(&rp),
            100.0 * axles[0].ratio_to(&rp),
            100.0 * axles[1].ratio_to(&rp),
            100.0 * axles[2].ratio_to(&rp),
        );
    }
    println!("(j) end-to-end time-ratio reduction of AXLE:");
    for (i, lbl) in ["p1", "p10", "p100"].iter().enumerate() {
        println!(
            "    {lbl:<5} vs RP: avg {} geomean {} max {} | vs BS: avg {} geomean {} max {}",
            pct(mean(&red_rp[i])),
            pct(geomean(&red_rp[i].iter().map(|x| x.max(1e-9)).collect::<Vec<_>>())),
            pct(red_rp[i].iter().cloned().fold(f64::MIN, f64::max)),
            pct(mean(&red_bs[i])),
            pct(geomean(&red_bs[i].iter().map(|x| x.max(1e-9)).collect::<Vec<_>>())),
            pct(red_bs[i].iter().cloned().fold(f64::MIN, f64::max)),
        );
    }
}

/// Fig. 11: the LLM case under the reduced-PU hardware profile.
pub fn fig11() {
    header("Fig. 11: LLM with reduced processing units (CCM/4, host/4)");
    for (label, cfg) in [("Table III baseline", SimConfig::m2ndp()), ("reduced", SimConfig::reduced())]
    {
        let w = workload::by_annotation('h', &cfg);
        let rp = protocol::run(Protocol::Rp, &w, &cfg);
        let bs = protocol::run(Protocol::Bs, &w, &cfg);
        let axle = protocol::run(Protocol::Axle, &w, &cfg.clone().with_poll(poll_factors::P10));
        println!(
            "{label:<20} RP 100.00%  BS {:>7.2}%  AXLE(p10) {:>7.2}%",
            100.0 * bs.ratio_to(&rp),
            100.0 * axle.ratio_to(&rp)
        );
    }
}

/// Fig. 12: idle-time comparison, all workloads, p10.
pub fn fig12(cfg: &SimConfig) {
    header("Fig. 12: idle time ratios (p10), RP vs BS vs AXLE");
    println!(
        "{:<4} {:>10} {:>10} {:>10} | {:>10} {:>10} {:>10}",
        "WL", "CCM:RP", "CCM:BS", "CCM:AXLE", "Host:RP", "Host:BS", "Host:AXLE"
    );
    let c10 = cfg.clone().with_poll(poll_factors::P10);
    let mut ccm_red_rp = Vec::new();
    let mut ccm_red_bs = Vec::new();
    let mut host_red_rp = Vec::new();
    let mut host_red_bs = Vec::new();
    for a in workload::ALL_ANNOTATIONS {
        let w = workload::by_annotation(a, cfg);
        let rp = protocol::run(Protocol::Rp, &w, cfg);
        let bs = protocol::run(Protocol::Bs, &w, cfg);
        let ax = protocol::run(Protocol::Axle, &w, &c10);
        println!(
            "({a})  {:>10} {:>10} {:>10} | {:>10} {:>10} {:>10}",
            pct(rp.frac(rp.ccm_idle())),
            pct(bs.frac(bs.ccm_idle())),
            pct(ax.frac(ax.ccm_idle())),
            pct(rp.frac(rp.host_idle())),
            pct(bs.frac(bs.host_idle())),
            pct(ax.frac(ax.host_idle())),
        );
        let safe = |x: u64| (x.max(1)) as f64;
        ccm_red_rp.push(safe(rp.ccm_idle()) * ax.total as f64 / (safe(ax.ccm_idle()) * rp.total as f64));
        ccm_red_bs.push(safe(bs.ccm_idle()) * ax.total as f64 / (safe(ax.ccm_idle()) * bs.total as f64));
        host_red_rp.push(safe(rp.host_idle()) * ax.total as f64 / (safe(ax.host_idle()) * rp.total as f64));
        host_red_bs.push(safe(bs.host_idle()) * ax.total as f64 / (safe(ax.host_idle()) * bs.total as f64));
    }
    println!(
        "avg idle-ratio reduction: CCM {:.2}x (vs RP) {:.2}x (vs BS) | host {:.2}x (vs RP) {:.2}x (vs BS)",
        mean(&ccm_red_rp),
        mean(&ccm_red_bs),
        mean(&host_red_rp),
        mean(&host_red_bs)
    );
}

/// Fig. 13: host core stall time, p10 and p100.
pub fn fig13(cfg: &SimConfig) {
    header("Fig. 13: host core stall time / end-to-end runtime");
    println!(
        "{:<4} {:>10} {:>10} {:>12} {:>12}",
        "WL", "RP", "BS", "AXLE p10", "AXLE p100"
    );
    for a in workload::ALL_ANNOTATIONS {
        let w = workload::by_annotation(a, cfg);
        let rp = protocol::run(Protocol::Rp, &w, cfg);
        let bs = protocol::run(Protocol::Bs, &w, cfg);
        let a10 = protocol::run(Protocol::Axle, &w, &cfg.clone().with_poll(poll_factors::P10));
        let a100 = protocol::run(Protocol::Axle, &w, &cfg.clone().with_poll(poll_factors::P100));
        println!(
            "({a})  {:>10} {:>10} {:>12} {:>12}",
            pct(rp.frac(rp.host_stall.min(rp.total))),
            pct(bs.frac(bs.host_stall.min(bs.total))),
            pct(a10.frac(a10.host_stall.min(a10.total))),
            pct(a100.frac(a100.host_stall.min(a100.total))),
        );
    }
}

/// Fig. 14: streaming-factor sweep.
pub fn fig14(cfg: &SimConfig) {
    header("Fig. 14: end-to-end runtime vs streaming factor (normalized to SF1)");
    for a in ['a', 'd', 'i'] {
        let w = workload::by_annotation(a, cfg);
        let total_result = w.total_result_bytes() / w.iters.len() as u64;
        let base = {
            let mut c = cfg.clone();
            c.axle.streaming_factor_bytes = 32;
            protocol::run(Protocol::Axle, &w, &c)
        };
        print!("({a}) ");
        for (label, sf) in [
            ("SF1", 32u64),
            ("SF2", 64),
            ("SF8", 256),
            ("SF32", 1024),
            ("SF64", 2048),
            ("SF_25%", total_result / 4),
            ("SF_50%", total_result / 2),
            ("SF_100%", total_result),
        ] {
            let mut c = cfg.clone();
            c.axle.streaming_factor_bytes = sf.max(32);
            let m = protocol::run(Protocol::Axle, &w, &c);
            print!("{label} {:.3}  ", m.total as f64 / base.total as f64);
        }
        let rp = protocol::run(Protocol::Rp, &w, cfg);
        let bs = protocol::run(Protocol::Bs, &w, cfg);
        println!(
            "| RP {:.3} BS {:.3}",
            rp.total as f64 / base.total as f64,
            bs.total as f64 / base.total as f64
        );
    }
}

/// Fig. 14-ext (extension): fixed vs adaptive streaming factor.
///
/// The paper flags "dynamically selecting an optimal SF" as future work
/// (§V-E). The adaptive policy targets one DMA-prep period's worth of
/// production; this report compares it against the best and worst fixed
/// settings per workload.
pub fn fig14_ext(cfg: &SimConfig) {
    header("Fig. 14-ext: adaptive streaming factor vs fixed (normalized to fixed SF1)");
    println!(
        "{:<4} {:>10} {:>10} {:>10} {:>10} {:>14} {:>14}",
        "WL", "SF1", "SF64", "SF_100%", "adaptive", "SF1 batches", "adapt batches"
    );
    for a in ['a', 'b', 'd', 'e', 'i'] {
        let w = workload::by_annotation(a, cfg);
        let base = protocol::run(Protocol::Axle, &w, cfg);
        let run_sf = |sf: u64| {
            let mut c = cfg.clone();
            c.axle.streaming_factor_bytes = sf.max(32);
            protocol::run(Protocol::Axle, &w, &c)
        };
        let sf64 = run_sf(2048);
        let sf_all = run_sf(w.iters[0].result_bytes());
        let adaptive = {
            let mut c = cfg.clone();
            c.axle.sf_policy = crate::config::SfPolicy::Adaptive;
            protocol::run(Protocol::Axle, &w, &c)
        };
        println!(
            "({a})  {:>10.3} {:>10.3} {:>10.3} {:>10.3} {:>14} {:>14}",
            1.0,
            sf64.total as f64 / base.total as f64,
            sf_all.total as f64 / base.total as f64,
            adaptive.total as f64 / base.total as f64,
            base.dma_batches,
            adaptive.dma_batches,
        );
    }
}

/// Fig. 15: OoO streaming on/off × RR/FIFO.
pub fn fig15(cfg: &SimConfig) {
    header("Fig. 15: runtime without OoO streaming / with OoO (per scheduler)");
    println!("{:<4} {:>10} {:>10}", "WL", "RR", "FIFO");
    for a in ['d', 'e', 'i'] {
        let w = workload::by_annotation(a, cfg);
        let mut row = Vec::new();
        for sched in [SchedPolicy::RoundRobin, SchedPolicy::Fifo] {
            let mut on = cfg.clone();
            on.sched = sched;
            on.axle.ooo_streaming = true;
            let mut off = on.clone();
            off.axle.ooo_streaming = false;
            let m_on = protocol::run(Protocol::Axle, &workload::by_annotation(a, &on), &on);
            let m_off = protocol::run(Protocol::Axle, &workload::by_annotation(a, &off), &off);
            row.push(m_off.total as f64 / m_on.total as f64);
        }
        let _ = &w;
        println!("({a})  {:>9.2}x {:>9.2}x", row[0], row[1]);
    }
}

/// Fig. 16: DMA slot capacity sweep + back-pressure cycles.
pub fn fig16(cfg: &SimConfig) {
    header("Fig. 16: runtime and back-pressure vs DMA slot capacity");
    println!(
        "{:<4} {:>10} {:>18} {:>18} {:>18}",
        "WL", "cap=100%", "50%", "25%", "12.5%"
    );
    for a in ['a', 'd', 'h', 'i'] {
        let w = workload::by_annotation(a, cfg);
        let base = protocol::run(Protocol::Axle, &w, cfg);
        print!("({a})  {:>9.3} ", 1.0);
        for div in [2usize, 4, 8] {
            let mut c = cfg.clone();
            c.axle.dma_slot_capacity = cfg.axle.dma_slot_capacity / div;
            let m = protocol::run(Protocol::Axle, &w, &c);
            if m.deadlock {
                print!("{:>18} ", "DEADLOCK");
            } else {
                print!(
                    "{:>9.3} (bp {:>4.1}%) ",
                    m.total as f64 / base.total as f64,
                    100.0 * m.frac(m.backpressure)
                );
            }
        }
        println!();
    }
}

/// Table I echo: what each workload offloads.
pub fn table1() {
    header("Table I: offloaded functions");
    for (dom, f) in [
        ("OLAP/OLTP", "Filtering (within SELECT)"),
        ("Graph Analytics", "Edge traversal -> Vertex update"),
        ("KNN/ANN", "Vector distance calculation"),
        ("LLM Inference", "Attention block"),
        ("DLRM", "Embedding lookup -> Sparse Length Sum"),
    ] {
        println!("{dom:<18} {f}");
    }
    let _ = olap::SsbQuery::Q1_1; // referenced by the generators
}

#[cfg(test)]
mod tests {
    use super::*;

    // Smoke tests: every emitter runs without panicking on the default
    // config (output goes to the test harness's captured stdout).
    #[test]
    fn fast_reports_run() {
        let cfg = SimConfig::m2ndp();
        table1();
        table2();
        table4(&cfg);
        fig3(&cfg);
        fig4();
        fig5(&cfg);
        fig7(&cfg);
    }

    #[test]
    fn sweep_reports_run() {
        let cfg = SimConfig::m2ndp();
        fig11();
        fig14(&cfg);
        fig14_ext(&cfg);
        fig15(&cfg);
        fig16(&cfg);
    }

    #[test]
    fn fig10_and_idle_reports_run() {
        let cfg = SimConfig::m2ndp();
        fig10(&cfg);
        fig12(&cfg);
        fig13(&cfg);
    }
}

/// Run every figure/table with the default Table III config.
pub fn all() {
    let cfg = SimConfig::m2ndp();
    table1();
    table2();
    table4(&cfg);
    fig3(&cfg);
    fig4();
    fig5(&cfg);
    fig7(&cfg);
    fig10(&cfg);
    fig11();
    fig12(&cfg);
    fig13(&cfg);
    fig14(&cfg);
    fig14_ext(&cfg);
    fig15(&cfg);
    fig16(&cfg);
}
