//! Graph analytics workloads: SSSP and PageRank (Table IV d–e; Fig. 5b).
//!
//! Offload boundary (Table I, Grudon-style): the CCM performs edge
//! traversal — gathering source-vertex values from CCM-resident arrays and
//! producing per-edge contributions — while the host applies the
//! destination-side updates (segment reduction + rank/distance update).
//! Per-edge intermediate results make these the paper's data-movement-heavy
//! cases (§III-B Case #2: up to ~48% of runtime is data movement).
//!
//! This module also hosts the RMAT generator used by the numerics path
//! (runtime tests / e2e example) so timing and numerics share one graph
//! model.

use crate::config::SimConfig;
use crate::util::rng::Pcg32;
use crate::workload::cost::{cycles_time, task_time, Traffic};
use crate::workload::{CcmTask, HostTask, IterSpec, WorkloadSpec};

/// PageRank iterations simulated (fixed-point style).
pub const PR_ITERS: usize = 5;

/// Host cycles per edge contribution (segment add into the rank array).
const HOST_CYCLES_PER_EDGE: f64 = 2.0;
/// Host cycles per vertex for the damped rank update.
const HOST_CYCLES_PER_VERTEX: f64 = 4.0;
/// Host cycles per relaxation candidate (min-merge) in SSSP.
const HOST_CYCLES_PER_CAND: f64 = 3.0;

/// Bellman-Ford frontier profile: fraction of |E| traversed per round
/// (bell-shaped expansion/contraction typical of low-diameter graphs).
pub const SSSP_FRONTIER: [f64; 12] =
    [0.01, 0.03, 0.08, 0.15, 0.22, 0.20, 0.13, 0.08, 0.05, 0.03, 0.015, 0.005];

fn edge_tasks(
    cfg: &SimConfig,
    edges: usize,
    result_bytes_per_edge: u64,
    random_accesses_per_edge: u64,
    stream_bytes_per_edge: u64,
) -> (Vec<CcmTask>, Vec<usize>) {
    // Partition into 8 waves of the CCM array (load-balanced blocks).
    let target_tasks = (cfg.ccm.num_pus * 8).min(edges.max(1));
    let ept = edges.div_ceil(target_tasks);
    let mut tasks = Vec::new();
    let mut sizes = Vec::new();
    let mut done = 0usize;
    while done < edges {
        let n = ept.min(edges - done);
        let traffic = Traffic {
            stream_bytes: stream_bytes_per_edge * n as u64,
            random_accesses: random_accesses_per_edge * n as u64,
            random_access_bytes: 8, // vertex-value gather (value + aux)
        };
        // Gather/scale is ~2 FLOPs per edge — never compute-bound.
        let dur = task_time(&cfg.ccm, 2.0 * n as f64, traffic);
        tasks.push(CcmTask { dur, result_bytes: result_bytes_per_edge * n as u64 });
        sizes.push(n);
        done += n;
    }
    (tasks, sizes)
}

/// PageRank over |V| vertices, |E| edges.
pub fn pagerank(cfg: &SimConfig, vertices: usize, edges: usize) -> WorkloadSpec {
    let mut iters = Vec::with_capacity(PR_ITERS);
    for _ in 0..PR_ITERS {
        // CCM: per edge, gather (rank, 1/deg) — one 8 B random access —
        // stream the src index in and the 4 B contribution out.
        let (ccm_tasks, sizes) = edge_tasks(cfg, edges, 4, 1, 8);
        // Host: apply each block's contributions + its share of the
        // per-vertex damped update.
        let vshare = vertices as f64 / ccm_tasks.len() as f64;
        let host_tasks = sizes
            .iter()
            .enumerate()
            .map(|(i, &n)| HostTask {
                dur: cycles_time(
                    &cfg.host,
                    HOST_CYCLES_PER_EDGE * n as f64 + HOST_CYCLES_PER_VERTEX * vshare,
                ),
                deps: vec![i as u32],
            })
            .collect();
        iters.push(IterSpec { ccm_tasks, host_tasks, host_serial: false });
    }
    WorkloadSpec {
        name: format!("PageRank (V {vertices}, E {edges})"),
        annot: 'e',
        domain: "Graph Analytics",
        iters,
    }
}

/// SSSP (Bellman-Ford frontier rounds) over |V| vertices, |E| edges.
pub fn sssp(cfg: &SimConfig, vertices: usize, edges: usize) -> WorkloadSpec {
    let _ = vertices;
    let mut iters = Vec::with_capacity(SSSP_FRONTIER.len());
    for w in SSSP_FRONTIER {
        let frontier_edges = ((edges as f64) * w).ceil() as usize;
        if frontier_edges == 0 {
            continue;
        }
        // CCM: per frontier edge, gather dist[src] (random) + read edge
        // (src, dst, w: 12 B stream) + write candidate; result carries
        // (dst, cand) = 8 B per edge.
        let (ccm_tasks, sizes) = edge_tasks(cfg, frontier_edges, 8, 1, 16);
        let host_tasks = sizes
            .iter()
            .enumerate()
            .map(|(i, &n)| HostTask {
                dur: cycles_time(&cfg.host, HOST_CYCLES_PER_CAND * n as f64),
                deps: vec![i as u32],
            })
            .collect();
        iters.push(IterSpec { ccm_tasks, host_tasks, host_serial: false });
    }
    WorkloadSpec {
        name: format!("SSSP (V {vertices}, E {edges})"),
        annot: 'd',
        domain: "Graph Analytics",
        iters,
    }
}

/// A synthetic RMAT-style graph with a power-law-ish degree distribution,
/// shared by the timing model and the numerics path.
#[derive(Debug, Clone)]
pub struct SynthGraph {
    pub vertices: usize,
    pub src: Vec<u32>,
    pub dst: Vec<u32>,
    pub out_deg: Vec<u32>,
}

impl SynthGraph {
    /// RMAT(a=0.57, b=0.19, c=0.19) edge sampling.
    pub fn rmat(vertices: usize, edges: usize, seed: u64) -> Self {
        assert!(vertices.is_power_of_two(), "RMAT needs power-of-two |V|");
        let levels = vertices.trailing_zeros();
        let mut rng = Pcg32::seed_from_u64(seed);
        let mut src = Vec::with_capacity(edges);
        let mut dst = Vec::with_capacity(edges);
        let mut out_deg = vec![0u32; vertices];
        for _ in 0..edges {
            let (mut r, mut c) = (0usize, 0usize);
            for _ in 0..levels {
                let p: f64 = rng.next_f64();
                let (dr, dc) = if p < 0.57 {
                    (0, 0)
                } else if p < 0.76 {
                    (0, 1)
                } else if p < 0.95 {
                    (1, 0)
                } else {
                    (1, 1)
                };
                r = (r << 1) | dr;
                c = (c << 1) | dc;
            }
            src.push(r as u32);
            dst.push(c as u32);
            out_deg[r] += 1;
        }
        Self { vertices, src, dst, out_deg }
    }

    pub fn edges(&self) -> usize {
        self.src.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::Ps;

    #[test]
    fn pagerank_is_data_movement_heavy() {
        // §III-B Case #2: T_D should be comparable to T_C (≈ 50/48 in the
        // paper). Check the per-iteration byte/time composition.
        let cfg = SimConfig::m2ndp();
        let w = pagerank(&cfg, 299_067, 977_676);
        let it = &w.iters[0];
        let t_c: Ps = it.ccm_tasks.iter().map(|t| t.dur).sum::<Ps>() / cfg.ccm.num_pus as u64;
        let bytes = it.result_bytes();
        assert_eq!(bytes, 4 * 977_676);
        let t_d = crate::sim::transfer_ps(bytes, cfg.cxl_bw_gbps);
        let ratio = t_d as f64 / t_c as f64;
        assert!(ratio > 0.5 && ratio < 2.0, "T_D/T_C = {ratio}");
    }

    #[test]
    fn sssp_frontier_rounds_vary_in_size() {
        let cfg = SimConfig::m2ndp();
        let w = sssp(&cfg, 264_346, 733_846);
        assert_eq!(w.iters.len(), SSSP_FRONTIER.len());
        let sizes: Vec<u64> = w.iters.iter().map(|i| i.result_bytes()).collect();
        assert!(sizes.iter().max() > sizes.iter().min());
        // Total traversed ≈ Σ frontier fractions × E × 8 B.
        let total: u64 = sizes.iter().sum();
        let expect = (SSSP_FRONTIER.iter().sum::<f64>() * 733_846.0 * 8.0) as u64;
        assert!((total as f64 - expect as f64).abs() / (expect as f64) < 0.01);
    }

    #[test]
    fn rmat_structure() {
        let g = SynthGraph::rmat(1024, 8192, 7);
        assert_eq!(g.edges(), 8192);
        assert!(g.src.iter().all(|&v| (v as usize) < 1024));
        assert!(g.dst.iter().all(|&v| (v as usize) < 1024));
        // Power-law-ish: max degree well above mean (8).
        let max_deg = *g.out_deg.iter().max().unwrap();
        assert!(max_deg > 24, "max_deg={max_deg}");
        // Deterministic for equal seeds.
        let g2 = SynthGraph::rmat(1024, 8192, 7);
        assert_eq!(g.src, g2.src);
    }
}
