//! DLRM workload: Criteo-style embedding offload (Table IV i).
//!
//! Offload boundary (Table I, CLAY-style): the CCM performs embedding
//! table lookups + Sparse-Length-Sum over a 1M-row, 256-dim table resident
//! in CXL memory, streaming back one pooled vector per sample; the host
//! runs the small interaction/top MLP. DLRM is the paper's CCM-dominated
//! case (§V-A: "DLRM is dominated by CCM-side computation").

use crate::config::SimConfig;
use crate::workload::cost::{cycles_time, task_time, Traffic};
use crate::workload::{CcmTask, HostTask, IterSpec, WorkloadSpec};

#[derive(Debug, Clone, Copy)]
pub struct DlrmConfig {
    /// Embedding table rows (Table IV: 1M).
    pub table_rows: usize,
    /// Embedding dimension (Table IV: 256).
    pub dim: usize,
    /// Multi-hot lookups pooled per sample.
    pub lookups_per_sample: usize,
    /// Samples per inference batch.
    pub batch: usize,
    /// Inference batches (offload iterations).
    pub batches: usize,
    /// Host cycles per sample for the top-MLP interaction.
    pub host_cycles_per_sample: f64,
}

impl DlrmConfig {
    /// The paper's Table IV row: Criteo-style, Dim 256, 1M rows.
    pub fn paper() -> Self {
        Self {
            table_rows: 1_000_000,
            dim: 256,
            lookups_per_sample: 80,
            batch: 2048,
            batches: 4,
            host_cycles_per_sample: 300.0,
        }
    }
}

/// Build the Table IV (i) workload.
pub fn criteo(cfg: &SimConfig, d: DlrmConfig) -> WorkloadSpec {
    let row_bytes = (d.dim * 4) as u64;
    let target_tasks = (cfg.ccm.num_pus * 8).min(d.batch);
    let spt = d.batch.div_ceil(target_tasks); // samples per task
    let mut iters = Vec::with_capacity(d.batches);
    for _ in 0..d.batches {
        let mut ccm_tasks = Vec::new();
        let mut host_tasks = Vec::new();
        let mut done = 0usize;
        while done < d.batch {
            let n = spt.min(d.batch - done);
            let accesses = (n * d.lookups_per_sample) as u64;
            let traffic = Traffic {
                // Pooled output written sequentially.
                stream_bytes: n as u64 * row_bytes,
                // Each lookup is a random row read (row = dim×4 bytes).
                random_accesses: accesses,
                random_access_bytes: row_bytes,
            };
            // SLS adds dim floats per lookup.
            let flops = (accesses * d.dim as u64) as f64;
            let dur = task_time(&cfg.ccm, flops, traffic);
            ccm_tasks.push(CcmTask { dur, result_bytes: n as u64 * row_bytes });
            host_tasks.push(HostTask {
                dur: cycles_time(&cfg.host, d.host_cycles_per_sample * n as f64),
                deps: vec![(ccm_tasks.len() - 1) as u32],
            });
            done += n;
        }
        iters.push(IterSpec { ccm_tasks, host_tasks, host_serial: false });
    }
    WorkloadSpec {
        name: format!(
            "DLRM Criteo (dim {}, rows {}, batch {})",
            d.dim, d.table_rows, d.batch
        ),
        annot: 'i',
        domain: "DLRM",
        iters,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::Ps;

    #[test]
    fn ccm_dominates() {
        let cfg = SimConfig::m2ndp();
        let w = criteo(&cfg, DlrmConfig::paper());
        let it = &w.iters[0];
        let t_c: Ps = it.ccm_tasks.iter().map(|t| t.dur).sum::<Ps>() / cfg.ccm.num_pus as u64;
        let t_h: Ps = it.host_tasks.iter().map(|t| t.dur).sum::<Ps>() / cfg.host.num_pus as u64;
        let t_d = crate::sim::transfer_ps(it.result_bytes(), cfg.cxl_bw_gbps);
        assert!(t_c > 2 * t_d, "T_C {t_c} vs T_D {t_d}");
        assert!(t_c > 10 * t_h, "T_C {t_c} vs T_H {t_h}");
    }

    #[test]
    fn result_is_one_pooled_vector_per_sample() {
        let cfg = SimConfig::m2ndp();
        let d = DlrmConfig::paper();
        let w = criteo(&cfg, d);
        assert_eq!(w.iters.len(), d.batches);
        assert_eq!(w.iters[0].result_bytes(), (d.batch * d.dim * 4) as u64);
    }
}
