//! LLM inference workload: OPT-2.7B attention-block offload (Table IV h;
//! Fig. 3, Fig. 11).
//!
//! Offload boundary (Table I, NeuPIMs-style): the CCM executes the
//! attention block — LayerNormQ → QKVProj → Attention1 → Attention2 →
//! OutProj → Residual (the Fig. 3 kernel order) — over the 1K-token KV
//! cache in CXL memory; the host runs the fully-connected MLP layers.
//!
//! The batch decodes `batch` requests; each layer is one offload
//! iteration (layer l+1's attention consumes layer l's MLP output — the
//! iterative dependency of §III-C). Within a layer, each request's
//! attention is partitioned into head-group CCM tasks and its MLP is ONE
//! host task depending on all of them — the paper's "sparse data
//! dependency" that makes (h) a marginal-improvement case and the Fig. 16
//! deadlock candidate.

use crate::config::SimConfig;
use crate::workload::cost::{task_time, Traffic};
use crate::workload::{CcmTask, HostTask, IterSpec, WorkloadSpec};

#[derive(Debug, Clone, Copy)]
pub struct OptConfig {
    pub hidden: usize,
    pub heads: usize,
    pub head_dim: usize,
    pub ffn: usize,
    pub tokens: usize,
    pub layers: usize,
    /// Decode requests in flight (batched inference).
    pub batch: usize,
    /// Head-group CCM tasks per request per layer.
    pub head_groups: usize,
}

impl OptConfig {
    /// OPT-2.7B with the paper's 1K-token context.
    pub fn opt_2_7b() -> Self {
        Self {
            hidden: 2560,
            heads: 32,
            head_dim: 80,
            ffn: 10240,
            tokens: 1024,
            layers: 32,
            batch: 32,
            head_groups: 4,
        }
    }

    /// Attention-block FLOPs per request per layer (decode, 1 token).
    pub fn attn_flops(&self) -> f64 {
        let h = self.hidden as f64;
        let t = self.tokens as f64;
        let qkv = 2.0 * h * (3.0 * h); // QKVProj
        let attn = 2.0 * 2.0 * t * h; // Attention1 + Attention2
        let out = 2.0 * h * h; // OutProj
        let ln_res = 10.0 * h; // LayerNormQ + Residual
        qkv + attn + out + ln_res
    }

    /// MLP FLOPs per request per layer (fc1 + fc2).
    pub fn mlp_flops(&self) -> f64 {
        2.0 * 2.0 * self.hidden as f64 * self.ffn as f64
    }

    /// Attention weight bytes per layer (QKV + output proj, f32).
    pub fn attn_weight_bytes(&self) -> u64 {
        ((self.hidden * 3 * self.hidden + self.hidden * self.hidden) * 4) as u64
    }

    /// KV-cache bytes per request per layer.
    pub fn kv_bytes(&self) -> u64 {
        (2 * self.heads * self.tokens * self.head_dim * 4) as u64
    }

    /// MLP weight bytes per layer.
    pub fn mlp_weight_bytes(&self) -> u64 {
        (2 * self.hidden * self.ffn * 4) as u64
    }
}

/// Build the Table IV (h) workload.
pub fn opt_attention(cfg: &SimConfig, opt: OptConfig) -> WorkloadSpec {
    let tasks_per_layer = opt.batch * opt.head_groups;
    let flops_per_task = opt.attn_flops() / opt.head_groups as f64;
    // Weights stream once per layer, shared across the task partition;
    // each task additionally streams its head-group's KV panel.
    let weight_share = opt.attn_weight_bytes() / tasks_per_layer as u64;
    let kv_share = opt.kv_bytes() / opt.head_groups as u64;
    let result_bytes = (opt.hidden * 4 / opt.head_groups) as u64;

    let mut iters = Vec::with_capacity(opt.layers);
    for _ in 0..opt.layers {
        let mut ccm_tasks = Vec::with_capacity(tasks_per_layer);
        let mut host_tasks = Vec::with_capacity(opt.batch);
        for r in 0..opt.batch {
            let first = (r * opt.head_groups) as u32;
            for _ in 0..opt.head_groups {
                let dur = task_time(
                    &cfg.ccm,
                    flops_per_task,
                    Traffic {
                        stream_bytes: weight_share + kv_share,
                        ..Default::default()
                    },
                );
                ccm_tasks.push(CcmTask { dur, result_bytes });
            }
            // One MLP per request, needing ALL of its head-group results.
            let mlp_dur = task_time(
                &cfg.host,
                opt.mlp_flops(),
                Traffic {
                    stream_bytes: opt.mlp_weight_bytes() / opt.batch as u64,
                    ..Default::default()
                },
            );
            host_tasks.push(HostTask {
                dur: mlp_dur,
                deps: (first..first + opt.head_groups as u32).collect(),
            });
        }
        iters.push(IterSpec { ccm_tasks, host_tasks, host_serial: false });
    }
    WorkloadSpec {
        name: format!(
            "OPT-2.7B attention offload (batch {}, {} tokens)",
            opt.batch, opt.tokens
        ),
        annot: 'h',
        domain: "LLM Inference",
        iters,
    }
}

/// The six Fig. 3 kernels, each runnable as a standalone single-kernel
/// offload (used by the Fig. 3 duality bench).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AttnKernel {
    LayerNormQ,
    QkvProj,
    Attention1,
    Attention2,
    OutProj,
    Residual,
}

impl AttnKernel {
    pub const ALL: [AttnKernel; 6] = [
        AttnKernel::LayerNormQ,
        AttnKernel::QkvProj,
        AttnKernel::Attention1,
        AttnKernel::Attention2,
        AttnKernel::OutProj,
        AttnKernel::Residual,
    ];

    pub fn label(&self) -> &'static str {
        match self {
            AttnKernel::LayerNormQ => "LayerNormQ",
            AttnKernel::QkvProj => "QKVProj",
            AttnKernel::Attention1 => "Attention1",
            AttnKernel::Attention2 => "Attention2",
            AttnKernel::OutProj => "OutProj",
            AttnKernel::Residual => "Residual",
        }
    }

    /// Fig. 3's split: computationally heavy vs lightweight kernels.
    pub fn is_heavy(&self) -> bool {
        matches!(
            self,
            AttnKernel::QkvProj | AttnKernel::Attention1 | AttnKernel::OutProj
        )
    }

    /// (FLOPs, streamed bytes, result bytes) per kernel at OPT-2.7B / 1K
    /// tokens, decode.
    pub fn costs(&self, opt: &OptConfig) -> (f64, u64, u64) {
        let h = opt.hidden as f64;
        let t = opt.tokens as f64;
        let hb = (opt.hidden * 4) as u64;
        match self {
            AttnKernel::LayerNormQ => (8.0 * h, 2 * hb, hb),
            AttnKernel::QkvProj => {
                (2.0 * h * 3.0 * h, (opt.hidden * 3 * opt.hidden * 4) as u64, 3 * hb)
            }
            AttnKernel::Attention1 => {
                (2.0 * t * h, opt.kv_bytes() / 2, (opt.heads * opt.tokens * 4) as u64)
            }
            AttnKernel::Attention2 => (2.0 * t * h, opt.kv_bytes() / 2, hb),
            AttnKernel::OutProj => (2.0 * h * h, (opt.hidden * opt.hidden * 4) as u64, hb),
            AttnKernel::Residual => (h, 2 * hb, hb),
        }
    }
}

/// A single attention kernel as a 1-iteration workload (Fig. 3 harness).
pub fn single_kernel(cfg: &SimConfig, k: AttnKernel) -> WorkloadSpec {
    let opt = OptConfig::opt_2_7b();
    let (flops, bytes, result) = k.costs(&opt);
    let n = cfg.ccm.num_pus;
    let ccm_tasks: Vec<CcmTask> = (0..n)
        .map(|_| CcmTask {
            dur: task_time(
                &cfg.ccm,
                flops / n as f64,
                Traffic { stream_bytes: bytes / n as u64, ..Default::default() },
            ),
            result_bytes: (result / n as u64).max(4),
        })
        .collect();
    // Downstream consumer: a trivial host task that touches the result.
    let host_tasks = vec![HostTask {
        dur: crate::workload::cost::cycles_time(&cfg.host, result as f64 / 8.0),
        deps: (0..n as u32).collect(),
    }];
    WorkloadSpec {
        name: format!("OPT-2.7B kernel {}", k.label()),
        annot: 'h',
        domain: "LLM Inference",
        iters: vec![IterSpec { ccm_tasks, host_tasks, host_serial: false }],
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::Ps;

    #[test]
    fn qkvproj_is_fig3_calibrated() {
        // The QKVProj single-kernel CCM wall time should be ≈897K CCM
        // cycles (Fig. 3a) — the calibration anchor.
        let cfg = SimConfig::m2ndp();
        let w = single_kernel(&cfg, AttnKernel::QkvProj);
        let dur = w.iters[0].ccm_tasks[0].dur; // equal tasks, 1 wave
        let cycles = dur as f64 / cfg.ccm.cycle() as f64;
        assert!(
            (cycles - 897_000.0).abs() / 897_000.0 < 0.05,
            "QKVProj wall cycles = {cycles}"
        );
    }

    #[test]
    fn heavy_kernels_dwarf_light_ones() {
        let cfg = SimConfig::m2ndp();
        let dur = |k: AttnKernel| -> Ps {
            single_kernel(&cfg, k).iters[0].ccm_tasks[0].dur
        };
        assert!(dur(AttnKernel::QkvProj) > 20 * dur(AttnKernel::Residual));
        assert!(dur(AttnKernel::OutProj) > 10 * dur(AttnKernel::LayerNormQ));
    }

    #[test]
    fn workload_dependency_shape() {
        let cfg = SimConfig::m2ndp();
        let opt = OptConfig::opt_2_7b();
        let w = opt_attention(&cfg, opt);
        assert_eq!(w.iters.len(), opt.layers);
        let it = &w.iters[0];
        assert_eq!(it.ccm_tasks.len(), opt.batch * opt.head_groups);
        assert_eq!(it.host_tasks.len(), opt.batch);
        // Request r depends exactly on its own head-group tasks.
        for (r, h) in it.host_tasks.iter().enumerate() {
            let first = (r * opt.head_groups) as u32;
            assert_eq!(h.deps, (first..first + opt.head_groups as u32).collect::<Vec<_>>());
        }
    }

    #[test]
    fn intermediate_results_are_small() {
        // §V-B: attention output is [1, hidden] — result sparsity.
        let cfg = SimConfig::m2ndp();
        let w = opt_attention(&cfg, OptConfig::opt_2_7b());
        let per_request: u64 = w.iters[0].ccm_tasks[..4].iter().map(|t| t.result_bytes).sum();
        assert_eq!(per_request, 2560 * 4);
    }
}
