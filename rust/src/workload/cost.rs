//! Analytic task cost model: FLOPs + byte traffic → per-PU task time.
//!
//! The paper's μthreaded PUs (16 μthreads on CCM, 2 on host) interleave
//! execution to hide memory latency, so a PU's achievable throughput is
//! the min of its issue rate and its share of DRAM bandwidth — the classic
//! roofline, evaluated per task. The [`PuPool`](crate::sim::PuPool) then
//! models PU-level parallelism and queueing on top.
//!
//! Calibration anchors (DESIGN.md §Timing model):
//! - CCM `flops_per_cycle = 2.75` reproduces Fig. 3(a)'s ≈897K-cycle
//!   QKVProj for OPT-2.7B on 16 PUs.
//! - Bandwidth derates (0.85 stream / 0.35 random) are standard DDR5
//!   sustained fractions.

use crate::config::PuConfig;
use crate::sim::{secs_to_ps, Ps};

/// Byte traffic of one task against its side's DRAM.
#[derive(Debug, Clone, Copy, Default)]
pub struct Traffic {
    /// Sequentially streamed bytes (reads + writes).
    pub stream_bytes: u64,
    /// Random accesses (line-granularity) and their payload size.
    pub random_accesses: u64,
    pub random_access_bytes: u64,
}

/// Time for one task on ONE processing unit of `pu`, given `flops` of
/// compute and `traffic` of memory work, with the DRAM shared equally
/// across the array's PUs (steady-state share).
pub fn task_time(pu: &PuConfig, flops: f64, traffic: Traffic) -> Ps {
    let compute_s = flops / (pu.freq_ghz * pu.flops_per_cycle * 1e9);
    let dram = pu.dram();
    let share = pu.num_pus as f64;
    let stream_s = traffic.stream_bytes as f64 / (dram.stream_gbps() * 1e9 / share);
    let lines = traffic.random_accesses
        * traffic.random_access_bytes.div_ceil(crate::mem::LINE_BYTES).max(1);
    let random_s = (lines * crate::mem::LINE_BYTES) as f64
        / (dram.peak_gbps * dram.random_eff * 1e9 / share);
    // μthread interleaving overlaps compute with memory: the task is bound
    // by whichever dominates, not their sum.
    let t = compute_s.max(stream_s + random_s);
    secs_to_ps(t).max(1)
}

/// Time for `cycles` of straight-line work on one PU (host-side scalar
/// task segments such as top-k heap updates, hash probes, rank updates).
pub fn cycles_time(pu: &PuConfig, cycles: f64) -> Ps {
    secs_to_ps(cycles / (pu.freq_ghz * 1e9)).max(1)
}

/// Deterministic per-task duration jitter modelling μthread interleave
/// and bank-conflict variance: multiplier in `[1 - j/2, 1 + j/2]` from a
/// splitmix64 hash of `(seed, id)`. Same seed ⇒ same timeline.
pub fn jitter(dur: Ps, amplitude: f64, seed: u64, id: u64) -> Ps {
    if amplitude <= 0.0 {
        return dur;
    }
    let mut z = seed ^ id.wrapping_mul(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^= z >> 31;
    let unit = (z >> 11) as f64 / (1u64 << 53) as f64; // [0, 1)
    let mult = 1.0 + amplitude * (unit - 0.5);
    ((dur as f64 * mult).round() as Ps).max(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SimConfig;
    use crate::sim::US;

    fn ccm() -> PuConfig {
        SimConfig::m2ndp().ccm
    }

    #[test]
    fn compute_bound_task() {
        // 5.5 MFLOP on one CCM PU @ 5.5 GFLOP/s = 1 ms.
        let t = task_time(&ccm(), 5.5e6, Traffic::default());
        assert_eq!(t, 1000 * US);
    }

    #[test]
    fn memory_bound_task_uses_bandwidth_share() {
        // Stream 32 MB with no compute: share = 614.4*0.85/16 ≈ 32.6 GB/s
        // per PU → ~0.98 ms.
        let t = task_time(
            &ccm(),
            0.0,
            Traffic { stream_bytes: 32 << 20, ..Default::default() },
        );
        let expect_s = (32u64 << 20) as f64 / (614.4e9 * 0.85 / 16.0);
        let expect = secs_to_ps(expect_s);
        let diff = (t as i64 - expect as i64).abs();
        assert!(diff < 1000, "t={t} expect={expect}");
    }

    #[test]
    fn roofline_takes_max_not_sum() {
        let tr = Traffic { stream_bytes: 1 << 20, ..Default::default() };
        let c = task_time(&ccm(), 1e9, Traffic::default());
        let m = task_time(&ccm(), 0.0, tr);
        let both = task_time(&ccm(), 1e9, tr);
        assert_eq!(both, c.max(m));
    }

    #[test]
    fn qkvproj_calibration_matches_fig3() {
        // OPT-2.7B QKVProj: 2*2560*7680 FLOPs across the 16-PU array should
        // be ≈897K CCM cycles (Fig. 3a). Whole-array time = per-task time
        // when the work is split into 16 equal tasks.
        let cfg = SimConfig::m2ndp();
        let flops_total = 2.0 * 2560.0 * 7680.0;
        let per_pu = flops_total / 16.0;
        let t = task_time(&cfg.ccm, per_pu, Traffic::default());
        let cycles = t as f64 / cfg.ccm.cycle() as f64;
        assert!((cycles - 897_000.0).abs() / 897_000.0 < 0.02, "cycles={cycles}");
    }

    #[test]
    fn jitter_is_deterministic_and_bounded() {
        for id in 0..1000u64 {
            let a = jitter(1_000_000, 0.2, 42, id);
            let b = jitter(1_000_000, 0.2, 42, id);
            assert_eq!(a, b);
            assert!(a >= 900_000 && a <= 1_100_000, "a={a}");
        }
        // Different seeds give different timelines.
        assert_ne!(jitter(1_000_000, 0.2, 1, 7), jitter(1_000_000, 0.2, 2, 7));
    }

    #[test]
    fn zero_jitter_identity() {
        assert_eq!(jitter(12345, 0.0, 9, 9), 12345);
    }
}
