//! Workload generators for the nine Table IV benchmarks.
//!
//! A workload is compiled into a [`WorkloadSpec`]: a sequence of offload
//! **iterations** (the paper's iterative-kernel structure, §III-C — the
//! next iteration launches only after the previous iteration's host tasks
//! complete). Each iteration holds the CCM task partition produced by the
//! CCM scheduler (one fixed-size input slice per task, §IV-B) and the host
//! downstream tasks with their data dependencies on CCM task results.
//!
//! Task durations come from the analytic cost model in [`cost`]: FLOP and
//! byte counts through the Table III hardware parameters. The *numerics*
//! of every offloaded function are executed separately through the AOT
//! artifacts (see `runtime`); the spec here is the timing skeleton.

pub mod cost;
pub mod dlrm;
pub mod graph;
pub mod knn;
pub mod llm;
pub mod olap;

use crate::config::SimConfig;
use crate::sim::Ps;

/// One CCM task: a scheduler-partitioned slice of the offloaded kernel.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CcmTask {
    /// Execution time on one CCM PU (μthread-interleaved throughput).
    pub dur: Ps,
    /// Result bytes this task back-streams / the host loads.
    pub result_bytes: u64,
}

/// One host downstream task.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HostTask {
    /// Execution time on one host PU.
    pub dur: Ps,
    /// Indices (within the same iteration) of the CCM tasks whose results
    /// this task consumes. LLM's sparse dependency is many-to-one here.
    pub deps: Vec<u32>,
}

/// One offload iteration.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct IterSpec {
    pub ccm_tasks: Vec<CcmTask>,
    pub host_tasks: Vec<HostTask>,
    /// If true, host tasks execute on a single PU in order (inherently
    /// sequential consumers such as KNN's top-k heap merge).
    pub host_serial: bool,
}

impl IterSpec {
    pub fn result_bytes(&self) -> u64 {
        self.ccm_tasks.iter().map(|t| t.result_bytes).sum()
    }
}

/// A full workload: Table IV row compiled against a [`SimConfig`].
/// `PartialEq` compares the full timing skeleton (every task duration,
/// result size and dependency) — what the fingerprint guard test uses to
/// prove cache-key completeness.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WorkloadSpec {
    pub name: String,
    /// Table IV annotation, 'a'..='i'.
    pub annot: char,
    pub domain: &'static str,
    pub iters: Vec<IterSpec>,
}

impl WorkloadSpec {
    pub fn total_ccm_tasks(&self) -> usize {
        self.iters.iter().map(|i| i.ccm_tasks.len()).sum()
    }

    pub fn total_host_tasks(&self) -> usize {
        self.iters.iter().map(|i| i.host_tasks.len()).sum()
    }

    pub fn total_result_bytes(&self) -> u64 {
        self.iters.iter().map(|i| i.result_bytes()).sum()
    }

    /// Sanity-check the dependency structure (host deps in range, every
    /// CCM result consumed by at most the iteration's host tasks).
    pub fn validate(&self) -> Result<(), String> {
        for (ii, it) in self.iters.iter().enumerate() {
            if it.ccm_tasks.is_empty() {
                return Err(format!("iteration {ii} has no CCM tasks"));
            }
            for (hi, h) in it.host_tasks.iter().enumerate() {
                if h.deps.is_empty() {
                    return Err(format!("iter {ii} host task {hi} has no deps"));
                }
                for &d in &h.deps {
                    if d as usize >= it.ccm_tasks.len() {
                        return Err(format!(
                            "iter {ii} host task {hi} dep {d} out of range ({} ccm tasks)",
                            it.ccm_tasks.len()
                        ));
                    }
                }
            }
        }
        Ok(())
    }
}

/// Build the Table IV workload for annotation `annot` under `cfg`.
pub fn by_annotation(annot: char, cfg: &SimConfig) -> WorkloadSpec {
    match annot {
        'a' => knn::generate(cfg, 2048, 128),
        'b' => knn::generate(cfg, 1024, 256),
        'c' => knn::generate(cfg, 512, 512),
        'd' => graph::sssp(cfg, 264_346, 733_846),
        'e' => graph::pagerank(cfg, 299_067, 977_676),
        'f' => olap::ssb_q1(cfg, olap::SsbQuery::Q1_1),
        'g' => olap::ssb_q1(cfg, olap::SsbQuery::Q1_2),
        'h' => llm::opt_attention(cfg, llm::OptConfig::opt_2_7b()),
        'i' => dlrm::criteo(cfg, dlrm::DlrmConfig::paper()),
        _ => panic!("unknown workload annotation {annot:?} (expected 'a'..='i')"),
    }
}

/// All Table IV annotations in order.
pub const ALL_ANNOTATIONS: [char; 9] = ['a', 'b', 'c', 'd', 'e', 'f', 'g', 'h', 'i'];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_workloads_generate_and_validate() {
        let cfg = SimConfig::m2ndp();
        for a in ALL_ANNOTATIONS {
            let w = by_annotation(a, &cfg);
            assert_eq!(w.annot, a);
            w.validate().unwrap_or_else(|e| panic!("workload {a}: {e}"));
            assert!(w.total_ccm_tasks() > 0);
            assert!(w.total_result_bytes() > 0);
        }
    }

    #[test]
    fn validate_catches_bad_deps() {
        let w = WorkloadSpec {
            name: "bad".into(),
            annot: 'x',
            domain: "test",
            iters: vec![IterSpec {
                ccm_tasks: vec![CcmTask { dur: 1, result_bytes: 4 }],
                host_tasks: vec![HostTask { dur: 1, deps: vec![7] }],
                host_serial: false,
            }],
        };
        assert!(w.validate().is_err());
    }

    #[test]
    fn llm_has_sparse_dependencies() {
        let cfg = SimConfig::m2ndp();
        let w = by_annotation('h', &cfg);
        // Host tasks are far fewer than CCM tasks (§V-B result sparsity).
        assert!(w.total_host_tasks() * 2 <= w.total_ccm_tasks());
        let it = &w.iters[0];
        assert!(it.host_tasks[0].deps.len() > 1, "LLM host tasks need many CCM results");
    }
}
