//! VectorDB / KNN workload (Table IV a–c; Fig. 4, Fig. 5a).
//!
//! Offload boundary (Table I): the CCM computes per-row vector distances
//! (MAC PFLs streaming the row database from CCM-local DRAM); the host
//! receives one 4-byte float per row and selects the top-K — an
//! inherently sequential heap merge, so host tasks run serially (§III-B
//! Case #1: as dimensionality shrinks and rows grow, KNN becomes
//! host-processing-intensive).

use crate::config::SimConfig;
use crate::sim::Ps;
use crate::workload::cost::{cycles_time, task_time, Traffic};
use crate::workload::{CcmTask, HostTask, IterSpec, WorkloadSpec};

/// Queries per run: each query is one offload iteration (iterations are
/// dependent — the application issues the next query's offload after
/// consuming the previous results, §III-C).
pub const QUERIES: usize = 16;

/// Top-K selection size.
pub const K: usize = 16;

/// Host cycles per distance value for streaming top-K maintenance
/// (load + compare + branchy heap sift on hit, K=16). Calibrated against
/// the paper's host shares: ≈30% of (a)'s runtime and up to ~65% for
/// host-heavy shapes (Fig. 4b, Fig. 5a).
pub const TOPK_CYCLES_PER_ELEM: f64 = 100.0;

/// Build the KNN workload for a `dim`-dimensional database of `rows` rows.
pub fn generate(cfg: &SimConfig, dim: usize, rows: usize) -> WorkloadSpec {
    generate_queries(cfg, dim, rows, QUERIES)
}

/// As [`generate`] with an explicit query count (used by Fig. 4's sweep).
pub fn generate_queries(
    cfg: &SimConfig,
    dim: usize,
    rows: usize,
    queries: usize,
) -> WorkloadSpec {
    // CCM scheduler partition: spread rows across 2 waves of the PU array,
    // at least 4 rows per task so a task is a meaningful μthread batch.
    let target_tasks = (cfg.ccm.num_pus * 2).min(rows / 4).max(1);
    let rows_per_task = rows.div_ceil(target_tasks);
    let mut iters = Vec::with_capacity(queries);
    for _ in 0..queries {
        let mut ccm_tasks = Vec::new();
        let mut host_tasks = Vec::new();
        let mut done = 0usize;
        while done < rows {
            let rpt = rows_per_task.min(rows - done);
            // 3 FLOPs per (row, dim) element: sub, mul, add (MAC form).
            let flops = 3.0 * dim as f64 * rpt as f64;
            let traffic = Traffic {
                stream_bytes: (rpt * dim * 4) as u64, // row data streamed
                ..Default::default()
            };
            let dur = task_time(&cfg.ccm, flops, traffic);
            ccm_tasks.push(CcmTask { dur, result_bytes: (rpt * 4) as u64 });
            // Host consumes this chunk's distances into the running top-K.
            let hdur: Ps = cycles_time(&cfg.host, TOPK_CYCLES_PER_ELEM * rpt as f64);
            host_tasks.push(HostTask { dur: hdur, deps: vec![(ccm_tasks.len() - 1) as u32] });
            done += rpt;
        }
        iters.push(IterSpec { ccm_tasks, host_tasks, host_serial: true });
    }
    WorkloadSpec {
        name: format!("KNN (Dim {dim}, Rows {rows})"),
        annot: match (dim, rows) {
            (2048, 128) => 'a',
            (1024, 256) => 'b',
            (512, 512) => 'c',
            _ => '?',
        },
        domain: "VectorDB",
        iters,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_configs_have_expected_structure() {
        let cfg = SimConfig::m2ndp();
        let w = generate(&cfg, 2048, 128);
        assert_eq!(w.annot, 'a');
        assert_eq!(w.iters.len(), QUERIES);
        // Every query moves rows*4 bytes of distances.
        assert_eq!(w.iters[0].result_bytes(), 128 * 4);
        w.validate().unwrap();
    }

    #[test]
    fn host_share_grows_as_dim_shrinks() {
        // §III-B Case #1: (512, 512) is more host-heavy than (2048, 128).
        let cfg = SimConfig::m2ndp();
        let ratio = |dim, rows| {
            let w = generate(&cfg, dim, rows);
            let it = &w.iters[0];
            let ccm: Ps = it.ccm_tasks.iter().map(|t| t.dur).sum();
            let host: Ps = it.host_tasks.iter().map(|t| t.dur).sum();
            host as f64 / ccm as f64
        };
        assert!(ratio(512, 512) > 2.0 * ratio(2048, 128));
    }

    #[test]
    fn host_tasks_are_serial_and_one_to_one() {
        let cfg = SimConfig::m2ndp();
        let w = generate(&cfg, 1024, 256);
        let it = &w.iters[0];
        assert!(it.host_serial);
        assert_eq!(it.ccm_tasks.len(), it.host_tasks.len());
        for (i, h) in it.host_tasks.iter().enumerate() {
            assert_eq!(h.deps, vec![i as u32]);
        }
    }
}
