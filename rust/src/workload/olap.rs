//! OLAP workload: Star Schema Benchmark Q1.1 / Q1.2 (Table IV f–g).
//!
//! Offload boundary (Table I, M²NDP-style): the CCM scans the lineorder
//! discount/quantity columns resident in CXL memory and produces boolean
//! marks (CMP PFLs); the host runs the rest of the query — predicate-mark
//! consumption, revenue aggregation and the remaining operators — which
//! dominates runtime (§V-A: "OLAP ... dominated by host-side execution";
//! Fig. 10f shows ≈76% host share under BS).

use crate::config::SimConfig;
use crate::workload::cost::{cycles_time, task_time, Traffic};
use crate::workload::{CcmTask, HostTask, IterSpec, WorkloadSpec};

/// Lineorder rows scanned (SF1 is ~6M rows; we keep the paper's
/// simulation-constrained scale).
pub const LINEORDER_ROWS: usize = 6_001_171;

/// Query repetitions (the app's offload iterations).
pub const QUERY_RUNS: usize = 2;

/// Host cycles per scanned row for downstream operators (mark test, date
/// join probe, aggregation bookkeeping).
const HOST_CYCLES_PER_ROW: f64 = 12.0;
/// Extra host cycles per *selected* row (revenue multiply-accumulate +
/// group bookkeeping).
const HOST_CYCLES_PER_SELECTED: f64 = 30.0;
/// CCM predicate ops per row (two range compares + AND + mark store).
const CCM_FLOPS_PER_ROW: f64 = 4.0;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[allow(non_camel_case_types)]
pub enum SsbQuery {
    Q1_1,
    Q1_2,
}

impl SsbQuery {
    /// Combined selectivity of the Q1 predicates [30].
    pub fn selectivity(&self) -> f64 {
        match self {
            // d_year = 1993 (1/7) × discount 1..3 (3/11) × quantity < 25 (24/50)
            SsbQuery::Q1_1 => (1.0 / 7.0) * (3.0 / 11.0) * (24.0 / 50.0),
            // d_yearmonth (1/84) × discount 4..6 (3/11) × quantity 26..35 (10/50)
            SsbQuery::Q1_2 => (1.0 / 84.0) * (3.0 / 11.0) * (10.0 / 50.0),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            SsbQuery::Q1_1 => "Q1_1",
            SsbQuery::Q1_2 => "Q1_2",
        }
    }

    /// Inclusive [lo, hi] bounds on (discount, quantity) — the columns the
    /// CCM filter kernel scans (the date predicate folds into row
    /// pre-selection, see `model.ssb_q1_ccm`).
    pub fn bounds(&self) -> ([f32; 2], [f32; 2]) {
        match self {
            SsbQuery::Q1_1 => ([1.0, 3.0], [1.0, 24.0]),
            SsbQuery::Q1_2 => ([4.0, 6.0], [26.0, 35.0]),
        }
    }
}

/// Build the SSB Q1 workload.
pub fn ssb_q1(cfg: &SimConfig, q: SsbQuery) -> WorkloadSpec {
    ssb_q1_rows(cfg, q, LINEORDER_ROWS)
}

/// As [`ssb_q1`] with an explicit row count (scaling studies).
pub fn ssb_q1_rows(cfg: &SimConfig, q: SsbQuery, rows: usize) -> WorkloadSpec {
    let sel = q.selectivity();
    let target_tasks = (cfg.ccm.num_pus * 8).min(rows.max(1));
    let rpt = rows.div_ceil(target_tasks);
    let mut iters = Vec::with_capacity(QUERY_RUNS);
    for _ in 0..QUERY_RUNS {
        let mut ccm_tasks = Vec::new();
        let mut host_tasks = Vec::new();
        let mut done = 0usize;
        while done < rows {
            let n = rpt.min(rows - done);
            // CCM: stream both predicate columns + write the mark bitmap.
            let traffic = Traffic {
                stream_bytes: (n * 8) as u64 + (n as u64).div_ceil(8),
                ..Default::default()
            };
            let dur = task_time(&cfg.ccm, CCM_FLOPS_PER_ROW * n as f64, traffic);
            // Result: this block's mark bitmap.
            ccm_tasks.push(CcmTask { dur, result_bytes: (n as u64).div_ceil(8) });
            let selected = sel * n as f64;
            host_tasks.push(HostTask {
                dur: cycles_time(
                    &cfg.host,
                    HOST_CYCLES_PER_ROW * n as f64 + HOST_CYCLES_PER_SELECTED * selected,
                ),
                deps: vec![(ccm_tasks.len() - 1) as u32],
            });
            done += n;
        }
        iters.push(IterSpec { ccm_tasks, host_tasks, host_serial: false });
    }
    WorkloadSpec {
        name: format!("SSB {} (rows {rows})", q.label()),
        annot: match q {
            SsbQuery::Q1_1 => 'f',
            SsbQuery::Q1_2 => 'g',
        },
        domain: "OLAP",
        iters,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::Ps;

    #[test]
    fn host_dominates_ccm() {
        // Fig. 10(f): host ≈ 76%, CCM ≈ 22% under BS — host should be
        // roughly 3× the CCM time.
        let cfg = SimConfig::m2ndp();
        let w = ssb_q1(&cfg, SsbQuery::Q1_1);
        let it = &w.iters[0];
        let t_c: Ps = it.ccm_tasks.iter().map(|t| t.dur).sum::<Ps>() / cfg.ccm.num_pus as u64;
        let t_h: Ps = it.host_tasks.iter().map(|t| t.dur).sum::<Ps>() / cfg.host.num_pus as u64;
        let ratio = t_h as f64 / t_c as f64;
        assert!(ratio > 2.0 && ratio < 6.0, "T_H/T_C = {ratio}");
    }

    #[test]
    fn marks_are_bitmap_sized() {
        let cfg = SimConfig::m2ndp();
        let w = ssb_q1(&cfg, SsbQuery::Q1_1);
        // Total back-streamed bytes ≈ rows/8 per query run.
        let per_run = w.iters[0].result_bytes();
        let expect = (LINEORDER_ROWS as u64).div_ceil(8);
        assert!((per_run as i64 - expect as i64).unsigned_abs() < 1024);
    }

    #[test]
    fn q1_2_is_more_selective() {
        assert!(SsbQuery::Q1_2.selectivity() < SsbQuery::Q1_1.selectivity() / 10.0);
    }

    #[test]
    fn bounds_match_query_definitions() {
        let (d, q) = SsbQuery::Q1_1.bounds();
        assert_eq!(d, [1.0, 3.0]);
        assert_eq!(q, [1.0, 24.0]);
    }
}
