//! PJRT runtime: load and execute the AOT-compiled JAX/Pallas artifacts.
//!
//! The build-time Python pipeline (`python/compile/aot.py`) lowers every
//! workload's CCM half and host half to **HLO text** under `artifacts/`,
//! with a `manifest.json` describing shapes. This module wraps the `xla`
//! crate's PJRT CPU client to compile and execute those artifacts from
//! Rust — the offloaded functions' real numerics, with Python never on
//! the execution path.
//!
//! HLO *text* (not serialized `HloModuleProto`) is the interchange format:
//! jax ≥ 0.5 emits protos with 64-bit instruction ids that xla_extension
//! 0.5.1 rejects; the text parser reassigns ids (see aot.py docstring and
//! /opt/xla-example/README.md).

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::util::json::Json;

/// Shape + dtype of one artifact input/output.
#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(j: &Json) -> Result<Self> {
        let shape = j
            .get("shape")
            .as_arr()
            .ok_or_else(|| anyhow!("tensor spec missing shape"))?
            .iter()
            .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim")))
            .collect::<Result<Vec<_>>>()?;
        let dtype = j
            .get("dtype")
            .as_str()
            .ok_or_else(|| anyhow!("tensor spec missing dtype"))?
            .to_string();
        Ok(Self { shape, dtype })
    }
}

/// One manifest entry (see aot.py).
#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    pub meta: Json,
    pub sha256: String,
}

impl ArtifactEntry {
    fn from_json(j: &Json) -> Result<Self> {
        let specs = |key: &str| -> Result<Vec<TensorSpec>> {
            j.get(key)
                .as_arr()
                .ok_or_else(|| anyhow!("manifest entry missing {key}"))?
                .iter()
                .map(TensorSpec::from_json)
                .collect()
        };
        Ok(Self {
            file: j
                .get("file")
                .as_str()
                .ok_or_else(|| anyhow!("manifest entry missing file"))?
                .to_string(),
            inputs: specs("inputs")?,
            outputs: specs("outputs")?,
            meta: j.get("meta").clone(),
            sha256: j.get("sha256").as_str().unwrap_or("").to_string(),
        })
    }
}

/// The artifact registry + PJRT client + compiled-executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: HashMap<String, ArtifactEntry>,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Runtime {
    /// Open `dir` (default `artifacts/`), parse `manifest.json`, create
    /// the PJRT CPU client.
    pub fn new(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {manifest_path:?}; run `make artifacts` first"))?;
        let doc = Json::parse(&text).context("parsing manifest.json")?;
        let manifest: HashMap<String, ArtifactEntry> = doc
            .as_obj()
            .ok_or_else(|| anyhow!("manifest.json is not an object"))?
            .iter()
            .map(|(k, v)| Ok((k.clone(), ArtifactEntry::from_json(v)?)))
            .collect::<Result<_>>()?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT CPU client: {e:?}"))?;
        Ok(Self { client, dir, manifest, cache: HashMap::new() })
    }

    /// Artifact names available (sorted).
    pub fn names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.manifest.keys().map(|s| s.as_str()).collect();
        v.sort();
        v
    }

    pub fn entry(&self, name: &str) -> Result<&ArtifactEntry> {
        self.manifest.get(name).ok_or_else(|| anyhow!("unknown artifact {name:?}"))
    }

    /// Compile (and cache) the named artifact.
    pub fn load(&mut self, name: &str) -> Result<()> {
        if self.cache.contains_key(name) {
            return Ok(());
        }
        let entry = self.entry(name)?.clone();
        let path = self.dir.join(&entry.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parsing HLO text {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
        self.cache.insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute the named artifact on `inputs`; returns the tuple elements
    /// as literals (aot.py lowers with `return_tuple=True`).
    pub fn execute(&mut self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        self.load(name)?;
        let entry = self.entry(name)?;
        if inputs.len() != entry.inputs.len() {
            return Err(anyhow!(
                "{name}: got {} inputs, manifest expects {}",
                inputs.len(),
                entry.inputs.len()
            ));
        }
        let exe = self.cache.get(name).expect("loaded above");
        let result = exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow!("executing {name}: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching {name} result: {e:?}"))?;
        result.to_tuple().map_err(|e| anyhow!("untupling {name} result: {e:?}"))
    }

    /// Execute with f32 slices in / f32 vectors out (convenience for the
    /// all-f32 artifacts).
    pub fn execute_f32(&mut self, name: &str, inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        let entry = self.entry(name)?.clone();
        let lits = inputs
            .iter()
            .zip(&entry.inputs)
            .map(|(data, spec)| literal_f32(data, &spec.shape))
            .collect::<Result<Vec<_>>>()?;
        let out = self.execute(name, &lits)?;
        out.iter()
            .map(|l| {
                // Non-f32 outputs (e.g. top-k's i32 indices) convert to
                // f32 for the uniform convenience signature.
                let l32 = l
                    .convert(xla::PrimitiveType::F32)
                    .map_err(|e| anyhow!("output convert: {e:?}"))?;
                l32.to_vec::<f32>().map_err(|e| anyhow!("output to_vec: {e:?}"))
            })
            .collect()
    }
}

/// Build an f32 literal of `shape` from a flat slice.
pub fn literal_f32(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    let n: usize = shape.iter().product();
    if data.len() != n {
        return Err(anyhow!("literal_f32: {} elements for shape {shape:?}", data.len()));
    }
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(data)
        .reshape(&dims)
        .map_err(|e| anyhow!("reshape {shape:?}: {e:?}"))
}

/// Build an i32 literal of `shape` from a flat slice.
pub fn literal_i32(data: &[i32], shape: &[usize]) -> Result<xla::Literal> {
    let n: usize = shape.iter().product();
    if data.len() != n {
        return Err(anyhow!("literal_i32: {} elements for shape {shape:?}", data.len()));
    }
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(data)
        .reshape(&dims)
        .map_err(|e| anyhow!("reshape {shape:?}: {e:?}"))
}

/// Deterministic pseudo-random f32 in [-1, 1) (numerics test inputs).
pub fn prand_f32(n: usize, seed: u64) -> Vec<f32> {
    let mut z = seed.wrapping_mul(0x9E37_79B9_7F4A_7C15) | 1;
    (0..n)
        .map(|_| {
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z ^= z >> 27;
            ((z >> 11) as f64 / (1u64 << 53) as f64 * 2.0 - 1.0) as f32
        })
        .collect()
}

/// Deterministic pseudo-random i32 in [0, bound) (index inputs).
pub fn prand_i32(n: usize, bound: i32, seed: u64) -> Vec<i32> {
    let mut z = seed.wrapping_mul(0xD1B5_4A32_D192_ED03) | 1;
    (0..n)
        .map(|_| {
            z = (z ^ (z >> 33)).wrapping_mul(0xFF51_AFD7_ED55_8CCD);
            z ^= z >> 29;
            ((z >> 16) % bound as u64) as i32
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> Option<PathBuf> {
        let d = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        d.join("manifest.json").exists().then_some(d)
    }

    #[test]
    fn manifest_loads_if_built() {
        let Some(dir) = artifacts_dir() else { return };
        let rt = Runtime::new(dir).unwrap();
        assert!(rt.names().contains(&"knn_a_ccm"));
        let e = rt.entry("knn_a_ccm").unwrap();
        assert_eq!(e.inputs[0].shape, vec![2048]);
    }

    #[test]
    fn knn_artifact_executes_with_correct_numerics() {
        let Some(dir) = artifacts_dir() else { return };
        let mut rt = Runtime::new(dir).unwrap();
        let (dim, rows) = (2048usize, 128usize);
        let q = prand_f32(dim, 1);
        let db = prand_f32(rows * dim, 2);
        let out = rt.execute_f32("knn_a_ccm", &[&q, &db]).unwrap();
        assert_eq!(out.len(), 1);
        let dists = &out[0];
        assert_eq!(dists.len(), rows);
        // Verify against a direct Rust computation.
        for r in 0..rows {
            let want: f32 = (0..dim)
                .map(|j| {
                    let d = db[r * dim + j] - q[j];
                    d * d
                })
                .sum();
            let got = dists[r];
            assert!(
                (got - want).abs() / want.max(1.0) < 1e-3,
                "row {r}: got {got}, want {want}"
            );
        }
    }

    #[test]
    fn prand_is_deterministic() {
        assert_eq!(prand_f32(16, 3), prand_f32(16, 3));
        assert_ne!(prand_f32(16, 3), prand_f32(16, 4));
        assert!(prand_i32(100, 50, 1).iter().all(|&x| (0..50).contains(&x)));
    }
}
