//! Processing-unit pool: k parallel servers with earliest-free dispatch.
//!
//! Models the paper's PU arrays (host: 32 PUs × 2 μthreads; CCM: 16 PUs ×
//! 16 μthreads, Table III). μthread interleaving hides memory latency
//! *within* a PU, so a task's duration already reflects achievable per-PU
//! throughput (see `workload::cost`); the pool models only the PU-level
//! parallelism and queueing.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use super::{BusyTracker, Ps};

/// One PU occupancy interval `[start, end)`, recorded when tracing is
/// enabled — the compute-side analogue of [`crate::cxl::WireMsg`].
///
/// Traces feed the topology layer's CCM PU-pool sharing
/// ([`crate::topo::fabric::arbitrate_pus`]): a tenant's solo-run lease
/// busy windows are replayed against co-located tenants' windows on one
/// shared pool to compute compute-contention delay, exactly the way wire
/// traces are replayed against a shared link.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PuSpan {
    /// Time the PU started executing the task (post any queueing).
    pub start: Ps,
    /// Time the PU freed up.
    pub end: Ps,
}

impl PuSpan {
    /// Occupancy duration.
    #[inline]
    pub fn dur(&self) -> Ps {
        self.end - self.start
    }
}

/// A pool of identical processing units.
#[derive(Debug)]
pub struct PuPool {
    free_at: BinaryHeap<Reverse<Ps>>,
    n: usize,
    busy: BusyTracker,
    last_dispatch_ready: Ps,
    /// Optional occupancy trace (`None` ⇒ zero overhead). Only nonzero-
    /// duration dispatches are recorded, mirroring `Link`'s data-bearing
    /// filter.
    trace: Option<Vec<PuSpan>>,
}

impl PuPool {
    /// Create a pool with `n` processing units, all free at t=0.
    pub fn new(n: usize) -> Self {
        assert!(n > 0, "pool needs at least one PU");
        let mut free_at = BinaryHeap::with_capacity(n);
        for _ in 0..n {
            free_at.push(Reverse(0));
        }
        Self { free_at, n, busy: BusyTracker::new(), last_dispatch_ready: 0, trace: None }
    }

    /// Start recording occupancy spans. Tracing never changes timing — it
    /// only observes the `(start, end)` pairs the pool already computes.
    pub fn enable_trace(&mut self) {
        if self.trace.is_none() {
            self.trace = Some(Vec::new());
        }
    }

    /// Take the recorded trace (empty if tracing was never enabled).
    /// Spans come out in dispatch order, which has monotone starts (both
    /// the ready times and the earliest-free frontier are non-decreasing).
    pub fn take_trace(&mut self) -> Vec<PuSpan> {
        self.trace.take().unwrap_or_default()
    }

    /// The recorded trace so far (empty slice if tracing is disabled).
    pub fn trace(&self) -> &[PuSpan] {
        self.trace.as_deref().unwrap_or(&[])
    }

    /// Number of processing units.
    #[inline]
    pub fn len(&self) -> usize {
        self.n
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        false
    }

    /// Dispatch a task that becomes ready at `ready` and runs for `dur`.
    /// Assigns the earliest-free PU; returns `(start, end)`.
    ///
    /// `ready` must be non-decreasing across calls (event-time order),
    /// which keeps the busy-union accounting exact.
    pub fn dispatch(&mut self, ready: Ps, dur: Ps) -> (Ps, Ps) {
        debug_assert!(
            ready >= self.last_dispatch_ready,
            "dispatch ready times must be monotone"
        );
        self.last_dispatch_ready = ready;
        let Reverse(free) = self.free_at.pop().expect("pool never empty");
        let start = free.max(ready);
        let end = start + dur;
        self.free_at.push(Reverse(end));
        self.busy.record(start, end);
        if end > start {
            if let Some(tr) = self.trace.as_mut() {
                tr.push(PuSpan { start, end });
            }
        }
        (start, end)
    }

    /// Earliest time any PU is free.
    pub fn earliest_free(&self) -> Ps {
        self.free_at.peek().map(|Reverse(t)| *t).unwrap_or(0)
    }

    /// Time when all current work completes (makespan so far).
    pub fn all_free(&self) -> Ps {
        self.free_at.iter().map(|Reverse(t)| *t).max().unwrap_or(0)
    }

    /// Busy statistics (aggregate + union).
    #[inline]
    pub fn busy(&self) -> &BusyTracker {
        &self.busy
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_pu_serializes() {
        let mut p = PuPool::new(1);
        assert_eq!(p.dispatch(0, 10), (0, 10));
        assert_eq!(p.dispatch(0, 10), (10, 20));
        assert_eq!(p.dispatch(25, 5), (25, 30));
        assert_eq!(p.all_free(), 30);
    }

    #[test]
    fn parallel_pus_run_concurrently() {
        let mut p = PuPool::new(4);
        for _ in 0..4 {
            let (s, e) = p.dispatch(0, 100);
            assert_eq!((s, e), (0, 100));
        }
        // Fifth task queues behind one of the four.
        assert_eq!(p.dispatch(0, 50), (100, 150));
        assert_eq!(p.busy().total(), 450);
        assert_eq!(p.busy().union(), 150);
    }

    #[test]
    fn ready_time_respected() {
        let mut p = PuPool::new(2);
        p.dispatch(0, 10);
        let (s, _) = p.dispatch(500, 10);
        assert_eq!(s, 500);
    }

    #[test]
    fn trace_records_spans_without_changing_timing() {
        let mut plain = PuPool::new(2);
        let mut traced = PuPool::new(2);
        traced.enable_trace();
        for (ready, dur) in [(0, 10), (0, 20), (5, 7), (30, 0), (40, 3)] {
            assert_eq!(plain.dispatch(ready, dur), traced.dispatch(ready, dur));
        }
        assert!(plain.trace().is_empty());
        let tr = traced.take_trace();
        // The zero-duration dispatch is not traced.
        assert_eq!(tr.len(), 4);
        assert_eq!(tr[0], PuSpan { start: 0, end: 10 });
        assert_eq!(tr[2], PuSpan { start: 10, end: 17 }); // queued behind #0
        assert_eq!(tr[3].dur(), 3);
        // Starts are monotone in dispatch order.
        for w in tr.windows(2) {
            assert!(w[1].start >= w[0].start);
        }
        // Taking the trace disables it.
        assert!(traced.trace().is_empty());
    }

    #[test]
    fn makespan_of_balanced_load() {
        // 8 equal tasks on 4 PUs: exactly two waves.
        let mut p = PuPool::new(4);
        let mut last = 0;
        for _ in 0..8 {
            let (_, e) = p.dispatch(0, 7);
            last = last.max(e);
        }
        assert_eq!(last, 14);
        assert_eq!(p.busy().union(), 14);
    }
}
