//! Deterministic discrete-event simulation engine.
//!
//! This is the substrate standing in for the paper's M²NDP testbed
//! (Ramulator + BookSim2): a picosecond-resolution event queue plus the
//! resource primitives ([`PuPool`], busy-interval accounting) every
//! offloading protocol is built from. Determinism is a hard requirement —
//! the same `(workload, protocol, config, seed)` tuple must produce the
//! same timeline on every run, which the property tests assert.

pub mod queue;
pub mod pool;
pub mod busy;

pub use busy::BusyTracker;
pub use pool::{PuPool, PuSpan};
pub use queue::EventQueue;

/// Simulation time in **picoseconds**.
///
/// Picoseconds keep every Table III clock exact: a 3 GHz host cycle is
/// 333 ps (we round to whole ps), a 2 GHz CCM cycle 500 ps, CXL.mem RTT
/// 70_000 ps. `u64` picoseconds overflow after ~213 days of simulated
/// time — far beyond any workload here.
pub type Ps = u64;

/// One nanosecond in [`Ps`].
pub const NS: Ps = 1_000;
/// One microsecond in [`Ps`].
pub const US: Ps = 1_000_000;
/// One millisecond in [`Ps`].
pub const MS: Ps = 1_000_000_000;

/// Convert a frequency in GHz to a cycle time in [`Ps`].
#[inline]
pub fn cycle_ps(freq_ghz: f64) -> Ps {
    (1_000.0 / freq_ghz).round() as Ps
}

/// Convert seconds (f64) to [`Ps`], saturating.
#[inline]
pub fn secs_to_ps(s: f64) -> Ps {
    (s * 1e12).round() as Ps
}

/// Convert [`Ps`] to fractional microseconds (for reports).
#[inline]
pub fn ps_to_us(t: Ps) -> f64 {
    t as f64 / US as f64
}

/// Time to move `bytes` at `gbps` GB/s, in [`Ps`].
#[inline]
pub fn transfer_ps(bytes: u64, gbps: f64) -> Ps {
    if bytes == 0 || gbps <= 0.0 {
        return 0;
    }
    ((bytes as f64 / (gbps * 1e9)) * 1e12).round() as Ps
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cycle_conversion() {
        assert_eq!(cycle_ps(2.0), 500);
        assert_eq!(cycle_ps(1.0), 1000);
        // 3 GHz rounds to 333 ps.
        assert_eq!(cycle_ps(3.0), 333);
    }

    #[test]
    fn transfer_times() {
        // 1 GB at 1 GB/s = 1 s = 1e12 ps.
        assert_eq!(transfer_ps(1_000_000_000, 1.0), 1_000_000_000_000);
        // 64 B at 32 GB/s = 2 ns.
        assert_eq!(transfer_ps(64, 32.0), 2 * NS);
        assert_eq!(transfer_ps(0, 32.0), 0);
    }

    #[test]
    fn unit_constants() {
        assert_eq!(NS * 1000, US);
        assert_eq!(US * 1000, MS);
    }
}
