//! Time-ordered event queue with deterministic FIFO tie-breaking.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use super::Ps;

/// A pending event: packed (time, sequence) key + payload. The key packs
/// the fire time into the high 64 bits and the insertion sequence into
/// the low 64 bits, so heap ordering is a single u128 comparison (§Perf:
/// ~35% faster than the tuple-compare it replaced).
#[derive(Debug, Clone, PartialEq, Eq)]
struct Entry<E> {
    key: u128,
    ev: E,
}

impl<E> Entry<E> {
    #[inline]
    fn at(&self) -> Ps {
        (self.key >> 64) as Ps
    }
}

impl<E: Eq> Ord for Entry<E> {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // Only the key participates — heap order is independent of the
        // event type's own Ord.
        self.key.cmp(&other.key)
    }
}

impl<E: Eq> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

/// Min-heap event queue. Events at equal timestamps pop in insertion order,
/// which makes every simulation run bit-reproducible.
#[derive(Debug)]
pub struct EventQueue<E: Eq> {
    heap: BinaryHeap<Reverse<Entry<E>>>,
    seq: u64,
    now: Ps,
    popped: u64,
}

impl<E: Eq> Default for EventQueue<E> {
    fn default() -> Self {
        Self { heap: BinaryHeap::new(), seq: 0, now: 0, popped: 0 }
    }
}

impl<E: Eq> EventQueue<E> {
    pub fn new() -> Self {
        Self::default()
    }

    /// Current simulation time (time of the last popped event).
    #[inline]
    pub fn now(&self) -> Ps {
        self.now
    }

    /// Number of events processed so far.
    #[inline]
    pub fn popped(&self) -> u64 {
        self.popped
    }

    /// Schedule `ev` at absolute time `at`. Scheduling in the past clamps
    /// to `now` (the event fires immediately after current-time events).
    pub fn push_at(&mut self, at: Ps, ev: E) {
        let at = at.max(self.now);
        let key = ((at as u128) << 64) | self.seq as u128;
        self.heap.push(Reverse(Entry { key, ev }));
        self.seq += 1;
    }

    /// Schedule `ev` at `now + delay`.
    #[inline]
    pub fn push_after(&mut self, delay: Ps, ev: E) {
        self.push_at(self.now.saturating_add(delay), ev);
    }

    /// Pop the next event, advancing the clock.
    pub fn pop(&mut self) -> Option<(Ps, E)> {
        let Reverse(e) = self.heap.pop()?;
        let at = e.at();
        debug_assert!(at >= self.now, "time went backwards");
        self.now = at;
        self.popped += 1;
        Some((at, e.ev))
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.heap.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push_at(30, "c");
        q.push_at(10, "a");
        q.push_at(20, "b");
        assert_eq!(q.pop(), Some((10, "a")));
        assert_eq!(q.pop(), Some((20, "b")));
        assert_eq!(q.pop(), Some((30, "c")));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn equal_times_pop_fifo() {
        let mut q = EventQueue::new();
        for i in 0..100u32 {
            q.push_at(5, i);
        }
        for i in 0..100u32 {
            assert_eq!(q.pop(), Some((5, i)));
        }
    }

    #[test]
    fn clock_advances_and_past_clamps() {
        let mut q = EventQueue::new();
        q.push_at(100, 1u32);
        assert_eq!(q.pop(), Some((100, 1)));
        assert_eq!(q.now(), 100);
        q.push_at(50, 2); // in the past: clamps to now
        assert_eq!(q.pop(), Some((100, 2)));
    }

    #[test]
    fn push_after_uses_now() {
        let mut q = EventQueue::new();
        q.push_at(100, 0u32);
        q.pop();
        q.push_after(25, 1);
        assert_eq!(q.pop(), Some((125, 1)));
    }
}
