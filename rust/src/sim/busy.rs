//! Busy-interval accounting for simulated resources.
//!
//! Tracks two quantities per resource:
//! - **aggregate busy time** — the sum of all busy intervals across all
//!   servers (used for utilization),
//! - **union busy time** — wall-clock time during which *any* server was
//!   busy (the paper's per-component times T_C / T_D / T_H are unions:
//!   "CCM processing time" is the span the CCM is doing work, regardless
//!   of how many μthreads are active).
//!
//! Intervals must be recorded with non-decreasing start times, which holds
//! for every caller because the event queue delivers events in time order.

use super::Ps;

/// Accumulates busy intervals; see module docs.
#[derive(Debug, Default, Clone)]
pub struct BusyTracker {
    total: Ps,
    union: Ps,
    covered_end: Ps,
    first_start: Option<Ps>,
    last_end: Ps,
    intervals: u64,
}

impl BusyTracker {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record a busy interval `[start, end)`. Starts must be non-decreasing
    /// across calls (debug-asserted); overlapping intervals are merged for
    /// the union statistic.
    pub fn record(&mut self, start: Ps, end: Ps) {
        debug_assert!(end >= start, "negative interval");
        if end == start {
            return;
        }
        self.total += end - start;
        self.intervals += 1;
        if self.first_start.is_none() {
            self.first_start = Some(start);
        }
        self.last_end = self.last_end.max(end);
        if start >= self.covered_end {
            self.union += end - start;
            self.covered_end = end;
        } else if end > self.covered_end {
            self.union += end - self.covered_end;
            self.covered_end = end;
        }
    }

    /// Sum of busy time across all servers.
    #[inline]
    pub fn total(&self) -> Ps {
        self.total
    }

    /// Wall-clock time during which at least one server was busy.
    #[inline]
    pub fn union(&self) -> Ps {
        self.union
    }

    /// End of the last recorded interval.
    #[inline]
    pub fn last_end(&self) -> Ps {
        self.last_end
    }

    /// Start of the first recorded interval (None if never busy).
    #[inline]
    pub fn first_start(&self) -> Option<Ps> {
        self.first_start
    }

    /// Number of recorded intervals.
    #[inline]
    pub fn intervals(&self) -> u64 {
        self.intervals
    }

    /// Idle time within `[0, horizon)` w.r.t. the union statistic.
    #[inline]
    pub fn idle_within(&self, horizon: Ps) -> Ps {
        horizon.saturating_sub(self.union)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disjoint_intervals() {
        let mut b = BusyTracker::new();
        b.record(0, 10);
        b.record(20, 30);
        assert_eq!(b.total(), 20);
        assert_eq!(b.union(), 20);
        assert_eq!(b.idle_within(40), 20);
    }

    #[test]
    fn overlapping_intervals_merge_in_union() {
        let mut b = BusyTracker::new();
        b.record(0, 10);
        b.record(5, 15); // overlaps by 5
        assert_eq!(b.total(), 20);
        assert_eq!(b.union(), 15);
    }

    #[test]
    fn contained_interval_adds_nothing_to_union() {
        let mut b = BusyTracker::new();
        b.record(0, 100);
        b.record(10, 20);
        assert_eq!(b.union(), 100);
        assert_eq!(b.total(), 110);
    }

    #[test]
    fn zero_length_ignored() {
        let mut b = BusyTracker::new();
        b.record(5, 5);
        assert_eq!(b.total(), 0);
        assert_eq!(b.intervals(), 0);
        assert_eq!(b.first_start(), None);
    }

    #[test]
    fn bounds_tracked() {
        let mut b = BusyTracker::new();
        b.record(7, 9);
        b.record(12, 40);
        assert_eq!(b.first_start(), Some(7));
        assert_eq!(b.last_end(), 40);
    }
}
