//! Trace well-formedness: structural checks plus conservation against
//! the run's [`SchedReport`].
//!
//! A trace that passes [`validate`] is internally consistent (canonical
//! order, balanced request lifecycles, non-overlapping wire grants,
//! paired fault windows) *and* reconciles exactly — integer
//! picoseconds, no tolerance — with the report the same run produced:
//! wire-grant time per device equals the calendar busy union, PU-lease
//! unions equal the pool busy union, fabric grants equal the fabric
//! message/busy counters, lifecycle counts equal
//! `scheduled`/`failed_requests`, and retained retry counters equal the
//! recorded retry events. The CI trace-smoke step and the `trace_props`
//! proptest both run every exported trace through this gate.

use super::{Trace, TraceEvent, Wire};
use crate::sched::SchedReport;
use crate::sim::Ps;
use std::collections::BTreeMap;

fn fail(msg: String) -> Result<(), String> {
    Err(msg)
}

/// Check `tr` for well-formedness and conservation against `report`.
pub fn validate(tr: &Trace, report: &SchedReport) -> Result<(), String> {
    // Canonical total order (implies per-track monotone timestamps).
    for w in tr.events.windows(2) {
        if w[0].key() > w[1].key() {
            return fail(format!(
                "events out of canonical order at t={} ps (kind rank {} after {})",
                w[1].at(),
                w[1].key().1,
                w[0].key().1
            ));
        }
    }

    let mut submits: BTreeMap<(u32, u32), Ps> = BTreeMap::new();
    let mut terminal_submit: BTreeMap<(u32, u32), Ps> = BTreeMap::new();
    let mut admit_count: BTreeMap<(u32, u32), u64> = BTreeMap::new();
    let mut last_admit: BTreeMap<(u32, u32), Ps> = BTreeMap::new();
    let mut complete_admit: BTreeMap<(u32, u32), Ps> = BTreeMap::new();
    let mut wire_prev_end: BTreeMap<(u32, u8), Ps> = BTreeMap::new();
    let mut wire_sum: BTreeMap<u32, Ps> = BTreeMap::new();
    let mut fabric_sum: Ps = 0;
    let mut fabric_count: u64 = 0;
    let mut fabric_prev_end: Ps = 0;
    let mut leases: BTreeMap<u32, Vec<(Ps, Ps)>> = BTreeMap::new();
    let mut host_sum: Ps = 0;
    let mut completes: u64 = 0;
    let mut faileds: u64 = 0;
    let mut retry_events: u64 = 0;
    let mut window_begins: Vec<(u32, &'static str, Ps)> = Vec::new();
    let mut window_ends: Vec<(u32, &'static str, Ps)> = Vec::new();

    for e in &tr.events {
        match *e {
            TraceEvent::Submit { at, tenant, index, .. } => {
                if submits.insert((tenant, index), at).is_some() {
                    return fail(format!("duplicate submit for t{tenant}#{index}"));
                }
            }
            TraceEvent::Admit { at, tenant, index, .. } => {
                *admit_count.entry((tenant, index)).or_insert(0) += 1;
                last_admit.insert((tenant, index), at);
            }
            TraceEvent::Complete { at, tenant, index, submit, admit, host_busy, .. } => {
                if terminal_submit.insert((tenant, index), submit).is_some() {
                    return fail(format!("t{tenant}#{index} terminates twice"));
                }
                if admit > at {
                    return fail(format!("t{tenant}#{index} admitted after completing"));
                }
                complete_admit.insert((tenant, index), admit);
                host_sum += host_busy;
                completes += 1;
            }
            TraceEvent::Failed { tenant, index, submit, .. } => {
                if terminal_submit.insert((tenant, index), submit).is_some() {
                    return fail(format!("t{tenant}#{index} terminates twice"));
                }
                faileds += 1;
            }
            TraceEvent::WireGrant { at, dur, device, wire, tenant, index, .. } => {
                if dur == 0 {
                    return fail(format!("zero-length wire grant for t{tenant}#{index}"));
                }
                if wire == Wire::Fabric {
                    if at < fabric_prev_end {
                        return fail(format!("fabric grants overlap at t={at} ps"));
                    }
                    fabric_prev_end = at + dur;
                    fabric_sum += dur;
                    fabric_count += 1;
                } else {
                    let key = (device, wire as u8);
                    let prev = wire_prev_end.entry(key).or_insert(0);
                    if at < *prev {
                        return fail(format!(
                            "{} grants overlap on device {device} at t={at} ps",
                            wire.label()
                        ));
                    }
                    *prev = at + dur;
                    *wire_sum.entry(device).or_insert(0) += dur;
                }
            }
            TraceEvent::PuLease { at, end, device, tenant, index, .. } => {
                if end <= at {
                    return fail(format!("empty PU lease for t{tenant}#{index}"));
                }
                leases.entry(device).or_default().push((at, end));
            }
            TraceEvent::Retry { .. } => retry_events += 1,
            TraceEvent::FaultBegin { at, device, kind, until } => {
                if let Some(u) = until {
                    if u <= at {
                        return fail(format!("empty fault window on device {device}"));
                    }
                    window_begins.push((device, kind.label(), u));
                }
            }
            TraceEvent::FaultEnd { at, device, kind } => {
                window_ends.push((device, kind.label(), at));
            }
            _ => {}
        }
    }

    // Request lifecycle balance against the report's counters.
    if submits.len() as u64 != report.scheduled {
        return fail(format!(
            "submit count {} != scheduled {}",
            submits.len(),
            report.scheduled
        ));
    }
    if completes + faileds != report.scheduled {
        return fail(format!(
            "terminal count {} != scheduled {}",
            completes + faileds,
            report.scheduled
        ));
    }
    if faileds != report.failed_requests as u64 {
        return fail(format!(
            "failed count {faileds} != report failed_requests {}",
            report.failed_requests
        ));
    }
    for (key, submit) in &terminal_submit {
        match submits.get(key) {
            None => return fail(format!("t{}#{} terminates without a submit", key.0, key.1)),
            Some(s) if s != submit => {
                return fail(format!("t{}#{} submit time mismatch", key.0, key.1))
            }
            _ => {}
        }
    }
    for (key, admit) in &complete_admit {
        match last_admit.get(key) {
            None => return fail(format!("t{}#{} completed without an admission", key.0, key.1)),
            Some(a) if a != admit => {
                return fail(format!(
                    "t{}#{} completion admit {} != last admission {}",
                    key.0, key.1, admit, a
                ))
            }
            _ => {}
        }
    }

    // Wire busy conservation: per-device grant time equals the
    // calendar busy union the report carries (grants are disjoint, so
    // sum == union), fabric grants equal the fabric counters.
    for (d, stats) in report.devices.iter().enumerate() {
        let got = wire_sum.get(&(d as u32)).copied().unwrap_or(0);
        if got != stats.link_busy {
            return fail(format!(
                "device {d} wire grants {got} ps != report link_busy {} ps",
                stats.link_busy
            ));
        }
    }
    if fabric_sum != report.fabric.busy {
        return fail(format!(
            "fabric grants {fabric_sum} ps != report fabric busy {} ps",
            report.fabric.busy
        ));
    }
    if fabric_count != report.fabric.messages {
        return fail(format!(
            "fabric grant count {fabric_count} != report fabric messages {}",
            report.fabric.messages
        ));
    }

    // PU lease unions equal the pool busy unions.
    for (d, stats) in report.devices.iter().enumerate() {
        let union = leases
            .get(&(d as u32))
            .map(|ls| {
                let (mut total, mut cs, mut ce): (Ps, Ps, Ps) = (0, ls[0].0, ls[0].1);
                for &(s, e) in &ls[1..] {
                    if s > ce {
                        total += ce - cs;
                        (cs, ce) = (s, e);
                    } else {
                        ce = ce.max(e);
                    }
                }
                total + (ce - cs)
            })
            .unwrap_or(0);
        if union != stats.pu_busy {
            return fail(format!(
                "device {d} PU lease union {union} ps != report pu_busy {} ps",
                stats.pu_busy
            ));
        }
    }

    // Host busy: each completion carries its solo host charge; failed
    // requests contribute none. Exact sum equality.
    if host_sum != report.host_busy {
        return fail(format!(
            "completion host_busy sum {host_sum} ps != report host_busy {} ps",
            report.host_busy
        ));
    }

    // Retry events reconcile with the retained per-request counters
    // (the terminal failure consumes the last increment without a
    // retry event). Streaming runs keep no per-request rows to check.
    if !report.streamed {
        let expect: u64 = report.requests.iter().map(|r| r.retries as u64).sum::<u64>()
            - report.failed_requests as u64;
        if retry_events != expect {
            return fail(format!("retry events {retry_events} != report retries {expect}"));
        }
    }

    // Every transient fault window that opened also closed, at its
    // declared end.
    window_begins.sort_unstable();
    window_ends.sort_unstable();
    if window_begins != window_ends {
        return fail(format!(
            "fault windows unbalanced: {} begins vs {} matching ends",
            window_begins.len(),
            window_ends.len()
        ));
    }

    Ok(())
}
