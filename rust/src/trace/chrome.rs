//! Chrome trace-event JSON export.
//!
//! Renders a [`Trace`] in the Chrome trace-event format (the JSON
//! array flavour, wrapped in `{"traceEvents": [...]}`), loadable in
//! Perfetto (<https://ui.perfetto.dev>) or `chrome://tracing`. Track
//! layout:
//!
//! - one *process* per device (`pid = 1 + d`) with four threads:
//!   `CXL.mem` (0), `CXL.io` (1), `CCM PUs` (2) and `events` (3 —
//!   fault windows, fail instants, early slot releases);
//! - one process for the shared fabric wire when the topology models
//!   one (`pid = 1 + devices`);
//! - one `requests` process (`pid = 2 + devices`) with a thread per
//!   tenant carrying request lifetime spans (submit → completion) and
//!   instants for admissions, retries, timeouts and requeues.
//!
//! Timestamps and durations are microseconds (`ps / 1e6`) per the
//! format; all values derive from integer picoseconds, so the printed
//! document is deterministic and byte-comparable across worker counts.

use super::{Trace, TraceEvent, Wire};
use crate::sim::Ps;
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::collections::BTreeSet;

fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect::<BTreeMap<_, _>>())
}

fn us(ps: Ps) -> Json {
    Json::Num(ps as f64 / 1e6)
}

fn span(name: String, pid: u32, tid: u32, ts: Ps, dur: Ps, args: Json) -> Json {
    obj(vec![
        ("ph", Json::Str("X".to_string())),
        ("name", Json::Str(name)),
        ("pid", Json::Num(pid as f64)),
        ("tid", Json::Num(tid as f64)),
        ("ts", us(ts)),
        ("dur", us(dur)),
        ("args", args),
    ])
}

fn instant(name: String, pid: u32, tid: u32, ts: Ps, args: Json) -> Json {
    obj(vec![
        ("ph", Json::Str("i".to_string())),
        ("s", Json::Str("t".to_string())),
        ("name", Json::Str(name)),
        ("pid", Json::Num(pid as f64)),
        ("tid", Json::Num(tid as f64)),
        ("ts", us(ts)),
        ("args", args),
    ])
}

fn metadata(kind: &str, pid: u32, tid: u32, name: &str) -> Json {
    obj(vec![
        ("ph", Json::Str("M".to_string())),
        ("name", Json::Str(kind.to_string())),
        ("pid", Json::Num(pid as f64)),
        ("tid", Json::Num(tid as f64)),
        ("args", obj(vec![("name", Json::Str(name.to_string()))])),
    ])
}

/// Render the trace as a Chrome trace-event document.
pub fn to_json(tr: &Trace) -> Json {
    let devices = tr.devices as u32;
    let dev_pid = |d: u32| 1 + d;
    let fabric_pid = 1 + devices;
    let req_pid = 2 + devices;

    let mut ev: Vec<Json> = Vec::with_capacity(tr.events.len() + 8 * tr.devices + 8);

    for d in 0..devices {
        ev.push(metadata("process_name", dev_pid(d), 0, &format!("device {d}")));
        ev.push(metadata("thread_name", dev_pid(d), 0, "CXL.mem"));
        ev.push(metadata("thread_name", dev_pid(d), 1, "CXL.io"));
        ev.push(metadata("thread_name", dev_pid(d), 2, "CCM PUs"));
        ev.push(metadata("thread_name", dev_pid(d), 3, "events"));
    }
    if tr.has_fabric {
        ev.push(metadata("process_name", fabric_pid, 0, "fabric"));
        ev.push(metadata("thread_name", fabric_pid, 0, "wire"));
    }
    ev.push(metadata("process_name", req_pid, 0, "requests"));
    let tenants: BTreeSet<u32> = tr
        .events
        .iter()
        .filter_map(|e| match e {
            TraceEvent::Submit { tenant, .. } => Some(*tenant),
            _ => None,
        })
        .collect();
    for t in tenants {
        ev.push(metadata("thread_name", req_pid, t, &format!("tenant {t}")));
    }

    for e in &tr.events {
        match *e {
            TraceEvent::Submit { .. } => {} // lifetime span starts here; drawn by Complete/Failed
            TraceEvent::Admit { at, tenant, index, device } => {
                ev.push(instant(
                    format!("admit d{device}"),
                    req_pid,
                    tenant,
                    at,
                    obj(vec![("index", Json::Num(index as f64))]),
                ));
            }
            TraceEvent::Complete { at, tenant, index, device, submit, admit, solo, host_busy } => {
                ev.push(span(
                    format!("t{tenant}#{index}"),
                    req_pid,
                    tenant,
                    submit,
                    at - submit,
                    obj(vec![
                        ("device", Json::Num(device as f64)),
                        ("admit_us", us(admit)),
                        ("solo_us", us(solo)),
                        ("host_busy_us", us(host_busy)),
                    ]),
                ));
            }
            TraceEvent::Failed { at, tenant, index, device, submit } => {
                ev.push(span(
                    format!("t{tenant}#{index} failed"),
                    req_pid,
                    tenant,
                    submit,
                    at - submit,
                    obj(vec![("device", Json::Num(device as f64))]),
                ));
            }
            TraceEvent::WireGrant { at, dur, device, wire, tenant, index, chunk } => {
                let (pid, tid) = match wire {
                    Wire::Mem => (dev_pid(device), 0),
                    Wire::Io => (dev_pid(device), 1),
                    Wire::Fabric => (fabric_pid, 0),
                };
                ev.push(span(
                    format!("t{tenant}#{index}"),
                    pid,
                    tid,
                    at,
                    dur,
                    obj(vec![
                        ("chunk", Json::Num(chunk as f64)),
                        ("device", Json::Num(device as f64)),
                    ]),
                ));
            }
            TraceEvent::PuLease { at, end, device, tenant, index, chunk } => {
                ev.push(span(
                    format!("t{tenant}#{index}"),
                    dev_pid(device),
                    2,
                    at,
                    end - at,
                    obj(vec![("chunk", Json::Num(chunk as f64))]),
                ));
            }
            TraceEvent::EarlyRelease { at, tenant, index, device } => {
                ev.push(instant(
                    format!("early-release t{tenant}#{index}"),
                    dev_pid(device),
                    3,
                    at,
                    obj(vec![]),
                ));
            }
            TraceEvent::Retry { at, tenant, index, retries, backoff, from_service } => {
                ev.push(instant(
                    format!("retry #{retries}"),
                    req_pid,
                    tenant,
                    at,
                    obj(vec![
                        ("index", Json::Num(index as f64)),
                        ("backoff_us", us(backoff)),
                        ("from_service", Json::Bool(from_service)),
                    ]),
                ));
            }
            TraceEvent::Timeout { at, tenant, index, device } => {
                ev.push(instant(
                    format!("timeout d{device}"),
                    req_pid,
                    tenant,
                    at,
                    obj(vec![("index", Json::Num(index as f64))]),
                ));
            }
            TraceEvent::Requeue { at, tenant, index, device, from_backoff } => {
                ev.push(instant(
                    format!("requeue d{device}"),
                    req_pid,
                    tenant,
                    at,
                    obj(vec![
                        ("index", Json::Num(index as f64)),
                        ("from_backoff", Json::Bool(from_backoff)),
                    ]),
                ));
            }
            TraceEvent::FaultBegin { at, device, kind, until } => match until {
                Some(u) => {
                    ev.push(span(kind.label().to_string(), dev_pid(device), 3, at, u - at,
                        obj(vec![])));
                }
                None => {
                    ev.push(instant(kind.label().to_string(), dev_pid(device), 3, at,
                        obj(vec![])));
                }
            },
            TraceEvent::FaultEnd { at, device, kind } => {
                ev.push(instant(format!("{} end", kind.label()), dev_pid(device), 3, at,
                    obj(vec![])));
            }
        }
    }

    obj(vec![
        ("displayTimeUnit", Json::Str("ms".to_string())),
        ("traceEvents", Json::Arr(ev)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Protocol;

    #[test]
    fn chrome_document_shape() {
        let events = vec![
            TraceEvent::Submit { at: 0, tenant: 0, index: 0, class: 0, device: 0,
                proto: Protocol::Axle },
            TraceEvent::Admit { at: 1_000_000, tenant: 0, index: 0, device: 0 },
            TraceEvent::WireGrant { at: 1_000_000, dur: 500_000, device: 0, wire: Wire::Mem,
                tenant: 0, index: 0, chunk: 0 },
            TraceEvent::PuLease { at: 1_500_000, end: 2_500_000, device: 0, tenant: 0,
                index: 0, chunk: 0 },
            TraceEvent::Complete { at: 3_000_000, tenant: 0, index: 0, device: 0, submit: 0,
                admit: 1_000_000, solo: 3_000_000, host_busy: 400_000 },
        ];
        let tr = Trace::new(1, false, events);
        let doc = to_json(&tr);
        let arr = doc.get("traceEvents").as_arr().unwrap();
        // 5 device metadata + 1 requests process + 1 tenant thread + 4 drawn events
        // (the Submit itself is folded into the lifetime span).
        assert_eq!(arr.len(), 11);
        assert_eq!(doc.get("displayTimeUnit").as_str(), Some("ms"));
        // Every drawn event has integer-µs-friendly f64 ts.
        let spans: Vec<&Json> =
            arr.iter().filter(|e| e.get("ph").as_str() == Some("X")).collect();
        assert_eq!(spans.len(), 3);
        // Lifetime span covers submit → completion on the requests pid.
        let life = spans
            .iter()
            .find(|s| s.get("pid").as_u64() == Some(3))
            .expect("request lifetime span");
        assert_eq!(life.get("ts").as_f64(), Some(0.0));
        assert_eq!(life.get("dur").as_f64(), Some(3.0));
        // Parse round-trip (valid JSON document).
        let printed = doc.to_string();
        assert_eq!(Json::parse(&printed).unwrap(), doc);
    }
}
