//! Windowed telemetry: fixed-width time buckets over a [`Trace`].
//!
//! Where the Chrome export shows individual spans, this view answers
//! "what was the system doing *around* t": per-window host and CCM
//! utilization, device-wire busy time, time-averaged admission queue
//! depth and outstanding-window occupancy, completion/retry counts and
//! a per-window slowdown [`QuantileSketch`] (so `axle report fig22`
//! and `--trace-buckets` can print p99-over-time).
//!
//! All busy accounting is integer-exact: wire/PU overlap is computed in
//! picoseconds from the recorded grants/leases, and summing a quantity
//! across all windows reproduces the run totals the `SchedReport`
//! carries (pinned by tests). Host busy is the one fractional series —
//! each completion's solo host-busy time is spread uniformly over its
//! service interval, mirroring the report's aggregate-sum convention.

use super::{Trace, TraceEvent, Wire};
use crate::metrics::QuantileSketch;
use crate::sim::Ps;
use crate::util::json::Json;
use std::collections::BTreeMap;

/// One fixed-width time bucket.
#[derive(Debug, Clone)]
pub struct Window {
    /// Inclusive window start (ps).
    pub start: Ps,
    /// Exclusive window end (ps; the last window is clipped to the
    /// run's makespan).
    pub end: Ps,
    /// Host busy time attributed to this window (fractional ps).
    pub host_busy: f64,
    /// Union CCM PU busy time summed over devices (ps).
    pub ccm_busy: Ps,
    /// Device wire (CXL.mem + CXL.io) grant time (ps).
    pub wire_busy: Ps,
    /// Shared fabric grant time (ps).
    pub fabric_busy: Ps,
    /// Time-averaged admission queue depth across devices.
    pub queue_depth: f64,
    /// Time-averaged outstanding (submitted, not yet completed/failed)
    /// request count.
    pub outstanding: f64,
    /// Requests completing inside the window.
    pub completions: u32,
    /// Retries consumed inside the window.
    pub retries: u32,
    /// Slowdowns of the requests completing inside the window.
    pub slowdown: QuantileSketch,
}

impl Window {
    pub fn width(&self) -> Ps {
        self.end.saturating_sub(self.start)
    }

    /// Host utilization share. The host-busy series uses the report's
    /// aggregate-sum accounting (overlapping tenants can sum past one
    /// host), so the displayed share is clamped at 1.
    pub fn host_util(&self) -> f64 {
        let w = self.width();
        if w == 0 {
            0.0
        } else {
            (self.host_busy / w as f64).min(1.0)
        }
    }

    /// Mean CCM PU-pool utilization across `devices` pools.
    pub fn ccm_util(&self, devices: usize) -> f64 {
        let w = self.width();
        if w == 0 || devices == 0 {
            0.0
        } else {
            self.ccm_busy as f64 / (w as f64 * devices as f64)
        }
    }

    pub fn to_json(&self, devices: usize) -> Json {
        let mut o = BTreeMap::new();
        o.insert("start_ps".into(), Json::Num(self.start as f64));
        o.insert("end_ps".into(), Json::Num(self.end as f64));
        o.insert("host_util".into(), Json::Num(self.host_util()));
        o.insert("ccm_util".into(), Json::Num(self.ccm_util(devices)));
        o.insert("wire_busy_ps".into(), Json::Num(self.wire_busy as f64));
        o.insert("fabric_busy_ps".into(), Json::Num(self.fabric_busy as f64));
        o.insert("queue_depth".into(), Json::Num(self.queue_depth));
        o.insert("outstanding".into(), Json::Num(self.outstanding));
        o.insert("completions".into(), Json::Num(self.completions as f64));
        o.insert("retries".into(), Json::Num(self.retries as f64));
        o.insert("slowdown".into(), self.slowdown.to_json());
        Json::Obj(o)
    }
}

/// The full windowed view of one run.
#[derive(Debug, Clone)]
pub struct Telemetry {
    /// Bucket width (ps).
    pub width: Ps,
    /// Run makespan the buckets partition (ps).
    pub makespan: Ps,
    /// Device count (for CCM utilization denominators).
    pub devices: usize,
    pub windows: Vec<Window>,
}

impl Telemetry {
    /// Median per-window host utilization (the CI smoke headline).
    pub fn host_util_p50(&self) -> f64 {
        let mut u: Vec<f64> =
            self.windows.iter().filter(|w| w.width() > 0).map(|w| w.host_util()).collect();
        if u.is_empty() {
            return 0.0;
        }
        u.sort_by(|a, b| a.partial_cmp(b).unwrap());
        u[u.len() / 2]
    }

    pub fn to_json(&self) -> Json {
        let mut o = BTreeMap::new();
        o.insert("width_ps".into(), Json::Num(self.width as f64));
        o.insert("makespan_ps".into(), Json::Num(self.makespan as f64));
        o.insert(
            "windows".into(),
            Json::Arr(self.windows.iter().map(|w| w.to_json(self.devices)).collect()),
        );
        Json::Obj(o)
    }
}

/// Distribute the overlap of `[s, e)` over the bucket grid.
fn for_overlap(width: Ps, n: usize, s: Ps, e: Ps, mut f: impl FnMut(usize, Ps)) {
    if e <= s || width == 0 {
        return;
    }
    let mut k = (s / width) as usize;
    let mut cur = s;
    while cur < e && k < n {
        let bend = (k as Ps + 1) * width;
        let seg = e.min(bend) - cur;
        f(k, seg);
        cur = bend;
        k += 1;
    }
}

/// Bucket a trace into `buckets` fixed-width windows over
/// `[0, makespan)`. Deterministic: a pure fold over the canonical event
/// order, integer arithmetic everywhere except the host-busy spread.
pub fn windows(tr: &Trace, buckets: u32, makespan: Ps) -> Telemetry {
    let n = buckets.max(1) as usize;
    let span = makespan.max(1);
    let width = span.div_ceil(n as Ps);
    let width = width.max(1);
    let idx = |t: Ps| ((t / width) as usize).min(n - 1);

    let mut host = vec![0f64; n];
    let mut ccm: Vec<Ps> = vec![0; n];
    let mut wire: Vec<Ps> = vec![0; n];
    let mut fabric: Vec<Ps> = vec![0; n];
    let mut qd = vec![0f64; n];
    let mut out = vec![0f64; n];
    let mut completions = vec![0u32; n];
    let mut retries = vec![0u32; n];
    let mut sketch: Vec<QuantileSketch> = (0..n).map(|_| QuantileSketch::new()).collect();

    // Per-device CCM lease unions (leases overlap across co-scheduled
    // requests; busy time is the union, matching `pu_busy`).
    let mut lease_cursor: Vec<Option<(Ps, Ps)>> = vec![None; tr.devices];

    // Queue-depth / outstanding step functions, folded between events.
    let mut cur_q: i64 = 0;
    let mut cur_out: i64 = 0;
    let mut prev: Ps = 0;
    let mut step = |from: Ps, to: Ps, q: i64, o: i64, qd: &mut [f64], out: &mut [f64]| {
        if q != 0 {
            for_overlap(width, n, from, to, |k, seg| qd[k] += q as f64 * seg as f64);
        }
        if o != 0 {
            for_overlap(width, n, from, to, |k, seg| out[k] += o as f64 * seg as f64);
        }
    };

    for e in &tr.events {
        let at = e.at();
        step(prev, at, cur_q, cur_out, &mut qd, &mut out);
        prev = at;
        match *e {
            TraceEvent::Submit { .. } => {
                cur_q += 1;
                cur_out += 1;
            }
            TraceEvent::Admit { .. } => cur_q -= 1,
            TraceEvent::Timeout { .. } => cur_q -= 1,
            TraceEvent::Requeue { from_backoff, .. } => {
                if from_backoff {
                    cur_q += 1;
                }
            }
            TraceEvent::Complete { at, submit, admit, solo, host_busy, .. } => {
                cur_out -= 1;
                let k = idx(at);
                completions[k] += 1;
                let sd = if solo == 0 { 1.0 } else { (at - submit) as f64 / solo as f64 };
                sketch[k].record(sd);
                // Spread the solo host-busy charge uniformly over the
                // service interval (all at the completion instant when
                // it is empty).
                if at <= admit {
                    host[k] += host_busy as f64;
                } else {
                    let frac = host_busy as f64 / (at - admit) as f64;
                    for_overlap(width, n, admit, at, |k, seg| host[k] += frac * seg as f64);
                }
            }
            TraceEvent::Failed { .. } => cur_out -= 1,
            TraceEvent::Retry { at, .. } => retries[idx(at)] += 1,
            TraceEvent::WireGrant { at, dur, wire: w, .. } => {
                let acc = if w == Wire::Fabric { &mut fabric } else { &mut wire };
                for_overlap(width, n, at, at + dur, |k, seg| acc[k] += seg);
            }
            TraceEvent::PuLease { at, end, device, .. } => {
                let d = device as usize;
                match lease_cursor[d] {
                    Some((cs, ce)) if at <= ce => {
                        lease_cursor[d] = Some((cs, ce.max(end)));
                    }
                    Some((cs, ce)) => {
                        for_overlap(width, n, cs, ce, |k, seg| ccm[k] += seg);
                        lease_cursor[d] = Some((at, end));
                    }
                    None => lease_cursor[d] = Some((at, end)),
                }
            }
            _ => {}
        }
    }
    step(prev, span, cur_q, cur_out, &mut qd, &mut out);
    for cursor in lease_cursor {
        if let Some((cs, ce)) = cursor {
            for_overlap(width, n, cs, ce, |k, seg| ccm[k] += seg);
        }
    }

    let windows = (0..n)
        .map(|k| {
            let start = k as Ps * width;
            let end = ((k as Ps + 1) * width).min(span).max(start);
            let w = end - start;
            Window {
                start,
                end,
                host_busy: host[k],
                ccm_busy: ccm[k],
                wire_busy: wire[k],
                fabric_busy: fabric[k],
                queue_depth: if w == 0 { 0.0 } else { qd[k] / w as f64 },
                outstanding: if w == 0 { 0.0 } else { out[k] / w as f64 },
                completions: completions[k],
                retries: retries[k],
                slowdown: sketch[k].clone(),
            }
        })
        .collect();

    Telemetry { width, makespan: span, devices: tr.devices, windows }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Protocol;

    fn lease(at: Ps, end: Ps, device: u32) -> TraceEvent {
        TraceEvent::PuLease { at, end, device, tenant: 0, index: 0, chunk: 0 }
    }

    #[test]
    fn busy_time_is_conserved_across_windows() {
        let events = vec![
            TraceEvent::WireGrant { at: 0, dur: 40, device: 0, wire: Wire::Mem, tenant: 0,
                index: 0, chunk: 0 },
            TraceEvent::WireGrant { at: 90, dur: 20, device: 0, wire: Wire::Io, tenant: 0,
                index: 0, chunk: 0 },
            lease(10, 30, 0),
            lease(20, 50, 0), // overlaps: union [10, 50)
            lease(70, 80, 1),
        ];
        let tr = Trace::new(2, false, events);
        let tm = windows(&tr, 4, 100);
        assert_eq!(tm.windows.len(), 4);
        let wire_total: Ps = tm.windows.iter().map(|w| w.wire_busy).sum();
        assert_eq!(wire_total, 60);
        let ccm_total: Ps = tm.windows.iter().map(|w| w.ccm_busy).sum();
        assert_eq!(ccm_total, 50); // union(10..50) + 70..80
        // The straddling grant splits exactly at the bucket edge.
        assert_eq!(tm.windows[0].wire_busy, 25);
        assert_eq!(tm.windows[1].wire_busy, 15);
    }

    #[test]
    fn queue_depth_and_outstanding_are_time_averaged() {
        let events = vec![
            TraceEvent::Submit { at: 0, tenant: 0, index: 0, class: 0, device: 0,
                proto: Protocol::Axle },
            TraceEvent::Admit { at: 50, tenant: 0, index: 0, device: 0 },
            TraceEvent::Complete { at: 100, tenant: 0, index: 0, device: 0, submit: 0,
                admit: 50, solo: 50, host_busy: 10 },
        ];
        let tr = Trace::new(1, false, events);
        let tm = windows(&tr, 2, 100);
        // Queued for all of window 0, none of window 1.
        assert!((tm.windows[0].queue_depth - 1.0).abs() < 1e-12);
        assert!(tm.windows[1].queue_depth.abs() < 1e-12);
        // Outstanding the whole run.
        assert!((tm.windows[0].outstanding - 1.0).abs() < 1e-12);
        assert!((tm.windows[1].outstanding - 1.0).abs() < 1e-12);
        assert_eq!(tm.windows[1].completions, 1);
        assert_eq!(tm.windows[1].slowdown.count(), 1);
        // Host charge spreads over [admit, completion) = window 1.
        assert!(tm.windows[0].host_busy.abs() < 1e-12);
        assert!((tm.windows[1].host_busy - 10.0).abs() < 1e-9);
        assert!(tm.host_util_p50() >= 0.0);
    }
}
