//! Deterministic event tracing for the closed-loop offload engine.
//!
//! The scheduler's reports are end-of-run aggregates; this module gives
//! the engine *eyes over time*: a [`Tracer`] threaded through
//! [`crate::sched::driver`] records one typed [`TraceEvent`] per
//! observable transition — request lifecycle (submit / admit / complete
//! / fail), per-wire calendar grants, CCM PU leases, retry machinery
//! (timeout, backoff retry, requeue), fault windows and pipelined early
//! slot releases — and a [`Trace`] is the canonically ordered event
//! log of one run.
//!
//! Three contracts, all pinned in tests:
//!
//! - **Observation only.** The engine never reads tracer state; every
//!   recording site is behind `if let Some(t) = tr`, and a run with
//!   tracing enabled is **bit-identical** (including f64 bit patterns)
//!   to the same run without it (`rust/tests/sched_regression.rs`).
//! - **Worker-count invariance.** Sharded runs (`--jobs N` on pinned
//!   fabric-free topologies) record into per-shard buffers; the shard
//!   event multisets are disjoint and their union equals the
//!   single-shard multiset, so the canonical sort in [`Trace::new`]
//!   makes the merged trace byte-identical to `--jobs 1`.
//! - **Conservation.** Wire-grant time per device equals the calendar
//!   busy union the report carries, PU-lease unions equal the pool busy
//!   union, and lifecycle counts reconcile with the report's
//!   `scheduled`/`failed`/retry counters ([`validate`]).
//!
//! Export surfaces: [`chrome`] (Chrome trace-event JSON for
//! Perfetto / `chrome://tracing`, `axle sched --trace out.json`) and
//! [`telemetry`] (fixed-width windowed utilization / queue-depth /
//! tail-latency buckets, `--trace-buckets N` and `axle report fig22`).

pub mod chrome;
pub mod telemetry;
pub mod validate;

pub use validate::validate;

use crate::config::{FaultKind, Protocol};
use crate::sim::Ps;

/// Which wire a calendar grant occupied.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Wire {
    /// The device's CXL.mem channel (operand transfer).
    Mem,
    /// The device's CXL.io channel (back-streamed results).
    Io,
    /// The shared upstream fabric link.
    Fabric,
}

impl Wire {
    pub fn label(self) -> &'static str {
        match self {
            Wire::Mem => "CXL.mem",
            Wire::Io => "CXL.io",
            Wire::Fabric => "fabric",
        }
    }

    fn code(self) -> u64 {
        match self {
            Wire::Mem => 0,
            Wire::Io => 1,
            Wire::Fabric => 2,
        }
    }
}

/// One observable engine transition. Every variant carries its absolute
/// simulated time `at` (integer picoseconds — no floats anywhere in the
/// event model, so traces merge and compare exactly).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum TraceEvent {
    /// A tenant submitted request `index` into device `device`'s
    /// admission queue, with the protocol the policy chose.
    Submit { at: Ps, tenant: u32, index: u32, class: u32, device: u32, proto: Protocol },
    /// The device moved the request from its admission queue into
    /// service (a re-placed request admits again on its new device).
    Admit { at: Ps, tenant: u32, index: u32, device: u32 },
    /// The request completed. `host_busy` is the solo run's host busy
    /// time (the report's aggregate-sum accounting); failed requests
    /// never contribute one.
    Complete {
        at: Ps,
        tenant: u32,
        index: u32,
        device: u32,
        submit: Ps,
        admit: Ps,
        solo: Ps,
        host_busy: Ps,
    },
    /// The request was dropped after exhausting its retry budget.
    Failed { at: Ps, tenant: u32, index: u32, device: u32, submit: Ps },
    /// The link calendar granted `[at, at + dur)` on `wire` to one solo
    /// trace message of the request (`chunk` tags stage-DAG admission;
    /// 0 for whole-request admission). Zero-duration messages are never
    /// granted, matching the calendars' accounting.
    WireGrant { at: Ps, dur: Ps, device: u32, wire: Wire, tenant: u32, index: u32, chunk: u32 },
    /// The device's CCM PU pool leased `[at, end)` to one solo CCM span
    /// of the request. Leases of co-scheduled requests may overlap (the
    /// pool has many PUs); their per-device interval *union* is the
    /// report's `pu_busy`.
    PuLease { at: Ps, end: Ps, device: u32, tenant: u32, index: u32, chunk: u32 },
    /// Pipelined chunked admission freed the request's service slot at
    /// its last CCM stage bound, before the back-stream drained.
    EarlyRelease { at: Ps, tenant: u32, index: u32, device: u32 },
    /// The request consumed one retry (`retries` so far) and entered
    /// exponential backoff for `backoff` ps. `from_service` marks a
    /// killed in-service attempt (vs. a timed-out queued one).
    Retry { at: Ps, tenant: u32, index: u32, retries: u32, backoff: Ps, from_service: bool },
    /// A queued request timed out on a non-admitting device.
    Timeout { at: Ps, tenant: u32, index: u32, device: u32 },
    /// The request re-entered an admission queue on `device` — after
    /// backoff (`from_backoff`) or via the free queue drain off a
    /// failed device.
    Requeue { at: Ps, tenant: u32, index: u32, device: u32, from_backoff: bool },
    /// Fault event onset on `device`. `until` carries the window end
    /// for transient kinds; permanent failures have none.
    FaultBegin { at: Ps, device: u32, kind: FaultKind, until: Option<Ps> },
    /// A transient fault window closed.
    FaultEnd { at: Ps, device: u32, kind: FaultKind },
}

impl TraceEvent {
    /// Absolute event time.
    pub fn at(&self) -> Ps {
        match *self {
            TraceEvent::Submit { at, .. }
            | TraceEvent::Admit { at, .. }
            | TraceEvent::Complete { at, .. }
            | TraceEvent::Failed { at, .. }
            | TraceEvent::WireGrant { at, .. }
            | TraceEvent::PuLease { at, .. }
            | TraceEvent::EarlyRelease { at, .. }
            | TraceEvent::Retry { at, .. }
            | TraceEvent::Timeout { at, .. }
            | TraceEvent::Requeue { at, .. }
            | TraceEvent::FaultBegin { at, .. }
            | TraceEvent::FaultEnd { at, .. } => at,
        }
    }

    /// Total-order key for the canonical (shard-invariant) event order:
    /// time, then a fixed kind rank, then enough identity fields that
    /// two distinct events never compare equal (events that *do* tie
    /// are field-for-field identical, so their mutual order is
    /// unobservable).
    pub fn key(&self) -> (Ps, u8, u64, u64, u64) {
        fn ti(tenant: u32, index: u32) -> u64 {
            ((tenant as u64) << 32) | index as u64
        }
        match *self {
            TraceEvent::FaultBegin { at, device, kind, until } => {
                (at, 0, device as u64, kind as u64, until.unwrap_or(0))
            }
            TraceEvent::FaultEnd { at, device, kind } => (at, 1, device as u64, kind as u64, 0),
            TraceEvent::Submit { at, tenant, index, class, device, proto } => {
                (at, 2, ti(tenant, index), ((class as u64) << 32) | device as u64, proto as u64)
            }
            TraceEvent::Requeue { at, tenant, index, device, from_backoff } => {
                (at, 3, ti(tenant, index), device as u64, from_backoff as u64)
            }
            TraceEvent::Admit { at, tenant, index, device } => {
                (at, 4, ti(tenant, index), device as u64, 0)
            }
            // Grants with dur > 0 on one serial calendar never share a
            // start, so (at, wire, device) is already unique; the tail
            // fields only make the ordering explicit.
            TraceEvent::WireGrant { at, dur, device, wire, tenant, index, .. } => {
                (at, 5, (wire.code() << 32) | device as u64, ti(tenant, index), dur)
            }
            TraceEvent::PuLease { at, end, device, tenant, index, chunk } => {
                (at, 6, ((chunk as u64) << 32) | device as u64, ti(tenant, index), end)
            }
            TraceEvent::EarlyRelease { at, tenant, index, device } => {
                (at, 7, ti(tenant, index), device as u64, 0)
            }
            TraceEvent::Timeout { at, tenant, index, device } => {
                (at, 8, ti(tenant, index), device as u64, 0)
            }
            TraceEvent::Retry { at, tenant, index, retries, backoff, from_service } => {
                (at, 9, ti(tenant, index), ((retries as u64) << 1) | from_service as u64, backoff)
            }
            TraceEvent::Complete { at, tenant, index, device, submit, .. } => {
                (at, 10, ti(tenant, index), device as u64, submit)
            }
            TraceEvent::Failed { at, tenant, index, device, submit } => {
                (at, 11, ti(tenant, index), device as u64, submit)
            }
        }
    }
}

/// The recording side: an append-only per-shard event buffer. The
/// driver owns `Option<Tracer>` — `None` costs one branch per site and
/// records nothing, the zero-cost-when-disabled contract.
#[derive(Debug, Default)]
pub struct Tracer {
    pub events: Vec<TraceEvent>,
}

impl Tracer {
    pub fn new() -> Self {
        Self { events: Vec::new() }
    }

    #[inline]
    pub fn push(&mut self, e: TraceEvent) {
        self.events.push(e);
    }

    /// Mirror of the engine's permanent-failure cleanup: when a device
    /// dies, the driver truncates its link calendars and PU pool at the
    /// kill instant so phantom future work leaves the busy accounting.
    /// Apply exactly the same surgery to the recorded grants/leases —
    /// drop those starting at or after `now`, clip ones straddling it —
    /// so busy-time conservation stays *exact* on fault runs.
    pub fn truncate_device(&mut self, device: u32, now: Ps) {
        self.events.retain_mut(|e| match e {
            TraceEvent::WireGrant { at, dur, device: d, wire, .. }
                if *d == device && *wire != Wire::Fabric =>
            {
                if *at >= now {
                    return false;
                }
                if *at + *dur > now {
                    *dur = now - *at;
                }
                true
            }
            TraceEvent::PuLease { at, end, device: d, .. } if *d == device => {
                if *at >= now {
                    return false;
                }
                if *end > now {
                    *end = now;
                }
                true
            }
            _ => true,
        });
    }
}

/// One run's complete, canonically ordered event log.
#[derive(Debug, Clone)]
pub struct Trace {
    /// Device count of the topology the run scheduled over.
    pub devices: usize,
    /// Whether a shared upstream fabric was modelled (fabric wire
    /// grants exist only then).
    pub has_fabric: bool,
    /// Events in the canonical total order ([`TraceEvent::key`]).
    pub events: Vec<TraceEvent>,
}

impl Trace {
    /// Canonicalize a (possibly multi-shard) event buffer. Sorting by
    /// the total key makes the result a pure function of the event
    /// *multiset*, which is what the sharded engine preserves — hence
    /// `--jobs N` traces are byte-identical to `--jobs 1`.
    pub fn new(devices: usize, has_fabric: bool, mut events: Vec<TraceEvent>) -> Self {
        events.sort_by(|a, b| a.key().cmp(&b.key()));
        Self { devices, has_fabric, events }
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    /// Latest instant any recorded event touches (span ends included).
    pub fn horizon(&self) -> Ps {
        self.events
            .iter()
            .map(|e| match e {
                TraceEvent::WireGrant { at, dur, .. } => *at + *dur,
                TraceEvent::PuLease { end, .. } => *end,
                other => other.at(),
            })
            .max()
            .unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn grant(at: Ps, dur: Ps, device: u32, wire: Wire) -> TraceEvent {
        TraceEvent::WireGrant { at, dur, device, wire, tenant: 0, index: 0, chunk: 0 }
    }

    #[test]
    fn canonical_order_is_input_order_invariant() {
        let a = TraceEvent::Submit {
            at: 5,
            tenant: 1,
            index: 0,
            class: 0,
            device: 0,
            proto: Protocol::Axle,
        };
        let b = grant(5, 3, 0, Wire::Mem);
        let c = TraceEvent::PuLease { at: 2, end: 9, device: 1, tenant: 0, index: 1, chunk: 0 };
        let t1 = Trace::new(2, false, vec![a.clone(), b.clone(), c.clone()]);
        let t2 = Trace::new(2, false, vec![b, a, c]);
        assert_eq!(t1.events, t2.events);
        assert!(t1.events.windows(2).all(|w| w[0].key() <= w[1].key()));
        assert_eq!(t1.events[0].at(), 2);
    }

    #[test]
    fn truncate_mirrors_calendar_and_pool_semantics() {
        let mut tr = Tracer::new();
        tr.push(grant(10, 5, 0, Wire::Mem)); // clipped to [10, 12)
        tr.push(grant(12, 4, 0, Wire::Io)); // dropped (starts at the kill)
        tr.push(grant(20, 2, 1, Wire::Mem)); // other device: untouched
        tr.push(grant(11, 9, 0, Wire::Fabric)); // fabric: never truncated
        tr.push(TraceEvent::PuLease { at: 4, end: 30, device: 0, tenant: 0, index: 0, chunk: 0 });
        tr.push(TraceEvent::PuLease { at: 13, end: 14, device: 0, tenant: 1, index: 0, chunk: 0 });
        tr.truncate_device(0, 12);
        let t = Trace::new(2, true, tr.events);
        let wire_busy: Ps = t
            .events
            .iter()
            .filter_map(|e| match *e {
                TraceEvent::WireGrant { dur, device: 0, wire, .. } if wire != Wire::Fabric => {
                    Some(dur)
                }
                _ => None,
            })
            .sum();
        assert_eq!(wire_busy, 2); // only the clipped mem grant survives
        let leases: Vec<(Ps, Ps)> = t
            .events
            .iter()
            .filter_map(|e| match *e {
                TraceEvent::PuLease { at, end, device: 0, .. } => Some((at, end)),
                _ => None,
            })
            .collect();
        assert_eq!(leases, vec![(4, 12)]);
        assert!(t.events.iter().any(|e| matches!(
            e,
            TraceEvent::WireGrant { wire: Wire::Fabric, dur: 9, .. }
        )));
        assert_eq!(t.horizon(), 22);
    }
}
