//! In-tree utility substrates.
//!
//! The build is fully offline against a minimal vendored crate set, so the
//! facilities a crates.io project would pull in are implemented here:
//!
//! - [`rng`] — deterministic PCG32 / splitmix64 PRNG (workload synthesis,
//!   property tests)
//! - [`json`] — a small recursive-descent JSON parser + writer (artifact
//!   manifest, config files, metric dumps)
//! - [`args`] — flag-style CLI argument parsing for the `axle` binary
//! - [`fmt`] — duration/percentage formatting (us/ms auto-scaling) for
//!   the CLI and report renderers
//! - [`prop`] — a miniature property-based testing harness (random case
//!   generation with seed-reported failures, used by rust/tests/proptests.rs)

pub mod args;
pub mod fmt;
pub mod json;
pub mod prop;
pub mod rng;
