//! Tiny flag-style CLI argument parser (offline substitute for clap).
//!
//! Supports `--flag`, `--key value`, `--key=value`, `-k value`, and bare
//! positionals. The `axle` binary builds its subcommands on top.

use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    flags: BTreeMap<String, Vec<String>>,
}

impl Args {
    /// Parse from an iterator of argument strings (no program name).
    pub fn parse<I: IntoIterator<Item = String>>(iter: I) -> Self {
        let mut out = Args::default();
        let mut it = iter.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--").or_else(|| a.strip_prefix('-')) {
                if rest.is_empty() {
                    out.positional.push(a);
                    continue;
                }
                if let Some((k, v)) = rest.split_once('=') {
                    out.flags.entry(k.to_string()).or_default().push(v.to_string());
                } else {
                    // Value-taking if the next token isn't a flag.
                    let takes_value =
                        it.peek().map(|n| !n.starts_with('-') || n.parse::<f64>().is_ok());
                    if takes_value == Some(true) {
                        let v = it.next().unwrap();
                        out.flags.entry(rest.to_string()).or_default().push(v);
                    } else {
                        out.flags.entry(rest.to_string()).or_default().push(String::new());
                    }
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    /// Present at all (with or without value)?
    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    /// Last value of `--key`.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).and_then(|v| v.last()).map(|s| s.as_str()).filter(|s| !s.is_empty())
    }

    /// Parse the value of `--key` as `T`.
    pub fn get_as<T: std::str::FromStr>(&self, key: &str) -> Option<T> {
        self.get(key).and_then(|v| v.parse().ok())
    }

    /// First positional (subcommand).
    pub fn command(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse("run --workload e --protocol axle --poll-ns 500 --no-ooo");
        assert_eq!(a.command(), Some("run"));
        assert_eq!(a.get("workload"), Some("e"));
        assert_eq!(a.get_as::<u64>("poll-ns"), Some(500));
        assert!(a.has("no-ooo"));
        assert!(!a.has("fifo"));
    }

    #[test]
    fn equals_form_and_short() {
        let a = parse("run --sf=64 -w e");
        assert_eq!(a.get_as::<u64>("sf"), Some(64));
        assert_eq!(a.get("w"), Some("e"));
    }

    #[test]
    fn boolean_flag_followed_by_flag() {
        let a = parse("run --no-ooo --fifo");
        assert!(a.has("no-ooo") && a.has("fifo"));
        assert_eq!(a.get("no-ooo"), None);
    }

    #[test]
    fn negative_numbers_are_values() {
        let a = parse("run --offset -5");
        assert_eq!(a.get_as::<i64>("offset"), Some(-5));
    }
}
