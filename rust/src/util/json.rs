//! Minimal JSON: recursive-descent parser + writer.
//!
//! Parses the artifact `manifest.json` emitted by `python/compile/aot.py`
//! and serializes metric dumps. Supports the full JSON grammar (objects,
//! arrays, strings with escapes, numbers, booleans, null); numbers are
//! held as f64 (adequate: the manifest's integers are all < 2^53).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, ParseError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ---- typed accessors ----

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().filter(|n| *n >= 0.0 && n.fract() == 0.0).map(|n| n as u64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// `obj["key"]` access; returns Null for missing keys / non-objects.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        self.as_obj().and_then(|o| o.get(key)).unwrap_or(&NULL)
    }

    /// Array index access.
    pub fn at(&self, i: usize) -> &Json {
        static NULL: Json = Json::Null;
        self.as_arr().and_then(|a| a.get(i)).unwrap_or(&NULL)
    }
}

#[derive(Debug, Clone)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "JSON parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { pos: self.i, msg: msg.to_string() }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        self.skip_ws();
        match self.peek().ok_or_else(|| self.err("unexpected end"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => Err(self.err(&format!("unexpected {:?}", c as char))),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, ParseError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {word}")))
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.eat(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let c = *self.b.get(self.i).ok_or_else(|| self.err("unterminated string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = *self.b.get(self.i).ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("short \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.i += 4;
                            // Surrogate pairs: join with the low half.
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                if self.b.get(self.i) == Some(&b'\\')
                                    && self.b.get(self.i + 1) == Some(&b'u')
                                    && self.i + 6 <= self.b.len()
                                {
                                    let lo_hex =
                                        std::str::from_utf8(&self.b[self.i + 2..self.i + 6])
                                            .map_err(|_| self.err("bad surrogate"))?;
                                    let lo = u32::from_str_radix(lo_hex, 16)
                                        .map_err(|_| self.err("bad surrogate"))?;
                                    self.i += 6;
                                    let c =
                                        0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                    char::from_u32(c)
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            out.push(ch.ok_or_else(|| self.err("invalid codepoint"))?);
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                _ => {
                    // Copy UTF-8 bytes through (already valid: input is &str).
                    let start = self.i - 1;
                    let mut end = self.i;
                    while end < self.b.len() && self.b[end] != b'"' && self.b[end] != b'\\' {
                        end += 1;
                    }
                    out.push_str(std::str::from_utf8(&self.b[start..end]).unwrap());
                    self.i = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.i += 1;
            }
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }
}

/// Serialize (compact).
impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\r' => write!(f, "\\r")?,
                        '\t' => write!(f, "\\t")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(o) => {
                write!(f, "{{")?;
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{}", Json::Str(k.clone()), v)?;
                }
                write!(f, "}}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_document() {
        let doc = r#"{
            "knn_a_ccm": {
                "file": "knn_a_ccm.hlo.txt",
                "inputs": [{"shape": [2048], "dtype": "float32"},
                           {"shape": [128, 2048], "dtype": "float32"}],
                "outputs": [{"shape": [128], "dtype": "float32"}],
                "meta": {"dim": 2048, "rows": 128, "workload": "knn"}
            }
        }"#;
        let j = Json::parse(doc).unwrap();
        let e = j.get("knn_a_ccm");
        assert_eq!(e.get("file").as_str(), Some("knn_a_ccm.hlo.txt"));
        assert_eq!(e.get("inputs").at(1).get("shape").at(0).as_usize(), Some(128));
        assert_eq!(e.get("meta").get("dim").as_u64(), Some(2048));
    }

    #[test]
    fn scalars_and_escapes() {
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-12.5e2").unwrap(), Json::Num(-1250.0));
        assert_eq!(
            Json::parse(r#""a\nb\"cAé""#).unwrap(),
            Json::Str("a\nb\"cAé".to_string())
        );
        // Surrogate pair (😀 U+1F600).
        assert_eq!(
            Json::parse(r#""😀""#).unwrap(),
            Json::Str("😀".to_string())
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn roundtrip() {
        let doc = r#"{"a":[1,2.5,null,true,"x\ny"],"b":{"c":-3}}"#;
        let j = Json::parse(doc).unwrap();
        let printed = j.to_string();
        assert_eq!(Json::parse(&printed).unwrap(), j);
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
    }
}
