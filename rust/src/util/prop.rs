//! Miniature property-based testing harness (offline proptest stand-in).
//!
//! `run_prop(name, cases, |rng| { ... })` executes the closure `cases`
//! times with independent deterministic RNG streams; on panic it reports
//! the failing case index and seed so the case can be replayed exactly:
//!
//! ```text
//! property 'ring_no_overflow' failed at case 317 (seed 0x51b3...): <panic>
//! ```
//!
//! Used by `rust/tests/proptests.rs` for the ring-buffer / flow-control /
//! scheduler invariants (DESIGN.md §Memory-correctness invariants).

use super::rng::{splitmix64, Pcg32};

/// Base seed: override with `AXLE_PROP_SEED` to replay a failure.
fn base_seed() -> u64 {
    std::env::var("AXLE_PROP_SEED")
        .ok()
        .and_then(|s| {
            let s = s.trim_start_matches("0x");
            u64::from_str_radix(s, 16).ok().or_else(|| s.parse().ok())
        })
        .unwrap_or(0xA81E_5EED)
}

/// Run `f` across `cases` random cases. Panics (with replay info) on the
/// first failing case.
pub fn run_prop<F: Fn(&mut Pcg32) + std::panic::RefUnwindSafe>(name: &str, cases: u32, f: F) {
    let base = base_seed();
    for case in 0..cases {
        let seed = splitmix64(base ^ (case as u64).wrapping_mul(0x2545_F491_4F6C_DD1D));
        let result = std::panic::catch_unwind(|| {
            let mut rng = Pcg32::seed_from_u64(seed);
            f(&mut rng);
        });
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .map(|s| s.as_str())
                .or_else(|| e.downcast_ref::<&str>().copied())
                .unwrap_or("<non-string panic>");
            panic!(
                "property '{name}' failed at case {case} (seed {seed:#x}, base {base:#x}): {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let count = std::cell::Cell::new(0u32);
        // Cell is not RefUnwindSafe-friendly inside catch_unwind closures,
        // so use an atomic.
        let n = std::sync::atomic::AtomicU32::new(0);
        run_prop("trivial", 50, |rng| {
            let x = rng.below(100);
            assert!(x < 100);
            n.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        });
        assert_eq!(n.load(std::sync::atomic::Ordering::Relaxed), 50);
        let _ = count;
    }

    #[test]
    #[should_panic(expected = "property 'always_fails'")]
    fn failing_property_reports_seed() {
        run_prop("always_fails", 10, |_rng| {
            panic!("boom");
        });
    }

    #[test]
    fn cases_get_distinct_streams() {
        let seen = std::sync::Mutex::new(std::collections::HashSet::new());
        run_prop("distinct", 20, |rng| {
            seen.lock().unwrap().insert(rng.next_u64());
        });
        assert!(seen.lock().unwrap().len() >= 19);
    }
}
