//! Deterministic PRNGs: PCG32 stream generator + splitmix64 hash.
//!
//! PCG-XSH-RR 64/32 (O'Neill 2014) — small, fast, and statistically solid
//! for workload synthesis. Identical seeds produce identical streams on
//! every platform, which the simulator's determinism guarantees rely on.

/// splitmix64: stateless 64-bit mixer (also used to seed PCG).
#[inline]
pub fn splitmix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// PCG-XSH-RR 64/32.
#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    const MULT: u64 = 6364136223846793005;

    pub fn seed_from_u64(seed: u64) -> Self {
        let mut rng = Self {
            state: 0,
            inc: (splitmix64(seed ^ 0xDA3E_39CB_94B9_5BDB) << 1) | 1,
        };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(splitmix64(seed));
        rng.next_u32();
        rng
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(Self::MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 / (1u32 << 24) as f32
    }

    /// Uniform integer in [0, bound) (Lemire-style rejection-free enough
    /// for simulation purposes: modulo with 64-bit source).
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0);
        self.next_u64() % bound
    }

    /// Uniform usize in [lo, hi] inclusive.
    #[inline]
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(hi >= lo);
        lo + self.below(hi - lo + 1)
    }

    /// Standard-normal-ish f32 via sum of 4 uniforms (Irwin–Hall; plenty
    /// for synthetic feature data).
    pub fn approx_normal_f32(&mut self) -> f32 {
        let s: f32 = (0..4).map(|_| self.next_f32()).sum();
        (s - 2.0) * (3.0f32).sqrt()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Pcg32::seed_from_u64(42);
        let mut b = Pcg32::seed_from_u64(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg32::seed_from_u64(1);
        let mut b = Pcg32::seed_from_u64(2);
        let same = (0..100).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 3);
    }

    #[test]
    fn uniform_range_bounds() {
        let mut r = Pcg32::seed_from_u64(7);
        for _ in 0..10_000 {
            let x = r.range(5, 10);
            assert!((5..=10).contains(&x));
            let f = r.next_f64();
            assert!((0.0..1.0).contains(&f));
        }
    }

    #[test]
    fn rough_uniformity() {
        let mut r = Pcg32::seed_from_u64(3);
        let mut buckets = [0u32; 10];
        for _ in 0..100_000 {
            buckets[r.below(10) as usize] += 1;
        }
        for &b in &buckets {
            assert!((8_000..12_000).contains(&b), "bucket {b}");
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg32::seed_from_u64(9);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        assert_ne!(xs, (0..100).collect::<Vec<u32>>());
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<u32>>());
    }

    #[test]
    fn approx_normal_moments() {
        let mut r = Pcg32::seed_from_u64(11);
        let n = 100_000;
        let xs: Vec<f32> = (0..n).map(|_| r.approx_normal_f32()).collect();
        let mean: f32 = xs.iter().sum::<f32>() / n as f32;
        let var: f32 = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }
}
