//! Human-readable quantity formatting shared by the CLI and the report
//! renderers.
//!
//! Every duration in the engine is integer picoseconds ([`Ps`]); the
//! printed figures historically hand-rolled `ps_to_us(x)` with a
//! `{:.2} us` format at each call site. [`fmt_time`] centralizes that
//! and auto-scales: values under 10 ms render in microseconds, larger
//! ones in milliseconds, so a million-request makespan no longer prints
//! as a seven-digit microsecond count. [`fmt_pct`] does the same for
//! the `100.0 * frac` / `{:.1}%` pattern.

use crate::sim::Ps;

/// Render a picosecond duration with automatic unit scaling: two
/// decimals, microseconds below 10 ms (`"1234.56 us"`), milliseconds at
/// or above (`"12.35 ms"`).
pub fn fmt_time(ps: Ps) -> String {
    let us = ps as f64 / 1e6;
    if us < 10_000.0 {
        format!("{us:.2} us")
    } else {
        format!("{:.2} ms", us / 1e3)
    }
}

/// Render a `0..=1` fraction as a percentage with one decimal:
/// `fmt_pct(0.42)` is `"42.0%"`.
pub fn fmt_pct(frac: f64) -> String {
    format!("{:.1}%", 100.0 * frac)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_auto_scales_at_ten_ms() {
        assert_eq!(fmt_time(0), "0.00 us");
        assert_eq!(fmt_time(1_234_560), "1.23 us");
        assert_eq!(fmt_time(9_999_990_000), "9999.99 us");
        assert_eq!(fmt_time(10_000_000_000), "10.00 ms");
        assert_eq!(fmt_time(12_345_000_000), "12.35 ms");
    }

    #[test]
    fn pct_matches_the_historical_format() {
        assert_eq!(fmt_pct(0.0), "0.0%");
        assert_eq!(fmt_pct(0.42), "42.0%");
        assert_eq!(fmt_pct(1.0), "100.0%");
    }
}
