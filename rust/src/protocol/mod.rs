//! The four partial-offloading mechanisms (§III, §IV), as **strategies
//! over borrowed device resources**.
//!
//! | Module | Mechanism | CXL use | Fig. 1 |
//! |---|---|---|---|
//! | [`rp`] | Remote Polling (device-centric) | CXL.io mailbox + remote polls | (a) |
//! | [`bs`] | Bulk-Synchronous flow (memory-centric, M²NDP) | synchronous CXL.mem | (b) |
//! | [`axle`] | Asynchronous Back-Streaming (this paper) | CXL.mem control + CXL.io DMA | (c) |
//!
//! `AXLE_Interrupt` is [`axle`] with interrupt-based notification
//! (§V-B's additional baseline).
//!
//! **Resource-layer architecture.** An engine no longer constructs its
//! own PU pools and links: every `run` borrows a
//! [`DeviceCtx`](crate::topo::DeviceCtx) — one CCM device's PU pool and
//! CXL.mem/CXL.io channels plus the host-side PU pool — owned by the
//! topology layer ([`crate::topo`]). The engine encodes *when* resources
//! are used; the ctx encodes *which physical resources* those are. A
//! fresh ctx per run ([`run`]) reproduces the original single-device,
//! single-tenant timing bit for bit; the multi-tenant driver
//! ([`crate::topo::tenant`]) instead materializes per-tenant ctxs for
//! the devices of a multi-device [`Topology`](crate::topo::Topology)
//! and arbitrates the shared wires.
//!
//! RP and BS are *fully serialized* pipelines by construction (Fig. 6),
//! so they compose directly over the resource models; AXLE runs on the
//! discrete-event engine because overlap, back-pressure and OoO delivery
//! are dynamic.

pub mod axle;
pub mod bs;
pub mod rp;

use crate::config::{Protocol, SchedPolicy, SimConfig};
use crate::metrics::RunMetrics;
use crate::sim::Ps;
use crate::topo::DeviceCtx;
use crate::workload::WorkloadSpec;

/// Host-core cost of one posted-store issue (launch, flow control).
pub(crate) const POSTED_STORE_COST: Ps = 10_000; // 10 ns

/// Firmware cycles to process a mailbox command (RP).
pub(crate) const FIRMWARE_CYCLES: f64 = 200.0;

/// Run `w` under `proto` with `cfg` on fresh single-device resources —
/// the paper's solo-workload setup, bit-identical to the pre-topology
/// engines. Equivalent to [`run_on`] with `DeviceCtx::new(cfg)`.
pub fn run(proto: Protocol, w: &WorkloadSpec, cfg: &SimConfig) -> RunMetrics {
    run_on(proto, w, cfg, &mut DeviceCtx::new(cfg))
}

/// Run `w` under `proto` with `cfg` against borrowed device resources.
pub fn run_on(
    proto: Protocol,
    w: &WorkloadSpec,
    cfg: &SimConfig,
    ctx: &mut DeviceCtx,
) -> RunMetrics {
    match proto {
        Protocol::Rp => rp::run(w, cfg, ctx),
        Protocol::Bs => bs::run(w, cfg, ctx),
        Protocol::Axle => axle::run(w, cfg, false, ctx),
        Protocol::AxleInterrupt => axle::run(w, cfg, true, ctx),
    }
}

/// CCM dispatch order for one iteration's `n` tasks under `policy`.
///
/// - FIFO: offset order — the fine-grained multithreaded pipeline drains
///   tasks in order, so results are emitted in offset order (§V-E).
/// - Round-robin: the scheduler deals partitions across μthread groups,
///   so completion (and hence streaming) order is scrambled relative to
///   offsets — the situation OoO streaming exists for.
///
/// Fills a reusable buffer: the protocol engines call this once per
/// iteration, so recycling the `Vec` keeps the per-run allocation count
/// independent of the iteration count.
pub(crate) fn dispatch_order_into(
    out: &mut Vec<u32>,
    n: usize,
    policy: SchedPolicy,
    seed: u64,
    salt: u64,
) {
    out.clear();
    out.extend(0..n as u32);
    if policy == SchedPolicy::RoundRobin {
        // Deterministic shuffle: sort by splitmix64 hash of (seed, salt, i).
        out.sort_by_key(|&i| {
            let salted = (i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
            let mut z = seed ^ salt.rotate_left(17) ^ salted;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            z ^ (z >> 31)
        });
    }
}

/// A resource lane one pipelined stage occupies (stage-DAG admission,
/// `axle sched --chunks` — see `docs/ARCHITECTURE.md`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Lane {
    /// CXL.mem wire messages (kernel launches, result loads).
    MemWire,
    /// CXL.io wire messages (DMA back-stream batches).
    IoWire,
    /// CCM PU lease windows.
    Ccm,
}

/// One stage of a chunked request: a contiguous slice of one traced
/// channel plus the happens-after edges that gate it. `after` only ever
/// names lower stage indices, so graph order is already topological.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Stage {
    pub lane: Lane,
    /// Which chunk this stage belongs to.
    pub chunk: u32,
    /// Half-open item range `[lo, hi)` into the lane's trace.
    pub lo: u32,
    pub hi: u32,
    /// Happens-after predecessors (stage indices in the same graph).
    pub after: Vec<u32>,
}

/// The per-request stage DAG a protocol emitter produces for chunked
/// admission: `chunks` near-equal contiguous slices of each traced
/// channel, wired serially ([`bs::stage_graph`]) or pipelined
/// ([`axle::stage_graph`]). The traced item offsets already encode the
/// solo overlap structure; the DAG edges tell the closed-loop driver
/// which *contention delays* must propagate between stages.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct StageGraph {
    pub chunks: u32,
    pub stages: Vec<Stage>,
    /// True when consecutive chunks are barrier-chained — the driver
    /// then holds the admission slot until full completion instead of
    /// releasing it at the last CCM stage.
    pub serial: bool,
}

impl StageGraph {
    /// Item range of chunk `k` of `chunks` over a `len`-item trace:
    /// contiguous, near-equal, exactly partitioning `[0, len)`.
    pub fn chunk_range(len: usize, chunks: u32, k: u32) -> (u32, u32) {
        let (len, chunks, k) = (len as u64, chunks as u64, k as u64);
        ((k * len / chunks) as u32, ((k + 1) * len / chunks) as u32)
    }
}

/// Emit the stage DAG for one traced request under `proto` and `mode`:
/// the asynchronous AXLE engines pipeline chunk back-streams by default
/// while the synchronous RP/BS flows chunk serially
/// ([`crate::config::PipelineMode::Auto`]); `Serial` / `Pipelined`
/// force the wiring regardless of protocol.
pub fn stage_graph_for(
    proto: Protocol,
    mode: crate::config::PipelineMode,
    chunks: u32,
    mem_len: usize,
    io_len: usize,
    ccm_len: usize,
) -> StageGraph {
    use crate::config::PipelineMode as Pm;
    let pipelined = match mode {
        Pm::Serial => false,
        Pm::Pipelined => true,
        Pm::Auto => matches!(proto, Protocol::Axle | Protocol::AxleInterrupt),
    };
    if pipelined {
        axle::stage_graph(chunks, mem_len, io_len, ccm_len)
    } else {
        bs::stage_graph(chunks, mem_len, io_len, ccm_len)
    }
}

/// Jittered duration of CCM task `task` in iteration `iter`.
pub(crate) fn jittered_dur(cfg: &SimConfig, base: Ps, iter: usize, task: u32) -> Ps {
    crate::workload::cost::jitter(
        base,
        cfg.jitter,
        cfg.seed,
        ((iter as u64) << 32) | task as u64,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dispatch_order(n: usize, policy: SchedPolicy, seed: u64, salt: u64) -> Vec<u32> {
        let mut idx = Vec::with_capacity(n);
        dispatch_order_into(&mut idx, n, policy, seed, salt);
        idx
    }

    #[test]
    fn fifo_order_is_identity() {
        assert_eq!(dispatch_order(5, SchedPolicy::Fifo, 1, 2), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn rr_order_is_deterministic_permutation() {
        let a = dispatch_order(64, SchedPolicy::RoundRobin, 7, 3);
        let b = dispatch_order(64, SchedPolicy::RoundRobin, 7, 3);
        assert_eq!(a, b);
        assert_ne!(a, (0..64).collect::<Vec<u32>>());
        let mut sorted = a.clone();
        sorted.sort();
        assert_eq!(sorted, (0..64).collect::<Vec<u32>>());
    }

    #[test]
    fn rr_differs_across_iterations() {
        let a = dispatch_order(64, SchedPolicy::RoundRobin, 7, 0);
        let b = dispatch_order(64, SchedPolicy::RoundRobin, 7, 1);
        assert_ne!(a, b);
    }

    #[test]
    fn chunk_ranges_partition_every_length() {
        for len in [0usize, 1, 2, 3, 7, 16, 100] {
            for chunks in [1u32, 2, 3, 4, 7, 32] {
                let mut next = 0u32;
                for k in 0..chunks {
                    let (lo, hi) = StageGraph::chunk_range(len, chunks, k);
                    assert_eq!(lo, next, "len {len} chunks {chunks} k {k}");
                    assert!(hi >= lo);
                    next = hi;
                }
                assert_eq!(next as usize, len);
            }
        }
    }

    #[test]
    fn serial_graph_barrier_chains_chunks() {
        let g = bs::stage_graph(3, 6, 0, 9);
        assert!(g.serial);
        assert_eq!(g.chunks, 3);
        // Two lanes per chunk (io empty), every chunk-k stage naming
        // every chunk-(k-1) stage.
        assert_eq!(g.stages.len(), 6);
        for (i, s) in g.stages.iter().enumerate() {
            assert!(s.after.iter().all(|&a| (a as usize) < i), "topological order");
            let expect: Vec<u32> = if s.chunk == 0 {
                vec![]
            } else {
                g.stages
                    .iter()
                    .enumerate()
                    .filter(|(_, p)| p.chunk + 1 == s.chunk)
                    .map(|(j, _)| j as u32)
                    .collect()
            };
            assert_eq!(s.after, expect, "barrier edges for stage {i}");
        }
    }

    #[test]
    fn pipelined_graph_wires_lane_chains() {
        let g = axle::stage_graph(4, 4, 8, 8);
        assert!(!g.serial);
        assert_eq!(g.stages.len(), 12);
        for (i, s) in g.stages.iter().enumerate() {
            assert!(s.after.iter().all(|&a| (a as usize) < i), "topological order");
            for &a in &s.after {
                let p = &g.stages[a as usize];
                // Edges are either the same-lane chain or the intra-chunk
                // MemWire → Ccm → IoWire forwarding.
                let same_lane_chain = p.lane == s.lane && p.chunk + 1 == s.chunk;
                let intra_chunk = p.chunk == s.chunk
                    && matches!(
                        (p.lane, s.lane),
                        (Lane::MemWire, Lane::Ccm) | (Lane::Ccm, Lane::IoWire)
                    );
                assert!(same_lane_chain || intra_chunk, "stage {i} edge to {a}");
            }
        }
        // Every Ccm stage waits for its chunk's transfer; every IoWire
        // back-stream waits for its chunk's Ccm stage.
        for s in &g.stages {
            match s.lane {
                Lane::Ccm => assert!(s
                    .after
                    .iter()
                    .any(|&a| g.stages[a as usize].lane == Lane::MemWire
                        && g.stages[a as usize].chunk == s.chunk)),
                Lane::IoWire => assert!(s
                    .after
                    .iter()
                    .any(|&a| g.stages[a as usize].lane == Lane::Ccm
                        && g.stages[a as usize].chunk == s.chunk)),
                Lane::MemWire => {}
            }
        }
        // An empty lane's chain passes through missing chunks.
        let sparse = axle::stage_graph(4, 2, 0, 4);
        assert!(sparse.stages.iter().all(|s| s.lane != Lane::IoWire));
    }

    #[test]
    fn stage_graph_for_dispatches_on_protocol_and_mode() {
        use crate::config::PipelineMode as Pm;
        // Auto: synchronous flows chunk serially, AXLE pipelines.
        assert!(stage_graph_for(Protocol::Bs, Pm::Auto, 2, 2, 2, 2).serial);
        assert!(stage_graph_for(Protocol::Rp, Pm::Auto, 2, 2, 2, 2).serial);
        assert!(!stage_graph_for(Protocol::Axle, Pm::Auto, 2, 2, 2, 2).serial);
        assert!(!stage_graph_for(Protocol::AxleInterrupt, Pm::Auto, 2, 2, 2, 2).serial);
        // Forced modes override the protocol default.
        assert!(stage_graph_for(Protocol::Axle, Pm::Serial, 2, 2, 2, 2).serial);
        assert!(!stage_graph_for(Protocol::Bs, Pm::Pipelined, 2, 2, 2, 2).serial);
    }

    #[test]
    fn order_into_reuses_buffer_and_matches() {
        let mut buf = Vec::new();
        dispatch_order_into(&mut buf, 32, SchedPolicy::RoundRobin, 7, 3);
        assert_eq!(buf, dispatch_order(32, SchedPolicy::RoundRobin, 7, 3));
        // Refill with different params: fully overwritten, same length rules.
        dispatch_order_into(&mut buf, 5, SchedPolicy::Fifo, 1, 2);
        assert_eq!(buf, vec![0, 1, 2, 3, 4]);
    }
}
