//! The four partial-offloading mechanisms (§III, §IV), as **strategies
//! over borrowed device resources**.
//!
//! | Module | Mechanism | CXL use | Fig. 1 |
//! |---|---|---|---|
//! | [`rp`] | Remote Polling (device-centric) | CXL.io mailbox + remote polls | (a) |
//! | [`bs`] | Bulk-Synchronous flow (memory-centric, M²NDP) | synchronous CXL.mem | (b) |
//! | [`axle`] | Asynchronous Back-Streaming (this paper) | CXL.mem control + CXL.io DMA | (c) |
//!
//! `AXLE_Interrupt` is [`axle`] with interrupt-based notification
//! (§V-B's additional baseline).
//!
//! **Resource-layer architecture.** An engine no longer constructs its
//! own PU pools and links: every `run` borrows a
//! [`DeviceCtx`](crate::topo::DeviceCtx) — one CCM device's PU pool and
//! CXL.mem/CXL.io channels plus the host-side PU pool — owned by the
//! topology layer ([`crate::topo`]). The engine encodes *when* resources
//! are used; the ctx encodes *which physical resources* those are. A
//! fresh ctx per run ([`run`]) reproduces the original single-device,
//! single-tenant timing bit for bit; the multi-tenant driver
//! ([`crate::topo::tenant`]) instead materializes per-tenant ctxs for
//! the devices of a multi-device [`Topology`](crate::topo::Topology)
//! and arbitrates the shared wires.
//!
//! RP and BS are *fully serialized* pipelines by construction (Fig. 6),
//! so they compose directly over the resource models; AXLE runs on the
//! discrete-event engine because overlap, back-pressure and OoO delivery
//! are dynamic.

pub mod axle;
pub mod bs;
pub mod rp;

use crate::config::{Protocol, SchedPolicy, SimConfig};
use crate::metrics::RunMetrics;
use crate::sim::Ps;
use crate::topo::DeviceCtx;
use crate::workload::WorkloadSpec;

/// Host-core cost of one posted-store issue (launch, flow control).
pub(crate) const POSTED_STORE_COST: Ps = 10_000; // 10 ns

/// Firmware cycles to process a mailbox command (RP).
pub(crate) const FIRMWARE_CYCLES: f64 = 200.0;

/// Run `w` under `proto` with `cfg` on fresh single-device resources —
/// the paper's solo-workload setup, bit-identical to the pre-topology
/// engines. Equivalent to [`run_on`] with `DeviceCtx::new(cfg)`.
pub fn run(proto: Protocol, w: &WorkloadSpec, cfg: &SimConfig) -> RunMetrics {
    run_on(proto, w, cfg, &mut DeviceCtx::new(cfg))
}

/// Run `w` under `proto` with `cfg` against borrowed device resources.
pub fn run_on(
    proto: Protocol,
    w: &WorkloadSpec,
    cfg: &SimConfig,
    ctx: &mut DeviceCtx,
) -> RunMetrics {
    match proto {
        Protocol::Rp => rp::run(w, cfg, ctx),
        Protocol::Bs => bs::run(w, cfg, ctx),
        Protocol::Axle => axle::run(w, cfg, false, ctx),
        Protocol::AxleInterrupt => axle::run(w, cfg, true, ctx),
    }
}

/// CCM dispatch order for one iteration's `n` tasks under `policy`.
///
/// - FIFO: offset order — the fine-grained multithreaded pipeline drains
///   tasks in order, so results are emitted in offset order (§V-E).
/// - Round-robin: the scheduler deals partitions across μthread groups,
///   so completion (and hence streaming) order is scrambled relative to
///   offsets — the situation OoO streaming exists for.
///
/// Fills a reusable buffer: the protocol engines call this once per
/// iteration, so recycling the `Vec` keeps the per-run allocation count
/// independent of the iteration count.
pub(crate) fn dispatch_order_into(
    out: &mut Vec<u32>,
    n: usize,
    policy: SchedPolicy,
    seed: u64,
    salt: u64,
) {
    out.clear();
    out.extend(0..n as u32);
    if policy == SchedPolicy::RoundRobin {
        // Deterministic shuffle: sort by splitmix64 hash of (seed, salt, i).
        out.sort_by_key(|&i| {
            let salted = (i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
            let mut z = seed ^ salt.rotate_left(17) ^ salted;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            z ^ (z >> 31)
        });
    }
}

/// Jittered duration of CCM task `task` in iteration `iter`.
pub(crate) fn jittered_dur(cfg: &SimConfig, base: Ps, iter: usize, task: u32) -> Ps {
    crate::workload::cost::jitter(
        base,
        cfg.jitter,
        cfg.seed,
        ((iter as u64) << 32) | task as u64,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dispatch_order(n: usize, policy: SchedPolicy, seed: u64, salt: u64) -> Vec<u32> {
        let mut idx = Vec::with_capacity(n);
        dispatch_order_into(&mut idx, n, policy, seed, salt);
        idx
    }

    #[test]
    fn fifo_order_is_identity() {
        assert_eq!(dispatch_order(5, SchedPolicy::Fifo, 1, 2), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn rr_order_is_deterministic_permutation() {
        let a = dispatch_order(64, SchedPolicy::RoundRobin, 7, 3);
        let b = dispatch_order(64, SchedPolicy::RoundRobin, 7, 3);
        assert_eq!(a, b);
        assert_ne!(a, (0..64).collect::<Vec<u32>>());
        let mut sorted = a.clone();
        sorted.sort();
        assert_eq!(sorted, (0..64).collect::<Vec<u32>>());
    }

    #[test]
    fn rr_differs_across_iterations() {
        let a = dispatch_order(64, SchedPolicy::RoundRobin, 7, 0);
        let b = dispatch_order(64, SchedPolicy::RoundRobin, 7, 1);
        assert_ne!(a, b);
    }

    #[test]
    fn order_into_reuses_buffer_and_matches() {
        let mut buf = Vec::new();
        dispatch_order_into(&mut buf, 32, SchedPolicy::RoundRobin, 7, 3);
        assert_eq!(buf, dispatch_order(32, SchedPolicy::RoundRobin, 7, 3));
        // Refill with different params: fully overwritten, same length rules.
        dispatch_order_into(&mut buf, 5, SchedPolicy::Fifo, 1, 2);
        assert_eq!(buf, vec![0, 1, 2, 3, 4]);
    }
}
