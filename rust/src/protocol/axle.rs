//! AXLE: Asynchronous Back-Streaming (§IV; Fig. 1c, Fig. 8, Fig. 9).
//!
//! The CCM device *pushes* partial results to host-local ring buffers via
//! CXL.io DMA as they are produced; the host discovers them by polling a
//! single local (cache-bypassed) metadata tail pointer, launches
//! downstream tasks from the ready pool, and returns ring-head indexes to
//! the CCM via posted CXL.mem flow-control stores. Nothing in the pipeline
//! waits for an ACK (the paper's "fully asynchronous interaction").
//!
//! Implemented as a discrete-event simulation over the shared substrate:
//!
//! - CCM task completions feed the **DMA executor**, which forms slot
//!   batches once `pending ≥ streaming factor` (batch = *all* pending —
//!   the natural batching §V-E observes), pays the per-request
//!   preparation latency, claims ring credit from its conservative
//!   producer view, and posts the payload+metadata over CXL.io.
//! - **OoO streaming** (default): results stream in completion order.
//!   Disabled: the executor holds results until offset order is restored
//!   (Fig. 15's ablation).
//! - Host **poll processing** is quantized to the polling interval; the
//!   aggregate cost of the spin polls themselves is charged to host core
//!   stall time (Fig. 13).
//! - **Back-pressure**: zero ring credit blocks the executor; cycles are
//!   accounted (Fig. 16b) and a blocked executor with nothing in flight is
//!   a detected **deadlock** (Fig. 16's (h) edge case).

use std::collections::VecDeque;

use crate::config::SimConfig;
use crate::metrics::RunMetrics;
use crate::ring::{ProducerView, Ring};
use crate::sim::{EventQueue, Ps};
use crate::topo::DeviceCtx;
use crate::workload::WorkloadSpec;

use super::{dispatch_order_into, jittered_dur, Lane, Stage, StageGraph, POSTED_STORE_COST};

/// Metadata record bytes on the wire (payload slot id + task tag).
const META_RECORD_BYTES: u64 = 8;
/// Per-batch tail-update message overhead on the wire.
const BATCH_TAIL_BYTES: u64 = 64;
/// Host cycles per poll iteration beyond the uncached tail read.
const POLL_ROUTINE_CYCLES: f64 = 20.0;
/// Host CPU cost charged per interrupt delivery (context switch slice of
/// the 50 μs handling latency).
const INTERRUPT_CPU: Ps = 5 * crate::sim::US;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Ev {
    /// Launch store arrives at the CCM; iteration `i` begins.
    CcmLaunch(u32),
    CcmTaskDone { iter: u32, task: u32 },
    /// DMA executor finished request preparation; may form the next batch.
    DmaFree,
    /// A back-streamed batch lands in the host DMA region (FIFO queue).
    DmaArrive,
    /// Host polling routine processes arrived metadata (tick-aligned).
    PollProcess,
    /// Interrupt-mode notification fires.
    Interrupt,
    HostTaskDone { iter: u32, h: u32 },
    /// Flow-control store arrives at the CCM (FIFO queue).
    FcArrive,
}

#[derive(Debug, Clone, Copy)]
struct Seg {
    task: u32,
    slots: u32,
    /// First payload slot id — the pointer each metadata record carries
    /// (§IV-C: "each metadata record stores the corresponding payload
    /// slot ID"). The simulator tracks ranges in `task_ranges`, so this
    /// field exists for trace fidelity/debugging only.
    #[allow(dead_code)]
    first_slot: u64,
}

#[derive(Debug)]
struct Batch {
    segs: Vec<Seg>,
    n_slots: u64,
}

#[derive(Debug, Clone, Copy)]
struct PendChunk {
    task: u32,
    slots_left: u32,
}

struct AxleSim<'a> {
    cfg: &'a SimConfig,
    w: &'a WorkloadSpec,
    interrupt_mode: bool,

    q: EventQueue<Ev>,
    /// Borrowed device resources (host/CCM pools, CXL.mem/CXL.io links).
    ctx: &'a mut DeviceCtx,

    // ---- current-iteration state ----
    iter: usize,
    task_slots: Vec<u32>,
    delivered_slots: Vec<u32>,
    task_ranges: Vec<Vec<(u64, u32)>>,
    /// host tasks consuming each CCM task (disjoint in all Table IV specs).
    consumers: Vec<Vec<u32>>,
    hdeps_left: Vec<u32>,
    host_done: usize,
    emitted: usize,
    emit_next: u32,
    /// In-order-streaming hold flags, indexed by task (reused per iter).
    emit_hold: Vec<bool>,
    chain_end: Ps,
    /// Reusable dispatch-order scratch (one fill per iteration).
    order_buf: Vec<u32>,

    // ---- DMA executor ----
    pending: VecDeque<PendChunk>,
    pending_slots: u64,
    dma_busy: bool,
    blocked_since: Option<Ps>,
    pv_payload: ProducerView,
    pv_meta: ProducerView,
    inflight_batches: VecDeque<Batch>,
    /// Adaptive-SF state: EWMA of result production rate (bytes/ps), the
    /// last emission timestamp, and bytes accumulated at that timestamp
    /// (same-cycle wave bursts are one rate sample, not N infinite ones).
    emit_rate_ewma: f64,
    last_emit_at: Ps,
    burst_bytes: f64,

    // ---- host side ----
    ring_payload: Ring,
    ring_meta: Ring,
    arrived: VecDeque<Seg>,
    fc_queue: VecDeque<(u64, u64)>,
    /// Reusable drain buffer for poll processing (no per-poll allocation).
    scratch_segs: Vec<Seg>,
    /// Recycled batch segment vectors (DMA batches churn constantly).
    seg_pool: Vec<Vec<Seg>>,

    // ---- inflight accounting (deadlock detection) ----
    ccm_inflight: usize,
    host_inflight: usize,
    fc_inflight: usize,
    launch_inflight: usize,
    notify_inflight: usize,

    // ---- metrics ----
    stall: Ps,
    backpressure: Ps,
    dma_batches: u64,
    fc_msgs: u64,
    result_bytes: u64,
    finished: bool,
    deadlock: bool,
    total: Ps,
}

pub fn run(
    w: &WorkloadSpec,
    cfg: &SimConfig,
    interrupt_mode: bool,
    ctx: &mut DeviceCtx,
) -> RunMetrics {
    let cap = cfg.axle.dma_slot_capacity;
    // Pre-size every per-iteration buffer from the spec's task counts so
    // the event loop itself never grows a container (§Perf: the LLM row
    // re-ran the allocator tens of thousands of times per simulation
    // before buffers were pooled).
    let max_ccm = w.iters.iter().map(|i| i.ccm_tasks.len()).max().unwrap_or(0);
    let max_host = w.iters.iter().map(|i| i.host_tasks.len()).max().unwrap_or(0);
    let mut sim = AxleSim {
        cfg,
        w,
        interrupt_mode,
        q: EventQueue::new(),
        ctx,
        iter: 0,
        task_slots: Vec::with_capacity(max_ccm),
        delivered_slots: Vec::with_capacity(max_ccm),
        task_ranges: vec![Vec::new(); max_ccm],
        consumers: vec![Vec::new(); max_ccm],
        hdeps_left: Vec::with_capacity(max_host),
        host_done: 0,
        emitted: 0,
        emit_next: 0,
        emit_hold: Vec::with_capacity(max_ccm),
        chain_end: 0,
        order_buf: Vec::with_capacity(max_ccm),
        pending: VecDeque::with_capacity(max_ccm),
        pending_slots: 0,
        dma_busy: false,
        blocked_since: None,
        pv_payload: ProducerView::new(cap),
        pv_meta: ProducerView::new(cap),
        inflight_batches: VecDeque::new(),
        emit_rate_ewma: 0.0,
        last_emit_at: 0,
        burst_bytes: 0.0,
        ring_payload: Ring::new(cap),
        ring_meta: Ring::new(cap),
        arrived: VecDeque::with_capacity(max_ccm),
        fc_queue: VecDeque::new(),
        scratch_segs: Vec::with_capacity(max_ccm),
        seg_pool: Vec::new(),
        ccm_inflight: 0,
        host_inflight: 0,
        fc_inflight: 0,
        launch_inflight: 0,
        notify_inflight: 0,
        stall: 0,
        backpressure: 0,
        dma_batches: 0,
        fc_msgs: 0,
        result_bytes: 0,
        finished: false,
        deadlock: false,
        total: 0,
    };
    sim.run();

    // Aggregate spin-poll cost: the host polls the local metadata tail for
    // the whole run; each poll is an uncached read (the DMA region is
    // cache-bypassed, §IV-C) plus the routine. A poll can't be shorter
    // than its own memory access.
    let (polls, poll_stall) = if interrupt_mode {
        (0u64, 0)
    } else {
        let poll_cost = cfg.host.dram().uncached_access()
            + crate::workload::cost::cycles_time(&cfg.host, POLL_ROUTINE_CYCLES);
        let eff_interval = cfg.axle.poll_interval.max(poll_cost);
        let n = sim.total / eff_interval.max(1);
        (n, (n * poll_cost).min(sim.total))
    };

    let mut m =
        RunMetrics::base(w, if interrupt_mode { "AXLE_Interrupt" } else { "AXLE" });
    m.total = sim.total;
    m.ccm_busy = sim.ctx.ccm.busy().union();
    m.dm_busy = sim.ctx.io.busy().union();
    m.host_busy = sim.ctx.host.busy().union();
    m.host_stall = sim.stall + poll_stall;
    m.backpressure = sim.backpressure;
    m.events = sim.q.popped();
    m.polls = polls;
    m.dma_batches = sim.dma_batches;
    m.fc_messages = sim.fc_msgs;
    m.result_bytes = sim.result_bytes;
    m.deadlock = sim.deadlock;
    m
}

impl<'a> AxleSim<'a> {
    fn run(&mut self) {
        self.result_bytes = self.w.total_result_bytes();
        // First launch: posted CXL.mem store, one-way latency.
        self.stall += POSTED_STORE_COST;
        self.launch_inflight += 1;
        self.q.push_at(self.ctx.mem.one_way(), Ev::CcmLaunch(0));

        while let Some((t, ev)) = self.q.pop() {
            if self.finished {
                break;
            }
            self.handle(t, ev);
            if self.finished {
                break;
            }
            if self.is_stuck() {
                self.deadlock = true;
                self.total = t;
                break;
            }
        }
        if !self.finished && !self.deadlock {
            // Queue drained without completing: also a deadlock.
            self.deadlock = true;
            self.total = self.q.now();
        }
    }

    /// True when no event can ever cause progress again.
    fn is_stuck(&self) -> bool {
        !self.finished
            && self.ccm_inflight == 0
            && self.host_inflight == 0
            && self.inflight_batches.is_empty()
            && self.fc_inflight == 0
            && self.launch_inflight == 0
            && self.notify_inflight == 0
            && !self.dma_busy
            && self.arrived.is_empty()
    }

    fn handle(&mut self, t: Ps, ev: Ev) {
        match ev {
            Ev::CcmLaunch(i) => self.on_launch(t, i as usize),
            Ev::CcmTaskDone { iter, task } => self.on_ccm_done(t, iter as usize, task),
            Ev::DmaFree => {
                self.dma_busy = false;
                self.try_dma(t);
            }
            Ev::DmaArrive => self.on_dma_arrive(t),
            Ev::PollProcess => self.process_arrivals(t),
            Ev::Interrupt => {
                self.notify_inflight -= 1;
                self.stall += INTERRUPT_CPU;
                self.process_arrivals(t);
            }
            Ev::HostTaskDone { iter, h } => self.on_host_done(t, iter as usize, h),
            Ev::FcArrive => self.on_fc_arrive(t),
        }
    }

    fn on_launch(&mut self, t: Ps, i: usize) {
        self.launch_inflight -= 1;
        self.iter = i;
        let iter = &self.w.iters[i];
        let n = iter.ccm_tasks.len();
        let slot = self.cfg.axle.dma_slot_bytes;
        // Reuse per-iteration buffers (§Perf: fresh Vec-of-Vec allocations
        // per iteration dominated the LLM run's 32×4096-task setup).
        self.task_slots.clear();
        self.task_slots.extend(
            iter.ccm_tasks.iter().map(|ct| (ct.result_bytes.div_ceil(slot).max(1)) as u32),
        );
        self.delivered_slots.clear();
        self.delivered_slots.resize(n, 0);
        if self.task_ranges.len() < n {
            self.task_ranges.resize_with(n, Vec::new);
        }
        if self.consumers.len() < n {
            self.consumers.resize_with(n, Vec::new);
        }
        for v in self.task_ranges.iter_mut().take(n) {
            v.clear();
        }
        for v in self.consumers.iter_mut().take(n) {
            v.clear();
        }
        self.hdeps_left.clear();
        self.hdeps_left.extend(iter.host_tasks.iter().map(|h| h.deps.len() as u32));
        for (hi, h) in iter.host_tasks.iter().enumerate() {
            for &d in &h.deps {
                self.consumers[d as usize].push(hi as u32);
            }
        }
        self.host_done = 0;
        self.emitted = 0;
        self.emit_next = 0;
        self.emit_hold.clear();
        self.emit_hold.resize(n, false);

        // Reusable dispatch-order buffer: take it out of `self` for the
        // duration of the dispatch loop (the loop mutates other fields).
        let mut order = std::mem::take(&mut self.order_buf);
        dispatch_order_into(&mut order, n, self.cfg.sched, self.cfg.seed, i as u64);
        for &task in &order {
            let dur = jittered_dur(self.cfg, iter.ccm_tasks[task as usize].dur, i, task);
            let (_, end) = self.ctx.ccm.dispatch(t, dur);
            self.ccm_inflight += 1;
            self.q.push_at(end, Ev::CcmTaskDone { iter: i as u32, task });
        }
        self.order_buf = order;
    }

    fn on_ccm_done(&mut self, t: Ps, iter: usize, task: u32) {
        debug_assert_eq!(iter, self.iter);
        self.ccm_inflight -= 1;
        if self.cfg.axle.ooo_streaming {
            self.emit(t, task);
        } else {
            // In-order streaming: hold completed results until the next
            // offset in sequence is available (Fig. 15, OoO disabled).
            self.emit_hold[task as usize] = true;
            while (self.emit_next as usize) < self.emit_hold.len()
                && self.emit_hold[self.emit_next as usize]
            {
                self.emit_hold[self.emit_next as usize] = false;
                let e = self.emit_next;
                self.emit(t, e);
                self.emit_next += 1;
            }
        }
        self.try_dma(t);
    }

    fn emit(&mut self, t: Ps, task: u32) {
        let slots = self.task_slots[task as usize];
        self.pending.push_back(PendChunk { task, slots_left: slots });
        self.pending_slots += slots as u64;
        self.emitted += 1;
        // Adaptive-SF bookkeeping: EWMA of the production rate, sampling
        // once per distinct timestamp (wave bursts coalesce).
        let bytes = slots as f64 * self.cfg.axle.dma_slot_bytes as f64;
        if t > self.last_emit_at {
            if self.burst_bytes > 0.0 {
                let dt = (t - self.last_emit_at) as f64;
                let inst = self.burst_bytes / dt;
                self.emit_rate_ewma = if self.emit_rate_ewma == 0.0 {
                    inst
                } else {
                    0.75 * self.emit_rate_ewma + 0.25 * inst
                };
            }
            self.last_emit_at = t;
            self.burst_bytes = bytes;
        } else {
            self.burst_bytes += bytes;
        }
    }

    /// Current back-stream trigger threshold in bytes. Fixed policy uses
    /// the configured streaming factor; the adaptive policy targets the
    /// bytes produced during one DMA-preparation period — streaming
    /// immediately when results trickle, batching just enough to amortize
    /// the per-request overhead when they pour (the paper's §V-E "dynamic
    /// SF" future-work knob).
    fn sf_threshold(&self) -> u64 {
        match self.cfg.axle.sf_policy {
            crate::config::SfPolicy::Fixed => self.cfg.axle.streaming_factor_bytes,
            crate::config::SfPolicy::Adaptive => {
                let per_prep = self.emit_rate_ewma * self.cfg.axle.dma_prep as f64;
                let cap = self.cfg.axle.dma_slot_bytes
                    * (self.cfg.axle.dma_slot_capacity as u64 / 4).max(1);
                (per_prep as u64)
                    .clamp(self.cfg.axle.dma_slot_bytes, cap.max(self.cfg.axle.dma_slot_bytes))
            }
        }
    }

    fn try_dma(&mut self, t: Ps) {
        if self.dma_busy || self.finished || self.pending_slots == 0 {
            return;
        }
        let slot = self.cfg.axle.dma_slot_bytes;
        let flush = self.emitted == self.w.iters[self.iter].ccm_tasks.len();
        if !flush && self.pending_slots * slot < self.sf_threshold() {
            return;
        }
        let credit = self.pv_payload.credit().min(self.pv_meta.credit());
        if credit == 0 {
            // Back-pressure: the conservative producer view has no slots.
            if self.blocked_since.is_none() {
                self.blocked_since = Some(t);
            }
            return;
        }
        if let Some(since) = self.blocked_since.take() {
            self.backpressure += t - since;
        }
        let claim = self.pending_slots.min(credit);
        let first = self.pv_payload.try_claim(claim).expect("credit checked");
        let mfirst = self.pv_meta.try_claim(claim).expect("credit checked");
        debug_assert_eq!(first, mfirst);

        // Carve the claimed slots out of pending chunks (chunks may split
        // across batches when credit runs short). Segment vectors are
        // recycled through `seg_pool` across batches.
        let mut segs = self.seg_pool.pop().unwrap_or_default();
        let mut off = 0u64;
        let mut left = claim;
        while left > 0 {
            let chunk = self.pending.front_mut().expect("pending_slots > 0");
            let take = (chunk.slots_left as u64).min(left) as u32;
            segs.push(Seg { task: chunk.task, slots: take, first_slot: first + off });
            self.task_ranges[chunk.task as usize].push((first + off, take));
            chunk.slots_left -= take;
            off += take as u64;
            left -= take as u64;
            if chunk.slots_left == 0 {
                self.pending.pop_front();
            }
        }
        self.pending_slots -= claim;

        // DMA request: preparation latency, then posted write over CXL.io
        // (payload slots + metadata records + tail-update messages).
        self.dma_batches += 1;
        self.dma_busy = true;
        let prep_done = t + self.cfg.axle.dma_prep;
        self.q.push_at(prep_done, Ev::DmaFree);
        let wire_bytes = claim * slot + claim * META_RECORD_BYTES + BATCH_TAIL_BYTES;
        let arrive = self.ctx.io.send(prep_done, wire_bytes, true);
        self.inflight_batches.push_back(Batch { segs, n_slots: claim });
        self.q.push_at(arrive, Ev::DmaArrive);
    }

    fn on_dma_arrive(&mut self, t: Ps) {
        let Batch { mut segs, n_slots } = self.inflight_batches.pop_front().expect("batch FIFO");
        // Ordering invariant (§IV-C): payload slots are fully written
        // before their metadata records become visible — modelled by
        // producing payload first, then metadata, atomically at arrival.
        self.ring_payload.produce(n_slots);
        self.ring_meta.produce(n_slots);
        self.arrived.extend(segs.iter().copied());
        segs.clear();
        self.seg_pool.push(segs);
        if self.interrupt_mode {
            self.notify_inflight += 1;
            self.q.push_at(t + self.cfg.axle.interrupt_latency, Ev::Interrupt);
        } else {
            // The polling routine observes the new metadata tail at the
            // next polling tick.
            let iv = self.cfg.axle.poll_interval.max(1);
            let tick = t.div_ceil(iv) * iv;
            self.q.push_at(tick, Ev::PollProcess);
        }
    }

    fn process_arrivals(&mut self, t: Ps) {
        if self.arrived.is_empty() {
            return;
        }
        let n_slots: u64 = self.arrived.iter().map(|s| s.slots as u64).sum();
        // Metadata is consumed FIFO into the ready pool; its ring head
        // advances immediately.
        let mhead = self.ring_meta.head();
        self.ring_meta.consume_range(mhead, n_slots);
        // Reading the metadata block from the local DMA region.
        self.stall += self.cfg.host.dram().stream_time(n_slots * META_RECORD_BYTES);

        // Drain into the reusable scratch buffer (no per-poll allocation;
        // the loop below dispatches host tasks, which mutates `self`).
        let mut segs = std::mem::take(&mut self.scratch_segs);
        segs.clear();
        segs.extend(self.arrived.drain(..));
        let iter = &self.w.iters[self.iter];
        for seg in &segs {
            self.delivered_slots[seg.task as usize] += seg.slots;
            if self.delivered_slots[seg.task as usize] >= self.task_slots[seg.task as usize] {
                for ci in 0..self.consumers[seg.task as usize].len() {
                    let h = self.consumers[seg.task as usize][ci];
                    self.hdeps_left[h as usize] -= 1;
                    if self.hdeps_left[h as usize] == 0 {
                        // Ready pool → host scheduler: dispatch downstream task.
                        let ready = if iter.host_serial { self.chain_end.max(t) } else { t };
                        let dur = iter.host_tasks[h as usize].dur;
                        let (_, end) = self.ctx.host.dispatch(ready, dur);
                        self.chain_end = end;
                        self.host_inflight += 1;
                        self.q.push_at(end, Ev::HostTaskDone { iter: self.iter as u32, h });
                    }
                }
            }
        }
        segs.clear();
        self.scratch_segs = segs;
        // Flow control: posted CXL.mem store with the updated metadata
        // head (payload head rides along).
        self.send_fc(t);
    }

    fn send_fc(&mut self, t: Ps) {
        self.fc_msgs += 1;
        self.stall += POSTED_STORE_COST;
        self.fc_inflight += 1;
        self.fc_queue.push_back((self.ring_payload.head(), self.ring_meta.head()));
        self.q.push_at(t + self.ctx.mem.one_way(), Ev::FcArrive);
    }

    fn on_fc_arrive(&mut self, t: Ps) {
        self.fc_inflight -= 1;
        let (ph, mh) = self.fc_queue.pop_front().expect("fc FIFO");
        self.pv_payload.update_head(ph);
        self.pv_meta.update_head(mh);
        self.try_dma(t);
    }

    fn on_host_done(&mut self, t: Ps, iter: usize, h: u32) {
        debug_assert_eq!(iter, self.iter);
        self.host_inflight -= 1;
        // Consume the payload slots of this task's dependencies
        // (gap-aware: the head only passes contiguous consumed prefixes).
        // `deps` borrows the workload spec, not `self`, so no clone.
        let deps = &self.w.iters[iter].host_tasks[h as usize].deps;
        for &d in deps {
            let d = d as usize;
            for &(first, n) in &self.task_ranges[d] {
                self.ring_payload.consume_range(first, n as u64);
            }
            self.task_ranges[d].clear();
        }
        self.send_fc(t);
        self.host_done += 1;
        if self.host_done == self.w.iters[iter].host_tasks.len() {
            if iter + 1 == self.w.iters.len() {
                self.finished = true;
                self.total = t;
            } else {
                // Next offload iteration: posted CXL.mem launch store.
                self.stall += POSTED_STORE_COST;
                self.launch_inflight += 1;
                self.q.push_at(t + self.ctx.mem.one_way(), Ev::CcmLaunch(iter as u32 + 1));
            }
        }
    }
}

/// Pipelined stage DAG for a traced request: chunk k's DMA back-stream
/// (`IoWire`) may start as soon as its CCM stage finishes, while chunk
/// k+1's transfer is already in flight — per-lane chains (M_k after
/// M_{k-1}, C_k after C_{k-1}, I_k after I_{k-1}) plus the intra-chunk
/// M_k → C_k → I_k edges. Lanes with no items in a chunk emit no stage
/// and their chain passes through.
pub fn stage_graph(chunks: u32, mem_len: usize, io_len: usize, ccm_len: usize) -> StageGraph {
    let mut stages: Vec<Stage> = Vec::new();
    let (mut m_prev, mut c_prev, mut i_prev): (Option<u32>, Option<u32>, Option<u32>) =
        (None, None, None);
    for k in 0..chunks {
        let mut emit = |lane: Lane, len: usize, deps: &[Option<u32>]| -> Option<u32> {
            let (lo, hi) = StageGraph::chunk_range(len, chunks, k);
            if lo == hi {
                return None;
            }
            let after: Vec<u32> = deps.iter().filter_map(|d| *d).collect();
            let idx = stages.len() as u32;
            stages.push(Stage { lane, chunk: k, lo, hi, after });
            Some(idx)
        };
        let m = emit(Lane::MemWire, mem_len, &[m_prev]);
        let c = emit(Lane::Ccm, ccm_len, &[m, c_prev]);
        let i = emit(Lane::IoWire, io_len, &[c, i_prev]);
        m_prev = m.or(m_prev);
        c_prev = c.or(c_prev);
        i_prev = i.or(i_prev);
    }
    StageGraph { chunks, stages, serial: false }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{poll_factors, Protocol, SimConfig};
    use crate::workload::{by_annotation, CcmTask, HostTask, IterSpec};

    fn solo(w: &WorkloadSpec, cfg: &SimConfig, interrupt: bool) -> RunMetrics {
        run(w, cfg, interrupt, &mut DeviceCtx::new(cfg))
    }

    fn tiny(ccm_dur: Ps, host_dur: Ps, result: u64, iters: usize, tasks: usize) -> WorkloadSpec {
        WorkloadSpec {
            name: "tiny".into(),
            annot: 'x',
            domain: "test",
            iters: (0..iters)
                .map(|_| IterSpec {
                    ccm_tasks: (0..tasks)
                        .map(|_| CcmTask { dur: ccm_dur, result_bytes: result })
                        .collect(),
                    host_tasks: (0..tasks)
                        .map(|i| HostTask { dur: host_dur, deps: vec![i as u32] })
                        .collect(),
                    host_serial: false,
                })
                .collect(),
        }
    }

    #[test]
    fn completes_and_overlaps() {
        // Wave-structured workload with comparable T_C / T_D / T_H — the
        // shape back-streaming exists for: results of wave i stream and
        // feed host tasks while wave i+1 computes.
        let mut cfg = SimConfig::m2ndp();
        cfg.jitter = 0.0;
        let w = tiny(100_000_000, 50_000_000, 65_536, 2, 128); // 100 μs CCM, 64 KB results
        let m = solo(&w, &cfg, false);
        assert!(!m.deadlock);
        let bs = super::super::run(Protocol::Bs, &w, &cfg);
        // Clear pipelining win (BS serializes 8 CCM waves + full load + host).
        assert!(
            (m.total as f64) < 0.8 * bs.total as f64,
            "AXLE {} vs BS {}",
            m.total,
            bs.total
        );
    }

    #[test]
    fn longer_poll_interval_slows_fine_grained_work() {
        let mut cfg = SimConfig::m2ndp();
        cfg.jitter = 0.0;
        let w = tiny(500_000, 200_000, 256, 8, 16);
        let fast = solo(&w, &cfg.clone().with_poll(poll_factors::P1), false);
        let slow = solo(&w, &cfg.clone().with_poll(poll_factors::P100), false);
        assert!(slow.total > fast.total, "p100 {} <= p1 {}", slow.total, fast.total);
    }

    #[test]
    fn interrupt_mode_much_slower_for_light_tasks() {
        // Fig. 10(a)-(d): 50 μs interrupt handling dwarfs light kernels.
        let mut cfg = SimConfig::m2ndp();
        cfg.jitter = 0.0;
        let w = tiny(500_000, 100_000, 256, 8, 16);
        let polled = solo(&w, &cfg, false);
        let interrupted = solo(&w, &cfg, true);
        assert!(
            interrupted.total > 2 * polled.total,
            "interrupt {} vs polled {}",
            interrupted.total,
            polled.total
        );
    }

    #[test]
    fn tight_ring_capacity_causes_backpressure_not_deadlock() {
        // Ring (4 slots) much smaller than a wave's total results (16
        // slots) but each dependency set (2 slots) fits: the ring must
        // churn through under back-pressure without deadlocking.
        let mut cfg = SimConfig::m2ndp();
        cfg.jitter = 0.0;
        cfg.axle.dma_slot_capacity = 4;
        // Slow consumers (5 μs host tasks) against fast producers: credit
        // runs dry while earlier payloads are still being processed.
        let w = tiny(100_000, 5_000_000, 64, 2, 8); // 2 slots per task
        let m = solo(&w, &cfg, false);
        assert!(!m.deadlock, "1:1 deps must drain");
        assert!(m.backpressure > 0, "expected credit stalls");
    }

    #[test]
    fn gather_deps_with_tiny_ring_deadlock() {
        // A host task needing ALL results while the ring can hold only a
        // fraction of them can never launch: Fig. 16's deadlock case.
        let mut cfg = SimConfig::m2ndp();
        cfg.jitter = 0.0;
        cfg.axle.dma_slot_capacity = 4;
        let w = WorkloadSpec {
            name: "gather".into(),
            annot: 'x',
            domain: "test",
            iters: vec![IterSpec {
                ccm_tasks: (0..8).map(|_| CcmTask { dur: 1000, result_bytes: 64 }).collect(),
                host_tasks: vec![HostTask { dur: 1000, deps: (0..8).collect() }],
                host_serial: false,
            }],
        };
        let m = solo(&w, &cfg, false);
        assert!(m.deadlock);
    }

    #[test]
    fn all_table_iv_workloads_beat_or_match_bs() {
        let cfg = SimConfig::m2ndp().with_poll(poll_factors::P1);
        for a in crate::workload::ALL_ANNOTATIONS {
            let w = by_annotation(a, &cfg);
            let axle = solo(&w, &cfg, false);
            let bs = super::super::run(Protocol::Bs, &w, &cfg);
            assert!(!axle.deadlock, "workload {a} deadlocked");
            assert!(
                axle.total <= bs.total * 102 / 100,
                "workload {a}: AXLE {} vs BS {}",
                axle.total,
                bs.total
            );
        }
    }

    #[test]
    fn deterministic_across_runs() {
        let cfg = SimConfig::m2ndp();
        let w = by_annotation('e', &cfg);
        let a = solo(&w, &cfg, false);
        let b = solo(&w, &cfg, false);
        assert_eq!(a.total, b.total);
        assert_eq!(a.events, b.events);
        assert_eq!(a.dma_batches, b.dma_batches);
    }
}
