//! Bulk-Synchronous flow (BS): memory-centric offloading over CXL.mem
//! (Fig. 1b, M²NDP's native mechanism).
//!
//! The host launches the remote kernel with a single CXL.mem store to the
//! kernel-launch address range (the packet filter distinguishes it from a
//! plain store); the hardware barrier suspends the host until the store
//! response returns at kernel completion, then the synchronous result
//! load brings the data over. Protocol overhead is minimal — but the host
//! processing unit stalls for the entire T_C + T_D (§III-C, Fig. 6).
//!
//! The engine is a strategy over a borrowed [`DeviceCtx`] (BS only uses
//! the CXL.mem channel; the ctx's CXL.io link stays idle).

use crate::config::SimConfig;
use crate::metrics::RunMetrics;
use crate::sim::Ps;
use crate::topo::DeviceCtx;
use crate::workload::WorkloadSpec;

use super::{dispatch_order_into, jittered_dur, Lane, Stage, StageGraph};

pub fn run(w: &WorkloadSpec, cfg: &SimConfig, ctx: &mut DeviceCtx) -> RunMetrics {
    let mut t: Ps = 0;
    let mut stall: Ps = 0;
    let mut result_bytes: u64 = 0;
    let mut order: Vec<u32> = Vec::new();

    for (ii, iter) in w.iters.iter().enumerate() {
        // Kernel launch: CXL.mem store; the launch reaches the CCM after a
        // one-way latency, and the response is held by the barrier until
        // the remote kernel completes.
        let launch_t = t + cfg.cxl_mem_rtt / 2;

        dispatch_order_into(&mut order, iter.ccm_tasks.len(), cfg.sched, cfg.seed, ii as u64);
        let mut complete: Ps = launch_t;
        for &task in &order {
            let dur = jittered_dur(cfg, iter.ccm_tasks[task as usize].dur, ii, task);
            let (_, end) = ctx.ccm.dispatch(launch_t, dur);
            complete = complete.max(end);
        }

        // Store response returns (kernel completion ACK).
        let ack = complete + cfg.cxl_mem_rtt / 2;

        // Synchronous result load over CXL.mem.
        let bytes = iter.result_bytes();
        result_bytes += bytes;
        let done = ctx.mem.round_trip(ack, bytes, true);

        // The host core was stalled from issue to load completion.
        stall += done - t;
        t = done;

        // Downstream host tasks.
        let mut chain_end: Ps = t;
        let mut iter_end: Ps = t;
        for h in &iter.host_tasks {
            let ready = if iter.host_serial { chain_end } else { t };
            let (_, end) = ctx.host.dispatch(ready, h.dur);
            chain_end = end;
            iter_end = iter_end.max(end);
        }
        t = iter_end;
    }

    let mut m = RunMetrics::base(w, "BS");
    m.total = t;
    m.ccm_busy = ctx.ccm.busy().union();
    m.dm_busy = ctx.mem.busy().union();
    m.host_busy = ctx.host.busy().union();
    m.host_stall = stall;
    m.result_bytes = result_bytes;
    m
}

/// Serial stage DAG for a traced request: the synchronous BS flow
/// back-streams nothing until the offload returns, so every stage of
/// chunk k happens after every stage of chunk k-1 (a barrier chain).
/// Within a chunk the traced item offsets already encode the
/// launch → CCM → result-load ordering; lanes with no items in a chunk
/// emit no stage.
pub fn stage_graph(chunks: u32, mem_len: usize, io_len: usize, ccm_len: usize) -> StageGraph {
    let mut stages: Vec<Stage> = Vec::new();
    let mut prev: Vec<u32> = Vec::new();
    for k in 0..chunks {
        let mut cur = Vec::new();
        let lanes = [(Lane::MemWire, mem_len), (Lane::IoWire, io_len), (Lane::Ccm, ccm_len)];
        for (lane, len) in lanes {
            let (lo, hi) = StageGraph::chunk_range(len, chunks, k);
            if lo == hi {
                continue;
            }
            cur.push(stages.len() as u32);
            stages.push(Stage { lane, chunk: k, lo, hi, after: prev.clone() });
        }
        if !cur.is_empty() {
            prev = cur;
        }
    }
    StageGraph { chunks, stages, serial: true }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Protocol, SimConfig};
    use crate::workload::{by_annotation, CcmTask, HostTask, IterSpec};

    fn solo(w: &WorkloadSpec, cfg: &SimConfig) -> RunMetrics {
        run(w, cfg, &mut DeviceCtx::new(cfg))
    }

    fn tiny(ccm_dur: Ps, host_dur: Ps, result: u64, iters: usize) -> WorkloadSpec {
        WorkloadSpec {
            name: "tiny".into(),
            annot: 'x',
            domain: "test",
            iters: (0..iters)
                .map(|_| IterSpec {
                    ccm_tasks: vec![CcmTask { dur: ccm_dur, result_bytes: result }],
                    host_tasks: vec![HostTask { dur: host_dur, deps: vec![0] }],
                    host_serial: false,
                })
                .collect(),
        }
    }

    #[test]
    fn bs_beats_rp_on_fine_grained_tasks() {
        // Fig. 3(b): lightweight kernels under BS take a small fraction of
        // their RP cycle count (≈17% in the paper).
        let mut cfg = SimConfig::m2ndp();
        cfg.jitter = 0.0;
        let w = tiny(100_000, 10_000, 64, 4); // 100 ns kernels
        let bs = solo(&w, &cfg);
        let rp = super::super::run(Protocol::Rp, &w, &cfg);
        let ratio = bs.total as f64 / rp.total as f64;
        assert!(ratio < 0.4, "BS/RP = {ratio}");
    }

    #[test]
    fn bs_close_to_rp_on_heavy_tasks() {
        // Fig. 3(a): for ~450 μs kernels, BS ≈ RP (897K vs 888K cycles).
        let mut cfg = SimConfig::m2ndp();
        cfg.jitter = 0.0;
        let w = tiny(448_000_000, 10_000, 64, 1);
        let bs = solo(&w, &cfg);
        let rp = super::super::run(Protocol::Rp, &w, &cfg);
        let ratio = bs.total as f64 / rp.total as f64;
        assert!(ratio > 0.97 && ratio <= 1.0, "BS/RP = {ratio}");
    }

    #[test]
    fn host_stalls_entire_ccm_and_load_time() {
        // §III-C: host idle (and stall) ≈ T_C + T_D.
        let mut cfg = SimConfig::m2ndp();
        cfg.jitter = 0.0;
        let w = tiny(1_000_000, 100_000, 1 << 20, 1);
        let m = solo(&w, &cfg);
        assert!(m.host_stall >= m.ccm_busy + m.dm_busy);
        assert_eq!(m.host_idle(), m.total - 100_000);
    }

    #[test]
    fn runs_all_table_iv_workloads_faster_or_equal_to_rp() {
        let cfg = SimConfig::m2ndp();
        for a in crate::workload::ALL_ANNOTATIONS {
            let w = by_annotation(a, &cfg);
            let bs = solo(&w, &cfg);
            let rp = super::super::run(Protocol::Rp, &w, &cfg);
            assert!(bs.total <= rp.total, "workload {a}: BS {} > RP {}", bs.total, rp.total);
        }
    }
}
