//! Remote Polling (RP): device-centric offloading over CXL.io (Fig. 1a).
//!
//! Per iteration (§III-A): the host writes the kernel descriptor via
//! CXL.mem, enqueues the offload command via a CXL.io mailbox write, then
//! **remote-polls** the device mailbox every `rp_poll_interval` — each
//! poll a full CXL.io round trip that stalls the issuing core. After the
//! completion descriptor is observed, the host dequeues the command
//! (CXL.io) and synchronously loads the results via CXL.mem before
//! running its downstream tasks. Everything is serialized (Fig. 6).
//!
//! The engine is a strategy over a borrowed [`DeviceCtx`]: it owns the
//! control flow, the ctx owns the PU pools and links.

use crate::config::SimConfig;
use crate::metrics::RunMetrics;
use crate::sim::{secs_to_ps, Ps};
use crate::topo::DeviceCtx;
use crate::workload::WorkloadSpec;

use super::{dispatch_order_into, jittered_dur, FIRMWARE_CYCLES};

pub fn run(w: &WorkloadSpec, cfg: &SimConfig, ctx: &mut DeviceCtx) -> RunMetrics {
    let fw_delay: Ps = secs_to_ps(FIRMWARE_CYCLES / (cfg.firmware_freq_ghz * 1e9));

    let mut t: Ps = 0;
    let mut stall: Ps = 0;
    let mut polls: u64 = 0;
    let mut result_bytes: u64 = 0;
    let mut order: Vec<u32> = Vec::new();

    for (ii, iter) in w.iters.iter().enumerate() {
        // (0) Kernel descriptor write to CXL memory (CXL.mem store, sync).
        stall += cfg.cxl_mem_rtt;
        t += cfg.cxl_mem_rtt;

        // (1) Enqueue offload command via CXL.io mailbox (MMIO round trip).
        stall += cfg.cxl_io_rtt;
        t += cfg.cxl_io_rtt;

        // Firmware dequeues and launches the kernel.
        let launch_t = t + fw_delay;

        // CCM task execution (scheduler-ordered, jittered).
        dispatch_order_into(&mut order, iter.ccm_tasks.len(), cfg.sched, cfg.seed, ii as u64);
        let mut complete: Ps = launch_t;
        for &task in &order {
            let dur = jittered_dur(cfg, iter.ccm_tasks[task as usize].dur, ii, task);
            let (_, end) = ctx.ccm.dispatch(launch_t, dur);
            complete = complete.max(end);
        }
        // Firmware writes the completion descriptor to the mailbox.
        let descriptor_ready = complete + fw_delay;

        // (2..n) Remote polling: polls at launch_t + k·interval; each poll
        // is a CXL.io RTT of core stall. Detection happens at the first
        // poll whose response observes the completion descriptor.
        let mut poll_t = launch_t + cfg.rp_poll_interval;
        loop {
            polls += 1;
            stall += cfg.cxl_io_rtt;
            let response_at = poll_t + cfg.cxl_io_rtt;
            if poll_t >= descriptor_ready {
                t = response_at;
                break;
            }
            poll_t += cfg.rp_poll_interval;
        }

        // (n+1) Dequeue the offload command (CXL.io).
        stall += cfg.cxl_io_rtt;
        t += cfg.cxl_io_rtt;

        // Result load over CXL.mem (synchronous, counted as data movement).
        let bytes = iter.result_bytes();
        result_bytes += bytes;
        let done = ctx.mem.round_trip(t, bytes, true);
        stall += done - t;
        t = done;

        // Downstream host tasks: all dependencies are satisfied.
        let mut chain_end: Ps = t;
        let mut iter_end: Ps = t;
        for h in &iter.host_tasks {
            let ready = if iter.host_serial { chain_end } else { t };
            let (_, end) = ctx.host.dispatch(ready, h.dur);
            chain_end = end;
            iter_end = iter_end.max(end);
        }
        t = iter_end;
    }

    let mut m = RunMetrics::base(w, "RP");
    m.total = t;
    m.ccm_busy = ctx.ccm.busy().union();
    m.dm_busy = ctx.mem.busy().union() + ctx.io.busy().union();
    m.host_busy = ctx.host.busy().union();
    m.host_stall = stall;
    m.polls = polls;
    m.result_bytes = result_bytes;
    m
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SimConfig;
    use crate::workload::{by_annotation, CcmTask, HostTask, IterSpec};

    fn solo(w: &WorkloadSpec, cfg: &SimConfig) -> RunMetrics {
        run(w, cfg, &mut DeviceCtx::new(cfg))
    }

    fn tiny_workload(cfg: &SimConfig, ccm_dur: Ps, host_dur: Ps, result: u64) -> WorkloadSpec {
        let _ = cfg;
        WorkloadSpec {
            name: "tiny".into(),
            annot: 'x',
            domain: "test",
            iters: vec![IterSpec {
                ccm_tasks: vec![CcmTask { dur: ccm_dur, result_bytes: result }],
                host_tasks: vec![HostTask { dur: host_dur, deps: vec![0] }],
                host_serial: false,
            }],
        }
    }

    #[test]
    fn pipeline_is_serialized() {
        // Total must be ≥ T_C + T_D + T_H + protocol overheads.
        let mut cfg = SimConfig::m2ndp();
        cfg.jitter = 0.0;
        let w = tiny_workload(&cfg, 1_000_000, 500_000, 4096);
        let m = solo(&w, &cfg);
        assert!(m.total >= m.ccm_busy + m.dm_busy + m.host_busy);
        // Host idle = everything except its own task.
        assert_eq!(m.host_idle(), m.total - 500_000);
    }

    #[test]
    fn poll_count_scales_with_kernel_length() {
        let mut cfg = SimConfig::m2ndp();
        cfg.jitter = 0.0;
        let short = solo(&tiny_workload(&cfg, 1_000_000, 0, 64), &cfg); // 1 μs kernel
        let long = solo(&tiny_workload(&cfg, 10_000_000, 0, 64), &cfg); // 10 μs kernel
        assert!(long.polls > short.polls);
        // ~1 poll per μs of kernel time.
        assert!((long.polls as i64 - 10).abs() <= 2, "polls={}", long.polls);
    }

    #[test]
    fn fine_grained_tasks_dominated_by_polling() {
        // §III-A: a ~100 ns kernel still pays ≥ one full polling interval.
        let mut cfg = SimConfig::m2ndp();
        cfg.jitter = 0.0;
        let w = tiny_workload(&cfg, 100_000, 0, 64);
        let m = solo(&w, &cfg);
        assert!(m.total > cfg.rp_poll_interval, "total={}", m.total);
        assert!(m.total > 10 * 100_000);
    }

    #[test]
    fn runs_all_table_iv_workloads() {
        let cfg = SimConfig::m2ndp();
        for a in crate::workload::ALL_ANNOTATIONS {
            let w = by_annotation(a, &cfg);
            let m = solo(&w, &cfg);
            assert!(m.total > 0, "workload {a}");
            assert!(!m.deadlock);
        }
    }
}
