//! Metadata / payload ring buffers with gap-aware OoO consumption.
//!
//! AXLE partitions the host-local DMA region into two fixed-size ring
//! buffers (§IV-B): a **payload** ring holding back-streamed result data
//! (32 B slots by default) and a **metadata** ring holding one record per
//! payload slot (payload slot id + task tag), which is what the host's
//! polling routine watches.
//!
//! Slot ids are monotonically increasing `u64` sequence numbers; the
//! physical slot is `id % capacity`. The paper's correctness invariants
//! (§IV-C) map onto this type as:
//!
//! - *visibility / flow control*: a producer may only claim slots while
//!   `tail - head_view < capacity`, where `head_view` is its (possibly
//!   stale) view of the consumer head — stale views are **conservative**,
//!   so no overwrite of unconsumed data is possible;
//! - *gap-aware head (OoO)*: consuming slot `s > head` marks it consumed
//!   but the head only advances past the maximal contiguous consumed
//!   prefix;
//! - *monotonicity / wraparound*: `head` and `tail` never decrease and
//!   `tail - head <= capacity` at all times (asserted in debug builds,
//!   property-tested in `rust/tests/proptests.rs`).

/// Host-side ring state: the authoritative head/tail plus the consumed map.
///
/// The consumed map is a bitset indexed by `slot_id % bit_capacity`, where
/// `bit_capacity` is the capacity rounded up to a 64-bit word multiple —
/// since the live window `[head, tail)` never exceeds `capacity ≤
/// bit_capacity`, two live slots can never collide. Bits are cleared as
/// the head passes them, so the words are clean for the next wrap. This
/// keeps produce/consume at O(1) amortized with word-level constants
/// (the §Perf pass replaced a per-slot `VecDeque<bool>` with this).
#[derive(Debug, Clone)]
pub struct Ring {
    capacity: u64,
    bit_capacity: u64,
    head: u64,
    tail: u64,
    consumed: Vec<u64>,
}

impl Ring {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0, "ring capacity must be positive");
        let words = capacity.div_ceil(64);
        Self {
            capacity: capacity as u64,
            bit_capacity: (words * 64) as u64,
            head: 0,
            tail: 0,
            consumed: vec![0u64; words],
        }
    }

    #[inline]
    fn bit(&self, id: u64) -> bool {
        let b = id % self.bit_capacity;
        (self.consumed[(b / 64) as usize] >> (b % 64)) & 1 == 1
    }

    #[inline]
    fn set_bit(&mut self, id: u64) {
        let b = id % self.bit_capacity;
        self.consumed[(b / 64) as usize] |= 1 << (b % 64);
    }


    #[inline]
    pub fn capacity(&self) -> u64 {
        self.capacity
    }

    /// Oldest unreleased slot id (contiguous consumption frontier).
    #[inline]
    pub fn head(&self) -> u64 {
        self.head
    }

    /// Next slot id a producer will write.
    #[inline]
    pub fn tail(&self) -> u64 {
        self.tail
    }

    /// Slots currently held (written or claimed, not yet released).
    #[inline]
    pub fn occupancy(&self) -> u64 {
        self.tail - self.head
    }

    #[inline]
    pub fn free(&self) -> u64 {
        self.capacity - self.occupancy()
    }

    #[inline]
    pub fn is_full(&self) -> bool {
        self.occupancy() == self.capacity
    }

    /// Producer writes `n` slots. Returns the first slot id written.
    /// Panics if the write would overflow — callers must gate on credit
    /// (the producer-side view), so an overflow is a flow-control bug.
    pub fn produce(&mut self, n: u64) -> u64 {
        assert!(
            self.occupancy() + n <= self.capacity,
            "ring overflow: occupancy {} + {} > capacity {} (flow-control violation)",
            self.occupancy(),
            n,
            self.capacity
        );
        let first = self.tail;
        self.tail += n;
        first
    }

    /// Consumer marks slot `id` consumed (possibly out of order), then
    /// advances the head past the maximal contiguous consumed prefix.
    /// Returns the (possibly unchanged) new head.
    pub fn consume(&mut self, id: u64) -> u64 {
        self.mark(id);
        self.advance_head()
    }

    /// Consume a contiguous range `[first, first+n)` with word-level bit
    /// fills (§Perf: ranges are how the AXLE host releases payload slots,
    /// hundreds of thousands per run — per-slot loops dominated profiles).
    pub fn consume_range(&mut self, first: u64, n: u64) -> u64 {
        if n == 0 {
            return self.head;
        }
        assert!(
            first >= self.head && first + n <= self.tail,
            "consume of unwritten/released range [{first}, {}) (head {}, tail {})",
            first + n,
            self.head,
            self.tail
        );
        let mut id = first;
        let end = first + n;
        while id < end {
            let b = id % self.bit_capacity;
            let w = (b / 64) as usize;
            let bit = b % 64;
            let count = (64 - bit).min(end - id);
            let mask = if count == 64 { !0u64 } else { ((1u64 << count) - 1) << bit };
            assert!(self.consumed[w] & mask == 0, "double consume within [{first}, {end})");
            self.consumed[w] |= mask;
            id += count;
        }
        self.advance_head()
    }

    #[inline]
    fn mark(&mut self, id: u64) {
        assert!(
            id >= self.head && id < self.tail,
            "consume of unwritten/released slot {id} (head {}, tail {})",
            self.head,
            self.tail
        );
        assert!(!self.bit(id), "double consume of slot {id}");
        self.set_bit(id);
    }

    /// Advance the head past the maximal contiguous consumed prefix,
    /// clearing bits as it passes — word-at-a-time via trailing-ones runs.
    fn advance_head(&mut self) -> u64 {
        while self.head < self.tail {
            let b = self.head % self.bit_capacity;
            let w = (b / 64) as usize;
            let bit = (b % 64) as u32;
            let run = (((!self.consumed[w]) >> bit).trailing_zeros()).min(64 - bit) as u64;
            if run == 0 {
                break;
            }
            let adv = run.min(self.tail - self.head);
            let mask = if adv == 64 { !0u64 } else { ((1u64 << adv) - 1) << bit };
            self.consumed[w] &= !mask;
            self.head += adv;
            if adv < run || (bit as u64 + run) < 64 {
                // Clamped by tail, or the consumed run ended mid-word.
                break;
            }
        }
        self.head
    }

    /// Check invariants (used by tests/assertions).
    pub fn check_invariants(&self) {
        assert!(self.tail >= self.head);
        assert!(self.tail - self.head <= self.capacity);
        // Head is maximal contiguous: the first pending slot is unconsumed.
        if self.head < self.tail {
            assert!(!self.bit(self.head), "head not advanced past consumed prefix");
        }
        // Consumed bits only within the live window.
        let set: u64 = self.consumed.iter().map(|w| w.count_ones() as u64).sum();
        assert!(set <= self.occupancy(), "stray consumed bits outside window");
    }
}

/// Producer-side (CCM) view of a ring: the true `tail` it owns plus a
/// possibly-stale `head_view` refreshed by flow-control messages. The view
/// is conservative — `head_view <= true head` always — so gating on it can
/// cause back-pressure but never overwrite (§IV-C "stale CCM head index
/// remains conservative enough").
#[derive(Debug, Clone)]
pub struct ProducerView {
    capacity: u64,
    head_view: u64,
    tail: u64,
}

impl ProducerView {
    pub fn new(capacity: usize) -> Self {
        Self { capacity: capacity as u64, head_view: 0, tail: 0 }
    }

    #[inline]
    pub fn credit(&self) -> u64 {
        self.capacity - (self.tail - self.head_view)
    }

    #[inline]
    pub fn tail(&self) -> u64 {
        self.tail
    }

    #[inline]
    pub fn head_view(&self) -> u64 {
        self.head_view
    }

    /// Try to claim `n` slots; returns the first claimed id, or `None`
    /// (back-pressure) if credit is insufficient.
    pub fn try_claim(&mut self, n: u64) -> Option<u64> {
        if self.credit() < n {
            return None;
        }
        let first = self.tail;
        self.tail += n;
        Some(first)
    }

    /// Apply a flow-control message carrying the host's head index.
    /// Out-of-order/stale messages are ignored (monotone update).
    pub fn update_head(&mut self, head: u64) {
        debug_assert!(head <= self.tail, "host head beyond producer tail");
        self.head_view = self.head_view.max(head);
    }
}

/// The paired AXLE rings: metadata + payload, sized per config.
#[derive(Debug, Clone)]
pub struct DmaRegion {
    pub payload: Ring,
    pub metadata: Ring,
}

impl DmaRegion {
    pub fn new(payload_slots: usize, metadata_slots: usize) -> Self {
        Self { payload: Ring::new(payload_slots), metadata: Ring::new(metadata_slots) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn produce_consume_in_order() {
        let mut r = Ring::new(4);
        assert_eq!(r.produce(3), 0);
        assert_eq!(r.occupancy(), 3);
        assert_eq!(r.consume(0), 1);
        assert_eq!(r.consume(1), 2);
        assert_eq!(r.consume(2), 3);
        assert_eq!(r.occupancy(), 0);
        r.check_invariants();
    }

    #[test]
    fn gap_aware_head_stays_put() {
        // Paper §IV-C example: results consumed OoO; head stays at 0 even
        // after slot 1 is consumed, until slot 0 is.
        let mut r = Ring::new(8);
        r.produce(3);
        assert_eq!(r.consume(1), 0); // gap at 0: head unchanged
        assert_eq!(r.consume(2), 0);
        assert_eq!(r.consume(0), 3); // prefix complete: head jumps to 3
        r.check_invariants();
    }

    #[test]
    #[should_panic(expected = "flow-control violation")]
    fn overflow_panics() {
        let mut r = Ring::new(2);
        r.produce(3);
    }

    #[test]
    #[should_panic(expected = "double consume")]
    fn double_consume_panics() {
        let mut r = Ring::new(2);
        r.produce(1);
        r.consume(0);
        // Slot 0 was released by head advance; consuming it again must trip
        // the released-slot assertion... produce another to keep id valid:
        // (directly assert double consume on an unreleased slot)
        let mut r2 = Ring::new(4);
        r2.produce(2);
        r2.consume(1);
        r2.consume(1);
    }

    #[test]
    fn wraparound_many_times() {
        let mut r = Ring::new(4);
        for round in 0..100u64 {
            let first = r.produce(4);
            assert_eq!(first, round * 4);
            r.consume_range(first, 4);
            r.check_invariants();
        }
        assert_eq!(r.head(), 400);
    }

    #[test]
    fn producer_view_backpressure_and_refresh() {
        let mut p = ProducerView::new(4);
        assert_eq!(p.try_claim(4), Some(0));
        assert_eq!(p.try_claim(1), None); // no credit
        p.update_head(2); // host consumed 2 slots
        assert_eq!(p.credit(), 2);
        assert_eq!(p.try_claim(2), Some(4));
        assert_eq!(p.try_claim(1), None);
    }

    #[test]
    fn producer_view_ignores_stale_fc() {
        let mut p = ProducerView::new(4);
        p.try_claim(4).unwrap();
        p.update_head(3);
        p.update_head(1); // stale, reordered message
        assert_eq!(p.head_view(), 3);
    }

    #[test]
    fn stale_view_is_conservative_not_unsafe() {
        // Host has consumed everything but producer never saw FC: producer
        // stalls (conservative) instead of overwriting.
        let mut host = Ring::new(2);
        let mut prod = ProducerView::new(2);
        let first = prod.try_claim(2).unwrap();
        host.produce(2);
        host.consume_range(first, 2);
        // No update_head: credit still zero.
        assert_eq!(prod.try_claim(1), None);
    }
}
