//! Configuration system: Table III hardware parameters, protocol knobs,
//! and the named profiles used across the evaluation.
//!
//! Profiles:
//! - [`SimConfig::m2ndp`] — the paper's default simulation setup (Table III)
//! - [`SimConfig::real_hw`] — the FPGA-prototype profile behind Fig. 4
//!   (slower CCM, 100 μs remote-polling interval, immature CXL IP latency)
//! - [`SimConfig::reduced`] — Fig. 11's cut-down machine (CCM PUs → 8,
//!   host PUs → 4)
//!
//! Every field can be overridden from the CLI (`axle run --help`) or a
//! JSON config file (parsed with the in-tree `util::json`).

use std::collections::BTreeMap;

use crate::mem::DramModel;
use crate::sim::{Ps, NS, US};
use crate::util::json::Json;

/// Which offload mechanism drives the host–CCM interaction.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Protocol {
    /// Device-centric remote polling over CXL.io (Fig. 1a).
    Rp,
    /// Memory-centric bulk-synchronous flow over CXL.mem (Fig. 1b, M²NDP).
    Bs,
    /// Asynchronous back-streaming (Fig. 1c, this paper).
    Axle,
    /// AXLE variant with interrupt-based result notification (§V-B).
    AxleInterrupt,
}

impl Protocol {
    pub fn label(&self) -> &'static str {
        match self {
            Protocol::Rp => "RP",
            Protocol::Bs => "BS",
            Protocol::Axle => "AXLE",
            Protocol::AxleInterrupt => "AXLE_Interrupt",
        }
    }

    /// Parse the CLI/JSON spelling (`rp | bs | axle | axle-interrupt`).
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "rp" => Some(Protocol::Rp),
            "bs" => Some(Protocol::Bs),
            "axle" => Some(Protocol::Axle),
            "axle-interrupt" | "axle_interrupt" => Some(Protocol::AxleInterrupt),
            _ => None,
        }
    }

    /// Lower-case CLI/JSON spelling (the `parse` inverse).
    pub fn key(&self) -> &'static str {
        match self {
            Protocol::Rp => "rp",
            Protocol::Bs => "bs",
            Protocol::Axle => "axle",
            Protocol::AxleInterrupt => "axle-interrupt",
        }
    }

    pub const ALL: [Protocol; 4] =
        [Protocol::Rp, Protocol::Bs, Protocol::Axle, Protocol::AxleInterrupt];
}

/// Task scheduling policy, applied symmetrically to CCM and host (§V-E).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SchedPolicy {
    /// Round-robin across task partitions: results complete out of order.
    RoundRobin,
    /// In-order FIFO: results are emitted in offset order.
    Fifo,
}

/// One side's processing-unit array (host or CCM).
#[derive(Debug, Clone, Copy)]
pub struct PuConfig {
    pub num_pus: usize,
    pub uthreads: usize,
    pub freq_ghz: f64,
    /// Effective FLOPs per cycle per PU (SIMD lanes × issue efficiency,
    /// with μthreads hiding memory latency — calibrated against Fig. 3's
    /// QKVProj cycle counts; see DESIGN.md §Timing model).
    pub flops_per_cycle: f64,
    pub dram_channels: u32,
}

impl PuConfig {
    /// Aggregate GFLOP/s across the PU array.
    pub fn gflops(&self) -> f64 {
        self.num_pus as f64 * self.freq_ghz * self.flops_per_cycle
    }

    pub fn dram(&self) -> DramModel {
        DramModel::ddr5_4800(self.dram_channels)
    }

    /// Cycle time in ps.
    pub fn cycle(&self) -> Ps {
        crate::sim::cycle_ps(self.freq_ghz)
    }
}

/// Streaming-factor policy (§V-E; the paper flags dynamic SF selection as
/// future work — implemented here as an extension, see Fig. 14-ext).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SfPolicy {
    /// Trigger back-streaming at a fixed pending-bytes threshold.
    Fixed,
    /// Adapt the threshold to the observed result-production rate: stream
    /// immediately when results trickle, batch enough to amortize one DMA
    /// preparation period when they pour.
    Adaptive,
}

/// AXLE-specific knobs (Table III bottom half).
#[derive(Debug, Clone, Copy)]
pub struct AxleConfig {
    /// Host local-polling interval (p1 = 50 ns, p10 = 500 ns, p100 = 5 μs).
    pub poll_interval: Ps,
    /// Streaming factor: pending result bytes that trigger a back-stream.
    pub streaming_factor_bytes: u64,
    /// Fixed vs adaptive streaming-factor policy.
    pub sf_policy: SfPolicy,
    /// Single DMA slot size (= ring-buffer slot size), bytes.
    pub dma_slot_bytes: u64,
    /// Ring capacity in slots (both rings; "DMA slot capacity").
    pub dma_slot_capacity: usize,
    /// DMA preparation latency per request (control-plane descriptor work).
    pub dma_prep: Ps,
    /// Interrupt handling latency per DMA request (AXLE_Interrupt only).
    pub interrupt_latency: Ps,
    /// Out-of-order streaming enabled (§IV-C OoO; Fig. 15 ablation).
    pub ooo_streaming: bool,
}

impl Default for AxleConfig {
    fn default() -> Self {
        Self {
            poll_interval: 500 * NS, // p10 default
            streaming_factor_bytes: 32,
            sf_policy: SfPolicy::Fixed,
            dma_slot_bytes: 32,
            dma_slot_capacity: 50_000,
            dma_prep: 500 * NS,
            interrupt_latency: 50 * US,
            ooo_streaming: true,
        }
    }
}

/// Full simulation setup (Table III).
#[derive(Debug, Clone)]
pub struct SimConfig {
    pub host: PuConfig,
    pub ccm: PuConfig,
    /// CXL.mem round-trip protocol latency.
    pub cxl_mem_rtt: Ps,
    /// CXL.io round-trip protocol latency.
    pub cxl_io_rtt: Ps,
    /// Effective CXL data bandwidth, GB/s (shared PHY).
    pub cxl_bw_gbps: f64,
    /// RP: device firmware frequency (mailbox processing).
    pub firmware_freq_ghz: f64,
    /// RP: remote polling interval.
    pub rp_poll_interval: Ps,
    /// Scheduling policy for both CCM and host schedulers.
    pub sched: SchedPolicy,
    pub axle: AxleConfig,
    /// Deterministic seed for task-duration jitter (μthread interleave,
    /// bank conflicts). Same seed ⇒ identical timeline.
    pub seed: u64,
    /// Relative task-duration jitter amplitude (0.0 = none).
    pub jitter: f64,
}

impl SimConfig {
    /// The paper's default setup (Table III).
    pub fn m2ndp() -> Self {
        Self {
            host: PuConfig {
                num_pus: 32,
                uthreads: 2,
                freq_ghz: 3.0,
                // 2-wide general-purpose cores (2 μthreads hide latency but
                // do not add issue width), matching the host:CCM capability
                // ratio the paper's §V workload mix implies.
                flops_per_cycle: 2.0,
                dram_channels: 16,
            },
            ccm: PuConfig {
                num_pus: 16,
                uthreads: 16,
                freq_ghz: 2.0,
                // Calibrated so OPT-2.7B QKVProj ≈ 897K CCM cycles (Fig. 3a):
                // 39.3 MFLOP / (16 PUs × 897K cycles) ≈ 2.75 FLOP/cycle/PU.
                flops_per_cycle: 2.75,
                dram_channels: 16,
            },
            cxl_mem_rtt: 70 * NS,
            cxl_io_rtt: 350 * NS,
            // Effective CXL data bandwidth: x8 PCIe5 PHY (32 GB/s raw) at
            // ~50% efficiency for 64 B flits + protocol/credit overhead —
            // calibrated so PageRank's T_D ≈ T_C (paper Fig. 5b: 48% vs
            // 49.9%).
            cxl_bw_gbps: 16.0,
            firmware_freq_ghz: 2.0,
            rp_poll_interval: 1 * US,
            sched: SchedPolicy::RoundRobin,
            axle: AxleConfig::default(),
            seed: 0xA81E,
            jitter: 0.2,
        }
    }

    /// FPGA-prototype profile (Fig. 4): slow CCM fabric, immature CXL IP,
    /// 100 μs real-hardware polling interval (§III-A).
    pub fn real_hw() -> Self {
        let mut c = Self::m2ndp();
        c.ccm.freq_ghz = 0.3; // FPGA fabric clock
        c.ccm.num_pus = 4; // PFL engines
        c.ccm.flops_per_cycle = 16.0; // hardwired MAC/ACC/CMP pipelines
        c.ccm.dram_channels = 4; // four DIMM slots (Fig. 2)
        c.cxl_mem_rtt = 600 * NS; // immature CXL IP latency
        c.cxl_io_rtt = 2 * US;
        c.cxl_bw_gbps = 8.0;
        c.rp_poll_interval = 100 * US;
        c
    }

    /// Fig. 11's reduced machine: CCM PUs 32→8 and host PUs 16→4 (the
    /// figure's caption counts; our Table III baseline uses its own PU
    /// counts, so scale both by the same 4× reduction).
    pub fn reduced() -> Self {
        let mut c = Self::m2ndp();
        c.ccm.num_pus = (c.ccm.num_pus / 4).max(1);
        c.host.num_pus = (c.host.num_pus / 4).max(1);
        c
    }

    /// Named AXLE polling-factor variants used throughout §V.
    pub fn with_poll(mut self, interval: Ps) -> Self {
        self.axle.poll_interval = interval;
        self
    }

    pub fn with_protocol_defaults(mut self, proto: Protocol) -> Self {
        if proto == Protocol::AxleInterrupt {
            // Interrupt variant keeps polling disabled.
            self.axle.poll_interval = Ps::MAX / 4;
        }
        self
    }

    /// Cheap structural fingerprint of the full simulation setup: an
    /// order-sensitive splitmix64 fold over every field (floats by bit
    /// pattern). Two configs with equal fingerprints produce identical
    /// simulations for all practical purposes; used by the sweep engine
    /// to deduplicate derived configs and label sweep points.
    pub fn fingerprint(&self) -> u64 {
        let mut h = self.workload_fingerprint();
        h = fp_fold(h, self.cxl_mem_rtt);
        h = fp_fold(h, self.cxl_io_rtt);
        h = fp_fold(h, self.firmware_freq_ghz.to_bits());
        h = fp_fold(h, self.rp_poll_interval);
        h = fp_fold(
            h,
            match self.sched {
                SchedPolicy::RoundRobin => 0,
                SchedPolicy::Fifo => 1,
            },
        );
        h = fp_fold(h, self.axle.poll_interval);
        h = fp_fold(h, self.axle.streaming_factor_bytes);
        h = fp_fold(
            h,
            match self.axle.sf_policy {
                SfPolicy::Fixed => 0,
                SfPolicy::Adaptive => 1,
            },
        );
        h = fp_fold(h, self.axle.dma_slot_bytes);
        h = fp_fold(h, self.axle.dma_slot_capacity as u64);
        h = fp_fold(h, self.axle.dma_prep);
        h = fp_fold(h, self.axle.interrupt_latency);
        h = fp_fold(h, self.axle.ooo_streaming as u64);
        h = fp_fold(h, self.seed);
        fp_fold(h, self.jitter.to_bits())
    }

    /// Fingerprint of ONLY the fields Table IV workload generation reads:
    /// `workload::by_annotation` touches `host`, `ccm` and `cxl_bw_gbps`
    /// and nothing else (protocol knobs, scheduling, seed and jitter act
    /// at simulation time). The sweep engine's workload-spec cache keys
    /// on the exact tuple of these fields (`sweep::cache::WorkloadKey`
    /// mirrors this function), so e.g. a poll-factor sweep builds each
    /// spec once. **Keep both in sync with `workload/`** — if a
    /// generator starts reading a new config field, fold it in here and
    /// there.
    pub fn workload_fingerprint(&self) -> u64 {
        let mut h = 0x00A8_1E5E_ED00_0001_u64;
        h = fp_pu(h, &self.host);
        h = fp_pu(h, &self.ccm);
        fp_fold(h, self.cxl_bw_gbps.to_bits())
    }

    /// Serialize to JSON (in-tree `util::json`).
    pub fn to_json(&self) -> Json {
        fn pu(p: &PuConfig) -> Json {
            let mut o = BTreeMap::new();
            o.insert("num_pus".into(), Json::Num(p.num_pus as f64));
            o.insert("uthreads".into(), Json::Num(p.uthreads as f64));
            o.insert("freq_ghz".into(), Json::Num(p.freq_ghz));
            o.insert("flops_per_cycle".into(), Json::Num(p.flops_per_cycle));
            o.insert("dram_channels".into(), Json::Num(p.dram_channels as f64));
            Json::Obj(o)
        }
        let mut ax = BTreeMap::new();
        ax.insert("poll_interval_ps".into(), Json::Num(self.axle.poll_interval as f64));
        let sf_bytes = self.axle.streaming_factor_bytes as f64;
        ax.insert("streaming_factor_bytes".into(), Json::Num(sf_bytes));
        ax.insert("dma_slot_bytes".into(), Json::Num(self.axle.dma_slot_bytes as f64));
        ax.insert("dma_slot_capacity".into(), Json::Num(self.axle.dma_slot_capacity as f64));
        ax.insert("dma_prep_ps".into(), Json::Num(self.axle.dma_prep as f64));
        ax.insert("interrupt_latency_ps".into(), Json::Num(self.axle.interrupt_latency as f64));
        ax.insert("ooo_streaming".into(), Json::Bool(self.axle.ooo_streaming));
        let mut o = BTreeMap::new();
        o.insert("host".into(), pu(&self.host));
        o.insert("ccm".into(), pu(&self.ccm));
        o.insert("cxl_mem_rtt_ps".into(), Json::Num(self.cxl_mem_rtt as f64));
        o.insert("cxl_io_rtt_ps".into(), Json::Num(self.cxl_io_rtt as f64));
        o.insert("cxl_bw_gbps".into(), Json::Num(self.cxl_bw_gbps));
        o.insert("firmware_freq_ghz".into(), Json::Num(self.firmware_freq_ghz));
        o.insert("rp_poll_interval_ps".into(), Json::Num(self.rp_poll_interval as f64));
        o.insert(
            "sched".into(),
            Json::Str(match self.sched {
                SchedPolicy::RoundRobin => "rr".into(),
                SchedPolicy::Fifo => "fifo".into(),
            }),
        );
        o.insert("axle".into(), Json::Obj(ax));
        o.insert("seed".into(), Json::Num(self.seed as f64));
        o.insert("jitter".into(), Json::Num(self.jitter));
        Json::Obj(o)
    }

    /// Deserialize from JSON, starting from the m2ndp defaults (missing
    /// keys keep their default — handy for sparse override files).
    pub fn from_json(j: &Json) -> Self {
        let mut c = Self::m2ndp();
        fn pu(p: &mut PuConfig, j: &Json) {
            if let Some(v) = j.get("num_pus").as_usize() {
                p.num_pus = v;
            }
            if let Some(v) = j.get("uthreads").as_usize() {
                p.uthreads = v;
            }
            if let Some(v) = j.get("freq_ghz").as_f64() {
                p.freq_ghz = v;
            }
            if let Some(v) = j.get("flops_per_cycle").as_f64() {
                p.flops_per_cycle = v;
            }
            if let Some(v) = j.get("dram_channels").as_u64() {
                p.dram_channels = v as u32;
            }
        }
        pu(&mut c.host, j.get("host"));
        pu(&mut c.ccm, j.get("ccm"));
        if let Some(v) = j.get("cxl_mem_rtt_ps").as_u64() {
            c.cxl_mem_rtt = v;
        }
        if let Some(v) = j.get("cxl_io_rtt_ps").as_u64() {
            c.cxl_io_rtt = v;
        }
        if let Some(v) = j.get("cxl_bw_gbps").as_f64() {
            c.cxl_bw_gbps = v;
        }
        if let Some(v) = j.get("firmware_freq_ghz").as_f64() {
            c.firmware_freq_ghz = v;
        }
        if let Some(v) = j.get("rp_poll_interval_ps").as_u64() {
            c.rp_poll_interval = v;
        }
        if let Some(s) = j.get("sched").as_str() {
            c.sched = if s == "fifo" { SchedPolicy::Fifo } else { SchedPolicy::RoundRobin };
        }
        let ax = j.get("axle");
        if let Some(v) = ax.get("poll_interval_ps").as_u64() {
            c.axle.poll_interval = v;
        }
        if let Some(v) = ax.get("streaming_factor_bytes").as_u64() {
            c.axle.streaming_factor_bytes = v;
        }
        if let Some(v) = ax.get("dma_slot_bytes").as_u64() {
            c.axle.dma_slot_bytes = v;
        }
        if let Some(v) = ax.get("dma_slot_capacity").as_usize() {
            c.axle.dma_slot_capacity = v;
        }
        if let Some(v) = ax.get("dma_prep_ps").as_u64() {
            c.axle.dma_prep = v;
        }
        if let Some(v) = ax.get("interrupt_latency_ps").as_u64() {
            c.axle.interrupt_latency = v;
        }
        if let Json::Bool(b) = ax.get("ooo_streaming") {
            c.axle.ooo_streaming = *b;
        }
        if let Some(v) = j.get("seed").as_u64() {
            c.seed = v;
        }
        if let Some(v) = j.get("jitter").as_f64() {
            c.jitter = v;
        }
        c
    }
}

/// Tenant→device placement policy for multi-tenant topologies.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Placement {
    /// Stream `i` lands on device `i mod D`.
    RoundRobin,
    /// Greedy: each stream lands on the device with the least accumulated
    /// solo service demand (ties broken by lowest device id).
    LeastLoaded,
    /// Stream `i` is pinned to device `i mod D` for its whole lifetime,
    /// independent of observed load. Unlike `RoundRobin` (which hands out
    /// devices in *arrival* order) the target is a pure function of the
    /// stream id, so disjoint tenant subsets never interact through the
    /// placement state — the property the sharded closed-loop driver
    /// (`axle sched --jobs N`) relies on to partition devices across
    /// worker threads with a deterministic merge.
    Pinned,
}

impl Placement {
    pub fn label(&self) -> &'static str {
        match self {
            Placement::RoundRobin => "rr",
            Placement::LeastLoaded => "least-loaded",
            Placement::Pinned => "pinned",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "rr" | "round-robin" | "round_robin" => Some(Placement::RoundRobin),
            "least-loaded" | "least_loaded" | "ll" => Some(Placement::LeastLoaded),
            "pinned" | "pin" => Some(Placement::Pinned),
            _ => None,
        }
    }
}

/// Link arbitration policy for the multi-tenant contention replay
/// (consumed by [`crate::topo::fabric`]; ROADMAP QoS follow-on).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum QosPolicy {
    /// Global issue order `(time, tenant id)` — the PR-2 arbiter. No
    /// isolation: one tenant's burst heads-of-line every later arrival.
    Fcfs,
    /// Weighted round-robin at message granularity: each tenant gets
    /// `weight` services per round while backlogged. Zero-weight tenants
    /// are best-effort (served only when nothing weighted is eligible).
    Wrr,
    /// Deficit round-robin at byte granularity: per-tenant quanta
    /// proportional to the configured bandwidth floors, so a backlogged
    /// tenant's long-run wire share never drops below its floor.
    Drr,
}

impl QosPolicy {
    pub fn label(&self) -> &'static str {
        match self {
            QosPolicy::Fcfs => "fcfs",
            QosPolicy::Wrr => "wrr",
            QosPolicy::Drr => "drr",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "fcfs" => Some(QosPolicy::Fcfs),
            "wrr" | "weighted" => Some(QosPolicy::Wrr),
            "drr" | "deficit" => Some(QosPolicy::Drr),
            _ => None,
        }
    }

    pub const ALL: [QosPolicy; 3] = [QosPolicy::Fcfs, QosPolicy::Wrr, QosPolicy::Drr];
}

/// Per-tenant QoS configuration: which arbitration policy governs shared
/// links, plus the per-tenant parameters the weighted policies read.
/// `weights`/`floors` are cycled over tenant ids (`tenant % len`), so a
/// two-class spec like `weights: [4, 1]` alternates priority across any
/// stream count; empty vectors mean "everyone equal".
#[derive(Debug, Clone, PartialEq)]
pub struct QosSpec {
    pub policy: QosPolicy,
    /// WRR services per round, by tenant id (cycled; empty ⇒ all 1).
    pub weights: Vec<u64>,
    /// DRR relative bandwidth floors, by tenant id (cycled; empty ⇒ equal
    /// shares). Only ratios matter: quanta are normalized over the sum.
    pub floors: Vec<f64>,
}

impl Default for QosSpec {
    fn default() -> Self {
        Self { policy: QosPolicy::Fcfs, weights: Vec::new(), floors: Vec::new() }
    }
}

impl QosSpec {
    pub fn fcfs() -> Self {
        Self::default()
    }

    pub fn wrr(weights: Vec<u64>) -> Self {
        Self { policy: QosPolicy::Wrr, weights, floors: Vec::new() }
    }

    pub fn drr(floors: Vec<f64>) -> Self {
        Self { policy: QosPolicy::Drr, weights: Vec::new(), floors }
    }

    /// WRR weight of tenant `tenant` (cycled; default 1).
    pub fn weight(&self, tenant: usize) -> u64 {
        if self.weights.is_empty() {
            1
        } else {
            self.weights[tenant % self.weights.len()]
        }
    }

    /// DRR relative floor of tenant `tenant` (cycled; default 1.0 ⇒ equal
    /// shares). Negative configs are clamped to zero.
    pub fn floor(&self, tenant: usize) -> f64 {
        if self.floors.is_empty() {
            1.0
        } else {
            self.floors[tenant % self.floors.len()].max(0.0)
        }
    }

    pub fn to_json(&self) -> Json {
        let mut o = BTreeMap::new();
        o.insert("policy".into(), Json::Str(self.policy.label().into()));
        o.insert(
            "weights".into(),
            Json::Arr(self.weights.iter().map(|&w| Json::Num(w as f64)).collect()),
        );
        o.insert("floors".into(), Json::Arr(self.floors.iter().map(|&f| Json::Num(f)).collect()));
        Json::Obj(o)
    }

    /// Deserialize, starting from the FCFS defaults (sparse files work).
    pub fn from_json(j: &Json) -> Self {
        let mut s = Self::default();
        if let Some(p) = j.get("policy").as_str().and_then(QosPolicy::parse) {
            s.policy = p;
        }
        if let Some(a) = j.get("weights").as_arr() {
            s.weights = a.iter().filter_map(|v| v.as_u64()).collect();
        }
        if let Some(a) = j.get("floors").as_arr() {
            s.floors = a.iter().filter_map(|v| v.as_f64()).collect();
        }
        s
    }
}

/// Sparse per-device hardware overrides: a heterogeneous topology mixes
/// device classes by replacing individual fields of the base
/// [`SimConfig`] on selected devices (a weak FPGA-class expander next to
/// an ASIC-class one, a narrow-linked device behind a long cable, ...).
/// Every field is optional; an all-`None` override is the identity.
///
/// Consumed by the closed-loop scheduler ([`crate::sched`]), whose solo
/// pass simulates each request on its *device's* effective config —
/// giving the protocol policy real placement trade-offs to exploit. The
/// open-loop tenant path (`axle tenants`) models homogeneous devices
/// only and rejects heterogeneous specs.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct DeviceOverride {
    /// Replace the device's CCM PU count.
    pub ccm_pus: Option<usize>,
    /// Replace the device's CCM PU frequency (GHz).
    pub ccm_freq_ghz: Option<f64>,
    /// Replace the device's CCM per-PU FLOPs/cycle.
    pub ccm_flops_per_cycle: Option<f64>,
    /// Replace the device's CXL link bandwidth (both channels), GB/s.
    pub link_bw_gbps: Option<f64>,
}

impl DeviceOverride {
    /// True iff applying this override changes nothing.
    pub fn is_identity(&self) -> bool {
        self.ccm_pus.is_none()
            && self.ccm_freq_ghz.is_none()
            && self.ccm_flops_per_cycle.is_none()
            && self.link_bw_gbps.is_none()
    }

    /// Apply the override to a device's effective config.
    pub fn apply(&self, cfg: &mut SimConfig) {
        if let Some(v) = self.ccm_pus {
            cfg.ccm.num_pus = v.max(1);
        }
        if let Some(v) = self.ccm_freq_ghz {
            cfg.ccm.freq_ghz = v;
        }
        if let Some(v) = self.ccm_flops_per_cycle {
            cfg.ccm.flops_per_cycle = v;
        }
        if let Some(v) = self.link_bw_gbps {
            cfg.cxl_bw_gbps = v;
        }
    }

    pub fn to_json(&self) -> Json {
        let mut o = BTreeMap::new();
        let num = |v: Option<f64>| match v {
            Some(x) => Json::Num(x),
            None => Json::Null,
        };
        o.insert("ccm_pus".into(), num(self.ccm_pus.map(|v| v as f64)));
        o.insert("ccm_freq_ghz".into(), num(self.ccm_freq_ghz));
        o.insert("ccm_flops_per_cycle".into(), num(self.ccm_flops_per_cycle));
        o.insert("link_bw_gbps".into(), num(self.link_bw_gbps));
        Json::Obj(o)
    }

    /// Deserialize; absent or `null` keys stay `None` (sparse files work).
    pub fn from_json(j: &Json) -> Self {
        Self {
            ccm_pus: j.get("ccm_pus").as_usize(),
            ccm_freq_ghz: j.get("ccm_freq_ghz").as_f64(),
            ccm_flops_per_cycle: j.get("ccm_flops_per_cycle").as_f64(),
            link_bw_gbps: j.get("link_bw_gbps").as_f64(),
        }
    }
}

/// Shared-fabric topology: how many CCM devices hang off the host, how
/// they are shared, and whether an upstream fabric link serializes their
/// aggregate traffic (the multi-tenant scenarios UDON/CXLMemUring argue
/// for). Parsed from JSON (`axle tenants --topo FILE.json`) or CLI flags;
/// consumed by [`crate::topo::Topology`].
#[derive(Debug, Clone, PartialEq)]
pub struct TopologySpec {
    /// Number of CCM devices (each with its own PU pool and CXL.mem/CXL.io
    /// links built from the base [`SimConfig`], then any per-device
    /// override in `overrides`).
    pub devices: usize,
    /// Effective bandwidth of the shared upstream fabric link, GB/s.
    /// `None` ⇒ dedicated per-device uplinks (no cross-device contention).
    pub fabric_bw_gbps: Option<f64>,
    /// Tenant→device placement policy.
    pub placement: Placement,
    /// Arbitration policy + per-tenant parameters for every shared link
    /// (device CXL.mem/CXL.io and the upstream fabric).
    pub qos: QosSpec,
    /// Sparse per-device hardware overrides: entry `i` applies to device
    /// `i`; missing entries (or an empty vector — the homogeneous
    /// default) leave the device at the base config.
    pub overrides: Vec<DeviceOverride>,
}

impl Default for TopologySpec {
    fn default() -> Self {
        Self {
            devices: 1,
            fabric_bw_gbps: None,
            placement: Placement::RoundRobin,
            qos: QosSpec::default(),
            overrides: Vec::new(),
        }
    }
}

impl TopologySpec {
    /// `devices` CCMs behind one shared fabric link of `bw_gbps`.
    pub fn shared_fabric(devices: usize, bw_gbps: f64) -> Self {
        Self { devices, fabric_bw_gbps: Some(bw_gbps), ..Self::default() }
    }

    pub fn with_placement(mut self, placement: Placement) -> Self {
        self.placement = placement;
        self
    }

    pub fn with_qos(mut self, qos: QosSpec) -> Self {
        self.qos = qos;
        self
    }

    /// Install one device's sparse hardware override (the vector is
    /// padded with identity overrides up to `device`).
    pub fn with_override(mut self, device: usize, ov: DeviceOverride) -> Self {
        if self.overrides.len() <= device {
            self.overrides.resize(device + 1, DeviceOverride::default());
        }
        self.overrides[device] = ov;
        self
    }

    /// True iff at least one device deviates from the base config.
    pub fn is_heterogeneous(&self) -> bool {
        self.overrides.iter().any(|o| !o.is_identity())
    }

    /// Effective [`SimConfig`] of device `d`: the base config with this
    /// device's sparse override applied (the base itself when absent).
    pub fn device_config(&self, d: usize, base: &SimConfig) -> SimConfig {
        let mut cfg = base.clone();
        if let Some(o) = self.overrides.get(d) {
            o.apply(&mut cfg);
        }
        cfg
    }

    pub fn to_json(&self) -> Json {
        let mut o = BTreeMap::new();
        o.insert("devices".into(), Json::Num(self.devices as f64));
        match self.fabric_bw_gbps {
            Some(bw) => o.insert("fabric_bw_gbps".into(), Json::Num(bw)),
            None => o.insert("fabric_bw_gbps".into(), Json::Null),
        };
        o.insert("placement".into(), Json::Str(self.placement.label().into()));
        o.insert("qos".into(), self.qos.to_json());
        o.insert(
            "overrides".into(),
            Json::Arr(self.overrides.iter().map(|ov| ov.to_json()).collect()),
        );
        Json::Obj(o)
    }

    /// Deserialize, starting from the defaults (sparse files work).
    pub fn from_json(j: &Json) -> Self {
        let mut s = Self::default();
        if let Some(v) = j.get("devices").as_usize() {
            s.devices = v.max(1);
        }
        if let Some(v) = j.get("fabric_bw_gbps").as_f64() {
            s.fabric_bw_gbps = Some(v);
        }
        if let Some(p) = j.get("placement").as_str().and_then(Placement::parse) {
            s.placement = p;
        }
        if j.get("qos").as_obj().is_some() {
            s.qos = QosSpec::from_json(j.get("qos"));
        }
        if let Some(a) = j.get("overrides").as_arr() {
            s.overrides = a.iter().map(DeviceOverride::from_json).collect();
        }
        s
    }
}

/// Which per-request offload-protocol policy the closed-loop scheduler
/// runs (see [`crate::sched::policy`] for the implementations).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PolicyKind {
    /// Every request uses one pinned protocol — today's (PR-3) behavior.
    Static(Protocol),
    /// Paper-style adaptive choice: pick RP/BS/AXLE per request from the
    /// workload's compute-vs-transfer ratio and the observed link/PU
    /// occupancy of the target device.
    Heuristic,
    /// Clairvoyant per-request choice: the protocol with the smallest
    /// solo runtime on the target device class (solo sims deduped
    /// through the sweep engine's workload cache) — the bound adaptive
    /// policies are reported against.
    Oracle,
    /// Learned, feedback-driven choice: deterministic per-(device ×
    /// workload × protocol) latency estimators fed by each completion's
    /// decomposed latency, with seeded epsilon-greedy exploration whose
    /// rate decays as arms accumulate observations (see
    /// [`crate::sched::learn`]). Placement moves inside the policy:
    /// on non-pinned topologies the learned decider also picks the
    /// device with the lowest estimated completion.
    Learned,
}

impl PolicyKind {
    pub fn label(&self) -> String {
        match self {
            PolicyKind::Static(p) => format!("static-{}", p.key()),
            PolicyKind::Heuristic => "heuristic".into(),
            PolicyKind::Oracle => "oracle".into(),
            PolicyKind::Learned => "learned".into(),
        }
    }

    /// Parse `static` (pins AXLE), `static-<proto>`, `heuristic`,
    /// `oracle`, or `learned`.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "static" => Some(PolicyKind::Static(Protocol::Axle)),
            "heuristic" => Some(PolicyKind::Heuristic),
            "oracle" => Some(PolicyKind::Oracle),
            "learned" => Some(PolicyKind::Learned),
            _ => s.strip_prefix("static-").and_then(Protocol::parse).map(PolicyKind::Static),
        }
    }

    pub const ALL: [PolicyKind; 6] = [
        PolicyKind::Static(Protocol::Rp),
        PolicyKind::Static(Protocol::Bs),
        PolicyKind::Static(Protocol::Axle),
        PolicyKind::Heuristic,
        PolicyKind::Oracle,
        PolicyKind::Learned,
    ];
}

/// What kind of fault one [`FaultEvent`] injects into a device.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum FaultKind {
    /// The device's effective CCM PU throughput drops by `factor` for
    /// the window (PU service times inflate by `factor`).
    DegradePus,
    /// The device's CXL link bandwidth (both channels) drops by `factor`
    /// for the window (wire service times inflate by `factor`).
    DegradeLink,
    /// The device is unresponsive for the window: admission closes and
    /// in-service work is suspended until the window ends.
    Stall,
    /// The device is removed permanently at `at`: in-service work is
    /// killed and re-placed, its admission queue drained onto survivors.
    Fail,
}

impl FaultKind {
    pub fn label(&self) -> &'static str {
        match self {
            FaultKind::DegradePus => "degrade-pus",
            FaultKind::DegradeLink => "degrade-link",
            FaultKind::Stall => "stall",
            FaultKind::Fail => "fail",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "degrade-pus" | "degrade_pus" => Some(FaultKind::DegradePus),
            "degrade-link" | "degrade_link" => Some(FaultKind::DegradeLink),
            "stall" => Some(FaultKind::Stall),
            "fail" => Some(FaultKind::Fail),
            _ => None,
        }
    }
}

/// One deterministic fault scheduled against one device: `kind` strikes
/// device `device` at simulation time `at` and (except for the
/// permanent [`FaultKind::Fail`]) heals at `until`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultEvent {
    /// Target device id.
    pub device: u32,
    pub kind: FaultKind,
    /// Window start (simulation time, ps).
    pub at: Ps,
    /// Window end, ps (ignored for `Fail`, which is permanent; kept
    /// equal to `at` by the constructors).
    pub until: Ps,
    /// Degradation factor (>= 1) for the degrade kinds: PU or wire
    /// service times inflate by this factor inside the window. Ignored
    /// (kept at 1.0) for `Stall`/`Fail`.
    pub factor: f64,
}

impl FaultEvent {
    pub fn fail(device: u32, at: Ps) -> Self {
        Self { device, kind: FaultKind::Fail, at, until: at, factor: 1.0 }
    }

    pub fn stall(device: u32, at: Ps, until: Ps) -> Self {
        Self { device, kind: FaultKind::Stall, at, until, factor: 1.0 }
    }

    pub fn degrade_pus(device: u32, at: Ps, until: Ps, factor: f64) -> Self {
        Self { device, kind: FaultKind::DegradePus, at, until, factor }
    }

    pub fn degrade_link(device: u32, at: Ps, until: Ps, factor: f64) -> Self {
        Self { device, kind: FaultKind::DegradeLink, at, until, factor }
    }

    pub fn to_json(&self) -> Json {
        let mut o = BTreeMap::new();
        o.insert("device".into(), Json::Num(self.device as f64));
        o.insert("kind".into(), Json::Str(self.kind.label().into()));
        o.insert("at_ps".into(), Json::Num(self.at as f64));
        o.insert("until_ps".into(), Json::Num(self.until as f64));
        o.insert("factor".into(), Json::Num(self.factor));
        Json::Obj(o)
    }

    /// Deserialize one event; `i` is its index in the spec (for error
    /// messages). Windows that precede t=0 are rejected here — the
    /// config-parse-time guard against mid-run underflow.
    pub fn from_json(i: usize, j: &Json) -> Result<Self, String> {
        let kind = j
            .get("kind")
            .as_str()
            .and_then(FaultKind::parse)
            .ok_or_else(|| format!("fault event {i}: unknown kind (want degrade-pus | degrade-link | stall | fail)"))?;
        for key in ["at_ps", "until_ps"] {
            if let Some(v) = j.get(key).as_f64() {
                if v < 0.0 {
                    return Err(format!("fault event {i}: window starts before t=0 ({key} = {v})"));
                }
            }
        }
        let device = j
            .get("device")
            .as_u64()
            .ok_or_else(|| format!("fault event {i}: missing device id"))? as u32;
        let at = j.get("at_ps").as_u64().ok_or_else(|| format!("fault event {i}: missing at_ps"))?;
        let until = j.get("until_ps").as_u64().unwrap_or(at);
        let factor = j.get("factor").as_f64().unwrap_or(1.0);
        Ok(Self { device, kind, at, until, factor })
    }
}

/// Deterministic fault-injection schedule plus the recovery-policy knobs
/// of the closed-loop scheduler (`axle sched --faults`, `axle scenario`).
/// An empty schedule is the identity: the driver's fault-free path is
/// pinned bit-identical to the pre-fault engine in `sched_regression.rs`.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultSpec {
    /// Scheduled fault events (any order; the driver sorts by time).
    pub events: Vec<FaultEvent>,
    /// Bounded retry: a request is re-placed at most this many times
    /// before it is marked failed and dropped from the run.
    pub max_retries: u32,
    /// Base retry backoff, ps; doubles with each retry of a request
    /// (exponential backoff).
    pub backoff: Ps,
    /// Per-request timeout multiplier: a queued request whose wait on a
    /// stalled device exceeds `timeout_factor × solo` is requeued.
    pub timeout_factor: f64,
}

impl Default for FaultSpec {
    fn default() -> Self {
        Self { events: Vec::new(), max_retries: 3, backoff: 50 * US, timeout_factor: 8.0 }
    }
}

impl FaultSpec {
    /// A schedule with the default recovery knobs.
    pub fn with(events: Vec<FaultEvent>) -> Self {
        Self { events, ..Self::default() }
    }

    /// True iff the spec injects nothing (the bit-identical identity).
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Validate the schedule against a `devices`-device topology. Called
    /// at config-parse time (CLI and JSON surfaces) so a doomed run —
    /// every device killed, malformed windows, senseless factors — fails
    /// with a clear error instead of a mid-run panic.
    pub fn validate(&self, devices: usize) -> Result<(), String> {
        let mut failed = vec![false; devices];
        for (i, e) in self.events.iter().enumerate() {
            if e.device as usize >= devices {
                return Err(format!(
                    "fault event {i}: device {} out of range (topology has {devices} devices)",
                    e.device
                ));
            }
            if e.kind != FaultKind::Fail && e.until < e.at {
                return Err(format!(
                    "fault event {i}: window ends at {} before it starts at {}",
                    e.until, e.at
                ));
            }
            if matches!(e.kind, FaultKind::DegradePus | FaultKind::DegradeLink) && e.factor < 1.0 {
                return Err(format!(
                    "fault event {i}: degradation factor {} must be >= 1",
                    e.factor
                ));
            }
            if e.kind == FaultKind::Fail {
                failed[e.device as usize] = true;
            }
        }
        if devices > 0 && failed.iter().all(|&f| f) && !self.events.is_empty() {
            return Err(format!(
                "fault spec kills all {devices} devices; at least one must survive"
            ));
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        let mut o = BTreeMap::new();
        o.insert("events".into(), Json::Arr(self.events.iter().map(|e| e.to_json()).collect()));
        o.insert("max_retries".into(), Json::Num(self.max_retries as f64));
        o.insert("backoff_ps".into(), Json::Num(self.backoff as f64));
        o.insert("timeout_factor".into(), Json::Num(self.timeout_factor));
        Json::Obj(o)
    }

    /// Deserialize, starting from the defaults (sparse files work).
    /// Malformed events — unknown kinds, windows before t=0 — are
    /// config-parse-time errors.
    pub fn from_json(j: &Json) -> Result<Self, String> {
        let mut s = Self::default();
        if let Some(a) = j.get("events").as_arr() {
            s.events =
                a.iter().enumerate().map(|(i, e)| FaultEvent::from_json(i, e)).collect::<Result<
                    Vec<_>,
                    _,
                >>()?;
        }
        if let Some(v) = j.get("max_retries").as_u64() {
            s.max_retries = v as u32;
        }
        if let Some(v) = j.get("backoff_ps").as_u64() {
            s.backoff = v;
        }
        if let Some(v) = j.get("timeout_factor").as_f64() {
            s.timeout_factor = v;
        }
        Ok(s)
    }
}

/// How a chunked request's stage DAG is wired (`axle sched
/// --chunk-mode`). The mode decides which happens-after lane edges the
/// protocol emitters install between consecutive chunks.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PipelineMode {
    /// Per-protocol default: the synchronous engines (RP, BS) chunk
    /// serially — they back-stream nothing before the offload returns —
    /// while the AXLE variants pipeline chunk back-streams against the
    /// next chunk's transfer.
    Auto,
    /// Force barrier chaining: every stage of chunk k waits for every
    /// stage of chunk k-1 (chunking without overlap).
    Serial,
    /// Force lane pipelining: a chunk's back-stream starts as soon as
    /// its CCM stage finishes, while the next chunk is still in flight.
    Pipelined,
}

impl PipelineMode {
    pub const ALL: [PipelineMode; 3] =
        [PipelineMode::Auto, PipelineMode::Serial, PipelineMode::Pipelined];

    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "auto" => Some(PipelineMode::Auto),
            "serial" => Some(PipelineMode::Serial),
            "pipelined" => Some(PipelineMode::Pipelined),
            _ => None,
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            PipelineMode::Auto => "auto",
            PipelineMode::Serial => "serial",
            PipelineMode::Pipelined => "pipelined",
        }
    }
}

/// Intra-request pipelining: split every offload into `chunks` stage
/// groups (wire transfer, CCM compute, back-stream per chunk) admitted
/// stage-by-stage against the device calendars (`axle sched --chunks`).
/// `chunks = 1` — and an absent spec — is the identity: whole-request
/// admission, pinned bit-identical in `sched_regression.rs`.
#[derive(Debug, Clone, PartialEq)]
pub struct PipelineSpec {
    /// Chunk count every request's traces are partitioned into (>= 1).
    pub chunks: u32,
    /// How consecutive chunks' stages are ordered.
    pub mode: PipelineMode,
}

impl Default for PipelineSpec {
    fn default() -> Self {
        Self { chunks: 1, mode: PipelineMode::Auto }
    }
}

impl PipelineSpec {
    /// A spec with the default (per-protocol) chunk wiring.
    pub fn with_chunks(chunks: u32) -> Self {
        Self { chunks, ..Self::default() }
    }

    /// Validate at config-parse time (CLI and JSON surfaces) so a
    /// malformed spec fails with a clear message, never a mid-run panic.
    pub fn validate(&self) -> Result<(), String> {
        if self.chunks == 0 {
            return Err(
                "pipeline spec: chunks must be >= 1 (0 chunks would emit no stages)".into()
            );
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        let mut o = BTreeMap::new();
        o.insert("chunks".into(), Json::Num(self.chunks as f64));
        o.insert("mode".into(), Json::Str(self.mode.label().into()));
        Json::Obj(o)
    }

    /// Deserialize, starting from the defaults (sparse files work);
    /// validates before returning.
    pub fn from_json(j: &Json) -> Result<Self, String> {
        let mut s = Self::default();
        if let Some(v) = j.get("chunks").as_u64() {
            s.chunks = v as u32;
        }
        if let Some(m) = j.get("mode").as_str() {
            s.mode = PipelineMode::parse(m)
                .ok_or_else(|| format!("pipeline spec: unknown mode {m:?} (want auto | serial | pipelined)"))?;
        }
        s.validate()?;
        Ok(s)
    }
}

/// Deterministic event tracing: record typed scheduler events into a
/// [`crate::trace::Trace`] alongside the run (`axle sched --trace`,
/// [`crate::sched::run_sched_traced`]). Tracing is observation-only —
/// a traced run's report is bit-identical to the untraced one, pinned
/// in `sched_regression.rs`. `buckets` sizes the fixed-width windowed
/// telemetry view (`--trace-buckets`).
#[derive(Debug, Clone, PartialEq)]
pub struct TraceSpec {
    /// Fixed-width telemetry window count over the run's makespan.
    pub buckets: u32,
}

impl Default for TraceSpec {
    fn default() -> Self {
        Self { buckets: 16 }
    }
}

impl TraceSpec {
    /// Validate at config-parse time (CLI and JSON surfaces) so a
    /// malformed spec fails with a clear message, never a mid-run panic.
    pub fn validate(&self) -> Result<(), String> {
        if self.buckets == 0 {
            return Err("trace spec: buckets must be >= 1 (0 windows would drop the run)".into());
        }
        if self.buckets > 65536 {
            return Err("trace spec: buckets must be <= 65536".into());
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        let mut o = BTreeMap::new();
        o.insert("buckets".into(), Json::Num(self.buckets as f64));
        Json::Obj(o)
    }

    /// Deserialize, starting from the defaults (sparse files work);
    /// validates before returning.
    pub fn from_json(j: &Json) -> Result<Self, String> {
        let mut s = Self::default();
        if let Some(v) = j.get("buckets").as_u64() {
            s.buckets = v as u32;
        }
        s.validate()?;
        Ok(s)
    }
}

/// Declarative description of one closed-loop scheduling run (`axle
/// sched`, [`crate::sched::run_sched`]): K tenants issuing requests
/// against completion feedback, per-device admission queues, and a
/// per-request protocol policy.
#[derive(Debug, Clone, PartialEq)]
pub struct SchedSpec {
    /// Number of concurrent tenants (K).
    pub streams: usize,
    /// Workload annotations, cycled across tenants (tenant `i` runs
    /// `workloads[i % len]` for every one of its requests).
    pub workloads: Vec<char>,
    /// Per-request protocol policy.
    pub policy: PolicyKind,
    /// Closed-loop window: max outstanding (submitted-but-uncompleted)
    /// requests per tenant. The next submission waits for a completion
    /// to free the window (`--depth`).
    pub depth: usize,
    /// Per-device admission-queue service limit: how many admitted
    /// requests one device serves concurrently; the rest wait FIFO in
    /// the device's admission queue (`--admit`).
    pub admit: usize,
    /// Per-tenant priority classes, cycled over tenant ids (`tenant %
    /// len`); higher class = more urgent. A higher class jumps the FIFO
    /// at admission time (preemption-at-admission) but never revokes
    /// in-service work. Empty ⇒ everyone class 0, which degenerates to
    /// the pure FIFO admission order (`--prio`).
    pub priorities: Vec<u32>,
    /// Requests each tenant issues over the run.
    pub requests: usize,
    /// Think time inserted before each submission (after the window
    /// opens), ps.
    pub think: Ps,
    /// `true` (default): closed-loop arrivals driven by completion
    /// feedback. `false`: the PR-3 open-loop arrival process (one
    /// request per tenant, seeded jittered gaps) — the regression pin
    /// for `Static` policies, which requires a homogeneous topology.
    pub closed: bool,
    /// Open-loop load factor (forwarded to the tenant driver when
    /// `closed == false`; unused otherwise).
    pub load: f64,
    /// Arrival-stagger / open-loop jitter seed.
    pub seed: u64,
    /// Exploration aggressiveness for the [`PolicyKind::Learned`]
    /// policy: an epsilon-greedy draw explores with probability
    /// `explore / (visits + explore)` (per device × workload arm set),
    /// so the rate starts at 1 and decays as observations accumulate.
    /// `0` disables exploration (pure greedy over the estimators).
    /// Ignored by the other policies (`--explore`).
    pub explore: u32,
    /// Deterministic fault-injection schedule + recovery knobs. Empty
    /// (the default) means the fault-free engine, bit-identically.
    pub faults: FaultSpec,
    /// `true` (default): retain every [`crate::sched::RequestRun`] for
    /// the report's per-request JSON array and exact percentile math —
    /// the PR-6 behavior, O(n) memory. `false`: streaming mode — the
    /// driver aggregates into fixed-size quantile sketches and recycles
    /// per-request buffers, so a run holds O(live requests) regardless
    /// of total volume; the report's `requests` array is empty and
    /// percentiles are sketch-derived (`axle sched` default; flip back
    /// with `--dump-requests`).
    pub retain: bool,
    /// Intra-request pipelining: `None` (the default) and `chunks = 1`
    /// both mean whole-request admission, bit-identically (`--chunks`).
    pub pipeline: Option<PipelineSpec>,
    /// Deterministic event tracing: `None` (the default) records
    /// nothing; `Some` makes [`crate::sched::run_sched_traced`] return
    /// a [`crate::trace::Trace`] without perturbing the run
    /// (`--trace`, `--trace-buckets`).
    pub trace: Option<TraceSpec>,
}

impl SchedSpec {
    /// `streams` tenants cycling through all Table IV workloads under
    /// the heuristic policy: window 1, two service slots per device,
    /// four requests per tenant, zero think time.
    pub fn new(streams: usize) -> Self {
        Self {
            streams,
            workloads: crate::workload::ALL_ANNOTATIONS.to_vec(),
            policy: PolicyKind::Heuristic,
            depth: 1,
            admit: 2,
            priorities: Vec::new(),
            requests: 4,
            think: 0,
            closed: true,
            load: 1.0,
            seed: 0x5C_4ED0,
            explore: 8,
            faults: FaultSpec::default(),
            retain: true,
            pipeline: None,
            trace: None,
        }
    }

    pub fn with_workloads(mut self, workloads: Vec<char>) -> Self {
        assert!(!workloads.is_empty(), "scheduler mix needs at least one workload");
        self.workloads = workloads;
        self
    }

    pub fn with_policy(mut self, policy: PolicyKind) -> Self {
        self.policy = policy;
        self
    }

    pub fn with_depth(mut self, depth: usize) -> Self {
        assert!(depth > 0, "closed-loop window needs depth >= 1");
        self.depth = depth;
        self
    }

    pub fn with_admit(mut self, admit: usize) -> Self {
        assert!(admit > 0, "device admission needs at least one service slot");
        self.admit = admit;
        self
    }

    pub fn with_priorities(mut self, priorities: Vec<u32>) -> Self {
        self.priorities = priorities;
        self
    }

    /// Priority class of tenant `tenant` (cycled; default class 0).
    pub fn priority(&self, tenant: usize) -> u32 {
        if self.priorities.is_empty() {
            0
        } else {
            self.priorities[tenant % self.priorities.len()]
        }
    }

    pub fn with_requests(mut self, requests: usize) -> Self {
        self.requests = requests;
        self
    }

    pub fn with_think(mut self, think: Ps) -> Self {
        self.think = think;
        self
    }

    pub fn open_loop(mut self) -> Self {
        self.closed = false;
        self
    }

    pub fn with_load(mut self, load: f64) -> Self {
        assert!(load > 0.0, "load factor must be positive");
        self.load = load;
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Exploration aggressiveness for the learned policy (see the
    /// `explore` field; `0` = pure greedy).
    pub fn with_explore(mut self, explore: u32) -> Self {
        self.explore = explore;
        self
    }

    pub fn with_faults(mut self, faults: FaultSpec) -> Self {
        self.faults = faults;
        self
    }

    /// Toggle per-request retention (see the `retain` field). `false`
    /// selects streaming aggregation with recycled request buffers.
    pub fn with_retain(mut self, retain: bool) -> Self {
        self.retain = retain;
        self
    }

    /// Install an intra-request pipelining spec (see [`PipelineSpec`]).
    pub fn with_pipeline(mut self, pipeline: PipelineSpec) -> Self {
        assert!(pipeline.validate().is_ok(), "invalid pipeline spec");
        self.pipeline = Some(pipeline);
        self
    }

    /// Enable deterministic event tracing (see [`TraceSpec`]).
    pub fn with_trace(mut self, trace: TraceSpec) -> Self {
        assert!(trace.validate().is_ok(), "invalid trace spec");
        self.trace = Some(trace);
        self
    }

    /// Effective chunk count: 1 (whole-request admission) without a
    /// pipeline spec.
    pub fn chunks(&self) -> u32 {
        self.pipeline.as_ref().map_or(1, |p| p.chunks.max(1))
    }

    /// Effective chunk wiring mode.
    pub fn chunk_mode(&self) -> PipelineMode {
        self.pipeline.as_ref().map_or(PipelineMode::Auto, |p| p.mode)
    }

    pub fn to_json(&self) -> Json {
        let mut o = BTreeMap::new();
        o.insert("streams".into(), Json::Num(self.streams as f64));
        o.insert("workloads".into(), Json::Str(self.workloads.iter().collect()));
        o.insert("policy".into(), Json::Str(self.policy.label()));
        o.insert("depth".into(), Json::Num(self.depth as f64));
        o.insert("admit".into(), Json::Num(self.admit as f64));
        o.insert(
            "priorities".into(),
            Json::Arr(self.priorities.iter().map(|&p| Json::Num(p as f64)).collect()),
        );
        o.insert("requests".into(), Json::Num(self.requests as f64));
        o.insert("think_ps".into(), Json::Num(self.think as f64));
        o.insert("closed".into(), Json::Bool(self.closed));
        o.insert("load".into(), Json::Num(self.load));
        o.insert("seed".into(), Json::Num(self.seed as f64));
        o.insert("explore".into(), Json::Num(self.explore as f64));
        o.insert("faults".into(), self.faults.to_json());
        o.insert("retain".into(), Json::Bool(self.retain));
        if let Some(p) = &self.pipeline {
            o.insert("pipeline".into(), p.to_json());
        }
        if let Some(t) = &self.trace {
            o.insert("trace".into(), t.to_json());
        }
        Json::Obj(o)
    }

    /// Deserialize, starting from the `new(4)` defaults (sparse override
    /// files work).
    pub fn from_json(j: &Json) -> Self {
        let mut s = Self::new(4);
        if let Some(v) = j.get("streams").as_usize() {
            s.streams = v;
        }
        if let Some(w) = j.get("workloads").as_str() {
            let ws: Vec<char> = w.chars().collect();
            if !ws.is_empty() {
                s.workloads = ws;
            }
        }
        if let Some(p) = j.get("policy").as_str().and_then(PolicyKind::parse) {
            s.policy = p;
        }
        if let Some(v) = j.get("depth").as_usize() {
            s.depth = v.max(1);
        }
        if let Some(v) = j.get("admit").as_usize() {
            s.admit = v.max(1);
        }
        if let Some(a) = j.get("priorities").as_arr() {
            s.priorities = a.iter().filter_map(|v| v.as_u64()).map(|v| v as u32).collect();
        }
        if let Some(v) = j.get("requests").as_usize() {
            s.requests = v;
        }
        if let Some(v) = j.get("think_ps").as_u64() {
            s.think = v;
        }
        if let Json::Bool(b) = j.get("closed") {
            s.closed = *b;
        }
        if let Some(v) = j.get("load").as_f64() {
            s.load = v;
        }
        if let Some(v) = j.get("seed").as_u64() {
            s.seed = v;
        }
        if let Some(v) = j.get("explore").as_u64() {
            s.explore = v as u32;
        }
        if j.get("faults").as_obj().is_some() {
            // Malformed fault schedules are config-parse-time errors with
            // the validation message attached (never a mid-run panic).
            s.faults = FaultSpec::from_json(j.get("faults")).expect("invalid fault spec");
        }
        if let Json::Bool(b) = j.get("retain") {
            s.retain = *b;
        }
        if j.get("pipeline").as_obj().is_some() {
            // Malformed pipeline specs are config-parse-time errors with
            // the validation message attached (never a mid-run panic).
            s.pipeline =
                Some(PipelineSpec::from_json(j.get("pipeline")).expect("invalid pipeline spec"));
        }
        if j.get("trace").as_obj().is_some() {
            // Malformed trace specs are config-parse-time errors with
            // the validation message attached (never a mid-run panic).
            s.trace = Some(TraceSpec::from_json(j.get("trace")).expect("invalid trace spec"));
        }
        s
    }
}

/// Order-sensitive 64-bit fold step for the config fingerprints.
#[inline]
fn fp_fold(h: u64, word: u64) -> u64 {
    crate::util::rng::splitmix64(h.rotate_left(5) ^ word)
}

/// Fold one PU array's parameters into a fingerprint accumulator.
fn fp_pu(h: u64, p: &PuConfig) -> u64 {
    let mut h = fp_fold(h, p.num_pus as u64);
    h = fp_fold(h, p.uthreads as u64);
    h = fp_fold(h, p.freq_ghz.to_bits());
    h = fp_fold(h, p.flops_per_cycle.to_bits());
    fp_fold(h, p.dram_channels as u64)
}

/// Polling-factor shorthand from Fig. 10: p1 = 50 ns, p10 = 500 ns,
/// p100 = 5 μs.
pub mod poll_factors {
    use crate::sim::{Ps, NS, US};

    pub const P1: Ps = 50 * NS;
    pub const P10: Ps = 500 * NS;
    pub const P100: Ps = 5 * US;

    pub fn label(p: Ps) -> &'static str {
        match p {
            P1 => "p1",
            P10 => "p10",
            P100 => "p100",
            _ => "custom",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn m2ndp_matches_table_iii() {
        let c = SimConfig::m2ndp();
        assert_eq!(c.host.num_pus, 32);
        assert_eq!(c.host.uthreads, 2);
        assert_eq!(c.ccm.num_pus, 16);
        assert_eq!(c.ccm.uthreads, 16);
        assert_eq!(c.cxl_mem_rtt, 70 * NS);
        assert_eq!(c.cxl_io_rtt, 350 * NS);
        assert_eq!(c.rp_poll_interval, US);
        assert_eq!(c.axle.dma_slot_bytes, 32);
        assert_eq!(c.axle.dma_slot_capacity, 50_000);
        assert_eq!(c.axle.dma_prep, 500 * NS);
        assert_eq!(c.axle.interrupt_latency, 50 * US);
    }

    #[test]
    fn reduced_cuts_pus_4x() {
        let c = SimConfig::reduced();
        assert_eq!(c.ccm.num_pus, 4);
        assert_eq!(c.host.num_pus, 8);
    }

    #[test]
    fn gflops_sane() {
        let c = SimConfig::m2ndp();
        // CCM: 16 × 2 GHz × 2.75 = 88 GFLOP/s.
        assert!((c.ccm.gflops() - 88.0).abs() < 1e-9);
        // Host: 32 × 3 GHz × 2 = 192 GFLOP/s.
        assert!((c.host.gflops() - 192.0).abs() < 1e-9);
    }

    #[test]
    fn config_json_roundtrip() {
        let mut c = SimConfig::real_hw();
        c.sched = SchedPolicy::Fifo;
        c.axle.ooo_streaming = false;
        let s = c.to_json().to_string();
        let c2 = SimConfig::from_json(&Json::parse(&s).unwrap());
        assert_eq!(c2.host.num_pus, c.host.num_pus);
        assert_eq!(c2.ccm.freq_ghz, c.ccm.freq_ghz);
        assert_eq!(c2.axle.dma_slot_capacity, c.axle.dma_slot_capacity);
        assert_eq!(c2.sched, SchedPolicy::Fifo);
        assert!(!c2.axle.ooo_streaming);
        assert_eq!(c2.rp_poll_interval, c.rp_poll_interval);
    }

    #[test]
    fn fingerprint_distinguishes_profiles_and_is_stable() {
        let a = SimConfig::m2ndp();
        let b = SimConfig::m2ndp();
        assert_eq!(a.fingerprint(), b.fingerprint());
        assert_eq!(a.workload_fingerprint(), b.workload_fingerprint());
        for other in [SimConfig::real_hw(), SimConfig::reduced()] {
            assert_ne!(a.fingerprint(), other.fingerprint());
            assert_ne!(a.workload_fingerprint(), other.workload_fingerprint());
        }
    }

    #[test]
    fn workload_fingerprint_ignores_protocol_knobs() {
        let base = SimConfig::m2ndp();
        let mut c = base.clone();
        c.axle.poll_interval = poll_factors::P100;
        c.axle.streaming_factor_bytes = 4096;
        c.axle.dma_slot_capacity /= 2;
        c.sched = SchedPolicy::Fifo;
        c.seed = 77;
        c.jitter = 0.0;
        // Simulation-time knobs change the full fingerprint only.
        assert_eq!(base.workload_fingerprint(), c.workload_fingerprint());
        assert_ne!(base.fingerprint(), c.fingerprint());
        // Generation-relevant fields change both.
        let mut g = base.clone();
        g.ccm.num_pus = 8;
        assert_ne!(base.workload_fingerprint(), g.workload_fingerprint());
        assert_ne!(base.fingerprint(), g.fingerprint());
        let mut bw = base.clone();
        bw.cxl_bw_gbps = 8.0;
        assert_ne!(base.workload_fingerprint(), bw.workload_fingerprint());
    }

    #[test]
    fn topology_spec_json_roundtrip() {
        let t = TopologySpec::shared_fabric(4, 16.0)
            .with_placement(Placement::LeastLoaded)
            .with_qos(QosSpec::wrr(vec![4, 1]));
        let s = t.to_json().to_string();
        let t2 = TopologySpec::from_json(&Json::parse(&s).unwrap());
        assert_eq!(t2, t);
        // No-fabric spec: Null round-trips back to None.
        let solo = TopologySpec::default();
        let s2 = solo.to_json().to_string();
        assert_eq!(TopologySpec::from_json(&Json::parse(&s2).unwrap()), solo);
        // Sparse override keeps defaults (including FCFS QoS).
        let sparse = TopologySpec::from_json(&Json::parse(r#"{"devices": 2}"#).unwrap());
        assert_eq!(sparse.devices, 2);
        assert_eq!(sparse.placement, Placement::RoundRobin);
        assert_eq!(sparse.fabric_bw_gbps, None);
        assert_eq!(sparse.qos, QosSpec::fcfs());
    }

    #[test]
    fn qos_spec_json_roundtrip_and_cycling() {
        let q = QosSpec { policy: QosPolicy::Drr, weights: vec![3, 1], floors: vec![0.5, 0.25] };
        let s = q.to_json().to_string();
        assert_eq!(QosSpec::from_json(&Json::parse(&s).unwrap()), q);
        // Sparse qos object keeps defaults.
        let sparse = QosSpec::from_json(&Json::parse(r#"{"policy": "wrr"}"#).unwrap());
        assert_eq!(sparse.policy, QosPolicy::Wrr);
        assert!(sparse.weights.is_empty() && sparse.floors.is_empty());
        // Parameter cycling over tenant ids, with empty-vec defaults.
        assert_eq!(q.weight(0), 3);
        assert_eq!(q.weight(3), 1);
        assert_eq!(sparse.weight(7), 1);
        assert!((q.floor(2) - 0.5).abs() < 1e-12);
        assert!((sparse.floor(2) - 1.0).abs() < 1e-12);
        // Negative floors clamp to zero.
        let neg = QosSpec::drr(vec![-1.0]);
        assert_eq!(neg.floor(0), 0.0);
    }

    #[test]
    fn qos_policy_parse_labels() {
        for p in QosPolicy::ALL {
            assert_eq!(QosPolicy::parse(p.label()), Some(p));
        }
        assert_eq!(QosPolicy::parse("nope"), None);
    }

    #[test]
    fn placement_parse_labels() {
        for p in [Placement::RoundRobin, Placement::LeastLoaded, Placement::Pinned] {
            assert_eq!(Placement::parse(p.label()), Some(p));
        }
        assert_eq!(Placement::parse("nope"), None);
    }

    #[test]
    fn sparse_override_keeps_defaults() {
        let j = Json::parse(r#"{"ccm": {"num_pus": 4}}"#).unwrap();
        let c = SimConfig::from_json(&j);
        assert_eq!(c.ccm.num_pus, 4);
        assert_eq!(c.host.num_pus, 32); // default retained
    }

    #[test]
    fn protocol_parse_round_trips_keys() {
        for p in Protocol::ALL {
            assert_eq!(Protocol::parse(p.key()), Some(p));
        }
        assert_eq!(Protocol::parse("axle_interrupt"), Some(Protocol::AxleInterrupt));
        assert_eq!(Protocol::parse("nope"), None);
    }

    #[test]
    fn device_override_applies_sparse_fields() {
        let base = SimConfig::m2ndp();
        let ov = DeviceOverride { ccm_pus: Some(4), link_bw_gbps: Some(8.0), ..Default::default() };
        assert!(!ov.is_identity());
        assert!(DeviceOverride::default().is_identity());
        let mut cfg = base.clone();
        ov.apply(&mut cfg);
        assert_eq!(cfg.ccm.num_pus, 4);
        assert_eq!(cfg.cxl_bw_gbps, 8.0);
        // Untouched fields survive.
        assert_eq!(cfg.ccm.freq_ghz, base.ccm.freq_ghz);
        assert_eq!(cfg.host.num_pus, base.host.num_pus);
        // JSON round-trip (None fields stay None through Null).
        let j = ov.to_json().to_string();
        assert_eq!(DeviceOverride::from_json(&Json::parse(&j).unwrap()), ov);
    }

    #[test]
    fn heterogeneous_topology_per_device_configs() {
        let base = SimConfig::m2ndp();
        let topo = TopologySpec::shared_fabric(2, base.cxl_bw_gbps)
            .with_override(1, DeviceOverride { ccm_pus: Some(4), ..Default::default() });
        assert!(topo.is_heterogeneous());
        assert!(!TopologySpec::default().is_heterogeneous());
        // Device 0 keeps the base; device 1 is the weak class; a device
        // beyond the override vector keeps the base too.
        assert_eq!(topo.device_config(0, &base).ccm.num_pus, base.ccm.num_pus);
        assert_eq!(topo.device_config(1, &base).ccm.num_pus, 4);
        assert_eq!(topo.device_config(7, &base).ccm.num_pus, base.ccm.num_pus);
        // Distinct classes fingerprint differently (the sched solo pass
        // dedupes per class on this).
        assert_ne!(
            topo.device_config(0, &base).workload_fingerprint(),
            topo.device_config(1, &base).workload_fingerprint()
        );
        // Round-trip with overrides attached.
        let j = topo.to_json().to_string();
        assert_eq!(TopologySpec::from_json(&Json::parse(&j).unwrap()), topo);
    }

    #[test]
    fn policy_kind_parse_labels() {
        for p in PolicyKind::ALL {
            assert_eq!(PolicyKind::parse(&p.label()), Some(p));
        }
        assert_eq!(PolicyKind::parse("static"), Some(PolicyKind::Static(Protocol::Axle)));
        assert_eq!(PolicyKind::parse("static-rp"), Some(PolicyKind::Static(Protocol::Rp)));
        assert_eq!(PolicyKind::parse("nope"), None);
    }

    #[test]
    fn sched_spec_json_roundtrip() {
        let s = SchedSpec::new(6)
            .with_workloads(vec!['a', 'd', 'e'])
            .with_policy(PolicyKind::Static(Protocol::Bs))
            .with_depth(2)
            .with_admit(3)
            .with_priorities(vec![2, 0, 1])
            .with_requests(5)
            .with_think(2 * crate::sim::US)
            .with_seed(99)
            .with_explore(3);
        let j = s.to_json().to_string();
        assert_eq!(SchedSpec::from_json(&Json::parse(&j).unwrap()), s);
        // Priority classes cycle over tenant ids; empty means class 0.
        assert_eq!(s.priority(0), 2);
        assert_eq!(s.priority(4), 0);
        assert_eq!(SchedSpec::new(2).priority(7), 0);
        // Open-loop flag survives too.
        let o = SchedSpec::new(2).open_loop();
        let j2 = o.to_json().to_string();
        assert_eq!(SchedSpec::from_json(&Json::parse(&j2).unwrap()), o);
        // Sparse override keeps the defaults.
        let sparse = SchedSpec::from_json(&Json::parse(r#"{"streams": 3}"#).unwrap());
        assert_eq!(sparse.streams, 3);
        assert_eq!(sparse.policy, PolicyKind::Heuristic);
        assert_eq!(sparse.depth, 1);
        assert!(sparse.closed);
        assert!(sparse.faults.is_empty());
        assert!(sparse.retain);
        assert_eq!(sparse.explore, 8);
        // Streaming mode (retain = false) survives the round trip too.
        let st = SchedSpec::new(2).with_retain(false);
        let j3 = st.to_json().to_string();
        assert_eq!(SchedSpec::from_json(&Json::parse(&j3).unwrap()), st);
    }

    #[test]
    fn fault_spec_json_roundtrip() {
        let f = FaultSpec {
            events: vec![
                FaultEvent::degrade_pus(1, 50 * US, 150 * US, 4.0),
                FaultEvent::degrade_link(0, 10 * US, 20 * US, 2.0),
                FaultEvent::stall(0, 100 * US, 300 * US),
                FaultEvent::fail(1, 800 * US),
            ],
            max_retries: 5,
            backoff: 25 * US,
            timeout_factor: 4.0,
        };
        let j = f.to_json().to_string();
        assert_eq!(FaultSpec::from_json(&Json::parse(&j).unwrap()).unwrap(), f);
        // Through a SchedSpec round-trip too.
        let s = SchedSpec::new(2).with_faults(f.clone());
        let sj = s.to_json().to_string();
        assert_eq!(SchedSpec::from_json(&Json::parse(&sj).unwrap()), s);
        // Sparse fault object keeps the recovery defaults.
        let sparse = FaultSpec::from_json(
            &Json::parse(r#"{"events": [{"device": 0, "kind": "fail", "at_ps": 7}]}"#).unwrap(),
        )
        .unwrap();
        assert_eq!(sparse.events, vec![FaultEvent::fail(0, 7)]);
        assert_eq!(sparse.max_retries, 3);
        assert_eq!(sparse.backoff, 50 * US);
        assert_eq!(sparse.timeout_factor, 8.0);
        assert!(FaultSpec::default().is_empty() && !sparse.is_empty());
    }

    #[test]
    fn fault_spec_parse_rejects_malformed_events() {
        // Unknown kind.
        let j = Json::parse(r#"{"events": [{"device": 0, "kind": "melt", "at_ps": 0}]}"#).unwrap();
        let e = FaultSpec::from_json(&j).unwrap_err();
        assert!(e.contains("fault event 0: unknown kind"), "{e}");
        // Window before t=0 is caught at parse time, not by u64 wrap.
        let j = Json::parse(r#"{"events": [{"device": 0, "kind": "stall", "at_ps": -5}]}"#).unwrap();
        let e = FaultSpec::from_json(&j).unwrap_err();
        assert!(e.contains("window starts before t=0"), "{e}");
        // Missing device id.
        let j = Json::parse(r#"{"events": [{"kind": "fail", "at_ps": 0}]}"#).unwrap();
        let e = FaultSpec::from_json(&j).unwrap_err();
        assert!(e.contains("fault event 0: missing device id"), "{e}");
    }

    #[test]
    fn fault_spec_validate_rejects_doomed_schedules() {
        // Killing every device can never complete the run.
        let kill_all = FaultSpec::with(vec![FaultEvent::fail(0, US), FaultEvent::fail(1, 2 * US)]);
        let e = kill_all.validate(2).unwrap_err();
        assert_eq!(e, "fault spec kills all 2 devices; at least one must survive");
        // One survivor is fine.
        assert!(kill_all.validate(3).is_ok());
        // Device out of range.
        let oob = FaultSpec::with(vec![FaultEvent::stall(5, 0, US)]);
        let e = oob.validate(2).unwrap_err();
        assert_eq!(e, "fault event 0: device 5 out of range (topology has 2 devices)");
        // Window ends before it starts.
        let inverted = FaultSpec::with(vec![FaultEvent::stall(0, 10 * US, US)]);
        let e = inverted.validate(1).unwrap_err();
        assert!(e.contains("window ends at"), "{e}");
        // Degradation factor below 1 would *speed the device up*.
        let speedup = FaultSpec::with(vec![FaultEvent::degrade_pus(0, 0, US, 0.5)]);
        let e = speedup.validate(1).unwrap_err();
        assert!(e.contains("degradation factor 0.5 must be >= 1"), "{e}");
        // Zero-duration windows and empty specs validate.
        assert!(FaultSpec::with(vec![FaultEvent::stall(0, US, US)]).validate(1).is_ok());
        assert!(FaultSpec::default().validate(1).is_ok());
        // A Fail event's `until` is ignored (constructors pin it to `at`).
        assert!(FaultSpec::with(vec![FaultEvent::fail(0, US)]).validate(2).is_ok());
    }

    #[test]
    fn pipeline_spec_validate_rejects_zero_chunks() {
        let e = PipelineSpec::with_chunks(0).validate().unwrap_err();
        assert_eq!(e, "pipeline spec: chunks must be >= 1 (0 chunks would emit no stages)");
        assert!(PipelineSpec::with_chunks(1).validate().is_ok());
        assert!(PipelineSpec::with_chunks(64).validate().is_ok());
        // JSON parsing funnels through the same validation.
        let e = PipelineSpec::from_json(&Json::parse(r#"{"chunks": 0}"#).unwrap()).unwrap_err();
        assert!(e.contains("chunks must be >= 1"), "{e}");
        let e = PipelineSpec::from_json(&Json::parse(r#"{"mode": "warp"}"#).unwrap()).unwrap_err();
        assert!(e.contains("unknown mode"), "{e}");
    }

    #[test]
    fn pipeline_spec_json_roundtrip_and_chunk_helpers() {
        let p = PipelineSpec { chunks: 4, mode: PipelineMode::Pipelined };
        let j = p.to_json().to_string();
        assert_eq!(PipelineSpec::from_json(&Json::parse(&j).unwrap()).unwrap(), p);
        // Sparse object keeps the defaults.
        let sparse = PipelineSpec::from_json(&Json::parse(r#"{"chunks": 2}"#).unwrap()).unwrap();
        assert_eq!(sparse, PipelineSpec::with_chunks(2));
        assert_eq!(sparse.mode, PipelineMode::Auto);
        for m in PipelineMode::ALL {
            assert_eq!(PipelineMode::parse(m.label()), Some(m));
        }
        assert_eq!(PipelineMode::parse("nope"), None);
        // SchedSpec helpers: absent spec means whole-request admission,
        // and the `pipeline` key stays out of the JSON (the PR-7 shape).
        let plain = SchedSpec::new(2);
        assert_eq!(plain.chunks(), 1);
        assert_eq!(plain.chunk_mode(), PipelineMode::Auto);
        assert!(!plain.to_json().to_string().contains("\"pipeline\""));
        // With a spec attached the SchedSpec round-trip carries it.
        let s = SchedSpec::new(2).with_pipeline(p.clone());
        assert_eq!(s.chunks(), 4);
        assert_eq!(s.chunk_mode(), PipelineMode::Pipelined);
        let sj = s.to_json().to_string();
        assert!(sj.contains("\"pipeline\""));
        assert_eq!(SchedSpec::from_json(&Json::parse(&sj).unwrap()), s);
    }
}
