//! Integration tests: whole-system behaviour across protocols, workloads
//! and configurations — the paper's claims as executable assertions.

use axle::config::{poll_factors, Protocol, SchedPolicy, SimConfig};
use axle::metrics::RunMetrics;
use axle::workload::{by_annotation, llm, olap, ALL_ANNOTATIONS};
use axle::{protocol, Coordinator};

fn run(annot: char, proto: Protocol, cfg: &SimConfig) -> RunMetrics {
    protocol::run(proto, &by_annotation(annot, cfg), cfg)
}

// ------------------------------------------------------------------
// Headline claims (abstract / §V-B).
// ------------------------------------------------------------------

#[test]
fn axle_reduces_end_to_end_runtime_up_to_forty_percent_vs_rp() {
    // Paper: up to 50.14% (PageRank). Our substrate reaches >40%; the
    // exact ceiling depends on the T_C share (EXPERIMENTS.md).
    let cfg = SimConfig::m2ndp().with_poll(poll_factors::P1);
    let best = ALL_ANNOTATIONS
        .iter()
        .map(|&a| {
            let rp = run(a, Protocol::Rp, &cfg);
            let ax = run(a, Protocol::Axle, &cfg);
            1.0 - ax.ratio_to(&rp)
        })
        .fold(f64::MIN, f64::max);
    assert!(best > 0.40, "best reduction vs RP = {best}");
}

#[test]
fn axle_never_loses_meaningfully_to_either_baseline() {
    let cfg = SimConfig::m2ndp().with_poll(poll_factors::P1);
    for a in ALL_ANNOTATIONS {
        let rp = run(a, Protocol::Rp, &cfg);
        let bs = run(a, Protocol::Bs, &cfg);
        let ax = run(a, Protocol::Axle, &cfg);
        assert!(!ax.deadlock, "({a}) deadlocked");
        assert!(ax.total as f64 <= 1.02 * rp.total as f64, "({a}) vs RP");
        assert!(ax.total as f64 <= 1.02 * bs.total as f64, "({a}) vs BS");
    }
}

#[test]
fn axle_reduces_both_idle_times_on_average() {
    // Paper: CCM idle ↓ 13.99×/14.53× and host idle ↓ 3.93×/3.85× on
    // average. Assert substantial average reductions (> 3× CCM, > 2× host).
    let cfg = SimConfig::m2ndp().with_poll(poll_factors::P10);
    let mut ccm_red = Vec::new();
    let mut host_red = Vec::new();
    for a in ALL_ANNOTATIONS {
        let rp = run(a, Protocol::Rp, &cfg);
        let ax = run(a, Protocol::Axle, &cfg);
        let ratio = |idle_base: u64, total_base: u64, idle_ax: u64, total_ax: u64| {
            (idle_base as f64 / total_base as f64) / (idle_ax.max(1) as f64 / total_ax as f64)
        };
        ccm_red.push(ratio(rp.ccm_idle(), rp.total, ax.ccm_idle(), ax.total));
        host_red.push(ratio(rp.host_idle(), rp.total, ax.host_idle(), ax.total));
    }
    let avg = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
    assert!(avg(&ccm_red) > 3.0, "avg CCM idle reduction {:.2}x", avg(&ccm_red));
    assert!(avg(&host_red) > 2.0, "avg host idle reduction {:.2}x", avg(&host_red));
}

#[test]
fn axle_cuts_host_core_stall_time_severalfold_vs_bs() {
    // Paper Fig. 13: up to 6× reduction; BS stalls ≈ the whole runtime.
    let cfg = SimConfig::m2ndp().with_poll(poll_factors::P10);
    let mut best = 0.0f64;
    for a in ALL_ANNOTATIONS {
        let bs = run(a, Protocol::Bs, &cfg);
        let ax = run(a, Protocol::Axle, &cfg);
        let bs_frac = bs.host_stall_clamped() as f64 / bs.total as f64;
        let ax_frac = ax.host_stall_clamped() as f64 / ax.total as f64;
        best = best.max(bs_frac / ax_frac.max(1e-9));
        assert!(bs_frac > ax_frac, "({a}) AXLE must stall less than BS");
    }
    // BS stalls the host for T_C + T_D: near-total for CCM/DM-bound cases.
    let e_bs = run('e', Protocol::Bs, &cfg);
    assert!(e_bs.frac(e_bs.host_stall_clamped()) > 0.9);
    assert!(best > 3.0, "best stall reduction {best:.2}x");
}

// ------------------------------------------------------------------
// Duality (§III): RP vs BS trade-off.
// ------------------------------------------------------------------

#[test]
fn bs_dominates_rp_for_fine_grained_light_kernels() {
    let cfg = SimConfig::m2ndp();
    for k in [llm::AttnKernel::LayerNormQ, llm::AttnKernel::Residual] {
        let w = llm::single_kernel(&cfg, k);
        let rp = protocol::run(Protocol::Rp, &w, &cfg);
        let bs = protocol::run(Protocol::Bs, &w, &cfg);
        let ratio = bs.total as f64 / rp.total as f64;
        assert!(ratio < 0.5, "{}: BS/RP = {ratio}", k.label());
    }
}

#[test]
fn bs_and_rp_converge_for_heavy_kernels() {
    let cfg = SimConfig::m2ndp();
    let w = llm::single_kernel(&cfg, llm::AttnKernel::QkvProj);
    let rp = protocol::run(Protocol::Rp, &w, &cfg);
    let bs = protocol::run(Protocol::Bs, &w, &cfg);
    let ratio = bs.total as f64 / rp.total as f64;
    assert!(ratio > 0.97, "QKVProj: BS/RP = {ratio}");
}

// ------------------------------------------------------------------
// §III-C: the two idle times of serialized pipelines.
// ------------------------------------------------------------------

#[test]
fn serialized_pipelines_idle_identity() {
    // For BS, host idle ≈ T_C + T_D.
    let mut cfg = SimConfig::m2ndp();
    cfg.jitter = 0.0;
    for a in ['a', 'e', 'f'] {
        let m = run(a, Protocol::Bs, &cfg);
        let host_idle = m.host_idle() as f64;
        let expect = (m.ccm_busy + m.dm_busy) as f64;
        let err = (host_idle - expect).abs() / m.total as f64;
        assert!(err < 0.05, "({a}) host idle {host_idle} vs T_C+T_D {expect}");
    }
}

// ------------------------------------------------------------------
// Interrupt notification (§IV-A / §V-B).
// ------------------------------------------------------------------

#[test]
fn interrupts_hurt_fine_grained_but_not_heavy_workloads() {
    let cfg = SimConfig::m2ndp();
    // (a) KNN: fine-grained -> interrupt delay dominates.
    let a_int = run('a', Protocol::AxleInterrupt, &cfg);
    let a_rp = run('a', Protocol::Rp, &cfg);
    assert!(a_int.total > 2 * a_rp.total, "(a) interrupt should blow up");
    // (e) PageRank: long tasks hide interrupt latency.
    let e_int = run('e', Protocol::AxleInterrupt, &cfg);
    let e_rp = run('e', Protocol::Rp, &cfg);
    assert!(
        (e_int.total as f64) < 0.8 * e_rp.total as f64,
        "(e) interrupt variant should still beat RP"
    );
}

// ------------------------------------------------------------------
// Fig. 11: reduced hardware makes the LLM case overlap-friendly.
// ------------------------------------------------------------------

#[test]
fn llm_marginal_on_baseline_but_wins_on_reduced_hardware() {
    let base = SimConfig::m2ndp().with_poll(poll_factors::P10);
    let rp = run('h', Protocol::Rp, &base);
    let ax = run('h', Protocol::Axle, &base);
    let ratio = ax.ratio_to(&rp);
    assert!(ratio > 0.97, "baseline LLM should be marginal, got {ratio}");

    let reduced = SimConfig::reduced().with_poll(poll_factors::P10);
    let rp_r = run('h', Protocol::Rp, &reduced);
    let ax_r = run('h', Protocol::Axle, &reduced);
    let ratio_r = ax_r.ratio_to(&rp_r);
    assert!(ratio_r < 0.9, "reduced-HW LLM should benefit, got {ratio_r}");
}

// ------------------------------------------------------------------
// Fig. 14: streaming factor.
// ------------------------------------------------------------------

#[test]
fn huge_streaming_factor_degrades_to_bulk_behavior() {
    // SF = 100% of a KNN query's result defeats overlap: runtime drifts
    // toward (and can exceed) SF1.
    let cfg = SimConfig::m2ndp();
    let w = by_annotation('a', &cfg);
    let sf1 = protocol::run(Protocol::Axle, &w, &cfg);
    let mut big = cfg.clone();
    big.axle.streaming_factor_bytes = w.iters[0].result_bytes();
    let sfall = protocol::run(Protocol::Axle, &w, &big);
    assert!(sfall.total > sf1.total, "SF_100% {} <= SF1 {}", sfall.total, sf1.total);
}

#[test]
fn moderate_streaming_factors_are_safe() {
    // SF2..SF32 stay within a few percent of SF1 (natural batching).
    let cfg = SimConfig::m2ndp();
    for a in ['d', 'i'] {
        let w = by_annotation(a, &cfg);
        let base = protocol::run(Protocol::Axle, &w, &cfg);
        for sf in [64u64, 256, 1024] {
            let mut c = cfg.clone();
            c.axle.streaming_factor_bytes = sf;
            let m = protocol::run(Protocol::Axle, &w, &c);
            let ratio = m.total as f64 / base.total as f64;
            assert!(ratio < 1.1, "({a}) SF{} ratio {ratio}", sf / 32);
        }
    }
}

// ------------------------------------------------------------------
// Fig. 15 / Fig. 16 ablations.
// ------------------------------------------------------------------

#[test]
fn disabling_ooo_streaming_hurts_under_rr_not_fifo() {
    let cfg = SimConfig::m2ndp();
    for a in ['d', 'e'] {
        let mut rr_on = cfg.clone();
        rr_on.sched = SchedPolicy::RoundRobin;
        let mut rr_off = rr_on.clone();
        rr_off.axle.ooo_streaming = false;
        let w = by_annotation(a, &cfg);
        let on = protocol::run(Protocol::Axle, &w, &rr_on);
        let off = protocol::run(Protocol::Axle, &w, &rr_off);
        assert!(
            off.total as f64 > 1.15 * on.total as f64,
            "({a}) RR OoO-off should cost >15%: {} vs {}",
            off.total,
            on.total
        );

        let mut fifo_on = cfg.clone();
        fifo_on.sched = SchedPolicy::Fifo;
        let mut fifo_off = fifo_on.clone();
        fifo_off.axle.ooo_streaming = false;
        let f_on = protocol::run(Protocol::Axle, &w, &fifo_on);
        let f_off = protocol::run(Protocol::Axle, &w, &fifo_off);
        let ratio = f_off.total as f64 / f_on.total as f64;
        assert!(ratio < 1.05, "({a}) FIFO should be insensitive, got {ratio}");
    }
}

#[test]
fn llm_deadlocks_at_eighth_capacity_and_only_llm() {
    let mut cfg = SimConfig::m2ndp();
    cfg.axle.dma_slot_capacity /= 8;
    for a in ALL_ANNOTATIONS {
        let m = run(a, Protocol::Axle, &cfg);
        if a == 'h' {
            assert!(m.deadlock, "(h) must deadlock at 12.5% capacity (Fig. 16)");
        } else {
            assert!(!m.deadlock, "({a}) must not deadlock at 12.5% capacity");
        }
    }
}

#[test]
fn backpressure_appears_under_tight_capacity_without_slowdown() {
    // Fig. 16: (d) absorbs heavy back-pressure with ~no runtime change.
    let cfg = SimConfig::m2ndp();
    let base = run('d', Protocol::Axle, &cfg);
    let mut tight = cfg.clone();
    tight.axle.dma_slot_capacity /= 8;
    let m = run('d', Protocol::Axle, &tight);
    assert!(!m.deadlock);
    assert!(m.backpressure > 0);
    assert!(
        (m.total as f64) < 1.1 * base.total as f64,
        "back-pressure amortized: {} vs {}",
        m.total,
        base.total
    );
}

// ------------------------------------------------------------------
// Determinism & config plumbing.
// ------------------------------------------------------------------

#[test]
fn identical_configs_are_bit_deterministic() {
    let cfg = SimConfig::m2ndp();
    for a in ['b', 'e', 'h'] {
        for p in Protocol::ALL {
            let m1 = run(a, p, &cfg);
            let m2 = run(a, p, &cfg);
            assert_eq!(m1.total, m2.total, "({a}) {}", p.label());
            assert_eq!(m1.host_stall, m2.host_stall);
            assert_eq!(m1.events, m2.events);
        }
    }
}

#[test]
fn different_seeds_change_axle_timelines() {
    // Use the CCM-bound DLRM (i): its critical path ends at jittered CCM
    // completions. (PageRank's AXLE total is wire-saturated and KNN's is
    // gated by the unjittered serial top-k chain — totals there are
    // legitimately seed-invariant.)
    let mut c1 = SimConfig::m2ndp();
    let mut c2 = SimConfig::m2ndp();
    c1.seed = 1;
    c2.seed = 2;
    let m1 = protocol::run(Protocol::Axle, &by_annotation('i', &c1), &c1);
    let m2 = protocol::run(Protocol::Axle, &by_annotation('i', &c2), &c2);
    assert_ne!(m1.total, m2.total);
}

#[test]
fn coordinator_matrix_and_counters() {
    let mut cfg = SimConfig::m2ndp();
    cfg.axle.poll_interval = poll_factors::P1;
    let coord = Coordinator::new(cfg);
    let ms = coord.run_matrix(&[Protocol::Axle]);
    assert_eq!(ms.len(), 9);
    for m in &ms {
        assert!(m.result_bytes > 0);
        assert!(m.dma_batches > 0);
        assert!(m.fc_messages > 0);
    }
}

// ------------------------------------------------------------------
// Real-hardware profile (Fig. 4 trend).
// ------------------------------------------------------------------

#[test]
fn real_hw_knn_host_share_grows_with_rows() {
    let cfg = SimConfig::real_hw();
    let share = |dim, rows| {
        let w = axle::workload::knn::generate_queries(&cfg, dim, rows, 4);
        let m = protocol::run(Protocol::Rp, &w, &cfg);
        m.host_busy as f64 / (m.ccm_busy + m.host_busy) as f64
    };
    let high_dim = share(2048, 128);
    let low_dim = share(32, 4096);
    assert!(low_dim > 0.5, "low-dim KNN should be host-heavy, got {low_dim}");
    assert!(low_dim > 2.0 * high_dim);
}

// ------------------------------------------------------------------
// OLAP selectivity plumbing.
// ------------------------------------------------------------------

#[test]
fn ssb_queries_differ_only_in_host_selected_work() {
    let cfg = SimConfig::m2ndp();
    let f = protocol::run(Protocol::Bs, &olap::ssb_q1(&cfg, olap::SsbQuery::Q1_1), &cfg);
    let g = protocol::run(Protocol::Bs, &olap::ssb_q1(&cfg, olap::SsbQuery::Q1_2), &cfg);
    // Q1.2 selects ~30× fewer rows: slightly less host work, same scans.
    assert!(g.host_busy < f.host_busy);
    assert_eq!(f.result_bytes, g.result_bytes);
}

// ------------------------------------------------------------------
// Extension: dynamic streaming-factor selection (§V-E future work).
// ------------------------------------------------------------------

#[test]
fn adaptive_sf_avoids_pathological_batching_and_cuts_dma_requests() {
    use axle::config::SfPolicy;
    let cfg = SimConfig::m2ndp();
    for a in ['a', 'd', 'e', 'i'] {
        let w = by_annotation(a, &cfg);
        let fixed = protocol::run(Protocol::Axle, &w, &cfg);
        // Pathological fixed setting: SF = an entire iteration's result.
        let mut big = cfg.clone();
        big.axle.streaming_factor_bytes = w.iters[0].result_bytes();
        let worst = protocol::run(Protocol::Axle, &w, &big);
        let mut ad = cfg.clone();
        ad.axle.sf_policy = SfPolicy::Adaptive;
        let adaptive = protocol::run(Protocol::Axle, &w, &ad);
        assert!(!adaptive.deadlock);
        // Within 25% of SF1 everywhere...
        assert!(
            (adaptive.total as f64) < 1.25 * fixed.total as f64,
            "({a}) adaptive {} vs SF1 {}",
            adaptive.total,
            fixed.total
        );
        // ...and never worse than the pathological fixed choice by >5%.
        assert!(
            (adaptive.total as f64) < 1.05 * worst.total as f64,
            "({a}) adaptive {} vs SF_100% {}",
            adaptive.total,
            worst.total
        );
        // Fewer DMA requests than SF1 (link-sharing benefit).
        assert!(
            adaptive.dma_batches <= fixed.dma_batches,
            "({a}) adaptive batches {} vs SF1 {}",
            adaptive.dma_batches,
            fixed.dma_batches
        );
    }
}

#[test]
fn adaptive_sf_is_deterministic() {
    use axle::config::SfPolicy;
    let mut cfg = SimConfig::m2ndp();
    cfg.axle.sf_policy = SfPolicy::Adaptive;
    let w = by_annotation('e', &cfg);
    let a = protocol::run(Protocol::Axle, &w, &cfg);
    let b = protocol::run(Protocol::Axle, &w, &cfg);
    assert_eq!(a.total, b.total);
    assert_eq!(a.dma_batches, b.dma_batches);
}
