//! Sweep determinism: the parallel executor must be bit-identical to
//! the serial path — same `RunMetrics`, same JSON, for every worker
//! count. Each simulation is a pure function of (workload, protocol,
//! config), so any divergence here means shared mutable state leaked
//! into the engine.

use axle::config::{poll_factors, Protocol, SimConfig};
use axle::metrics::RunMetrics;
use axle::sweep::{ConfigDelta, SweepSpec};
use axle::util::prop::run_prop;
use axle::workload::ALL_ANNOTATIONS;
use axle::Coordinator;

fn jsons(ms: &[RunMetrics]) -> Vec<String> {
    ms.iter().map(|m| m.to_json().to_string()).collect()
}

#[test]
fn parallel_sweep_bit_identical_to_serial_matrix() {
    // All 9 workloads × all 4 protocols, against the pre-sweep serial
    // reference loop, at 1, 2 and 8 workers.
    let cfg = SimConfig::m2ndp();
    let coord = Coordinator::new(cfg.clone());
    let baseline = jsons(&coord.run_matrix_serial(&Protocol::ALL));
    let spec =
        SweepSpec::matrix(cfg, &ALL_ANNOTATIONS, &Protocol::ALL, &[ConfigDelta::identity()]);
    for threads in [1usize, 2, 8] {
        let got = jsons(&spec.run(threads));
        assert_eq!(got.len(), baseline.len());
        for (i, (g, b)) in got.iter().zip(&baseline).enumerate() {
            assert_eq!(g, b, "threads={threads}, point {i}");
        }
    }
}

#[test]
fn sweep_with_deltas_matches_direct_cloned_config_runs() {
    // Poll-factor deltas must reproduce the clone-and-override pattern
    // the report code used before the sweep engine existed.
    let cfg = SimConfig::m2ndp();
    let deltas = [
        ConfigDelta::identity().with_poll(poll_factors::P1),
        ConfigDelta::identity().with_poll(poll_factors::P100),
    ];
    let spec = SweepSpec::matrix(cfg.clone(), &['a', 'e'], &[Protocol::Axle], &deltas);
    let ms = spec.run(8);
    let mut k = 0;
    for a in ['a', 'e'] {
        let w = axle::workload::by_annotation(a, &cfg);
        for p in [poll_factors::P1, poll_factors::P100] {
            let direct_cfg = cfg.clone().with_poll(p);
            let direct = axle::protocol::run(Protocol::Axle, &w, &direct_cfg);
            assert_eq!(
                ms[k].to_json().to_string(),
                direct.to_json().to_string(),
                "workload {a}, poll {p}"
            );
            k += 1;
        }
    }
}

#[test]
fn prop_random_subsets_identical_across_worker_counts() {
    // Property flavor: random workload subsets, protocols, and deltas —
    // jobs ∈ {2, 8} must match jobs = 1 exactly.
    run_prop("sweep_worker_count_invariance", 6, |rng| {
        let cfg = SimConfig::m2ndp();
        let all = ALL_ANNOTATIONS;
        let w1 = all[rng.below(all.len() as u64) as usize];
        let w2 = all[rng.below(all.len() as u64) as usize];
        let protos = [Protocol::ALL[rng.below(4) as usize], Protocol::Bs];
        let mut delta = ConfigDelta::identity();
        if rng.next_f64() < 0.5 {
            delta = delta.with_poll(poll_factors::P1);
        }
        if rng.next_f64() < 0.5 {
            delta = delta.with_sf(rng.range(32, 2048));
        }
        if rng.next_f64() < 0.3 {
            delta = delta.with_seed(rng.next_u64());
        }
        let spec = SweepSpec::matrix(cfg, &[w1, w2], &protos, &[delta]);
        let serial = jsons(&spec.run(1));
        for threads in [2usize, 8] {
            assert_eq!(jsons(&spec.run(threads)), serial, "threads={threads}");
        }
    });
}
